#!/usr/bin/env bash
# Perf-regression gate over two bench-harness JSON snapshots.
#
#   scripts/perf_compare.sh OLD.json NEW.json
#
# Each input is the one-row-per-line format the in-tree bench harness
# writes (rust/src/bench_harness.rs / BENCH_quant.json):
#
#   {"name": "...", "iters": N, "ns_per_iter": X, "ns_min": X,
#    "per_sec": P, "ts": EPOCH, "git_rev": "abc1234"}
#
# For every row present in BOTH files, ns_per_iter is compared; a row
# that got slower by more than the noise threshold fails the gate.
# Rows only in NEW are informational (new benches are fine); rows only
# in OLD are a warning by default — a bench silently disappearing is
# how perf coverage rots.
#
# Knobs (env):
#   PERF_COMPARE_THRESHOLD       allowed slowdown in percent (default 10)
#   PERF_COMPARE_OVERRIDES       file of per-bench thresholds, one per
#                                line: "<percent> <bench name...>"
#                                (name may contain spaces; '#' comments
#                                and blank lines ignored)
#   PERF_COMPARE_STRICT_MISSING  1 = rows missing from NEW fail too
#
# Exit codes:
#   0   within threshold
#   2   usage / unreadable input
#   20  at least one regression (or strict-missing violation)
set -euo pipefail

usage() {
  echo "usage: $0 OLD.json NEW.json" >&2
  echo "  (bench-harness snapshots; see rust/src/bench_harness.rs)" >&2
  exit 2
}

[[ $# -eq 2 ]] || usage
OLD="$1"
NEW="$2"
for f in "$OLD" "$NEW"; do
  if [[ ! -s "$f" ]]; then
    echo "perf_compare: ERROR: '$f' is missing or empty" >&2
    exit 2
  fi
done

THRESHOLD="${PERF_COMPARE_THRESHOLD:-10}"
OVERRIDES="${PERF_COMPARE_OVERRIDES:-}"
STRICT_MISSING="${PERF_COMPARE_STRICT_MISSING:-0}"

if [[ -n "$OVERRIDES" && ! -r "$OVERRIDES" ]]; then
  echo "perf_compare: ERROR: PERF_COMPARE_OVERRIDES='$OVERRIDES' is not readable" >&2
  exit 2
fi

rc=0
awk -v threshold="$THRESHOLD" -v overrides="$OVERRIDES" \
    -v strict="$STRICT_MISSING" -v oldfile="$OLD" -v newfile="$NEW" '
# Minimal field extraction for the harness line format (flat object,
# ": "-separated) — same contract read_entries() relies on in Rust.
function jstr(line, key,    pat, i, s) {
  pat = "\"" key "\": \""
  i = index(line, pat)
  if (i == 0) return ""
  s = substr(line, i + length(pat))
  i = index(s, "\"")
  return (i > 0) ? substr(s, 1, i - 1) : ""
}
function jnum(line, key,    pat, i, s) {
  pat = "\"" key "\": "
  i = index(line, pat)
  if (i == 0) return ""
  s = substr(line, i + length(pat))
  sub(/[,}].*$/, "", s)
  return s + 0
}
function provenance(rev, ts) {
  if (rev == "" && ts == 0) return "(no provenance stamps)"
  return sprintf("(rev %s, ts %d)", (rev == "" ? "?" : rev), ts)
}
BEGIN {
  # per-bench threshold overrides: "<percent> <name with spaces>"
  if (overrides != "") {
    while ((getline line < overrides) > 0) {
      sub(/^[ \t]+/, "", line)
      if (line == "" || line ~ /^#/) continue
      sp = index(line, " ")
      if (sp == 0) continue
      over[substr(line, sp + 1)] = substr(line, 1, sp - 1) + 0
    }
    close(overrides)
  }
}
NR == FNR {
  if (index($0, "\"name\"") == 0) next
  name = jstr($0, "name")
  old_ns[name] = jnum($0, "ns_per_iter")
  old_rev = jstr($0, "git_rev"); old_ts = jnum($0, "ts")
  next
}
{
  if (index($0, "\"name\"") == 0) next
  name = jstr($0, "name")
  new_ns[name] = jnum($0, "ns_per_iter")
  new_rev = jstr($0, "git_rev"); new_ts = jnum($0, "ts")
}
END {
  printf "perf_compare: old %s %s\n", oldfile, provenance(old_rev, old_ts)
  printf "perf_compare: new %s %s\n", newfile, provenance(new_rev, new_ts)
  bad = 0; compared = 0
  for (name in old_ns) {
    if (!(name in new_ns)) {
      missing++
      printf "  MISSING   %-60s (in old only)\n", name
      if (strict != 0) bad++
      continue
    }
    o = old_ns[name]; n = new_ns[name]
    compared++
    if (o <= 0) {
      printf "  SKIP      %-60s old ns_per_iter is 0\n", name
      continue
    }
    pct = (n - o) / o * 100.0
    lim = (name in over) ? over[name] : threshold + 0
    if (pct > lim) {
      bad++
      printf "  REGRESSED %-60s %12.1f -> %12.1f ns/iter  (%+.1f%% > %.1f%%)\n", \
        name, o, n, pct, lim
    } else if (pct < -lim) {
      printf "  improved  %-60s %12.1f -> %12.1f ns/iter  (%+.1f%%)\n", name, o, n, pct
    } else {
      printf "  ok        %-60s %12.1f -> %12.1f ns/iter  (%+.1f%%)\n", name, o, n, pct
    }
  }
  for (name in new_ns) if (!(name in old_ns)) {
    printf "  new       %-60s %12.1f ns/iter (no baseline)\n", name, new_ns[name]
  }
  if (compared == 0 && missing == 0) {
    print "perf_compare: ERROR: no comparable rows found" > "/dev/stderr"
    exit 2
  }
  printf "perf_compare: %d compared, %d regressed (threshold %.1f%%)\n", \
    compared, bad, threshold + 0
  if (bad > 0) exit 20
}
' "$OLD" "$NEW" || rc=$?

exit "$rc"
