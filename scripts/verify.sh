#!/usr/bin/env bash
# Tier-1 verification + quick-mode bench smoke.
#
#   scripts/verify.sh            # build + tests + 1-iter bench smoke
#   VERIFY_SKIP_BENCH=1 ...      # tier-1 only
#   VERIFY_REQUIRE_TOOLCHAIN=1   # hard-fail when cargo is missing
#
# The bench smoke runs every CPU-only bench with IRQLORA_BENCH_QUICK=1
# (one measured iteration each) so perf-path regressions — panics,
# non-termination, broken bench-JSON emission — fail loudly in CI even
# when full benchmarking is too slow. The smoke's JSON goes to a
# scratch path (IRQLORA_BENCH_JSON) so 1-iteration noise never
# overwrites measured rows in the tracked BENCH_quant.json; only real
# `cargo bench` runs (no QUICK/JSON override) update the tracked file.
# IRQLORA_THREADS is pinned for determinism unless the caller
# overrides it.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== perf_compare.sh self-test (toolchain-free) =="
# The perf gate is pure bash/awk, so it is exercised even in
# containers without cargo: identical snapshots must pass, and a
# synthetic 30% slowdown must fail with the regression exit code (20).
PC_DIR="$(mktemp -d -t irqlora_perf_compare.XXXXXX)"
# (traps replace, not stack — every later trap in this script must
# keep removing $PC_DIR)
trap 'rm -rf "$PC_DIR"' EXIT
cat > "$PC_DIR/old.json" <<'PCEOF'
{"name": "selftest_bench_a", "iters": 100, "ns_per_iter": 1000.0, "ns_min": 990.0, "per_sec": 1000000.0, "ts": 1754500000, "git_rev": "selftest"}
{"name": "selftest_bench_b", "iters": 100, "ns_per_iter": 2000.0, "ns_min": 1900.0, "per_sec": 500000.0, "ts": 1754500000, "git_rev": "selftest"}
PCEOF
sed 's/"ns_per_iter": 1000\.0/"ns_per_iter": 1300.0/' "$PC_DIR/old.json" > "$PC_DIR/regressed.json"
if ! scripts/perf_compare.sh "$PC_DIR/old.json" "$PC_DIR/old.json" >/dev/null; then
  echo "verify.sh: ERROR: perf_compare.sh rejected identical snapshots" >&2
  exit 14
fi
pc_rc=0
scripts/perf_compare.sh "$PC_DIR/old.json" "$PC_DIR/regressed.json" >/dev/null || pc_rc=$?
if [[ "$pc_rc" != 20 ]]; then
  echo "verify.sh: ERROR: perf_compare.sh missed a 30% regression (exit $pc_rc, want 20)" >&2
  exit 14
fi
# Overrides machinery: a per-bench rule loosening the threshold to 40%
# must let the same 30% slowdown pass — this is the code path the
# tracked scripts/perf_overrides.txt rides through.
printf '40 selftest_bench_a\n' > "$PC_DIR/overrides.txt"
if ! PERF_COMPARE_OVERRIDES="$PC_DIR/overrides.txt" \
     scripts/perf_compare.sh "$PC_DIR/old.json" "$PC_DIR/regressed.json" >/dev/null; then
  echo "verify.sh: ERROR: perf_compare.sh ignored a PERF_COMPARE_OVERRIDES rule" >&2
  exit 14
fi
echo "verify.sh: perf_compare self-test OK (identical pass, regression exit 20, overrides honored)"

# Optional real comparison: arm a baseline by copying a measured
# BENCH_quant.json to BENCH_baseline.json; the gate then enforces the
# noise threshold on every verify run. Skipped while either file has
# no harness rows (the tracked file starts as a pending-first-run
# placeholder until a cargo-equipped environment populates it).
if grep -q '"ns_per_iter"' BENCH_baseline.json 2>/dev/null \
   && grep -q '"ns_per_iter"' BENCH_quant.json 2>/dev/null; then
  echo "== perf gate: BENCH_baseline.json vs BENCH_quant.json =="
  # Per-bench noise thresholds (microsecond-scale kernel rows, parallel
  # fan-out jitter) live in the tracked overrides file; a caller-set
  # PERF_COMPARE_OVERRIDES still wins.
  PERF_COMPARE_OVERRIDES="${PERF_COMPARE_OVERRIDES:-scripts/perf_overrides.txt}" \
    scripts/perf_compare.sh BENCH_baseline.json BENCH_quant.json
fi

if ! command -v cargo >/dev/null 2>&1; then
  echo "verify.sh: WARNING: no cargo on PATH — Rust tier-1 skipped." >&2
  echo "verify.sh: (this container lacks the Rust toolchain; see ROADMAP open items)" >&2
  if [[ "${VERIFY_REQUIRE_TOOLCHAIN:-0}" != 0 ]]; then
    exit 3
  fi
  exit 0
fi

export IRQLORA_THREADS="${IRQLORA_THREADS:-4}"

echo "== tier-1: cargo build --release && cargo test -q =="
(cd rust && cargo build --release && cargo test -q)

echo "== pool concurrency battery (IRQLORA_SERVE_WORKERS=4) =="
# Re-run the sharded-serving tests with the worker-count env knob set
# explicitly: the pool must honor IRQLORA_SERVE_WORKERS and the
# eviction/re-merge races stay hot with 4 workers over a capacity-2
# merged cache (the tests pin the cache capacity themselves).
(cd rust && IRQLORA_SERVE_WORKERS=4 cargo test -q --test pool_concurrency)

echo "== pool concurrency battery, legacy scheduler (IRQLORA_SERVE_STEAL=0) =="
# Pin the pre-stealing push-spill scheduler: the kill switch must keep
# the whole battery green (the steal-specific test self-skips), so the
# legacy path stays a supported escape hatch, not dead code.
(cd rust && IRQLORA_SERVE_WORKERS=4 IRQLORA_SERVE_STEAL=0 cargo test -q --test pool_concurrency)

echo "== chaos soak (seeded deterministic fault injection) =="
# The soak battery replays fixed seeds (11/23/47) against the pool with
# a FaultBackend wrapper: every handle must resolve, delivered replies
# must match the serial oracle bit-for-bit, parked depth stays under
# park_bound, and PoolStats counters reconcile exactly with observed
# client outcomes. Also re-run under the legacy scheduler so shedding
# and accounting hold with stealing disabled.
(cd rust && cargo test -q --test chaos_soak)
(cd rust && IRQLORA_SERVE_STEAL=0 cargo test -q --test chaos_soak)

echo "== streaming decode battery (continuous batching vs serial oracle) =="
# Concurrent k-stream bit-identity against both the serial oracle and
# the one-shot fused path, mid-stream deadline shed without poisoning
# co-batched streams, and mid-stream worker death surfacing WorkerDead.
(cd rust && cargo test -q --test streaming_decode)

echo "== backend HAL matrix (irqlora backends + native-backend batteries) =="
# The capability listing must include both in-tree CPU backends; a
# registration/validation regression that drops one would otherwise
# only surface when someone asks for it by name.
BACKENDS_OUT="$(cd rust && cargo run --release --quiet -- backends)"
if ! grep -q '`reference`' <<<"$BACKENDS_OUT" \
   || ! grep -q '`native`' <<<"$BACKENDS_OUT"; then
  echo "verify.sh: ERROR: 'irqlora backends' does not list both reference and native:" >&2
  echo "$BACKENDS_OUT" >&2
  exit 11
fi
# Replay the concurrency + chaos batteries over the native CPU backend
# (the pooled side is built through the HAL's validated factory; the
# serial oracle inside the tests stays pinned to reference, so this is
# a cross-backend bit-identity gate, not just a smoke).
(cd rust && IRQLORA_SERVE_BACKEND=native IRQLORA_SERVE_WORKERS=4 \
  cargo test -q --test pool_concurrency)
(cd rust && IRQLORA_SERVE_BACKEND=native cargo test -q --test chaos_soak)
# One end-to-end CLI run over the native backend.
(cd rust && cargo run --release --quiet -- serve --backend native --workers 2)

echo "== kernel bit-identity battery (packed GEMM vs dequant oracle) =="
# Replay the property sweep with the native backend selected, the
# configuration under which the packed-domain kernels actually carry
# serving traffic: gemm_packed must stay bit-identical to
# dequantize-then-gemm_f32_reference across ragged shapes, partial and
# all-zero blocks, k in {2,3,4,8} and mixed-k planned models, and the
# counting-allocator harness must show the packed path never
# materializing the dequantized matrix.
if ! (cd rust && IRQLORA_SERVE_BACKEND=native cargo test -q --test kernel_identity); then
  echo "verify.sh: ERROR: packed-kernel bit-identity battery failed under the native backend" >&2
  exit 17
fi
if ! (cd rust && cargo test -q --test kernel_alloc); then
  echo "verify.sh: ERROR: packed-kernel allocation discipline battery failed" >&2
  exit 17
fi

echo "== chaos serve smoke (irqlora serve --reference --chaos 7) =="
# One end-to-end CLI run with injected faults: liveness is the gate —
# the command bails nonzero if the pool delivers nothing.
(cd rust && cargo run --release --quiet -- serve --reference --chaos 7)

echo "== telemetry smoke (IRQLORA_TELEMETRY=1 + JSONL + stats verb) =="
# End-to-end over the env knobs (not the test-scoped injection): a
# serve run and a plan run with telemetry on must emit well-formed
# JSONL snapshots containing the expected keys, and `irqlora stats`
# must render the file back. Guards the knob plumbing, the JSONL
# appender, and the exit-time final flush in main().
TELEM_JSONL="$PC_DIR/telem_serve.jsonl"
(cd rust && IRQLORA_TELEMETRY=1 IRQLORA_TELEMETRY_JSONL="$TELEM_JSONL" \
  cargo run --release --quiet -- serve --reference --workers 2 >/dev/null)
if [[ ! -s "$TELEM_JSONL" ]]; then
  echo "verify.sh: ERROR: telemetry serve smoke wrote no JSONL" >&2
  exit 13
fi
if grep -vq '^{.*}$' "$TELEM_JSONL"; then
  echo "verify.sh: ERROR: malformed telemetry JSONL line(s):" >&2
  grep -v '^{.*}$' "$TELEM_JSONL" | head -3 >&2
  exit 13
fi
if ! grep -q '"key": "serve.requests", "value": [1-9]' "$TELEM_JSONL"; then
  echo "verify.sh: ERROR: telemetry JSONL shows no served requests" >&2
  exit 13
fi
if ! grep -q 'hal.forward_time{backend=' "$TELEM_JSONL"; then
  echo "verify.sh: ERROR: telemetry JSONL has no per-backend forward timers" >&2
  exit 13
fi
STATS_OUT="$(cd rust && cargo run --release --quiet -- stats "$TELEM_JSONL")"
if ! grep -q 'serve.requests' <<<"$STATS_OUT"; then
  echo "verify.sh: ERROR: 'irqlora stats' failed to render the JSONL back:" >&2
  echo "$STATS_OUT" >&2
  exit 13
fi
TELEM_PLAN_JSONL="$PC_DIR/telem_plan.jsonl"
(cd rust && IRQLORA_TELEMETRY=1 IRQLORA_TELEMETRY_JSONL="$TELEM_PLAN_JSONL" \
  cargo run --release --quiet -- plan --synthetic --budget 3.0 --check >/dev/null)
if ! grep -q 'plan.chosen_k{k=' "$TELEM_PLAN_JSONL" \
   || ! grep -q 'quant.blocks_quantized{k=' "$TELEM_PLAN_JSONL"; then
  echo "verify.sh: ERROR: plan telemetry lacks plan.chosen_k / quant.blocks_quantized keys" >&2
  exit 13
fi
echo "verify.sh: telemetry smoke OK"

# Formatting gate. Advisory by default (the tree predates the check
# and this container has no rustfmt to normalize it with); set
# VERIFY_FMT_STRICT=1 to hard-fail once `cargo fmt` has run.
if (cd rust && cargo fmt --version >/dev/null 2>&1); then
  echo "== cargo fmt --check =="
  if ! (cd rust && cargo fmt --check); then
    echo "verify.sh: WARNING: cargo fmt --check found unformatted code" >&2
    if [[ "${VERIFY_FMT_STRICT:-0}" != 0 ]]; then
      exit 6
    fi
  fi
else
  echo "verify.sh: rustfmt unavailable — skipping cargo fmt --check" >&2
fi

echo "== planner smoke (plan --synthetic --budget 3.0 --check) =="
# Plans the offline synthetic fixture at an average budget of 3.0 code
# bits/weight; --check asserts the plan stays within budget AND its
# mean code entropy matches or beats the uniform 3-bit ICQ baseline.
(cd rust && cargo run --release --quiet -- plan --synthetic --budget 3.0 --check)

if [[ "${VERIFY_SKIP_BENCH:-0}" == 0 ]]; then
  echo "== bench smoke (IRQLORA_BENCH_QUICK=1) =="
  SMOKE_JSON="$(mktemp -t irqlora_bench_smoke.XXXXXX.json)"
  trap 'rm -f "$SMOKE_JSON"; rm -rf "$PC_DIR"' EXIT
  (
    cd rust
    export IRQLORA_BENCH_QUICK=1
    export IRQLORA_BENCH_JSON="$SMOKE_JSON"
    cargo bench --bench quantize_throughput
    cargo bench --bench iec_merge
    cargo bench --bench icq_overhead
    cargo bench --bench kernel_throughput
    cargo bench --bench plan_throughput
    # serve_latency's PJRT scenarios need `make artifacts` (self-skip
    # when absent), but its reference-backend multi-adapter scenario
    # always runs — the smoke spins up the registry + batch server and
    # must emit per-adapter rows. train_step self-skips w/o artifacts.
    cargo bench --bench serve_latency
    cargo bench --bench train_step
  )
  echo "== bench smoke JSON ($SMOKE_JSON) =="
  if [[ -s "$SMOKE_JSON" ]]; then
    cat "$SMOKE_JSON"
  else
    echo "verify.sh: ERROR: bench smoke JSON was not produced" >&2
    exit 4
  fi
  if ! grep -q "serve_latency multi-adapter" "$SMOKE_JSON"; then
    echo "verify.sh: ERROR: serve_latency smoke emitted no multi-adapter rows" >&2
    echo "verify.sh: (the multi-adapter server path should run without artifacts)" >&2
    exit 5
  fi
  if ! grep -q "serve_latency pool workers=2 worker=" "$SMOKE_JSON"; then
    echo "verify.sh: ERROR: serve_latency smoke emitted no per-worker pool rows" >&2
    echo "verify.sh: (the 2-worker reference-backend pool scenario should run without artifacts)" >&2
    exit 7
  fi
  if ! grep -q "serve_latency fused workers=" "$SMOKE_JSON" \
     || ! grep -q "per-group serial" "$SMOKE_JSON"; then
    echo "verify.sh: ERROR: serve_latency smoke emitted no paired fused/[per-group serial] rows" >&2
    echo "verify.sh: (the fused-vs-serial reference sweep should run without artifacts)" >&2
    exit 8
  fi
  if ! grep -q "serve_latency pool steal=on" "$SMOKE_JSON" \
     || ! grep -q "serve_latency pool steal=off" "$SMOKE_JSON"; then
    echo "verify.sh: ERROR: serve_latency smoke emitted no steal=on/off pool rows" >&2
    exit 9
  fi
  if ! grep -q "serve_latency saturation p50 workers=" "$SMOKE_JSON" \
     || ! grep -q "serve_latency saturation shed workers=" "$SMOKE_JSON"; then
    echo "verify.sh: ERROR: serve_latency smoke emitted no saturation (2x overload) rows" >&2
    echo "verify.sh: (delivered p50/p99 + shed count under admission control should always emit)" >&2
    exit 10
  fi
  if ! grep -q "serve_latency backend=native" "$SMOKE_JSON" \
     || ! grep -q "serve_latency backend=reference" "$SMOKE_JSON"; then
    echo "verify.sh: ERROR: serve_latency smoke emitted no paired backend=native/backend=reference rows" >&2
    echo "verify.sh: (the HAL-built native-vs-reference sweep should run without artifacts)" >&2
    exit 12
  fi
  if ! grep -q "serve_latency streamed ttft p50" "$SMOKE_JSON" \
     || ! grep -q "serve_latency streamed ttft p99" "$SMOKE_JSON" \
     || ! grep -q "serve_latency streamed tokens_per_sec" "$SMOKE_JSON" \
     || ! grep -q "serve_latency oneshot ttft p50" "$SMOKE_JSON"; then
    echo "verify.sh: ERROR: serve_latency smoke emitted no paired streamed/oneshot rows" >&2
    echo "verify.sh: (continuous-batching TTFT p50/p99 + tokens/sec should run without artifacts)" >&2
    exit 15
  fi
  # kernel_throughput must emit every fast row with its [reference
  # serial] twin — spot-check the k sweep across all three sizes plus
  # the dense and merge pairs (exact "name" fields; -F so the bracket
  # suffix is matched literally).
  for kstem in \
    'gemm_packed_nf2 (64x256)' \
    'gemm_packed_nf3 (64x256)' \
    'gemm_packed_nf4 (256x1024)' \
    'gemm_packed_nf8 (512x2048)' \
    'gemm_f32 (256x256x64)' \
    'merge_delta (256x16x256)'; do
    if ! grep -qF "\"name\": \"$kstem [reference serial]\"" "$SMOKE_JSON" \
       || ! grep -qF "\"name\": \"$kstem\"" "$SMOKE_JSON"; then
      echo "verify.sh: ERROR: kernel_throughput smoke lacks the paired '$kstem' rows" >&2
      echo "verify.sh: (every fast kernel row must ship with its [reference serial] twin)" >&2
      exit 16
    fi
  done
  if ! grep -qF '"name": "dequant_then_gemm_nf4 (256x1024)"' "$SMOKE_JSON"; then
    echo "verify.sh: ERROR: kernel_throughput smoke lacks the dequant_then_gemm replaced-path row" >&2
    echo "verify.sh: (the dequantize-then-dense-GEMM baseline documents what gemm_packed replaces)" >&2
    exit 16
  fi
fi

echo "verify.sh: OK"
