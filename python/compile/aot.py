"""AOT compiler: lower every graph to HLO text + write the manifest.

Run once at build time (`make artifacts`); the Rust binary is
self-contained afterwards.

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .config import (
    SIZES,
    base_param_specs,
    config_dict,
    lora_param_specs,
    quantized_param_specs,
)
from .kernels.icq_entropy import icq_entropy_sweep
from .kernels.iec_lora import iec_lora
from .kernels.nf_dequant_matmul import nf_dequant_matmul
from .kernels.quant_block import quant_block

F32 = "f32"
I32 = "i32"
U8 = "u8"

_DTYPES = {F32: jnp.float32, I32: jnp.int32, U8: jnp.uint8}


def spec(shape, dtype=F32, name=""):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default printer
    # elides array constants as '{...}', which xla_extension 0.5.1's
    # text parser silently reads back as ZEROS (e.g. the NF4 codebook
    # becomes all-zero and every downstream number is garbage).
    return comp.as_hlo_text(print_large_constants=True)


def lower_and_write(fn, input_specs, out_dir, fname):
    args = [
        jax.ShapeDtypeStruct(tuple(s["shape"]), _DTYPES[s["dtype"]])
        for s in input_specs
    ]
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, fname)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {fname} ({len(text) / 1e6:.2f} MB, {len(input_specs)} inputs)")
    return text


def graph_entry(fname, input_specs, n_outputs):
    return {"file": fname, "inputs": input_specs, "n_outputs": n_outputs}


def build_size(tag, cfg, out_dir, with_forward_q):
    print(f"[aot] size '{tag}' "
          f"(d={cfg.d_model} L={cfg.n_layers} params={cfg.n_params():,})")
    graphs = {}
    bspecs = base_param_specs(cfg)
    lspecs = lora_param_specs(cfg)
    b, s = cfg.batch, cfg.seq

    # pretrain_step
    ins = (
        [spec(sh, F32, n) for n, sh in bspecs]
        + [spec(sh, F32, f"m.{n}") for n, sh in bspecs]
        + [spec(sh, F32, f"v.{n}") for n, sh in bspecs]
        + [
            spec((), F32, "step"),
            spec((b, s), I32, "tokens"),
            spec((b, s), I32, "targets"),
        ]
    )
    lower_and_write(M.make_pretrain_step(cfg), ins, out_dir, f"pretrain_{tag}.hlo.txt")
    graphs["pretrain_step"] = graph_entry(
        f"pretrain_{tag}.hlo.txt", ins, 1 + 3 * len(bspecs)
    )

    # train_step
    ins = (
        [spec(sh, F32, n) for n, sh in bspecs]
        + [spec(sh, F32, n) for n, sh in lspecs]
        + [spec(sh, F32, f"m.{n}") for n, sh in lspecs]
        + [spec(sh, F32, f"v.{n}") for n, sh in lspecs]
        + [
            spec((), F32, "step"),
            spec((), F32, "m1"),
            spec((), F32, "m2"),
            spec((b, s), I32, "tokens"),
            spec((b, s), I32, "targets"),
        ]
    )
    lower_and_write(M.make_train_step(cfg), ins, out_dir, f"train_{tag}.hlo.txt")
    graphs["train_step"] = graph_entry(
        f"train_{tag}.hlo.txt", ins, 1 + 3 * len(lspecs)
    )

    # forward (eval)
    ins = (
        [spec(sh, F32, n) for n, sh in bspecs]
        + [spec(sh, F32, n) for n, sh in lspecs]
        + [
            spec((), F32, "m1"),
            spec((), F32, "m2"),
            spec((b, s), I32, "tokens"),
        ]
    )
    lower_and_write(M.make_forward(cfg), ins, out_dir, f"forward_{tag}.hlo.txt")
    graphs["forward"] = graph_entry(f"forward_{tag}.hlo.txt", ins, 1)

    # forward_q (fused quantized serving; Pallas in-graph)
    if with_forward_q:
        qspecs = quantized_param_specs(cfg)
        ins = [spec(sh, dt, n) for n, sh, dt in qspecs] + [
            spec((b, s), I32, "tokens")
        ]
        lower_and_write(
            M.make_forward_q(cfg, qspecs), ins, out_dir, f"forward_q_{tag}.hlo.txt"
        )
        graphs["forward_q"] = graph_entry(f"forward_q_{tag}.hlo.txt", ins, 1)

    return {"config": config_dict(cfg), "graphs": graphs}


def build_kernels(out_dir):
    """Standalone kernel artifacts for cross-language parity tests."""
    print("[aot] kernel artifacts")
    kernels = {}

    ins = [spec((64,), F32, "block"), spec((201,), F32, "taus")]
    lower_and_write(
        lambda blk, t: (icq_entropy_sweep(blk, t),), ins, out_dir,
        "kernel_icq_entropy.hlo.txt",
    )
    kernels["icq_entropy"] = graph_entry("kernel_icq_entropy.hlo.txt", ins, 1)

    ins = [spec((1024, 64), F32, "w")]
    lower_and_write(
        lambda w: tuple(quant_block(w)), ins, out_dir, "kernel_quant_block.hlo.txt"
    )
    kernels["quant_block"] = graph_entry("kernel_quant_block.hlo.txt", ins, 2)

    ins = [
        spec((8, 256), F32, "x"),
        spec((256, 16), F32, "l1"),
        spec((16, 256), F32, "l2"),
        spec((), F32, "alpha"),
        spec((), F32, "beta1"),
        spec((), F32, "beta2"),
        spec((), F32, "m1"),
        spec((), F32, "m2"),
    ]
    lower_and_write(
        lambda *a: (iec_lora(*a),), ins, out_dir, "kernel_iec_lora.hlo.txt"
    )
    kernels["iec_lora"] = graph_entry("kernel_iec_lora.hlo.txt", ins, 1)

    ins = [
        spec((4, 64), F32, "x"),
        spec((64, 128), U8, "packed"),
        spec((64, 4), F32, "scales"),
        spec((64, 4), F32, "taus"),
    ]
    lower_and_write(
        lambda *a: (nf_dequant_matmul(*a),), ins, out_dir,
        "kernel_nf_dequant_matmul.hlo.txt",
    )
    kernels["nf_dequant_matmul"] = graph_entry(
        "kernel_nf_dequant_matmul.hlo.txt", ins, 1
    )
    return kernels


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--sizes", default="xs,s,m,l")
    ap.add_argument("--forward-q-sizes", default="xs,s")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    fq = set(args.forward_q_sizes.split(","))
    manifest = {"sizes": {}, "kernels": build_kernels(args.out)}
    for tag in args.sizes.split(","):
        cfg = SIZES[tag]
        manifest["sizes"][tag] = build_size(tag, cfg, args.out, tag in fq)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest.json written to {args.out}")


if __name__ == "__main__":
    main()
