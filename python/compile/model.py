"""L2: NanoLLaMA in JAX — forward, LoRA+IEC, loss, AdamW train steps.

Everything here is traced once by aot.py and shipped to the Rust
coordinator as HLO text; Python never runs at serving/training time.

Graphs built from this module:
- `pretrain_step`: full-parameter AdamW step (produces the "trained
  base weights" the quantization experiments start from);
- `train_step`: QLoRA finetuning step — base weights frozen
  (pre-dequantized on the Rust side), LoRA + IEC trainable, IEC gated
  by runtime masks (m1, m2) so a single graph serves every ablation
  arm of Table 4;
- `forward`: logits for evaluation (same gating);
- `forward_q`: fused quantized serving path — NF4 codes dequantized
  in-kernel (Pallas) + merged LoRA (Eq. 16/17 applied Rust-side).

Parameter order is defined by config.py and recorded in the manifest.
"""

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from .config import (
    ModelConfig,
    PROJ_KINDS,
    base_param_specs,
    lora_param_specs,
    proj_dims,
)
from .kernels.iec_lora import iec_lora as iec_lora_kernel
from .kernels.nf_dequant_matmul import nf_dequant_matmul

# ---------------------------------------------------------------------------
# Optimizer hyper-parameters (paper Appendix B.4)
# ---------------------------------------------------------------------------
ADAM_B1 = 0.9
ADAM_B2 = 0.999  # "beta2 value of 0.999"
ADAM_EPS = 1e-8
GRAD_CLIP = 0.3  # "maximum gradient norm to 0.3" (finetuning, per paper)
LR_FINETUNE = 2e-4  # "learning rate of 2e-4 for models up to 13B"
LR_PRETRAIN = 1e-3
PRETRAIN_CLIP = 1.0  # pretraining needs a looser clip than LoRA finetuning


# ---------------------------------------------------------------------------
# Param plumbing: flat list <-> named dict
# ---------------------------------------------------------------------------
def base_to_dict(cfg: ModelConfig, flat):
    names = [n for n, _ in base_param_specs(cfg)]
    assert len(flat) == len(names), f"{len(flat)} vs {len(names)}"
    return dict(zip(names, flat))


def lora_to_dict(cfg: ModelConfig, flat):
    names = [n for n, _ in lora_param_specs(cfg)]
    assert len(flat) == len(names), f"{len(flat)} vs {len(names)}"
    return dict(zip(names, flat))


def init_base_params(cfg: ModelConfig, seed: int = 0):
    """Numpy init (GPT-2-style scaled normal) — used by pytest; the Rust
    coordinator performs its own identical-distribution init."""
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in base_param_specs(cfg):
        if name.endswith("norm"):
            out.append(np.ones(shape, np.float32))
        else:
            std = 0.02
            if name.endswith(".wo") or name.endswith(".w2"):
                std = 0.02 / math.sqrt(2 * cfg.n_layers)
            out.append(rng.normal(0.0, std, size=shape).astype(np.float32))
    return out


def init_lora_params(cfg: ModelConfig, seed: int = 0):
    """ℓ1 ~ N(0, 1/r), ℓ2 = 0, β = 0 (adapter starts as identity)."""
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in lora_param_specs(cfg):
        if name.endswith("lora_a"):
            out.append(
                rng.normal(0.0, 1.0 / math.sqrt(cfg.rank), size=shape).astype(
                    np.float32
                )
            )
        else:  # lora_b and betas start at zero
            out.append(np.zeros(shape, np.float32))
    return out


# ---------------------------------------------------------------------------
# Model pieces
# ---------------------------------------------------------------------------
def rmsnorm(x, w, eps):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def rope_tables(cfg: ModelConfig):
    hd = cfg.head_dim
    pos = np.arange(cfg.seq)[:, None]
    freqs = cfg.rope_theta ** (-np.arange(0, hd, 2) / hd)
    ang = pos * freqs[None, :]
    return (
        jnp.asarray(np.cos(ang), jnp.float32),
        jnp.asarray(np.sin(ang), jnp.float32),
    )


def apply_rope(x, cos, sin):
    """x: [B, S, H, hd] with hd split into (even, odd) interleaved pairs."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    c = cos[None, :, None, :]
    s = sin[None, :, None, :]
    ro = jnp.stack([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return ro.reshape(x.shape)


def _gcd(a, b):
    while b:
        a, b = b, a % b
    return a


def iec_lora_jnp(x2d, l1, l2, alpha_over_r, beta1, beta2, m1, m2):
    """Differentiable IEC LoRA (Eq. 12-15, tile semantics) on [N, h]."""
    h, r = l1.shape
    o = l2.shape[1]
    xp = x2d @ l1
    g1 = _gcd(h, r)
    pooled1 = x2d.reshape(-1, g1, h // g1).mean(axis=2)
    xp = xp + (m1 * beta1) * jnp.tile(pooled1, (1, r // g1))
    y = xp @ l2
    g2 = _gcd(o, r)
    pooled2 = xp.reshape(-1, g2, r // g2).mean(axis=2)
    y = y + (m2 * beta2) * jnp.tile(pooled2, (1, o // g2))
    return alpha_over_r * y


def _proj(x, w, lora, m1, m2):
    """x: [..., h] -> [..., o]; lora = None or (a, b, alpha_over_r, b1, b2)."""
    y = x @ w
    if lora is not None:
        a, b, aor, b1, b2 = lora
        lead = x.shape[:-1]
        x2d = x.reshape(-1, x.shape[-1])
        y = y + iec_lora_jnp(x2d, a, b, aor, b1, b2, m1, m2).reshape(
            *lead, b.shape[1]
        )
    return y


def _attention(cfg, x, wq, wk, wv, wo, loras, cos, sin, m1, m2):
    b, s, d = x.shape
    nh, hd = cfg.n_heads, cfg.head_dim
    q = _proj(x, wq, loras.get("wq"), m1, m2).reshape(b, s, nh, hd)
    k = _proj(x, wk, loras.get("wk"), m1, m2).reshape(b, s, nh, hd)
    v = _proj(x, wv, loras.get("wv"), m1, m2).reshape(b, s, nh, hd)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, d)
    return _proj(out, wo, loras.get("wo"), m1, m2)


def _ffn(cfg, x, w1, w3, w2, loras, m1, m2):
    gate = _proj(x, w1, loras.get("w1"), m1, m2)
    up = _proj(x, w3, loras.get("w3"), m1, m2)
    return _proj(jax.nn.silu(gate) * up, w2, loras.get("w2"), m1, m2)


def _layer_loras(cfg, lora, i):
    """Collect per-projection LoRA tuples for layer i (or {} if no LoRA)."""
    if lora is None:
        return {}
    aor = cfg.lora_alpha / cfg.rank
    betas = lora["betas"]
    out = {}
    for pi, kind in enumerate(PROJ_KINDS):
        out[kind] = (
            lora[f"l{i}.{kind}.lora_a"],
            lora[f"l{i}.{kind}.lora_b"],
            aor,
            betas[i, pi, 0],
            betas[i, pi, 1],
        )
    return out


def forward_logits(cfg: ModelConfig, base, lora, tokens, m1, m2):
    """Shared decoder body. base/lora are name->tensor dicts; lora may be
    None (pretraining). tokens: [B, S] int32. Returns [B, S, vocab]."""
    cos, sin = rope_tables(cfg)
    x = jnp.take(base["embed"], tokens, axis=0)
    for i in range(cfg.n_layers):
        loras = _layer_loras(cfg, lora, i)
        hx = rmsnorm(x, base[f"l{i}.attn_norm"], cfg.norm_eps)
        x = x + _attention(
            cfg, hx, base[f"l{i}.wq"], base[f"l{i}.wk"], base[f"l{i}.wv"],
            base[f"l{i}.wo"], loras, cos, sin, m1, m2,
        )
        hx = rmsnorm(x, base[f"l{i}.ffn_norm"], cfg.norm_eps)
        x = x + _ffn(
            cfg, hx, base[f"l{i}.w1"], base[f"l{i}.w3"], base[f"l{i}.w2"],
            loras, m1, m2,
        )
    x = rmsnorm(x, base["final_norm"], cfg.norm_eps)
    return x @ base["lm_head"]


def masked_ce_loss(logits, targets):
    """Cross-entropy over positions with target >= 0 (prompt tokens are
    masked with -1 by the data pipeline)."""
    mask = (targets >= 0).astype(jnp.float32)
    safe = jnp.maximum(targets, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# AdamW (functional)
# ---------------------------------------------------------------------------
def adamw_update(params, grads, ms, vs, step, lr, clip=GRAD_CLIP):
    """Global-norm clip + AdamW. All lists positional; step: f32 scalar
    (1-based). Returns (new_params, new_ms, new_vs)."""
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g)) for g in grads) + 1e-12
    )
    scale = jnp.minimum(1.0, clip / gnorm)
    new_p, new_m, new_v = [], [], []
    bc1 = 1.0 - ADAM_B1 ** step
    bc2 = 1.0 - ADAM_B2 ** step
    for p, g, m, v in zip(params, grads, ms, vs):
        g = g * scale
        m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
        v = ADAM_B2 * v + (1.0 - ADAM_B2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        new_p.append(p - lr * mh / (jnp.sqrt(vh) + ADAM_EPS))
        new_m.append(m)
        new_v.append(v)
    return new_p, new_m, new_v


# ---------------------------------------------------------------------------
# Exported graphs
# ---------------------------------------------------------------------------
def make_pretrain_step(cfg: ModelConfig):
    n = len(base_param_specs(cfg))

    def step_fn(*args):
        params = list(args[:n])
        ms = list(args[n : 2 * n])
        vs = list(args[2 * n : 3 * n])
        step, tokens, targets = args[3 * n :]

        def loss_of(plist):
            base = base_to_dict(cfg, plist)
            logits = forward_logits(cfg, base, None, tokens, 0.0, 0.0)
            return masked_ce_loss(logits, targets)

        loss, grads = jax.value_and_grad(loss_of)(params)
        new_p, new_m, new_v = adamw_update(
            params, grads, ms, vs, step, LR_PRETRAIN, clip=PRETRAIN_CLIP
        )
        return tuple([loss] + new_p + new_m + new_v)

    return step_fn


def make_train_step(cfg: ModelConfig):
    nb = len(base_param_specs(cfg))
    nl = len(lora_param_specs(cfg))

    def step_fn(*args):
        base_flat = list(args[:nb])
        lora_flat = list(args[nb : nb + nl])
        ms = list(args[nb + nl : nb + 2 * nl])
        vs = list(args[nb + 2 * nl : nb + 3 * nl])
        step, m1, m2, tokens, targets = args[nb + 3 * nl :]
        base = base_to_dict(cfg, base_flat)

        def loss_of(llist):
            lora = lora_to_dict(cfg, llist)
            logits = forward_logits(cfg, base, lora, tokens, m1, m2)
            return masked_ce_loss(logits, targets)

        loss, grads = jax.value_and_grad(loss_of)(lora_flat)
        new_p, new_m, new_v = adamw_update(
            lora_flat, grads, ms, vs, step, LR_FINETUNE
        )
        return tuple([loss] + new_p + new_m + new_v)

    return step_fn


def make_forward(cfg: ModelConfig):
    nb = len(base_param_specs(cfg))
    nl = len(lora_param_specs(cfg))

    def fwd(*args):
        base = base_to_dict(cfg, list(args[:nb]))
        lora = lora_to_dict(cfg, list(args[nb : nb + nl]))
        m1, m2, tokens = args[nb + nl :]
        return (forward_logits(cfg, base, lora, tokens, m1, m2),)

    return fwd


# ---------------------------------------------------------------------------
# Quantized serving graph (Pallas fused path, merged adapters)
# ---------------------------------------------------------------------------
def _proj_q(x, codes, scales, taus, la, lb):
    """x: [B*S, h]; quantized weight + merged (Eq. 16/17) LoRA."""
    y = nf_dequant_matmul(x, codes, scales, taus)
    return y + (x @ la) @ lb


def make_forward_q(cfg: ModelConfig, specs):
    names = [s[0] for s in specs]

    def fwd(*args):
        p = dict(zip(names, args[:-1]))
        tokens = args[-1]
        cos, sin = rope_tables(cfg)
        b, s = tokens.shape
        d = cfg.d_model
        nh, hd = cfg.n_heads, cfg.head_dim

        def qproj(x2d, layer, kind):
            pre = f"l{layer}.{kind}"
            return _proj_q(
                x2d,
                p[f"{pre}.codes"],
                p[f"{pre}.scales"],
                p[f"{pre}.taus"],
                p[f"{pre}.lora_a"],
                p[f"{pre}.lora_b"],
            )

        x = jnp.take(p["embed"], tokens, axis=0)
        for i in range(cfg.n_layers):
            hx = rmsnorm(x, p[f"l{i}.attn_norm"], cfg.norm_eps)
            h2 = hx.reshape(b * s, d)
            q = qproj(h2, i, "wq").reshape(b, s, nh, hd)
            k = qproj(h2, i, "wk").reshape(b, s, nh, hd)
            v = qproj(h2, i, "wv").reshape(b, s, nh, hd)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
            att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
            mask = jnp.tril(jnp.ones((s, s), bool))
            att = jnp.where(mask[None, None], att, -1e30)
            att = jax.nn.softmax(att, axis=-1)
            out = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b * s, d)
            x = x + qproj(out, i, "wo").reshape(b, s, d)

            hx = rmsnorm(x, p[f"l{i}.ffn_norm"], cfg.norm_eps)
            h2 = hx.reshape(b * s, d)
            gate = qproj(h2, i, "w1")
            up = qproj(h2, i, "w3")
            y = qproj(jax.nn.silu(gate) * up, i, "w2")
            x = x + y.reshape(b, s, d)

        x = rmsnorm(x, p["final_norm"], cfg.norm_eps)
        return (x @ p["lm_head"],)

    return fwd
