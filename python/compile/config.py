"""Model configurations and the flat parameter ordering contract.

The Rust coordinator and the AOT-compiled HLO graphs exchange tensors
positionally; this module is the single source of truth for that order.
`aot.py` serializes it into artifacts/manifest.json, which the Rust
side parses (rust/src/runtime/manifest.rs) — neither side hard-codes
the layout.

NanoLLaMA family: LLaMA architecture (RMSNorm, RoPE, SwiGLU MHA
decoder) at synthetic-substitute scales. Size tags are analogues of
the paper's 7B/13B/30B/65B rows (see DESIGN.md §2).
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    d_ff: int = 512
    seq: int = 128
    batch: int = 8
    rank: int = 16           # LoRA r (paper: 64 at d=4096; scaled)
    lora_alpha: float = 16.0 # paper α
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def n_params(self) -> int:
        return sum(int(np_prod(s)) for _, s in base_param_specs(self))


def np_prod(shape):
    out = 1
    for s in shape:
        out *= s
    return out


# Size tags -> paper-row analogues (7B, 13B, 30B, 65B).
SIZES = {
    "xs": ModelConfig(name="xs", d_model=192, n_layers=3, n_heads=6, d_ff=384),
    "s": ModelConfig(name="s", d_model=256, n_layers=4, n_heads=8, d_ff=512),
    "m": ModelConfig(name="m", d_model=320, n_layers=5, n_heads=8, d_ff=640),
    "l": ModelConfig(name="l", d_model=384, n_layers=6, n_heads=8, d_ff=768),
}

# Paper-size label each tag stands in for (used by the table renderers).
PAPER_ANALOG = {"xs": "7B", "s": "13B", "m": "30B", "l": "65B"}

# The seven adapted projections per layer — Figure 5's panel list.
PROJ_KINDS = ("wq", "wk", "wv", "wo", "w1", "w3", "w2")


def proj_dims(cfg: ModelConfig, kind: str):
    """(in_dim, out_dim) of each adapted projection."""
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wq": (d, d),
        "wk": (d, d),
        "wv": (d, d),
        "wo": (d, d),
        "w1": (d, f),
        "w3": (d, f),
        "w2": (f, d),
    }[kind]


def base_param_specs(cfg: ModelConfig):
    """Ordered (name, shape) of all base-model tensors."""
    specs = [("embed", (cfg.vocab, cfg.d_model))]
    for i in range(cfg.n_layers):
        specs.append((f"l{i}.attn_norm", (cfg.d_model,)))
        for kind in ("wq", "wk", "wv", "wo"):
            specs.append((f"l{i}.{kind}", proj_dims(cfg, kind)))
        specs.append((f"l{i}.ffn_norm", (cfg.d_model,)))
        for kind in ("w1", "w3", "w2"):
            specs.append((f"l{i}.{kind}", proj_dims(cfg, kind)))
    specs.append(("final_norm", (cfg.d_model,)))
    specs.append(("lm_head", (cfg.d_model, cfg.vocab)))
    return specs


def lora_param_specs(cfg: ModelConfig):
    """Ordered (name, shape) of all trainable LoRA tensors.

    Per layer, per projection: a (in×r) and b (r×out). One global
    `betas` tensor [n_layers, 7, 2] carries the IEC scalars (β1, β2)
    for every adapted projection.
    """
    specs = []
    for i in range(cfg.n_layers):
        for kind in PROJ_KINDS:
            h, o = proj_dims(cfg, kind)
            specs.append((f"l{i}.{kind}.lora_a", (h, cfg.rank)))
            specs.append((f"l{i}.{kind}.lora_b", (cfg.rank, o)))
    specs.append(("betas", (cfg.n_layers, len(PROJ_KINDS), 2)))
    return specs


def quantized_param_specs(cfg: ModelConfig):
    """Ordered specs for the fused quantized-serving graph (forward_q).

    Every adapted projection weight arrives as NF4 storage: packed
    codes (uint8, two 4-bit codes per byte along the out dim),
    per-64-block scales and τ (f32, already double-dequantized on the
    Rust side). Norms / embeddings / lm_head stay f32 (QLoRA does not
    quantize them either).
    """
    specs = [("embed", (cfg.vocab, cfg.d_model), "f32")]
    for i in range(cfg.n_layers):
        specs.append((f"l{i}.attn_norm", (cfg.d_model,), "f32"))
        for kind in PROJ_KINDS:
            h, o = proj_dims(cfg, kind)
            assert o % 64 == 0, "out dim must be a multiple of the block"
            if kind == "w1":  # keep spec order aligned with base specs
                specs.append((f"l{i}.ffn_norm", (cfg.d_model,), "f32"))
            specs.append((f"l{i}.{kind}.codes", (h, o // 2), "u8"))
            specs.append((f"l{i}.{kind}.scales", (h, o // 64), "f32"))
            specs.append((f"l{i}.{kind}.taus", (h, o // 64), "f32"))
            # merged LoRA adapters (IEC folded in — Eq. 16/17)
            specs.append((f"l{i}.{kind}.lora_a", (h, cfg.rank), "f32"))
            specs.append((f"l{i}.{kind}.lora_b", (cfg.rank, o), "f32"))
    specs.append(("final_norm", (cfg.d_model,), "f32"))
    specs.append(("lm_head", (cfg.d_model, cfg.vocab), "f32"))
    return specs


def config_dict(cfg: ModelConfig):
    return asdict(cfg)
