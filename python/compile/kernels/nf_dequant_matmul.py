"""Pallas kernel: fused NF4 dequantize + matmul — the serving hot spot.

The QLoRA inference insight (keep 4-bit codes resident in fast memory,
dequantize inside the GEMM tile) mapped to the TPU model:

- the grid tiles the output columns (`bn` per program); each program
  streams its `[K, bn/2]` packed-code tile, `[K, bn/64]` scale/τ tiles
  and the full `[B, K]` activation block through VMEM via BlockSpec —
  the Pallas analogue of the CUDA kernel's threadblock schedule;
- the 16-entry NF4 LUT lives as a kernel constant (VMEM), standing in
  for CUDA's shared-memory LUT;
- the dequantized tile feeds `jnp.dot` with f32 accumulation, which on
  real TPU lowers to the MXU systolic array (bf16 matmul units); here
  we keep f32 end-to-end for exact parity with the Rust oracle.

interpret=True lowers to plain HLO at *trace* time — the emitted graph
runs natively through XLA CPU (Mosaic is TPU-only on this image).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NF4_CODEBOOK

# Output-column tile width. 128 matches the MXU lane width; every
# weight matrix in the NanoLLaMA family has out-dim % 128 == 0.
DEFAULT_BN = 128


def _kernel(x_ref, packed_ref, scales_ref, taus_ref, cb_ref, o_ref):
    x = x_ref[...]                      # [B, K]
    packed = packed_ref[...]            # [K, bn/2]
    scales = scales_ref[...]            # [K, bn/64]
    taus = taus_ref[...]                # [K, bn/64]
    cb = cb_ref[...]                    # [16] VMEM-resident LUT

    # unpack two 4-bit codes per byte (low nibble first)
    lo = packed & 0xF
    hi = packed >> 4
    codes = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)

    w = cb[codes]                       # [K, bn]
    s = jnp.repeat(scales, 64, axis=1)
    t = jnp.repeat(taus, 64, axis=1)
    w = w * s + t

    o_ref[...] = jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bn",))
def nf_dequant_matmul(x, packed, scales, taus, bn: int = DEFAULT_BN):
    """y = x @ dequant(packed, scales, taus).

    x: [B, K] f32; packed: [K, N/2] uint8; scales/taus: [K, N/64] f32.
    Returns [B, N] f32.
    """
    b, k = x.shape
    n = packed.shape[1] * 2
    assert n % 64 == 0, "out dim must cover whole 64-blocks"
    bn = min(bn, n)
    if n % bn != 0:
        bn = 64  # every weight out-dim is a multiple of the 64-block
    assert n % bn == 0 and bn % 64 == 0, f"bn={bn} must tile n={n}"

    grid = (n // bn,)
    cb = jnp.asarray(NF4_CODEBOOK)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, k), lambda j: (0, 0)),
            pl.BlockSpec((k, bn // 2), lambda j: (0, j)),
            pl.BlockSpec((k, bn // 64), lambda j: (0, j)),
            pl.BlockSpec((k, bn // 64), lambda j: (0, j)),
            pl.BlockSpec((16,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((b, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,
    )(x, packed, scales, taus, cb)


def vmem_footprint_bytes(b: int, k: int, bn: int = DEFAULT_BN) -> int:
    """Estimated per-program VMEM residency (see DESIGN.md §9):
    activations + packed codes + scales/τ + dequantized tile + output."""
    return (
        b * k * 4               # x
        + k * bn // 2           # packed codes (u8)
        + 2 * k * (bn // 64) * 4  # scales + taus
        + k * bn * 4            # dequantized tile
        + b * bn * 4            # output tile
    )
