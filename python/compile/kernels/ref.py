"""Pure-jnp oracles for every Pallas kernel (L1 correctness anchors).

These are deliberately written in the most transparent way possible —
no tiling, no tricks — and double as the reference semantics the Rust
unit tests mirror. pytest asserts kernel == ref to tight tolerances;
hypothesis sweeps shapes and bit-widths.
"""

import jax.numpy as jnp
import numpy as np

# NF4 codebook — paper Table 13 (must match rust/src/quant/nf.rs).
NF4_CODEBOOK = np.array(
    [
        -1.0,
        -0.6961928009986877,
        -0.5250730514526367,
        -0.39491748809814453,
        -0.28444138169288635,
        -0.18477343022823334,
        -0.09105003625154495,
        0.0,
        0.07958029955625534,
        0.16093020141124725,
        0.24611230194568634,
        0.33791524171829224,
        0.44070982933044434,
        0.5626170039176941,
        0.7229568362236023,
        1.0,
    ],
    dtype=np.float32,
)

# NF3 / NF2 (Tables 12 / 11), used by the quantize oracle sweeps.
NF3_CODEBOOK = np.array(
    [
        -1.0,
        -0.4786292016506195,
        -0.217141792178154,
        0.0,
        0.16093020141124725,
        0.33791524171829224,
        0.5626170039176941,
        1.0,
    ],
    dtype=np.float32,
)

NF2_CODEBOOK = np.array(
    [-1.0, -0.25256848335266113, 0.2525685131549835, 1.0], dtype=np.float32
)


def codebook(k: int) -> np.ndarray:
    return {2: NF2_CODEBOOK, 3: NF3_CODEBOOK, 4: NF4_CODEBOOK}[k]


def boundaries(cb: np.ndarray) -> np.ndarray:
    return (cb[1:] + cb[:-1]) / 2.0


def quantize_codes_ref(x, cb):
    """Nearest-level codes for normalized values x (any shape)."""
    b = jnp.asarray(boundaries(np.asarray(cb)))
    # number of boundaries strictly below x == nearest index (ties to lower)
    return jnp.sum(x[..., None] > b, axis=-1).astype(jnp.uint8)


def quant_block_ref(w):
    """Blockwise NF4 quantization oracle.

    w: [n_blocks, B] f32 -> (codes uint8 [n_blocks, B], scales [n_blocks]).
    """
    amax = jnp.max(jnp.abs(w), axis=1)
    scale = jnp.where(amax > 0, amax, 1.0)
    normed = w / scale[:, None]
    codes = quantize_codes_ref(normed, NF4_CODEBOOK)
    return codes, scale


def unpack_nf4_ref(packed):
    """packed uint8 [K, N/2] -> codes uint8 [K, N] (low nibble first)."""
    lo = packed & 0xF
    hi = packed >> 4
    return jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)


def dequant_ref(packed, scales, taus):
    """NF4 storage -> f32 weight [K, N]. scales/taus: [K, N/64]."""
    codes = unpack_nf4_ref(packed)
    cb = jnp.asarray(NF4_CODEBOOK)
    w = cb[codes]
    s = jnp.repeat(scales, 64, axis=1)
    t = jnp.repeat(taus, 64, axis=1)
    return w * s + t


def nf_dequant_matmul_ref(x, packed, scales, taus):
    """y = x @ dequant(w): the QLoRA fused-inference oracle."""
    return x @ dequant_ref(packed, scales, taus)


def _gcd(a, b):
    while b:
        a, b = b, a % b
    return a


def groupavg_tile_ref(x, groups, dim_out):
    """Average x (last dim) within `groups` segments, tile to dim_out."""
    b, d = x.shape
    seg = d // groups
    pooled = x.reshape(b, groups, seg).mean(axis=2)
    reps = dim_out // groups
    return jnp.tile(pooled, (1, reps))


def iec_lora_ref(x, l1, l2, alpha, beta1, beta2, m1, m2):
    """IEC LoRA forward oracle (paper Eq. 12-15, tile semantics).

    x: [B, h]; l1: [h, r]; l2: [r, o]; scalars broadcastable.
    Matches rust/src/lora/iec.rs::lora_iec_forward.
    """
    h, r = l1.shape
    _, o = l2.shape
    xp = x @ l1
    g1 = _gcd(h, r)
    xp = xp + m1 * beta1 * groupavg_tile_ref(x, g1, r)
    y = xp @ l2
    g2 = _gcd(o, r)
    y = y + m2 * beta2 * groupavg_tile_ref(xp, g2, o)
    return alpha * y


def entropy_ref(codes, k):
    """Shannon entropy (bits) of code histograms along the last axis.

    codes: [..., B] integer; returns [...] f32.
    """
    levels = 1 << k
    onehot = (codes[..., None] == jnp.arange(levels)).astype(jnp.float32)
    p = onehot.sum(axis=-2) / codes.shape[-1]
    plogp = jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0)
    return -plogp.sum(axis=-1)


def icq_entropy_sweep_ref(block, taus):
    """ICQ inner loop oracle: entropy of NF4-quantized (block - tau).

    block: [B] f32; taus: [T] f32 -> [T] f32 entropies.
    """
    shifted = block[None, :] - taus[:, None]  # [T, B]
    amax = jnp.max(jnp.abs(shifted), axis=1, keepdims=True)
    normed = shifted / jnp.where(amax > 0, amax, 1.0)
    codes = quantize_codes_ref(normed, NF4_CODEBOOK)
    return entropy_ref(codes, 4)
