"""Pallas kernel: fused IEC LoRA forward (paper Eq. 12-15).

Computes α·U2(U1(x)) in one kernel: both LoRA matmuls plus the two
parameter-free elastic terms (group-average + tile), gated by the
ablation masks m1/m2. Scalars arrive as (1,1) f32 operands.

Grid: single program — LoRA tiles are tiny (h×r and r×o with r ≤ 64),
the whole working set fits VMEM comfortably; the win is fusing four
elementwise/pool steps into the two small GEMMs.
"""

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _groupavg_tile(x, groups, dim_out):
    b, d = x.shape
    seg = d // groups
    pooled = jnp.mean(x.reshape(b, groups, seg), axis=2)
    return jnp.tile(pooled, (1, dim_out // groups))


def _kernel(x_ref, l1_ref, l2_ref, sc_ref, o_ref):
    x = x_ref[...]          # [B, h]
    l1 = l1_ref[...]        # [h, r]
    l2 = l2_ref[...]        # [r, o]
    alpha = sc_ref[0, 0]
    beta1 = sc_ref[0, 1]
    beta2 = sc_ref[0, 2]
    m1 = sc_ref[0, 3]
    m2 = sc_ref[0, 4]

    h, r = l1.shape
    o = l2.shape[1]
    g1 = math.gcd(h, r)
    g2 = math.gcd(o, r)

    xp = jnp.dot(x, l1, preferred_element_type=jnp.float32)
    xp = xp + m1 * beta1 * _groupavg_tile(x, g1, r)
    y = jnp.dot(xp, l2, preferred_element_type=jnp.float32)
    y = y + m2 * beta2 * _groupavg_tile(xp, g2, o)
    o_ref[...] = alpha * y


@jax.jit
def iec_lora(x, l1, l2, alpha, beta1, beta2, m1, m2):
    """α·U2(U1(x)) with IEC gating. x: [B,h]; l1: [h,r]; l2: [r,o]."""
    b, h = x.shape
    r, o = l2.shape
    scalars = jnp.stack(
        [alpha, beta1, beta2, m1, m2]
    ).astype(jnp.float32).reshape(1, 5)
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
        interpret=True,
    )(x, l1, l2, scalars)
