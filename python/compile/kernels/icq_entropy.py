"""Pallas kernel: ICQ τ-candidate entropy sweep (Algorithm 1 inner loop).

For one weight block and T candidate calibration constants, computes
the Shannon entropy of the NF4 code histogram at every τ in one shot:
shift → normalize → boundary-compare → one-hot histogram → entropy,
vectorized over the candidate axis. This is the Pallas twin of
rust/src/quant/icq.rs::entropy_at, and the exported artifact is used
by the Rust integration suite as a cross-language parity check.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NF4_CODEBOOK, boundaries


def _kernel(block_ref, taus_ref, bounds_ref, h_ref):
    block = block_ref[...]          # [B]
    taus = taus_ref[...]            # [T]
    b = bounds_ref[...]             # [15]
    shifted = block[None, :] - taus[:, None]            # [T, B]
    amax = jnp.max(jnp.abs(shifted), axis=1, keepdims=True)
    normed = shifted / jnp.where(amax > 0, amax, 1.0)
    codes = jnp.sum(normed[..., None] > b, axis=-1)     # [T, B] int32
    onehot = (codes[..., None] == jnp.arange(16)).astype(jnp.float32)
    p = onehot.sum(axis=1) / block.shape[0]             # [T, 16]
    plogp = jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0)
    h_ref[...] = -plogp.sum(axis=-1)


@jax.jit
def icq_entropy_sweep(block, taus):
    """block: [B] f32, taus: [T] f32 -> entropies [T] f32."""
    (t,) = taus.shape
    bounds = jnp.asarray(boundaries(NF4_CODEBOOK))
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        interpret=True,
    )(block, taus, bounds)
