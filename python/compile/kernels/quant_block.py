"""Pallas kernel: blockwise NF4 quantization (paper Eq. 1/8).

Maps each 64-element block to (codes, absmax scale). The nearest-level
search is the branchless comparison-sum over the 15 NF4 decision
boundaries — the vector-unit formulation of the binary search the Rust
hot path uses (rust/src/quant/nf.rs::quantize_one).

Grid tiles the block axis so arbitrarily many blocks stream through
VMEM in chunks of `rows_per_program`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NF4_CODEBOOK, boundaries

ROWS_PER_PROGRAM = 256


def _kernel(w_ref, bounds_ref, codes_ref, scales_ref):
    w = w_ref[...]                                   # [rows, B]
    b = bounds_ref[...]                              # [15]
    amax = jnp.max(jnp.abs(w), axis=1)
    scale = jnp.where(amax > 0, amax, 1.0)
    normed = w / scale[:, None]
    codes = jnp.sum(
        normed[..., None] > b, axis=-1
    ).astype(jnp.uint8)
    codes_ref[...] = codes
    scales_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("rows_per_program",))
def quant_block(w, rows_per_program: int = ROWS_PER_PROGRAM):
    """w: [n_blocks, B] f32 -> (codes uint8 [n_blocks, B], scales [n_blocks])."""
    n, blk = w.shape
    rows = min(rows_per_program, n)
    assert n % rows == 0, f"n_blocks={n} must tile rows={rows}"
    bounds = jnp.asarray(boundaries(NF4_CODEBOOK))
    return pl.pallas_call(
        _kernel,
        grid=(n // rows,),
        in_specs=[
            pl.BlockSpec((rows, blk), lambda i: (i, 0)),
            pl.BlockSpec((15,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((rows, blk), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, blk), jnp.uint8),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(w, bounds)
