"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes and value ranges; fixed-seed cases pin the
exact semantics (code values, not just allclose).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.icq_entropy import icq_entropy_sweep
from compile.kernels.iec_lora import iec_lora
from compile.kernels.nf_dequant_matmul import nf_dequant_matmul, vmem_footprint_bytes
from compile.kernels.quant_block import quant_block

HYPO = dict(max_examples=12, deadline=None)


# ---------------------------------------------------------------------------
# codebooks
# ---------------------------------------------------------------------------
def test_nf4_codebook_matches_paper_table13():
    assert ref.NF4_CODEBOOK.shape == (16,)
    assert ref.NF4_CODEBOOK[0] == -1.0
    assert ref.NF4_CODEBOOK[7] == 0.0
    assert ref.NF4_CODEBOOK[15] == 1.0
    assert abs(ref.NF4_CODEBOOK[14] - 0.7229568362236023) < 1e-9


@pytest.mark.parametrize("k", [2, 3, 4])
def test_codebooks_sorted(k):
    cb = ref.codebook(k)
    assert len(cb) == 1 << k
    assert np.all(np.diff(cb) > 0)


def test_quantize_codes_nearest():
    cb = ref.NF4_CODEBOOK
    codes = np.asarray(ref.quantize_codes_ref(jnp.asarray(cb), cb))
    assert np.array_equal(codes, np.arange(16))


# ---------------------------------------------------------------------------
# quant_block
# ---------------------------------------------------------------------------
def test_quant_block_matches_ref_fixed():
    rng = np.random.default_rng(1)
    w = rng.normal(0, 0.05, size=(512, 64)).astype(np.float32)
    c_k, s_k = quant_block(w)
    c_r, s_r = ref.quant_block_ref(w)
    assert np.array_equal(np.asarray(c_k), np.asarray(c_r))
    assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=0, atol=0)


@settings(**HYPO)
@given(
    n_blocks=st.sampled_from([256, 512, 1024]),
    scale=st.floats(1e-3, 10.0),
    shift=st.floats(-0.5, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_block_hypothesis(n_blocks, scale, shift, seed):
    rng = np.random.default_rng(seed)
    w = (rng.normal(shift, scale, size=(n_blocks, 64))).astype(np.float32)
    c_k, s_k = quant_block(w, rows_per_program=256)
    c_r, s_r = ref.quant_block_ref(w)
    assert np.array_equal(np.asarray(c_k), np.asarray(c_r))
    assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=0, atol=0)


def test_quant_block_zero_block():
    w = np.zeros((256, 64), np.float32)
    c, s = quant_block(w)
    assert np.all(np.asarray(s) == 1.0)
    # zero maps to the zero level (index 7 in NF4)
    assert np.all(np.asarray(c) == 7)


# ---------------------------------------------------------------------------
# nf_dequant_matmul
# ---------------------------------------------------------------------------
def _dq_inputs(rng, b, k, n):
    x = rng.normal(size=(b, k)).astype(np.float32)
    packed = rng.integers(0, 256, size=(k, n // 2)).astype(np.uint8)
    scales = rng.uniform(0.005, 0.1, size=(k, n // 64)).astype(np.float32)
    taus = rng.normal(0, 0.01, size=(k, n // 64)).astype(np.float32)
    return x, packed, scales, taus


def test_dequant_matmul_matches_ref_fixed():
    rng = np.random.default_rng(2)
    x, packed, scales, taus = _dq_inputs(rng, 8, 128, 256)
    got = nf_dequant_matmul(x, packed, scales, taus)
    want = ref.nf_dequant_matmul_ref(x, packed, scales, taus)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(**HYPO)
@given(
    b=st.sampled_from([1, 4, 8]),
    k=st.sampled_from([32, 64, 192]),
    n=st.sampled_from([64, 128, 192, 384]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dequant_matmul_hypothesis(b, k, n, seed):
    rng = np.random.default_rng(seed)
    x, packed, scales, taus = _dq_inputs(rng, b, k, n)
    got = nf_dequant_matmul(x, packed, scales, taus)
    want = ref.nf_dequant_matmul_ref(x, packed, scales, taus)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_unpack_low_nibble_first():
    packed = np.array([[0x21, 0x43]], np.uint8)  # low nibble first: 1,2,3,4
    codes = np.asarray(ref.unpack_nf4_ref(packed))
    assert codes.tolist() == [[1, 2, 3, 4]]


def test_vmem_footprint_under_budget():
    # serving tile must fit comfortably in a 16 MB VMEM (DESIGN.md §9)
    assert vmem_footprint_bytes(b=8, k=768, bn=128) < 16 * 2**20 // 4


# ---------------------------------------------------------------------------
# iec_lora
# ---------------------------------------------------------------------------
@settings(**HYPO)
@given(
    h=st.sampled_from([32, 64, 96, 256]),
    r=st.sampled_from([8, 16]),
    o=st.sampled_from([32, 64, 96, 512]),
    m1=st.sampled_from([0.0, 1.0]),
    m2=st.sampled_from([0.0, 1.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_iec_lora_hypothesis(h, r, o, m1, m2, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(4, h)).astype(np.float32)
    l1 = rng.normal(size=(h, r)).astype(np.float32) * 0.2
    l2 = rng.normal(size=(r, o)).astype(np.float32) * 0.2
    sc = [jnp.float32(v) for v in (1.0, 0.37, -0.21, m1, m2)]
    got = iec_lora(x, l1, l2, *sc)
    want = ref.iec_lora_ref(x, l1, l2, *sc)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_iec_masks_recover_vanilla_lora():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(2, 64)).astype(np.float32)
    l1 = rng.normal(size=(64, 16)).astype(np.float32)
    l2 = rng.normal(size=(16, 64)).astype(np.float32)
    sc = [jnp.float32(v) for v in (2.0, 0.9, 0.8, 0.0, 0.0)]
    got = np.asarray(iec_lora(x, l1, l2, *sc))
    want = 2.0 * (x @ l1 @ l2)
    assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# icq_entropy
# ---------------------------------------------------------------------------
def test_icq_entropy_matches_ref_fixed():
    rng = np.random.default_rng(4)
    block = (rng.normal(0, 0.03, size=64) + 0.01).astype(np.float32)
    taus = np.linspace(-0.09, 0.11, 201).astype(np.float32)
    got = icq_entropy_sweep(block, taus)
    want = ref.icq_entropy_sweep_ref(block, taus)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(**HYPO)
@given(
    spread=st.floats(1e-3, 1.0),
    center=st.floats(-0.2, 0.2),
    t=st.sampled_from([21, 101, 201]),
    seed=st.integers(0, 2**31 - 1),
)
def test_icq_entropy_hypothesis(spread, center, t, seed):
    rng = np.random.default_rng(seed)
    block = rng.normal(center, spread, size=64).astype(np.float32)
    taus = np.linspace(center - 0.1, center + 0.1, t).astype(np.float32)
    got = np.asarray(icq_entropy_sweep(block, taus))
    want = np.asarray(ref.icq_entropy_sweep_ref(block, taus))
    assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    # entropies are valid: within [0, 4] bits
    assert np.all(got >= -1e-6) and np.all(got <= 4.0 + 1e-6)


def test_entropy_uniform_codes_is_4_bits():
    codes = jnp.asarray(np.tile(np.arange(16), 4)[None, :])  # 64 codes uniform
    h = ref.entropy_ref(codes, 4)
    assert_allclose(np.asarray(h), [4.0], atol=1e-6)
