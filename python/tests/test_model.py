"""L2 correctness: NanoLLaMA forward/train graphs.

Uses a micro config so each test runs in seconds. The key integration
test (`test_forward_q_parity`) replicates the Rust storage pipeline in
numpy (blockwise NF4 quantize + bit-pack + merged IEC adapters) and
asserts the fused Pallas serving graph agrees with the plain forward
graph on dequantized weights — the same contract the Rust runtime
relies on.
"""

import math

import numpy as np
import jax
import jax.numpy as jnp
from numpy.testing import assert_allclose

from compile import model as M
from compile.config import (
    ModelConfig,
    PROJ_KINDS,
    base_param_specs,
    lora_param_specs,
    quantized_param_specs,
    proj_dims,
)
from compile.kernels import ref

CFG = ModelConfig(
    name="t", vocab=64, d_model=64, n_layers=2, n_heads=4, d_ff=128,
    seq=16, batch=2, rank=8,
)


def _batch(rng):
    tokens = rng.integers(0, CFG.vocab, size=(CFG.batch, CFG.seq)).astype(np.int32)
    targets = np.roll(tokens, -1, axis=1).astype(np.int32)
    targets[:, -1] = -1  # masked
    return jnp.asarray(tokens), jnp.asarray(targets)


def test_param_specs_consistent():
    base = base_param_specs(CFG)
    names = [n for n, _ in base]
    assert len(names) == len(set(names))
    assert names[0] == "embed" and names[-1] == "lm_head"
    lora = lora_param_specs(CFG)
    assert lora[-1][0] == "betas"
    assert len(lora) == 2 * 7 * CFG.n_layers + 1


def test_forward_shapes_and_finite():
    base = M.init_base_params(CFG, seed=0)
    lora = M.init_lora_params(CFG, seed=0)
    tokens, _ = _batch(np.random.default_rng(0))
    bd = M.base_to_dict(CFG, base)
    ld = M.lora_to_dict(CFG, lora)
    logits = M.forward_logits(CFG, bd, ld, tokens, 1.0, 1.0)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_lora_init_is_identity():
    """l2=0 and beta=0 => adapted model == base model exactly."""
    base = M.init_base_params(CFG, seed=1)
    lora = M.init_lora_params(CFG, seed=1)
    tokens, _ = _batch(np.random.default_rng(1))
    bd = M.base_to_dict(CFG, base)
    with_lora = M.forward_logits(CFG, bd, M.lora_to_dict(CFG, lora), tokens, 1.0, 1.0)
    without = M.forward_logits(CFG, bd, None, tokens, 0.0, 0.0)
    assert_allclose(np.asarray(with_lora), np.asarray(without), atol=1e-6)


def test_masks_gate_iec():
    base = M.init_base_params(CFG, seed=2)
    lora = M.init_lora_params(CFG, seed=2)
    # make IEC active: nonzero betas and lora_b
    rng = np.random.default_rng(2)
    names = [n for n, _ in lora_param_specs(CFG)]
    for i, n in enumerate(names):
        if n.endswith("lora_b"):
            lora[i] = rng.normal(0, 0.1, size=lora[i].shape).astype(np.float32)
        if n == "betas":
            lora[i] = rng.normal(0, 0.5, size=lora[i].shape).astype(np.float32)
    tokens, _ = _batch(rng)
    bd = M.base_to_dict(CFG, base)
    ld = M.lora_to_dict(CFG, lora)
    off = M.forward_logits(CFG, bd, ld, tokens, 0.0, 0.0)
    u1 = M.forward_logits(CFG, bd, ld, tokens, 1.0, 0.0)
    u2 = M.forward_logits(CFG, bd, ld, tokens, 0.0, 1.0)
    both = M.forward_logits(CFG, bd, ld, tokens, 1.0, 1.0)
    # each arm produces a distinct function
    assert not np.allclose(np.asarray(off), np.asarray(u1))
    assert not np.allclose(np.asarray(off), np.asarray(u2))
    assert not np.allclose(np.asarray(u1), np.asarray(both))


def test_masked_loss_ignores_negative_targets():
    rng = np.random.default_rng(3)
    logits = jnp.asarray(rng.normal(size=(2, 4, 8)).astype(np.float32))
    t_all = jnp.asarray(rng.integers(0, 8, size=(2, 4)).astype(np.int32))
    l_full = M.masked_ce_loss(logits, t_all)
    t_masked = np.asarray(t_all).copy()
    t_masked[:, :2] = -1
    l_masked = M.masked_ce_loss(logits, jnp.asarray(t_masked))
    assert l_full.shape == () and float(l_full) > 0
    assert not np.isclose(float(l_full), float(l_masked))


def test_pretrain_step_decreases_loss():
    step_fn = jax.jit(M.make_pretrain_step(CFG))
    params = [jnp.asarray(p) for p in M.init_base_params(CFG, seed=4)]
    ms = [jnp.zeros_like(p) for p in params]
    vs = [jnp.zeros_like(p) for p in params]
    rng = np.random.default_rng(4)
    tokens, targets = _batch(rng)
    losses = []
    for i in range(12):
        out = step_fn(*params, *ms, *vs, jnp.float32(i + 1), tokens, targets)
        loss, rest = out[0], out[1:]
        n = len(params)
        params, ms, vs = list(rest[:n]), list(rest[n:2 * n]), list(rest[2 * n:])
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses


def test_train_step_updates_only_lora_and_decreases_loss():
    step_fn = jax.jit(M.make_train_step(CFG))
    base = [jnp.asarray(p) for p in M.init_base_params(CFG, seed=5)]
    lora = [jnp.asarray(p) for p in M.init_lora_params(CFG, seed=5)]
    ms = [jnp.zeros_like(p) for p in lora]
    vs = [jnp.zeros_like(p) for p in lora]
    rng = np.random.default_rng(5)
    tokens, targets = _batch(rng)
    losses = []
    for i in range(15):
        out = step_fn(
            *base, *lora, *ms, *vs,
            jnp.float32(i + 1), jnp.float32(1.0), jnp.float32(1.0),
            tokens, targets,
        )
        loss, rest = out[0], out[1:]
        n = len(lora)
        lora, ms, vs = list(rest[:n]), list(rest[n:2 * n]), list(rest[2 * n:])
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    # betas became trainable signal (IEC active) — they moved off zero
    betas = np.asarray(lora[-1])
    assert np.any(betas != 0.0)


def _quantize_like_rust(w):
    """Blockwise NF4 quantization of a [h, o] weight, blocks of 64 along
    the flattened row-major order (== along o when 64 | o), bit-packed
    low-nibble-first — byte-identical to rust QuantizedTensor."""
    h, o = w.shape
    flat = w.reshape(-1, 64)
    codes, scales = ref.quant_block_ref(flat)
    codes = np.asarray(codes)
    scales = np.asarray(scales)
    packed = (codes[:, 0::2] | (codes[:, 1::2] << 4)).astype(np.uint8)
    packed = packed.reshape(h, o // 2)
    cb = ref.NF4_CODEBOOK
    dq = (cb[codes] * scales[:, None]).reshape(h, o)
    return packed, scales.reshape(h, o // 64), dq


def _merge_tile(l, rows, cols, beta, g):
    """Tile-semantics Eq. 16 merge (mirrors rust/src/lora/merge.rs)."""
    out = l.copy()
    seg_i = rows // g
    add = beta * g / rows
    for i in range(rows):
        gi = i // seg_i
        for j in range(cols):
            if j % g == gi:
                out[i, j] += add
    return out


def test_forward_q_parity():
    """Fused quantized serving graph == plain forward on dequantized
    weights with merged IEC adapters."""
    rng = np.random.default_rng(6)
    base = M.init_base_params(CFG, seed=6)
    lora = M.init_lora_params(CFG, seed=6)
    lnames = [n for n, _ in lora_param_specs(CFG)]
    for i, n in enumerate(lnames):
        if n.endswith("lora_b"):
            lora[i] = rng.normal(0, 0.05, size=lora[i].shape).astype(np.float32)
        if n == "betas":
            lora[i] = rng.normal(0, 0.3, size=lora[i].shape).astype(np.float32)
    bd = dict(zip([n for n, _ in base_param_specs(CFG)], base))
    ld = dict(zip(lnames, lora))

    qspecs = quantized_param_specs(CFG)
    qvals = {}
    bd_dq = dict(bd)
    aor = CFG.lora_alpha / CFG.rank
    for i in range(CFG.n_layers):
        for pi, kind in enumerate(PROJ_KINDS):
            h, o = proj_dims(CFG, kind)
            pre = f"l{i}.{kind}"
            packed, scales, dq = _quantize_like_rust(bd[pre])
            qvals[f"{pre}.codes"] = packed
            qvals[f"{pre}.scales"] = scales
            qvals[f"{pre}.taus"] = np.zeros_like(scales)
            bd_dq[pre] = jnp.asarray(dq)
            b1, b2 = ld["betas"][i, pi]
            g1 = math.gcd(h, CFG.rank)
            g2 = math.gcd(o, CFG.rank)
            # scale alpha/r into the merged b matrix so serving is a plain
            # two-matmul adapter
            la = _merge_tile(ld[f"{pre}.lora_a"], h, CFG.rank, float(b1), g1)
            lb = _merge_tile(ld[f"{pre}.lora_b"], CFG.rank, o, float(b2), g2) * aor
            qvals[f"{pre}.lora_a"] = la.astype(np.float32)
            qvals[f"{pre}.lora_b"] = lb.astype(np.float32)
    for n in ("embed", "final_norm", "lm_head"):
        qvals[n] = bd[n]
    for i in range(CFG.n_layers):
        qvals[f"l{i}.attn_norm"] = bd[f"l{i}.attn_norm"]
        qvals[f"l{i}.ffn_norm"] = bd[f"l{i}.ffn_norm"]

    tokens, _ = _batch(rng)
    fwd_q = M.make_forward_q(CFG, qspecs)
    args = [jnp.asarray(qvals[s[0]]) for s in qspecs] + [tokens]
    (logits_q,) = jax.jit(fwd_q)(*args)

    logits_ref = M.forward_logits(CFG, bd_dq, ld, tokens, 1.0, 1.0)
    assert_allclose(
        np.asarray(logits_q), np.asarray(logits_ref), rtol=5e-4, atol=5e-4
    )
