//! End-to-end driver (the validation run recorded in EXPERIMENTS.md):
//!
//! 1. pretrain a NanoLLaMA base on the synthetic corpus (cached);
//! 2. ICQ-quantize it to NF4;
//! 3. LoRA+IEC finetune for a few hundred steps on alpaca-syn,
//!    logging the loss curve;
//! 4. evaluate 5-shot SynMMLU, against a vanilla-QLoRA arm.
//!
//! All compute flows rust -> PJRT -> AOT HLO; Python is not involved.
//!
//! Run: `cargo run --release --example finetune_e2e [--size s] [--steps N]`

use anyhow::{Context, Result};

use irqlora::coordinator::{pretrained_base, run_arm, Arm, RunCfg};
use irqlora::data::evalset::mmlu_set;
use irqlora::data::instruct::Dataset;
use irqlora::data::{World, MMLU_GROUPS};
use irqlora::runtime::{Manifest, Runtime};
use irqlora::util::timer::{fmt_duration, Timer};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut tag = "s".to_string();
    let mut cfg = RunCfg {
        pretrain_steps: 400,
        finetune_steps: 200,
        eval_per_group: 75,
        ..Default::default()
    };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--size" => {
                i += 1;
                tag = args[i].clone();
            }
            "--steps" => {
                i += 1;
                cfg.finetune_steps = args[i].parse()?;
            }
            "--pretrain-steps" => {
                i += 1;
                cfg.pretrain_steps = args[i].parse()?;
            }
            other => anyhow::bail!("unknown arg {other}"),
        }
        i += 1;
    }

    let manifest =
        Manifest::load("artifacts").context("run `make artifacts` first")?;
    let rt = Runtime::cpu()?;
    let world = World::new(cfg.world_seed);
    println!("== IR-QLoRA end-to-end driver ==");
    println!(
        "model nano-{tag} | pretrain {} steps | finetune {} steps | platform {}",
        cfg.pretrain_steps,
        cfg.finetune_steps,
        rt.platform()
    );

    // 1. pretrain (or load cache)
    let total = Timer::start();
    let base = pretrained_base(&rt, &manifest, &tag, &cfg)?;
    println!(
        "[1/4] base ready: {} params ({})",
        base.total_params(),
        fmt_duration(total.elapsed())
    );

    let items = mmlu_set(&world, cfg.eval_per_group, cfg.seed);

    // 2-4. two arms through quantize -> finetune -> eval
    let mut results = Vec::new();
    for arm in [Arm::qlora(4), Arm::ir_qlora(4)] {
        println!("\n[arm: {}] quantize + finetune + eval …", arm.name);
        let r = run_arm(
            &rt, &manifest, &tag, &base, arm, Dataset::AlpacaSyn, &items, &cfg,
        )?;
        // loss curve, decimated to ~20 points
        let n = r.loss_curve.len().max(1);
        let stride = (n / 20).max(1);
        print!("  loss curve: ");
        for (i, l) in r.loss_curve.iter().enumerate() {
            if i % stride == 0 || i + 1 == n {
                print!("{l:.3} ");
            }
        }
        println!();
        println!(
            "  quantize {} | finetune {} | entropy {:.3} bits | storage {:.2} MB",
            fmt_duration(r.quantize_time),
            fmt_duration(r.finetune_time),
            r.mean_entropy,
            r.storage_mb
        );
        results.push(r);
    }

    println!("\n== SynMMLU (5-shot) ==");
    print!("{:<12}", "arm");
    for (g, _) in MMLU_GROUPS {
        print!(" {g:>8}");
    }
    println!(" {:>8}", "Avg.");
    for r in &results {
        print!("{:<12}", r.arm.name);
        for g in 0..MMLU_GROUPS.len() {
            print!(" {:>8.1}", r.eval.group_accuracy(g) * 100.0);
        }
        println!(" {:>8.1}", r.eval.avg_accuracy() * 100.0);
    }

    let d = results[1].eval.avg_accuracy() - results[0].eval.avg_accuracy();
    println!(
        "\nIR-QLoRA vs QLoRA: {:+.1} points | total wall time {}",
        d * 100.0,
        fmt_duration(total.elapsed())
    );
    Ok(())
}
