//! Bit-width sweep (the Table 9 story at tensor level): quantize a
//! trained base model at NF2/NF3/NF4 with and without ICQ and print
//! entropy + reconstruction error per bit-width — showing the
//! degradation grow as bits shrink and ICQ's growing advantage.
//!
//! Run: `cargo run --release --example bitwidth_sweep`

use anyhow::{Context, Result};

use irqlora::coordinator::{pretrained_base, quantize_model, RunCfg};
use irqlora::quant::Method;
use irqlora::runtime::{Manifest, Runtime};
use irqlora::util::stats;

fn main() -> Result<()> {
    let manifest = Manifest::load("artifacts").context("run `make artifacts`")?;
    let rt = Runtime::cpu()?;
    let cfg = RunCfg { pretrain_steps: 200, ..Default::default() };
    let tag = "xs";
    let base = pretrained_base(&rt, &manifest, tag, &cfg)?;

    println!(
        "{:>4} {:>12} {:>12} {:>14} {:>14} {:>10}",
        "bits", "H vanilla", "H ICQ", "MSE vanilla", "MSE ICQ", "MSE ratio"
    );
    for k in [4u8, 3, 2] {
        let v = quantize_model(&base, Method::Nf { k }, cfg.seed)?;
        let i = quantize_model(&base, Method::NfIcq { k }, cfg.seed)?;
        // weight-space MSE across all quantized projections
        let mut mse_v = 0f64;
        let mut mse_i = 0f64;
        let mut n = 0usize;
        for (name, t) in base.iter() {
            if !irqlora::model::weights::is_quantized_proj(name) {
                continue;
            }
            let dv = v.dequantized.get(name)?;
            let di = i.dequantized.get(name)?;
            mse_v += stats::mse(t.data(), dv.data()) * t.len() as f64;
            mse_i += stats::mse(t.data(), di.data()) * t.len() as f64;
            n += t.len();
        }
        mse_v /= n as f64;
        mse_i /= n as f64;
        println!(
            "{:>4} {:>12.4} {:>12.4} {:>14.3e} {:>14.3e} {:>10.3}",
            k,
            v.mean_entropy(),
            i.mean_entropy(),
            mse_v,
            mse_i,
            mse_i / mse_v
        );
    }
    println!("\n(entropy gap ICQ-vanilla widens as bits shrink — the paper's ultra-low-bit claim)");
    Ok(())
}
