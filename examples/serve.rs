//! Serving example: load a quantized+finetuned model behind the
//! dynamic-batching server and replay a synthetic request trace,
//! reporting latency percentiles and throughput.
//!
//! Run: `cargo run --release --example serve [--requests N] [--clients N]`

use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use irqlora::coordinator::{pretrained_base, quantize_model, BatchServer, ServerConfig, RunCfg};
use irqlora::data::evalset::mmlu_item;
use irqlora::data::World;
use irqlora::model::weights;
use irqlora::quant::Method;
use irqlora::runtime::Manifest;
use irqlora::util::timer::Timer;
use irqlora::util::Rng;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut n_requests = 256usize;
    let mut n_clients = 8usize;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--requests" => {
                i += 1;
                n_requests = args[i].parse()?;
            }
            "--clients" => {
                i += 1;
                n_clients = args[i].parse()?;
            }
            other => anyhow::bail!("unknown arg {other}"),
        }
        i += 1;
    }

    let tag = "xs";
    let manifest = Manifest::load("artifacts").context("run `make artifacts`")?;
    let cfg = RunCfg { pretrain_steps: 200, ..Default::default() };

    // base model: pretrained, ICQ-quantized (serving-ready weights)
    let rt = irqlora::runtime::Runtime::cpu()?;
    let base = pretrained_base(&rt, &manifest, tag, &cfg)?;
    let qm = quantize_model(&base, Method::NfIcq { k: 4 }, cfg.seed)?;
    println!(
        "quantized base: {:.2} MB, entropy {:.3} bits",
        qm.storage_mb(),
        qm.mean_entropy()
    );
    // identity adapter (a trained one would come from `irqlora finetune`)
    let spec = manifest.graph(tag, "train_step")?;
    let nb = qm.dequantized.len();
    let nl = irqlora::coordinator::trainer::train_layout(spec.inputs.len(), nb)?;
    let mut rng = Rng::new(cfg.seed);
    let lora = weights::init_lora(
        &spec.inputs[nb..nb + nl],
        manifest.size(tag)?.config.rank,
        &mut rng,
    );
    drop(rt); // server owns its own runtime

    let server = Arc::new(BatchServer::spawn(
        manifest,
        ServerConfig {
            tag: tag.into(),
            masks: (1.0, 1.0),
            max_wait: Duration::from_millis(2),
        },
        qm.dequantized,
        lora,
    )?);
    println!("server up; replaying {n_requests} requests from {n_clients} clients…");

    // request trace: 5-shot MMLU prompts
    let world = World::new(cfg.world_seed);
    let mut rng = Rng::new(99);
    let prompts: Vec<Vec<i32>> = (0..n_requests)
        .map(|_| mmlu_item(&world, rng.below(4), &mut rng, 5).prompt)
        .collect();

    let t = Timer::start();
    let mut handles = Vec::new();
    let per_client = n_requests.div_ceil(n_clients);
    for c in 0..n_clients {
        let server = server.clone();
        let chunk: Vec<Vec<i32>> = prompts
            [c * per_client..((c + 1) * per_client).min(prompts.len())]
            .to_vec();
        handles.push(std::thread::spawn(move || -> Result<Vec<f64>> {
            let mut lat = Vec::new();
            for p in chunk {
                let reply = server.query(p)?;
                lat.push(reply.latency.as_secs_f64() * 1e3);
            }
            Ok(lat)
        }));
    }
    let mut latencies: Vec<f64> = Vec::new();
    for h in handles {
        latencies.extend(h.join().expect("client panicked")?);
    }
    let wall = t.elapsed_secs();

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| latencies[((latencies.len() - 1) as f64 * q) as usize];
    let stats = server.stats();
    println!("\n== serving results ==");
    println!("requests          {}", latencies.len());
    println!("throughput        {:.1} req/s", latencies.len() as f64 / wall);
    println!("latency p50       {:.1} ms", pct(0.50));
    println!("latency p90       {:.1} ms", pct(0.90));
    println!("latency p99       {:.1} ms", pct(0.99));
    println!("batches           {}", stats.batches);
    println!("mean batch size   {:.2}", stats.mean_batch_size());
    Ok(())
}
