//! Quickstart: the Figure-2 story on a single tensor.
//!
//! Quantizes a synthetic "trained" weight tensor with vanilla NF4 and
//! with ICQ, then prints entropy, reconstruction error, and storage —
//! the smallest possible demonstration of what Information Calibration
//! Quantization buys.
//!
//! Run: `cargo run --release --example quickstart`

use irqlora::quant::{blockwise, entropy, icq, nf, QuantizedTensor};
use irqlora::util::{stats, Rng, Tensor};

fn main() {
    // A weight tensor the way trained LLM weights actually look:
    // roughly normal, slightly shifted per channel, with outliers.
    let mut rng = Rng::new(42);
    let (rows, cols) = (256usize, 256usize);
    let mut w = Vec::with_capacity(rows * cols);
    for _ in 0..rows {
        let channel_shift = rng.normal_ms(0.0, 0.01);
        for _ in 0..cols {
            let mut v = rng.normal_ms(channel_shift, 0.02);
            if rng.chance(0.004) {
                v *= 6.0; // outliers
            }
            w.push(v);
        }
    }
    let t = Tensor::new(&[rows, cols], w.clone());

    println!("NF4 codebook head (paper Table 13): {:?}\n", &nf::codebook(4)[..4]);

    // --- vanilla NF4 (QLoRA baseline, Eq. 1) ---
    let q_van = QuantizedTensor::quantize(&t, 4, blockwise::DEFAULT_BLOCK, None);
    let wh_van = q_van.dequantize();

    // --- ICQ (IR-QLoRA, Eq. 8-10) ---
    let q_icq = QuantizedTensor::quantize(
        &t,
        4,
        blockwise::DEFAULT_BLOCK,
        Some(&icq::IcqConfig::default()),
    );
    let wh_icq = q_icq.dequantize();

    println!("{:<28} {:>12} {:>12}", "", "vanilla NF4", "ICQ NF4");
    println!(
        "{:<28} {:>12.4} {:>12.4}",
        "mean block entropy (bits)",
        q_van.mean_entropy(),
        q_icq.mean_entropy()
    );
    println!(
        "{:<28} {:>12.3e} {:>12.3e}",
        "reconstruction MSE",
        stats::mse(&w, wh_van.data()),
        stats::mse(&w, wh_icq.data())
    );
    println!(
        "{:<28} {:>12.4} {:>12.4}",
        "bits per weight",
        q_van.bits_per_weight(),
        q_icq.bits_per_weight()
    );

    // per-block view of the search itself
    let block = &w[0..64];
    let search = icq::search_tau(block, 4, &icq::IcqConfig::default());
    println!(
        "\nfirst block: tau* = {:+.5}, entropy {:.4} -> {:.4} bits",
        search.tau, search.entropy_vanilla, search.entropy
    );

    let q0 = blockwise::quantize(block, 4, 64, None);
    let q1 = blockwise::quantize(block, 4, 64, Some(&[search.tau]));
    println!(
        "code histogram vanilla: {:?}",
        entropy::code_histogram(&q0.codes, 4)
    );
    println!(
        "code histogram ICQ:     {:?}",
        entropy::code_histogram(&q1.codes, 4)
    );
    println!("\n(ICQ spreads codes across more levels => more information retained)");
}
