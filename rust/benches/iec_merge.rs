//! Bench: IEC forward overhead vs plain LoRA, and the Eq. 16 merge
//! cost — supporting the paper's "IEC is free at inference" claim.
//! The `_into` rows reuse one scratch pair across iterations (the
//! serving adapter-reload path).
//! Run: cargo bench --bench iec_merge

use irqlora::bench_harness::{bench, iters};
use irqlora::lora::iec::lora_iec_forward;
use irqlora::lora::merge::{merge_l1, merge_l1_into, merge_l2, merge_l2_into};
use irqlora::util::Rng;

fn main() {
    let (h, r, o) = (1024usize, 64usize, 1024usize);
    let mut rng = Rng::new(4);
    let x = rng.normal_vec(h, 0.0, 1.0);
    let l1 = rng.normal_vec(h * r, 0.0, 0.1);
    let l2 = rng.normal_vec(r * o, 0.0, 0.1);

    bench("lora_forward plain (h=o=1024, r=64)", 5, iters(30), || {
        std::hint::black_box(lora_iec_forward(
            &x, &l1, &l2, r, o, 1.0, 0.5, 0.5, 0.0, 0.0,
        ));
    });
    bench("lora_forward with IEC (explicit U1+U2)", 5, iters(30), || {
        std::hint::black_box(lora_iec_forward(
            &x, &l1, &l2, r, o, 1.0, 0.5, 0.5, 1.0, 1.0,
        ));
    });

    bench("merge_l1 (Eq.16, 1024x64)", 5, iters(50), || {
        std::hint::black_box(merge_l1(&l1, h, r, 0.5));
    });
    bench("merge_l2 (Eq.16, 64x1024)", 5, iters(50), || {
        std::hint::black_box(merge_l2(&l2, r, o, 0.5));
    });

    // allocation-free variants: one scratch pair reused per iteration
    let mut s1 = Vec::new();
    let mut s2 = Vec::new();
    bench("merge_l1_into (scratch reuse)", 5, iters(50), || {
        merge_l1_into(&l1, h, r, 0.5, &mut s1);
        std::hint::black_box(&s1);
    });
    bench("merge_l2_into (scratch reuse)", 5, iters(50), || {
        merge_l2_into(&l2, r, o, 0.5, &mut s2);
        std::hint::black_box(&s2);
    });

    // merged adapters: forward is the plain path again (zero overhead)
    let m1 = merge_l1(&l1, h, r, 0.5);
    let m2 = merge_l2(&l2, r, o, 0.5);
    bench("lora_forward merged (inference path)", 5, iters(30), || {
        std::hint::black_box(lora_iec_forward(
            &x, &m1, &m2, r, o, 1.0, 0.0, 0.0, 0.0, 0.0,
        ));
    });
}
