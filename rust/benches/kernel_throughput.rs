//! Bench: kernel-layer GEMM throughput — the packed-domain matvec
//! (`kernels::gemm_packed`, y = W_q·x straight from NF-k codes)
//! against its serial reference twin AND against the path it replaces
//! (dequantize the tensor, then run the blocked dense kernel), plus
//! the dense `gemm_f32` pair and the `lora::merge` delta pair.
//!
//! Every fast row has a `[reference serial]` partner with the same
//! stem, so BENCH_quant.json records the before/after ratio with the
//! code that produced it; verify.sh's smoke pass asserts the pairs
//! exist. All pairs are bit-identical by the kernels' oracle contract
//! (`tests/kernel_identity.rs`), so the rows measure the same
//! arithmetic — only the storage domain and scheduling differ.
//!
//! Run: cargo bench --bench kernel_throughput
//! Env: IRQLORA_BENCH_QUICK=1 (1 iter smoke), IRQLORA_THREADS=n,
//!      IRQLORA_BENCH_JSON=path, IRQLORA_GEMM_BLOCK,
//!      IRQLORA_GEMM_SERIAL_BELOW

use irqlora::bench_harness::{bench_json_path, bench_throughput, iters, JsonSink};
use irqlora::kernels::{
    gemm_f32, gemm_f32_reference, gemm_packed_into, gemm_packed_reference, PackedGemmScratch,
};
use irqlora::lora::merge::{merge_delta_into, merge_delta_reference};
use irqlora::quant::{DequantScratch, QuantizedTensor};
use irqlora::util::{Rng, Tensor};

fn main() {
    let mut rng = Rng::new(9);
    let it = iters(20);
    let mut sink = JsonSink::new();

    // --- packed matvec: k sweep × three sizes ---------------------
    // Sizes straddle the serial threshold: the small shape runs the
    // serial packed path, the larger two fan rows across workers.
    let sizes: [(usize, usize); 3] = [(64, 256), (256, 1024), (512, 2048)];
    for k in [2u8, 3, 4, 8] {
        for (rows, cols) in sizes {
            let n = rows * cols;
            let w = Tensor::new(&[rows, cols], rng.normal_vec(n, 0.0, 0.02));
            let qt = QuantizedTensor::quantize(&w, k, 64, None);
            let x = rng.normal_vec(cols, 0.0, 1.0);
            let stem = format!("gemm_packed_nf{k} ({rows}x{cols})");

            let r = bench_throughput(
                &format!("{stem} [reference serial]"),
                1,
                it,
                n as f64,
                "madd",
                || {
                    std::hint::black_box(gemm_packed_reference(&qt, &x));
                },
            );
            sink.push(&r, Some(n as f64));

            // the path gemm_packed replaces: materialize the f32
            // matrix, then run the blocked dense kernel over it
            let mut deq = vec![0f32; n];
            let mut dq_scratch = DequantScratch::default();
            let r = bench_throughput(
                &format!("dequant_then_gemm_nf{k} ({rows}x{cols})"),
                1,
                it,
                n as f64,
                "madd",
                || {
                    qt.dequantize_into(&mut deq, &mut dq_scratch);
                    std::hint::black_box(gemm_f32(&deq, &x, rows, cols, 1));
                },
            );
            sink.push(&r, Some(n as f64));

            let mut y = Vec::new();
            let mut scratch = PackedGemmScratch::new();
            let r = bench_throughput(&stem, 1, it, n as f64, "madd", || {
                gemm_packed_into(&qt, &x, &mut y, &mut scratch);
                std::hint::black_box(&y);
            });
            sink.push(&r, Some(n as f64));
        }
    }

    // --- dense blocked kernel pair --------------------------------
    let (m, kd, n_cols) = (256usize, 256usize, 64usize);
    let a = rng.normal_vec(m * kd, 0.0, 0.5);
    let b = rng.normal_vec(kd * n_cols, 0.0, 0.5);
    let madds = (m * kd * n_cols) as f64;
    let r = bench_throughput(
        &format!("gemm_f32 ({m}x{kd}x{n_cols}) [reference serial]"),
        1,
        it,
        madds,
        "madd",
        || {
            std::hint::black_box(gemm_f32_reference(&a, &b, m, kd, n_cols));
        },
    );
    sink.push(&r, Some(madds));
    let r = bench_throughput(
        &format!("gemm_f32 ({m}x{kd}x{n_cols})"),
        1,
        it,
        madds,
        "madd",
        || {
            std::hint::black_box(gemm_f32(&a, &b, m, kd, n_cols));
        },
    );
    sink.push(&r, Some(madds));

    // --- lora::merge dense-delta pair (ΔW = ℓ̃1·ℓ̃2) ---------------
    let (h, rr, o) = (256usize, 16usize, 256usize);
    let l1 = rng.normal_vec(h * rr, 0.0, 0.3);
    let l2 = rng.normal_vec(rr * o, 0.0, 0.3);
    let madds = (h * rr * o) as f64;
    let r = bench_throughput(
        &format!("merge_delta ({h}x{rr}x{o}) [reference serial]"),
        1,
        it,
        madds,
        "madd",
        || {
            std::hint::black_box(merge_delta_reference(&l1, &l2, h, rr, o));
        },
    );
    sink.push(&r, Some(madds));
    let mut delta = Vec::new();
    let r = bench_throughput(&format!("merge_delta ({h}x{rr}x{o})"), 1, it, madds, "madd", || {
        merge_delta_into(&l1, &l2, h, rr, o, &mut delta);
        std::hint::black_box(&delta);
    });
    sink.push(&r, Some(madds));

    let path = bench_json_path("BENCH_quant.json");
    match sink.write_merged(&path) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", path.display()),
    }
}
