//! Bench: batched serving latency/throughput through the forward graph
//! under the dynamic batcher, across offered concurrency levels.
//! Requires `make artifacts`. Rows are also recorded into
//! `BENCH_quant.json` under names carrying their own semantics
//! (`serve_latency p50 clients=N`): unlike `bench()`-produced rows,
//! ns_per_iter holds the p50 request latency under contention, ns_min
//! the fastest request, iters the request count, per_sec requests/s.
//! Run: cargo bench --bench serve_latency

use std::sync::Arc;
use std::time::Duration;

use irqlora::bench_harness::{bench_json_path, JsonSink};
use irqlora::coordinator::{BatchServer, ServerConfig};
use irqlora::data::evalset::mmlu_item;
use irqlora::data::World;
use irqlora::model::weights::{init_base, init_lora};
use irqlora::runtime::Manifest;
use irqlora::util::timer::Timer;
use irqlora::util::Rng;

fn main() {
    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let tag = "xs";
    let size = manifest.size(tag).unwrap().clone();
    let spec = manifest.graph(tag, "pretrain_step").unwrap();
    let nb = irqlora::coordinator::trainer::pretrain_layout(spec.inputs.len()).unwrap();
    let mut rng = Rng::new(1);
    let base = init_base(&spec.inputs[..nb], size.config.n_layers, &mut rng);
    let tspec = manifest.graph(tag, "train_step").unwrap();
    let nl = irqlora::coordinator::trainer::train_layout(tspec.inputs.len(), nb).unwrap();
    let lora = init_lora(&tspec.inputs[nb..nb + nl], size.config.rank, &mut rng);

    let server = Arc::new(
        BatchServer::spawn(
            manifest,
            ServerConfig {
                tag: tag.into(),
                masks: (1.0, 1.0),
                max_wait: Duration::from_millis(2),
            },
            base,
            lora,
        )
        .unwrap(),
    );

    let world = World::new(1);
    let mut prng = Rng::new(9);
    let prompts: Vec<Vec<i32>> = (0..512)
        .map(|_| mmlu_item(&world, prng.below(4), &mut prng, 5).prompt)
        .collect();

    let mut sink = JsonSink::new();
    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>12}",
        "clients", "req/s", "p50 ms", "p99 ms", "mean batch"
    );
    for &clients in &[1usize, 2, 4, 8, 16] {
        let n = 128usize;
        let t = Timer::start();
        let mut handles = Vec::new();
        for c in 0..clients {
            let server = server.clone();
            let chunk: Vec<Vec<i32>> = (0..n / clients)
                .map(|i| prompts[(c * 131 + i * 17) % prompts.len()].clone())
                .collect();
            handles.push(std::thread::spawn(move || {
                let mut lat = Vec::new();
                for p in chunk {
                    let r = server.query(p).unwrap();
                    lat.push(r.latency.as_secs_f64() * 1e3);
                }
                lat
            }));
        }
        let mut lat: Vec<f64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        let wall = t.elapsed_secs();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p = |q: f64| lat[((lat.len() - 1) as f64 * q) as usize];
        let before = server.stats();
        println!(
            "{:>8} {:>12.1} {:>12.1} {:>12.1} {:>12.2}",
            clients,
            lat.len() as f64 / wall,
            p(0.5),
            p(0.99),
            before.mean_batch_size(),
        );
        sink.push_raw(
            &format!("serve_latency p50 clients={clients}"),
            lat.len(), // request count, not closure iterations
            p(0.5) * 1e6, // p50 ms -> ns per request
            lat[0] * 1e6, // fastest request, ns
            Some(lat.len() as f64 / wall),
        );
    }

    let path = bench_json_path("BENCH_quant.json");
    match sink.write_merged(&path) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", path.display()),
    }
}
