//! Bench: batched serving latency/throughput under the dynamic
//! batcher, across offered concurrency levels and adapter counts.
//!
//! Two scenario families:
//! - **PJRT** (requires `make artifacts`): the forward graph under
//!   contention, single-adapter baseline rows (`serve_latency p50
//!   clients=N`, same semantics as before: ns_per_iter = p50 request
//!   latency, ns_min = fastest request, per_sec = requests/s) plus
//!   multi-adapter rows (`... adapters=K`) so routing overhead is
//!   visible next to the baseline.
//! - **Reference** (always runs, offline included): the registry +
//!   batcher over the deterministic host backend, with per-adapter
//!   occupancy rows (`serve_latency multi-adapter adapter=NAME`:
//!   ns_per_iter = mean request latency, per_sec = that adapter's
//!   requests/s). This is the path `scripts/verify.sh` smokes under
//!   `IRQLORA_BENCH_QUICK=1`.
//! - **Pool scale-out** (always runs): the same mixed-adapter offered
//!   load against 1/2/4-worker `ServerPool`s sharing ONE registry
//!   (`serve_latency pool workers=N adapters=K`: ns_per_iter = mean
//!   request latency, per_sec = requests/s; fused + stealing, the
//!   production defaults), plus per-worker routing rows for the
//!   2-worker pool (`... workers=2 worker=I`: iters = requests routed
//!   there, per_sec = that worker's requests/s) that
//!   `scripts/verify.sh` asserts on.
//! - **Fused vs per-group serial** (always runs): paired rows for the
//!   mixed-adapter sweep at 1/4/8 adapters × 1/2/4 workers —
//!   `serve_latency fused workers=W adapters=K` next to
//!   `... [per-group serial]` (the pre-fusion oracle path) so the
//!   before/after ratio of the one-forward-per-drain rewrite travels
//!   with the code. `scripts/verify.sh` asserts both flavors exist.
//! - **Native vs reference** (always runs): the same mixed-adapter
//!   offered load at 1/4/8 adapters × 1/2/4 workers, once per HAL
//!   backend — `serve_latency backend=native workers=W adapters=K`
//!   paired with `... backend=reference ...` — built through
//!   `BackendRegistry::pool_factory` so the bench exercises the exact
//!   manifest-validated construction path `irqlora serve --backend`
//!   uses. `scripts/verify.sh` asserts both flavors exist.
//! - **Steal on/off** (always runs): a skewed hot-adapter burst
//!   against a 4-worker pool with the work-stealing scheduler on vs
//!   off (`serve_latency pool steal=on|off workers=4 adapters=8`);
//!   the printed table carries the steal/spill counters.
//! - **Streamed vs oneshot** (always runs): the same skewed open-loop
//!   burst as paired 4-step decode streams vs one-shot requests
//!   (`serve_latency streamed|oneshot ttft p50|p99 workers=4
//!   adapters=8`: ns_per_iter = time-to-first-token at that quantile;
//!   `... tokens_per_sec ...`: per_sec = decode tokens/s) so the
//!   continuous-batching scheduler's join/leave overhead travels next
//!   to the one-shot path it grew out of. `scripts/verify.sh` asserts
//!   both flavors exist.
//! - **Saturation** (always runs): open-loop offered load paced at
//!   ~2× the pool's measured clean throughput against a small parked
//!   overflow, so admission control actually engages. Rows
//!   `serve_latency saturation p50|p99|shed workers=4`: delivered
//!   request wait at p50/p99 (ns_per_iter), shed count (iters of the
//!   shed row), delivered-vs-shed per_sec. `scripts/verify.sh`
//!   asserts the family exists in the smoke JSON.
//!
//! Run: cargo bench --bench serve_latency

use std::sync::Arc;
use std::time::Duration;

use irqlora::bench_harness::{bench_json_path, JsonSink};
use irqlora::coordinator::backend::{ReferenceBackend, ServeBackend};
use irqlora::coordinator::pool::{PoolConfig, ServerPool};
use irqlora::coordinator::{
    synthetic_serve_registry, AdapterRegistry, BatchServer, ServerConfig,
};
use irqlora::data::evalset::mmlu_item;
use irqlora::data::World;
use irqlora::model::weights::{init_base, init_lora};
use irqlora::runtime::Manifest;
use irqlora::util::timer::Timer;
use irqlora::util::Rng;

fn main() {
    let mut sink = JsonSink::new();
    match Manifest::load("artifacts") {
        Ok(m) => pjrt_scenarios(m, &mut sink),
        Err(e) => eprintln!("skipping PJRT serve scenarios ({e})"),
    }
    reference_multi_adapter(&mut sink);
    pool_scaling(&mut sink);
    fused_vs_serial(&mut sink);
    native_vs_reference(&mut sink);
    steal_on_off(&mut sink);
    streamed_vs_oneshot(&mut sink);
    saturation(&mut sink);

    let path = bench_json_path("BENCH_quant.json");
    match sink.write_merged(&path) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", path.display()),
    }
}

/// Forward-graph serving under contention: single-adapter baseline
/// sweeps plus mixed-adapter sweeps over one shared base.
fn pjrt_scenarios(manifest: Manifest, sink: &mut JsonSink) {
    let tag = "xs";
    let size = manifest.size(tag).unwrap().clone();
    let spec = manifest.graph(tag, "pretrain_step").unwrap();
    let nb = irqlora::coordinator::trainer::pretrain_layout(spec.inputs.len()).unwrap();
    let mut rng = Rng::new(1);
    let base = init_base(&spec.inputs[..nb], size.config.n_layers, &mut rng);
    let tspec = manifest.graph(tag, "train_step").unwrap();
    let nl = irqlora::coordinator::trainer::train_layout(tspec.inputs.len(), nb).unwrap();
    let lora_specs = tspec.inputs[nb..nb + nl].to_vec();

    let registry = Arc::new(AdapterRegistry::new(base, (1.0, 1.0)));
    let n_adapters = 3usize;
    for i in 0..n_adapters {
        let mut arng = Rng::new(2 + i as u64);
        registry
            .register(
                &format!("tenant{i}"),
                init_lora(&lora_specs, size.config.rank, &mut arng),
            )
            .unwrap();
    }

    let server = Arc::new(
        BatchServer::spawn(
            manifest,
            tag,
            ServerConfig::new(Duration::from_millis(2)),
            registry,
        )
        .unwrap(),
    );

    let world = World::new(1);
    let mut prng = Rng::new(9);
    let prompts: Vec<Vec<i32>> = (0..512)
        .map(|_| mmlu_item(&world, prng.below(4), &mut prng, 5).prompt)
        .collect();

    let n = irqlora::bench_harness::iters(128).max(16);
    println!(
        "{:>8} {:>9} {:>12} {:>12} {:>12} {:>12}",
        "clients", "adapters", "req/s", "p50 ms", "p99 ms", "mean batch"
    );
    let sweeps: &[(usize, usize)] =
        &[(1, 1), (2, 1), (4, 1), (8, 1), (16, 1), (4, 3), (8, 3), (16, 3)];
    for &(clients, adapters) in sweeps {
        let per_client = (n / clients).max(1);
        let t = Timer::start();
        let mut handles = Vec::new();
        for c in 0..clients {
            let server = server.clone();
            let chunk: Vec<Vec<i32>> = (0..per_client)
                .map(|i| prompts[(c * 131 + i * 17) % prompts.len()].clone())
                .collect();
            let adapter = format!("tenant{}", c % adapters);
            handles.push(std::thread::spawn(move || {
                let mut lat = Vec::new();
                for p in chunk {
                    let r = server.query(&adapter, p).unwrap();
                    lat.push(r.latency.as_secs_f64() * 1e3);
                }
                lat
            }));
        }
        let mut lat: Vec<f64> =
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        let wall = t.elapsed_secs();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p = |q: f64| lat[((lat.len() - 1) as f64 * q) as usize];
        println!(
            "{:>8} {:>9} {:>12.1} {:>12.1} {:>12.1} {:>12.2}",
            clients,
            adapters,
            lat.len() as f64 / wall,
            p(0.5),
            p(0.99),
            server.stats().mean_batch_size(),
        );
        // single-adapter rows keep their PR-1 names so the perf
        // trajectory stays comparable across PRs
        let name = if adapters == 1 {
            format!("serve_latency p50 clients={clients}")
        } else {
            format!("serve_latency p50 clients={clients} adapters={adapters}")
        };
        sink.push_raw(
            &name,
            lat.len(), // request count, not closure iterations
            p(0.5) * 1e6, // p50 ms -> ns per request
            lat[0] * 1e6, // fastest request, ns
            Some(lat.len() as f64 / wall),
        );
    }
}

/// Registry + batcher throughput over the deterministic reference
/// backend: no artifacts needed, so the multi-adapter serving path is
/// exercised (and its JSON rows emitted) even in offline CI smoke.
fn reference_multi_adapter(sink: &mut JsonSink) {
    const BATCH: usize = 8;
    const SEQ: usize = 32;
    const VOCAB: usize = 64;
    let n_adapters = 4usize;
    let per_adapter = irqlora::bench_harness::iters(256).max(32);

    let registry = synthetic_serve_registry(n_adapters, 5);

    let reg = registry.clone();
    let server = Arc::new(
        BatchServer::spawn_with(
            ServerConfig::new(Duration::from_millis(2)),
            registry,
            move || {
                Ok(Box::new(ReferenceBackend::new(BATCH, SEQ, VOCAB, reg.base()))
                    as Box<dyn ServeBackend>)
            },
        )
        .unwrap(),
    );

    println!(
        "\nmulti-adapter routing (reference backend, {n_adapters} adapters, \
         {per_adapter} req/adapter):"
    );
    let t = Timer::start();
    let mut handles = Vec::new();
    for a in 0..n_adapters {
        let server = server.clone();
        handles.push(std::thread::spawn(move || {
            let name = format!("tenant{a}");
            let mut rng = Rng::new(100 + a as u64);
            let mut total = Duration::ZERO;
            let mut fastest = Duration::MAX;
            for _ in 0..per_adapter {
                let len = 1 + rng.below(SEQ - 1);
                let prompt: Vec<i32> =
                    (0..len).map(|_| 1 + rng.below(VOCAB - 1) as i32).collect();
                let r = server.query(&name, prompt).unwrap();
                total += r.latency;
                fastest = fastest.min(r.latency);
            }
            (name, total, fastest)
        }));
    }
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let wall = t.elapsed_secs();
    let stats = server.stats();

    println!(
        "{:>10} {:>10} {:>12} {:>12} {:>12}",
        "adapter", "requests", "mean ms", "req/s", "mean batch"
    );
    for (name, total, fastest) in &results {
        let a = stats.per_adapter.get(name.as_str()).cloned().unwrap_or_default();
        let mean = total.as_secs_f64() / per_adapter as f64;
        println!(
            "{:>10} {:>10} {:>12.3} {:>12.1} {:>12.2}",
            name,
            a.requests,
            mean * 1e3,
            a.requests as f64 / wall,
            a.mean_batch_size(),
        );
        sink.push_raw(
            &format!("serve_latency multi-adapter adapter={name}"),
            per_adapter,
            mean * 1e9,
            fastest.as_secs_f64() * 1e9,
            Some(per_adapter as f64 / wall),
        );
    }
    let total_req = n_adapters * per_adapter;
    let fast = results
        .iter()
        .map(|(_, _, f)| *f)
        .min()
        .unwrap_or(Duration::ZERO);
    println!(
        "{:>10} {:>10} {:>12.3} {:>12.1} {:>12.2}",
        "all",
        stats.requests,
        wall / total_req as f64 * 1e3,
        total_req as f64 / wall,
        stats.mean_batch_size(),
    );
    sink.push_raw(
        "serve_latency multi-adapter total",
        total_req,
        wall / total_req as f64 * 1e9,
        fast.as_secs_f64() * 1e9,
        Some(total_req as f64 / wall),
    );
}

/// Pool scale-out: 1/2/4 `BatchServer` workers sharing ONE registry
/// under the same mixed-adapter offered load (2 async clients per
/// worker, reference backend — runs offline, so the sharded serving
/// path is smoked in CI). The 2-worker sweep also emits per-worker
/// routing rows so affinity skew travels with the numbers.
fn pool_scaling(sink: &mut JsonSink) {
    const BATCH: usize = 8;
    const SEQ: usize = 32;
    const VOCAB: usize = 64;
    let n_adapters = 4usize;
    let per_client = irqlora::bench_harness::iters(128).max(16);

    let registry = synthetic_serve_registry(n_adapters, 7);

    println!(
        "\npool scale-out (reference backend, {n_adapters} adapters, \
         {per_client} req/client, 2 clients/worker):"
    );
    println!(
        "{:>8} {:>9} {:>12} {:>12} {:>8} {:>9}",
        "workers", "clients", "req/s", "mean ms", "spills", "reroutes"
    );
    for &workers in &[1usize, 2, 4] {
        let reg = registry.clone();
        let pool = Arc::new(
            ServerPool::spawn_with(
                PoolConfig::new(workers, Duration::from_millis(2)),
                registry.clone(),
                move |_w| {
                    Ok(Box::new(ReferenceBackend::new(BATCH, SEQ, VOCAB, reg.base()))
                        as Box<dyn ServeBackend>)
                },
            )
            .unwrap(),
        );
        let clients = 2 * workers;
        let t = Timer::start();
        let mut handles = Vec::new();
        for c in 0..clients {
            let pool = pool.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(40 + c as u64);
                let mut total = Duration::ZERO;
                let mut fastest = Duration::MAX;
                let mut window = Vec::new();
                for i in 0..per_client {
                    let adapter = format!("tenant{}", (c + i) % n_adapters);
                    let len = 1 + rng.below(SEQ - 1);
                    let prompt: Vec<i32> =
                        (0..len).map(|_| 1 + rng.below(VOCAB - 1) as i32).collect();
                    window.push(pool.submit_async(&adapter, prompt).unwrap());
                    if window.len() >= 8 {
                        for p in window.drain(..) {
                            let r = p.wait().unwrap();
                            total += r.latency;
                            fastest = fastest.min(r.latency);
                        }
                    }
                }
                for p in window.drain(..) {
                    let r = p.wait().unwrap();
                    total += r.latency;
                    fastest = fastest.min(r.latency);
                }
                (total, fastest)
            }));
        }
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let wall = t.elapsed_secs();
        let n_req = clients * per_client;
        let total: Duration = results.iter().map(|(t, _)| *t).sum();
        let fastest = results
            .iter()
            .map(|(_, f)| *f)
            .min()
            .unwrap_or(Duration::ZERO);
        let stats = pool.stats();
        println!(
            "{:>8} {:>9} {:>12.1} {:>12.3} {:>8} {:>9}",
            workers,
            clients,
            n_req as f64 / wall,
            total.as_secs_f64() / n_req as f64 * 1e3,
            stats.spills,
            stats.reroutes
        );
        sink.push_raw(
            &format!("serve_latency pool workers={workers} adapters={n_adapters}"),
            n_req,
            total.as_secs_f64() / n_req as f64 * 1e9,
            fastest.as_secs_f64() * 1e9,
            Some(n_req as f64 / wall),
        );
        if workers == 2 {
            // per-worker ROUTING rows: only iters (requests routed
            // there) and per_sec carry meaning; the ns fields are
            // zeroed rather than filled with inter-arrival pseudo-
            // latency that tooling could mistake for request latency
            for (i, w) in stats.workers.iter().enumerate() {
                sink.push_raw(
                    &format!("serve_latency pool workers=2 worker={i}"),
                    w.routed,
                    0.0,
                    0.0,
                    Some(w.routed as f64 / wall),
                );
            }
        }
        drop(pool); // BatchServer::drop joins each worker cleanly
    }
}

/// Paired fused-vs-serial rows: the same mixed-adapter offered load at
/// 1/4/8 adapters × 1/2/4 workers, once through the fused
/// one-forward-per-drain path and once through the pre-fusion
/// per-adapter-group serial oracle (`[per-group serial]` suffix, the
/// PR-1 naming convention for kept reference paths). Stealing is off
/// in BOTH arms so the pair isolates exactly the forward-call fusion.
fn fused_vs_serial(sink: &mut JsonSink) {
    const BATCH: usize = 8;
    const SEQ: usize = 32;
    const VOCAB: usize = 64;
    let per_client = irqlora::bench_harness::iters(96).max(16);

    println!(
        "\nfused vs per-group serial (reference backend, {per_client} req/client, \
         2 clients/worker):"
    );
    println!(
        "{:>8} {:>9} {:>9} {:>12} {:>12} {:>11} {:>13}",
        "workers", "adapters", "mode", "req/s", "mean ms", "fwd calls", "mean fused occ"
    );
    for &workers in &[1usize, 2, 4] {
        for &n_adapters in &[1usize, 4, 8] {
            let registry = synthetic_serve_registry(n_adapters, 11);
            for &fused in &[true, false] {
                let reg = registry.clone();
                let mut cfg =
                    PoolConfig::new(workers, Duration::from_millis(2)).no_steal();
                if !fused {
                    cfg = cfg.serial();
                }
                let pool = Arc::new(
                    ServerPool::spawn_with(cfg, registry.clone(), move |_w| {
                        Ok(Box::new(ReferenceBackend::new(BATCH, SEQ, VOCAB, reg.base()))
                            as Box<dyn ServeBackend>)
                    })
                    .unwrap(),
                );
                let clients = 2 * workers;
                let t = Timer::start();
                let mut handles = Vec::new();
                for c in 0..clients {
                    let pool = pool.clone();
                    handles.push(std::thread::spawn(move || {
                        let mut rng = Rng::new(60 + c as u64);
                        let mut total = Duration::ZERO;
                        let mut fastest = Duration::MAX;
                        let mut window = Vec::new();
                        for i in 0..per_client {
                            let adapter = format!("tenant{}", (c + i) % n_adapters);
                            let len = 1 + rng.below(SEQ - 1);
                            let prompt: Vec<i32> = (0..len)
                                .map(|_| 1 + rng.below(VOCAB - 1) as i32)
                                .collect();
                            window.push(pool.submit_async(&adapter, prompt).unwrap());
                            if window.len() >= 8 {
                                for p in window.drain(..) {
                                    let r = p.wait().unwrap();
                                    total += r.latency;
                                    fastest = fastest.min(r.latency);
                                }
                            }
                        }
                        for p in window.drain(..) {
                            let r = p.wait().unwrap();
                            total += r.latency;
                            fastest = fastest.min(r.latency);
                        }
                        (total, fastest)
                    }));
                }
                let results: Vec<_> =
                    handles.into_iter().map(|h| h.join().unwrap()).collect();
                let wall = t.elapsed_secs();
                let n_req = clients * per_client;
                let total: Duration = results.iter().map(|(t, _)| *t).sum();
                let fastest = results
                    .iter()
                    .map(|(_, f)| *f)
                    .min()
                    .unwrap_or(Duration::ZERO);
                let stats = pool.stats();
                let fwd: usize = stats.batches;
                let occ: f64 = stats
                    .workers
                    .iter()
                    .map(|w| w.server.mean_fused_occupancy() * w.server.fused_batches as f64)
                    .sum::<f64>()
                    / stats.fused_batches.max(1) as f64;
                println!(
                    "{:>8} {:>9} {:>9} {:>12.1} {:>12.3} {:>11} {:>13.2}",
                    workers,
                    n_adapters,
                    if fused { "fused" } else { "serial" },
                    n_req as f64 / wall,
                    total.as_secs_f64() / n_req as f64 * 1e3,
                    fwd,
                    occ,
                );
                let suffix = if fused { "" } else { " [per-group serial]" };
                sink.push_raw(
                    &format!(
                        "serve_latency fused workers={workers} adapters={n_adapters}{suffix}"
                    ),
                    n_req,
                    total.as_secs_f64() / n_req as f64 * 1e9,
                    fastest.as_secs_f64() * 1e9,
                    Some(n_req as f64 / wall),
                );
                drop(pool);
            }
        }
    }
}

/// Paired native-vs-reference rows: the same mixed-adapter offered
/// load at 1/4/8 adapters × 1/2/4 workers, run once per HAL backend.
/// Workers are constructed through `BackendRegistry::pool_factory` —
/// the same manifest-validated path as `irqlora serve --backend` — so
/// any capability regression (e.g. a backend that stops supporting the
/// serve shape) fails here loudly instead of silently dropping rows.
/// Both backends are bit-identical by contract (the cross-backend test
/// matrix asserts it), so the pair isolates pure compute/layout cost.
fn native_vs_reference(sink: &mut JsonSink) {
    use irqlora::hal::{BackendRegistry, BackendRequest};
    const BATCH: usize = 8;
    const SEQ: usize = 32;
    const VOCAB: usize = 64;
    let per_client = irqlora::bench_harness::iters(96).max(16);

    let hal = BackendRegistry::builtin();
    let backends: Vec<String> = ["native", "reference"]
        .iter()
        .map(|s| s.to_string())
        .filter(|name| match hal.availability(name) {
            Ok(()) => true,
            Err(reason) => {
                eprintln!("skipping backend '{name}' in native-vs-reference ({reason})");
                false
            }
        })
        .collect();

    println!(
        "\nnative vs reference backend ({per_client} req/client, 2 clients/worker):"
    );
    println!(
        "{:>10} {:>8} {:>9} {:>12} {:>12}",
        "backend", "workers", "adapters", "req/s", "mean ms"
    );
    for &workers in &[1usize, 2, 4] {
        for &n_adapters in &[1usize, 4, 8] {
            let registry = synthetic_serve_registry(n_adapters, 19);
            for name in &backends {
                let mut req = BackendRequest::new(BATCH, SEQ, VOCAB);
                req.workers = workers;
                let factory = hal
                    .pool_factory(name, &req, registry.base().clone(), "bench")
                    .unwrap();
                let pool = Arc::new(
                    ServerPool::spawn_with(
                        PoolConfig::new(workers, Duration::from_millis(2)),
                        registry.clone(),
                        factory,
                    )
                    .unwrap(),
                );
                let clients = 2 * workers;
                let t = Timer::start();
                let mut handles = Vec::new();
                for c in 0..clients {
                    let pool = pool.clone();
                    handles.push(std::thread::spawn(move || {
                        let mut rng = Rng::new(80 + c as u64);
                        let mut total = Duration::ZERO;
                        let mut fastest = Duration::MAX;
                        let mut window = Vec::new();
                        for i in 0..per_client {
                            let adapter = format!("tenant{}", (c + i) % n_adapters);
                            let len = 1 + rng.below(SEQ - 1);
                            let prompt: Vec<i32> = (0..len)
                                .map(|_| 1 + rng.below(VOCAB - 1) as i32)
                                .collect();
                            window.push(pool.submit_async(&adapter, prompt).unwrap());
                            if window.len() >= 8 {
                                for p in window.drain(..) {
                                    let r = p.wait().unwrap();
                                    total += r.latency;
                                    fastest = fastest.min(r.latency);
                                }
                            }
                        }
                        for p in window.drain(..) {
                            let r = p.wait().unwrap();
                            total += r.latency;
                            fastest = fastest.min(r.latency);
                        }
                        (total, fastest)
                    }));
                }
                let results: Vec<_> =
                    handles.into_iter().map(|h| h.join().unwrap()).collect();
                let wall = t.elapsed_secs();
                let n_req = clients * per_client;
                let total: Duration = results.iter().map(|(t, _)| *t).sum();
                let fastest = results
                    .iter()
                    .map(|(_, f)| *f)
                    .min()
                    .unwrap_or(Duration::ZERO);
                println!(
                    "{:>10} {:>8} {:>9} {:>12.1} {:>12.3}",
                    name,
                    workers,
                    n_adapters,
                    n_req as f64 / wall,
                    total.as_secs_f64() / n_req as f64 * 1e3,
                );
                sink.push_raw(
                    &format!(
                        "serve_latency backend={name} workers={workers} adapters={n_adapters}"
                    ),
                    n_req,
                    total.as_secs_f64() / n_req as f64 * 1e9,
                    fastest.as_secs_f64() * 1e9,
                    Some(n_req as f64 / wall),
                );
                drop(pool);
            }
        }
    }
}

/// Steal on/off: a skewed burst (half the load on one hot adapter)
/// against a 4-worker pool, once with the work-stealing scheduler and
/// once with the legacy push-spill scheduler. Open-loop submission
/// (handles harvested at the end) so the hot home worker really
/// saturates past its park/spill threshold.
fn steal_on_off(sink: &mut JsonSink) {
    const BATCH: usize = 8;
    const SEQ: usize = 32;
    const VOCAB: usize = 64;
    const WORKERS: usize = 4;
    let n_adapters = 8usize;
    let n_req = (irqlora::bench_harness::iters(384).max(64)).min(900);

    let registry = synthetic_serve_registry(n_adapters, 13);
    println!(
        "\nwork stealing (reference backend, {WORKERS} workers, {n_adapters} adapters, \
         {n_req} open-loop requests, 50% on one hot adapter):"
    );
    println!(
        "{:>6} {:>12} {:>12} {:>8} {:>8} {:>9}",
        "steal", "req/s", "mean ms", "steals", "spills", "reroutes"
    );
    for &steal in &[true, false] {
        let reg = registry.clone();
        let mut cfg = PoolConfig::new(WORKERS, Duration::from_millis(2));
        if !steal {
            cfg = cfg.no_steal();
        }
        let pool = ServerPool::spawn_with(cfg, registry.clone(), move |_w| {
            Ok(Box::new(
                ReferenceBackend::new(BATCH, SEQ, VOCAB, reg.base())
                    .with_forward_delay(Duration::from_micros(300)),
            ) as Box<dyn ServeBackend>)
        })
        .unwrap();
        let mut rng = Rng::new(21);
        let t = Timer::start();
        let handles: Vec<_> = (0..n_req)
            .map(|i| {
                // every other request hammers tenant0; the rest spread
                let adapter = if i % 2 == 0 {
                    "tenant0".to_string()
                } else {
                    format!("tenant{}", 1 + i % (n_adapters - 1))
                };
                let len = 1 + rng.below(SEQ - 1);
                let prompt: Vec<i32> =
                    (0..len).map(|_| 1 + rng.below(VOCAB - 1) as i32).collect();
                pool.submit_async(&adapter, prompt).unwrap()
            })
            .collect();
        let mut total = Duration::ZERO;
        let mut fastest = Duration::MAX;
        for h in handles {
            let r = h.wait().unwrap();
            total += r.latency;
            fastest = fastest.min(r.latency);
        }
        let wall = t.elapsed_secs();
        let stats = pool.stats();
        println!(
            "{:>6} {:>12.1} {:>12.3} {:>8} {:>8} {:>9}",
            if steal { "on" } else { "off" },
            n_req as f64 / wall,
            total.as_secs_f64() / n_req as f64 * 1e3,
            stats.steals,
            stats.spills,
            stats.reroutes,
        );
        sink.push_raw(
            &format!(
                "serve_latency pool steal={} workers={WORKERS} adapters={n_adapters}",
                if steal { "on" } else { "off" }
            ),
            n_req,
            total.as_secs_f64() / n_req as f64 * 1e9,
            fastest.as_secs_f64() * 1e9,
            Some(n_req as f64 / wall),
        );
        pool.shutdown();
    }
}

/// Paired streamed-vs-oneshot rows: the same skewed open-loop offered
/// load (half on one hot adapter) against a 4-worker continuous-
/// batching pool, once as 4-step decode streams and once as one-shot
/// requests. Streamed rows report time-to-first-token — the p50/p99 of
/// each stream's first-step submit-to-reply latency — plus decode
/// throughput in tokens/sec; the oneshot pair reports the same
/// quantities, where TTFT degenerates to full request latency and
/// every request emits exactly one token. Harvest iterates each
/// `Pending` as a stream for both arms (a one-shot is a 1-step
/// stream), so the rows measure the scheduler clients actually use.
fn streamed_vs_oneshot(sink: &mut JsonSink) {
    const BATCH: usize = 8;
    const SEQ: usize = 32;
    const VOCAB: usize = 64;
    const WORKERS: usize = 4;
    const STEPS: usize = 4;
    let n_adapters = 8usize;
    let n_req = (irqlora::bench_harness::iters(384).max(64)).min(900);

    let registry = synthetic_serve_registry(n_adapters, 13);
    println!(
        "\nstreamed vs oneshot (reference backend, {WORKERS} workers, {n_adapters} adapters, \
         {n_req} open-loop requests, {STEPS}-step streams, 50% on one hot adapter):"
    );
    println!(
        "{:>9} {:>13} {:>13} {:>12} {:>12}",
        "mode", "ttft p50 ms", "ttft p99 ms", "tokens/s", "req/s"
    );
    for &streamed in &[true, false] {
        let reg = registry.clone();
        let pool = ServerPool::spawn_with(
            PoolConfig::new(WORKERS, Duration::from_millis(2)),
            registry.clone(),
            move |_w| {
                Ok(Box::new(
                    ReferenceBackend::new(BATCH, SEQ, VOCAB, reg.base())
                        .with_forward_delay(Duration::from_micros(300)),
                ) as Box<dyn ServeBackend>)
            },
        )
        .unwrap();
        let mut rng = Rng::new(21);
        let t = Timer::start();
        let handles: Vec<_> = (0..n_req)
            .map(|i| {
                // identical skew to the steal_on_off burst: every other
                // request hammers tenant0, the rest spread
                let adapter = if i % 2 == 0 {
                    "tenant0".to_string()
                } else {
                    format!("tenant{}", 1 + i % (n_adapters - 1))
                };
                // leave room for STEPS-1 decoded tokens within SEQ
                let len = 1 + rng.below(SEQ - STEPS);
                let prompt: Vec<i32> =
                    (0..len).map(|_| 1 + rng.below(VOCAB - 1) as i32).collect();
                if streamed {
                    pool.submit_stream(&adapter, prompt, STEPS).unwrap()
                } else {
                    pool.submit_async(&adapter, prompt).unwrap()
                }
            })
            .collect();
        let mut ttft: Vec<f64> = Vec::with_capacity(n_req);
        let mut tokens = 0usize;
        for h in handles {
            let mut first = true;
            for r in h {
                let r = r.unwrap();
                if first {
                    ttft.push(r.latency.as_secs_f64());
                    first = false;
                }
                tokens += 1;
                if r.last {
                    break;
                }
            }
        }
        let wall = t.elapsed_secs();
        ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| ttft[((ttft.len() - 1) as f64 * p) as usize];
        let mode = if streamed { "streamed" } else { "oneshot" };
        println!(
            "{:>9} {:>13.3} {:>13.3} {:>12.1} {:>12.1}",
            mode,
            q(0.5) * 1e3,
            q(0.99) * 1e3,
            tokens as f64 / wall,
            n_req as f64 / wall,
        );
        sink.push_raw(
            &format!(
                "serve_latency {mode} ttft p50 workers={WORKERS} adapters={n_adapters}"
            ),
            n_req,
            q(0.5) * 1e9,
            ttft[0] * 1e9,
            Some(n_req as f64 / wall),
        );
        sink.push_raw(
            &format!(
                "serve_latency {mode} ttft p99 workers={WORKERS} adapters={n_adapters}"
            ),
            n_req,
            q(0.99) * 1e9,
            ttft[0] * 1e9,
            Some(n_req as f64 / wall),
        );
        // tokens row: iters = tokens emitted, per_sec = decode
        // throughput, ns_per_iter = mean wall time per emitted token;
        // ns_min is zeroed (the pool_scaling convention for fields
        // that would otherwise carry a misleading pseudo-latency)
        sink.push_raw(
            &format!(
                "serve_latency {mode} tokens_per_sec workers={WORKERS} adapters={n_adapters}"
            ),
            tokens,
            wall / tokens.max(1) as f64 * 1e9,
            0.0,
            Some(tokens as f64 / wall),
        );
        pool.shutdown();
    }
}

/// Saturation under admission control: calibrate the pool's clean
/// closed-loop throughput, then offer an open-loop stream paced at 2×
/// that rate against a deliberately small parked overflow. Reports
/// what a graceful-shedding server should show: delivered p50/p99
/// wait stays bounded while the excess is refused with `Overloaded`
/// (counted in the `shed` row) instead of growing queues without
/// limit. With `IRQLORA_SERVE_STEAL=0` the legacy scheduler has no
/// parked overflow, so the shed row legitimately reads 0.
fn saturation(sink: &mut JsonSink) {
    use irqlora::coordinator::ServeError;
    const BATCH: usize = 8;
    const SEQ: usize = 32;
    const VOCAB: usize = 64;
    const WORKERS: usize = 4;
    let n_adapters = 4usize;
    let n_req = (irqlora::bench_harness::iters(512).max(64)).min(1200);

    let registry = synthetic_serve_registry(n_adapters, 17);
    let reg = registry.clone();
    let mut cfg = PoolConfig::new(WORKERS, Duration::from_millis(1));
    cfg.spill_depth = Some(2);
    cfg.park_bound = Some(16);
    cfg.park_age = Some(Duration::from_millis(4));
    let pool = ServerPool::spawn_with(cfg, registry.clone(), move |_w| {
        Ok(Box::new(
            ReferenceBackend::new(BATCH, SEQ, VOCAB, reg.base())
                .with_forward_delay(Duration::from_micros(300)),
        ) as Box<dyn ServeBackend>)
    })
    .unwrap();

    let mut rng = Rng::new(29);
    let mut gen = |i: usize| {
        let adapter = format!("tenant{}", i % n_adapters);
        let len = 1 + rng.below(SEQ - 1);
        let prompt: Vec<i32> = (0..len).map(|_| 1 + rng.below(VOCAB - 1) as i32).collect();
        (adapter, prompt)
    };

    // calibration: closed-loop (windowed) clean throughput
    let cal = irqlora::bench_harness::iters(128).max(32);
    let t = Timer::start();
    let mut window = Vec::new();
    for i in 0..cal {
        let (adapter, prompt) = gen(i);
        window.push(pool.submit_async(&adapter, prompt).unwrap());
        if window.len() >= 8 {
            for p in window.drain(..) {
                p.wait().unwrap();
            }
        }
    }
    for p in window.drain(..) {
        p.wait().unwrap();
    }
    let clean_rate = cal as f64 / t.elapsed_secs().max(1e-9);

    // offered load at 2× the measured clean rate, open loop: nothing
    // is harvested until every submission is in
    let gap = Duration::from_secs_f64(1.0 / (2.0 * clean_rate));
    let mut handles = Vec::new();
    let mut shed = 0usize;
    let t = Timer::start();
    for i in 0..n_req {
        let (adapter, prompt) = gen(i);
        match pool.submit_async(&adapter, prompt) {
            Ok(p) => handles.push(p),
            Err(ServeError::Overloaded { .. }) => shed += 1,
            Err(e) => panic!("saturation submit failed unexpectedly: {e}"),
        }
        std::thread::sleep(gap);
    }
    // harvest: admitted requests can still be shed while parked (the
    // 4ms aging bound), which is exactly the graceful degradation this
    // row measures — count those with the refusals, panic on anything
    // else (no faults are injected here)
    let mut waits: Vec<f64> = Vec::new();
    for p in handles {
        match p.wait() {
            Ok(reply) => waits.push(reply.latency.as_secs_f64()),
            Err(ServeError::DeadlineExceeded { .. }) => shed += 1,
            Err(e) => panic!("saturation harvest failed unexpectedly: {e}"),
        }
    }
    let wall = t.elapsed_secs();
    let delivered = waits.len();
    if waits.is_empty() {
        // pathological (everything refused): still emit the row family
        // so downstream greps see it, with honest zeros
        for row in ["p50", "p99"] {
            sink.push_raw(&format!("serve_latency saturation {row} workers=4"), 0, 0.0, 0.0, None);
        }
        sink.push_raw(
            "serve_latency saturation shed workers=4",
            shed,
            0.0,
            0.0,
            Some(shed as f64 / wall),
        );
        pool.shutdown();
        return;
    }
    waits.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| waits[((waits.len() - 1) as f64 * p) as usize];
    let stats = pool.stats();

    println!(
        "\nsaturation (reference backend, {WORKERS} workers, 2x clean rate \
         {:.0} req/s offered, park bound 16):",
        2.0 * clean_rate
    );
    println!(
        "{:>10} {:>8} {:>12} {:>12} {:>12} {:>12}",
        "delivered", "shed", "p50 ms", "p99 ms", "req/s", "parked peak"
    );
    println!(
        "{:>10} {:>8} {:>12.3} {:>12.3} {:>12.1} {:>12}",
        delivered,
        shed,
        q(0.5) * 1e3,
        q(0.99) * 1e3,
        delivered as f64 / wall,
        stats.parked_peak,
    );
    sink.push_raw(
        "serve_latency saturation p50 workers=4",
        delivered,
        q(0.5) * 1e9,
        waits[0] * 1e9,
        Some(delivered as f64 / wall),
    );
    sink.push_raw(
        "serve_latency saturation p99 workers=4",
        delivered,
        q(0.99) * 1e9,
        waits[0] * 1e9,
        Some(delivered as f64 / wall),
    );
    // shed row: iters = refused requests; ns fields are meaningless
    // for refusals and stay zeroed (the pool_scaling convention)
    sink.push_raw(
        "serve_latency saturation shed workers=4",
        shed,
        0.0,
        0.0,
        Some(shed as f64 / wall),
    );
    pool.shutdown();
}
