//! Bench: mixed-precision planner cost — per-tensor information
//! profiling (the dominant term: an ICQ τ search per candidate
//! bit-width per block) and the greedy budget solve, at increasing
//! synthetic model sizes. Rows land in `BENCH_quant.json` so the
//! planner's overhead trajectory travels with the code, next to the
//! quantization throughput it gates.
//!
//! Run: cargo bench --bench plan_throughput
//! Env: IRQLORA_BENCH_QUICK=1 (1 iter smoke), IRQLORA_THREADS=n,
//!      IRQLORA_BENCH_JSON=path

use irqlora::bench_harness::{bench_json_path, bench_throughput, iters, JsonSink};
use irqlora::model::weights::is_quantized_proj;
use irqlora::precision::{
    plan, profile_model, synthetic_model, PlannerConfig, ProfileConfig,
};

fn main() {
    let mut sink = JsonSink::new();
    let it = iters(3);

    // (layers, hidden) — ~41k / ~82k / ~328k quantized params
    for (layers, h) in [(1usize, 64usize), (2, 64), (2, 128)] {
        let model = synthetic_model(layers, h, 9);
        let pcfg = ProfileConfig::default();
        let params: usize = model
            .iter()
            .filter(|(n, _)| is_quantized_proj(n))
            .map(|(_, t)| t.len())
            .sum();

        let mut profile = None;
        let r = bench_throughput(
            &format!("plan_profile l{layers} h{h} ({params} params)"),
            0,
            it,
            params as f64,
            "elem",
            || {
                profile = Some(profile_model(&model, &pcfg));
            },
        );
        sink.push(&r, Some(params as f64));

        let profile = profile.expect("profiled at least once");
        let cfg = PlannerConfig::new(3.2);
        let r = bench_throughput(
            &format!("plan_solve l{layers} h{h} ({params} params)"),
            1,
            it,
            params as f64,
            "elem",
            || {
                std::hint::black_box(plan(&profile, &cfg).expect("solvable"));
            },
        );
        sink.push(&r, Some(params as f64));
    }

    let path = bench_json_path("BENCH_quant.json");
    match sink.write_merged(&path) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", path.display()),
    }
}
