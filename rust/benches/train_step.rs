//! Bench: end-to-end finetune step time through the AOT train graph
//! (the denominator of the paper's Table 7 overhead percentages), and
//! pretrain step for comparison. Requires `make artifacts`.
//! Run: cargo bench --bench train_step

use irqlora::bench_harness::{bench, iters};
use irqlora::coordinator::{Finetuner, Pretrainer};
use irqlora::coordinator::quantize_model;
use irqlora::data::instruct::{instruct_batch, Dataset};
use irqlora::data::{corpus, World};
use irqlora::model::weights::init_base;
use irqlora::quant::Method;
use irqlora::runtime::{Manifest, Runtime};
use irqlora::util::Rng;

fn main() {
    let Ok(manifest) = Manifest::load("artifacts") else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let tag = "xs";
    let size = manifest.size(tag).unwrap();
    let (b, s) = (size.config.batch, size.config.seq);
    let world = World::new(1);
    let mut rng = Rng::new(1);

    // pretrain step
    let mut pre = Pretrainer::new(&rt, &manifest, tag, 1).unwrap();
    bench("pretrain_step nano-xs (B=8, S=128)", 2, iters(10), || {
        let batch = corpus::pretrain_batch(&world, &mut rng, b, s);
        std::hint::black_box(pre.step(batch.tokens, batch.targets).unwrap());
    });

    // finetune step (quantized base, LoRA+IEC)
    let spec = manifest.graph(tag, "pretrain_step").unwrap();
    let nb = irqlora::coordinator::trainer::pretrain_layout(spec.inputs.len()).unwrap();
    let mut rng2 = Rng::new(2);
    let base = init_base(&spec.inputs[..nb], size.config.n_layers, &mut rng2);
    let qm = quantize_model(&base, Method::NfIcq { k: 4 }, 1).unwrap();
    let mut ft = Finetuner::new(&rt, &manifest, tag, &qm.dequantized, (1.0, 1.0), 1).unwrap();
    let mut rng3 = Rng::new(3);
    bench("finetune_step nano-xs IR-QLoRA (B=8, S=128)", 2, iters(10), || {
        let batch = instruct_batch(&world, Dataset::AlpacaSyn, &mut rng3, b, s);
        std::hint::black_box(ft.step(batch.tokens, batch.targets).unwrap());
    });

    let mut ft0 = Finetuner::new(&rt, &manifest, tag, &qm.dequantized, (0.0, 0.0), 1).unwrap();
    bench("finetune_step nano-xs vanilla QLoRA", 2, iters(10), || {
        let batch = instruct_batch(&world, Dataset::AlpacaSyn, &mut rng3, b, s);
        std::hint::black_box(ft0.step(batch.tokens, batch.targets).unwrap());
    });
}
