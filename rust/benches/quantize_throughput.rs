//! Bench: blockwise NF quant/dequant + packing throughput — the raw
//! storage-pipeline cost per weight (feeds the Table 6 storage story).
//! Run: cargo bench --bench quantize_throughput

use irqlora::bench_harness::bench_throughput;
use irqlora::quant::{blockwise, QuantizedTensor};
use irqlora::util::{Rng, Tensor};

fn main() {
    let n = 1 << 20; // 1M weights
    let mut rng = Rng::new(1);
    let w = rng.normal_vec(n, 0.0, 0.02);
    let t = Tensor::new(&[n], w.clone());

    for k in [2u8, 3, 4] {
        bench_throughput(
            &format!("blockwise_quantize_nf{k} (1M f32)"),
            1,
            10,
            n as f64,
            "elem",
            || {
                std::hint::black_box(blockwise::quantize(&w, k, 64, None));
            },
        );
    }

    let q = blockwise::quantize(&w, 4, 64, None);
    bench_throughput("dequantize_nf4 (1M)", 1, 10, n as f64, "elem", || {
        std::hint::black_box(blockwise::dequantize(&q));
    });
    bench_throughput("pack_codes 4bit (1M)", 1, 10, n as f64, "elem", || {
        std::hint::black_box(blockwise::pack_codes(&q.codes, 4));
    });
    let packed = blockwise::pack_codes(&q.codes, 4);
    bench_throughput("unpack_codes 4bit (1M)", 1, 10, n as f64, "elem", || {
        std::hint::black_box(blockwise::unpack_codes(&packed, 4, n));
    });
    bench_throughput(
        "full_pipeline_quantize (double-quant incl.)",
        1,
        5,
        n as f64,
        "elem",
        || {
            std::hint::black_box(QuantizedTensor::quantize(&t, 4, 64, None));
        },
    );
}
