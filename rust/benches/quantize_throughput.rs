//! Bench: blockwise NF quant/dequant + packing throughput — the raw
//! storage-pipeline cost per weight (feeds the Table 6 storage story
//! and the §Perf claims of the fused packed-domain pipeline).
//!
//! Every operation is measured twice: the `[reference serial]` rows run
//! the original element-at-a-time implementations (kept in-tree as the
//! property-test oracles), the unsuffixed rows run the parallel /
//! fused fast paths. Both land in `BENCH_quant.json` so the before /
//! after ratio is recorded with the code that produced it.
//!
//! Run: cargo bench --bench quantize_throughput
//! Env: IRQLORA_BENCH_QUICK=1 (1 iter smoke), IRQLORA_THREADS=n,
//!      IRQLORA_BENCH_JSON=path

use irqlora::bench_harness::{bench_json_path, bench_throughput, iters, JsonSink};
use irqlora::quant::blockwise::{self, QuantizedBlocks};
use irqlora::quant::{DequantScratch, QuantizedTensor};
use irqlora::util::{Rng, Tensor};

fn main() {
    let n = 1 << 20; // 1M weights
    let mut rng = Rng::new(1);
    let w = rng.normal_vec(n, 0.0, 0.02);
    let t = Tensor::new(&[n], w.clone());
    let it = iters(10);
    let mut sink = JsonSink::new();

    // --- blockwise quantization: reference serial vs parallel ---
    for k in [2u8, 3, 4] {
        let r = bench_throughput(
            &format!("blockwise_quantize_nf{k} (1M f32) [reference serial]"),
            1,
            it,
            n as f64,
            "elem",
            || {
                std::hint::black_box(blockwise::quantize_reference(&w, k, 64, None));
            },
        );
        sink.push(&r, Some(n as f64));
        let mut q_scratch = QuantizedBlocks::scratch();
        let r = bench_throughput(
            &format!("blockwise_quantize_nf{k} (1M f32)"),
            1,
            it,
            n as f64,
            "elem",
            || {
                blockwise::quantize_into(&w, k, 64, None, &mut q_scratch);
                std::hint::black_box(&q_scratch);
            },
        );
        sink.push(&r, Some(n as f64));
    }

    // --- dequantization (unpacked domain): reference vs parallel ---
    let q = blockwise::quantize(&w, 4, 64, None);
    let r = bench_throughput(
        "dequantize_nf4 unpacked (1M) [reference serial]",
        1,
        it,
        n as f64,
        "elem",
        || {
            std::hint::black_box(blockwise::dequantize_reference(&q));
        },
    );
    sink.push(&r, Some(n as f64));
    let mut deq = vec![0f32; n];
    let r = bench_throughput(
        "dequantize_nf4 unpacked (1M)",
        1,
        it,
        n as f64,
        "elem",
        || {
            blockwise::dequantize_into(&q, &mut deq);
            std::hint::black_box(&deq);
        },
    );
    sink.push(&r, Some(n as f64));

    // --- bit packing: reference vs byte-aligned parallel ---
    let r = bench_throughput(
        "pack_codes 4bit (1M) [reference serial]",
        1,
        it,
        n as f64,
        "elem",
        || {
            std::hint::black_box(blockwise::pack_codes_reference(&q.codes, 4));
        },
    );
    sink.push(&r, Some(n as f64));
    let mut packed_buf = Vec::new();
    let r = bench_throughput("pack_codes 4bit (1M)", 1, it, n as f64, "elem", || {
        blockwise::pack_codes_into(&q.codes, 4, &mut packed_buf);
        std::hint::black_box(&packed_buf);
    });
    sink.push(&r, Some(n as f64));

    let packed = blockwise::pack_codes(&q.codes, 4);
    let r = bench_throughput(
        "unpack_codes 4bit (1M) [reference serial]",
        1,
        it,
        n as f64,
        "elem",
        || {
            std::hint::black_box(blockwise::unpack_codes_reference(&packed, 4, n));
        },
    );
    sink.push(&r, Some(n as f64));
    let mut codes_buf = Vec::new();
    let r = bench_throughput("unpack_codes 4bit (1M)", 1, it, n as f64, "elem", || {
        blockwise::unpack_codes_into(&packed, 4, n, &mut codes_buf);
        std::hint::black_box(&codes_buf);
    });
    sink.push(&r, Some(n as f64));

    // --- the headline: full storage-pipeline dequantization ---
    // reference = unpack every code to a byte, reconstruct constants,
    // serial dequant (the pre-fusion pipeline); fast = fused LUT dequant
    // straight from packed bytes with reused scratch.
    let qt = QuantizedTensor::quantize(&t, 4, 64, None);
    let r = bench_throughput(
        "dequantize_nf4 (1M) [reference serial]",
        1,
        it,
        n as f64,
        "elem",
        || {
            std::hint::black_box(qt.dequantize_reference());
        },
    );
    sink.push(&r, Some(n as f64));
    let mut out = vec![0f32; n];
    let mut scratch = DequantScratch::default();
    let r = bench_throughput("dequantize_nf4 (1M)", 1, it, n as f64, "elem", || {
        qt.dequantize_into(&mut out, &mut scratch);
        std::hint::black_box(&out);
    });
    sink.push(&r, Some(n as f64));

    // --- full pipeline quantize (pack + double-quant included) ---
    let r = bench_throughput(
        "full_pipeline_quantize (double-quant incl.)",
        1,
        iters(5),
        n as f64,
        "elem",
        || {
            std::hint::black_box(QuantizedTensor::quantize(&t, 4, 64, None));
        },
    );
    sink.push(&r, Some(n as f64));

    let path = bench_json_path("BENCH_quant.json");
    match sink.write_merged(&path) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", path.display()),
    }
}
