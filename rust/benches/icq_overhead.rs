//! Bench: ICQ tau-search cost vs vanilla quantization, and the
//! paper-relative claim that ICQ adds <1% of finetuning time
//! (Tables 6/7 and the §4.2 efficiency ablation).
//! Run: cargo bench --bench icq_overhead

use irqlora::bench_harness::{bench, bench_throughput, iters};
use irqlora::quant::icq::{self, IcqConfig};
use irqlora::quant::{blockwise, Method};
use irqlora::coordinator::quantize_model;
use irqlora::model::weights::init_base;
use irqlora::runtime::{Dtype, InputSpec};
use irqlora::util::Rng;

fn main() {
    let n = 1 << 18; // 256K weights
    let mut rng = Rng::new(2);
    let w = rng.normal_vec(n, 0.005, 0.02);

    let vanilla = bench_throughput(
        "vanilla_nf4_quantize (256K)",
        1,
        iters(5),
        n as f64,
        "elem",
        || {
            std::hint::black_box(blockwise::quantize(&w, 4, 64, None));
        },
    );
    let icq_r = bench_throughput(
        "icq_nf4_quantize (256K, 201 taus, parallel)",
        1,
        iters(5),
        n as f64,
        "elem",
        || {
            std::hint::black_box(icq::quantize(&w, 4, 64, &IcqConfig::default()));
        },
    );
    println!(
        "\nICQ search overhead vs vanilla quantization: {:.1}x",
        icq_r.mean_secs() / vanilla.mean_secs()
    );

    // single-block search cost (Algorithm 1 inner loop):
    // §Perf before/after — the sorted-block fast path vs the naive
    // reference loop (bit-identical results, property-tested)
    let block = &w[0..64];
    let before = bench("icq_search_tau REFERENCE (naive loop)", 10, iters(50), || {
        std::hint::black_box(icq::search_tau_reference(block, 4, &IcqConfig::default()));
    });
    let after = bench("icq_search_tau FAST (sorted+binary-search)", 10, iters(50), || {
        std::hint::black_box(icq::search_tau(block, 4, &IcqConfig::default()));
    });
    println!(
        "
ICQ inner-loop speedup (fast vs reference): {:.2}x",
        before.mean_secs() / after.mean_secs()
    );

    // model-level: quantization time as a fraction of a finetune run.
    // The paper reports <=0.84% extra; our reference point is the
    // measured finetune step time (see bench train_step) — printed here
    // as absolute quantize-time for a ~1.3M-param model.
    let specs: Vec<InputSpec> = vec![
        InputSpec { name: "l0.wq".into(), shape: vec![384, 384], dtype: Dtype::F32 },
        InputSpec { name: "l0.w1".into(), shape: vec![384, 768], dtype: Dtype::F32 },
        InputSpec { name: "l0.w2".into(), shape: vec![768, 384], dtype: Dtype::F32 },
    ];
    let mut rng = Rng::new(3);
    let model = init_base(&specs, 6, &mut rng);
    bench("quantize_model NfIcq (0.74M params)", 1, iters(3), || {
        std::hint::black_box(quantize_model(&model, Method::NfIcq { k: 4 }, 0).unwrap());
    });
    bench("quantize_model Nf (0.74M params)", 1, iters(3), || {
        std::hint::black_box(quantize_model(&model, Method::Nf { k: 4 }, 0).unwrap());
    });
}
