//! Offline **stub** of the vendored xla-rs (PJRT) bindings.
//!
//! The real crate wraps the PJRT C API (xla_extension, CPU plugin) and
//! is not in the offline vendor set. This stub keeps the whole
//! `runtime` / `coordinator` layer compiling and testable without it:
//! every constructor ([`PjRtClient::cpu`],
//! [`HloModuleProto::from_text_file`]) returns a clear
//! "PJRT unavailable" error, and every other type is uninhabited — the
//! methods on them typecheck but are statically unreachable, so the
//! stub cannot silently miscompute.
//!
//! Call sites need no `cfg` gating: integration tests and benches that
//! would reach PJRT already self-skip when `make artifacts` hasn't
//! produced HLO files, and [`crate::Runtime`-level] callers surface the
//! constructor error verbatim. Restoring the real crate is a
//! Cargo.toml path swap (ROADMAP open item).

use std::fmt;

/// Error type of every fallible stub call.
#[derive(Clone, Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error {
            msg: format!(
                "{what}: PJRT runtime unavailable — this build uses the offline \
                 `xla` stub (rust/vendor/xla); restore the vendored xla-rs crate \
                 to run HLO artifacts"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types transferable to/from device buffers and literals.
pub trait ArrayElement: Copy {}
impl ArrayElement for f32 {}
impl ArrayElement for f64 {}
impl ArrayElement for i32 {}
impl ArrayElement for i64 {}
impl ArrayElement for u8 {}
impl ArrayElement for u32 {}

/// Uninhabited marker: values of stub device types cannot exist.
#[derive(Clone, Debug)]
enum Void {}

/// Stub PJRT client. [`PjRtClient::cpu`] always fails; the remaining
/// methods are unreachable (no client value can exist).
#[derive(Debug)]
pub struct PjRtClient(Void);

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        match self.0 {}
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self.0 {}
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        match self.0 {}
    }
}

/// Stub device buffer (uninhabited).
#[derive(Debug)]
pub struct PjRtBuffer(Void);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self.0 {}
    }
}

/// Stub compiled executable (uninhabited).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(Void);

impl PjRtLoadedExecutable {
    /// Execute over device buffers; one inner `Vec` per replica.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.0 {}
    }
}

/// Stub host literal (uninhabited).
#[derive(Debug)]
pub struct Literal(Void);

impl Literal {
    pub fn ty(&self) -> Result<ElementType> {
        match self.0 {}
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        match self.0 {}
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match self.0 {}
    }

    pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal> {
        match self.0 {}
    }
}

/// Stub parsed HLO module. [`HloModuleProto::from_text_file`] always
/// fails (parsing needs the real bindings).
#[derive(Debug)]
pub struct HloModuleProto(Void);

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!("parsing HLO text '{path}'")))
    }
}

/// Stub XLA computation (uninhabited; only constructible from a proto,
/// which itself cannot exist in the stub).
#[derive(Debug)]
pub struct XlaComputation(Void);

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match proto.0 {}
    }
}

/// Literal element types (the subset the host layer distinguishes,
/// plus enough others that matches need a wildcard arm, as with the
/// real bindings).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U8,
    U32,
    F16,
    Bf16,
    F32,
    F64,
}

/// XLA primitive types accepted by [`Literal::convert`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    Pred,
    S32,
    S64,
    U8,
    U32,
    F16,
    Bf16,
    F32,
    F64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_fail_with_clear_message() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT runtime unavailable"), "{e}");
        let e = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
        assert!(e.to_string().contains("x.hlo.txt"), "{e}");
    }

    #[test]
    fn error_converts_via_std_error() {
        fn take(_: &dyn std::error::Error) {}
        take(&Error::unavailable("t"));
    }
}
