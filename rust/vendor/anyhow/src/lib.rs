//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The real `anyhow` is not in the offline vendor set, so this shim
//! provides the exact API surface the repo uses — [`Result`],
//! [`Error`], the [`Context`] extension trait for `Result`/`Option`,
//! and the [`anyhow!`] / [`bail!`] / [`ensure!`] macros — with the same
//! semantics the call sites rely on:
//!
//! - `{e}` prints the outermost message, `{e:#}` the whole context
//!   chain joined by `": "`, `{e:?}` the message plus a `Caused by:`
//!   list (what `fn main() -> Result<()>` prints on error);
//! - `?` converts any `std::error::Error + Send + Sync + 'static` into
//!   [`Error`], capturing its `source()` chain;
//! - `.context(..)` works on `Result<_, E: std::error::Error>`,
//!   `Result<_, Error>`, and `Option<_>`.
//!
//! Deliberately not implemented (unused in this repo): downcasting,
//! backtraces, `Error::new`/`chain` adaptors beyond [`Error::chain`].
//! Like the real crate, [`Error`] does **not** implement
//! `std::error::Error` — that is what lets the blanket `From` impl
//! coexist with the reflexive `From<Error> for Error`.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>`, with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error: a chain of messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    fn from_std<E: StdError + ?Sized>(e: &E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    /// Wrap with an outer context message (what [`Context`] calls).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

mod private {
    use std::error::Error as StdError;

    /// Sealed bridge: either a std error or already an [`crate::Error`].
    /// Mirrors the real anyhow's `ext::StdError` trick — the two impls
    /// don't overlap because `crate::Error` is not a `std` error.
    pub trait IntoAnyhow {
        fn into_anyhow(self) -> crate::Error;
    }

    impl<E: StdError + Send + Sync + 'static> IntoAnyhow for E {
        fn into_anyhow(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoAnyhow for crate::Error {
        fn into_anyhow(self) -> crate::Error {
            self
        }
    }
}

/// Attach context to a `Result` or `Option` error path.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: private::IntoAnyhow> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_anyhow().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_anyhow().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err()
            .context("starting up");
        assert_eq!(format!("{e}"), "starting up");
        assert_eq!(format!("{e:#}"), "starting up: reading config: file gone");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:") && dbg.contains("file gone"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{:#}", inner().unwrap_err()), "file gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        let e = Some(7u32).with_context(|| "unused").unwrap();
        assert_eq!(e, 7);
    }

    #[test]
    fn macros() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let n = 3;
        let e = anyhow!("n = {}", n);
        assert_eq!(format!("{e}"), "n = 3");
        let e = anyhow!("inline {n}");
        assert_eq!(format!("{e}"), "inline 3");

        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(format!("{:#}", f(false).unwrap_err()), "flag was false");
    }
}
