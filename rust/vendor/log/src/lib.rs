//! Minimal offline stand-in for the `log` crate.
//!
//! Provides the facade surface this repo uses: the [`Log`] trait,
//! [`Level`] / [`LevelFilter`], [`set_logger`] / [`set_max_level`],
//! and the [`error!`] … [`trace!`] macros. Records are dispatched to a
//! single process-global logger; no module-path filtering beyond the
//! global max level.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Severity of a log record, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Global verbosity ceiling ([`set_max_level`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Metadata about a record: its level and target module path.
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the formatted message arguments.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink. Implementations must be thread-safe.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);

/// Returned when [`set_logger`] is called twice.
#[derive(Debug)]
pub struct SetLoggerError(());

/// Install the process-global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global verbosity ceiling.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// The current global verbosity ceiling.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

/// Macro plumbing — not part of the public facade.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[doc(hidden)]
#[macro_export]
macro_rules! __log_at {
    ($level:expr, $($arg:tt)+) => {
        $crate::__log($level, ::core::module_path!(), ::core::format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::__log_at!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::__log_at!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::__log_at!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::__log_at!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::__log_at!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;
    impl Log for Counter {
        fn enabled(&self, _: &Metadata) -> bool {
            true
        }
        fn log(&self, record: &Record) {
            assert_eq!(record.level(), Level::Info);
            assert_eq!(format!("{}", record.args()), "x = 7");
            HITS.fetch_add(1, Ordering::Relaxed);
        }
        fn flush(&self) {}
    }

    #[test]
    fn filtered_dispatch() {
        static LOGGER: Counter = Counter;
        let _ = set_logger(&LOGGER);

        set_max_level(LevelFilter::Off);
        info!("x = {}", 7);
        assert_eq!(HITS.load(Ordering::Relaxed), 0);

        set_max_level(LevelFilter::Info);
        assert_eq!(max_level(), LevelFilter::Info);
        let x = 7;
        info!("x = {x}");
        assert_eq!(HITS.load(Ordering::Relaxed), 1);
        debug!("suppressed {}", 1); // above the ceiling
        assert_eq!(HITS.load(Ordering::Relaxed), 1);
    }
}
