//! # IR-QLoRA
//!
//! Reproduction of *"Accurate LoRA-Finetuning Quantization of LLMs via
//! Information Retention"* (ICML 2024) as a three-layer Rust + JAX +
//! Pallas system. See `DESIGN.md` for the architecture and the
//! per-experiment index.
//!
//! Layer map:
//! - [`quant`] + [`lora`] — the paper's contribution (ICQ, IEC) and all
//!   baselines, in Rust;
//! - [`precision`] — information-budgeted mixed-precision planning
//!   (profile → plan → apply over the ICQ entropy metric);
//! - [`kernels`] — dense + packed-domain GEMM kernels with serial
//!   reference oracles (`gemm_f32`, `gemm_packed` computing y = W_q·x
//!   straight from packed NF-k codes);
//! - [`model`] / [`data`] — NanoLLaMA substrate and synthetic corpora;
//! - [`runtime`] — PJRT loader/executor for the AOT HLO artifacts;
//! - [`coordinator`] — quantize → finetune → evaluate → serve pipeline;
//! - [`hal`] — serving-backend HAL: capability manifests, validated
//!   registration, and named backend selection (`reference`, `native`,
//!   `pjrt`);
//! - [`telemetry`] — labeled counters/gauges/timers threaded through
//!   quantize → plan → merge → serve (zero-cost unless
//!   `IRQLORA_TELEMETRY=1`; JSONL snapshots + `irqlora stats`);
//! - [`tables`] — paper-format table/figure regeneration.

pub mod util;
pub mod quant;
pub mod kernels;
pub mod precision;
pub mod lora;
pub mod model;
pub mod data;
pub mod coordinator;
pub mod hal;
pub mod telemetry;

pub use util::{Rng, Tensor};
pub mod runtime;
pub mod tables;
pub mod bench_harness;
