//! irqlora — CLI for the IR-QLoRA reproduction.
//!
//! ```text
//! irqlora pretrain --size s [--steps N]        pretrain + cache a base model
//! irqlora quantize --size s --method ir-qlora  quantize + report entropy/storage
//! irqlora plan [--budget 3.2] [--synthetic]    mixed-precision allocation table
//! irqlora finetune --size s --arm ir-qlora     full arm: quantize + LoRA finetune + eval
//! irqlora serve [--workers N] [--backend B]    N-worker sharded serving pool demo
//! irqlora stats [FILE]                         last snapshot of a telemetry JSONL
//! irqlora backends                             HAL backend capability table
//! irqlora table <1|2|3|4|5|6|7|8|9|10|11>      regenerate a paper table
//! irqlora figure <4|5>                         regenerate a paper figure
//! irqlora all                                  every table + figure
//! ```
//! Global flags: --sizes xs,s  --pretrain-steps N  --finetune-steps N
//!               --eval-per-group N  --seed N  --full (paper-scale settings)
//! Plan flags:   --budget B (avg code bits/weight; default
//!               IRQLORA_BIT_BUDGET or 3.2)  --floor K  --ceil K
//!               --synthetic (offline fixture model)  --check (assert
//!               budget met + entropy ≥ uniform 3-bit)
//! Serve flags:  --workers N (0 = IRQLORA_SERVE_WORKERS, default 2)
//!               --adapters K  --requests M
//!               --backend B (named HAL backend: reference | native |
//!               pjrt | …; validated against its capability manifest
//!               BEFORE workers spawn. Unset: IRQLORA_SERVE_BACKEND
//!               if set, else the legacy auto-selection — PJRT when
//!               artifacts exist, reference otherwise)
//!               --reference (alias for --backend reference; also the
//!               fallback when artifacts are missing)  --fused (default) /
//!               --no-fused (per-group serial oracle path)
//!               --no-steal (disable the work-stealing scheduler;
//!               also IRQLORA_SERVE_STEAL=0)
//!               --chaos SEED (reference demo under seeded
//!               deterministic fault injection: per-worker injected
//!               errors/panics/latency, shed + retry accounting)

use anyhow::{bail, Context, Result};

use irqlora::coordinator::{pretrained_base, run_arm, Arm, RunCfg};
use irqlora::data::evalset::mmlu_set;
use irqlora::data::instruct::Dataset;
use irqlora::data::World;
use irqlora::runtime::{Manifest, Runtime};
use irqlora::tables;

struct Cli {
    cmd: String,
    arg: Option<String>,
    sizes: Vec<String>,
    cfg: RunCfg,
    method: String,
    bits: u8,
    full: bool,
    budget: Option<String>,
    floor: Option<u8>,
    ceil: Option<u8>,
    synthetic: bool,
    check: bool,
    workers: usize,
    adapters: usize,
    requests: usize,
    backend: Option<String>,
    reference: bool,
    fused: bool,
    steal: bool,
    chaos: Option<u64>,
}

fn parse_args() -> Result<Cli> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        bail!(USAGE);
    }
    let cmd = args[0].clone();
    let mut arg = None;
    let mut sizes = vec!["xs".to_string()];
    let mut cfg = RunCfg::default();
    let mut method = "ir-qlora".to_string();
    let mut bits = 4u8;
    let mut full = false;
    let mut budget = None;
    let mut floor = None;
    let mut ceil = None;
    let mut synthetic = false;
    let mut check = false;
    let mut workers = 0usize;
    let mut adapters = 4usize;
    let mut requests = 64usize;
    let mut backend = None;
    let mut reference = false;
    let mut fused = true;
    let mut steal = true;
    let mut chaos = None;
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--size" | "--sizes" => {
                i += 1;
                sizes = args
                    .get(i)
                    .context("--sizes needs a value")?
                    .split(',')
                    .map(String::from)
                    .collect();
            }
            "--pretrain-steps" => {
                i += 1;
                cfg.pretrain_steps = args.get(i).context("value")?.parse()?;
            }
            "--finetune-steps" | "--steps" => {
                i += 1;
                cfg.finetune_steps = args.get(i).context("value")?.parse()?;
            }
            "--eval-per-group" => {
                i += 1;
                cfg.eval_per_group = args.get(i).context("value")?.parse()?;
            }
            "--seed" => {
                i += 1;
                cfg.seed = args.get(i).context("value")?.parse()?;
            }
            "--method" | "--arm" => {
                i += 1;
                method = args.get(i).context("value")?.clone();
            }
            "--bits" => {
                i += 1;
                bits = args.get(i).context("value")?.parse()?;
            }
            "--full" => {
                full = true;
            }
            "--budget" => {
                i += 1;
                budget = Some(args.get(i).context("--budget needs a value")?.clone());
            }
            "--floor" => {
                i += 1;
                let f: u8 = args.get(i).context("value")?.parse()?;
                if !(1..=8).contains(&f) {
                    bail!("--floor must be in 1..=8, got {f}");
                }
                floor = Some(f);
            }
            "--ceil" => {
                i += 1;
                let c: u8 = args.get(i).context("value")?.parse()?;
                if !(1..=8).contains(&c) {
                    bail!("--ceil must be in 1..=8, got {c}");
                }
                ceil = Some(c);
            }
            "--synthetic" => {
                synthetic = true;
            }
            "--check" => {
                check = true;
            }
            "--workers" => {
                i += 1;
                workers = args.get(i).context("--workers needs a value")?.parse()?;
            }
            "--adapters" => {
                i += 1;
                adapters = args.get(i).context("--adapters needs a value")?.parse()?;
            }
            "--requests" => {
                i += 1;
                requests = args.get(i).context("--requests needs a value")?.parse()?;
            }
            "--backend" => {
                i += 1;
                backend = Some(args.get(i).context("--backend needs a name")?.clone());
            }
            "--reference" => {
                reference = true;
            }
            "--fused" => {
                fused = true;
            }
            "--no-fused" => {
                fused = false;
            }
            "--no-steal" => {
                steal = false;
            }
            "--chaos" => {
                i += 1;
                chaos = Some(args.get(i).context("--chaos needs a seed")?.parse()?);
            }
            s if arg.is_none() && !s.starts_with("--") => arg = Some(s.to_string()),
            s => bail!("unknown flag {s}\n{USAGE}"),
        }
        i += 1;
    }
    if full {
        cfg.pretrain_steps = cfg.pretrain_steps.max(800);
        cfg.finetune_steps = cfg.finetune_steps.max(200);
        cfg.eval_per_group = cfg.eval_per_group.max(150);
    }
    Ok(Cli {
        cmd,
        arg,
        sizes,
        cfg,
        method,
        bits,
        full,
        budget,
        floor,
        ceil,
        synthetic,
        check,
        workers,
        adapters,
        requests,
        backend,
        reference,
        fused,
        steal,
        chaos,
    })
}

const USAGE: &str = "usage: irqlora \
<pretrain|quantize|plan|finetune|serve|stats [FILE]|backends|table N|figure N|all> \
[--sizes xs,s] [--pretrain-steps N] [--finetune-steps N] [--eval-per-group N] \
[--seed N] [--method ARM] [--bits K] [--full] \
[--budget B] [--floor K] [--ceil K] [--synthetic] [--check] \
[--workers N] [--adapters K] [--requests M] [--backend NAME] [--reference] \
[--fused|--no-fused] [--no-steal] [--chaos SEED]";

fn arm_by_name(name: &str, k: u8) -> Result<Arm> {
    Ok(match name {
        "16-bit" | "fp16" => Arm::fp16(),
        "normalfloat" | "nf" => Arm::normalfloat(k),
        "qlora" => Arm::qlora(k),
        "qlora-gptq" | "gptq" => Arm::qlora_gptq(k),
        "qa-lora" | "qalora" => Arm::qalora(k),
        "ir-qlora" | "irqlora" => Arm::ir_qlora(k),
        "icq" => Arm::icq_only(k),
        "iec" => Arm::iec_only(k),
        "iec-u1" => Arm::iec_u1(k),
        "iec-u2" => Arm::iec_u2(k),
        "ir-qlora-int" => Arm::ir_qlora_int(k),
        _ => bail!("unknown arm '{name}'"),
    })
}

fn main() -> Result<()> {
    let result = run();
    // final telemetry snapshot: the periodic flusher ticks once a
    // second, so without this the tail of a fast run never lands in
    // the JSONL (a no-op when telemetry or the JSONL sink is off)
    let _ = irqlora::telemetry::global().flush_jsonl();
    result
}

fn run() -> Result<()> {
    init_logger();
    let cli = parse_args()?;
    let sizes: Vec<&str> = cli.sizes.iter().map(String::as_str).collect();

    if cli.cmd == "table" && cli.arg.as_deref() == Some("11") {
        tables::table_codebooks();
        return Ok(());
    }
    if cli.cmd == "plan" {
        // loads the manifest itself only when a real base is needed,
        // so `plan --synthetic` runs in toolchain-only environments
        return cmd_plan(&cli);
    }
    if cli.cmd == "serve" {
        // loads the manifest itself (the --reference demo and the
        // artifacts-missing fallback run without it)
        return cmd_serve(&cli);
    }
    if cli.cmd == "stats" {
        // render a telemetry JSONL's last snapshot (no artifacts, no
        // PJRT, no manifest — a pure file read)
        return cmd_stats(&cli);
    }
    if cli.cmd == "backends" {
        // print the HAL capability table (no artifacts/PJRT needed)
        let reg = irqlora::hal::BackendRegistry::builtin();
        print!("{}", reg.capability_table());
        return Ok(());
    }

    let manifest = Manifest::load("artifacts").context(
        "loading artifacts/manifest.json (run `make artifacts` first)",
    )?;
    let rt = Runtime::cpu()?;
    log::info!("PJRT platform: {}", rt.platform());

    match cli.cmd.as_str() {
        "pretrain" => {
            for tag in &sizes {
                let base = pretrained_base(&rt, &manifest, tag, &cli.cfg)?;
                println!(
                    "pretrained nano-{tag}: {} params cached under runs/",
                    base.total_params()
                );
            }
        }
        "quantize" => {
            let arm = arm_by_name(&cli.method, cli.bits)?;
            for tag in &sizes {
                let base = pretrained_base(&rt, &manifest, tag, &cli.cfg)?;
                let q = irqlora::coordinator::quantize_model(&base, arm.method, cli.cfg.seed)?;
                println!(
                    "nano-{tag} {} -> {:.2} MB, mean entropy {:.3} bits, {:?}",
                    arm.method.paper_name(),
                    q.storage_mb(),
                    q.mean_entropy(),
                    q.elapsed
                );
            }
        }
        "finetune" => {
            let arm = arm_by_name(&cli.method, cli.bits)?;
            let world = World::new(cli.cfg.world_seed);
            for tag in &sizes {
                let base = pretrained_base(&rt, &manifest, tag, &cli.cfg)?;
                let items = mmlu_set(&world, cli.cfg.eval_per_group, cli.cfg.seed);
                let r = run_arm(
                    &rt, &manifest, tag, &base, arm,
                    Dataset::AlpacaSyn, &items, &cli.cfg,
                )?;
                println!(
                    "nano-{tag} {}: avg {:.1}% (finetune {:?})",
                    arm.name,
                    r.eval.avg_accuracy() * 100.0,
                    r.finetune_time
                );
            }
        }
        "table" => {
            let n: u32 = cli
                .arg
                .context("table needs a number (1-11)")?
                .parse()
                .context("table number")?;
            match n {
                1 => tables::table_main(&rt, &manifest, Dataset::AlpacaSyn, &sizes, &cli.cfg)?,
                2 => tables::table_main(&rt, &manifest, Dataset::FlanSyn, &sizes, &cli.cfg)?,
                3 => tables::table3(&rt, &manifest, &sizes, &cli.cfg)?,
                4 => tables::table4(&rt, &manifest, sizes[0], &cli.cfg)?,
                5 => tables::table5(&rt, &manifest, sizes[0], &cli.cfg)?,
                6 | 7 | 15 => tables::table6_7(&rt, &manifest, &sizes, &cli.cfg)?,
                8 => tables::table8(&rt, &manifest, sizes[0], &cli.cfg)?,
                9 => tables::table9(&rt, &manifest, sizes[0], &cli.cfg)?,
                10 => tables::table10(&rt, &manifest, sizes[0], &cli.cfg)?,
                _ => bail!("unknown table {n}"),
            }
        }
        "figure" => {
            let n: u32 = cli.arg.context("figure needs 4 or 5")?.parse()?;
            match n {
                4 | 5 => tables::figures_4_5(&rt, &manifest, sizes[0], &cli.cfg)?,
                _ => bail!("unknown figure {n}"),
            }
        }
        "all" => {
            let _ = cli.full;
            tables::table_codebooks();
            tables::table_main(&rt, &manifest, Dataset::AlpacaSyn, &sizes, &cli.cfg)?;
            tables::table_main(&rt, &manifest, Dataset::FlanSyn, &sizes, &cli.cfg)?;
            tables::table3(&rt, &manifest, &sizes[..1], &cli.cfg)?;
            tables::table4(&rt, &manifest, sizes[0], &cli.cfg)?;
            tables::table5(&rt, &manifest, sizes[0], &cli.cfg)?;
            tables::table6_7(&rt, &manifest, &sizes, &cli.cfg)?;
            tables::table8(&rt, &manifest, sizes[0], &cli.cfg)?;
            tables::table9(&rt, &manifest, sizes[0], &cli.cfg)?;
            tables::table10(&rt, &manifest, sizes[0], &cli.cfg)?;
            tables::figures_4_5(&rt, &manifest, sizes[0], &cli.cfg)?;
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
    Ok(())
}

/// The `plan` verb: profile a base model's per-tensor information,
/// solve the budgeted bit allocation, print the table. `--synthetic`
/// plans the offline fixture model (no artifacts/PJRT needed);
/// `--check` additionally applies the plan and asserts it stays
/// within budget while matching or beating the uniform 3-bit ICQ
/// baseline's UNWEIGHTED mean code entropy (the planner smoke in
/// scripts/verify.sh). Caveat: the solver maximizes param-weighted
/// information, so on bases whose tensor sizes vary wildly the
/// unweighted comparison can fail even for a correct plan — the
/// check prints the weighted means too for that diagnosis; it is a
/// smoke for the fixture (and similar same-order-of-size models),
/// not a universal optimality proof.
fn cmd_plan(cli: &Cli) -> Result<()> {
    use irqlora::precision::{self, parse_budget, PlannerConfig};

    // env knobs (IRQLORA_BIT_BUDGET/FLOOR/CEIL act independently),
    // CLI flags win where explicitly given
    let mut pcfg = PlannerConfig::from_env_or(3.2);
    if let Some(raw) = &cli.budget {
        pcfg.budget_bits = parse_budget(raw)
            .ok_or_else(|| anyhow::anyhow!("--budget must be a positive number, got '{raw}'"))?;
    }
    if let Some(f) = cli.floor {
        pcfg.floor = f;
    }
    if let Some(c) = cli.ceil {
        pcfg.ceil = c;
    }

    let base = if cli.synthetic {
        precision::synthetic_model(2, 64, cli.cfg.seed)
    } else {
        let manifest = Manifest::load("artifacts").context(
            "loading artifacts/manifest.json (run `make artifacts` first, or use --synthetic)",
        )?;
        let rt = Runtime::cpu()?;
        pretrained_base(&rt, &manifest, &cli.sizes[0], &cli.cfg)?
    };

    let profile = precision::profile_model(&base, &precision::ProfileConfig::default());
    let plan = precision::plan(&profile, &pcfg)?;
    print!("{}", plan.render_table());

    if cli.check {
        let icq_cfg = irqlora::quant::icq::IcqConfig::default();
        let qm = precision::apply_plan(&base, &plan, &icq_cfg)?;
        let uniform = irqlora::coordinator::quantize_model(
            &base,
            irqlora::quant::Method::NfIcq { k: 3 },
            cli.cfg.seed,
        )?;
        let code_bits: usize = qm.storage.iter().map(|(_, qt)| qt.len * qt.k as usize).sum();
        let params: usize = qm.storage.iter().map(|(_, qt)| qt.len).sum();
        let avg = code_bits as f64 / params.max(1) as f64;
        let (hp, hu) = (qm.mean_entropy(), uniform.mean_entropy());
        // param-weighted means: the quantity the solver maximizes
        let weighted = |m: &irqlora::coordinator::QuantizedModel| -> f64 {
            let s: f64 = m.reports.iter().map(|r| r.entropy * r.n_params as f64).sum();
            s / params.max(1) as f64
        };
        println!(
            "check: {avg:.3} code b/w (budget {:.3}); mean entropy planned {hp:.3} vs \
             uniform-3 {hu:.3} (weighted {:.3} vs {:.3})",
            pcfg.budget_bits,
            weighted(&qm),
            weighted(&uniform)
        );
        if avg > pcfg.budget_bits + 1e-9 {
            bail!("planner check failed: {avg:.3} code b/w above budget {:.3}", pcfg.budget_bits);
        }
        if hp + 1e-9 < hu {
            bail!("planner check failed: planned entropy {hp:.4} below uniform 3-bit {hu:.4}");
        }
        println!("planner check OK");
    }
    maybe_print_telemetry();
    Ok(())
}

/// The `stats` verb: parse a telemetry JSONL file (the positional
/// argument, else `IRQLORA_TELEMETRY_JSONL`) and render its LAST
/// snapshot as the same table a live process prints — post-mortem
/// observability for a run that already exited.
fn cmd_stats(cli: &Cli) -> Result<()> {
    let path = cli
        .arg
        .clone()
        .or_else(irqlora::util::env::telemetry_jsonl)
        .context("stats needs a JSONL path (argument or IRQLORA_TELEMETRY_JSONL)")?;
    let last = irqlora::telemetry::read_last_snapshot(std::path::Path::new(&path))
        .with_context(|| format!("no well-formed telemetry snapshot in {path}"))?;
    println!(
        "telemetry snapshot {} at +{:.0}ms ({} keys) from {path}",
        last.snapshot,
        last.ts_ms,
        last.entries.len()
    );
    print!("{}", irqlora::telemetry::render_table(&last.entries));
    Ok(())
}

/// Print the process-global telemetry snapshot after a verb's own
/// report, when telemetry is on — so `IRQLORA_TELEMETRY=1 irqlora
/// serve …` shows its counters without needing the JSONL sink.
fn maybe_print_telemetry() {
    let reg = irqlora::telemetry::global();
    if !reg.is_enabled() {
        return;
    }
    let entries = reg.snapshot();
    if entries.is_empty() {
        return;
    }
    println!("\ntelemetry ({} keys):", entries.len());
    print!("{}", irqlora::telemetry::render_table(&entries));
}

/// The `serve` verb: spin up an N-worker [`ServerPool`] over one
/// shared `AdapterRegistry`, fire a mixed-adapter request stream
/// through `submit_async`, and print the aggregate `PoolStats`
/// (per-worker routing/occupancy, per-adapter requests, spills).
///
/// Backend selection goes through the HAL: `--backend NAME` (or
/// `IRQLORA_SERVE_BACKEND`) resolves the name against the builtin
/// [`irqlora::hal::BackendRegistry`] — capability-validated before
/// any worker spawns, so an unknown name or unsupported combination
/// is a typed error here. `--reference` is the legacy alias for
/// `--backend reference`. With nothing named, the legacy auto-path
/// holds: PJRT when artifacts exist, reference demo otherwise.
fn cmd_serve(cli: &Cli) -> Result<()> {
    use irqlora::coordinator::pool::serve_workers;

    let workers = if cli.workers == 0 { serve_workers() } else { cli.workers };
    let n_adapters = cli.adapters.max(1);
    let n_requests = cli.requests.max(1);

    if let Some(seed) = cli.chaos {
        // chaos runs the named (default reference) offline backend —
        // the point is a replayable fault schedule
        return cmd_serve_chaos(cli, workers, n_adapters, n_requests, seed);
    }

    let named = cli
        .backend
        .clone()
        .or_else(|| cli.reference.then(|| "reference".to_string()))
        .or_else(irqlora::util::env::serve_backend_override);
    match named.as_deref() {
        // pjrt keeps its rich demo (quantized pretrained base, real
        // LoRA adapters) — but only after the HAL confirms the entry
        // is registered and available, so the failure is typed
        Some("pjrt") => {
            let hal = irqlora::hal::BackendRegistry::builtin();
            if let Err(reason) = hal.availability("pjrt") {
                bail!("backend 'pjrt' unavailable: {reason}");
            }
            let manifest = Manifest::load("artifacts").context(
                "backend 'pjrt' needs artifacts/manifest.json (run `make artifacts`)",
            )?;
            cmd_serve_pjrt(cli, manifest, workers, n_adapters, n_requests)
        }
        Some(name) => cmd_serve_named(cli, name, workers, n_adapters, n_requests),
        None => match Manifest::load("artifacts") {
            Ok(manifest) => cmd_serve_pjrt(cli, manifest, workers, n_adapters, n_requests),
            Err(e) => {
                log::warn!("no artifacts ({e:#}) — serving the reference-backend demo");
                cmd_serve_named(cli, "reference", workers, n_adapters, n_requests)
            }
        },
    }
}

/// Offline demo over a NAMED HAL backend (`reference`, `native`, …):
/// the shared synthetic fixture, resolved and capability-validated
/// through [`irqlora::coordinator::serve_pool_backend`]. Same path
/// the bench smoke and the cross-backend batteries exercise.
fn cmd_serve_named(
    cli: &Cli,
    name: &str,
    workers: usize,
    n_adapters: usize,
    n_requests: usize,
) -> Result<()> {
    use irqlora::coordinator::pool::PoolConfig;
    use irqlora::coordinator::{serve_pool_backend, synthetic_serve_registry};
    use irqlora::util::Rng;
    use std::time::Duration;

    const BATCH: usize = 8;
    const SEQ: usize = 32;
    const VOCAB: usize = 64;
    let registry = synthetic_serve_registry(n_adapters, cli.cfg.seed);
    let mut pcfg = PoolConfig::new(workers, Duration::from_millis(2));
    pcfg.fused = cli.fused;
    pcfg.steal = cli.steal;
    let pool = serve_pool_backend(name, (BATCH, SEQ, VOCAB), pcfg, registry)?;
    println!(
        "{name} pool: {} workers, {n_adapters} adapters, {n_requests} requests",
        pool.workers()
    );

    let mut prng = Rng::new(cli.cfg.seed ^ 0x5e21);
    let (done, wall) = drive_pool(&pool, n_requests, 64, |i| {
        let adapter = format!("tenant{}", i % n_adapters);
        let len = 1 + prng.below(SEQ - 1);
        (adapter, (0..len).map(|_| 1 + prng.below(VOCAB - 1) as i32).collect())
    })?;
    print_pool_report(&pool.stats(), done, wall);
    pool.shutdown();
    maybe_print_telemetry();
    Ok(())
}

/// The `serve --chaos SEED` arm: the offline demo with every worker's
/// backend wrapped in a seed-derived [`FaultBackend`] (worker w gets
/// `FaultConfig::from_seed(seed ^ w)`), so injected errors, panics,
/// and latency replay identically for a given seed. The inner engine
/// is the HAL-resolved named backend (`--backend`, default
/// `reference`), so the chaos battery runs against any registered
/// CPU backend. Unlike the clean demo this drive tolerates failed
/// requests: every outcome is classified and reconciled against the
/// pool's shed/retry counters and the per-worker injected-fault
/// counters in the report.
fn cmd_serve_chaos(
    cli: &Cli,
    workers: usize,
    n_adapters: usize,
    n_requests: usize,
    seed: u64,
) -> Result<()> {
    use irqlora::coordinator::pool::{PoolConfig, ServerPool};
    use irqlora::coordinator::{
        synthetic_serve_registry, FaultBackend, FaultConfig, FaultStats, ServeBackend,
        ServeError,
    };
    use irqlora::hal::{BackendRegistry, BackendRequest};
    use irqlora::util::Rng;
    use std::sync::{Arc, Mutex};
    use std::time::{Duration, Instant};

    const BATCH: usize = 8;
    const SEQ: usize = 32;
    const VOCAB: usize = 64;
    let name = cli
        .backend
        .clone()
        .unwrap_or_else(|| irqlora::util::env::serve_backend());
    let registry = synthetic_serve_registry(n_adapters, cli.cfg.seed);
    let mut pcfg = PoolConfig::new(workers, Duration::from_millis(2));
    pcfg.fused = cli.fused;
    pcfg.steal = cli.steal;
    let mut req = BackendRequest::new(BATCH, SEQ, VOCAB);
    req.workers = workers;
    let make_inner = BackendRegistry::builtin().pool_factory(
        &name,
        &req,
        registry.base().clone(),
        "serve",
    )?;
    let fault_stats: Arc<Mutex<Vec<(usize, Arc<FaultStats>)>>> =
        Arc::new(Mutex::new(Vec::new()));
    let fs = fault_stats.clone();
    let pool = ServerPool::spawn_with(pcfg, registry, move |w| {
        let fb = FaultBackend::new(make_inner(w)?, FaultConfig::from_seed(seed ^ w as u64));
        fs.lock().unwrap().push((w, fb.stats()));
        Ok(Box::new(fb) as Box<dyn ServeBackend>)
    })?;
    println!(
        "chaos pool ({name}): {} workers (seed {seed}), {n_adapters} adapters, \
         {n_requests} requests",
        pool.workers()
    );

    #[derive(Default)]
    struct Tally {
        delivered: usize,
        backend_faults: usize,
        worker_dead: usize,
        deadline: usize,
        overloaded: usize,
        rejected: usize,
        shutdown: usize,
    }
    impl Tally {
        fn record(&mut self, r: Result<irqlora::coordinator::Reply, ServeError>) {
            match r {
                Ok(_) => self.delivered += 1,
                Err(ServeError::BackendFault(_)) => self.backend_faults += 1,
                Err(ServeError::WorkerDead { .. }) => self.worker_dead += 1,
                Err(ServeError::DeadlineExceeded { .. }) => self.deadline += 1,
                Err(ServeError::Overloaded { .. }) => self.overloaded += 1,
                Err(ServeError::Rejected(_)) => self.rejected += 1,
                Err(ServeError::Shutdown) => self.shutdown += 1,
            }
        }
    }

    let mut tally = Tally::default();
    let mut prng = Rng::new(cli.cfg.seed ^ 0x5e21);
    let t = irqlora::util::timer::Timer::start();
    let mut pending = Vec::new();
    for i in 0..n_requests {
        let adapter = format!("tenant{}", i % n_adapters);
        let len = 1 + prng.below(SEQ - 1);
        let prompt: Vec<i32> = (0..len).map(|_| 1 + prng.below(VOCAB - 1) as i32).collect();
        // every 8th request carries a tight deadline so shedding is on
        // the menu even when the pool keeps up
        let deadline = (i % 8 == 7).then(|| Instant::now() + Duration::from_millis(5));
        match pool.submit_with_deadline(&adapter, prompt, deadline) {
            Ok(p) => pending.push(p),
            Err(e) => tally.record(Err(e)),
        }
        if pending.len() >= 64 {
            for p in pending.drain(..) {
                tally.record(p.wait());
            }
        }
    }
    for p in pending.drain(..) {
        tally.record(p.wait());
    }
    let wall = t.elapsed_secs();

    let stats = pool.stats();
    print_pool_report(&stats, tally.delivered, wall);
    println!(
        "chaos outcomes: {} delivered, {} backend faults, {} worker-dead, \
         {} deadline, {} overloaded, {} rejected, {} shutdown",
        tally.delivered,
        tally.backend_faults,
        tally.worker_dead,
        tally.deadline,
        tally.overloaded,
        tally.rejected,
        tally.shutdown
    );
    let mut injected = fault_stats.lock().unwrap();
    injected.sort_by_key(|(w, _)| *w);
    for (w, s) in injected.iter() {
        println!(
            "worker {w} injected: {} forwards, {} errors, {} panics, {} delays",
            s.forwards(),
            s.errors(),
            s.panics(),
            s.delays()
        );
    }
    drop(injected);
    pool.shutdown();
    maybe_print_telemetry();
    if tally.delivered == 0 {
        bail!("chaos run delivered nothing — the pool lost liveness under injected faults");
    }
    Ok(())
}

/// Drive `n_requests` through `pool.submit_async`, keeping up to
/// `window` handles in flight before draining; `next(i)` produces the
/// (adapter, prompt) of request `i`. Returns (completed, wall secs).
fn drive_pool(
    pool: &irqlora::coordinator::ServerPool,
    n_requests: usize,
    window: usize,
    mut next: impl FnMut(usize) -> (String, Vec<i32>),
) -> Result<(usize, f64)> {
    let t = irqlora::util::timer::Timer::start();
    let mut pending = Vec::new();
    let mut done = 0usize;
    for i in 0..n_requests {
        let (adapter, prompt) = next(i);
        pending.push(pool.submit_async(&adapter, prompt)?);
        if pending.len() >= window.max(1) {
            for p in pending.drain(..) {
                p.wait()?;
                done += 1;
            }
        }
    }
    for p in pending.drain(..) {
        p.wait()?;
        done += 1;
    }
    Ok((done, t.elapsed_secs()))
}

/// PJRT arm of `serve`: quantized pretrained base, one registry, N
/// PJRT workers (each owning its runtime), seeded LoRA adapters.
fn cmd_serve_pjrt(
    cli: &Cli,
    manifest: Manifest,
    workers: usize,
    n_adapters: usize,
    n_requests: usize,
) -> Result<()> {
    use irqlora::coordinator::{quantize_model, serve_pool, PoolConfig};
    use irqlora::model::weights::init_lora;
    use irqlora::util::Rng;
    use std::time::Duration;

    let rt = Runtime::cpu()?;
    log::info!("PJRT platform: {}", rt.platform());
    let tag = cli.sizes[0].as_str();
    let arm = arm_by_name(&cli.method, cli.bits)?;
    let base = pretrained_base(&rt, &manifest, tag, &cli.cfg)?;
    let qm = quantize_model(&base, arm.method, cli.cfg.seed)?;

    let size = manifest.size(tag)?.clone();
    let tspec = manifest.graph(tag, "train_step")?;
    let nb = qm.dequantized.len();
    let nl = irqlora::coordinator::trainer::train_layout(tspec.inputs.len(), nb)?;
    let lora_specs = tspec.inputs[nb..nb + nl].to_vec();

    let mut pcfg = PoolConfig::new(workers, Duration::from_millis(2));
    pcfg.fused = cli.fused;
    pcfg.steal = cli.steal;
    let (registry, pool) = serve_pool(manifest, tag, &qm, arm.masks, pcfg)?;
    for i in 0..n_adapters {
        let mut arng = Rng::new(cli.cfg.seed ^ (0xada0 + i as u64));
        registry.register(
            &format!("tenant{i}"),
            init_lora(&lora_specs, size.config.rank, &mut arng),
        )?;
    }
    println!(
        "pjrt pool: {} workers over nano-{tag} ({}), {n_adapters} adapters, {n_requests} requests",
        pool.workers(),
        arm.name
    );

    let world = World::new(cli.cfg.world_seed);
    let mut prng = Rng::new(cli.cfg.seed ^ 0x9e37);
    let max_len = pool.max_prompt_len();
    let (done, wall) = drive_pool(&pool, n_requests, 32, |i| {
        let adapter = format!("tenant{}", i % n_adapters);
        let cat = prng.below(4);
        let mut prompt = irqlora::data::evalset::mmlu_item(&world, cat, &mut prng, 5).prompt;
        prompt.truncate(max_len);
        if prompt.is_empty() {
            prompt.push(1);
        }
        (adapter, prompt)
    })?;
    print_pool_report(&pool.stats(), done, wall);
    pool.shutdown();
    maybe_print_telemetry();
    Ok(())
}

/// Render a [`PoolStats`] snapshot: totals, per-worker routing and
/// occupancy (with liveness), and the per-adapter breakdown.
fn print_pool_report(stats: &irqlora::coordinator::PoolStats, done: usize, wall: f64) {
    println!(
        "\nserved {done} requests in {wall:.2}s ({:.1} req/s, mean batch {:.2}, \
         spills {}, reroutes {}, steals {})",
        done as f64 / wall.max(1e-9),
        stats.mean_batch_size(),
        stats.spills,
        stats.reroutes,
        stats.steals
    );
    println!(
        "fused forwards {} of {} (adapter-cache uploads: {} hits / {} misses)",
        stats.fused_batches, stats.batches, stats.upload_hits, stats.upload_misses
    );
    println!(
        "admission: shed_overload {}, shed_deadline {}, submit retries {}, parked peak {}",
        stats.shed_overload, stats.shed_deadline, stats.retries, stats.parked_peak
    );
    println!(
        "{:>7} {:>9} {:>9} {:>11} {:>6}",
        "worker", "routed", "batches", "mean batch", "alive"
    );
    for (i, w) in stats.workers.iter().enumerate() {
        println!(
            "{:>7} {:>9} {:>9} {:>11.2} {:>6}",
            i,
            w.routed,
            w.server.batches,
            w.server.mean_batch_size(),
            if w.dead.is_some() { "DEAD" } else { "yes" }
        );
    }
    println!("{:>10} {:>9} {:>11}", "adapter", "requests", "mean batch");
    for (name, a) in &stats.per_adapter {
        println!("{:>10} {:>9} {:>11.2}", name, a.requests, a.mean_batch_size());
    }
}

/// Minimal env-driven logger (RUST_LOG=info|debug).
fn init_logger() {
    struct L;
    impl log::Log for L {
        fn enabled(&self, _: &log::Metadata) -> bool {
            true
        }
        fn log(&self, record: &log::Record) {
            eprintln!("[{}] {}", record.level(), record.args());
        }
        fn flush(&self) {}
    }
    static LOGGER: L = L;
    let level = match std::env::var("RUST_LOG").as_deref() {
        Ok("debug") => log::LevelFilter::Debug,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("error") => log::LevelFilter::Error,
        _ => log::LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}
