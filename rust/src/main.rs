//! irqlora — CLI for the IR-QLoRA reproduction.
//!
//! ```text
//! irqlora pretrain --size s [--steps N]        pretrain + cache a base model
//! irqlora quantize --size s --method ir-qlora  quantize + report entropy/storage
//! irqlora finetune --size s --arm ir-qlora     full arm: quantize + LoRA finetune + eval
//! irqlora table <1|2|3|4|5|6|7|8|9|10|11>      regenerate a paper table
//! irqlora figure <4|5>                         regenerate a paper figure
//! irqlora all                                  every table + figure
//! ```
//! Global flags: --sizes xs,s  --pretrain-steps N  --finetune-steps N
//!               --eval-per-group N  --seed N  --full (paper-scale settings)

use anyhow::{bail, Context, Result};

use irqlora::coordinator::{pretrained_base, run_arm, Arm, RunCfg};
use irqlora::data::evalset::mmlu_set;
use irqlora::data::instruct::Dataset;
use irqlora::data::World;
use irqlora::runtime::{Manifest, Runtime};
use irqlora::tables;

struct Cli {
    cmd: String,
    arg: Option<String>,
    sizes: Vec<String>,
    cfg: RunCfg,
    method: String,
    bits: u8,
    full: bool,
}

fn parse_args() -> Result<Cli> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        bail!(USAGE);
    }
    let cmd = args[0].clone();
    let mut arg = None;
    let mut sizes = vec!["xs".to_string()];
    let mut cfg = RunCfg::default();
    let mut method = "ir-qlora".to_string();
    let mut bits = 4u8;
    let mut full = false;
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--size" | "--sizes" => {
                i += 1;
                sizes = args
                    .get(i)
                    .context("--sizes needs a value")?
                    .split(',')
                    .map(String::from)
                    .collect();
            }
            "--pretrain-steps" => {
                i += 1;
                cfg.pretrain_steps = args.get(i).context("value")?.parse()?;
            }
            "--finetune-steps" | "--steps" => {
                i += 1;
                cfg.finetune_steps = args.get(i).context("value")?.parse()?;
            }
            "--eval-per-group" => {
                i += 1;
                cfg.eval_per_group = args.get(i).context("value")?.parse()?;
            }
            "--seed" => {
                i += 1;
                cfg.seed = args.get(i).context("value")?.parse()?;
            }
            "--method" | "--arm" => {
                i += 1;
                method = args.get(i).context("value")?.clone();
            }
            "--bits" => {
                i += 1;
                bits = args.get(i).context("value")?.parse()?;
            }
            "--full" => {
                full = true;
            }
            s if arg.is_none() && !s.starts_with("--") => arg = Some(s.to_string()),
            s => bail!("unknown flag {s}\n{USAGE}"),
        }
        i += 1;
    }
    if full {
        cfg.pretrain_steps = cfg.pretrain_steps.max(800);
        cfg.finetune_steps = cfg.finetune_steps.max(200);
        cfg.eval_per_group = cfg.eval_per_group.max(150);
    }
    Ok(Cli { cmd, arg, sizes, cfg, method, bits, full })
}

const USAGE: &str = "usage: irqlora <pretrain|quantize|finetune|table N|figure N|all> \
[--sizes xs,s] [--pretrain-steps N] [--finetune-steps N] [--eval-per-group N] \
[--seed N] [--method ARM] [--bits K] [--full]";

fn arm_by_name(name: &str, k: u8) -> Result<Arm> {
    Ok(match name {
        "16-bit" | "fp16" => Arm::fp16(),
        "normalfloat" | "nf" => Arm::normalfloat(k),
        "qlora" => Arm::qlora(k),
        "qlora-gptq" | "gptq" => Arm::qlora_gptq(k),
        "qa-lora" | "qalora" => Arm::qalora(k),
        "ir-qlora" | "irqlora" => Arm::ir_qlora(k),
        "icq" => Arm::icq_only(k),
        "iec" => Arm::iec_only(k),
        "iec-u1" => Arm::iec_u1(k),
        "iec-u2" => Arm::iec_u2(k),
        "ir-qlora-int" => Arm::ir_qlora_int(k),
        _ => bail!("unknown arm '{name}'"),
    })
}

fn main() -> Result<()> {
    init_logger();
    let cli = parse_args()?;
    let sizes: Vec<&str> = cli.sizes.iter().map(String::as_str).collect();

    if cli.cmd == "table" && cli.arg.as_deref() == Some("11") {
        tables::table_codebooks();
        return Ok(());
    }

    let manifest = Manifest::load("artifacts").context(
        "loading artifacts/manifest.json (run `make artifacts` first)",
    )?;
    let rt = Runtime::cpu()?;
    log::info!("PJRT platform: {}", rt.platform());

    match cli.cmd.as_str() {
        "pretrain" => {
            for tag in &sizes {
                let base = pretrained_base(&rt, &manifest, tag, &cli.cfg)?;
                println!(
                    "pretrained nano-{tag}: {} params cached under runs/",
                    base.total_params()
                );
            }
        }
        "quantize" => {
            let arm = arm_by_name(&cli.method, cli.bits)?;
            for tag in &sizes {
                let base = pretrained_base(&rt, &manifest, tag, &cli.cfg)?;
                let q = irqlora::coordinator::quantize_model(&base, arm.method, cli.cfg.seed)?;
                println!(
                    "nano-{tag} {} -> {:.2} MB, mean entropy {:.3} bits, {:?}",
                    arm.method.paper_name(),
                    q.storage_mb(),
                    q.mean_entropy(),
                    q.elapsed
                );
            }
        }
        "finetune" => {
            let arm = arm_by_name(&cli.method, cli.bits)?;
            let world = World::new(cli.cfg.world_seed);
            for tag in &sizes {
                let base = pretrained_base(&rt, &manifest, tag, &cli.cfg)?;
                let items = mmlu_set(&world, cli.cfg.eval_per_group, cli.cfg.seed);
                let r = run_arm(
                    &rt, &manifest, tag, &base, arm,
                    Dataset::AlpacaSyn, &items, &cli.cfg,
                )?;
                println!(
                    "nano-{tag} {}: avg {:.1}% (finetune {:?})",
                    arm.name,
                    r.eval.avg_accuracy() * 100.0,
                    r.finetune_time
                );
            }
        }
        "table" => {
            let n: u32 = cli
                .arg
                .context("table needs a number (1-11)")?
                .parse()
                .context("table number")?;
            match n {
                1 => tables::table_main(&rt, &manifest, Dataset::AlpacaSyn, &sizes, &cli.cfg)?,
                2 => tables::table_main(&rt, &manifest, Dataset::FlanSyn, &sizes, &cli.cfg)?,
                3 => tables::table3(&rt, &manifest, &sizes, &cli.cfg)?,
                4 => tables::table4(&rt, &manifest, sizes[0], &cli.cfg)?,
                5 => tables::table5(&rt, &manifest, sizes[0], &cli.cfg)?,
                6 | 7 | 15 => tables::table6_7(&rt, &manifest, &sizes, &cli.cfg)?,
                8 => tables::table8(&rt, &manifest, sizes[0], &cli.cfg)?,
                9 => tables::table9(&rt, &manifest, sizes[0], &cli.cfg)?,
                10 => tables::table10(&rt, &manifest, sizes[0], &cli.cfg)?,
                _ => bail!("unknown table {n}"),
            }
        }
        "figure" => {
            let n: u32 = cli.arg.context("figure needs 4 or 5")?.parse()?;
            match n {
                4 | 5 => tables::figures_4_5(&rt, &manifest, sizes[0], &cli.cfg)?,
                _ => bail!("unknown figure {n}"),
            }
        }
        "all" => {
            let _ = cli.full;
            tables::table_codebooks();
            tables::table_main(&rt, &manifest, Dataset::AlpacaSyn, &sizes, &cli.cfg)?;
            tables::table_main(&rt, &manifest, Dataset::FlanSyn, &sizes, &cli.cfg)?;
            tables::table3(&rt, &manifest, &sizes[..1], &cli.cfg)?;
            tables::table4(&rt, &manifest, sizes[0], &cli.cfg)?;
            tables::table5(&rt, &manifest, sizes[0], &cli.cfg)?;
            tables::table6_7(&rt, &manifest, &sizes, &cli.cfg)?;
            tables::table8(&rt, &manifest, sizes[0], &cli.cfg)?;
            tables::table9(&rt, &manifest, sizes[0], &cli.cfg)?;
            tables::table10(&rt, &manifest, sizes[0], &cli.cfg)?;
            tables::figures_4_5(&rt, &manifest, sizes[0], &cli.cfg)?;
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
    Ok(())
}

/// Minimal env-driven logger (RUST_LOG=info|debug).
fn init_logger() {
    struct L;
    impl log::Log for L {
        fn enabled(&self, _: &log::Metadata) -> bool {
            true
        }
        fn log(&self, record: &log::Record) {
            eprintln!("[{}] {}", record.level(), record.args());
        }
        fn flush(&self) {}
    }
    static LOGGER: L = L;
    let level = match std::env::var("RUST_LOG").as_deref() {
        Ok("debug") => log::LevelFilter::Debug,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("error") => log::LevelFilter::Error,
        _ => log::LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}
