//! The metric registry and its handle types.
//!
//! A [`Registry`] maps `name{label=value,...}` keys to [`Slot`]s of
//! striped, cache-line-aligned atomics. Handles ([`Counter`],
//! [`Gauge`], [`Timer`]) are `Option<Arc<Slot>>`: `None` from a
//! disabled registry (every operation is one branch, nothing else),
//! `Some` from an enabled one (relaxed atomic adds on a per-thread
//! stripe). The registry mutex guards only the key → slot map, taken
//! at handle resolution time — never on the record path.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::jsonl;

/// Stripes per slot. Threads hash onto stripes so concurrent
/// increments of one hot counter don't all bounce a single cache
/// line; reads sum all stripes.
const STRIPES: usize = 8;

/// One cache-line-padded atomic cell.
#[repr(align(64))]
struct Stripe(AtomicU64);

/// What a key measures — fixed at first resolution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Monotonic event count (`value` = total, `count` unused).
    Counter,
    /// Last-set / high-water value (`value` only, stripe 0).
    Gauge,
    /// Accumulated duration (`value` = total ns, `count` = samples).
    Timer,
}

impl Kind {
    pub fn as_str(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Timer => "timer",
        }
    }

    pub fn from_str(s: &str) -> Option<Kind> {
        match s {
            "counter" => Some(Kind::Counter),
            "gauge" => Some(Kind::Gauge),
            "timer" => Some(Kind::Timer),
            _ => None,
        }
    }
}

/// Striped storage behind one metric key.
pub(super) struct Slot {
    kind: Kind,
    value: [Stripe; STRIPES],
    count: [Stripe; STRIPES],
}

/// This thread's stripe index: assigned round-robin on first use so
/// distinct recording threads usually land on distinct cache lines.
fn stripe_index() -> usize {
    use std::cell::Cell;
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static IDX: Cell<usize> = const { Cell::new(usize::MAX) };
    }
    IDX.with(|c| {
        let mut i = c.get();
        if i == usize::MAX {
            i = NEXT.fetch_add(1, Ordering::Relaxed) % STRIPES;
            c.set(i);
        }
        i
    })
}

impl Slot {
    fn new(kind: Kind) -> Slot {
        Slot {
            kind,
            value: std::array::from_fn(|_| Stripe(AtomicU64::new(0))),
            count: std::array::from_fn(|_| Stripe(AtomicU64::new(0))),
        }
    }

    #[inline]
    fn add_value(&self, n: u64) {
        self.value[stripe_index()].0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    fn add_count(&self, n: u64) {
        self.count[stripe_index()].0.fetch_add(n, Ordering::Relaxed);
    }

    /// Gauges live in stripe 0 only (a gauge is a point value, not a
    /// sum, so striping would be meaningless).
    fn set(&self, v: u64) {
        self.value[0].0.store(v, Ordering::Relaxed);
    }

    /// Monotonic high-water update (CAS loop, lock-free).
    fn set_max(&self, v: u64) {
        let a = &self.value[0].0;
        let mut cur = a.load(Ordering::Relaxed);
        while v > cur {
            match a.compare_exchange_weak(cur, v, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    fn value_total(&self) -> u64 {
        match self.kind {
            Kind::Gauge => self.value[0].0.load(Ordering::Acquire),
            _ => self.value.iter().map(|s| s.0.load(Ordering::Acquire)).sum(),
        }
    }

    fn count_total(&self) -> u64 {
        self.count.iter().map(|s| s.0.load(Ordering::Acquire)).sum()
    }
}

/// Monotonic event counter handle. Cheap to clone (an `Arc`).
#[derive(Clone, Default)]
pub struct Counter(Option<Arc<Slot>>);

impl Counter {
    /// A handle that records nothing (what a disabled registry hands
    /// out; also the `Default`).
    pub fn noop() -> Counter {
        Counter(None)
    }

    #[inline]
    pub fn add(&self, n: u64) {
        if let Some(s) = &self.0 {
            s.add_value(n);
        }
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current total (0 from a no-op handle).
    pub fn get(&self) -> u64 {
        self.0.as_ref().map_or(0, |s| s.value_total())
    }
}

/// Point-value / high-water gauge handle.
#[derive(Clone, Default)]
pub struct Gauge(Option<Arc<Slot>>);

impl Gauge {
    pub fn noop() -> Gauge {
        Gauge(None)
    }

    #[inline]
    pub fn set(&self, v: u64) {
        if let Some(s) = &self.0 {
            s.set(v);
        }
    }

    /// Raise the gauge to `v` if `v` is higher (high-water semantics).
    #[inline]
    pub fn set_max(&self, v: u64) {
        if let Some(s) = &self.0 {
            s.set_max(v);
        }
    }

    pub fn get(&self) -> u64 {
        self.0.as_ref().map_or(0, |s| s.value_total())
    }
}

/// Accumulating duration handle: total elapsed ns + sample count.
#[derive(Clone, Default)]
pub struct Timer(Option<Arc<Slot>>);

impl Timer {
    pub fn noop() -> Timer {
        Timer(None)
    }

    /// Scoped measurement: the returned guard records the elapsed
    /// time when dropped. A no-op handle's guard never reads the
    /// clock at all.
    #[inline]
    pub fn start(&self) -> TimerGuard {
        TimerGuard {
            slot: self.0.clone(),
            start: self.0.as_ref().map(|_| Instant::now()),
        }
    }

    /// Record an externally measured duration.
    pub fn record(&self, d: Duration) {
        if let Some(s) = &self.0 {
            s.add_value(saturating_ns(d));
            s.add_count(1);
        }
    }

    /// Total recorded time (zero from a no-op handle).
    pub fn total(&self) -> Duration {
        Duration::from_nanos(self.0.as_ref().map_or(0, |s| s.value_total()))
    }

    pub fn samples(&self) -> u64 {
        self.0.as_ref().map_or(0, |s| s.count_total())
    }
}

/// Drop guard returned by [`Timer::start`].
pub struct TimerGuard {
    slot: Option<Arc<Slot>>,
    start: Option<Instant>,
}

impl Drop for TimerGuard {
    fn drop(&mut self) {
        if let (Some(s), Some(t)) = (&self.slot, self.start) {
            s.add_value(saturating_ns(t.elapsed()));
            s.add_count(1);
        }
    }
}

fn saturating_ns(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

/// One metric's consistent read, as taken by [`Registry::snapshot`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SnapshotEntry {
    /// `name{label=value,...}` key.
    pub key: String,
    pub kind: Kind,
    /// Counter total / gauge value / timer total ns.
    pub value: u64,
    /// Timer sample count (0 for counters and gauges).
    pub count: u64,
}

/// A registry of labeled metrics. See the module docs for the design;
/// the process-global instance is [`super::global`], and tests inject
/// scoped instances (`Registry::enabled()`) instead of touching the
/// environment.
pub struct Registry {
    enabled: bool,
    /// `BTreeMap` so snapshots come out key-sorted without a sort.
    slots: Mutex<BTreeMap<String, Arc<Slot>>>,
    /// Origin for monotonic JSONL timestamps.
    origin: Instant,
    /// JSONL appender; presence is fixed at construction so the hot
    /// `has_jsonl` check needs no lock.
    jsonl: Option<Mutex<jsonl::Appender>>,
    /// Snapshot sequence number for JSONL lines.
    snapshots: AtomicU64,
}

impl Registry {
    /// A registry whose handles are all no-ops.
    pub fn disabled() -> Registry {
        Registry::build(false)
    }

    /// A recording registry (no JSONL until [`Registry::with_jsonl`]).
    pub fn enabled() -> Registry {
        Registry::build(true)
    }

    fn build(enabled: bool) -> Registry {
        Registry {
            enabled,
            slots: Mutex::new(BTreeMap::new()),
            origin: Instant::now(),
            jsonl: None,
            snapshots: AtomicU64::new(0),
        }
    }

    /// Attach a JSONL appender (builder style). Ignored on a disabled
    /// registry — disabled telemetry must never create files.
    pub fn with_jsonl(mut self, path: impl Into<PathBuf>) -> Registry {
        if self.enabled {
            self.jsonl = Some(Mutex::new(jsonl::Appender::new(path.into())));
        }
        self
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    pub fn has_jsonl(&self) -> bool {
        self.jsonl.is_some()
    }

    /// Resolve a slot. The disabled check comes FIRST: a disabled
    /// registry returns before any key string is formatted, so
    /// handle resolution allocates nothing when telemetry is off.
    fn slot(&self, kind: Kind, name: &str, labels: &[(&str, &str)]) -> Option<Arc<Slot>> {
        if !self.enabled {
            return None;
        }
        let key = format_key(name, labels);
        let mut slots = self.slots.lock().unwrap();
        let slot = slots.entry(key).or_insert_with(|| Arc::new(Slot::new(kind)));
        debug_assert!(
            slot.kind == kind,
            "telemetry key '{name}' re-resolved with a different kind"
        );
        Some(slot.clone())
    }

    /// A counter handle for `name{labels}`.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        Counter(self.slot(Kind::Counter, name, labels))
    }

    /// A gauge handle for `name{labels}`.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Gauge {
        Gauge(self.slot(Kind::Gauge, name, labels))
    }

    /// A timer handle for `name{labels}`.
    pub fn timer(&self, name: &str, labels: &[(&str, &str)]) -> Timer {
        Timer(self.slot(Kind::Timer, name, labels))
    }

    /// Milliseconds since this registry was created (the monotonic
    /// timestamp JSONL lines carry).
    pub fn ts_ms(&self) -> f64 {
        self.origin.elapsed().as_secs_f64() * 1e3
    }

    /// Key-sorted consistent-enough read of every metric. (Relaxed
    /// counters: each value is exact for events that happened-before
    /// the read; the snapshot is not a cross-metric atomic cut.)
    pub fn snapshot(&self) -> Vec<SnapshotEntry> {
        let slots = self.slots.lock().unwrap();
        slots
            .iter()
            .map(|(k, s)| SnapshotEntry {
                key: k.clone(),
                kind: s.kind,
                value: s.value_total(),
                count: s.count_total(),
            })
            .collect()
    }

    /// Append one snapshot to the attached JSONL file (no-op without
    /// one). Called periodically by the global flusher thread and once
    /// more by `main` on exit.
    pub fn flush_jsonl(&self) -> std::io::Result<()> {
        let Some(app) = &self.jsonl else {
            return Ok(());
        };
        let snap = self.snapshot();
        let seq = self.snapshots.fetch_add(1, Ordering::AcqRel);
        // serialize writers so periodic + final flushes can't interleave
        app.lock().unwrap().append(seq, self.ts_ms(), &snap)
    }
}

/// `name` → `name`, `name` + labels → `name{k1=v1,k2=v2}`. Label
/// order is the caller's; instrumentation sites pass a fixed slice so
/// one metric always formats to one key.
fn format_key(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let mut s = String::with_capacity(name.len() + 16);
    s.push_str(name);
    s.push('{');
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(k);
        s.push('=');
        s.push_str(v);
    }
    s.push('}');
    s
}

/// Compact duration formatting for the stats table ("1.234s",
/// "12.345ms", "6.7µs", "890ns").
fn fmt_ns(ns: u64) -> String {
    let s = ns as f64 / 1e9;
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.1}µs", s * 1e6)
    } else {
        format!("{ns}ns")
    }
}

/// Render snapshot entries as the aligned table `irqlora stats`
/// prints (also used by the serve verbs when telemetry is on).
pub fn render_table(entries: &[SnapshotEntry]) -> String {
    let key_w = entries
        .iter()
        .map(|e| e.key.len())
        .chain(std::iter::once("key".len()))
        .max()
        .unwrap_or(3);
    let mut out = String::new();
    out.push_str(&format!(
        "  {:<key_w$}  {:<7}  {:>14}  {:>8}  {:>12}\n",
        "key", "kind", "value", "count", "mean"
    ));
    for e in entries {
        let (value, count, mean) = match e.kind {
            Kind::Counter | Kind::Gauge => (e.value.to_string(), "-".into(), "-".into()),
            Kind::Timer => (
                fmt_ns(e.value),
                e.count.to_string(),
                fmt_ns(e.value / e.count.max(1)),
            ),
        };
        out.push_str(&format!(
            "  {:<key_w$}  {:<7}  {:>14}  {:>8}  {:>12}\n",
            e.key,
            e.kind.as_str(),
            value,
            count,
            mean
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_registry_hands_out_noops() {
        let r = Registry::disabled();
        let c = r.counter("a", &[("x", "1")]);
        c.add(5);
        assert_eq!(c.get(), 0);
        let g = r.gauge("b", &[]);
        g.set(9);
        g.set_max(11);
        assert_eq!(g.get(), 0);
        let t = r.timer("c", &[]);
        drop(t.start());
        t.record(Duration::from_millis(1));
        assert_eq!(t.samples(), 0);
        assert!(r.snapshot().is_empty());
        assert!(r.flush_jsonl().is_ok());
    }

    #[test]
    fn counter_sums_across_threads_and_handles() {
        let r = Arc::new(Registry::enabled());
        let c = r.counter("hits", &[]);
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        // a later handle for the same key sees the same slot
        assert_eq!(r.counter("hits", &[]).get(), 80_000);
    }

    #[test]
    fn keys_carry_labels_and_sort() {
        let r = Registry::enabled();
        r.counter("quant.blocks", &[("k", "4")]).add(3);
        r.counter("quant.blocks", &[("k", "2")]).inc();
        r.gauge("serve.parked_peak", &[]).set_max(7);
        let snap = r.snapshot();
        let keys: Vec<&str> = snap.iter().map(|e| e.key.as_str()).collect();
        assert_eq!(
            keys,
            ["quant.blocks{k=2}", "quant.blocks{k=4}", "serve.parked_peak"]
        );
        assert_eq!(snap[1].value, 3);
        assert_eq!(snap[2].kind, Kind::Gauge);
        assert_eq!(snap[2].value, 7);
    }

    #[test]
    fn gauge_set_max_is_monotone() {
        let r = Registry::enabled();
        let g = r.gauge("peak", &[]);
        g.set_max(5);
        g.set_max(3);
        assert_eq!(g.get(), 5);
        g.set_max(9);
        assert_eq!(g.get(), 9);
        g.set(2); // plain set still overwrites
        assert_eq!(g.get(), 2);
    }

    #[test]
    fn timer_guard_accumulates() {
        let r = Registry::enabled();
        let t = r.timer("work", &[]);
        for _ in 0..3 {
            let _g = t.start();
            std::hint::black_box(1 + 1);
        }
        t.record(Duration::from_micros(10));
        assert_eq!(t.samples(), 4);
        assert!(t.total() >= Duration::from_micros(10));
        let snap = r.snapshot();
        assert_eq!(snap[0].kind, Kind::Timer);
        assert_eq!(snap[0].count, 4);
    }

    #[test]
    fn table_renders_every_kind() {
        let r = Registry::enabled();
        r.counter("serve.requests", &[]).add(272);
        r.timer("plan.solve_time", &[]).record(Duration::from_millis(2));
        let table = render_table(&r.snapshot());
        assert!(table.contains("serve.requests"));
        assert!(table.contains("272"));
        assert!(table.contains("plan.solve_time"));
        assert!(table.contains("2.000ms"));
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(890), "890ns");
        assert_eq!(fmt_ns(6_700), "6.7µs");
        assert_eq!(fmt_ns(12_345_000), "12.345ms");
        assert_eq!(fmt_ns(1_234_000_000), "1.234s");
    }
}
