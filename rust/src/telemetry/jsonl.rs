//! JSONL snapshot persistence: one JSON object per metric per
//! snapshot, appended to the `IRQLORA_TELEMETRY_JSONL` path:
//!
//! ```json
//! {"snapshot": 3, "ts_ms": 1204.511, "kind": "counter", "key": "serve.requests", "value": 272, "count": 0}
//! ```
//!
//! `snapshot` is a per-registry sequence number, `ts_ms` a monotonic
//! offset from registry creation (never wall-clock, so a paused or
//! NTP-stepped host can't produce time travel). Timers store raw
//! total nanoseconds in `value` and samples in `count`.
//!
//! The reader ([`read_last_snapshot`]) is the `irqlora stats` verb's
//! backend: it keeps only the highest-sequence snapshot, tolerating a
//! file that mixes periodic and final flushes. Writer and reader use
//! the same hand-rolled field conventions as `bench_harness` — no
//! JSON dependency — but the reader anchors on whole top-level keys
//! with a string-aware scan, so a field name occurring inside a label
//! value (or as a suffix of a longer key, `ts` vs `ts_ms`) can never
//! forge or shadow a field.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use super::registry::{Kind, SnapshotEntry};

/// Append-only JSONL writer. The file is opened lazily at first
/// flush, so constructing a registry with a path but never recording
/// doesn't create an empty file.
pub(super) struct Appender {
    path: PathBuf,
    file: Option<File>,
}

impl Appender {
    pub(super) fn new(path: PathBuf) -> Appender {
        Appender { path, file: None }
    }

    pub(super) fn append(
        &mut self,
        seq: u64,
        ts_ms: f64,
        entries: &[SnapshotEntry],
    ) -> std::io::Result<()> {
        if self.file.is_none() {
            self.file = Some(
                OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(&self.path)?,
            );
        }
        let f = self.file.as_mut().unwrap();
        let mut buf = String::with_capacity(entries.len() * 96);
        for e in entries {
            buf.push_str(&format!(
                "{{\"snapshot\": {seq}, \"ts_ms\": {ts_ms:.3}, \"kind\": \"{}\", \
                 \"key\": \"{}\", \"value\": {}, \"count\": {}}}\n",
                e.kind.as_str(),
                sanitize(&e.key),
                e.value,
                e.count,
            ));
        }
        f.write_all(buf.as_bytes())?;
        f.flush()
    }
}

/// Keys are code-controlled (`name{label=value}`), but adapter names
/// can flow into labels — force JSON-safety the same way the bench
/// harness does: quotes, backslashes, and control bytes become `_`.
fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| if c == '"' || c == '\\' || c.is_control() { '_' } else { c })
        .collect()
}

/// The highest-sequence snapshot found in a telemetry JSONL file.
pub struct LastSnapshot {
    /// Snapshot sequence number.
    pub snapshot: u64,
    /// Monotonic ms offset the snapshot was taken at.
    pub ts_ms: f64,
    /// Key-ordered entries, as written.
    pub entries: Vec<SnapshotEntry>,
}

/// Parse a telemetry JSONL file and return its last (highest
/// `snapshot`) snapshot. `None` if the file is unreadable or holds no
/// well-formed lines.
pub fn read_last_snapshot(path: &Path) -> Option<LastSnapshot> {
    let text = std::fs::read_to_string(path).ok()?;
    let best = text
        .lines()
        .filter_map(|l| field_num(l.trim(), "snapshot"))
        .map(|s| s as u64)
        .max()?;
    let mut ts_ms = 0.0;
    let mut entries = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        let Some(seq) = field_num(line, "snapshot") else {
            continue;
        };
        if seq as u64 != best {
            continue;
        }
        let (Some(kind), Some(key), Some(value)) = (
            field_str(line, "kind").and_then(|k| Kind::from_str(&k)),
            field_str(line, "key"),
            field_num(line, "value"),
        ) else {
            continue;
        };
        ts_ms = field_num(line, "ts_ms").unwrap_or(ts_ms);
        entries.push(SnapshotEntry {
            key,
            kind,
            value: value as u64,
            count: field_num(line, "count").unwrap_or(0.0) as u64,
        });
    }
    if entries.is_empty() {
        None
    } else {
        Some(LastSnapshot { snapshot: best, ts_ms, entries })
    }
}

/// Scan one flat JSONL object for the top-level `"field":` key and
/// return the raw text after its colon. Unlike a substring search,
/// this walks the line tracking quoted strings (with `\` escapes), so
/// a field name can only match as a whole quoted key followed by a
/// colon — never as the suffix of a longer key (`ts` vs `ts_ms`) and
/// never inside an adversarial label value.
fn field_raw<'a>(line: &'a str, field: &str) -> Option<&'a str> {
    let b = line.as_bytes();
    let mut i = 0;
    while i < b.len() {
        if b[i] != b'"' {
            i += 1;
            continue;
        }
        // a quoted token: scan to its closing quote, honouring escapes
        let start = i + 1;
        let mut j = start;
        while j < b.len() && b[j] != b'"' {
            j += if b[j] == b'\\' { 2 } else { 1 };
        }
        if j >= b.len() {
            return None; // unterminated string
        }
        // a key iff the next non-space byte is ':'; otherwise it was a
        // string value — keep scanning after it either way
        let mut k = j + 1;
        while k < b.len() && b[k].is_ascii_whitespace() {
            k += 1;
        }
        if k < b.len() && b[k] == b':' {
            k += 1;
            while k < b.len() && b[k].is_ascii_whitespace() {
                k += 1;
            }
            if &line[start..j] == field {
                return Some(&line[k..]);
            }
            i = k;
        } else {
            i = j + 1;
        }
    }
    None
}

/// Extract a `"field": "string"` value from one JSONL line. Escape
/// sequences are passed through verbatim ([`sanitize`] never emits
/// them, so our own files contain none).
fn field_str(line: &str, field: &str) -> Option<String> {
    let raw = field_raw(line, field)?;
    let b = raw.as_bytes();
    if b.first() != Some(&b'"') {
        return None;
    }
    let mut j = 1;
    while j < b.len() && b[j] != b'"' {
        j += if b[j] == b'\\' { 2 } else { 1 };
    }
    if j < b.len() {
        Some(raw[1..j].to_string())
    } else {
        None
    }
}

/// Extract a `"field": number` value from one JSONL line.
fn field_num(line: &str, field: &str) -> Option<f64> {
    let raw = field_raw(line, field)?;
    if raw.starts_with('"') {
        return None; // a string where a number was expected
    }
    let end = raw
        .find(|c: char| c == ',' || c == '}')
        .unwrap_or(raw.len());
    raw[..end].trim().parse::<f64>().ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::Registry;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("irqlora_telem_{}_{name}.jsonl", std::process::id()))
    }

    #[test]
    fn appender_roundtrips_through_reader() {
        let path = tmp("roundtrip");
        let _ = std::fs::remove_file(&path);
        let r = Registry::enabled().with_jsonl(&path);
        r.counter("serve.requests", &[]).add(42);
        r.timer("plan.solve_time", &[]).record(std::time::Duration::from_micros(5));
        r.flush_jsonl().unwrap();
        r.counter("serve.requests", &[]).add(8);
        r.flush_jsonl().unwrap();

        // every line is one JSON object
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().count() >= 4);
        for line in text.lines() {
            assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
        }

        // the reader keeps only the LAST snapshot (the updated total)
        let last = read_last_snapshot(&path).unwrap();
        assert_eq!(last.snapshot, 1);
        let req = last
            .entries
            .iter()
            .find(|e| e.key == "serve.requests")
            .unwrap();
        assert_eq!((req.kind, req.value), (Kind::Counter, 50));
        let timer = last
            .entries
            .iter()
            .find(|e| e.key == "plan.solve_time")
            .unwrap();
        assert_eq!(timer.count, 1);
        assert!(timer.value >= 5_000);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn disabled_registry_never_creates_the_file() {
        let path = tmp("disabled");
        let _ = std::fs::remove_file(&path);
        let r = Registry::disabled().with_jsonl(&path);
        r.counter("x", &[]).inc();
        r.flush_jsonl().unwrap();
        assert!(!path.exists(), "disabled telemetry must not write files");
    }

    #[test]
    fn reader_rejects_garbage_and_empty() {
        let path = tmp("garbage");
        std::fs::write(&path, "not json\n{\"half\": 1\n").unwrap();
        assert!(read_last_snapshot(&path).is_none());
        std::fs::remove_file(&path).unwrap();
        assert!(read_last_snapshot(Path::new("/nonexistent/telem.jsonl")).is_none());
    }

    #[test]
    fn labels_survive_sanitization() {
        assert_eq!(sanitize("a{k=4}"), "a{k=4}");
        assert_eq!(sanitize("bad\"quote\\and\ncontrol"), "bad_quote_and_control");
    }

    #[test]
    fn fields_anchor_on_whole_keys_not_substrings() {
        // a sanitized label can legally contain field names and fake
        // `name: value` text; none of it may satisfy a field lookup
        let line = "{\"snapshot\": 2, \"ts_ms\": 10.500, \"kind\": \"counter\", \
                    \"key\": \"k{label=snapshot, value: 99, count}\", \
                    \"value\": 7, \"count\": 1}";
        assert_eq!(field_num(line, "snapshot"), Some(2.0));
        assert_eq!(field_num(line, "ts_ms"), Some(10.5));
        assert_eq!(field_num(line, "value"), Some(7.0));
        assert_eq!(field_num(line, "count"), Some(1.0));
        assert_eq!(
            field_str(line, "key").as_deref(),
            Some("k{label=snapshot, value: 99, count}")
        );
        // `ts` is a suffix-colliding non-key: it must NOT resolve via
        // the `ts_ms` key, and `kind` must not resolve as a number
        assert_eq!(field_num(line, "ts"), None);
        assert_eq!(field_num(line, "kind"), None);
    }

    #[test]
    fn escaped_quotes_cannot_forge_fields() {
        // foreign files may escape quotes; an injected `\"value\": 999`
        // inside a string is data, not a key
        let line = "{\"snapshot\": 1, \"kind\": \"counter\", \
                    \"key\": \"a\\\", \\\"value\\\": 999, \\\"x\", \
                    \"value\": 7, \"count\": 0}";
        assert_eq!(field_num(line, "value"), Some(7.0));
        assert_eq!(field_num(line, "snapshot"), Some(1.0));
        // unterminated string: refuse the whole line, don't misparse
        assert_eq!(field_num("{\"key\": \"open, \"value\": 7}", "missing"), None);
        assert_eq!(field_num("{\"snapshot\": 3, \"key\": \"trail\\", "snapshot"), Some(3.0));
    }
}
