//! Process-wide telemetry: labeled counters, gauges, and scoped
//! timers threaded through quantize → plan → merge → serve.
//!
//! Design goals, in priority order:
//!
//! 1. **Zero cost when disabled.** Recording is off unless
//!    `IRQLORA_TELEMETRY=1`. Every instrumentation site holds a
//!    [`Counter`]/[`Gauge`]/[`Timer`] *handle*; a handle from a
//!    disabled registry is a `None` and every operation on it is a
//!    single branch — no key formatting, no allocation, no atomics
//!    (`rust/tests/telemetry_disabled.rs` asserts the zero-allocation
//!    property under a counting global allocator).
//! 2. **Lock-free hot path when enabled.** A handle points at a
//!    [`registry::Slot`] of cache-line-padded atomic stripes; threads
//!    hash onto stripes, so concurrent increments don't bounce one
//!    cache line. The registry's mutex is taken only when a handle is
//!    *resolved* (component construction), never per event.
//! 3. **One counter, many views.** The serving layer's public stats
//!    structs (`PoolStats`, `ServerStats`, `UploadStats`,
//!    `FaultStats`) are incremented at the *same* mutation sites as
//!    their telemetry counters, so the two views reconcile exactly by
//!    construction — the chaos-soak battery asserts equality per seed.
//!
//! Keys are `name{label=value,...}` strings (e.g.
//! `quant.blocks_quantized{k=4}`, `hal.forward_time{backend=native}`).
//! With `IRQLORA_TELEMETRY_JSONL=path` the global registry appends one
//! JSON object per metric per snapshot — periodic (~1 s) and final —
//! with monotonic `ts_ms` timestamps; `irqlora stats FILE` renders the
//! last snapshot as the same table [`render_table`] produces from a
//! live [`Registry::snapshot`].
//!
//! Tests that need an *enabled* registry inject their own scoped
//! [`Registry`] (`PoolConfig.telemetry`, `FaultBackend::with_telemetry`)
//! instead of mutating the process environment — tests run in
//! parallel and the env is process-global.

mod jsonl;
mod registry;

pub use jsonl::{read_last_snapshot, LastSnapshot};
pub use registry::{
    render_table, Counter, Gauge, Kind, Registry, SnapshotEntry, Timer, TimerGuard,
};

use std::sync::{Arc, OnceLock};
use std::time::Duration;

/// Cadence of the global registry's periodic JSONL flusher thread.
const FLUSH_PERIOD: Duration = Duration::from_secs(1);

/// The process-global registry: enabled iff `IRQLORA_TELEMETRY=1` at
/// first use, with a JSONL appender iff `IRQLORA_TELEMETRY_JSONL` is
/// also set (in which case a detached ~1 s flusher thread keeps the
/// file fresh; `main` writes the final snapshot on exit). Library code
/// that has no injected registry records here; when disabled, every
/// handle it hands out is a no-op.
pub fn global() -> Arc<Registry> {
    static GLOBAL: OnceLock<Arc<Registry>> = OnceLock::new();
    static FLUSHER: OnceLock<()> = OnceLock::new();
    let reg = GLOBAL.get_or_init(|| {
        if crate::util::env::telemetry_enabled() {
            let mut r = Registry::enabled();
            if let Some(path) = crate::util::env::telemetry_jsonl() {
                r = r.with_jsonl(path);
            }
            Arc::new(r)
        } else {
            Arc::new(Registry::disabled())
        }
    });
    if reg.has_jsonl() {
        FLUSHER.get_or_init(|| {
            let r = reg.clone();
            let _ = std::thread::Builder::new()
                .name("irqlora-telemetry-flush".into())
                .spawn(move || loop {
                    std::thread::sleep(FLUSH_PERIOD);
                    let _ = r.flush_jsonl();
                });
        });
    }
    reg.clone()
}

/// Cached per-k counter handles (k ∈ 1..=8) for hot-path quant
/// metrics: resolving a handle takes the registry mutex and formats a
/// key, so callers resolve a `PerK` once (in a `OnceLock`) and record
/// through it — per-event cost is an array index plus the handle's
/// own branch/atomic.
pub struct PerK([Counter; 8]);

impl PerK {
    /// Resolve `name{k=1}` … `name{k=8}` from the global registry.
    pub fn resolve(name: &'static str) -> PerK {
        let reg = global();
        PerK(std::array::from_fn(|i| {
            let ks = (i + 1).to_string();
            reg.counter(name, &[("k", ks.as_str())])
        }))
    }

    /// Add `n` to the `k`-labeled counter. Out-of-range `k` (never
    /// produced by the quant layer, which validates 1..=8) is ignored
    /// rather than panicking inside an observability call.
    #[inline]
    pub fn add(&self, k: u8, n: u64) {
        if let Some(c) = self.0.get((k as usize).wrapping_sub(1)) {
            c.add(n);
        }
    }
}
