//! Minimal statistical bench harness (criterion is not in the offline
//! vendor set). Warms up, runs timed iterations, reports mean ± std,
//! min, and optional throughput. Used by every target in
//! `rust/benches/` (all built with `harness = false`).
//!
//! Besides stdout, benches can record results machine-readably through
//! [`JsonSink`], which merges into `BENCH_quant.json` at the repo root
//! (same-name entries are replaced, other benches' entries are kept) so
//! the perf trajectory is tracked across PRs — and compared across
//! snapshots by `scripts/perf_compare.sh`. Every row carries
//! provenance: a wall-clock `ts` and a best-effort `git_rev` (empty
//! when git is unavailable). Environment knobs:
//!
//! - `IRQLORA_BENCH_QUICK=1` — [`iters`] returns 1 (CI smoke mode;
//!   `scripts/verify.sh` sets it);
//! - `IRQLORA_BENCH_JSON=path` — override the JSON output path;
//! - `IRQLORA_THREADS=n` — pin the worker pool for reproducible runs
//!   (see `util::threads::worker_count`).

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub std: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Measured-iteration count for a bench: `default_iters`, or 1 when
/// `IRQLORA_BENCH_QUICK` is set to a non-empty, non-"0" value (read
/// through `util::env`).
pub fn iters(default_iters: usize) -> usize {
    if crate::util::env::bench_quick() {
        1
    } else {
        default_iters
    }
}

/// Whether an `IRQLORA_BENCH_QUICK` value means "quick mode on"
/// (parse in `util::env`).
#[cfg(test)]
fn quick_mode(v: Option<&str>) -> bool {
    crate::util::env::parse_quick(v)
}

fn sample<F: FnMut()>(warmup: usize, iters: usize, f: &mut F) -> (f64, f64, f64) {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / iters as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / iters as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    (mean, var.sqrt(), min)
}

fn bench_inner<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    tput: Option<(f64, &str)>,
    mut f: F,
) -> BenchResult {
    let (mean, std, min) = sample(warmup, iters, &mut f);
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean: Duration::from_secs_f64(mean),
        std: Duration::from_secs_f64(std),
        min: Duration::from_secs_f64(min),
    };
    report(&r, tput);
    r
}

/// Run `f` repeatedly: `warmup` unmeasured + `iters` measured calls.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, f: F) -> BenchResult {
    bench_inner(name, warmup, iters, None, f)
}

/// Like [`bench`] but also reports `units_per_iter / sec` throughput.
pub fn bench_throughput<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    units_per_iter: f64,
    unit: &str,
    f: F,
) -> BenchResult {
    bench_inner(name, warmup, iters, Some((units_per_iter, unit)), f)
}

fn report(r: &BenchResult, tput: Option<(f64, &str)>) {
    let fmt = |d: Duration| {
        let s = d.as_secs_f64();
        if s >= 1.0 {
            format!("{s:.3} s")
        } else if s >= 1e-3 {
            format!("{:.3} ms", s * 1e3)
        } else {
            format!("{:.1} µs", s * 1e6)
        }
    };
    print!(
        "{:<52} {:>12} ± {:<10} (min {:>10}, n={})",
        r.name,
        fmt(r.mean),
        fmt(r.std),
        fmt(r.min),
        r.iters
    );
    if let Some((units, name)) = tput {
        let per_sec = units / r.mean.as_secs_f64();
        if per_sec >= 1e9 {
            print!("  {:.2} G{name}/s", per_sec / 1e9);
        } else if per_sec >= 1e6 {
            print!("  {:.2} M{name}/s", per_sec / 1e6);
        } else if per_sec >= 1e3 {
            print!("  {:.2} K{name}/s", per_sec / 1e3);
        } else {
            print!("  {per_sec:.2} {name}/s");
        }
    }
    println!();
}

/// One machine-readable benchmark record (see [`JsonSink`]).
///
/// Rows pushed via [`JsonSink::push_raw`] may carry different
/// statistics than the mean-over-iterations of [`bench`]-produced
/// rows; such rows must say so in their name (e.g. the
/// `serve_latency p50 clients=N` rows record p50 request latency) so
/// cross-row tooling never mixes semantics silently.
#[derive(Clone, Debug, PartialEq)]
pub struct JsonEntry {
    pub name: String,
    pub iters: usize,
    /// Mean wall time per iteration, nanoseconds.
    pub ns_per_iter: f64,
    /// Fastest iteration, nanoseconds.
    pub ns_min: f64,
    /// Units (elements, requests, …) per second, when the bench
    /// reported throughput.
    pub per_sec: Option<f64>,
    /// Unix epoch seconds the row was recorded at (0 when the clock
    /// is unreadable).
    pub ts: u64,
    /// Short git revision of the recording tree — best-effort: empty
    /// when `git` is unavailable or the CWD is not a work tree.
    pub git_rev: String,
}

/// Collects [`JsonEntry`]s and writes them as a stable, dependency-free
/// JSON document (one entry per line under `"results"`). Writing merges
/// with an existing file: entries sharing a name are replaced, entries
/// from other benches are preserved.
#[derive(Debug, Default)]
pub struct JsonSink {
    entries: Vec<JsonEntry>,
}

impl JsonSink {
    pub fn new() -> JsonSink {
        JsonSink::default()
    }

    /// Record a finished benchmark. `units_per_iter` (if given) adds a
    /// derived `per_sec` throughput field.
    pub fn push(&mut self, r: &BenchResult, units_per_iter: Option<f64>) {
        self.push_raw(
            &r.name,
            r.iters,
            r.mean.as_secs_f64() * 1e9,
            r.min.as_secs_f64() * 1e9,
            units_per_iter.map(|u| u / r.mean.as_secs_f64()),
        );
    }

    /// Record an arbitrary measurement (e.g. a serving-latency row that
    /// did not come from [`bench`]).
    pub fn push_raw(
        &mut self,
        name: &str,
        iters: usize,
        ns_per_iter: f64,
        ns_min: f64,
        per_sec: Option<f64>,
    ) {
        self.entries.push(JsonEntry {
            name: sanitize(name),
            iters,
            ns_per_iter,
            ns_min,
            per_sec,
            ts: epoch_secs(),
            git_rev: git_rev().to_string(),
        });
    }

    /// Merge with any entries already in `path` and (re)write the file.
    pub fn write_merged(&self, path: &Path) -> std::io::Result<()> {
        let mut merged = read_entries(path).unwrap_or_default();
        merged.retain(|e| !self.entries.iter().any(|n| n.name == e.name));
        merged.extend(self.entries.iter().cloned());
        let mut s = String::new();
        s.push_str("{\n  \"schema\": \"irqlora-bench-v1\",\n  \"results\": [\n");
        for (i, e) in merged.iter().enumerate() {
            let per_sec = match e.per_sec {
                Some(p) => fnum(p),
                None => "null".to_string(),
            };
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"iters\": {}, \"ns_per_iter\": {}, \"ns_min\": {}, \"per_sec\": {}, \"ts\": {}, \"git_rev\": \"{}\"}}{}\n",
                e.name,
                e.iters,
                fnum(e.ns_per_iter),
                fnum(e.ns_min),
                per_sec,
                e.ts,
                e.git_rev,
                if i + 1 == merged.len() { "" } else { "," },
            ));
        }
        s.push_str("  ]\n}\n");
        std::fs::write(path, s)
    }
}

/// Keep names trivially JSON-safe (the parser in [`read_entries`] and
/// downstream tooling rely on it).
fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c == '"' || c == '\\' || c.is_control() { '_' } else { c })
        .collect()
}

fn fnum(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "0.000".to_string()
    }
}

/// Unix epoch seconds, 0 when the clock is unreadable (a pre-epoch
/// clock should not fail the write path).
fn epoch_secs() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Best-effort short git revision of the recording tree, resolved once
/// per process. Empty when `git` is missing, errors, or the CWD is not
/// inside a work tree — bench rows must never fail over provenance.
fn git_rev() -> &'static str {
    static REV: std::sync::OnceLock<String> = std::sync::OnceLock::new();
    REV.get_or_init(|| {
        std::process::Command::new("git")
            .args(["rev-parse", "--short", "HEAD"])
            .output()
            .ok()
            .filter(|o| o.status.success())
            .and_then(|o| String::from_utf8(o.stdout).ok())
            .map(|s| sanitize(s.trim()))
            .unwrap_or_default()
    })
}

/// Parse a file previously written by [`JsonSink::write_merged`]. Only
/// understands that exact line-per-entry layout — enough for merging.
pub fn read_entries(path: &Path) -> Option<Vec<JsonEntry>> {
    let text = std::fs::read_to_string(path).ok()?;
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim().trim_end_matches(',');
        if !line.starts_with("{\"name\": \"") {
            continue;
        }
        let (Some(name), Some(iters), Some(ns), Some(ns_min)) = (
            field_str(line, "name"),
            field_num(line, "iters"),
            field_num(line, "ns_per_iter"),
            field_num(line, "ns_min"),
        ) else {
            continue;
        };
        out.push(JsonEntry {
            name,
            iters: iters as usize,
            ns_per_iter: ns,
            ns_min,
            per_sec: field_num(line, "per_sec"),
            // absent in pre-stamp files: default rather than reject
            ts: field_num(line, "ts").unwrap_or(0.0) as u64,
            git_rev: field_str(line, "git_rev").unwrap_or_default(),
        });
    }
    Some(out)
}

fn field_str(line: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\": \"");
    let start = line.find(&pat)? + pat.len();
    let end = line[start..].find('"')? + start;
    Some(line[start..end].to_string())
}

fn field_num(line: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\": ");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = rest
        .find(|c: char| c == ',' || c == '}')
        .unwrap_or(rest.len());
    rest[..end].trim().parse::<f64>().ok()
}

/// Default output path for a bench JSON artifact: honors the
/// `IRQLORA_BENCH_JSON` override, else places `name` at the repo root
/// (benches run with CWD = `rust/`, so that is usually `../name`).
pub fn bench_json_path(name: &str) -> PathBuf {
    if let Some(p) = crate::util::env::bench_json() {
        return PathBuf::from(p);
    }
    let parent = Path::new("..");
    if parent.join(".git").exists() && !Path::new(".git").exists() {
        return parent.join(name);
    }
    PathBuf::from(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut count = 0;
        let r = bench("noop", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.mean);
    }

    #[test]
    fn throughput_variant() {
        let r = bench_throughput("spin", 1, 3, 1000.0, "elem", || {
            std::hint::black_box((0..100).sum::<usize>());
        });
        assert!(r.mean_secs() >= 0.0);
    }

    #[test]
    fn json_sink_roundtrip_and_merge() {
        let dir = std::env::temp_dir().join(format!(
            "irqlora_bench_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bench.json");

        let mut a = JsonSink::new();
        a.push_raw("alpha (1M)", 10, 1234.5, 1000.0, Some(8.1e8));
        a.push_raw("beta", 3, 50.0, 49.0, None);
        a.write_merged(&path).unwrap();

        let back = read_entries(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].name, "alpha (1M)");
        assert_eq!(back[0].iters, 10);
        assert!((back[0].ns_per_iter - 1234.5).abs() < 1e-9);
        assert!((back[0].per_sec.unwrap() - 8.1e8).abs() < 1.0);
        assert_eq!(back[1].per_sec, None);

        // second sink replaces same-name entries, keeps the rest
        let mut b = JsonSink::new();
        b.push_raw("beta", 5, 40.0, 39.0, Some(100.0));
        b.write_merged(&path).unwrap();
        let back = read_entries(&path).unwrap();
        assert_eq!(back.len(), 2);
        let beta = back.iter().find(|e| e.name == "beta").unwrap();
        assert_eq!(beta.iters, 5);
        assert!((beta.per_sec.unwrap() - 100.0).abs() < 1e-9);
        assert!(back.iter().any(|e| e.name == "alpha (1M)"));

        // the document is self-describing and rows carry provenance
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("irqlora-bench-v1"));
        assert!(text.contains("\"ts\": "));
        assert!(text.contains("\"git_rev\": \""));
        let alpha = back.iter().find(|e| e.name == "alpha (1M)").unwrap();
        assert!(alpha.ts > 0, "push_raw must stamp a wall-clock ts");
        // git_rev is best-effort (may be empty offline) but must stay
        // JSON-safe when present
        assert!(!alpha.git_rev.contains('"') && !alpha.git_rev.contains('\\'));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn read_entries_tolerates_pre_stamp_rows() {
        let dir = std::env::temp_dir().join(format!(
            "irqlora_bench_prestamp_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("old.json");
        std::fs::write(
            &path,
            "{\n  \"schema\": \"irqlora-bench-v1\",\n  \"results\": [\n    \
             {\"name\": \"legacy\", \"iters\": 2, \"ns_per_iter\": 10.000, \
             \"ns_min\": 9.000, \"per_sec\": null}\n  ]\n}\n",
        )
        .unwrap();
        let back = read_entries(&path).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].ts, 0);
        assert_eq!(back[0].git_rev, "");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quick_mode_iters() {
        // the env-value interpretation is tested through the pure
        // helper; no process-global env mutation (tests run in
        // parallel and benches rely on the caller's pin).
        assert!(!quick_mode(None));
        assert!(!quick_mode(Some("")));
        assert!(!quick_mode(Some("0")));
        assert!(quick_mode(Some("1")));
        assert!(quick_mode(Some("yes")));
        // iters() itself just routes through quick_mode
        assert!(iters(10) == 10 || iters(10) == 1);
    }

    #[test]
    fn sanitize_names() {
        assert_eq!(sanitize("ok name (1M)"), "ok name (1M)");
        assert_eq!(sanitize("bad\"name\\x"), "bad_name_x");
    }
}
