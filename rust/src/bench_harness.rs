//! Minimal statistical bench harness (criterion is not in the offline
//! vendor set). Warms up, runs timed iterations, reports mean ± std,
//! min, and optional throughput. Used by every target in
//! `rust/benches/` (all built with `harness = false`).

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub std: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Run `f` repeatedly: `warmup` unmeasured + `iters` measured calls.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / iters as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / iters as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean: Duration::from_secs_f64(mean),
        std: Duration::from_secs_f64(var.sqrt()),
        min: Duration::from_secs_f64(min),
    };
    report(&r, None);
    r
}

/// Like [`bench`] but also reports `units_per_iter / sec` throughput.
pub fn bench_throughput<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    units_per_iter: f64,
    unit: &str,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / iters as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / iters as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean: Duration::from_secs_f64(mean),
        std: Duration::from_secs_f64(var.sqrt()),
        min: Duration::from_secs_f64(min),
    };
    report(&r, Some((units_per_iter, unit)));
    r
}

fn report(r: &BenchResult, tput: Option<(f64, &str)>) {
    let fmt = |d: Duration| {
        let s = d.as_secs_f64();
        if s >= 1.0 {
            format!("{s:.3} s")
        } else if s >= 1e-3 {
            format!("{:.3} ms", s * 1e3)
        } else {
            format!("{:.1} µs", s * 1e6)
        }
    };
    print!(
        "{:<44} {:>12} ± {:<10} (min {:>10}, n={})",
        r.name,
        fmt(r.mean),
        fmt(r.std),
        fmt(r.min),
        r.iters
    );
    if let Some((units, name)) = tput {
        let per_sec = units / r.mean.as_secs_f64();
        if per_sec >= 1e9 {
            print!("  {:.2} G{name}/s", per_sec / 1e9);
        } else if per_sec >= 1e6 {
            print!("  {:.2} M{name}/s", per_sec / 1e6);
        } else if per_sec >= 1e3 {
            print!("  {:.2} K{name}/s", per_sec / 1e3);
        } else {
            print!("  {per_sec:.2} {name}/s");
        }
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut count = 0;
        let r = bench("noop", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.mean);
    }

    #[test]
    fn throughput_variant() {
        let r = bench_throughput("spin", 1, 3, 1000.0, "elem", || {
            std::hint::black_box((0..100).sum::<usize>());
        });
        assert!(r.mean_secs() >= 0.0);
    }
}
