//! Information-entropy metric over quantized codes (paper Eq. 7).
//!
//! ICQ's objective is the Shannon entropy of the code histogram of a
//! quantized block; this module provides the histogram/entropy helpers
//! plus model-level aggregates used for Figure 4/5 and Table 5.

use crate::util::stats::entropy_bits;

use super::blockwise::QuantizedBlocks;

/// Histogram of k-bit codes.
pub fn code_histogram(codes: &[u8], k: u8) -> Vec<u32> {
    let mut counts = vec![0u32; 1 << k];
    for &c in codes {
        counts[c as usize] += 1;
    }
    counts
}

/// Shannon entropy (bits) of a slice of k-bit codes.
pub fn code_entropy(codes: &[u8], k: u8) -> f64 {
    entropy_bits(&code_histogram(codes, k))
}

/// Entropy of each block of a quantized tensor.
pub fn per_block_entropy(q: &QuantizedBlocks) -> Vec<f64> {
    (0..q.n_blocks())
        .map(|bi| {
            let lo = bi * q.block;
            let hi = (lo + q.block).min(q.len);
            code_entropy(&q.codes[lo..hi], q.k)
        })
        .collect()
}

/// Mean per-block entropy of a quantized tensor — the quantity plotted
/// in Figures 4/5 and reported in Table 5 ("Ent.").
pub fn mean_block_entropy(q: &QuantizedBlocks) -> f64 {
    let per = per_block_entropy(q);
    if per.is_empty() {
        0.0
    } else {
        per.iter().sum::<f64>() / per.len() as f64
    }
}

/// Upper bound on code entropy for bit-width k.
pub fn max_entropy(k: u8) -> f64 {
    k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::blockwise;
    use crate::util::Rng;

    #[test]
    fn histogram_counts() {
        let h = code_histogram(&[0, 0, 1, 3, 3, 3], 2);
        assert_eq!(h, vec![2, 1, 0, 3]);
    }

    #[test]
    fn entropy_bounds() {
        let mut rng = Rng::new(5);
        let w = rng.normal_vec(4096, 0.0, 0.05);
        let q = blockwise::quantize(&w, 4, 64, None);
        let h = mean_block_entropy(&q);
        assert!(h > 2.0 && h <= max_entropy(4), "h={h}");
    }

    #[test]
    fn degenerate_block_zero_entropy() {
        let w = vec![0.5f32; 64];
        let q = blockwise::quantize(&w, 4, 64, None);
        assert_eq!(mean_block_entropy(&q), 0.0); // all elements -> same code
    }

    #[test]
    fn per_block_lengths() {
        let mut rng = Rng::new(6);
        let w = rng.normal_vec(200, 0.0, 1.0);
        let q = blockwise::quantize(&w, 3, 64, None);
        assert_eq!(per_block_entropy(&q).len(), 4); // 64*3 + 8
    }

    #[test]
    fn normal_data_nf4_entropy_near_theoretical() {
        // NF4 is designed so N(0,1) data spreads across levels; with
        // blockwise absmax normalization mean entropy lands well above
        // 3 bits (paper Table 5 reports 3.67 for LLaMA-7B).
        let mut rng = Rng::new(7);
        let w = rng.normal_vec(64 * 2000, 0.0, 1.0);
        let q = blockwise::quantize(&w, 4, 64, None);
        let h = mean_block_entropy(&q);
        assert!(h > 3.3 && h < 3.95, "h={h}");
    }
}
