//! Information-entropy metric over quantized codes (paper Eq. 7).
//!
//! ICQ's objective is the Shannon entropy of the code histogram of a
//! quantized block; this module provides the histogram/entropy helpers
//! plus model-level aggregates used for Figure 4/5 and Table 5.

use anyhow::{bail, Result};

use crate::util::stats::entropy_bits;

use super::blockwise::QuantizedBlocks;

/// Histogram of k-bit codes. Out-of-range codes (corrupt storage, a
/// k/codes mismatch) saturate into the top bin instead of indexing
/// past the histogram — the entropy they contribute is then slightly
/// off, but callers deep in the serving/report path never panic. Use
/// [`try_code_histogram`] where a corrupt input should surface as an
/// error instead.
pub fn code_histogram(codes: &[u8], k: u8) -> Vec<u32> {
    let top = (1usize << k) - 1;
    let mut counts = vec![0u32; 1 << k];
    for &c in codes {
        counts[(c as usize).min(top)] += 1;
    }
    counts
}

/// Strict [`code_histogram`]: errors on the first code ≥ 2^k.
pub fn try_code_histogram(codes: &[u8], k: u8) -> Result<Vec<u32>> {
    let mut counts = vec![0u32; 1 << k];
    for (i, &c) in codes.iter().enumerate() {
        match counts.get_mut(c as usize) {
            Some(slot) => *slot += 1,
            None => bail!("code {c} at index {i} out of range for k={k}"),
        }
    }
    Ok(counts)
}

/// Shannon entropy (bits) of a slice of k-bit codes.
pub fn code_entropy(codes: &[u8], k: u8) -> f64 {
    entropy_bits(&code_histogram(codes, k))
}

/// Entropy of each block of a quantized tensor.
pub fn per_block_entropy(q: &QuantizedBlocks) -> Vec<f64> {
    (0..q.n_blocks())
        .map(|bi| {
            let lo = bi * q.block;
            let hi = (lo + q.block).min(q.len);
            code_entropy(&q.codes[lo..hi], q.k)
        })
        .collect()
}

/// Mean per-block entropy of a quantized tensor — the quantity plotted
/// in Figures 4/5 and reported in Table 5 ("Ent.").
pub fn mean_block_entropy(q: &QuantizedBlocks) -> f64 {
    let per = per_block_entropy(q);
    if per.is_empty() {
        0.0
    } else {
        per.iter().sum::<f64>() / per.len() as f64
    }
}

/// Upper bound on code entropy for bit-width k.
pub fn max_entropy(k: u8) -> f64 {
    k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::blockwise;
    use crate::util::Rng;

    #[test]
    fn histogram_counts() {
        let h = code_histogram(&[0, 0, 1, 3, 3, 3], 2);
        assert_eq!(h, vec![2, 1, 0, 3]);
    }

    #[test]
    fn out_of_range_codes_saturate_instead_of_panicking() {
        // regression: code 9 at k=2 used to index past the 4-slot
        // histogram and panic; it must now count into the top bin
        let h = code_histogram(&[0, 1, 9, 255], 2);
        assert_eq!(h, vec![1, 1, 0, 2]);
        assert_eq!(h.iter().sum::<u32>(), 4); // nothing dropped
        // entropy over such codes is finite, not a crash
        assert!(code_entropy(&[0, 9, 9, 255], 2).is_finite());
        // k = 8 covers the full u8 range: nothing can saturate
        let h8 = code_histogram(&[255], 8);
        assert_eq!(h8[255], 1);
    }

    #[test]
    fn strict_histogram_rejects_out_of_range() {
        assert_eq!(
            try_code_histogram(&[0, 0, 1, 3], 2).unwrap(),
            vec![2, 1, 0, 1]
        );
        let err = try_code_histogram(&[0, 4], 2).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        assert!(try_code_histogram(&[255], 8).is_ok());
    }

    #[test]
    fn entropy_bounds() {
        let mut rng = Rng::new(5);
        let w = rng.normal_vec(4096, 0.0, 0.05);
        let q = blockwise::quantize(&w, 4, 64, None);
        let h = mean_block_entropy(&q);
        assert!(h > 2.0 && h <= max_entropy(4), "h={h}");
    }

    #[test]
    fn degenerate_block_zero_entropy() {
        let w = vec![0.5f32; 64];
        let q = blockwise::quantize(&w, 4, 64, None);
        assert_eq!(mean_block_entropy(&q), 0.0); // all elements -> same code
    }

    #[test]
    fn per_block_lengths() {
        let mut rng = Rng::new(6);
        let w = rng.normal_vec(200, 0.0, 1.0);
        let q = blockwise::quantize(&w, 3, 64, None);
        assert_eq!(per_block_entropy(&q).len(), 4); // 64*3 + 8
    }

    #[test]
    fn normal_data_nf4_entropy_near_theoretical() {
        // NF4 is designed so N(0,1) data spreads across levels; with
        // blockwise absmax normalization mean entropy lands well above
        // 3 bits (paper Table 5 reports 3.67 for LLaMA-7B).
        let mut rng = Rng::new(7);
        let w = rng.normal_vec(64 * 2000, 0.0, 1.0);
        let q = blockwise::quantize(&w, 4, 64, None);
        let h = mean_block_entropy(&q);
        assert!(h > 3.3 && h < 3.95, "h={h}");
    }
}
