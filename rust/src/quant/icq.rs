//! Information Calibration Quantization — the paper's core technique
//! (§3.2, Algorithm 1).
//!
//! Per quantization block, ICQ introduces a calibration constant τ:
//! `ŵ = NFk((w − τ) / absmax(w − τ))` (Eq. 8), chosen to maximize the
//! Shannon entropy of the quantized codes (Eq. 9):
//!
//! 1. init τ₀ = median(block) — robust to outliers, centers the NF grid
//!    on the densest region of a (roughly) symmetric distribution;
//! 2. exhaustive search over `linspace(τ₀ − λσ, τ₀ + λσ)` with 2n+1
//!    candidates (paper defaults λ = 0.1, n = 100, σ = 1 — the std of
//!    N(0,1));
//! 3. keep the entropy-maximizing τ*; τ* and the resulting scale are
//!    then double-quantized (see `double_quant`).
//!
//! The search is embarrassingly parallel across blocks; `quantize`
//! fans out with `util::threads::par_map_with` (low serial-fallback
//! threshold — each block runs 2n+1 entropy evaluations).

use crate::util::stats::{self, entropy_bits};
use crate::util::threads::par_map_with;

use super::blockwise::QuantizedBlocks;
use super::nf;

/// ICQ hyper-parameters (paper §3.2.2 defaults).
#[derive(Clone, Copy, Debug)]
pub struct IcqConfig {
    /// Half-width coefficient λ of the search interval.
    pub lambda: f32,
    /// Half the candidate count: the grid has 2n+1 points.
    pub n: usize,
    /// σ in the interval [τ₀ − λσ, τ₀ + λσ]. The paper fixes σ = 1
    /// (the std of N(0,1)); `SigmaMode::BlockStd` instead scales the
    /// interval to each block's own spread (ablation option).
    pub sigma_mode: SigmaMode,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SigmaMode {
    /// σ = 1 (paper setting).
    Unit,
    /// σ = std of the block (adaptive variant).
    BlockStd,
}

impl Default for IcqConfig {
    fn default() -> Self {
        IcqConfig { lambda: 0.1, n: 100, sigma_mode: SigmaMode::Unit }
    }
}

/// Result of the per-block τ search.
#[derive(Clone, Copy, Debug)]
pub struct TauSearch {
    pub tau: f32,
    /// Entropy (bits) achieved at τ*.
    pub entropy: f64,
    /// Entropy (bits) of the uncalibrated (τ = 0) quantization, for
    /// the Figure-4 style comparisons.
    pub entropy_vanilla: f64,
}

/// Entropy of one block quantized with shift `tau` (Algorithm 1 body).
#[inline]
fn entropy_at(
    block: &[f32],
    tau: f32,
    bounds: &[f32],
    counts: &mut [u32],
) -> f64 {
    let mut amax = 0f32;
    for &x in block {
        amax = amax.max((x - tau).abs());
    }
    if amax == 0.0 {
        return 0.0; // constant block: a single code, zero entropy
    }
    counts.fill(0);
    let inv = 1.0 / amax;
    for &x in block {
        let c = nf::quantize_one(bounds, (x - tau) * inv);
        counts[c as usize] += 1;
    }
    entropy_bits(counts)
}

/// Entropy at shift `tau` over a PRE-SORTED block: absmax comes from
/// the extremes in O(1) and each histogram bin from a binary search
/// over the sorted values (15·log B instead of B·log 16 comparisons).
/// This is the optimized inner loop of Algorithm 1 — bit-identical to
/// [`entropy_at`] (property-tested) but ~2-4x faster, which matters
/// because it runs 201 times per 64-weight block of the model.
#[inline]
fn entropy_at_sorted(
    sorted: &[f32],
    tau: f32,
    bounds: &[f32],
    counts: &mut [u32],
) -> f64 {
    let lo = sorted[0] - tau;
    let hi = sorted[sorted.len() - 1] - tau;
    let amax = lo.abs().max(hi.abs());
    if amax == 0.0 {
        return 0.0;
    }
    // element i falls in bin b iff (x - tau)/amax > bounds[b-1] etc.
    // cumulative counts via partition points of tau + amax*bound.
    counts.fill(0);
    let mut prev = 0usize;
    for (b, &bound) in bounds.iter().enumerate() {
        let threshold = tau + amax * bound;
        // number of elements with (x - tau) <= amax*bound, i.e. NOT in
        // a later bin; quantize_one uses strict '>', so count x <= thr
        let mut l = prev; // thresholds ascend, so resume from prev
        let mut r = sorted.len();
        while l < r {
            let mid = (l + r) / 2;
            if sorted[mid] <= threshold {
                l = mid + 1;
            } else {
                r = mid;
            }
        }
        counts[b] = (l - prev) as u32;
        prev = l;
    }
    counts[bounds.len()] = (sorted.len() - prev) as u32;
    entropy_bits(counts)
}

/// Exhaustive τ search for one block (Algorithm 1), using the
/// sorted-block fast path.
pub fn search_tau(block: &[f32], k: u8, cfg: &IcqConfig) -> TauSearch {
    let cb = nf::codebook(k);
    let bounds = nf::boundaries(&cb);
    let mut counts = vec![0u32; 1 << k];

    let entropy_vanilla = entropy_at(block, 0.0, &bounds, &mut counts);

    let mut sorted = block.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let tau0 = stats::quantile_sorted(&sorted, 0.5);
    let sigma = match cfg.sigma_mode {
        SigmaMode::Unit => 1.0,
        SigmaMode::BlockStd => stats::std(block).max(1e-12),
    };
    let half = cfg.lambda * sigma;
    let steps = 2 * cfg.n; // grid points besides the left endpoint

    let mut best_tau = tau0;
    let mut best_h = entropy_at_sorted(&sorted, tau0, &bounds, &mut counts);
    for i in 0..=steps {
        let tau = tau0 - half + (2.0 * half) * i as f32 / steps as f32;
        let h = entropy_at_sorted(&sorted, tau, &bounds, &mut counts);
        if h > best_h {
            best_h = h;
            best_tau = tau;
        }
    }

    TauSearch { tau: best_tau, entropy: best_h, entropy_vanilla }
}

/// Reference (unsorted) τ search — kept as the oracle for the fast
/// path; see `fast_path_matches_reference` below.
pub fn search_tau_reference(block: &[f32], k: u8, cfg: &IcqConfig) -> TauSearch {
    let cb = nf::codebook(k);
    let bounds = nf::boundaries(&cb);
    let mut counts = vec![0u32; 1 << k];
    let entropy_vanilla = entropy_at(block, 0.0, &bounds, &mut counts);
    let tau0 = stats::median(block);
    let sigma = match cfg.sigma_mode {
        SigmaMode::Unit => 1.0,
        SigmaMode::BlockStd => stats::std(block).max(1e-12),
    };
    let half = cfg.lambda * sigma;
    let steps = 2 * cfg.n;
    let mut best_tau = tau0;
    let mut best_h = entropy_at(block, tau0, &bounds, &mut counts);
    for i in 0..=steps {
        let tau = tau0 - half + (2.0 * half) * i as f32 / steps as f32;
        let h = entropy_at(block, tau, &bounds, &mut counts);
        if h > best_h {
            best_h = h;
            best_tau = tau;
        }
    }
    TauSearch { tau: best_tau, entropy: best_h, entropy_vanilla }
}

/// ICQ-quantize a tensor: per-block τ search (parallel across blocks),
/// then blockwise NF-k quantization with the found shifts.
pub fn quantize(w: &[f32], k: u8, block: usize, cfg: &IcqConfig) -> QuantizedBlocks {
    let n_blocks = w.len().div_ceil(block);
    // the τ search runs 2n+1 entropy evaluations per block, so fanning
    // out pays off from 2 blocks up (low threshold, unlike the cheap
    // per-item maps elsewhere)
    let taus: Vec<f32> = par_map_with(n_blocks, 2, |bi| {
        let lo = bi * block;
        let hi = (lo + block).min(w.len());
        search_tau(&w[lo..hi], k, cfg).tau
    });
    super::blockwise::quantize(w, k, block, Some(&taus))
}

/// Per-block search results (τ + both entropies) — used by the
/// Figure 4/5 harness and Table 5.
pub fn search_all(w: &[f32], k: u8, block: usize, cfg: &IcqConfig) -> Vec<TauSearch> {
    let n_blocks = w.len().div_ceil(block);
    par_map_with(n_blocks, 2, |bi| {
        let lo = bi * block;
        let hi = (lo + block).min(w.len());
        search_tau(&w[lo..hi], k, cfg)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{blockwise, entropy};
    use crate::util::Rng;

    #[test]
    fn icq_entropy_never_below_vanilla() {
        // The search grid includes entropies >= the best found; vanilla
        // (tau=0) is not on the grid, but ICQ must beat or match it on
        // average by a clear margin for skewed blocks.
        let mut rng = Rng::new(11);
        // skewed blocks: normal + constant shift stresses absmax quant
        let w: Vec<f32> = (0..64 * 50)
            .map(|_| rng.normal_ms(0.03, 0.02))
            .collect();
        let q_van = blockwise::quantize(&w, 4, 64, None);
        let q_icq = quantize(&w, 4, 64, &IcqConfig::default());
        let h_van = entropy::mean_block_entropy(&q_van);
        let h_icq = entropy::mean_block_entropy(&q_icq);
        assert!(
            h_icq > h_van,
            "ICQ {h_icq:.4} should exceed vanilla {h_van:.4} on shifted data"
        );
    }

    #[test]
    fn tau_near_median_for_symmetric_data() {
        let mut rng = Rng::new(12);
        let block: Vec<f32> = (0..64).map(|_| rng.normal_ms(0.0, 0.02)).collect();
        let r = search_tau(&block, 4, &IcqConfig::default());
        // tau* stays within the search interval around the median
        let med = crate::util::stats::median(&block);
        assert!((r.tau - med).abs() <= 0.1 + 1e-6);
    }

    #[test]
    fn search_interval_respected() {
        let mut rng = Rng::new(13);
        let block: Vec<f32> = (0..64).map(|_| rng.normal_ms(0.5, 0.1)).collect();
        let cfg = IcqConfig { lambda: 0.05, n: 10, sigma_mode: SigmaMode::Unit };
        let r = search_tau(&block, 4, &cfg);
        let med = crate::util::stats::median(&block);
        assert!((r.tau - med).abs() <= 0.05 + 1e-6);
    }

    #[test]
    fn entropy_reported_matches_requantization() {
        let mut rng = Rng::new(14);
        let block: Vec<f32> = (0..64).map(|_| rng.normal_ms(0.01, 0.03)).collect();
        let r = search_tau(&block, 4, &IcqConfig::default());
        let q = blockwise::quantize(&block, 4, 64, Some(&[r.tau]));
        let h = entropy::code_entropy(&q.codes, 4);
        assert!((h - r.entropy).abs() < 1e-9, "{h} vs {}", r.entropy);
    }

    #[test]
    fn reconstruction_still_faithful() {
        // ICQ must not hurt reconstruction error materially
        let mut rng = Rng::new(15);
        let w = rng.normal_vec(64 * 20, 0.01, 0.02);
        let q = quantize(&w, 4, 64, &IcqConfig::default());
        let wh = blockwise::dequantize(&q);
        let mse_icq = crate::util::stats::mse(&w, &wh);
        let q0 = blockwise::quantize(&w, 4, 64, None);
        let mse_van = crate::util::stats::mse(&w, &blockwise::dequantize(&q0));
        assert!(mse_icq < mse_van * 1.5, "icq {mse_icq} vanilla {mse_van}");
    }

    #[test]
    fn block_std_mode_adapts() {
        // with tiny-spread data, Unit mode's +-0.1 interval is mostly
        // wasted; BlockStd zooms in and must find at least as good tau
        let mut rng = Rng::new(16);
        let block: Vec<f32> = (0..64).map(|_| rng.normal_ms(0.0, 0.001)).collect();
        let unit = search_tau(&block, 4, &IcqConfig::default());
        let adaptive = search_tau(
            &block,
            4,
            &IcqConfig { sigma_mode: SigmaMode::BlockStd, ..Default::default() },
        );
        assert!(adaptive.entropy >= unit.entropy - 1e-9);
    }

    #[test]
    fn fast_path_matches_reference() {
        // the sorted-block fast path must agree with the naive
        // Algorithm-1 loop on tau and entropy across random blocks
        for seed in 0..30u64 {
            let mut rng = Rng::new(900 + seed);
            let shift = rng.range_f32(-0.05, 0.05);
            let scale = rng.range_f32(0.002, 0.1);
            let block: Vec<f32> = (0..64).map(|_| rng.normal_ms(shift, scale)).collect();
            let fast = search_tau(&block, 4, &IcqConfig::default());
            let slow = search_tau_reference(&block, 4, &IcqConfig::default());
            assert!(
                (fast.entropy - slow.entropy).abs() < 1e-9,
                "seed {seed}: entropy {} vs {}",
                fast.entropy,
                slow.entropy
            );
            assert!(
                (fast.tau - slow.tau).abs() < 1e-6,
                "seed {seed}: tau {} vs {}",
                fast.tau,
                slow.tau
            );
        }
    }

    #[test]
    fn constant_block_degenerates_gracefully() {
        let block = vec![0.25f32; 64];
        let r = search_tau(&block, 4, &IcqConfig::default());
        assert!(r.entropy >= 0.0 && r.tau.is_finite());
    }

    #[test]
    fn ultra_low_bitwidths() {
        let mut rng = Rng::new(17);
        let w = rng.normal_vec(64 * 30, 0.02, 0.05);
        for k in [2u8, 3] {
            let q_icq = quantize(&w, k, 64, &IcqConfig::default());
            let q_van = blockwise::quantize(&w, k, 64, None);
            let h_icq = entropy::mean_block_entropy(&q_icq);
            let h_van = entropy::mean_block_entropy(&q_van);
            assert!(h_icq >= h_van, "k={k}: {h_icq} < {h_van}");
        }
    }
}
