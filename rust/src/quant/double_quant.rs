//! Double quantization of per-block constants (paper Eq. 3/10).
//!
//! The first-level quantization leaves one f32 scale per 64-element
//! block (and, with ICQ, one τ per block). Double quantization re-
//! quantizes those constants: groups of 256 are encoded as FP8 E4M3
//! codes (`s₁^FP8`/`τ₁^FP8`) with one FP16 group scale
//! (`s₂^FP16`/`τ₂^FP16`), cutting the per-weight overhead from
//! 32/64 ≈ 0.5 bit to (8 + 16/256)/64 ≈ 0.126 bit.

use crate::util::f16;
use crate::util::threads;

use super::fp8;

/// Paper-default double-quantization group size.
pub const DEFAULT_GROUP: usize = 256;

/// Double-quantized representation of a vector of per-block constants.
#[derive(Clone, Debug)]
pub struct DoubleQuant {
    /// FP8 E4M3 code per constant (s₁ / τ₁).
    pub codes: Vec<u8>,
    /// FP16-rounded scale per group of `group` constants (s₂ / τ₂).
    pub group_scales: Vec<f32>,
    /// Group size.
    pub group: usize,
}

impl DoubleQuant {
    /// Quantize a vector of constants. Parallel over groups (a large
    /// model quantizes tens of thousands of per-block constants).
    pub fn quantize(values: &[f32], group: usize) -> DoubleQuant {
        assert!(group > 0);
        let n_groups = values.len().div_ceil(group);
        let mut codes = vec![0u8; values.len()];
        let mut group_scales = vec![0f32; n_groups];
        // pass 1: one f16-rounded scale per group of `group` constants
        threads::par_chunks_mut_with(&mut group_scales, 64, 2, |ci, gs| {
            for (j, s) in gs.iter_mut().enumerate() {
                let gi = ci * 64 + j;
                let lo = gi * group;
                let hi = (lo + group).min(values.len());
                let amax = values[lo..hi].iter().fold(0f32, |m, &x| m.max(x.abs()));
                // map the group's absmax to FP8's max magnitude
                let g = if amax > 0.0 { amax / fp8::E4M3_MAX } else { 1.0 };
                let g = f16::round_f16(g);
                // guard: f16 rounding of tiny scales can underflow to 0
                *s = if g > 0.0 { g } else { f16::round_f16(f32::MIN_POSITIVE * 1e30) };
            }
        });
        // pass 2: E4M3 codes, parallel over groups (disjoint chunks)
        let gs_ref = &group_scales;
        threads::par_chunks_mut_with(&mut codes, group, 2, |gi, chunk| {
            let lo = gi * group;
            let gs = gs_ref[gi];
            for (j, c) in chunk.iter_mut().enumerate() {
                *c = fp8::f32_to_e4m3(values[lo + j] / gs);
            }
        });
        DoubleQuant { codes, group_scales, group }
    }

    /// Reconstruct constant `i` (paper's `dequant(s₁, s₂)`).
    #[inline]
    pub fn get(&self, i: usize) -> f32 {
        fp8::e4m3_to_f32(self.codes[i]) * self.group_scales[i / self.group]
    }

    /// Reconstruct all constants.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.dequantize_into(&mut out);
        out
    }

    /// Allocation-free reconstruction into a reused buffer (cleared
    /// and refilled) — the scratch path of
    /// [`super::QuantizedTensor::dequantize_into`].
    pub fn dequantize_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.codes.len());
        for i in 0..self.codes.len() {
            out.push(self.get(i));
        }
    }

    pub fn len(&self) -> usize {
        self.codes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    /// Total storage in bits (8 per code + 16 per group scale).
    pub fn storage_bits(&self) -> usize {
        self.codes.len() * 8 + self.group_scales.len() * 16
    }
}

/// Per-weight storage overhead in bits contributed by double-quantized
/// per-block constants with the given block/group sizes.
pub fn overhead_bits_per_weight(block: usize, group: usize) -> f64 {
    (8.0 + 16.0 / group as f64) / block as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn reconstruction_error_bounded() {
        let mut rng = Rng::new(1);
        // scales are positive absmax values, typically ~3σ of weights
        let scales: Vec<f32> = (0..1000).map(|_| rng.range_f32(0.01, 0.2)).collect();
        let dq = DoubleQuant::quantize(&scales, 256);
        let back = dq.dequantize();
        for (a, b) in scales.iter().zip(&back) {
            // E4M3 rel err <= 2^-4 plus f16 group-scale rounding
            assert!(((a - b) / a).abs() < 0.07, "{a} -> {b}");
        }
    }

    #[test]
    fn signed_values_supported() {
        // taus can be negative
        let taus = [-0.05f32, 0.03, -0.001, 0.0, 0.08];
        let dq = DoubleQuant::quantize(&taus, 256);
        let back = dq.dequantize();
        for (a, b) in taus.iter().zip(&back) {
            assert!((a - b).abs() < 0.01, "{a} -> {b}");
        }
        assert!(back[0] < 0.0);
    }

    #[test]
    fn group_boundaries() {
        let vals = vec![1.0f32; 300]; // 2 groups of 256
        let dq = DoubleQuant::quantize(&vals, 256);
        assert_eq!(dq.group_scales.len(), 2);
        assert_eq!(dq.len(), 300);
        assert!(dq.dequantize().iter().all(|&x| (x - 1.0).abs() < 1e-3));
    }

    #[test]
    fn zero_and_empty() {
        let dq = DoubleQuant::quantize(&[0.0, 0.0], 256);
        assert_eq!(dq.dequantize(), vec![0.0, 0.0]);
        let dq = DoubleQuant::quantize(&[], 256);
        assert!(dq.is_empty());
    }

    #[test]
    fn storage_accounting() {
        let dq = DoubleQuant::quantize(&vec![0.5f32; 512], 256);
        assert_eq!(dq.storage_bits(), 512 * 8 + 2 * 16);
        let ov = overhead_bits_per_weight(64, 256);
        assert!((ov - 0.1259765625).abs() < 1e-9);
    }

    #[test]
    fn parallel_groups_match_serial_oracle() {
        use crate::quant::fp8;
        use crate::util::f16;
        let mut rng = Rng::new(9);
        for n in [0usize, 1, 255, 256, 257, 300, 64 * 256 + 3] {
            let vals: Vec<f32> = (0..n).map(|_| rng.normal_ms(0.0, 0.1)).collect();
            let dq = DoubleQuant::quantize(&vals, 256);
            // inline serial oracle (the original algorithm)
            let mut codes = Vec::new();
            let mut gss = Vec::new();
            for chunk in vals.chunks(256) {
                let amax = chunk.iter().fold(0f32, |m, &x| m.max(x.abs()));
                let gs = if amax > 0.0 { amax / fp8::E4M3_MAX } else { 1.0 };
                let gs = f16::round_f16(gs);
                let gs =
                    if gs > 0.0 { gs } else { f16::round_f16(f32::MIN_POSITIVE * 1e30) };
                gss.push(gs);
                for &v in chunk {
                    codes.push(fp8::f32_to_e4m3(v / gs));
                }
            }
            assert_eq!(dq.codes, codes, "n={n}");
            assert_eq!(dq.group_scales, gss, "n={n}");
            // dequantize_into reuse matches dequantize
            let mut out = vec![7.0f32; 3];
            dq.dequantize_into(&mut out);
            assert_eq!(out, dq.dequantize(), "n={n}");
        }
    }

    #[test]
    fn wide_dynamic_range_groups() {
        // groups mix tiny and large magnitudes; large ones dominate the
        // group scale, small ones lose relative precision but stay finite
        let mut vals = vec![100.0f32; 10];
        vals.extend(vec![0.001f32; 10]);
        let dq = DoubleQuant::quantize(&vals, 256);
        let back = dq.dequantize();
        assert!(back.iter().all(|x| x.is_finite()));
        assert!((back[0] - 100.0).abs() / 100.0 < 0.07);
    }
}
