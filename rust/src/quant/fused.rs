//! Fused packed-domain dequantization — `packed bytes → f32` with no
//! unpacked `Vec<u8>` code intermediate (the hot serving/eval path).
//!
//! The reference pipeline ([`super::blockwise::unpack_codes_reference`]
//! followed by [`super::blockwise::dequantize_reference`]) walks every
//! element twice and materializes one byte per element in between. This
//! module fuses the two walks and removes the intermediate entirely:
//!
//! - **k ∈ {1, 2, 4, 8}** (k divides 8): a precomputed 256-entry
//!   byte → `[f32; 8/k]` lookup table maps each packed byte straight to
//!   its `8/k` codebook values — for NF4 one table hit emits two
//!   weights. Tables are scale-free (they hold raw codebook levels);
//!   the per-block `s`/`τ` are applied in the same `cb[c] * s + τ`
//!   expression the reference uses, so results are bit-identical.
//! - **k ∈ {3, 5, 6, 7}**: word-at-a-time unpacking through a `u64`
//!   bit accumulator (one shift/mask per code, one byte load per 8
//!   bits) feeding the same codebook lookup.
//!
//! Work is parallel across quantization blocks whenever a block spans
//! whole bytes (`block * k ≡ 0 (mod 8)` — always true for the paper's
//! block = 64); otherwise a serial bit-walk fallback handles the
//! unaligned geometry, still without the unpacked intermediate.
//!
//! Bit-identity with the reference path is property-tested for
//! k ∈ 1..=8 including partial last blocks and zero/constant blocks
//! (see tests below and `rust/tests/proptests.rs`).

use std::sync::OnceLock;

use super::nf;
use crate::util::threads;

/// Precomputed per-k lookup structure. For k dividing 8 it holds the
/// byte → values table; for other k just the codebook (word-at-a-time
/// path). Obtain via [`lut`] — instances are built once per process.
#[derive(Clone, Debug)]
pub struct DequantLut {
    k: u8,
    /// Codes per byte when k divides 8, else 0.
    cpb: usize,
    /// `256 * cpb` raw codebook values when `cpb > 0`, else empty.
    table: Vec<f32>,
    /// The plain NF-k codebook (always present; serial fallback and
    /// word-at-a-time path read it).
    codebook: Vec<f32>,
}

impl DequantLut {
    /// The raw NF-k codebook (2^k levels). The packed-domain kernels in
    /// [`crate::kernels`] read this to build per-block scaled LUTs
    /// (`cb[c] * s + τ`) without re-deriving the codebook per call.
    pub fn codebook(&self) -> &[f32] {
        &self.codebook
    }

    fn new(k: u8) -> DequantLut {
        assert!((1..=8).contains(&k));
        let codebook = nf::codebook(k);
        if 8 % (k as usize) == 0 {
            let cpb = 8 / k as usize;
            let mask = (1usize << k) - 1;
            let mut table = vec![0f32; 256 * cpb];
            for (b, row) in table.chunks_mut(cpb).enumerate() {
                for (j, v) in row.iter_mut().enumerate() {
                    *v = codebook[(b >> (j * k as usize)) & mask];
                }
            }
            DequantLut { k, cpb, table, codebook }
        } else {
            DequantLut { k, cpb: 0, table: Vec::new(), codebook }
        }
    }
}

/// Process-wide cached [`DequantLut`] for bit width `k` (1..=8).
pub fn lut(k: u8) -> &'static DequantLut {
    assert!((1..=8).contains(&k), "k={k} out of range 1..=8");
    static LUTS: OnceLock<Vec<DequantLut>> = OnceLock::new();
    let all = LUTS.get_or_init(|| (1..=8u8).map(DequantLut::new).collect());
    &all[(k - 1) as usize]
}

/// Dequantize `len` elements directly from `packed` k-bit codes:
/// `out[i] = cb[code_i] * scales[i / block] + taus[i / block]`.
///
/// `scales` (and `taus`, if given) must hold at least
/// `ceil(len / block)` entries. `out.len()` must equal `len`.
/// Bit-identical to unpack + reference dequantization.
pub fn dequantize_packed_into(
    packed: &[u8],
    k: u8,
    len: usize,
    block: usize,
    scales: &[f32],
    taus: Option<&[f32]>,
    out: &mut [f32],
) {
    assert!(block > 0);
    assert_eq!(out.len(), len, "output buffer length != element count");
    let n_blocks = len.div_ceil(block);
    assert!(scales.len() >= n_blocks, "need one scale per block");
    if let Some(t) = taus {
        assert!(t.len() >= n_blocks, "need one tau per block");
    }
    if len == 0 {
        return;
    }
    let l = lut(k);
    let kb = k as usize;
    telem_dequant_bytes().add(k, (len * kb).div_ceil(8) as u64);
    if (block * kb) % 8 != 0 {
        return dequantize_packed_serial(packed, k, len, block, scales, taus, out);
    }
    let bytes_per_block = block * kb / 8;
    threads::par_chunks_mut_with(out, block, 8, |bi, chunk| {
        let s = scales[bi];
        let tau = taus.map_or(0.0, |t| t[bi]);
        let bytes = &packed[bi * bytes_per_block..];
        if l.cpb > 0 {
            let cpb = l.cpb;
            let tab = &l.table;
            let full = chunk.len() / cpb;
            for j in 0..full {
                let base = bytes[j] as usize * cpb;
                for t in 0..cpb {
                    chunk[j * cpb + t] = tab[base + t] * s + tau;
                }
            }
            let rem = chunk.len() - full * cpb;
            if rem > 0 {
                // partial trailing byte (only the tensor's last block);
                // table rows depend on the low j*k bits only, so the
                // padding bits in the byte are harmless.
                let base = bytes[full] as usize * cpb;
                for t in 0..rem {
                    chunk[full * cpb + t] = tab[base + t] * s + tau;
                }
            }
        } else {
            // word-at-a-time path: k ∈ {3, 5, 6, 7}
            let cb = &l.codebook;
            walk_codes(bytes, k, chunk.len(), |j, code| {
                chunk[j] = cb[code] * s + tau;
            });
        }
    });
}

/// Cached telemetry handle for packed bytes consumed by LUT dequant
/// (no-op unless `IRQLORA_TELEMETRY=1`).
fn telem_dequant_bytes() -> &'static crate::telemetry::PerK {
    static C: OnceLock<crate::telemetry::PerK> = OnceLock::new();
    C.get_or_init(|| crate::telemetry::PerK::resolve("quant.dequant_bytes"))
}

/// Shared word-at-a-time k-bit walk through a `u64` bit accumulator:
/// calls `emit(i, code)` for each of the first `len` codes in
/// `packed`, reading from bit 0. Both the parallel per-block path and
/// the unaligned serial fallback run exactly this loop — and the
/// packed-domain GEMM kernels in [`crate::kernels`] iterate code runs
/// through it — so the subtle shift/mask/refill logic exists once.
#[inline]
pub fn walk_codes(packed: &[u8], k: u8, len: usize, mut emit: impl FnMut(usize, usize)) {
    let mask = (1u64 << k) - 1;
    let kw = k as u32;
    let mut acc = 0u64;
    let mut nbits = 0u32;
    let mut byte_idx = 0usize;
    for i in 0..len {
        while nbits < kw {
            acc |= (packed[byte_idx] as u64) << nbits;
            byte_idx += 1;
            nbits += 8;
        }
        emit(i, (acc & mask) as usize);
        acc >>= kw;
        nbits -= kw;
    }
}

/// [`walk_codes`] starting from an arbitrary element offset `start`
/// rather than bit 0: emits `emit(j, code)` for the codes of elements
/// `start .. start + len`, with `j` counted from 0. The first code may
/// begin mid-byte (`start * k % 8 != 0`); the partial leading byte is
/// pre-shifted into the accumulator so the main loop is unchanged.
/// This is what lets the packed GEMM kernels jump straight to a row's
/// codes without walking the whole tensor.
#[inline]
pub fn walk_codes_from(
    packed: &[u8],
    k: u8,
    start: usize,
    len: usize,
    mut emit: impl FnMut(usize, usize),
) {
    let skip_bits = start * k as usize;
    let mut byte_idx = skip_bits / 8;
    let rem = (skip_bits % 8) as u32;
    let mask = (1u64 << k) - 1;
    let kw = k as u32;
    let mut acc = 0u64;
    let mut nbits = 0u32;
    if rem != 0 {
        acc = (packed[byte_idx] as u64) >> rem;
        nbits = 8 - rem;
        byte_idx += 1;
    }
    for j in 0..len {
        while nbits < kw {
            acc |= (packed[byte_idx] as u64) << nbits;
            byte_idx += 1;
            nbits += 8;
        }
        emit(j, (acc & mask) as usize);
        acc >>= kw;
        nbits -= kw;
    }
}

/// Serial packed-domain fallback for geometries where blocks do not
/// align to byte boundaries (`block * k % 8 != 0`). Still avoids the
/// unpacked intermediate.
fn dequantize_packed_serial(
    packed: &[u8],
    k: u8,
    len: usize,
    block: usize,
    scales: &[f32],
    taus: Option<&[f32]>,
    out: &mut [f32],
) {
    let cb = &lut(k).codebook;
    walk_codes(packed, k, len, |i, code| {
        let bi = i / block;
        let tau = taus.map_or(0.0, |t| t[bi]);
        out[i] = cb[code] * scales[bi] + tau;
    });
}

/// Reusable scratch for [`super::QuantizedTensor::dequantize_into`]:
/// holds the dequantized per-block constants between calls so repeated
/// tensor dequantization allocates nothing.
#[derive(Debug, Default)]
pub struct DequantScratch {
    pub(crate) scales: Vec<f32>,
    pub(crate) taus: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::blockwise;
    use crate::util::Rng;

    fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
        assert_eq!(got.len(), want.len(), "{ctx}: length");
        for (i, (a, b)) in got.iter().zip(want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{ctx} i={i}: {a} vs {b}");
        }
    }

    #[test]
    fn fused_matches_reference_all_k() {
        let mut rng = Rng::new(60);
        for k in 1..=8u8 {
            for n in [1usize, 63, 64, 65, 100, 64 * 40 + 7] {
                let w = rng.normal_vec(n, 0.01, 0.05);
                let taus: Vec<f32> = (0..n.div_ceil(64))
                    .map(|_| rng.range_f32(-0.02, 0.02))
                    .collect();
                for taus_opt in [None, Some(taus.as_slice())] {
                    let q = blockwise::quantize_reference(&w, k, 64, taus_opt);
                    let packed = blockwise::pack_codes_reference(&q.codes, k);
                    let want = blockwise::dequantize_reference(&q);
                    let mut got = vec![0f32; n];
                    dequantize_packed_into(
                        &packed,
                        k,
                        n,
                        64,
                        &q.scales,
                        q.taus.as_deref(),
                        &mut got,
                    );
                    assert_bits_eq(&got, &want, &format!("k={k} n={n}"));
                }
            }
        }
    }

    #[test]
    fn fused_unaligned_block_serial_fallback() {
        // block sizes where block*k % 8 != 0 exercise the serial
        // bit-walk (e.g. block=7 k=4 -> 28 bits, block=10 k=3 -> 30).
        let mut rng = Rng::new(61);
        for (k, block) in [(4u8, 7usize), (3, 10), (5, 9), (2, 3), (7, 11)] {
            let n = block * 13 + block / 2; // partial last block too
            let w = rng.normal_vec(n, 0.0, 0.1);
            let q = blockwise::quantize_reference(&w, k, block, None);
            let packed = blockwise::pack_codes_reference(&q.codes, k);
            let want = blockwise::dequantize_reference(&q);
            let mut got = vec![0f32; n];
            dequantize_packed_into(&packed, k, n, block, &q.scales, None, &mut got);
            assert_bits_eq(&got, &want, &format!("k={k} block={block}"));
        }
    }

    #[test]
    fn zero_and_constant_blocks() {
        // zero block: scale forced to 1.0, codes hit cb near 0
        let w = vec![0.0f32; 64];
        let q = blockwise::quantize_reference(&w, 4, 64, None);
        let packed = blockwise::pack_codes_reference(&q.codes, 4);
        let mut got = vec![1f32; 64];
        dequantize_packed_into(&packed, 4, 64, 64, &q.scales, None, &mut got);
        assert!(got.iter().all(|&x| x == 0.0));

        // constant block with tau = the constant reconstructs exactly
        let w = vec![0.7f32; 64];
        let q = blockwise::quantize_reference(&w, 4, 64, Some(&[0.7]));
        let packed = blockwise::pack_codes_reference(&q.codes, 4);
        let want = blockwise::dequantize_reference(&q);
        let mut got = vec![0f32; 64];
        dequantize_packed_into(&packed, 4, 64, 64, &q.scales, q.taus.as_deref(), &mut got);
        assert_bits_eq(&got, &want, "constant block");
    }

    #[test]
    fn lut_table_contents_nf4() {
        let l = lut(4);
        assert_eq!(l.cpb, 2);
        assert_eq!(l.table.len(), 512);
        let cb = nf::codebook(4);
        // byte 0xA3 -> low nibble 0x3, high nibble 0xA
        assert_eq!(l.table[0xA3 * 2], cb[0x3]);
        assert_eq!(l.table[0xA3 * 2 + 1], cb[0xA]);
        assert_eq!(l.k, 4);
    }

    #[test]
    fn walk_codes_from_matches_full_walk_at_any_offset() {
        // every (k, start) combination must see exactly the codes the
        // from-bit-0 walk sees, including starts that land mid-byte
        let mut rng = Rng::new(62);
        for k in 1..=8u8 {
            let n = 97usize;
            let codes: Vec<u8> = (0..n).map(|_| (rng.next_u64() & ((1u64 << k) - 1)) as u8).collect();
            let packed = blockwise::pack_codes_reference(&codes, k);
            let mut all = vec![0usize; n];
            walk_codes(&packed, k, n, |i, c| all[i] = c);
            for start in [0usize, 1, 2, 3, 7, 8, 9, 31, 63, 64, 96] {
                let len = n - start;
                let mut got = vec![usize::MAX; len];
                walk_codes_from(&packed, k, start, len, |j, c| got[j] = c);
                assert_eq!(got, &all[start..], "k={k} start={start}");
            }
        }
    }

    #[test]
    fn word_at_a_time_k3_bit_order() {
        // hand-packed k=3 stream: codes 5, 2, 7 -> bits 101 010 111
        // little-endian within bytes: byte0 = 0b11_010_101 = 0xD5,
        // byte1 = 0b0000000_1 = 0x01
        let codes = vec![5u8, 2, 7];
        let packed = blockwise::pack_codes_reference(&codes, 3);
        assert_eq!(packed, vec![0xD5, 0x01]);
        let cb = nf::codebook(3);
        let mut got = vec![0f32; 3];
        dequantize_packed_into(&packed, 3, 3, 64, &[2.0], None, &mut got);
        assert_eq!(got, vec![cb[5] * 2.0, cb[2] * 2.0, cb[7] * 2.0]);
    }
}
