//! NormalFloat (NF-k) data types — paper §3.1 / Appendix B.2.
//!
//! NF-k places the 2^k quantization levels at (averaged) quantiles of
//! N(0,1), normalized to [-1, 1], so that a normally-distributed weight
//! tensor uses all levels equally often (information-theoretically
//! optimal for that prior). The exact level values the paper prints in
//! Tables 11–13 come from the QLoRA construction:
//!
//! - NF4 / NF3 (asymmetric, "extra value" on the positive side):
//!   positive levels = Φ⁻¹(linspace(δ, 0.5, 2^(k-1)+1))[:-1],
//!   negative levels = −Φ⁻¹(linspace(δ, 0.5, 2^(k-1)))[:-1],
//!   plus 0, all divided by the largest magnitude; δ = 0.9677083.
//! - NF2 (symmetric — the paper uses "symmetrical settings in NF2 to
//!   prevent excessive deviation of information"): ±Φ⁻¹(linspace(δ₂,
//!   0.5, 3))[:-1] normalized, with δ₂ = 0.9959171689 reproducing the
//!   published ±0.2525685 level.
//!
//! `codebook(k)` returns the authoritative values (asserted against the
//! paper's tables in unit tests); `construct_asymmetric` /
//! `construct_symmetric` expose the generative recipe.

use crate::util::mathfn::norm_ppf;

/// QLoRA offset δ for the asymmetric NF3/NF4 construction.
pub const NF_OFFSET: f64 = 0.9677083;
/// Offset reproducing the paper's symmetric NF2 levels (Table 11).
pub const NF2_OFFSET: f64 = 0.9959171689285915;

/// Paper Table 11 — NF2.
pub const NF2: [f32; 4] = [-1.0, -0.25256848335266113, 0.2525685131549835, 1.0];

/// Paper Table 12 — NF3.
pub const NF3: [f32; 8] = [
    -1.0,
    -0.4786292016506195,
    -0.217141792178154,
    0.0,
    0.16093020141124725,
    0.33791524171829224,
    0.5626170039176941,
    1.0,
];

/// Paper Table 13 — NF4.
pub const NF4: [f32; 16] = [
    -1.0,
    -0.6961928009986877,
    -0.5250730514526367,
    -0.39491748809814453,
    -0.28444138169288635,
    -0.18477343022823334,
    -0.09105003625154495,
    0.0,
    0.07958029955625534,
    0.16093020141124725,
    0.24611230194568634,
    0.33791524171829224,
    0.44070982933044434,
    0.5626170039176941,
    0.7229568362236023,
    1.0,
];

/// Generic asymmetric NF-k construction (QLoRA recipe, k >= 3).
pub fn construct_asymmetric(k: u8, offset: f64) -> Vec<f32> {
    assert!((2..=8).contains(&k), "NF-k supports k in 2..=8, got {k}");
    let n_pos = 1usize << (k - 1); // positive side levels (incl. max)
    let n_neg = (1usize << (k - 1)) - 1; // negative side levels
    let mut v: Vec<f64> = Vec::with_capacity(1 << k);
    // positive side: Φ⁻¹ over linspace(offset, 0.5, n_pos+1) minus endpoint 0.5
    for i in 0..n_pos {
        let p = offset + (0.5 - offset) * i as f64 / n_pos as f64;
        v.push(norm_ppf(p));
    }
    v.push(0.0);
    for i in 0..n_neg {
        let p = offset + (0.5 - offset) * i as f64 / n_neg as f64;
        v.push(-norm_ppf(p));
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let max = v.iter().fold(0.0f64, |m, x| m.max(x.abs()));
    v.into_iter().map(|x| (x / max) as f32).collect()
}

/// Symmetric NF-k construction (used for NF2).
pub fn construct_symmetric(k: u8, offset: f64) -> Vec<f32> {
    assert!((2..=8).contains(&k));
    let n_side = 1usize << (k - 1);
    let mut v: Vec<f64> = Vec::with_capacity(1 << k);
    for i in 0..n_side {
        let p = offset + (0.5 - offset) * i as f64 / n_side as f64;
        let q = norm_ppf(p);
        v.push(q);
        v.push(-q);
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let max = v.iter().fold(0.0f64, |m, x| m.max(x.abs()));
    v.into_iter().map(|x| (x / max) as f32).collect()
}

/// Degenerate 1-bit codebook (sign quantization). Not in the paper's
/// tables; defined so the packed-domain pipeline covers k ∈ 1..=8.
pub const NF1: [f32; 2] = [-1.0, 1.0];

/// Authoritative NF-k codebook (ascending). k in {2, 3, 4} returns the
/// paper's exact table values; k = 1 is the sign codebook; other k
/// uses the generic construction.
pub fn codebook(k: u8) -> Vec<f32> {
    match k {
        1 => NF1.to_vec(),
        2 => NF2.to_vec(),
        3 => NF3.to_vec(),
        4 => NF4.to_vec(),
        _ => construct_asymmetric(k, NF_OFFSET),
    }
}

/// Decision boundaries (midpoints) for nearest-level quantization.
pub fn boundaries(codebook: &[f32]) -> Vec<f32> {
    codebook
        .windows(2)
        .map(|w| 0.5 * (w[0] + w[1]))
        .collect()
}

/// Quantize one normalized value (expected in [-1, 1]) to a code index
/// by nearest level, via branchy binary search on the boundaries.
#[inline]
pub fn quantize_one(bounds: &[f32], x: f32) -> u8 {
    // partition_point: number of boundaries strictly below x.
    let mut lo = 0usize;
    let mut hi = bounds.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if x > bounds[mid] {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo as u8
}

/// Quantize a slice of normalized values into code indices.
pub fn quantize_codes(cb: &[f32], xs: &[f32], out: &mut Vec<u8>) {
    let bounds = boundaries(cb);
    out.clear();
    out.reserve(xs.len());
    for &x in xs {
        out.push(quantize_one(&bounds, x));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nf4_construction_matches_table13() {
        let got = construct_asymmetric(4, NF_OFFSET);
        assert_eq!(got.len(), 16);
        for (g, w) in got.iter().zip(NF4.iter()) {
            assert!((g - w).abs() < 1e-6, "got {g} want {w}");
        }
    }

    #[test]
    fn nf3_construction_matches_table12() {
        let got = construct_asymmetric(3, NF_OFFSET);
        for (g, w) in got.iter().zip(NF3.iter()) {
            assert!((g - w).abs() < 1e-6, "got {g} want {w}");
        }
    }

    #[test]
    fn nf2_symmetric_matches_table11() {
        let got = construct_symmetric(2, NF2_OFFSET);
        for (g, w) in got.iter().zip(NF2.iter()) {
            assert!((g - w).abs() < 1e-6, "got {g} want {w}");
        }
    }

    #[test]
    fn codebooks_sorted_and_bounded() {
        for k in 2..=6u8 {
            let cb = codebook(k);
            assert_eq!(cb.len(), 1 << k);
            assert_eq!(cb[0], -1.0);
            assert_eq!(*cb.last().unwrap(), 1.0);
            for w in cb.windows(2) {
                assert!(w[0] < w[1], "not strictly ascending at k={k}");
            }
        }
    }

    #[test]
    fn nf4_contains_zero() {
        assert!(NF4.contains(&0.0));
        assert!(NF3.contains(&0.0));
        // symmetric NF2 has no zero — by design
        assert!(!NF2.contains(&0.0));
    }

    #[test]
    fn nf1_sign_codebook() {
        let cb = codebook(1);
        assert_eq!(cb, vec![-1.0, 1.0]);
        let bounds = boundaries(&cb);
        assert_eq!(bounds, vec![0.0]);
        assert_eq!(quantize_one(&bounds, -0.3), 0);
        assert_eq!(quantize_one(&bounds, 0.3), 1);
    }

    #[test]
    fn quantize_one_nearest() {
        let cb = codebook(4);
        let bounds = boundaries(&cb);
        // exact levels map to themselves
        for (i, &v) in cb.iter().enumerate() {
            assert_eq!(quantize_one(&bounds, v) as usize, i);
        }
        // extremes clamp
        assert_eq!(quantize_one(&bounds, -5.0), 0);
        assert_eq!(quantize_one(&bounds, 5.0), 15);
        // midpoint-ish value picks the nearer level
        assert_eq!(quantize_one(&bounds, 0.05) as usize, 8); // 0.0796 closer than 0.0
    }

    #[test]
    fn quantize_codes_batch() {
        let cb = codebook(2);
        let mut out = Vec::new();
        quantize_codes(&cb, &[-1.0, -0.3, 0.3, 1.0], &mut out);
        assert_eq!(out, vec![0, 1, 2, 3]);
    }

    #[test]
    fn nearest_is_truly_nearest() {
        // property: quantize_one returns the index minimizing |cb[i]-x|
        let cb = codebook(4);
        let bounds = boundaries(&cb);
        let mut x = -1.2f32;
        while x <= 1.2 {
            let i = quantize_one(&bounds, x) as usize;
            let best = cb
                .iter()
                .map(|&c| (c - x).abs())
                .fold(f32::INFINITY, f32::min);
            assert!(
                (cb[i] - x).abs() <= best + 1e-6,
                "x={x} picked {} best dist {best}",
                cb[i]
            );
            x += 0.013;
        }
    }
}
