//! Group-wise affine integer quantization — the QA-LoRA-style baseline
//! (paper §4.3, Table 10), plus the ICQ variant that searches the zero
//! point by entropy maximization ("IR-QLoRA (QA-LoRA)" row).
//!
//! q = clamp(round(w/s) + z, 0, 2^k − 1), ŵ = (q − z)·s, with one
//! (s, z) pair per group. The vanilla min/max calibration uses
//! s = (max − min)/(2^k − 1), z = round(−min/s). The ICQ variant sweeps
//! z over an integer window around the min/max zero point and keeps the
//! entropy-maximizing one (the paper notes the calibration constant τ
//! can be merged into the integer zero point, so the gain is cost-free).

use crate::util::stats::entropy_bits;
use crate::util::threads::par_map;

/// Group-wise integer-quantized tensor.
#[derive(Clone, Debug)]
pub struct IntQuantized {
    pub k: u8,
    pub group: usize,
    pub len: usize,
    /// Unsigned codes in 0..2^k.
    pub codes: Vec<u8>,
    /// Scale per group.
    pub scales: Vec<f32>,
    /// Zero point per group (integer, stored as f32 for arithmetic).
    pub zeros: Vec<f32>,
}

impl IntQuantized {
    pub fn n_groups(&self) -> usize {
        self.len.div_ceil(self.group)
    }
}

fn quantize_group(chunk: &[f32], k: u8, s: f32, z: f32, out: &mut [u8]) {
    let qmax = ((1u32 << k) - 1) as f32;
    let inv = 1.0 / s;
    for (o, &x) in out.iter_mut().zip(chunk) {
        let q = (x * inv + z).round().clamp(0.0, qmax);
        *o = q as u8;
    }
}

/// Min/max affine calibration for one group.
fn minmax_params(chunk: &[f32], k: u8) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in chunk {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if !lo.is_finite() || hi <= lo {
        return (1.0, 0.0);
    }
    let qmax = ((1u32 << k) - 1) as f32;
    let s = (hi - lo) / qmax;
    let z = (-lo / s).round();
    (s, z)
}

/// Vanilla group-wise integer quantization (QA-LoRA baseline).
pub fn quantize(w: &[f32], k: u8, group: usize) -> IntQuantized {
    let n_groups = w.len().div_ceil(group);
    let mut codes = vec![0u8; w.len()];
    let mut scales = vec![0f32; n_groups];
    let mut zeros = vec![0f32; n_groups];
    for (gi, chunk) in w.chunks(group).enumerate() {
        let (s, z) = minmax_params(chunk, k);
        scales[gi] = s;
        zeros[gi] = z;
        quantize_group(chunk, k, s, z, &mut codes[gi * group..gi * group + chunk.len()]);
    }
    IntQuantized { k, group, len: w.len(), codes, scales, zeros }
}

/// ICQ variant: per group, search the zero point over an integer window
/// around the min/max zero point, maximizing code entropy (Table 10).
pub fn quantize_icq(w: &[f32], k: u8, group: usize, window: u32) -> IntQuantized {
    let n_groups = w.len().div_ceil(group);
    let per_group: Vec<(f32, f32)> = par_map(n_groups, |gi| {
        let lo = gi * group;
        let hi = (lo + group).min(w.len());
        let chunk = &w[lo..hi];
        let (s, z0) = minmax_params(chunk, k);
        let qmax = (1u32 << k) - 1;
        let mut counts = vec![0u32; 1 << k];
        let mut best = (s, z0);
        let mut best_h = f64::NEG_INFINITY;
        let lo_z = z0 - window as f32;
        let hi_z = z0 + window as f32;
        let mut z = lo_z;
        while z <= hi_z {
            counts.fill(0);
            let inv = 1.0 / s;
            for &x in chunk {
                let q = (x * inv + z).round().clamp(0.0, qmax as f32) as usize;
                counts[q] += 1;
            }
            let h = entropy_bits(&counts);
            if h > best_h {
                best_h = h;
                best = (s, z);
            }
            z += 1.0;
        }
        best
    });

    let mut codes = vec![0u8; w.len()];
    let mut scales = vec![0f32; n_groups];
    let mut zeros = vec![0f32; n_groups];
    for (gi, chunk) in w.chunks(group).enumerate() {
        let (s, z) = per_group[gi];
        scales[gi] = s;
        zeros[gi] = z;
        quantize_group(chunk, k, s, z, &mut codes[gi * group..gi * group + chunk.len()]);
    }
    IntQuantized { k, group, len: w.len(), codes, scales, zeros }
}

/// Dequantize: ŵ = (q − z)·s.
pub fn dequantize(q: &IntQuantized) -> Vec<f32> {
    let mut out = vec![0f32; q.len];
    for gi in 0..q.n_groups() {
        let lo = gi * q.group;
        let hi = (lo + q.group).min(q.len);
        let s = q.scales[gi];
        let z = q.zeros[gi];
        for i in lo..hi {
            out[i] = (q.codes[i] as f32 - z) * s;
        }
    }
    out
}

/// Mean per-group code entropy.
pub fn mean_entropy(q: &IntQuantized) -> f64 {
    let mut total = 0.0;
    let n = q.n_groups();
    for gi in 0..n {
        let lo = gi * q.group;
        let hi = (lo + q.group).min(q.len);
        let mut counts = vec![0u32; 1 << q.k];
        for &c in &q.codes[lo..hi] {
            counts[c as usize] += 1;
        }
        total += entropy_bits(&counts);
    }
    if n == 0 {
        0.0
    } else {
        total / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{stats, Rng};

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Rng::new(21);
        let w = rng.normal_vec(1024, 0.0, 0.02);
        let q = quantize(&w, 4, 64);
        let wh = dequantize(&q);
        // int4 min/max: step = range/15, max err = step/2
        let err = stats::max_abs_diff(&w, &wh);
        assert!(err < 0.02 * 7.0 / 15.0, "err {err}");
    }

    #[test]
    fn codes_in_range() {
        let mut rng = Rng::new(22);
        let w = rng.normal_vec(300, 0.0, 1.0);
        for k in [2u8, 3, 4] {
            let q = quantize(&w, k, 64);
            assert!(q.codes.iter().all(|&c| (c as u32) < (1 << k)));
        }
    }

    #[test]
    fn icq_zero_point_entropy_gain() {
        let mut rng = Rng::new(23);
        // heavily skewed data: min/max zero point underuses the grid
        let w: Vec<f32> = (0..64 * 40)
            .map(|_| {
                let x = rng.normal_ms(0.0, 0.02);
                if rng.chance(0.02) { x + 0.3 } else { x } // outliers
            })
            .collect();
        let q_v = quantize(&w, 4, 64);
        let q_i = quantize_icq(&w, 4, 64, 3);
        assert!(
            mean_entropy(&q_i) >= mean_entropy(&q_v),
            "{} < {}",
            mean_entropy(&q_i),
            mean_entropy(&q_v)
        );
    }

    #[test]
    fn constant_group_safe() {
        let w = vec![3.0f32; 64];
        let q = quantize(&w, 4, 64);
        let wh = dequantize(&q);
        assert!(wh.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn exact_grid_values_roundtrip() {
        // values already on the int grid come back exactly
        let s = 0.1f32;
        let w: Vec<f32> = (0..16).map(|i| (i as f32 - 8.0) * s).collect();
        let q = quantize(&w, 4, 16);
        let wh = dequantize(&q);
        for (a, b) in w.iter().zip(&wh) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}
