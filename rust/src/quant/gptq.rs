//! GPTQ baseline (Frantar et al., 2022) — the "QLoRA w/ GPTQ" rows.
//!
//! GPTQ quantizes a linear layer's weight rows one column at a time,
//! propagating the rounding error of each column into the not-yet-
//! quantized columns through the inverse Hessian of the layer inputs
//! (H = 2XᵀX + λI). This is a faithful (unblocked) implementation —
//! adequate at our layer widths (h ≤ 1024) where the O(h³) Cholesky is
//! cheap — with the same group-wise integer grid the QA-LoRA rows use.

use crate::util::Tensor;

use super::integer;

/// Symmetric positive-definite Cholesky factorization: A = L·Lᵀ.
/// Returns the lower factor row-major, or None if not PD.
pub fn cholesky(a: &[f64], n: usize) -> Option<Vec<f64>> {
    let mut l = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Inverse of an SPD matrix via Cholesky (A⁻¹ = L⁻ᵀ·L⁻¹).
pub fn spd_inverse(a: &[f64], n: usize) -> Option<Vec<f64>> {
    let l = cholesky(a, n)?;
    // invert L (lower triangular) by forward substitution
    let mut linv = vec![0f64; n * n];
    for i in 0..n {
        linv[i * n + i] = 1.0 / l[i * n + i];
        for j in 0..i {
            let mut sum = 0.0;
            for k in j..i {
                sum -= l[i * n + k] * linv[k * n + j];
            }
            linv[i * n + j] = sum / l[i * n + i];
        }
    }
    // A^-1 = L^-T * L^-1
    let mut inv = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut sum = 0.0;
            // (L^-T)[i,k] = linv[k,i]; nonzero for k >= i
            for k in i.max(j)..n {
                sum += linv[k * n + i] * linv[k * n + j];
            }
            inv[i * n + j] = sum;
        }
    }
    Some(inv)
}

/// GPTQ configuration.
#[derive(Clone, Copy, Debug)]
pub struct GptqConfig {
    pub k: u8,
    /// Integer quantization group size along the input dimension.
    pub group: usize,
    /// Hessian damping fraction (of the mean diagonal).
    pub damp: f64,
}

impl Default for GptqConfig {
    fn default() -> Self {
        GptqConfig { k: 4, group: 64, damp: 0.01 }
    }
}

/// Quantize a linear layer weight `w` (o×h, row-major; rows are output
/// neurons) given calibration inputs `x` (n×h). Returns the
/// dequantized weight (o×h) and the total squared compensation error.
pub fn gptq_quantize(w: &Tensor, x: &Tensor, cfg: &GptqConfig) -> (Tensor, f64) {
    assert_eq!(w.rank(), 2);
    assert_eq!(x.rank(), 2);
    let (o, h) = (w.shape()[0], w.shape()[1]);
    assert_eq!(x.shape()[1], h, "calibration width mismatch");
    let n = x.shape()[0];

    // H = 2 XᵀX + λI  (f64 accumulation)
    let mut hmat = vec![0f64; h * h];
    for s in 0..n {
        let row = x.row(s);
        for i in 0..h {
            let xi = row[i] as f64;
            if xi == 0.0 {
                continue;
            }
            for j in i..h {
                hmat[i * h + j] += 2.0 * xi * row[j] as f64;
            }
        }
    }
    for i in 0..h {
        for j in 0..i {
            hmat[i * h + j] = hmat[j * h + i];
        }
    }
    let mean_diag = (0..h).map(|i| hmat[i * h + i]).sum::<f64>() / h as f64;
    let damp = (cfg.damp * mean_diag).max(1e-8);
    for i in 0..h {
        hmat[i * h + i] += damp;
    }

    let hinv = spd_inverse(&hmat, h).expect("damped Hessian must be SPD");

    // Per-group integer grids calibrated on the original weights.
    let qmax = ((1u32 << cfg.k) - 1) as f32;
    let n_groups = h.div_ceil(cfg.group);
    // (scale, zero) per (row, group)
    let mut grids = vec![(1.0f32, 0.0f32); o * n_groups];
    for r in 0..o {
        let row = w.row(r);
        for g in 0..n_groups {
            let lo = g * cfg.group;
            let hi = (lo + cfg.group).min(h);
            let chunk = &row[lo..hi];
            let mn = chunk.iter().cloned().fold(f32::INFINITY, f32::min);
            let mx = chunk.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            if mx > mn {
                let s = (mx - mn) / qmax;
                grids[r * n_groups + g] = (s, (-mn / s).round());
            }
        }
    }

    // Column-wise greedy rounding with error propagation.
    let mut wk: Vec<f32> = w.data().to_vec(); // working copy, mutated
    let mut out = vec![0f32; o * h];
    let mut total_err = 0.0f64;
    for j in 0..h {
        let d = hinv[j * h + j];
        let g = j / cfg.group;
        for r in 0..o {
            let (s, z) = grids[r * n_groups + g];
            let wj = wk[r * h + j];
            let q = ((wj / s + z).round()).clamp(0.0, qmax);
            let wq = (q - z) * s;
            out[r * h + j] = wq;
            let err = (wj - wq) as f64 / d;
            total_err += err * err * d;
            // propagate into remaining columns of this row
            let roww = &mut wk[r * h..(r + 1) * h];
            for jj in (j + 1)..h {
                roww[jj] -= (err * hinv[j * h + jj]) as f32;
            }
        }
    }

    (Tensor::new(&[o, h], out), total_err)
}

/// Round-to-nearest baseline on the same grid, for comparison: returns
/// the dequantized weight.
pub fn rtn_quantize(w: &Tensor, k: u8, group: usize) -> Tensor {
    assert_eq!(w.rank(), 2);
    let (o, h) = (w.shape()[0], w.shape()[1]);
    let mut out = vec![0f32; o * h];
    for r in 0..o {
        let q = integer::quantize(w.row(r), k, group);
        out[r * h..(r + 1) * h].copy_from_slice(&integer::dequantize(&q));
    }
    Tensor::new(&[o, h], out)
}

/// Layer output MSE of a quantized weight vs the original, under
/// calibration inputs — the quantity GPTQ minimizes.
pub fn layer_mse(w: &Tensor, wq: &Tensor, x: &Tensor) -> f64 {
    let y = x.matmul(&w.transpose());
    let yq = x.matmul(&wq.transpose());
    crate::util::stats::mse(y.data(), yq.data())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_layer(rng: &mut Rng, o: usize, h: usize, n: usize) -> (Tensor, Tensor) {
        let w = Tensor::new(&[o, h], rng.normal_vec(o * h, 0.0, 0.05));
        // correlated inputs make the Hessian non-trivial
        let base = rng.normal_vec(n * h, 0.0, 1.0);
        let mut xv = base.clone();
        for s in 0..n {
            for j in 1..h {
                xv[s * h + j] = 0.6 * xv[s * h + j - 1] + 0.8 * base[s * h + j];
            }
        }
        (w, Tensor::new(&[n, h], xv))
    }

    #[test]
    fn cholesky_identity() {
        let n = 4;
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let l = cholesky(&a, n).unwrap();
        assert_eq!(l, a);
        let inv = spd_inverse(&a, n).unwrap();
        assert_eq!(inv, a);
    }

    #[test]
    fn spd_inverse_correct() {
        // A = M Mᵀ + I is SPD; check A·A⁻¹ ≈ I
        let mut rng = Rng::new(31);
        let n = 8;
        let m: Vec<f64> = (0..n * n).map(|_| rng.normal() as f64).collect();
        let mut a = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    a[i * n + j] += m[i * n + k] * m[j * n + k];
                }
            }
            a[i * n + i] += 1.0;
        }
        let inv = spd_inverse(&a, n).unwrap();
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += a[i * n + k] * inv[k * n + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-8, "({i},{j}) = {s}");
            }
        }
    }

    #[test]
    fn cholesky_rejects_non_pd() {
        let a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky(&a, 2).is_none());
    }

    #[test]
    fn gptq_beats_rtn_on_layer_mse() {
        let mut rng = Rng::new(32);
        let (w, x) = random_layer(&mut rng, 16, 64, 128);
        let cfg = GptqConfig { k: 3, ..Default::default() };
        let (wq, _) = gptq_quantize(&w, &x, &cfg);
        let wr = rtn_quantize(&w, cfg.k, cfg.group);
        let e_gptq = layer_mse(&w, &wq, &x);
        let e_rtn = layer_mse(&w, &wr, &x);
        assert!(
            e_gptq <= e_rtn * 1.02,
            "gptq {e_gptq} should not lose to rtn {e_rtn}"
        );
    }

    #[test]
    fn gptq_output_finite() {
        let mut rng = Rng::new(33);
        let (w, x) = random_layer(&mut rng, 8, 32, 64);
        let (wq, err) = gptq_quantize(&w, &x, &GptqConfig::default());
        assert!(wq.data().iter().all(|v| v.is_finite()));
        assert!(err.is_finite() && err >= 0.0);
    }
}
