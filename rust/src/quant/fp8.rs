//! Emulated FP8 E4M3 codec.
//!
//! Double quantization stores first-level scale codes as FP8
//! (`s₁^FP8`, `τ₁^FP8` in the paper). This image has no hardware FP8,
//! so we emulate the OCP E4M3 format exactly: 1 sign, 4 exponent
//! (bias 7), 3 mantissa bits; max finite value 448; no infinities
//! (S.1111.111 is NaN).

/// Largest finite E4M3 magnitude.
pub const E4M3_MAX: f32 = 448.0;
/// Smallest positive normal.
pub const E4M3_MIN_NORMAL: f32 = 0.015625; // 2^-6
/// Smallest positive subnormal.
pub const E4M3_MIN_SUBNORMAL: f32 = 0.001953125; // 2^-9

/// Encode f32 -> E4M3 bits (round-to-nearest-even, saturating).
pub fn f32_to_e4m3(x: f32) -> u8 {
    if x.is_nan() {
        return 0x7F;
    }
    let sign = if x.is_sign_negative() { 0x80u8 } else { 0 };
    let a = x.abs();
    if a == 0.0 {
        return sign;
    }
    if a >= E4M3_MAX {
        return sign | 0x7E; // saturate to ±448 (E4M3 has no inf)
    }
    // Decompose |x| = m * 2^e with m in [1, 2).
    let bits = a.to_bits();
    let mut e = ((bits >> 23) & 0xFF) as i32 - 127;
    let frac = bits & 0x7F_FFFF;

    if e >= -6 {
        // Normal E4M3: 3 mantissa bits.
        let mut m = frac >> 20;
        let rem = frac & 0xF_FFFF;
        if rem > 0x8_0000 || (rem == 0x8_0000 && (m & 1) == 1) {
            m += 1;
        }
        if m == 8 {
            m = 0;
            e += 1;
        }
        if e > 8 {
            return sign | 0x7E; // overflow after rounding
        }
        sign | (((e + 7) as u8) << 3) | m as u8
    } else {
        // Subnormal: value = m/8 * 2^-6.
        let scaled = a / E4M3_MIN_SUBNORMAL; // in units of 2^-9
        let mut m = scaled.floor() as u32;
        let rem = scaled - m as f32;
        if rem > 0.5 || (rem == 0.5 && (m & 1) == 1) {
            m += 1;
        }
        if m >= 8 {
            return sign | (1 << 3); // rounds up to min normal
        }
        sign | m as u8
    }
}

/// Decode E4M3 bits -> f32 (exact).
pub fn e4m3_to_f32(b: u8) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let e = ((b >> 3) & 0xF) as i32;
    let m = (b & 0x7) as f32;
    if e == 0xF && (b & 0x7) == 0x7 {
        return f32::NAN;
    }
    if e == 0 {
        sign * m * E4M3_MIN_SUBNORMAL
    } else {
        sign * (1.0 + m / 8.0) * (2.0f32).powi(e - 7)
    }
}

/// Quantize-dequantize through E4M3.
#[inline]
pub fn round_e4m3(x: f32) -> f32 {
    e4m3_to_f32(f32_to_e4m3(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_representables_roundtrip() {
        for &x in &[0.0f32, 1.0, -1.0, 0.5, 448.0, -448.0, 0.015625, 1.75, 240.0] {
            assert_eq!(round_e4m3(x), x, "{x}");
        }
    }

    #[test]
    fn saturation() {
        assert_eq!(round_e4m3(1e9), 448.0);
        assert_eq!(round_e4m3(-1e9), -448.0);
        assert_eq!(round_e4m3(460.0), 448.0);
    }

    #[test]
    fn nan_encoding() {
        assert!(round_e4m3(f32::NAN).is_nan());
        assert_eq!(f32_to_e4m3(f32::NAN), 0x7F);
    }

    #[test]
    fn subnormals() {
        assert_eq!(round_e4m3(E4M3_MIN_SUBNORMAL), E4M3_MIN_SUBNORMAL);
        assert_eq!(round_e4m3(E4M3_MIN_SUBNORMAL * 3.0), E4M3_MIN_SUBNORMAL * 3.0);
        assert_eq!(round_e4m3(1e-5), 0.0);
    }

    #[test]
    fn relative_error_bound() {
        // 3 mantissa bits -> relative error <= 2^-4 for normals.
        let mut x = 0.02f32;
        while x < 440.0 {
            let y = round_e4m3(x);
            assert!(((x - y) / x).abs() <= 1.0 / 16.0 + 1e-6, "x={x} y={y}");
            x *= 1.171;
        }
    }

    #[test]
    fn all_256_codes_decode_finite_or_nan() {
        let mut distinct = std::collections::HashSet::new();
        for b in 0..=255u8 {
            let v = e4m3_to_f32(b);
            if v.is_nan() {
                continue;
            }
            assert!(v.abs() <= 448.0);
            distinct.insert(v.to_bits());
        }
        // 254 non-NaN codes; +0.0 and -0.0 share a value magnitude-wise
        assert!(distinct.len() >= 253);
    }

    #[test]
    fn encode_decode_monotone() {
        // decoding should be monotone in the positive code range
        let mut prev = f32::NEG_INFINITY;
        for b in 0..0x7Fu8 {
            let v = e4m3_to_f32(b);
            assert!(v > prev, "code {b:#x} not monotone");
            prev = v;
        }
    }
}
