//! Blockwise absmax NF-k quantization (paper Eq. 1) and bit-packing.
//!
//! Weights are split into contiguous blocks (default 64 elements, the
//! paper's setting); each block is normalized by its absmax and each
//! element mapped to the nearest NF-k level. Codes are bit-packed
//! (1..=8 bits per element) for storage.
//!
//! Two implementations coexist:
//!
//! - **Fast path** (the public [`quantize`] / [`dequantize`] /
//!   [`pack_codes`] / [`unpack_codes`] and their allocation-free
//!   `*_into` variants): parallel over blocks via
//!   [`crate::util::threads`], with scratch-buffer reuse across calls.
//!   Packing parallelizes on byte-aligned spans (any 8 consecutive
//!   k-bit codes occupy exactly k whole bytes, so chunks of a multiple
//!   of 8 elements own disjoint output bytes). For dequantization
//!   *directly from packed bytes* — no unpacked `u8` intermediate at
//!   all — see [`super::fused`].
//! - **Reference path** ([`quantize_reference`],
//!   [`dequantize_reference`], [`pack_codes_reference`],
//!   [`unpack_codes_reference`]): the original serial loops, kept as
//!   the oracle the fast paths are property-tested bit-identical
//!   against (`rust/tests/proptests.rs` and the tests below).
//!
//! Every fast path computes exactly the same f32 expressions in the
//! same per-element order as the reference, so equality is exact
//! (bit-identical), not approximate.

use super::nf;
use crate::util::threads;

/// Paper-default quantization block size.
pub const DEFAULT_BLOCK: usize = 64;

/// Elements per parallel packing task. Must be a multiple of 8 so each
/// task's k-bit codes cover whole output bytes (8 codes ↔ k bytes).
const PACK_CHUNK_ELEMS: usize = 8192;

/// Blocks per task when computing per-block scales in parallel.
const SCALE_CHUNK_BLOCKS: usize = 256;

/// A blockwise-quantized tensor (codes + one scale per block, plus an
/// optional per-block shift τ — ICQ fills it, vanilla leaves it None).
#[derive(Clone, Debug)]
pub struct QuantizedBlocks {
    /// Bit width k.
    pub k: u8,
    /// Block size in elements.
    pub block: usize,
    /// Original element count (last block may be partial).
    pub len: usize,
    /// Unpacked code per element (values in 0..2^k).
    pub codes: Vec<u8>,
    /// absmax scale per block.
    pub scales: Vec<f32>,
    /// Optional calibration constant per block (ICQ).
    pub taus: Option<Vec<f32>>,
}

impl QuantizedBlocks {
    /// An empty container to be filled by [`quantize_into`]; reusing
    /// one across calls makes repeated quantization allocation-free.
    pub fn scratch() -> QuantizedBlocks {
        QuantizedBlocks { k: 0, block: 1, len: 0, codes: Vec::new(), scales: Vec::new(), taus: None }
    }

    pub fn n_blocks(&self) -> usize {
        self.len.div_ceil(self.block)
    }

    /// Storage in bits: packed codes + one f32-equivalent scale slot per
    /// block (double quantization shrinks the scale term further; see
    /// `double_quant::storage_bits`).
    pub fn packed_code_bits(&self) -> usize {
        self.len * self.k as usize
    }
}

/// Quantize `w` blockwise with the NF-k codebook. `taus[i]` (if given)
/// is subtracted from block i before normalization (ICQ, Eq. 8).
/// Parallel over blocks; allocates a fresh [`QuantizedBlocks`] — use
/// [`quantize_into`] to reuse buffers across calls.
pub fn quantize(w: &[f32], k: u8, block: usize, taus: Option<&[f32]>) -> QuantizedBlocks {
    let mut q = QuantizedBlocks::scratch();
    quantize_into(w, k, block, taus, &mut q);
    q
}

/// Allocation-free quantization into a reused [`QuantizedBlocks`]:
/// `q`'s buffers are cleared and refilled (growing only when the input
/// outgrows them). Bit-identical to [`quantize_reference`].
pub fn quantize_into(
    w: &[f32],
    k: u8,
    block: usize,
    taus: Option<&[f32]>,
    q: &mut QuantizedBlocks,
) {
    assert!(block > 0);
    let n_blocks = w.len().div_ceil(block);
    if let Some(t) = taus {
        assert_eq!(t.len(), n_blocks, "one tau per block");
    }
    let cb = nf::codebook(k);
    let bounds = nf::boundaries(&cb);

    q.k = k;
    q.block = block;
    q.len = w.len();
    q.codes.clear();
    q.codes.resize(w.len(), 0);
    q.scales.clear();
    q.scales.resize(n_blocks, 0.0);
    match taus {
        Some(t) => match &mut q.taus {
            Some(v) => {
                v.clear();
                v.extend_from_slice(t);
            }
            None => q.taus = Some(t.to_vec()),
        },
        None => q.taus = None,
    }
    telem_blocks().add(k, n_blocks as u64);

    // Pass 1: per-block absmax scales, parallel over scale chunks.
    threads::par_chunks_mut_with(&mut q.scales, SCALE_CHUNK_BLOCKS, 2, |ci, sc| {
        for (j, s) in sc.iter_mut().enumerate() {
            let bi = ci * SCALE_CHUNK_BLOCKS + j;
            let lo = bi * block;
            let hi = (lo + block).min(w.len());
            let tau = taus.map_or(0.0, |t| t[bi]);
            let mut amax = 0f32;
            for &x in &w[lo..hi] {
                amax = amax.max((x - tau).abs());
            }
            *s = if amax > 0.0 { amax } else { 1.0 };
        }
    });

    // Pass 2: codes, parallel over blocks (disjoint code chunks).
    let scales = &q.scales;
    threads::par_chunks_mut_with(&mut q.codes, block, 2, |bi, out| {
        let lo = bi * block;
        let chunk = &w[lo..lo + out.len()];
        let tau = taus.map_or(0.0, |t| t[bi]);
        let inv = 1.0 / scales[bi];
        for (o, &x) in out.iter_mut().zip(chunk) {
            *o = nf::quantize_one(&bounds, (x - tau) * inv);
        }
    });
}

/// Reference implementation of [`quantize`]: the original serial loop,
/// kept as the property-test oracle for the parallel path.
pub fn quantize_reference(
    w: &[f32],
    k: u8,
    block: usize,
    taus: Option<&[f32]>,
) -> QuantizedBlocks {
    assert!(block > 0);
    let cb = nf::codebook(k);
    let bounds = nf::boundaries(&cb);
    let n_blocks = w.len().div_ceil(block);
    if let Some(t) = taus {
        assert_eq!(t.len(), n_blocks, "one tau per block");
    }
    let mut codes = vec![0u8; w.len()];
    let mut scales = vec![0f32; n_blocks];

    for (bi, chunk) in w.chunks(block).enumerate() {
        let tau = taus.map_or(0.0, |t| t[bi]);
        let mut amax = 0f32;
        for &x in chunk {
            amax = amax.max((x - tau).abs());
        }
        let s = if amax > 0.0 { amax } else { 1.0 };
        scales[bi] = s;
        let out = &mut codes[bi * block..bi * block + chunk.len()];
        let inv = 1.0 / s;
        for (o, &x) in out.iter_mut().zip(chunk) {
            *o = nf::quantize_one(&bounds, (x - tau) * inv);
        }
    }

    QuantizedBlocks {
        k,
        block,
        len: w.len(),
        codes,
        scales,
        taus: taus.map(|t| t.to_vec()),
    }
}

/// Dequantize back to f32: `ŵ = cb[code] * s + τ` (Eq. 10 without the
/// double-quantization of s/τ — see `double_quant` for that layer).
/// Parallel over blocks; use [`dequantize_into`] to reuse the output
/// buffer across calls.
pub fn dequantize(q: &QuantizedBlocks) -> Vec<f32> {
    let mut out = vec![0f32; q.len];
    dequantize_into(q, &mut out);
    out
}

/// Allocation-free dequantization into a caller-provided buffer
/// (`out.len()` must equal `q.len`). Parallel over blocks,
/// bit-identical to [`dequantize_reference`].
pub fn dequantize_into(q: &QuantizedBlocks, out: &mut [f32]) {
    assert_eq!(out.len(), q.len, "output buffer length != element count");
    let cb = nf::codebook(q.k);
    let codes = &q.codes;
    let scales = &q.scales;
    let taus = q.taus.as_deref();
    let block = q.block;
    threads::par_chunks_mut_with(out, block, 8, |bi, chunk| {
        let lo = bi * block;
        let s = scales[bi];
        let tau = taus.map_or(0.0, |t| t[bi]);
        for (j, o) in chunk.iter_mut().enumerate() {
            *o = cb[codes[lo + j] as usize] * s + tau;
        }
    });
}

/// Reference implementation of [`dequantize`]: the original serial
/// loop, kept as the property-test oracle.
pub fn dequantize_reference(q: &QuantizedBlocks) -> Vec<f32> {
    let cb = nf::codebook(q.k);
    let mut out = vec![0f32; q.len];
    for bi in 0..q.n_blocks() {
        let lo = bi * q.block;
        let hi = (lo + q.block).min(q.len);
        let s = q.scales[bi];
        let tau = q.taus.as_ref().map_or(0.0, |t| t[bi]);
        for i in lo..hi {
            out[i] = cb[q.codes[i] as usize] * s + tau;
        }
    }
    out
}

/// Serial bit-packer over a local span. `out` must be zeroed and hold
/// exactly `ceil(codes.len() * k / 8)` bytes; bit 0 of `out[0]` is the
/// low bit of `codes[0]` (little-endian bit order within bytes).
fn pack_slice(codes: &[u8], k: u8, out: &mut [u8]) {
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!((c as u16) < (1u16 << k), "code {c} out of range for k={k}");
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + k as usize > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += k as usize;
    }
}

/// Serial bit-unpacker over a local span: fills `out` with
/// `out.len()` k-bit codes read from `packed` starting at bit 0.
fn unpack_slice(packed: &[u8], k: u8, out: &mut [u8]) {
    let mask = ((1u16 << k) - 1) as u8;
    let mut bitpos = 0usize;
    for o in out.iter_mut() {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut v = packed[byte] >> off;
        if off + k as usize > 8 {
            v |= packed[byte + 1] << (8 - off);
        }
        *o = v & mask;
        bitpos += k as usize;
    }
}

/// Pack k-bit codes into bytes (little-endian bit order within bytes).
/// Parallel over byte-aligned spans of [`PACK_CHUNK_ELEMS`] codes.
pub fn pack_codes(codes: &[u8], k: u8) -> Vec<u8> {
    let mut out = Vec::new();
    pack_codes_into(codes, k, &mut out);
    out
}

/// Allocation-free variant of [`pack_codes`] writing into a reused
/// buffer (cleared and refilled).
pub fn pack_codes_into(codes: &[u8], k: u8, out: &mut Vec<u8>) {
    assert!((1..=8).contains(&k));
    let total_bits = codes.len() * k as usize;
    out.clear();
    out.resize(total_bits.div_ceil(8), 0);
    telem_packed_bytes().add(k, out.len() as u64);
    let bytes_per_chunk = PACK_CHUNK_ELEMS * k as usize / 8;
    threads::par_chunks_mut_with(out, bytes_per_chunk, 2, |ci, bytes| {
        let start = ci * PACK_CHUNK_ELEMS;
        let end = (start + PACK_CHUNK_ELEMS).min(codes.len());
        pack_slice(&codes[start..end], k, bytes);
    });
}

/// Cached telemetry handles for the hot quantize/pack paths (no-ops
/// unless `IRQLORA_TELEMETRY=1`): resolved once, so recording costs
/// one `OnceLock` load plus the handle's own branch per call.
fn telem_blocks() -> &'static crate::telemetry::PerK {
    static C: std::sync::OnceLock<crate::telemetry::PerK> = std::sync::OnceLock::new();
    C.get_or_init(|| crate::telemetry::PerK::resolve("quant.blocks_quantized"))
}

fn telem_packed_bytes() -> &'static crate::telemetry::PerK {
    static C: std::sync::OnceLock<crate::telemetry::PerK> = std::sync::OnceLock::new();
    C.get_or_init(|| crate::telemetry::PerK::resolve("quant.packed_bytes"))
}

/// Reference implementation of [`pack_codes`] (original serial loop).
pub fn pack_codes_reference(codes: &[u8], k: u8) -> Vec<u8> {
    assert!((1..=8).contains(&k));
    let total_bits = codes.len() * k as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    pack_slice(codes, k, &mut out);
    out
}

/// Unpack k-bit codes from bytes. Parallel over byte-aligned spans.
pub fn unpack_codes(packed: &[u8], k: u8, n: usize) -> Vec<u8> {
    let mut out = Vec::new();
    unpack_codes_into(packed, k, n, &mut out);
    out
}

/// Allocation-free variant of [`unpack_codes`] writing into a reused
/// buffer (cleared and refilled).
pub fn unpack_codes_into(packed: &[u8], k: u8, n: usize, out: &mut Vec<u8>) {
    assert!((1..=8).contains(&k));
    out.clear();
    out.resize(n, 0);
    let byte_per_chunk = PACK_CHUNK_ELEMS * k as usize / 8;
    threads::par_chunks_mut_with(out, PACK_CHUNK_ELEMS, 2, |ci, chunk| {
        unpack_slice(&packed[ci * byte_per_chunk..], k, chunk);
    });
}

/// Reference implementation of [`unpack_codes`] (original serial loop).
pub fn unpack_codes_reference(packed: &[u8], k: u8, n: usize) -> Vec<u8> {
    assert!((1..=8).contains(&k));
    let mut out = vec![0u8; n];
    unpack_slice(packed, k, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{stats, Rng};

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Rng::new(1);
        let w = rng.normal_vec(1024, 0.0, 0.02);
        let q = quantize(&w, 4, 64, None);
        let wh = dequantize(&q);
        // worst-case NF4 step near 0 is ~0.08 of absmax; blocks of
        // normals have absmax ~3σ, so error per element << σ.
        let err = stats::max_abs_diff(&w, &wh);
        assert!(err < 0.02 * 3.5 * 0.15, "err {err}");
        // and strictly positive — quantization is lossy
        assert!(stats::mse(&w, &wh) > 0.0);
    }

    #[test]
    fn exact_levels_roundtrip_exactly() {
        // a block consisting of exact scaled codebook values survives
        let cb = nf::codebook(4);
        let s = 0.05f32;
        let w: Vec<f32> = cb.iter().map(|&c| c * s).collect();
        let q = quantize(&w, 4, 16, None);
        let wh = dequantize(&q);
        for (a, b) in w.iter().zip(&wh) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn partial_last_block() {
        let mut rng = Rng::new(2);
        let w = rng.normal_vec(100, 0.0, 1.0); // 64 + 36
        let q = quantize(&w, 4, 64, None);
        assert_eq!(q.n_blocks(), 2);
        assert_eq!(dequantize(&q).len(), 100);
    }

    #[test]
    fn zero_block_safe() {
        let w = vec![0.0f32; 64];
        let q = quantize(&w, 4, 64, None);
        let wh = dequantize(&q);
        assert!(wh.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn tau_shift_applied() {
        // constant block: with tau = the constant, everything quantizes
        // to (near) zero code and reconstructs exactly.
        let w = vec![0.7f32; 64];
        let q = quantize(&w, 4, 64, Some(&[0.7]));
        let wh = dequantize(&q);
        for &x in &wh {
            assert!((x - 0.7).abs() < 1e-6, "{x}");
        }
    }

    #[test]
    fn pack_unpack_identity_all_k() {
        let mut rng = Rng::new(3);
        for k in 1..=8u8 {
            for n in [0usize, 1, 7, 64, 65, 1000] {
                let codes: Vec<u8> =
                    (0..n).map(|_| (rng.below(1 << k)) as u8).collect();
                let packed = pack_codes(&codes, k);
                assert_eq!(packed.len(), (n * k as usize).div_ceil(8));
                let back = unpack_codes(&packed, k, n);
                assert_eq!(back, codes, "k={k} n={n}");
            }
        }
    }

    #[test]
    fn packed_size_4bit() {
        let codes = vec![0xFu8; 128];
        assert_eq!(pack_codes(&codes, 4).len(), 64);
    }

    #[test]
    fn bitwidths_2_and_3() {
        let mut rng = Rng::new(4);
        let w = rng.normal_vec(256, 0.0, 1.0);
        for k in [2u8, 3] {
            let q = quantize(&w, k, 64, None);
            assert!(q.codes.iter().all(|&c| c < (1 << k)));
            let wh = dequantize(&q);
            // lower bit-width => higher error than NF4
            let e_k = stats::mse(&w, &wh);
            let e_4 = stats::mse(&w, &dequantize(&quantize(&w, 4, 64, None)));
            assert!(e_k > e_4, "k={k}: {e_k} vs {e_4}");
        }
    }

    #[test]
    fn parallel_quantize_matches_reference_bitwise() {
        let mut rng = Rng::new(40);
        for k in 1..=8u8 {
            // sizes exercising empty, single, partial-last-block, many
            for n in [0usize, 1, 63, 64, 65, 100, 64 * 300 + 17] {
                let w = rng.normal_vec(n, 0.01, 0.05);
                let taus: Vec<f32> = (0..n.div_ceil(64))
                    .map(|_| rng.range_f32(-0.02, 0.02))
                    .collect();
                for taus_opt in [None, Some(taus.as_slice())] {
                    let fast = quantize(&w, k, 64, taus_opt);
                    let refr = quantize_reference(&w, k, 64, taus_opt);
                    assert_eq!(fast.codes, refr.codes, "k={k} n={n}");
                    assert_eq!(fast.scales, refr.scales, "k={k} n={n}");
                    assert_eq!(fast.taus, refr.taus, "k={k} n={n}");
                    let d_fast = dequantize(&fast);
                    let d_ref = dequantize_reference(&refr);
                    for (i, (a, b)) in d_fast.iter().zip(&d_ref).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "k={k} n={n} i={i}: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn parallel_pack_unpack_matches_reference() {
        let mut rng = Rng::new(41);
        for k in 1..=8u8 {
            // spans crossing multiple PACK_CHUNK_ELEMS chunks
            for n in [0usize, 5, 8191, 8192, 8193, 3 * 8192 + 100] {
                let codes: Vec<u8> =
                    (0..n).map(|_| rng.below(1 << k) as u8).collect();
                let fast = pack_codes(&codes, k);
                let refr = pack_codes_reference(&codes, k);
                assert_eq!(fast, refr, "pack k={k} n={n}");
                let ufast = unpack_codes(&fast, k, n);
                let urefr = unpack_codes_reference(&refr, k, n);
                assert_eq!(ufast, urefr, "unpack k={k} n={n}");
                assert_eq!(ufast, codes, "roundtrip k={k} n={n}");
            }
        }
    }

    #[test]
    fn scratch_reuse_across_calls() {
        // one QuantizedBlocks + one packed buffer reused across inputs
        // of different sizes and bit widths must match fresh results.
        let mut rng = Rng::new(42);
        let mut q = QuantizedBlocks::scratch();
        let mut packed = Vec::new();
        let mut out = Vec::new();
        for (k, n) in [(4u8, 1000usize), (2, 130), (3, 64), (4, 8200)] {
            let w = rng.normal_vec(n, 0.0, 0.1);
            quantize_into(&w, k, 64, None, &mut q);
            let fresh = quantize_reference(&w, k, 64, None);
            assert_eq!(q.codes, fresh.codes);
            assert_eq!(q.scales, fresh.scales);
            pack_codes_into(&q.codes, k, &mut packed);
            assert_eq!(packed, pack_codes_reference(&fresh.codes, k));
            unpack_codes_into(&packed, k, n, &mut out);
            assert_eq!(out, fresh.codes);
            let mut deq = vec![0f32; n];
            dequantize_into(&q, &mut deq);
            assert_eq!(deq, dequantize_reference(&fresh));
        }
    }

    #[test]
    fn scratch_tau_transitions() {
        // Some -> None -> Some tau transitions through a reused scratch
        let w = vec![0.7f32; 64];
        let mut q = QuantizedBlocks::scratch();
        quantize_into(&w, 4, 64, Some(&[0.7]), &mut q);
        assert_eq!(q.taus.as_deref(), Some(&[0.7f32][..]));
        quantize_into(&w, 4, 64, None, &mut q);
        assert!(q.taus.is_none());
        quantize_into(&w, 4, 64, Some(&[0.1]), &mut q);
        assert_eq!(q.taus.as_deref(), Some(&[0.1f32][..]));
    }
}
