//! Blockwise absmax NF-k quantization (paper Eq. 1) and bit-packing.
//!
//! Weights are split into contiguous blocks (default 64 elements, the
//! paper's setting); each block is normalized by its absmax and each
//! element mapped to the nearest NF-k level. Codes are bit-packed
//! (2/3/4 bits per element) for storage accounting; the compute path
//! works on unpacked `u8` codes.

use super::nf;

/// Paper-default quantization block size.
pub const DEFAULT_BLOCK: usize = 64;

/// A blockwise-quantized tensor (codes + one scale per block, plus an
/// optional per-block shift τ — ICQ fills it, vanilla leaves it None).
#[derive(Clone, Debug)]
pub struct QuantizedBlocks {
    /// Bit width k.
    pub k: u8,
    /// Block size in elements.
    pub block: usize,
    /// Original element count (last block may be partial).
    pub len: usize,
    /// Unpacked code per element (values in 0..2^k).
    pub codes: Vec<u8>,
    /// absmax scale per block.
    pub scales: Vec<f32>,
    /// Optional calibration constant per block (ICQ).
    pub taus: Option<Vec<f32>>,
}

impl QuantizedBlocks {
    pub fn n_blocks(&self) -> usize {
        self.len.div_ceil(self.block)
    }

    /// Storage in bits: packed codes + one f32-equivalent scale slot per
    /// block (double quantization shrinks the scale term further; see
    /// `double_quant::storage_bits`).
    pub fn packed_code_bits(&self) -> usize {
        self.len * self.k as usize
    }
}

/// Quantize `w` blockwise with the NF-k codebook. `taus[i]` (if given)
/// is subtracted from block i before normalization (ICQ, Eq. 8).
pub fn quantize(w: &[f32], k: u8, block: usize, taus: Option<&[f32]>) -> QuantizedBlocks {
    assert!(block > 0);
    let cb = nf::codebook(k);
    let bounds = nf::boundaries(&cb);
    let n_blocks = w.len().div_ceil(block);
    if let Some(t) = taus {
        assert_eq!(t.len(), n_blocks, "one tau per block");
    }
    let mut codes = vec![0u8; w.len()];
    let mut scales = vec![0f32; n_blocks];

    for (bi, chunk) in w.chunks(block).enumerate() {
        let tau = taus.map_or(0.0, |t| t[bi]);
        let mut amax = 0f32;
        for &x in chunk {
            amax = amax.max((x - tau).abs());
        }
        let s = if amax > 0.0 { amax } else { 1.0 };
        scales[bi] = s;
        let out = &mut codes[bi * block..bi * block + chunk.len()];
        let inv = 1.0 / s;
        for (o, &x) in out.iter_mut().zip(chunk) {
            *o = nf::quantize_one(&bounds, (x - tau) * inv);
        }
    }

    QuantizedBlocks {
        k,
        block,
        len: w.len(),
        codes,
        scales,
        taus: taus.map(|t| t.to_vec()),
    }
}

/// Dequantize back to f32: `ŵ = cb[code] * s + τ` (Eq. 10 without the
/// double-quantization of s/τ — see `double_quant` for that layer).
pub fn dequantize(q: &QuantizedBlocks) -> Vec<f32> {
    let cb = nf::codebook(q.k);
    let mut out = vec![0f32; q.len];
    for bi in 0..q.n_blocks() {
        let lo = bi * q.block;
        let hi = (lo + q.block).min(q.len);
        let s = q.scales[bi];
        let tau = q.taus.as_ref().map_or(0.0, |t| t[bi]);
        for i in lo..hi {
            out[i] = cb[q.codes[i] as usize] * s + tau;
        }
    }
    out
}

/// Pack k-bit codes into bytes (little-endian bit order within bytes).
pub fn pack_codes(codes: &[u8], k: u8) -> Vec<u8> {
    assert!((1..=8).contains(&k));
    let total_bits = codes.len() * k as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &c in codes {
        debug_assert!((c as u16) < (1u16 << k), "code {c} out of range for k={k}");
        let byte = bitpos / 8;
        let off = bitpos % 8;
        out[byte] |= c << off;
        if off + k as usize > 8 {
            out[byte + 1] |= c >> (8 - off);
        }
        bitpos += k as usize;
    }
    out
}

/// Unpack k-bit codes from bytes.
pub fn unpack_codes(packed: &[u8], k: u8, n: usize) -> Vec<u8> {
    assert!((1..=8).contains(&k));
    let mask = ((1u16 << k) - 1) as u8;
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut v = packed[byte] >> off;
        if off + k as usize > 8 {
            v |= packed[byte + 1] << (8 - off);
        }
        out.push(v & mask);
        bitpos += k as usize;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{stats, Rng};

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Rng::new(1);
        let w = rng.normal_vec(1024, 0.0, 0.02);
        let q = quantize(&w, 4, 64, None);
        let wh = dequantize(&q);
        // worst-case NF4 step near 0 is ~0.08 of absmax; blocks of
        // normals have absmax ~3σ, so error per element << σ.
        let err = stats::max_abs_diff(&w, &wh);
        assert!(err < 0.02 * 3.5 * 0.15, "err {err}");
        // and strictly positive — quantization is lossy
        assert!(stats::mse(&w, &wh) > 0.0);
    }

    #[test]
    fn exact_levels_roundtrip_exactly() {
        // a block consisting of exact scaled codebook values survives
        let cb = nf::codebook(4);
        let s = 0.05f32;
        let w: Vec<f32> = cb.iter().map(|&c| c * s).collect();
        let q = quantize(&w, 4, 16, None);
        let wh = dequantize(&q);
        for (a, b) in w.iter().zip(&wh) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn partial_last_block() {
        let mut rng = Rng::new(2);
        let w = rng.normal_vec(100, 0.0, 1.0); // 64 + 36
        let q = quantize(&w, 4, 64, None);
        assert_eq!(q.n_blocks(), 2);
        assert_eq!(dequantize(&q).len(), 100);
    }

    #[test]
    fn zero_block_safe() {
        let w = vec![0.0f32; 64];
        let q = quantize(&w, 4, 64, None);
        let wh = dequantize(&q);
        assert!(wh.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn tau_shift_applied() {
        // constant block: with tau = the constant, everything quantizes
        // to (near) zero code and reconstructs exactly.
        let w = vec![0.7f32; 64];
        let q = quantize(&w, 4, 64, Some(&[0.7]));
        let wh = dequantize(&q);
        for &x in &wh {
            assert!((x - 0.7).abs() < 1e-6, "{x}");
        }
    }

    #[test]
    fn pack_unpack_identity_all_k() {
        let mut rng = Rng::new(3);
        for k in 1..=8u8 {
            for n in [0usize, 1, 7, 64, 65, 1000] {
                let codes: Vec<u8> =
                    (0..n).map(|_| (rng.below(1 << k)) as u8).collect();
                let packed = pack_codes(&codes, k);
                assert_eq!(packed.len(), (n * k as usize).div_ceil(8));
                let back = unpack_codes(&packed, k, n);
                assert_eq!(back, codes, "k={k} n={n}");
            }
        }
    }

    #[test]
    fn packed_size_4bit() {
        let codes = vec![0xFu8; 128];
        assert_eq!(pack_codes(&codes, 4).len(), 64);
    }

    #[test]
    fn bitwidths_2_and_3() {
        let mut rng = Rng::new(4);
        let w = rng.normal_vec(256, 0.0, 1.0);
        for k in [2u8, 3] {
            let q = quantize(&w, k, 64, None);
            assert!(q.codes.iter().all(|&c| c < (1 << k)));
            let wh = dequantize(&q);
            // lower bit-width => higher error than NF4
            let e_k = stats::mse(&w, &wh);
            let e_4 = stats::mse(&w, &dequantize(&quantize(&w, 4, 64, None)));
            assert!(e_k > e_4, "k={k}: {e_k} vs {e_4}");
        }
    }
}
