//! Percentile / quantile quantization (Dettmers et al., 2021) — the
//! information-theoretically optimal data-dependent codebook the paper
//! references when constructing NF2/NF3 (§4.3, Appendix B.2).
//!
//! Level i is the midpoint of adjacent (i/(2^k+1))-quantiles of the
//! data (paper Eq. 2 with the empirical quantile function in place of
//! Φ⁻¹), normalized to [-1, 1].

use crate::util::stats::quantile_sorted;

/// Build a 2^k-level codebook from the empirical quantiles of `data`,
/// normalized to [-1, 1] (ascending).
pub fn percentile_codebook(data: &[f32], k: u8) -> Vec<f32> {
    assert!(!data.is_empty());
    assert!((1..=8).contains(&k));
    let mut sorted = data.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let levels = 1usize << k;
    // Level i sits at the median of equal-mass bin i (the symmetric
    // empirical counterpart of Eq. 2 — the paper's averaged-adjacent-
    // quantile form is asymmetric at the edges because Q(0) = -inf for
    // the normal prior; with empirical quantiles bin medians give exact
    // equal occupancy on the calibration data).
    let mut cb: Vec<f32> = (0..levels)
        .map(|i| quantile_sorted(&sorted, (i as f32 + 0.5) / levels as f32))
        .collect();
    // Normalize by the data absmax (not the codebook max): blockwise
    // quantization feeds the codebook values normalized by absmax, so
    // this convention keeps bin occupancy uniform under that pipeline.
    let amax = sorted
        .first()
        .unwrap()
        .abs()
        .max(sorted.last().unwrap().abs());
    if amax > 0.0 {
        for v in &mut cb {
            *v /= amax;
        }
    }
    // enforce strict monotonicity for boundary construction
    for i in 1..cb.len() {
        if cb[i] <= cb[i - 1] {
            cb[i] = cb[i - 1] + f32::EPSILON.max(cb[i - 1].abs() * 1e-6);
        }
    }
    cb
}

/// Fraction of data per bin when quantized with this codebook — the
/// "equal occupancy" property quantile quantization targets.
pub fn bin_occupancy(data: &[f32], cb: &[f32]) -> Vec<f32> {
    let bounds = crate::quant::nf::boundaries(cb);
    let amax = data.iter().fold(0f32, |m, &x| m.max(x.abs())).max(1e-12);
    let mut counts = vec![0u32; cb.len()];
    for &x in data {
        counts[crate::quant::nf::quantize_one(&bounds, x / amax) as usize] += 1;
    }
    counts
        .into_iter()
        .map(|c| c as f32 / data.len() as f32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn codebook_sorted_normalized() {
        let mut rng = Rng::new(41);
        let data = rng.normal_vec(10_000, 0.0, 1.0);
        for k in [2u8, 3, 4] {
            let cb = percentile_codebook(&data, k);
            assert_eq!(cb.len(), 1 << k);
            assert!(cb.windows(2).all(|w| w[0] < w[1]));
            assert!(cb.iter().all(|&v| (-1.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn normal_data_approximates_nf() {
        // percentile codebook on big N(0,1) sample ≈ NF codebook shape:
        // inner levels denser than outer
        let mut rng = Rng::new(42);
        let data = rng.normal_vec(200_000, 0.0, 1.0);
        let cb = percentile_codebook(&data, 4);
        let inner_gap = cb[8] - cb[7];
        let outer_gap = cb[15] - cb[14];
        assert!(outer_gap > inner_gap * 1.5, "{outer_gap} vs {inner_gap}");
    }

    #[test]
    fn occupancy_roughly_uniform() {
        let mut rng = Rng::new(43);
        let data = rng.normal_vec(100_000, 0.0, 1.0);
        let cb = percentile_codebook(&data, 3);
        let occ = bin_occupancy(&data, &cb);
        let target = 1.0 / 8.0;
        for (i, &o) in occ.iter().enumerate() {
            assert!((o - target).abs() < 0.06, "bin {i}: {o}");
        }
    }

    #[test]
    fn skewed_data_supported() {
        let mut rng = Rng::new(44);
        let data: Vec<f32> = (0..5000).map(|_| rng.f32().powi(3) * 2.0 - 0.1).collect();
        let cb = percentile_codebook(&data, 4);
        assert!(cb.windows(2).all(|w| w[0] < w[1]));
    }
}
