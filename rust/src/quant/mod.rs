//! Quantization stack — the paper's §3 plus every baseline its
//! evaluation compares against.
//!
//! - [`nf`]: NormalFloat codebooks (Tables 11–13)
//! - [`blockwise`]: blocksize-64 absmax NF-k quantization + bit packing
//! - [`fused`]: packed-domain dequantization (bytes → f32 with no
//!   unpacked intermediate) — the serving/eval fast path
//! - [`fp8`] / [`double_quant`]: E4M3 + FP16 double quantization of
//!   per-block constants
//! - [`icq`]: Information Calibration Quantization (the contribution)
//! - [`entropy`]: the information metric (Eq. 7)
//! - [`integer`]: group-wise affine integer quantization (QA-LoRA) and
//!   its ICQ zero-point variant (Table 10)
//! - [`gptq`]: Hessian-compensated GPTQ baseline
//! - [`percentile`]: quantile-quantization codebooks
//!
//! [`QuantizedTensor`] bundles the full storage pipeline of Eq. 10 —
//! packed NF codes + double-quantized scales (and τ, for ICQ) — and is
//! the unit the model-level pipeline moves around. [`Method`] names
//! every quantization scheme that appears as a table row.
//!
//! ## Fast path vs. reference path
//!
//! Every hot operation has two implementations. The **fast path**
//! (what the public entry points run) is parallel over quantization
//! blocks and works in the packed domain where possible:
//! [`QuantizedTensor::dequantize`] / [`QuantizedTensor::dequantize_into`]
//! go straight from packed bytes to f32 through the per-k lookup
//! tables in [`fused`], reusing caller scratch ([`DequantScratch`])
//! for the per-block constants. The **reference path** (the
//! `*_reference` functions in [`blockwise`], plus
//! [`QuantizedTensor::to_blocks`] + [`blockwise::dequantize_reference`])
//! is the original serial element-at-a-time pipeline, kept as the
//! oracle: property tests assert the fast paths are bit-identical to
//! it for k ∈ 1..=8, including partial last blocks and zero/constant
//! blocks. Throughput of both is tracked in `BENCH_quant.json` by
//! `benches/quantize_throughput.rs`.

pub mod blockwise;
pub mod double_quant;
pub mod entropy;
pub mod fp8;
pub mod fused;
pub mod gptq;
pub mod icq;
pub mod integer;
pub mod nf;
pub mod percentile;

use crate::util::Tensor;

use blockwise::QuantizedBlocks;
use double_quant::DoubleQuant;
pub use fused::DequantScratch;

/// Every weight-quantization scheme that appears in the paper's tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// No quantization (16-bit rows).
    Fp16,
    /// Vanilla blockwise NF-k (QLoRA / "NormalFloat" rows).
    Nf { k: u8 },
    /// NF-k with ICQ calibration (IR-QLoRA / "ICQ" rows).
    NfIcq { k: u8 },
    /// Group-wise integer min/max (QA-LoRA rows).
    Int { k: u8 },
    /// Integer with ICQ zero-point search ("IR-QLoRA (QA-LoRA)").
    IntIcq { k: u8 },
    /// GPTQ on the integer grid ("QLoRA w/ GPTQ" rows).
    Gptq { k: u8 },
    /// Mixed per-tensor bit-widths from a `precision::PrecisionPlan`
    /// (ICQ NF-k with plan-assigned k; built by
    /// `coordinator::quantize::quantize_model_planned`).
    Planned,
}

impl Method {
    /// Uniform bit-width of the method; 0 for [`Method::Planned`],
    /// whose per-tensor widths live in the model's plan.
    pub fn bits(&self) -> u8 {
        match *self {
            Method::Fp16 => 16,
            Method::Planned => 0,
            Method::Nf { k }
            | Method::NfIcq { k }
            | Method::Int { k }
            | Method::IntIcq { k }
            | Method::Gptq { k } => k,
        }
    }

    pub fn uses_icq(&self) -> bool {
        matches!(
            self,
            Method::NfIcq { .. } | Method::IntIcq { .. } | Method::Planned
        )
    }

    pub fn paper_name(&self) -> String {
        match *self {
            Method::Fp16 => "16-bit".into(),
            Method::Nf { k } => format!("NormalFloat NF{k}"),
            Method::NfIcq { k } => format!("ICQ NF{k}"),
            Method::Int { k } => format!("Integer g64 INT{k}"),
            Method::IntIcq { k } => format!("Integer+ICQ INT{k}"),
            Method::Gptq { k } => format!("GPTQ INT{k}"),
            Method::Planned => "ICQ mixed-k (planned)".into(),
        }
    }
}

/// Full storage-pipeline quantized tensor (paper Eq. 10): packed NF
/// codes, double-quantized scales s₁/s₂ and (ICQ) τ₁/τ₂, original
/// shape. Dequantization reproduces ŵ^FP16 exactly as inference would.
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    pub shape: Vec<usize>,
    pub k: u8,
    pub block: usize,
    /// Bit-packed codes.
    pub packed: Vec<u8>,
    /// Element count.
    pub len: usize,
    /// Double-quantized per-block scales.
    pub scales: DoubleQuant,
    /// Double-quantized per-block τ (ICQ only).
    pub taus: Option<DoubleQuant>,
}

impl QuantizedTensor {
    /// Quantize with the full pipeline. `icq` enables the τ search.
    pub fn quantize(
        w: &Tensor,
        k: u8,
        block: usize,
        icq: Option<&icq::IcqConfig>,
    ) -> QuantizedTensor {
        let qb: QuantizedBlocks = match icq {
            Some(cfg) => icq::quantize(w.data(), k, block, cfg),
            None => blockwise::quantize(w.data(), k, block, None),
        };
        Self::from_blocks(w.shape(), qb)
    }

    /// Pack a [`QuantizedBlocks`] into the storage representation.
    pub fn from_blocks(shape: &[usize], qb: QuantizedBlocks) -> QuantizedTensor {
        let packed = blockwise::pack_codes(&qb.codes, qb.k);
        let scales = DoubleQuant::quantize(&qb.scales, double_quant::DEFAULT_GROUP);
        let taus = qb
            .taus
            .as_ref()
            .map(|t| DoubleQuant::quantize(t, double_quant::DEFAULT_GROUP));
        QuantizedTensor {
            shape: shape.to_vec(),
            k: qb.k,
            block: qb.block,
            packed,
            len: qb.len,
            scales,
            taus,
        }
    }

    /// Unpack into code + reconstructed per-block constants (the
    /// reference-path representation; entropy accounting reads it).
    pub fn to_blocks(&self) -> QuantizedBlocks {
        QuantizedBlocks {
            k: self.k,
            block: self.block,
            len: self.len,
            codes: blockwise::unpack_codes(&self.packed, self.k, self.len),
            scales: self.scales.dequantize(),
            taus: self.taus.as_ref().map(|t| t.dequantize()),
        }
    }

    /// Dequantize to ŵ^FP16 (f32 container) — Eq. 10. Runs the fused
    /// packed-domain fast path; see [`Self::dequantize_into`] to also
    /// reuse buffers across calls.
    pub fn dequantize(&self) -> Tensor {
        let mut data = vec![0f32; self.len];
        let mut scratch = DequantScratch::default();
        self.dequantize_into(&mut data, &mut scratch);
        Tensor::new(&self.shape, data)
    }

    /// Allocation-free fused dequantization: packed codes → `out`
    /// directly (no unpacked `Vec<u8>` intermediate), per-block
    /// constants double-dequantized into `scratch` and reused across
    /// calls. `out.len()` must equal `self.len`. Bit-identical to the
    /// reference pipeline [`Self::dequantize_reference`].
    pub fn dequantize_into(&self, out: &mut [f32], scratch: &mut DequantScratch) {
        self.scales.dequantize_into(&mut scratch.scales);
        let taus = match &self.taus {
            Some(t) => {
                t.dequantize_into(&mut scratch.taus);
                Some(scratch.taus.as_slice())
            }
            None => None,
        };
        fused::dequantize_packed_into(
            &self.packed,
            self.k,
            self.len,
            self.block,
            &scratch.scales,
            taus,
            out,
        );
    }

    /// Reference (pre-fusion) dequantization pipeline: unpack every
    /// code to a byte, reconstruct constants, then a serial
    /// element-at-a-time walk. Kept as the oracle for the fused path
    /// and as the before-side of the `quantize_throughput` bench.
    pub fn dequantize_reference(&self) -> Tensor {
        let qb = QuantizedBlocks {
            k: self.k,
            block: self.block,
            len: self.len,
            codes: blockwise::unpack_codes_reference(&self.packed, self.k, self.len),
            scales: self.scales.dequantize(),
            taus: self.taus.as_ref().map(|t| t.dequantize()),
        };
        Tensor::new(&self.shape, blockwise::dequantize_reference(&qb))
    }

    /// Total storage in bits: packed codes + double-quantized constants.
    pub fn storage_bits(&self) -> usize {
        let mut bits = self.len * self.k as usize + self.scales.storage_bits();
        if let Some(t) = &self.taus {
            bits += t.storage_bits();
        }
        bits
    }

    /// Effective bits per weight.
    pub fn bits_per_weight(&self) -> f64 {
        self.storage_bits() as f64 / self.len as f64
    }

    /// Mean per-block code entropy (Table 5 "Ent." / Figures 4–5).
    pub fn mean_entropy(&self) -> f64 {
        entropy::mean_block_entropy(&self.to_blocks())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{stats, Rng};

    #[test]
    fn full_pipeline_roundtrip() {
        let mut rng = Rng::new(51);
        let w = Tensor::new(&[32, 64], rng.normal_vec(2048, 0.0, 0.04));
        let q = QuantizedTensor::quantize(&w, 4, 64, None);
        let wh = q.dequantize();
        assert_eq!(wh.shape(), w.shape());
        // double quantization adds scale error (<~7%) on top of NF4
        let err = stats::max_abs_diff(w.data(), wh.data());
        assert!(err < 0.04 * 4.0 * 0.2, "err {err}");
    }

    #[test]
    fn icq_pipeline_has_taus() {
        let mut rng = Rng::new(52);
        let w = Tensor::new(&[8, 64], rng.normal_vec(512, 0.02, 0.05));
        let q = QuantizedTensor::quantize(&w, 4, 64, Some(&icq::IcqConfig::default()));
        assert!(q.taus.is_some());
        let wh = q.dequantize();
        assert!(stats::mse(w.data(), wh.data()) < 1e-4);
    }

    #[test]
    fn storage_accounting_4bit() {
        let mut rng = Rng::new(53);
        let n = 64 * 256; // whole number of blocks and dq groups
        let w = Tensor::new(&[n], rng.normal_vec(n, 0.0, 1.0));
        let q = QuantizedTensor::quantize(&w, 4, 64, None);
        // 4 bits/code + (8b per block scale + 16b per 256 scales)/64
        let expect = n * 4 + (n / 64) * 8 + 16;
        assert_eq!(q.storage_bits(), expect);
        assert!((q.bits_per_weight() - 4.126).abs() < 0.01);
    }

    #[test]
    fn icq_storage_overhead_matches_paper_ratio() {
        // ICQ doubles the per-block constant storage (τ next to s):
        // paper Table 6 reports ~2% model-level increase at 4-bit.
        let mut rng = Rng::new(54);
        let n = 64 * 256;
        let w = Tensor::new(&[n], rng.normal_vec(n, 0.0, 1.0));
        let q0 = QuantizedTensor::quantize(&w, 4, 64, None);
        let q1 = QuantizedTensor::quantize(&w, 4, 64, Some(&icq::IcqConfig::default()));
        let ratio = q1.storage_bits() as f64 / q0.storage_bits() as f64;
        assert!(ratio > 1.0 && ratio < 1.05, "ratio {ratio}");
    }

    #[test]
    fn method_names_and_bits() {
        assert_eq!(Method::Nf { k: 4 }.bits(), 4);
        assert_eq!(Method::Fp16.bits(), 16);
        assert!(Method::NfIcq { k: 2 }.uses_icq());
        assert!(!Method::Gptq { k: 4 }.uses_icq());
        assert!(Method::IntIcq { k: 4 }.paper_name().contains("ICQ"));
        assert_eq!(Method::Planned.bits(), 0); // per-tensor: see the plan
        assert!(Method::Planned.uses_icq());
        assert!(Method::Planned.paper_name().contains("mixed"));
    }

    #[test]
    fn fused_dequantize_matches_reference_pipeline() {
        let mut rng = Rng::new(56);
        for k in [2u8, 3, 4] {
            for icq_cfg in [None, Some(icq::IcqConfig::default())] {
                let n = 64 * 9 + 17; // partial last block
                let w = Tensor::new(&[n], rng.normal_vec(n, 0.01, 0.05));
                let q = QuantizedTensor::quantize(&w, k, 64, icq_cfg.as_ref());
                let want = q.dequantize_reference();
                let got = q.dequantize();
                let mut into = vec![0f32; n];
                let mut scratch = DequantScratch::default();
                q.dequantize_into(&mut into, &mut scratch);
                for i in 0..n {
                    assert_eq!(
                        got.data()[i].to_bits(),
                        want.data()[i].to_bits(),
                        "k={k} i={i}"
                    );
                    assert_eq!(into[i].to_bits(), want.data()[i].to_bits(), "k={k} i={i}");
                }
            }
        }
    }

    #[test]
    fn entropy_icq_beats_vanilla_model_level() {
        let mut rng = Rng::new(55);
        // mildly skewed weights, as after pre-training
        let w = Tensor::new(
            &[64, 64],
            (0..4096).map(|_| rng.normal_ms(0.015, 0.03)).collect(),
        );
        let q0 = QuantizedTensor::quantize(&w, 4, 64, None);
        let q1 = QuantizedTensor::quantize(&w, 4, 64, Some(&icq::IcqConfig::default()));
        assert!(q1.mean_entropy() > q0.mean_entropy());
    }
}
