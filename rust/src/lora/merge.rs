//! Merging IEC into the LoRA matrices — paper Appendix A.2, Eq. 16/17.
//!
//! The elastic terms are linear in the input, so β1/β2 fold into the
//! adapter weights and serving runs plain LoRA matmuls — IEC costs
//! nothing at inference (the property Table 6 relies on). Merging is
//! independent of the base's quantization: adapters fold identically
//! over uniform-k and mixed-k (plan-driven) bases, since only the
//! adapter matrices and β scalars participate.
//!
//! Note on Eq. 16: taken literally, its floor-based index condition
//! places the pooled groups in *block-repeat* order
//! (p₀…p₀ p₁…p₁ …), while Eq. 13/14 and Algorithm 2 define the
//! elastic term as *repeated concatenation* (tile) of the pooled
//! vector (p₀ p₁ … p₀ p₁ …). The two differ by a fixed output
//! permutation of the elastic term only; since the forward pass
//! follows Eq. 13/14 (see [`super::iec`]), the merge here uses the
//! tile-consistent condition `group(i) == j mod g` so that
//! x·ℓ̃1·ℓ̃2 == U2(U1(x)) holds exactly (the property Eq. 17 asserts).

use anyhow::{anyhow, Result};

use super::iec::gcd;
use crate::model::weights::{parse_layer_proj, validate_adapter, NamedTensors};
use crate::util::threads;
use crate::util::Tensor;

/// Merge β1 into ℓ1 (h×r row-major): ℓ̃1[i,j] = ℓ1[i,j] + β1·g/h
/// where floor(i/(h/g)) == j mod g, g = gcd(h, r).
pub fn merge_l1(l1: &[f32], h: usize, r: usize, beta1: f32) -> Vec<f32> {
    let mut out = Vec::new();
    merge_l1_into(l1, h, r, beta1, &mut out);
    out
}

/// Allocation-free [`merge_l1`] into a reused buffer (cleared and
/// refilled) — serving reloads adapters often enough that the merge
/// scratch is worth keeping around. Parallel over output rows.
pub fn merge_l1_into(l1: &[f32], h: usize, r: usize, beta1: f32, out: &mut Vec<f32>) {
    assert_eq!(l1.len(), h * r);
    let g = gcd(h, r);
    let seg_i = h / g; // input rows per pooled group
    let add = beta1 * g as f32 / h as f32; // = beta1 / seg_i
    out.clear();
    out.extend_from_slice(l1);
    threads::par_chunks_mut_with(out.as_mut_slice(), r, 64, |i, row| {
        // the touched columns of row i are exactly j ≡ gi (mod g) with
        // gi < g, so walk them directly instead of scanning every j
        // and testing `j % g == gi` (no per-element modulo on the
        // serving-reload hot path; bit-identical — the touched set and
        // the single add per element are unchanged)
        let gi = i / seg_i;
        for v in row[gi..].iter_mut().step_by(g) {
            *v += add;
        }
    });
}

/// Merge β2 into ℓ2 (r×o row-major): ℓ̃2[i,j] = ℓ2[i,j] + β2·g/r
/// where floor(i/(r/g)) == j mod g, g = gcd(o, r).
pub fn merge_l2(l2: &[f32], r: usize, o: usize, beta2: f32) -> Vec<f32> {
    let mut out = Vec::new();
    merge_l2_into(l2, r, o, beta2, &mut out);
    out
}

/// Allocation-free [`merge_l2`] into a reused buffer. Parallel over
/// output rows.
pub fn merge_l2_into(l2: &[f32], r: usize, o: usize, beta2: f32, out: &mut Vec<f32>) {
    assert_eq!(l2.len(), r * o);
    let g = gcd(o, r);
    let seg_i = r / g;
    let add = beta2 * g as f32 / r as f32;
    out.clear();
    out.extend_from_slice(l2);
    threads::par_chunks_mut_with(out.as_mut_slice(), o, 64, |i, row| {
        // strided writes: see merge_l1_into
        let gi = i / seg_i;
        for v in row[gi..].iter_mut().step_by(g) {
            *v += add;
        }
    });
}

/// Fold every layer's IEC scalars (β1, β2), gated by the serving
/// masks, into an adapter's LoRA matrices — Eq. 16/17 applied
/// model-wide. The result serves through the plain-LoRA forward path
/// (masks (0,0), `betas` zeroed), which is how the multi-adapter
/// registry caches adapters: merge once per adapter, then every batch
/// runs mask-free. Each output tensor is produced by one
/// `merge_l*_into` call writing the buffer that becomes the cached
/// tensor, so there are no intermediate copies. The merge is
/// deterministic: re-merging the same source is bit-identical, which
/// the registry's evict/reload path relies on.
pub fn merge_adapter(lora: &NamedTensors, masks: (f32, f32)) -> Result<NamedTensors> {
    validate_adapter(lora)?;
    telem_merges().inc();
    let betas = lora.get("betas")?;
    let n_proj = betas.shape()[1];
    let beta_at = |stem: &str, which: usize| -> Result<f32> {
        let (layer, pi) = parse_layer_proj(stem)
            .ok_or_else(|| anyhow!("bad adapter tensor stem '{stem}'"))?;
        // validate_adapter bounds every stem; .get keeps a future
        // validation gap an Err instead of a panic under callers' locks
        betas
            .data()
            .get((layer * n_proj + pi) * 2 + which)
            .copied()
            .ok_or_else(|| anyhow!("'{stem}' indexes outside betas"))
    };
    let mut out = NamedTensors::new();
    for (name, t) in lora.iter() {
        if name == "betas" {
            out.push(name, Tensor::zeros(t.shape()));
        } else if let Some(stem) = name.strip_suffix(".lora_a") {
            let (h, r) = (t.shape()[0], t.shape()[1]);
            let mut v = Vec::new();
            merge_l1_into(t.data(), h, r, masks.0 * beta_at(stem, 0)?, &mut v);
            out.push(name, Tensor::new(t.shape(), v));
        } else if let Some(stem) = name.strip_suffix(".lora_b") {
            let (r, o) = (t.shape()[0], t.shape()[1]);
            let mut v = Vec::new();
            merge_l2_into(t.data(), r, o, masks.1 * beta_at(stem, 1)?, &mut v);
            out.push(name, Tensor::new(t.shape(), v));
        } else {
            out.push(name, t.clone());
        }
    }
    Ok(out)
}

/// Dense merged-branch delta ΔW = ℓ̃1·ℓ̃2 (h×o row-major) — the whole
/// adapter contribution as one matrix, computed with the blocked
/// kernel [`crate::kernels::gemm_f32`]. Serving never materializes
/// this product ([`merge_adapter`] keeps the two thin matrices and the
/// LRU caches those byte-for-byte, so cache keys and cached contents
/// are untouched by the kernel layer) — but adapter diffing,
/// checkpoint export and the kernel benches want the dense form, and
/// this is the one sanctioned way to build it.
pub fn merge_delta(l1m: &[f32], l2m: &[f32], h: usize, r: usize, o: usize) -> Vec<f32> {
    let mut out = Vec::new();
    merge_delta_into(l1m, l2m, h, r, o, &mut out);
    out
}

/// [`merge_delta`] into a reused buffer (allocation-free once warm).
pub fn merge_delta_into(
    l1m: &[f32],
    l2m: &[f32],
    h: usize,
    r: usize,
    o: usize,
    out: &mut Vec<f32>,
) {
    crate::kernels::gemm_f32_into(l1m, l2m, h, r, o, out);
}

/// Serial reference twin of [`merge_delta`]: the naive triple loop
/// (one f64 accumulator per element, r-index order), kept as the
/// oracle and as the before-side of the `kernel_throughput` bench
/// pair. Bit-identical to [`merge_delta`].
pub fn merge_delta_reference(l1m: &[f32], l2m: &[f32], h: usize, r: usize, o: usize) -> Vec<f32> {
    crate::kernels::gemm_f32_reference(l1m, l2m, h, r, o)
}

/// Cached telemetry counter for Eq. 16/17 merges (no-op unless
/// `IRQLORA_TELEMETRY=1`).
fn telem_merges() -> &'static crate::telemetry::Counter {
    static C: std::sync::OnceLock<crate::telemetry::Counter> = std::sync::OnceLock::new();
    C.get_or_init(|| crate::telemetry::global().counter("lora.merges", &[]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lora::iec::lora_iec_forward;
    use crate::util::Rng;

    /// Merged adapters must reproduce the explicit elastic computation
    /// exactly (Eq. 17): x·ℓ̃1·ℓ̃2 == U2(U1(x)).
    fn check_equivalence(h: usize, r: usize, o: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let x = rng.normal_vec(h, 0.0, 1.0);
        let l1 = rng.normal_vec(h * r, 0.0, 0.15);
        let l2 = rng.normal_vec(r * o, 0.0, 0.15);
        let (b1, b2) = (rng.normal(), rng.normal());

        let explicit = lora_iec_forward(&x, &l1, &l2, r, o, 1.0, b1, b2, 1.0, 1.0);

        let m1 = merge_l1(&l1, h, r, b1);
        let m2 = merge_l2(&l2, r, o, b2);
        let merged = lora_iec_forward(&x, &m1, &m2, r, o, 1.0, 0.0, 0.0, 0.0, 0.0);

        for (a, b) in explicit.iter().zip(&merged) {
            assert!(
                (a - b).abs() < 1e-4 * (1.0 + a.abs()),
                "h={h} r={r} o={o}: {a} vs {b}"
            );
        }
    }

    #[test]
    fn merge_equivalence_multiple_dims() {
        check_equivalence(16, 4, 8, 71); // r | h, r | o
        check_equivalence(64, 8, 64, 72);
        check_equivalence(128, 16, 32, 73);
    }

    #[test]
    fn merge_equivalence_non_multiple_dims() {
        check_equivalence(12, 8, 20, 74); // gcd(12,8)=4, gcd(20,8)=4
        check_equivalence(18, 12, 30, 75); // gcd=6
    }

    #[test]
    fn merge_equivalence_paper_dims() {
        check_equivalence(128, 64, 128, 77); // shrunk 4096/64/4096 shape
    }

    #[test]
    fn merge_zero_beta_is_identity() {
        let mut rng = Rng::new(76);
        let l1 = rng.normal_vec(32 * 4, 0.0, 1.0);
        assert_eq!(merge_l1(&l1, 32, 4, 0.0), l1);
        let l2 = rng.normal_vec(4 * 16, 0.0, 1.0);
        assert_eq!(merge_l2(&l2, 4, 16, 0.0), l2);
    }

    #[test]
    fn merged_l1_structure() {
        // zero l1: column j reads the mean of input segment (j mod g)
        let (h, r) = (8usize, 4usize);
        let m = merge_l1(&vec![0.0; h * r], h, r, 1.0);
        let g = gcd(h, r); // 4
        let add = g as f32 / h as f32; // 0.5
        for i in 0..h {
            for j in 0..r {
                let want = if j % g == i / (h / g) { add } else { 0.0 };
                assert_eq!(m[i * r + j], want, "({i},{j})");
            }
        }
    }

    /// The branchy per-element-modulo form the strided merge replaced,
    /// kept as the oracle: ℓ̃[i,j] = ℓ[i,j] + add iff j % g == i/seg_i.
    fn merge_branchy(l: &[f32], rows: usize, cols: usize, g: usize, add: f32) -> Vec<f32> {
        let seg_i = rows / g;
        let mut out = l.to_vec();
        for i in 0..rows {
            let gi = i / seg_i;
            for (j, v) in out[i * cols..(i + 1) * cols].iter_mut().enumerate() {
                if j % g == gi {
                    *v += add;
                }
            }
        }
        out
    }

    #[test]
    fn strided_merge_bit_identical_to_branchy_oracle() {
        let mut rng = Rng::new(79);
        // multiple, non-multiple, and gcd==1 shapes
        for (h, r, o) in [
            (16usize, 4usize, 8usize),
            (64, 8, 64),
            (12, 8, 20),
            (18, 12, 30),
            (7, 3, 5), // gcd(7,3)=1, gcd(5,3)=1: every element touched
            (128, 64, 128),
        ] {
            let l1 = rng.normal_vec(h * r, 0.0, 0.2);
            let l2 = rng.normal_vec(r * o, 0.0, 0.2);
            let (b1, b2) = (rng.normal(), rng.normal());
            let g1 = gcd(h, r);
            let want1 = merge_branchy(&l1, h, r, g1, b1 * g1 as f32 / h as f32);
            let got1 = merge_l1(&l1, h, r, b1);
            for (i, (a, b)) in got1.iter().zip(&want1).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "l1 h={h} r={r} i={i}");
            }
            let g2 = gcd(o, r);
            let want2 = merge_branchy(&l2, r, o, g2, b2 * g2 as f32 / r as f32);
            let got2 = merge_l2(&l2, r, o, b2);
            for (i, (a, b)) in got2.iter().zip(&want2).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "l2 r={r} o={o} i={i}");
            }
        }
    }

    #[test]
    fn into_variants_reuse_scratch() {
        // one pair of buffers reused across differently-sized merges
        // must match the allocating variants exactly
        let mut rng = Rng::new(78);
        let mut m1 = Vec::new();
        let mut m2 = Vec::new();
        for (h, r, o) in [(16usize, 4usize, 8usize), (64, 8, 64), (12, 8, 20)] {
            let l1 = rng.normal_vec(h * r, 0.0, 0.2);
            let l2 = rng.normal_vec(r * o, 0.0, 0.2);
            let (b1, b2) = (rng.normal(), rng.normal());
            merge_l1_into(&l1, h, r, b1, &mut m1);
            merge_l2_into(&l2, r, o, b2, &mut m2);
            assert_eq!(m1, merge_l1(&l1, h, r, b1), "h={h} r={r}");
            assert_eq!(m2, merge_l2(&l2, r, o, b2), "r={r} o={o}");
        }
    }

    fn adapter_fixture(seed: u64) -> NamedTensors {
        let mut rng = Rng::new(seed);
        let (h, r, o) = (16usize, 4usize, 8usize);
        let mut nt = NamedTensors::new();
        nt.push("l0.wq.lora_a", Tensor::new(&[h, r], rng.normal_vec(h * r, 0.0, 0.2)));
        nt.push("l0.wq.lora_b", Tensor::new(&[r, o], rng.normal_vec(r * o, 0.0, 0.2)));
        nt.push("l1.w2.lora_a", Tensor::new(&[o, r], rng.normal_vec(o * r, 0.0, 0.2)));
        nt.push("l1.w2.lora_b", Tensor::new(&[r, h], rng.normal_vec(r * h, 0.0, 0.2)));
        nt.push("betas", Tensor::new(&[2, 7, 2], rng.normal_vec(2 * 7 * 2, 0.0, 0.5)));
        nt
    }

    #[test]
    fn merge_adapter_matches_per_tensor_merges() {
        let adapter = adapter_fixture(91);
        let merged = merge_adapter(&adapter, (1.0, 1.0)).unwrap();
        let betas = adapter.get("betas").unwrap().data().to_vec();
        // l0.wq is (layer 0, proj 0); l1.w2 is (layer 1, proj 6)
        let cases = [("l0.wq", 16usize, 8usize, 0usize), ("l1.w2", 8, 16, 1 * 7 + 6)];
        for (stem, h, o, bi) in cases {
            let (b1, b2) = (betas[bi * 2], betas[bi * 2 + 1]);
            let a = adapter.get(&format!("{stem}.lora_a")).unwrap();
            let b = adapter.get(&format!("{stem}.lora_b")).unwrap();
            assert_eq!(
                merged.get(&format!("{stem}.lora_a")).unwrap().data(),
                merge_l1(a.data(), h, 4, b1).as_slice(),
                "{stem}.lora_a"
            );
            assert_eq!(
                merged.get(&format!("{stem}.lora_b")).unwrap().data(),
                merge_l2(b.data(), 4, o, b2).as_slice(),
                "{stem}.lora_b"
            );
        }
        // betas are consumed by the merge: zeroed in the output
        assert!(merged.get("betas").unwrap().data().iter().all(|&x| x == 0.0));
        assert_eq!(merged.names(), adapter.names());
    }

    #[test]
    fn merge_adapter_masks_gate_folding() {
        let adapter = adapter_fixture(92);
        // masks (0,0): vanilla-LoRA serving — matrices pass through
        let off = merge_adapter(&adapter, (0.0, 0.0)).unwrap();
        for (name, t) in adapter.iter() {
            if name == "betas" {
                continue;
            }
            assert_eq!(off.get(name).unwrap().data(), t.data(), "{name}");
        }
        // masks (1,0): only lora_a moves
        let u1 = merge_adapter(&adapter, (1.0, 0.0)).unwrap();
        assert_ne!(
            u1.get("l0.wq.lora_a").unwrap().data(),
            adapter.get("l0.wq.lora_a").unwrap().data()
        );
        assert_eq!(
            u1.get("l0.wq.lora_b").unwrap().data(),
            adapter.get("l0.wq.lora_b").unwrap().data()
        );
        // deterministic: same input, bit-identical output
        let again = merge_adapter(&adapter, (1.0, 0.0)).unwrap();
        for (name, t) in u1.iter() {
            assert_eq!(again.get(name).unwrap().data(), t.data(), "{name}");
        }
    }

    #[test]
    fn merge_delta_blocked_matches_reference() {
        let mut rng = Rng::new(93);
        for (h, r, o) in [(16usize, 4usize, 8usize), (64, 8, 64), (33, 7, 129)] {
            let l1 = rng.normal_vec(h * r, 0.0, 0.2);
            let l2 = rng.normal_vec(r * o, 0.0, 0.2);
            let (b1, b2) = (rng.normal(), rng.normal());
            let m1 = merge_l1(&l1, h, r, b1);
            let m2 = merge_l2(&l2, r, o, b2);
            let got = merge_delta(&m1, &m2, h, r, o);
            let want = merge_delta_reference(&m1, &m2, h, r, o);
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "h={h} r={r} o={o} i={i}");
            }
        }
    }

    #[test]
    fn merge_adapter_rejects_malformed() {
        let mut no_betas = NamedTensors::new();
        no_betas.push("l0.wq.lora_a", Tensor::zeros(&[8, 4]));
        no_betas.push("l0.wq.lora_b", Tensor::zeros(&[4, 8]));
        assert!(merge_adapter(&no_betas, (1.0, 1.0)).is_err());

        let mut widowed = NamedTensors::new();
        widowed.push("l0.wq.lora_a", Tensor::zeros(&[8, 4]));
        widowed.push("betas", Tensor::zeros(&[1, 7, 2]));
        assert!(merge_adapter(&widowed, (1.0, 1.0)).is_err());

        let mut out_of_range = NamedTensors::new();
        out_of_range.push("l3.wq.lora_a", Tensor::zeros(&[8, 4]));
        out_of_range.push("l3.wq.lora_b", Tensor::zeros(&[4, 8]));
        out_of_range.push("betas", Tensor::zeros(&[1, 7, 2]));
        assert!(merge_adapter(&out_of_range, (1.0, 1.0)).is_err());
    }

    #[test]
    fn merged_l2_tile_structure() {
        // r | o, zero l2: out = x' tiled o/r times => m[i,j]=β iff i == j mod r
        let (r, o) = (2usize, 6usize);
        let m = merge_l2(&vec![0.0; r * o], r, o, 1.0);
        for i in 0..r {
            for j in 0..o {
                let want = if j % r == i { 1.0 } else { 0.0 };
                assert_eq!(m[i * o + j], want, "({i},{j})");
            }
        }
    }
}
