//! Information Elastic Connection — paper §3.3, Eq. 12–14.
//!
//! IEC adds parameter-free elastic skip paths around both LoRA
//! matrices so each sub-unit can see the *original* representation,
//! not only the transformed one:
//!
//! - `U1(x) = x·ℓ1 + β1 · tile_{r/g}( groupavg_g(x) )` where
//!   g = gcd(h, r): the h-dim input is partitioned into g groups of
//!   h/g, averaged within each group (the paper's (g/h)·Σ term), and
//!   the g-dim result is repeat-concatenated to dimension r.
//! - `U2(x') = x'·ℓ2 + β2 · tile_{o/g'}( groupavg_{g'}(x') )` with
//!   g' = gcd(o, r); when r | o this degenerates to plain repetition
//!   of x' (Eq. 14).
//!
//! β1/β2 are layerwise learnable scalars (2 params per layer — the
//! whole storage cost of IEC, Table 6).

/// Greatest common divisor.
pub fn gcd(a: usize, b: usize) -> usize {
    let (mut a, mut b) = (a, b);
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

/// Group-average a `dim_in`-vector into `groups` equal segments
/// (average within each segment), then tile the result to `dim_out`.
/// Requires groups | dim_in and groups | dim_out.
pub fn groupavg_tile(x: &[f32], groups: usize, dim_out: usize) -> Vec<f32> {
    let dim_in = x.len();
    assert!(groups > 0 && dim_in % groups == 0 && dim_out % groups == 0,
        "groupavg_tile: dim_in={dim_in} groups={groups} dim_out={dim_out}");
    let seg = dim_in / groups;
    let scale = 1.0 / seg as f32;
    let mut pooled = vec![0f32; groups];
    for (g, p) in pooled.iter_mut().enumerate() {
        let mut s = 0.0;
        for &v in &x[g * seg..(g + 1) * seg] {
            s += v;
        }
        *p = s * scale;
    }
    let reps = dim_out / groups;
    let mut out = Vec::with_capacity(dim_out);
    for _ in 0..reps {
        out.extend_from_slice(&pooled);
    }
    out
}

/// The parameter-free term of U1 (Eq. 12): dim h -> dim r.
pub fn u1_elastic(x: &[f32], r: usize) -> Vec<f32> {
    let h = x.len();
    groupavg_tile(x, gcd(h, r), r)
}

/// The parameter-free term of U2 (Eq. 13): dim r -> dim o.
pub fn u2_elastic(xp: &[f32], o: usize) -> Vec<f32> {
    let r = xp.len();
    groupavg_tile(xp, gcd(o, r), o)
}

/// Full IEC LoRA forward for a single example (Eq. 15):
/// `out = α · U2(U1(x))`, with the elastic terms gated by masks
/// (m1, m2) so one code path serves Vanilla/(U1)/(U2)/full ablations.
///
/// `l1` is (h×r) row-major, `l2` is (r×o) row-major.
#[allow(clippy::too_many_arguments)]
pub fn lora_iec_forward(
    x: &[f32],
    l1: &[f32],
    l2: &[f32],
    r: usize,
    o: usize,
    alpha: f32,
    beta1: f32,
    beta2: f32,
    m1: f32,
    m2: f32,
) -> Vec<f32> {
    let h = x.len();
    assert_eq!(l1.len(), h * r, "l1 must be h x r");
    assert_eq!(l2.len(), r * o, "l2 must be r x o");

    // U1
    let mut xp = vec![0f32; r];
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &l1[i * r..(i + 1) * r];
        for j in 0..r {
            xp[j] += xi * row[j];
        }
    }
    if m1 != 0.0 && beta1 != 0.0 {
        let el = u1_elastic(x, r);
        for j in 0..r {
            xp[j] += m1 * beta1 * el[j];
        }
    }

    // U2
    let mut y = vec![0f32; o];
    for (i, &xi) in xp.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &l2[i * o..(i + 1) * o];
        for j in 0..o {
            y[j] += xi * row[j];
        }
    }
    if m2 != 0.0 && beta2 != 0.0 {
        let el = u2_elastic(&xp, o);
        for j in 0..o {
            y[j] += m2 * beta2 * el[j];
        }
    }

    for v in &mut y {
        *v *= alpha;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn gcd_basic() {
        assert_eq!(gcd(4096, 64), 64);
        assert_eq!(gcd(64, 4096), 64);
        assert_eq!(gcd(7, 3), 1);
        assert_eq!(gcd(12, 18), 6);
        assert_eq!(gcd(5, 0), 5);
    }

    #[test]
    fn u1_simplified_case() {
        // r | h: per Eq. 14, output j is the mean of segment j of size h/r
        let h = 8;
        let r = 4;
        let x: Vec<f32> = (0..h).map(|i| i as f32).collect();
        let e = u1_elastic(&x, r);
        assert_eq!(e.len(), r);
        assert_eq!(e, vec![0.5, 2.5, 4.5, 6.5]);
    }

    #[test]
    fn u2_simplified_case() {
        // r | o: plain repetition of x'
        let xp = vec![1.0f32, 2.0, 3.0, 4.0];
        let e = u2_elastic(&xp, 8);
        assert_eq!(e, vec![1.0, 2.0, 3.0, 4.0, 1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn non_multiple_dims_use_gcd() {
        // h=6, r=4 -> g=2: pool to 2 groups of 3, tile twice
        let x = vec![1.0f32, 2.0, 3.0, 10.0, 11.0, 12.0];
        let e = u1_elastic(&x, 4);
        assert_eq!(e, vec![2.0, 11.0, 2.0, 11.0]);
        // o=6, r=4 -> g=2: pool x' (len 4) into 2 groups of 2, tile 3x
        let xp = vec![1.0f32, 3.0, 5.0, 7.0];
        let e2 = u2_elastic(&xp, 6);
        assert_eq!(e2, vec![2.0, 6.0, 2.0, 6.0, 2.0, 6.0]);
    }

    #[test]
    fn mean_preserving() {
        // group-averaging + tiling preserves the global mean
        let mut rng = Rng::new(61);
        let x: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
        let e = u1_elastic(&x, 16);
        let m_in: f32 = x.iter().sum::<f32>() / 64.0;
        let m_out: f32 = e.iter().sum::<f32>() / 16.0;
        assert!((m_in - m_out).abs() < 1e-5);
    }

    #[test]
    fn masks_gate_elastic_terms() {
        let mut rng = Rng::new(62);
        let (h, r, o) = (16, 4, 8);
        let x = rng.normal_vec(h, 0.0, 1.0);
        let l1 = rng.normal_vec(h * r, 0.0, 0.1);
        let l2 = rng.normal_vec(r * o, 0.0, 0.1);
        let vanilla = lora_iec_forward(&x, &l1, &l2, r, o, 1.0, 0.5, 0.5, 0.0, 0.0);
        let full = lora_iec_forward(&x, &l1, &l2, r, o, 1.0, 0.5, 0.5, 1.0, 1.0);
        assert_ne!(vanilla, full);
        // beta = 0 equals masked-off
        let beta0 = lora_iec_forward(&x, &l1, &l2, r, o, 1.0, 0.0, 0.0, 1.0, 1.0);
        for (a, b) in vanilla.iter().zip(&beta0) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn vanilla_matches_plain_lora() {
        let mut rng = Rng::new(63);
        let (h, r, o) = (12, 3, 6);
        let x = rng.normal_vec(h, 0.0, 1.0);
        let l1 = rng.normal_vec(h * r, 0.0, 0.2);
        let l2 = rng.normal_vec(r * o, 0.0, 0.2);
        let got = lora_iec_forward(&x, &l1, &l2, r, o, 2.0, 0.7, 0.7, 0.0, 0.0);
        // oracle: alpha * x l1 l2
        let mut xp = vec![0f32; r];
        for i in 0..h {
            for j in 0..r {
                xp[j] += x[i] * l1[i * r + j];
            }
        }
        let mut want = vec![0f32; o];
        for i in 0..r {
            for j in 0..o {
                want[j] += xp[i] * l2[i * o + j];
            }
        }
        for (g, w) in got.iter().zip(want.iter().map(|v| v * 2.0)) {
            assert!((g - w).abs() < 1e-5);
        }
    }

    #[test]
    fn paper_dims_shape_check() {
        // the paper's running example: h=o=4096, r=64
        let mut rng = Rng::new(64);
        let x = rng.normal_vec(4096, 0.0, 1.0);
        let e1 = u1_elastic(&x, 64);
        assert_eq!(e1.len(), 64);
        let e2 = u2_elastic(&e1, 4096);
        assert_eq!(e2.len(), 4096);
        // e2 is 64 copies of e1
        assert_eq!(&e2[0..64], &e1[..]);
        assert_eq!(&e2[4032..4096], &e1[..]);
    }
}
