//! LoRA adapters with Information Elastic Connection (paper §3.3).
//!
//! - [`iec`]: the elastic transforms U1/U2 (Eq. 12–14) and the gated
//!   forward used by the ablation arms;
//! - [`merge`]: folding β1/β2 into ℓ̃1/ℓ̃2 for zero-cost inference
//!   (Eq. 16/17).
//!
//! [`LoraAdapter`] is the host-side state for one adapted projection;
//! the actual finetuning math runs inside the AOT train-step graph —
//! this struct is what the coordinator initializes, checkpoints, and
//! uploads as device buffers.

pub mod iec;
pub mod merge;

use crate::util::Rng;

/// Host-side LoRA + IEC state for one linear projection (h → o).
#[derive(Clone, Debug)]
pub struct LoraAdapter {
    pub h: usize,
    pub o: usize,
    pub r: usize,
    /// ℓ1, h×r row-major. Kaiming-ish init.
    pub l1: Vec<f32>,
    /// ℓ2, r×o row-major. Zero init (standard LoRA).
    pub l2: Vec<f32>,
    /// Scaling α (paper default 16).
    pub alpha: f32,
    /// IEC layerwise scalars (learnable; init 0 so finetuning starts
    /// exactly at the vanilla-LoRA function).
    pub beta1: f32,
    pub beta2: f32,
}

impl LoraAdapter {
    /// Standard initialization: ℓ1 ~ N(0, 1/r), ℓ2 = 0, β = 0.
    pub fn init(h: usize, o: usize, r: usize, alpha: f32, rng: &mut Rng) -> LoraAdapter {
        let std = 1.0 / (r as f32).sqrt();
        LoraAdapter {
            h,
            o,
            r,
            l1: rng.normal_vec(h * r, 0.0, std),
            l2: vec![0.0; r * o],
            alpha,
            beta1: 0.0,
            beta2: 0.0,
        }
    }

    /// Trainable parameter count (the paper's efficiency argument:
    /// IEC adds exactly 2 scalars per adapted projection).
    pub fn n_params(&self) -> usize {
        self.h * self.r + self.r * self.o + 2
    }

    /// Forward for a single example, with IEC gating masks.
    pub fn forward(&self, x: &[f32], m1: f32, m2: f32) -> Vec<f32> {
        iec::lora_iec_forward(
            x, &self.l1, &self.l2, self.r, self.o, self.alpha, self.beta1, self.beta2,
            m1, m2,
        )
    }

    /// Produce inference-time merged matrices (ℓ̃1, ℓ̃2): IEC folded in.
    pub fn merged(&self) -> (Vec<f32>, Vec<f32>) {
        let mut m1 = Vec::new();
        let mut m2 = Vec::new();
        self.merged_into(&mut m1, &mut m2);
        (m1, m2)
    }

    /// Allocation-free [`Self::merged`]: writes into reused buffers so
    /// a serving loop re-merging many adapters recycles one scratch
    /// pair instead of allocating per projection.
    pub fn merged_into(&self, m1: &mut Vec<f32>, m2: &mut Vec<f32>) {
        merge::merge_l1_into(&self.l1, self.h, self.r, self.beta1, m1);
        merge::merge_l2_into(&self.l2, self.r, self.o, self.beta2, m2);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_shapes_and_zero_output() {
        let mut rng = Rng::new(81);
        let a = LoraAdapter::init(32, 16, 4, 16.0, &mut rng);
        assert_eq!(a.l1.len(), 128);
        assert_eq!(a.l2.len(), 64);
        // l2 = 0 and beta = 0 => adapter output is exactly zero at init
        let x = rng.normal_vec(32, 0.0, 1.0);
        let y = a.forward(&x, 1.0, 1.0);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn param_count() {
        let mut rng = Rng::new(82);
        let a = LoraAdapter::init(64, 32, 8, 16.0, &mut rng);
        assert_eq!(a.n_params(), 64 * 8 + 8 * 32 + 2);
    }

    #[test]
    fn merged_equals_forward_after_training_sim() {
        let mut rng = Rng::new(83);
        let mut a = LoraAdapter::init(24, 12, 6, 16.0, &mut rng);
        // simulate finetuned state
        a.l2 = rng.normal_vec(6 * 12, 0.0, 0.1);
        a.beta1 = 0.4;
        a.beta2 = -0.3;
        let x = rng.normal_vec(24, 0.0, 1.0);
        let explicit = a.forward(&x, 1.0, 1.0);
        let (m1, m2) = a.merged();
        let merged = iec::lora_iec_forward(
            &x, &m1, &m2, a.r, a.o, a.alpha, 0.0, 0.0, 0.0, 0.0,
        );
        for (e, m) in explicit.iter().zip(&merged) {
            assert!((e - m).abs() < 1e-4, "{e} vs {m}");
        }
    }
}
