//! Training drivers: pre-training (full parameters) and QLoRA
//! finetuning (frozen base + LoRA/IEC) over the AOT train-step graphs.
//!
//! The finetuning trainer uploads the (large, frozen) base weights to
//! the device once; each step moves only the batch and the small
//! LoRA + AdamW state. Optimizer math (AdamW, grad clip, LR) lives
//! inside the graph — Rust just threads state.

use anyhow::{bail, Context, Result};

use crate::model::weights::{self, NamedTensors};
use crate::runtime::{Executor, HostTensor, Manifest, Runtime};
use crate::util::{Rng, Tensor};

/// Graph-input layout of a train_step graph (see aot.py):
/// base(nb) | lora(nl) | m(nl) | v(nl) | step m1 m2 tokens targets.
pub fn train_layout(n_inputs: usize, nb: usize) -> Result<usize> {
    let rest = n_inputs
        .checked_sub(nb + 5)
        .context("train graph has too few inputs")?;
    if rest % 3 != 0 {
        bail!("train graph input count {n_inputs} inconsistent with nb={nb}");
    }
    Ok(rest / 3)
}

/// Layout of a pretrain graph: params(nb) | m(nb) | v(nb) | step tokens targets.
pub fn pretrain_layout(n_inputs: usize) -> Result<usize> {
    let rest = n_inputs.checked_sub(3).context("too few inputs")?;
    if rest % 3 != 0 {
        bail!("pretrain graph input count {n_inputs} not 3n+3");
    }
    Ok(rest / 3)
}

fn tensors_to_hosts(nt: &NamedTensors) -> Vec<HostTensor> {
    nt.tensors()
        .iter()
        .map(|t| HostTensor::F32(t.data().to_vec()))
        .collect()
}

fn update_from_hosts(nt: &mut NamedTensors, outs: &[HostTensor]) -> Result<()> {
    let names: Vec<String> = nt.names().to_vec();
    for (name, out) in names.iter().zip(outs) {
        let shape = nt.get(name)?.shape().to_vec();
        nt.set(name, Tensor::new(&shape, out.as_f32()?.to_vec()))?;
    }
    Ok(())
}

/// Full-parameter pre-training driver.
pub struct Pretrainer<'rt> {
    exe: Executor<'rt>,
    pub params: NamedTensors,
    m: NamedTensors,
    v: NamedTensors,
    step: usize,
    pub losses: Vec<f32>,
}

impl<'rt> Pretrainer<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        manifest: &Manifest,
        tag: &str,
        seed: u64,
    ) -> Result<Self> {
        let spec = manifest.graph(tag, "pretrain_step")?;
        let nb = pretrain_layout(spec.inputs.len())?;
        let cfg = &manifest.size(tag)?.config;
        let mut rng = Rng::new(seed);
        let params = weights::init_base(&spec.inputs[..nb], cfg.n_layers, &mut rng);
        let m = weights::zeros_like(&spec.inputs[..nb]);
        let v = weights::zeros_like(&spec.inputs[..nb]);
        let exe = rt.load(spec)?;
        Ok(Pretrainer { exe, params, m, v, step: 0, losses: Vec::new() })
    }

    pub fn step(&mut self, tokens: Vec<i32>, targets: Vec<i32>) -> Result<f32> {
        self.step += 1;
        let mut inputs = tensors_to_hosts(&self.params);
        inputs.extend(tensors_to_hosts(&self.m));
        inputs.extend(tensors_to_hosts(&self.v));
        inputs.push(HostTensor::F32(vec![self.step as f32]));
        inputs.push(HostTensor::I32(tokens));
        inputs.push(HostTensor::I32(targets));
        let outs = self.exe.call(&inputs)?;
        let loss = outs[0].as_f32()?[0];
        let n = self.params.len();
        update_from_hosts(&mut self.params, &outs[1..1 + n])?;
        update_from_hosts(&mut self.m, &outs[1 + n..1 + 2 * n])?;
        update_from_hosts(&mut self.v, &outs[1 + 2 * n..1 + 3 * n])?;
        self.losses.push(loss);
        Ok(loss)
    }
}

/// QLoRA finetuning driver (frozen quantized base, trainable LoRA+IEC).
pub struct Finetuner<'rt> {
    exe: Executor<'rt>,
    base_bufs: Vec<xla::PjRtBuffer>,
    nb: usize,
    pub lora: NamedTensors,
    m: NamedTensors,
    v: NamedTensors,
    step: usize,
    /// IEC gating masks (m1, m2): (0,0) = vanilla QLoRA … (1,1) = IR-QLoRA.
    pub masks: (f32, f32),
    pub losses: Vec<f32>,
}

impl<'rt> Finetuner<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        manifest: &Manifest,
        tag: &str,
        base: &NamedTensors,
        masks: (f32, f32),
        seed: u64,
    ) -> Result<Self> {
        let spec = manifest.graph(tag, "train_step")?;
        let nb = base.len();
        let nl = train_layout(spec.inputs.len(), nb)?;
        let cfg = &manifest.size(tag)?.config;
        // sanity: the base tensor names must match the graph's inputs
        for (i, s) in spec.inputs[..nb].iter().enumerate() {
            if base.names()[i] != s.name {
                bail!(
                    "base weight order mismatch at {i}: '{}' vs graph '{}'",
                    base.names()[i],
                    s.name
                );
            }
        }
        let mut rng = Rng::new(seed);
        let lora = weights::init_lora(&spec.inputs[nb..nb + nl], cfg.rank, &mut rng);
        let m = weights::zeros_like(&spec.inputs[nb..nb + nl]);
        let v = weights::zeros_like(&spec.inputs[nb..nb + nl]);

        let exe = rt.load(spec)?;
        // upload the frozen base once
        let mut base_bufs = Vec::with_capacity(nb);
        for (i, t) in base.tensors().iter().enumerate() {
            base_bufs.push(exe.upload_one(i, &HostTensor::F32(t.data().to_vec()))?);
        }
        Ok(Finetuner {
            exe,
            base_bufs,
            nb,
            lora,
            m,
            v,
            step: 0,
            masks,
            losses: Vec::new(),
        })
    }

    pub fn n_trainable(&self) -> usize {
        self.lora.total_params()
    }

    pub fn step(&mut self, tokens: Vec<i32>, targets: Vec<i32>) -> Result<f32> {
        self.step += 1;
        let nl = self.lora.len();
        // upload the small mutable state + batch
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(3 * nl + 5);
        let mut slot = self.nb;
        for nt in [&self.lora, &self.m, &self.v] {
            for t in nt.tensors() {
                bufs.push(
                    self.exe
                        .upload_one(slot, &HostTensor::F32(t.data().to_vec()))?,
                );
                slot += 1;
            }
        }
        bufs.push(self.exe.upload_one(slot, &HostTensor::F32(vec![self.step as f32]))?);
        bufs.push(self.exe.upload_one(slot + 1, &HostTensor::F32(vec![self.masks.0]))?);
        bufs.push(self.exe.upload_one(slot + 2, &HostTensor::F32(vec![self.masks.1]))?);
        bufs.push(self.exe.upload_one(slot + 3, &HostTensor::I32(tokens))?);
        bufs.push(self.exe.upload_one(slot + 4, &HostTensor::I32(targets))?);

        let mut all: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.nb + bufs.len());
        all.extend(self.base_bufs.iter());
        all.extend(bufs.iter());
        let outs = self.exe.execute(&all)?;

        let loss = outs[0].as_f32()?[0];
        update_from_hosts(&mut self.lora, &outs[1..1 + nl])?;
        update_from_hosts(&mut self.m, &outs[1 + nl..1 + 2 * nl])?;
        update_from_hosts(&mut self.v, &outs[1 + 2 * nl..1 + 3 * nl])?;
        self.losses.push(loss);
        Ok(loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layouts() {
        // nb=38, nl=57: 38 + 3*57 + 5 = 214... synthetic check
        assert_eq!(train_layout(38 + 3 * 57 + 5, 38).unwrap(), 57);
        assert_eq!(pretrain_layout(3 * 38 + 3).unwrap(), 38);
        assert!(train_layout(10, 38).is_err());
        assert!(pretrain_layout(5).is_err());
    }
}
