//! Typed serving-failure taxonomy for the pool/server stack.
//!
//! Every way a serving request can fail is one [`ServeError`] variant,
//! so callers can dispatch on the *kind* of failure instead of
//! grepping message strings (which is what the pre-taxonomy
//! `Result<_, String>` reply channel forced). The variants split along
//! the axis a front door actually cares about — **is retrying this
//! request useful?** ([`ServeError::retryable`]):
//!
//! | variant            | meaning                                   | retry? |
//! |--------------------|-------------------------------------------|--------|
//! | `Rejected`         | the request itself is bad (malformed      | no     |
//! |                    | prompt, unknown/evicted adapter)          |        |
//! | `Overloaded`       | admission control refused it: the bounded | yes,   |
//! |                    | parked overflow is full                   | later  |
//! | `DeadlineExceeded` | its per-request deadline passed before a  | no —   |
//! |                    | forward ran (shed, not executed)          | budget |
//! |                    |                                           | is gone|
//! | `WorkerDead`       | a worker died under it (panicking         | yes —  |
//! |                    | backend); other workers may be healthy    | reroute|
//! | `BackendFault`     | the forward itself errored (transient or  | maybe  |
//! |                    | not — the backend's message says)         |        |
//! | `Shutdown`         | the pool has no alive workers / is gone   | no     |
//!
//! The error crosses threads (it travels the reply channel from worker
//! to handle), so it is `Clone + Send + Sync` and carries owned
//! strings rather than borrowed sources. It implements
//! `std::error::Error`, so `?` in an `anyhow::Result` context converts
//! it transparently — existing callers keep working while typed
//! callers match on the variant.

use std::fmt;
use std::time::Duration;

/// Why a serving request failed — see the module docs for the
/// taxonomy and retryability table.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The request itself is invalid: malformed prompt, unknown
    /// adapter at submit, or an adapter evicted between submit and
    /// drain. Resubmitting the same request is pointless.
    Rejected(String),
    /// Admission control refused the request: its home worker is
    /// saturated AND the bounded parked overflow (`IRQLORA_PARK_BOUND`)
    /// is full. `depth` is the pool-wide parked count observed;
    /// `retry_after_hint` is a coarse estimate of when capacity may
    /// free up (queue depth × batch window) — retry after it.
    Overloaded {
        depth: usize,
        retry_after_hint: Duration,
    },
    /// The request's deadline passed before any forward ran for it;
    /// it was shed (at submit, in the parked overflow, or in the
    /// drain) instead of executing dead work. `waited` is how long it
    /// had been queued when shed.
    DeadlineExceeded { waited: Duration },
    /// A worker died under the request (panicking backend, exited
    /// thread). `worker` is the routing target when one can be blamed;
    /// `None` for parked requests, which any worker may have pulled.
    /// Other workers may be healthy — resubmitting reroutes.
    WorkerDead {
        worker: Option<usize>,
        reason: String,
    },
    /// The backend's forward call itself failed (the worker survived).
    /// The message is the backend's own; whether a retry helps depends
    /// on it (transient device hiccup vs deterministic shape error).
    BackendFault(String),
    /// The pool is shut down or every worker is dead; nothing will
    /// serve a resubmit.
    Shutdown,
}

impl ServeError {
    /// Is resubmitting this request potentially useful? `Overloaded`
    /// (after the hint) and `WorkerDead` (reroutes to a live worker)
    /// are; `Rejected`/`DeadlineExceeded`/`Shutdown` are not, and
    /// `BackendFault` is conservatively treated as not (the backend's
    /// message must be consulted to know better).
    pub fn retryable(&self) -> bool {
        matches!(
            self,
            ServeError::Overloaded { .. } | ServeError::WorkerDead { .. }
        )
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Rejected(msg) => write!(f, "{msg}"),
            ServeError::Overloaded { depth, retry_after_hint } => write!(
                f,
                "pool overloaded: parked overflow full ({depth} parked); \
                 retry after ~{}ms",
                retry_after_hint.as_millis()
            ),
            ServeError::DeadlineExceeded { waited } => write!(
                f,
                "deadline exceeded: request shed after waiting {waited:?} \
                 without reaching a forward"
            ),
            ServeError::WorkerDead { worker: Some(w), reason } => {
                write!(f, "pool worker {w} died: {reason}")
            }
            ServeError::WorkerDead { worker: None, reason } => write!(f, "{reason}"),
            ServeError::BackendFault(msg) => write!(f, "backend fault: {msg}"),
            ServeError::Shutdown => {
                write!(f, "serving pool is shut down (no alive workers)")
            }
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retryability_split() {
        assert!(ServeError::Overloaded {
            depth: 3,
            retry_after_hint: Duration::from_millis(2)
        }
        .retryable());
        assert!(ServeError::WorkerDead { worker: Some(1), reason: "died".into() }
            .retryable());
        assert!(!ServeError::Rejected("bad prompt".into()).retryable());
        assert!(
            !ServeError::DeadlineExceeded { waited: Duration::from_millis(5) }.retryable()
        );
        assert!(!ServeError::BackendFault("oom".into()).retryable());
        assert!(!ServeError::Shutdown.retryable());
    }

    #[test]
    fn display_keeps_matchable_substrings() {
        // callers (and older tests) grep these words — keep them stable
        let s = ServeError::Rejected("unknown adapter 'x'".into()).to_string();
        assert!(s.contains("unknown adapter"));
        let s = ServeError::WorkerDead {
            worker: Some(2),
            reason: "died while serving adapter 'a'".into(),
        }
        .to_string();
        assert!(s.contains("died"));
        let s = ServeError::Overloaded {
            depth: 7,
            retry_after_hint: Duration::from_millis(4),
        }
        .to_string();
        assert!(s.contains("overloaded") && s.contains('7'));
        let s =
            ServeError::DeadlineExceeded { waited: Duration::from_millis(1) }.to_string();
        assert!(s.contains("deadline exceeded"));
        assert!(ServeError::BackendFault("x".into()).to_string().contains("backend fault"));
        assert!(ServeError::Shutdown.to_string().contains("shut down"));
    }

    #[test]
    fn converts_into_anyhow() {
        fn takes_anyhow() -> anyhow::Result<()> {
            Err(ServeError::Shutdown)?;
            Ok(())
        }
        let err = takes_anyhow().unwrap_err();
        assert!(format!("{err:#}").contains("shut down"));
    }
}
