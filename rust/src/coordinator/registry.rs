//! Multi-adapter registry: one shared dequantized base, many cheap
//! per-tenant IEC-LoRA adapters.
//!
//! QA-LoRA / LoftQ / IR-QLoRA all share the same serving economics:
//! the quantized base is the expensive, shared artifact while each
//! adapter is two small matrices per projection plus two scalars per
//! layer. The base's bit-widths never reach this layer — uniform-k
//! and mixed-k (`precision::PrecisionPlan`-driven) models hand over
//! the same dequantized f32 tensors. The registry exploits that structure — the base is
//! dequantized **once** (by `quantize_model`'s fused packed-domain
//! path) and held behind an `Arc`; adapters register by name and are
//! folded (IEC β1/β2 merged via Eq. 16/17, `lora::merge::merge_adapter`)
//! into serving-ready tensors on first use. Merged weights live in an
//! LRU-bounded cache so a long tail of tenants doesn't pin memory:
//! evicted adapters re-merge (bit-identically) on their next request.
//!
//! Adapter sources are either in-memory ([`AdapterRegistry::register`],
//! e.g. fresh out of a finetune run) or `.irqc` checkpoints
//! ([`AdapterRegistry::register_file`]) whose headers are validated
//! cheaply up front (`checkpoint::peek_entries`) and whose data loads
//! lazily on each cache miss.
//!
//! Cache capacity comes from the `IRQLORA_ADAPTER_CACHE` environment
//! variable (mirroring `IRQLORA_THREADS`: positive integers honored,
//! zero/garbage ignored), default [`DEFAULT_CACHE_CAPACITY`].
//!
//! Every lookup is generation-tagged ([`AdapterRegistry::merged_tagged`]):
//! the registration generation is a registry-wide monotonic id bumped
//! on every (re)register and **preserved** across evict/re-merge of an
//! unchanged source. That pair `(name, generation)` is the key the
//! serving backends build their device-side caches on — the
//! `PjrtBackend` adapter device-buffer LRU and the `ReferenceBackend`
//! fingerprint cache (see `coordinator::backend`) — which is what lets
//! a fused mixed-adapter batch reuse uploads across drains without any
//! pointer-ABA hazard. By default the device cache is sized to this
//! registry's merged-cache capacity, so the two tiers age together.

use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::lora::merge::merge_adapter;
use crate::model::checkpoint;
use crate::model::weights::{validate_adapter, validate_adapter_shapes, NamedTensors};

use super::error::ServeError;

/// Merged-weight cache capacity when `IRQLORA_ADAPTER_CACHE` is unset
/// (declared in `util::env` with the other knobs).
pub const DEFAULT_CACHE_CAPACITY: usize = crate::util::env::DEFAULT_ADAPTER_CACHE;

/// How many times [`AdapterRegistry::merged_tagged`] re-merges when a
/// concurrent re-register keeps invalidating its work before it gives
/// up and returns the last (self-consistent) result.
pub const MAX_MERGE_RETRIES: usize = 3;

/// Resolve the merged-cache capacity: the `IRQLORA_ADAPTER_CACHE`
/// override, else [`DEFAULT_CACHE_CAPACITY`]. Reads through
/// `util::env`.
pub fn cache_capacity() -> usize {
    crate::util::env::adapter_cache()
}

/// Interpret an `IRQLORA_ADAPTER_CACHE` value: positive integers are
/// honored (capped at 4096); zero and garbage are ignored (parse in
/// `util::env`).
#[cfg(test)]
fn parse_cache_override(v: &str) -> Option<usize> {
    crate::util::env::parse_count(v, crate::util::env::CACHE_CAP)
}

/// Where an adapter's raw (unmerged) tensors live.
#[derive(Clone)]
enum AdapterSource {
    /// Registered in-memory.
    Memory(Arc<NamedTensors>),
    /// An `.irqc` checkpoint, reloaded lazily on each cache miss.
    File(PathBuf),
}

/// Monotonic cache counters.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RegistryStats {
    /// Lookups served from the merged cache.
    pub hits: usize,
    /// Lookups that merged (and possibly reloaded) the adapter.
    pub misses: usize,
    /// Merged entries dropped to stay within capacity.
    pub evictions: usize,
}

struct Inner {
    /// name → (registration generation, raw tensors). The generation
    /// is a registry-wide monotonic id bumped on every (re)register,
    /// so backends can key device-side caches by (name, generation)
    /// without pointer-address ABA hazards.
    sources: BTreeMap<String, (u64, AdapterSource)>,
    merged: BTreeMap<String, (u64, Arc<NamedTensors>)>,
    /// LRU order over `merged` keys: front = coldest.
    order: VecDeque<String>,
    next_gen: u64,
    stats: RegistryStats,
}

impl Inner {
    fn touch(&mut self, name: &str) {
        if let Some(pos) = self.order.iter().position(|n| n == name) {
            let n = self.order.remove(pos).unwrap();
            self.order.push_back(n);
        }
    }

    fn drop_merged(&mut self, name: &str) {
        if self.merged.remove(name).is_some() {
            if let Some(pos) = self.order.iter().position(|n| n == name) {
                self.order.remove(pos);
            }
        }
    }
}

/// Named IEC-LoRA adapters over one shared dequantized base, with an
/// LRU-bounded cache of serving-ready (merged) weights. All methods
/// take `&self`; the registry is safe to share behind an `Arc`
/// between submitters and the serving worker.
pub struct AdapterRegistry {
    base: Arc<NamedTensors>,
    masks: (f32, f32),
    capacity: usize,
    inner: Mutex<Inner>,
}

impl AdapterRegistry {
    /// Registry over `base` with the [`cache_capacity`] env default.
    /// `masks` is the IEC gating the adapters were trained under; it
    /// is folded into each adapter at merge time (after which serving
    /// runs mask-free).
    pub fn new(base: NamedTensors, masks: (f32, f32)) -> AdapterRegistry {
        Self::with_capacity(base, masks, cache_capacity())
    }

    /// Registry with an explicit merged-cache capacity (min 1).
    pub fn with_capacity(
        base: NamedTensors,
        masks: (f32, f32),
        capacity: usize,
    ) -> AdapterRegistry {
        AdapterRegistry {
            base: Arc::new(base),
            masks,
            capacity: capacity.max(1),
            inner: Mutex::new(Inner {
                sources: BTreeMap::new(),
                merged: BTreeMap::new(),
                order: VecDeque::new(),
                next_gen: 0,
                stats: RegistryStats::default(),
            }),
        }
    }

    /// The shared dequantized base every adapter serves over.
    pub fn base(&self) -> &Arc<NamedTensors> {
        &self.base
    }

    /// IEC masks folded into adapters at merge time.
    pub fn masks(&self) -> (f32, f32) {
        self.masks
    }

    /// Merged-cache capacity (adapters beyond this re-merge on demand).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Register an in-memory adapter under `name`, replacing any
    /// previous adapter of that name (and dropping its cached merge).
    pub fn register(&self, name: &str, adapter: NamedTensors) -> Result<()> {
        validate_adapter(&adapter)
            .with_context(|| format!("registering adapter '{name}'"))?;
        self.insert_source(name, AdapterSource::Memory(Arc::new(adapter)));
        Ok(())
    }

    /// Register a checkpoint-backed adapter: the header is validated
    /// now (cheap, via [`checkpoint::peek_entries`] — no tensor data
    /// is read); the data loads lazily on each merged-cache miss.
    pub fn register_file(&self, name: &str, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let entries = checkpoint::peek_entries(path)?;
        validate_adapter_shapes(&entries).with_context(|| {
            format!("registering adapter '{name}' from {}", path.display())
        })?;
        self.insert_source(name, AdapterSource::File(path.to_path_buf()));
        Ok(())
    }

    fn insert_source(&self, name: &str, src: AdapterSource) {
        let mut inner = self.inner.lock().unwrap();
        let g = inner.next_gen;
        inner.next_gen += 1;
        inner.sources.insert(name.to_string(), (g, src));
        inner.drop_merged(name);
    }

    /// Registration generation of `name` (bumped on every
    /// (re)register), if registered.
    pub fn generation(&self, name: &str) -> Option<u64> {
        self.inner.lock().unwrap().sources.get(name).map(|(g, _)| *g)
    }

    /// Is `name` registered (regardless of cache state)?
    pub fn contains(&self, name: &str) -> bool {
        self.inner.lock().unwrap().sources.contains_key(name)
    }

    /// Registered adapter names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.inner.lock().unwrap().sources.keys().cloned().collect()
    }

    /// Number of registered adapters.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().sources.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop `name`'s cached merged weights; the source stays, so the
    /// next lookup re-merges. No-op when not cached.
    pub fn evict(&self, name: &str) {
        self.inner.lock().unwrap().drop_merged(name);
    }

    /// Remove an adapter entirely (source + cached merge). Returns
    /// whether it was registered.
    pub fn remove(&self, name: &str) -> bool {
        let mut inner = self.inner.lock().unwrap();
        inner.drop_merged(name);
        inner.sources.remove(name).is_some()
    }

    /// Cache counters so far.
    pub fn stats(&self) -> RegistryStats {
        self.inner.lock().unwrap().stats.clone()
    }

    /// Serving weights for `name`: the IEC-merged LoRA tensors, from
    /// cache when warm. Merging is deterministic, so an evict/reload
    /// round-trip yields bit-identical weights.
    pub fn merged(&self, name: &str) -> Result<Arc<NamedTensors>> {
        self.merged_tagged(name).map(|(_, w)| w)
    }

    /// [`Self::merged`] plus the adapter's registration generation —
    /// the cache key backends use for device-side buffers (stable
    /// across evict/re-merge of the same source, bumped on
    /// re-register; unlike `Arc` addresses it cannot suffer ABA
    /// reuse). The expensive part of a miss (checkpoint reload +
    /// merge) runs *outside* the registry lock so concurrent
    /// `submit()` calls never stall behind disk I/O; a raced
    /// duplicate merge of one generation is tolerated (both results
    /// are bit-identical) and the cache may evict its coldest entry
    /// on insert.
    ///
    /// Freshness: the generation is re-read under the cache lock
    /// before the result is committed. If a concurrent `register`
    /// replaced the source while the merge ran, the stale merge is
    /// discarded and the lookup retries against the new source —
    /// callers never receive a (generation, weights) pair older than
    /// the registration that was current when the result was
    /// determined. (Before this check-and-retry, a lookup racing a
    /// re-register could hand back the *previous* generation's
    /// weights even though the new registration had already
    /// completed.) Retries are bounded: under a pathological register
    /// storm (every merge outpaced by another re-register) the lookup
    /// gives up after [`MAX_MERGE_RETRIES`] and returns its last
    /// merge — still a self-consistent (generation, weights) pair,
    /// just not the newest, and never cached — rather than livelock
    /// the serving worker. A removal racing the merge surfaces as
    /// "unknown adapter", same as a lookup after the removal.
    pub fn merged_tagged(&self, name: &str) -> Result<(u64, Arc<NamedTensors>)> {
        let mut attempts = 0usize;
        loop {
            let (generation, src) = {
                let mut inner = self.inner.lock().unwrap();
                if let Some((g, m)) = inner.merged.get(name).cloned() {
                    // a retry that finds another thread's commit is
                    // still the same logical lookup — it already
                    // counted its miss, so don't also count a hit
                    if attempts == 0 {
                        inner.stats.hits += 1;
                        telem_merge_cache()[0].inc();
                    }
                    inner.touch(name);
                    return Ok((g, m));
                }
                if attempts == 0 {
                    // one logical lookup = at most one miss, however
                    // many times a racing re-register forces a re-merge
                    inner.stats.misses += 1;
                    telem_merge_cache()[1].inc();
                }
                match inner.sources.get(name) {
                    Some((g, s)) => (*g, s.clone()),
                    None => {
                        return Err(anyhow!(
                            "unknown adapter '{name}' (registered: {:?})",
                            inner.sources.keys().collect::<Vec<_>>()
                        ))
                    }
                }
            };

            // expensive section — no lock held
            let raw: Arc<NamedTensors> = match src {
                AdapterSource::Memory(a) => a,
                AdapterSource::File(p) => Arc::new(
                    checkpoint::load(&p)
                        .with_context(|| format!("reloading adapter '{name}'"))?,
                ),
            };
            let merged = Arc::new(
                merge_adapter(&raw, self.masks)
                    .with_context(|| format!("merging adapter '{name}'"))?,
            );

            let mut inner = self.inner.lock().unwrap();
            // another thread merged the same generation while we worked?
            if let Some((g, m)) = inner.merged.get(name).cloned() {
                if g == generation {
                    inner.touch(name);
                    return Ok((g, m));
                }
            }
            // commit only while the source we merged is still the
            // registered one — checked under the same lock that
            // `register`/`evict` take, so the generation cannot move
            // between this check and the insert
            let source_gen = inner.sources.get(name).map(|(g, _)| *g);
            match source_gen {
                Some(g) if g == generation => {
                    inner.drop_merged(name);
                    inner.merged.insert(name.to_string(), (generation, merged.clone()));
                    inner.order.push_back(name.to_string());
                    while inner.merged.len() > self.capacity {
                        match inner.order.pop_front() {
                            Some(cold) => {
                                inner.merged.remove(&cold);
                                inner.stats.evictions += 1;
                                telem_merge_cache()[2].inc();
                            }
                            None => break,
                        }
                    }
                    return Ok((generation, merged));
                }
                // source replaced mid-merge: our merge is stale — drop
                // it and retry against the fresh source (bounded; see
                // the freshness note above)
                Some(_) if attempts < MAX_MERGE_RETRIES => {
                    attempts += 1;
                    continue;
                }
                Some(_) => return Ok((generation, merged)),
                None => {
                    return Err(anyhow!(
                        "unknown adapter '{name}' (removed during merge)"
                    ))
                }
            }
        }
    }

    /// [`Self::merged_tagged`] classified into the serving taxonomy:
    /// a failure because the adapter is not (or no longer) registered
    /// is the caller's problem — [`ServeError::Rejected`] — while a
    /// reload/merge failure of a *registered* adapter is
    /// infrastructure — [`ServeError::BackendFault`]. The full anyhow
    /// chain is flattened into the message either way, so existing
    /// substring matches ("unknown adapter", "reloading adapter")
    /// keep working.
    pub(crate) fn merged_for_serving(
        &self,
        name: &str,
    ) -> Result<(u64, Arc<NamedTensors>), ServeError> {
        self.merged_tagged(name).map_err(|e| {
            if !self.contains(name) {
                ServeError::Rejected(format!("{e:#}"))
            } else {
                ServeError::BackendFault(format!("{e:#}"))
            }
        })
    }
}

/// Cached merge-cache telemetry counters `[hit, miss, eviction]`,
/// mirrored at the exact sites that bump [`RegistryStats`] (no-ops
/// unless `IRQLORA_TELEMETRY=1`).
fn telem_merge_cache() -> &'static [crate::telemetry::Counter; 3] {
    static C: std::sync::OnceLock<[crate::telemetry::Counter; 3]> = std::sync::OnceLock::new();
    C.get_or_init(|| {
        let reg = crate::telemetry::global();
        ["hit", "miss", "eviction"].map(|ev| reg.counter("serve.merge_cache", &[("event", ev)]))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Rng, Tensor};

    fn adapter(seed: u64) -> NamedTensors {
        let mut rng = Rng::new(seed);
        let (h, r, o) = (16usize, 4usize, 8usize);
        let mut nt = NamedTensors::new();
        nt.push("l0.wq.lora_a", Tensor::new(&[h, r], rng.normal_vec(h * r, 0.0, 0.3)));
        nt.push("l0.wq.lora_b", Tensor::new(&[r, o], rng.normal_vec(r * o, 0.0, 0.3)));
        nt.push("betas", Tensor::new(&[1, 7, 2], rng.normal_vec(14, 0.0, 0.5)));
        nt
    }

    fn base() -> NamedTensors {
        let mut nt = NamedTensors::new();
        nt.push("embed", Tensor::full(&[4, 4], 0.5));
        nt
    }

    #[test]
    fn env_override_parsing() {
        assert_eq!(parse_cache_override("2"), Some(2));
        assert_eq!(parse_cache_override(" 16 "), Some(16));
        assert_eq!(parse_cache_override("999999"), Some(4096)); // capped
        assert_eq!(parse_cache_override("0"), None);
        assert_eq!(parse_cache_override("nope"), None);
        assert_eq!(parse_cache_override(""), None);
        assert!(cache_capacity() >= 1);
    }

    #[test]
    fn register_lookup_and_stats() {
        let reg = AdapterRegistry::with_capacity(base(), (1.0, 1.0), 4);
        assert!(reg.is_empty());
        reg.register("a", adapter(1)).unwrap();
        reg.register("b", adapter(2)).unwrap();
        assert_eq!(reg.len(), 2);
        assert!(reg.contains("a") && !reg.contains("c"));
        assert_eq!(reg.names(), vec!["a".to_string(), "b".to_string()]);

        let m1 = reg.merged("a").unwrap(); // miss
        let m2 = reg.merged("a").unwrap(); // hit — same Arc
        assert!(Arc::ptr_eq(&m1, &m2));
        assert_eq!(
            reg.stats(),
            RegistryStats { hits: 1, misses: 1, evictions: 0 }
        );
        // merged output folded the betas away
        assert!(m1.get("betas").unwrap().data().iter().all(|&x| x == 0.0));

        let err = reg.merged("missing").unwrap_err();
        assert!(format!("{err:#}").contains("unknown adapter"), "{err:#}");
    }

    #[test]
    fn lru_evicts_coldest_not_most_recent() {
        let reg = AdapterRegistry::with_capacity(base(), (0.0, 0.0), 2);
        for (n, s) in [("a", 1u64), ("b", 2), ("c", 3)] {
            reg.register(n, adapter(s)).unwrap();
        }
        reg.merged("a").unwrap();
        reg.merged("b").unwrap();
        reg.merged("a").unwrap(); // touch: LRU order now [b, a]
        reg.merged("c").unwrap(); // evicts b, not a
        let s = reg.stats();
        assert_eq!(s.evictions, 1);
        let before = reg.stats().hits;
        reg.merged("a").unwrap(); // still cached
        assert_eq!(reg.stats().hits, before + 1);
        reg.merged("b").unwrap(); // re-merge (was evicted)
        assert_eq!(reg.stats().misses, 4);
    }

    #[test]
    fn reregister_drops_stale_cache_and_bumps_generation() {
        let reg = AdapterRegistry::with_capacity(base(), (0.0, 0.0), 4);
        reg.register("a", adapter(1)).unwrap();
        let (g1, m1) = reg.merged_tagged("a").unwrap();
        assert_eq!(reg.generation("a"), Some(g1));
        reg.register("a", adapter(99)).unwrap(); // replace source
        let (g2, m2) = reg.merged_tagged("a").unwrap();
        // the generation moves, so backend device caches keyed on
        // (name, generation) can never serve the stale upload
        assert!(g2 > g1, "generation must bump on re-register: {g1} -> {g2}");
        assert!(!Arc::ptr_eq(&m1, &m2));
        assert_ne!(
            m1.get("l0.wq.lora_a").unwrap().data(),
            m2.get("l0.wq.lora_a").unwrap().data()
        );
        // evict + re-merge of an UNCHANGED source keeps its generation
        reg.evict("a");
        let (g3, _) = reg.merged_tagged("a").unwrap();
        assert_eq!(g2, g3);
        assert_eq!(reg.generation("missing"), None);
    }

    #[test]
    fn evict_and_remove() {
        let reg = AdapterRegistry::with_capacity(base(), (1.0, 1.0), 4);
        reg.register("a", adapter(5)).unwrap();
        let m1 = reg.merged("a").unwrap();
        reg.evict("a"); // cache dropped, source kept
        assert!(reg.contains("a"));
        let m2 = reg.merged("a").unwrap();
        assert!(!Arc::ptr_eq(&m1, &m2));
        for (name, t) in m1.iter() {
            assert_eq!(t.data(), m2.get(name).unwrap().data(), "{name}");
        }
        assert!(reg.remove("a"));
        assert!(!reg.remove("a"));
        assert!(reg.merged("a").is_err());
    }

    /// The kernel layer must not perturb the serving merge path:
    /// registry-cached merged weights are byte-identical to a direct
    /// `merge_adapter` call, and an evict/re-merge round trip
    /// reproduces the exact same bytes under the exact same cache key
    /// — `lora::merge` output is unchanged by `kernels` landing.
    #[test]
    fn merged_weights_byte_identical_to_direct_merge_across_round_trip() {
        let masks = (0.8f32, 1.3f32);
        let reg = AdapterRegistry::with_capacity(base(), masks, 4);
        reg.register("a", adapter(11)).unwrap();
        // adapter(seed) is deterministic, so this is the same source
        let direct = merge_adapter(&adapter(11), masks).unwrap();
        let (g1, m1) = reg.merged_tagged("a").unwrap();
        assert_eq!(m1.len(), direct.len());
        for (name, t) in direct.iter() {
            let got = m1.get(name).unwrap();
            assert_eq!(got.shape(), t.shape(), "{name}");
            for (i, (a, b)) in got.data().iter().zip(t.data()).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{name} slot {i}");
            }
        }
        reg.evict("a");
        let (g2, m2) = reg.merged_tagged("a").unwrap();
        assert_eq!(g1, g2, "evict/re-merge must keep the cache key");
        for (name, t) in m1.iter() {
            let got = m2.get(name).unwrap();
            for (i, (a, b)) in got.data().iter().zip(t.data()).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{name} slot {i}");
            }
        }
    }

    #[test]
    fn rejects_malformed_adapter() {
        let reg = AdapterRegistry::new(base(), (1.0, 1.0));
        let mut bad = NamedTensors::new();
        bad.push("l0.wq.lora_a", Tensor::zeros(&[8, 4]));
        assert!(reg.register("bad", bad).is_err());
        assert!(!reg.contains("bad"));
    }
}
