//! Serving execution backends: the forward-pass engines behind
//! [`super::server::BatchServer`], abstracted so the batching/routing
//! layer is independent of (and testable without) PJRT. Backends are
//! per-worker state: an N-worker [`super::pool::ServerPool`] builds
//! one backend per worker thread (N runtimes, N base uploads) while
//! the registry's merged-weight cache stays shared.
//!
//! - [`PjrtBackend`] runs the manifest's `forward` graph on a PJRT
//!   runtime it **owns** (an [`OwnedExecutor`] — the worker no longer
//!   `Box::leak`s a `Runtime` per spawn). The shared base uploads to
//!   the device once; the active adapter's merged tensors upload on
//!   adapter switch and are reused while consecutive batches stay on
//!   one adapter.
//! - [`ReferenceBackend`] is a deterministic host-side stand-in (no
//!   artifacts, no PJRT — it works in the offline stub build): logits
//!   are a fixed synthetic function of the shared base, the adapter
//!   weights, and the token prefix. Not a transformer — it exists to
//!   give routing tests and the offline bench smoke exactly the
//!   properties they check: adapter-sensitivity, prompt-sensitivity,
//!   and bit-exact determinism.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::data::PAD;
use crate::model::weights::NamedTensors;
use crate::runtime::{Manifest, OwnedExecutor, Runtime};

/// A batched forward engine: given one adapter's merged weights and a
/// padded `[batch, seq]` token matrix, produce `[batch, seq, vocab]`
/// next-token logits.
pub trait ServeBackend {
    /// (max rows per forward call, padded sequence length, vocab).
    fn shape(&self) -> (usize, usize, usize);

    /// Run one padded batch under `weights` (the merged tensors of
    /// adapter `name`, at registry registration `generation` — see
    /// `AdapterRegistry::merged_tagged`; backends may key device-side
    /// caches by `(name, generation)`). `tokens.len()` must equal
    /// `batch * seq`.
    fn forward(
        &mut self,
        name: &str,
        generation: u64,
        weights: &Arc<NamedTensors>,
        tokens: &[i32],
    ) -> Result<Vec<f32>>;
}

/// PJRT-backed [`ServeBackend`] over the manifest's `forward` graph.
pub struct PjrtBackend {
    exe: OwnedExecutor,
    base_bufs: Vec<xla::PjRtBuffer>,
    mask_bufs: [xla::PjRtBuffer; 2],
    adapter_bufs: Vec<xla::PjRtBuffer>,
    /// (adapter name, registration generation) the device-side
    /// adapter buffers currently hold; both must match to reuse. The
    /// generation is bumped by the registry on every re-register, so
    /// — unlike a pointer address — it cannot collide after a
    /// drop/realloc; and since merges of one generation are
    /// bit-identical, reuse across evict/re-merge is exact.
    cached: Option<(String, u64)>,
    nb: usize,
    nl: usize,
    batch: usize,
    seq: usize,
    vocab: usize,
}

impl PjrtBackend {
    /// Compile the `forward` graph on a fresh CPU runtime (owned by
    /// the returned value) and upload the shared base once. The IEC
    /// mask inputs are pinned to 0: registry adapters arrive
    /// pre-merged (Eq. 16/17), so the elastic path is off at serving.
    pub fn new(manifest: &Manifest, tag: &str, base: &NamedTensors) -> Result<PjrtBackend> {
        let spec = manifest.graph(tag, "forward")?;
        let cfg = &manifest.size(tag)?.config;
        let nb = base.len();
        let nl = spec
            .inputs
            .len()
            .checked_sub(nb + 3)
            .context("forward graph has fewer inputs than base + masks + tokens")?;
        let runtime = Arc::new(Runtime::cpu()?);
        let exe = runtime.load_owned(spec)?;
        let mut base_bufs = Vec::with_capacity(nb);
        for (i, t) in base.tensors().iter().enumerate() {
            // zero-copy upload: no per-tensor host clone
            base_bufs.push(exe.upload_f32(i, t.data())?);
        }
        let mask_bufs = [
            exe.upload_f32(nb + nl, &[0.0])?,
            exe.upload_f32(nb + nl + 1, &[0.0])?,
        ];
        Ok(PjrtBackend {
            exe,
            base_bufs,
            mask_bufs,
            adapter_bufs: Vec::new(),
            cached: None,
            nb,
            nl,
            batch: cfg.batch,
            seq: cfg.seq,
            vocab: cfg.vocab,
        })
    }
}

impl ServeBackend for PjrtBackend {
    fn shape(&self) -> (usize, usize, usize) {
        (self.batch, self.seq, self.vocab)
    }

    fn forward(
        &mut self,
        name: &str,
        generation: u64,
        weights: &Arc<NamedTensors>,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        if weights.len() != self.nl {
            bail!(
                "adapter '{name}' has {} tensors, forward graph expects {}",
                weights.len(),
                self.nl
            );
        }
        let reuse =
            matches!(&self.cached, Some((n, g)) if n == name && *g == generation);
        if !reuse {
            self.cached = None;
            self.adapter_bufs.clear();
            for (i, t) in weights.tensors().iter().enumerate() {
                self.adapter_bufs.push(self.exe.upload_f32(self.nb + i, t.data())?);
            }
            self.cached = Some((name.to_string(), generation));
        }
        let tok = self.exe.upload_i32(self.nb + self.nl + 2, tokens)?;
        let mut all: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.nb + self.nl + 3);
        all.extend(self.base_bufs.iter());
        all.extend(self.adapter_bufs.iter());
        all.push(&self.mask_bufs[0]);
        all.push(&self.mask_bufs[1]);
        all.push(&tok);
        let outs = self.exe.execute(&all)?;
        outs.into_iter()
            .next()
            .context("forward graph returned no outputs")?
            .into_f32()
    }
}

/// Deterministic host-side [`ServeBackend`] for routing tests and the
/// offline bench smoke (see module docs). Logit `[b, t, v]` is a
/// fixed function of the base fingerprint, the adapter fingerprint,
/// and the weighted non-PAD token prefix of row `b` up to `t` — rows
/// are independent, so a request's logits cannot depend on its
/// batchmates, and any change to adapter weights or prompt moves the
/// output.
pub struct ReferenceBackend {
    batch: usize,
    seq: usize,
    vocab: usize,
    base_fp: f64,
    /// Artificial per-forward latency, for tests that need requests to
    /// pile up behind a busy worker (shutdown/in-flight coverage).
    pub forward_delay: std::time::Duration,
}

impl ReferenceBackend {
    pub fn new(batch: usize, seq: usize, vocab: usize, base: &NamedTensors) -> ReferenceBackend {
        assert!(batch > 0 && seq > 0 && vocab > 0);
        ReferenceBackend {
            batch,
            seq,
            vocab,
            base_fp: fingerprint(base),
            forward_delay: std::time::Duration::ZERO,
        }
    }

    /// Builder-style `forward_delay` (handy inside the `move` backend
    /// factories servers and pools take).
    pub fn with_forward_delay(mut self, delay: std::time::Duration) -> ReferenceBackend {
        self.forward_delay = delay;
        self
    }
}

/// Order- and position-sensitive weighted sum over every tensor value:
/// any change anywhere in the collection moves it.
fn fingerprint(nt: &NamedTensors) -> f64 {
    let mut fp = 0f64;
    let mut i = 0u64;
    for t in nt.tensors() {
        for &v in t.data() {
            i += 1;
            fp += v as f64 * ((i % 127) + 1) as f64;
        }
    }
    fp
}

impl ServeBackend for ReferenceBackend {
    fn shape(&self) -> (usize, usize, usize) {
        (self.batch, self.seq, self.vocab)
    }

    fn forward(
        &mut self,
        _name: &str,
        _generation: u64,
        weights: &Arc<NamedTensors>,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        if tokens.len() != self.batch * self.seq {
            bail!(
                "token matrix has {} elems, expected batch*seq = {}",
                tokens.len(),
                self.batch * self.seq
            );
        }
        if !self.forward_delay.is_zero() {
            std::thread::sleep(self.forward_delay);
        }
        let afp = fingerprint(weights);
        let mut out = vec![0f32; self.batch * self.seq * self.vocab];
        for b in 0..self.batch {
            let mut prefix = 0f64;
            for t in 0..self.seq {
                let tok = tokens[b * self.seq + t];
                if tok != PAD {
                    prefix += (t as f64 + 1.0) * (tok as f64 + 1.0);
                }
                let row = &mut out
                    [(b * self.seq + t) * self.vocab..(b * self.seq + t + 1) * self.vocab];
                for (v, slot) in row.iter_mut().enumerate() {
                    *slot = (1e-3 * self.base_fp
                        + 1e-2 * afp * ((v % 31) as f64 + 1.0)
                        + 1e-4 * prefix * ((v % 7) as f64 + 1.0))
                        as f32;
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Rng, Tensor};

    fn named(seed: u64, n: usize) -> NamedTensors {
        let mut rng = Rng::new(seed);
        let mut nt = NamedTensors::new();
        nt.push("w", Tensor::new(&[n], rng.normal_vec(n, 0.0, 1.0)));
        nt
    }

    #[test]
    fn fingerprint_is_order_and_value_sensitive() {
        let a = named(1, 64);
        let b = named(2, 64);
        assert_ne!(fingerprint(&a), fingerprint(&b));
        assert_eq!(fingerprint(&a), fingerprint(&a.clone()));
        // swapping two values moves the fingerprint (position weights)
        let mut swapped = a.clone();
        let d = swapped.get_mut("w").unwrap().data_mut();
        d.swap(0, 1);
        assert_ne!(fingerprint(&a), fingerprint(&swapped));
    }

    #[test]
    fn reference_backend_contract() {
        let base = named(3, 32);
        let mut be = ReferenceBackend::new(2, 4, 8, &base);
        assert_eq!(be.shape(), (2, 4, 8));
        let w1 = Arc::new(named(4, 16));
        let w2 = Arc::new(named(5, 16));
        let toks = vec![1, 2, 3, PAD, 4, 5, PAD, PAD];
        let l1 = be.forward("a", 0, &w1, &toks).unwrap();
        assert_eq!(l1.len(), 2 * 4 * 8);
        // deterministic
        assert_eq!(l1, be.forward("a", 0, &w1, &toks).unwrap());
        // adapter-sensitive
        assert_ne!(l1, be.forward("b", 1, &w2, &toks).unwrap());
        // prompt-sensitive at the changed row only
        let toks2 = vec![1, 2, 9, PAD, 4, 5, PAD, PAD];
        let l2 = be.forward("a", 0, &w1, &toks2).unwrap();
        assert_ne!(l1[..4 * 8], l2[..4 * 8]);
        assert_eq!(l1[4 * 8..], l2[4 * 8..], "row 1 must not see row 0's change");
        // wrong token-matrix size is rejected
        assert!(be.forward("a", 0, &w1, &[1, 2, 3]).is_err());
    }
}
