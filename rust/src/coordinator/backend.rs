//! Serving execution backends: the forward-pass engines behind
//! [`super::server::BatchServer`], abstracted so the batching/routing
//! layer is independent of (and testable without) PJRT. Backends are
//! per-worker state: an N-worker [`super::pool::ServerPool`] builds
//! one backend per worker thread (N runtimes, N base uploads) while
//! the registry's merged-weight cache stays shared.
//!
//! Two forward entry points:
//!
//! - [`ServeBackend::forward`] — one adapter, one padded batch (the
//!   pre-fusion contract, kept as the per-group serial oracle);
//! - [`ServeBackend::forward_fused`] — ONE padded `[batch, seq]` call
//!   for a drained batch that spans several adapters, each adapter
//!   owning a contiguous row span ([`AdapterGroup`]). The contract is
//!   bit-identity with running each group alone through `forward` and
//!   scattering the rows back; the default implementation does exactly
//!   that scatter, so engines that are inherently one-adapter-per-call
//!   inherit a correct fused path.
//!
//! Backends key adapter-side caches by `(name, generation)` — the
//! registry bumps the generation on every re-register, so the key can
//! never alias stale weights (no pointer-ABA), while evict/re-merge of
//! an unchanged source keeps its generation and its cached state:
//!
//! - [`PjrtBackend`] runs the manifest's `forward` graph on a PJRT
//!   runtime it **owns** (an [`OwnedExecutor`] — the worker no longer
//!   `Box::leak`s a `Runtime` per spawn). The shared base uploads to
//!   the device once; merged adapter tensors live in a
//!   generation-keyed device-buffer LRU ([`device_cache_capacity`],
//!   env `IRQLORA_DEVICE_CACHE`, default = the registry's merged-cache
//!   size) so alternating tenants stop re-uploading on every switch.
//!   Note the PJRT graph takes ONE adapter's weights per call, so a
//!   mixed batch always *executes* group by group (the inherited
//!   scatter); what the cache changes is the upload step — a hit
//!   executes straight from resident buffers, a miss uploads first
//!   (both counted in [`UploadStats`]). A true single-launch
//!   multi-adapter graph is a ROADMAP next step.
//! - [`ReferenceBackend`] is a deterministic host-side stand-in (no
//!   artifacts, no PJRT — it works in the offline stub build): logits
//!   are a fixed synthetic function of the shared base, the adapter
//!   weights, and the token prefix. Not a transformer — it exists to
//!   give routing tests and the offline bench smoke exactly the
//!   properties they check: adapter-sensitivity, prompt-sensitivity,
//!   and bit-exact determinism. Its `forward_fused` is a true
//!   single-pass implementation (per-row adapter fingerprint
//!   selection), and its fingerprint cache mirrors the device-buffer
//!   cache's keying/counters so the plumbing is covered offline.

use std::collections::VecDeque;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::data::PAD;
use crate::model::weights::NamedTensors;
use crate::runtime::{Manifest, OwnedExecutor, Runtime};

/// One adapter's slice of a fused mixed-adapter batch: the merged
/// serving weights (tagged with their registry generation) and the
/// contiguous row span the adapter's requests occupy in the padded
/// `[batch, seq]` token matrix.
#[derive(Clone)]
pub struct AdapterGroup {
    /// Adapter name (cache key part 1).
    pub name: String,
    /// Registry registration generation (cache key part 2).
    pub generation: u64,
    /// Merged (Eq. 16/17-folded) serving tensors.
    pub weights: Arc<NamedTensors>,
    /// Rows of the fused token matrix owned by this adapter.
    pub rows: std::ops::Range<usize>,
}

/// Adapter-side cache counters: [`PjrtBackend`]'s device-buffer
/// upload LRU, mirrored by [`ReferenceBackend`]'s fingerprint cache so
/// the counter plumbing is exercised offline. Monotonic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct UploadStats {
    /// Forwards whose adapter-side state was already resident.
    pub hits: usize,
    /// Forwards that had to upload (PJRT) / recompute (reference) it.
    pub misses: usize,
}

/// Resolve the per-worker adapter device-buffer cache capacity: the
/// `IRQLORA_DEVICE_CACHE` override, else the registry's merged-cache
/// size ([`super::registry::cache_capacity`]) — one device slot per
/// host-cached merge, so a tenant set that fits the merge cache also
/// fits the device. Caveat: device memory is a SEPARATE budget from
/// host RAM — an operator who raises `IRQLORA_ADAPTER_CACHE` for a
/// large host cache should set `IRQLORA_DEVICE_CACHE` explicitly to
/// what the accelerator can actually hold (this knob exists precisely
/// to decouple the two tiers). Reads through `util::env`.
pub fn device_cache_capacity() -> usize {
    crate::util::env::device_cache()
}

/// Interpret an `IRQLORA_DEVICE_CACHE` value: positive integers are
/// honored (capped at 4096); zero and garbage are ignored (parse in
/// `util::env`).
#[cfg(test)]
fn parse_device_cache_override(v: &str) -> Option<usize> {
    crate::util::env::parse_count(v, crate::util::env::CACHE_CAP)
}

/// Tiny `(adapter name, generation)`-keyed LRU shared by the PJRT
/// device-buffer cache, the reference fingerprint cache, and the
/// native backend's fingerprint cache (`hal::native`) — ONE
/// implementation of the touch/insert/evict/counter logic, so the
/// offline tests really exercise the same aging the device path uses.
/// Linear scan: capacities are small (≤4096) and lookups happen once
/// per forward, not per element.
pub(crate) struct KeyedLru<V> {
    /// front = coldest, back = hottest.
    entries: VecDeque<((String, u64), V)>,
    cap: usize,
    pub(crate) stats: UploadStats,
}

impl<V> KeyedLru<V> {
    pub(crate) fn new(cap: usize) -> KeyedLru<V> {
        KeyedLru { entries: VecDeque::new(), cap: cap.max(1), stats: UploadStats::default() }
    }

    /// Hit path: move the entry to the hottest slot, count the hit,
    /// and return its index (valid until the next mutation).
    pub(crate) fn touch(&mut self, name: &str, generation: u64) -> Option<usize> {
        let pos = self
            .entries
            .iter()
            .position(|((n, g), _)| n == name && *g == generation)?;
        let entry = self.entries.remove(pos).unwrap();
        self.entries.push_back(entry);
        self.stats.hits += 1;
        Some(self.entries.len() - 1)
    }

    /// Miss path: insert as hottest, count the miss, evict the coldest
    /// beyond capacity, and return the new entry's index.
    pub(crate) fn insert(&mut self, name: &str, generation: u64, value: V) -> usize {
        self.stats.misses += 1;
        self.entries.push_back(((name.to_string(), generation), value));
        while self.entries.len() > self.cap {
            self.entries.pop_front();
        }
        self.entries.len() - 1
    }

    pub(crate) fn get(&self, idx: usize) -> &V {
        &self.entries[idx].1
    }
}

/// A batched forward engine: given adapter weights and a padded
/// `[batch, seq]` token matrix, produce `[batch, seq, vocab]`
/// next-token logits.
pub trait ServeBackend {
    /// (max rows per forward call, padded sequence length, vocab).
    fn shape(&self) -> (usize, usize, usize);

    /// Run one padded batch under `weights` (the merged tensors of
    /// adapter `name`, at registry registration `generation` — see
    /// `AdapterRegistry::merged_tagged`; backends may key device-side
    /// caches by `(name, generation)`). `tokens.len()` must equal
    /// `batch * seq`.
    fn forward(
        &mut self,
        name: &str,
        generation: u64,
        weights: &Arc<NamedTensors>,
        tokens: &[i32],
    ) -> Result<Vec<f32>>;

    /// Run ONE padded `[batch, seq]` forward for a drained batch that
    /// spans multiple adapters: `groups` assigns each adapter its
    /// contiguous row span inside `tokens`, and row `b` of the
    /// returned `[batch, seq, vocab]` logits is computed under the
    /// weights of the group owning `b` (rows owned by no group are
    /// unspecified padding).
    ///
    /// Contract: bit-identical to running each group alone through
    /// [`Self::forward`] (rows packed from 0, the rest PAD) and
    /// scattering the rows back. The default implementation does
    /// exactly that scatter, so engines whose execution is inherently
    /// per-adapter (one weight set per graph call, e.g.
    /// [`PjrtBackend`]) inherit a correct fused path and win through
    /// adapter-side caching instead; [`ReferenceBackend`] overrides it
    /// with a true single-pass implementation.
    fn forward_fused(&mut self, groups: &[AdapterGroup], tokens: &[i32]) -> Result<Vec<f32>> {
        let (batch, seq, vocab) = self.shape();
        if tokens.len() != batch * seq {
            bail!(
                "token matrix has {} elems, expected batch*seq = {}",
                tokens.len(),
                batch * seq
            );
        }
        // dominant case under affinity routing: the whole drain is one
        // adapter packed from row 0 — the fused matrix already IS the
        // per-group layout, so skip the scatter buffers entirely
        if let [g] = groups {
            if g.rows.start == 0 {
                if g.rows.end > batch {
                    bail!(
                        "adapter group '{}' rows {}..{} exceed batch {batch}",
                        g.name,
                        g.rows.start,
                        g.rows.end
                    );
                }
                return self.forward(&g.name, g.generation, &g.weights, tokens);
            }
        }
        let mut out = vec![0f32; batch * seq * vocab];
        let mut group_toks = vec![PAD; batch * seq];
        for g in groups {
            if g.rows.end > batch {
                bail!(
                    "adapter group '{}' rows {}..{} exceed batch {batch}",
                    g.name,
                    g.rows.start,
                    g.rows.end
                );
            }
            for t in group_toks.iter_mut() {
                *t = PAD;
            }
            for (i, row) in g.rows.clone().enumerate() {
                group_toks[i * seq..(i + 1) * seq]
                    .copy_from_slice(&tokens[row * seq..(row + 1) * seq]);
            }
            let logits = self.forward(&g.name, g.generation, &g.weights, &group_toks)?;
            for (i, row) in g.rows.clone().enumerate() {
                out[row * seq * vocab..(row + 1) * seq * vocab]
                    .copy_from_slice(&logits[i * seq * vocab..(i + 1) * seq * vocab]);
            }
        }
        Ok(out)
    }

    /// Run ONE decode step for a (possibly mixed-adapter) batch:
    /// `tokens` is the same padded `[batch, seq]` matrix as
    /// [`Self::forward_fused`], `lens[b]` is row `b`'s live prefix
    /// length (must be in `1..=seq` for rows owned by a group; ignored
    /// for unowned rows), and the returned `[batch, vocab]` buffer
    /// holds, for each owned row `b`, the next-token logits at
    /// position `lens[b] - 1`.
    ///
    /// Contract: row `b` of the result is bit-identical to slicing
    /// `forward_fused(groups, tokens)` at `(b*seq + lens[b]-1)*vocab`.
    /// The default implementation does exactly that slice, so every
    /// backend inherits a correct streaming path; backends whose
    /// manifest declares `streaming_decode` override it with a true
    /// single-position compute (reference, native).
    fn forward_step(
        &mut self,
        groups: &[AdapterGroup],
        tokens: &[i32],
        lens: &[usize],
    ) -> Result<Vec<f32>> {
        let (batch, seq, vocab) = self.shape();
        if lens.len() != batch {
            bail!("lens has {} entries, expected batch = {batch}", lens.len());
        }
        for g in groups {
            for row in g.rows.clone() {
                if row >= batch {
                    bail!(
                        "adapter group '{}' rows {}..{} exceed batch {batch}",
                        g.name,
                        g.rows.start,
                        g.rows.end
                    );
                }
                if !(1..=seq).contains(&lens[row]) {
                    bail!("row {row} prefix length {} out of range 1..={seq}", lens[row]);
                }
            }
        }
        let full = self.forward_fused(groups, tokens)?;
        let mut out = vec![0f32; batch * vocab];
        for g in groups {
            for row in g.rows.clone() {
                let off = (row * seq + lens[row] - 1) * vocab;
                if off + vocab > full.len() {
                    bail!("backend returned {} logits, need at least {}", full.len(), off + vocab);
                }
                out[row * vocab..(row + 1) * vocab].copy_from_slice(&full[off..off + vocab]);
            }
        }
        Ok(out)
    }

    /// Adapter-side cache counters so far (uploads for PJRT,
    /// fingerprint recomputes for the reference stand-in). Default:
    /// zeros, for backends without such a cache.
    fn upload_stats(&self) -> UploadStats {
        UploadStats::default()
    }
}

/// PJRT-backed [`ServeBackend`] over the manifest's `forward` graph.
pub struct PjrtBackend {
    exe: OwnedExecutor,
    base_bufs: Vec<xla::PjRtBuffer>,
    mask_bufs: [xla::PjRtBuffer; 2],
    /// Generation-keyed device-buffer LRU: `(name, generation)` → the
    /// adapter's uploaded tensors. The generation is bumped by the
    /// registry on every re-register, so — unlike a pointer address —
    /// a key cannot alias stale weights after a drop/realloc; and
    /// since merges of one generation are bit-identical, reuse across
    /// evict/re-merge is exact.
    device_cache: KeyedLru<Vec<xla::PjRtBuffer>>,
    nb: usize,
    nl: usize,
    batch: usize,
    seq: usize,
    vocab: usize,
}

impl PjrtBackend {
    /// Compile the `forward` graph on a fresh CPU runtime (owned by
    /// the returned value) and upload the shared base once. The IEC
    /// mask inputs are pinned to 0: registry adapters arrive
    /// pre-merged (Eq. 16/17), so the elastic path is off at serving.
    /// The adapter device cache is sized by [`device_cache_capacity`].
    pub fn new(manifest: &Manifest, tag: &str, base: &NamedTensors) -> Result<PjrtBackend> {
        let spec = manifest.graph(tag, "forward")?;
        let cfg = &manifest.size(tag)?.config;
        let nb = base.len();
        let nl = spec
            .inputs
            .len()
            .checked_sub(nb + 3)
            .context("forward graph has fewer inputs than base + masks + tokens")?;
        let runtime = Arc::new(Runtime::cpu()?);
        let exe = runtime.load_owned(spec)?;
        let mut base_bufs = Vec::with_capacity(nb);
        for (i, t) in base.tensors().iter().enumerate() {
            // zero-copy upload: no per-tensor host clone
            base_bufs.push(exe.upload_f32(i, t.data())?);
        }
        let mask_bufs = [
            exe.upload_f32(nb + nl, &[0.0])?,
            exe.upload_f32(nb + nl + 1, &[0.0])?,
        ];
        Ok(PjrtBackend {
            exe,
            base_bufs,
            mask_bufs,
            device_cache: KeyedLru::new(device_cache_capacity()),
            nb,
            nl,
            batch: cfg.batch,
            seq: cfg.seq,
            vocab: cfg.vocab,
        })
    }

    /// Make `(name, generation)`'s buffers resident (uploading on a
    /// miss, touching the LRU on a hit) and return their cache index —
    /// always the hottest (back) slot.
    fn ensure_uploaded(
        &mut self,
        name: &str,
        generation: u64,
        weights: &Arc<NamedTensors>,
    ) -> Result<usize> {
        if weights.len() != self.nl {
            bail!(
                "adapter '{name}' has {} tensors, forward graph expects {}",
                weights.len(),
                self.nl
            );
        }
        if let Some(idx) = self.device_cache.touch(name, generation) {
            return Ok(idx);
        }
        let mut bufs = Vec::with_capacity(self.nl);
        for (i, t) in weights.tensors().iter().enumerate() {
            bufs.push(self.exe.upload_f32(self.nb + i, t.data())?);
        }
        Ok(self.device_cache.insert(name, generation, bufs))
    }
}

/// Per-backend forward timers (`hal.forward_time{backend=...}` /
/// `hal.fused_forward_time{backend=...}`), resolved once per process
/// and cached — the per-call cost is one branch when telemetry is
/// disabled, a clock read + relaxed atomics when enabled.
pub(crate) struct ForwardTimers {
    pub(crate) forward: crate::telemetry::Timer,
    pub(crate) fused: crate::telemetry::Timer,
    /// One decode step of the streaming path (true single-position
    /// `forward_step` overrides only; the inherited slice records
    /// under `fused` because it runs a whole fused forward).
    pub(crate) step: crate::telemetry::Timer,
}

impl ForwardTimers {
    pub(crate) fn resolve(backend: &str) -> ForwardTimers {
        let reg = crate::telemetry::global();
        ForwardTimers {
            forward: reg.timer("hal.forward_time", &[("backend", backend)]),
            fused: reg.timer("hal.fused_forward_time", &[("backend", backend)]),
            step: reg.timer("hal.step_forward_time", &[("backend", backend)]),
        }
    }
}

fn telem_pjrt() -> &'static ForwardTimers {
    static T: std::sync::OnceLock<ForwardTimers> = std::sync::OnceLock::new();
    T.get_or_init(|| ForwardTimers::resolve("pjrt"))
}

fn telem_reference() -> &'static ForwardTimers {
    static T: std::sync::OnceLock<ForwardTimers> = std::sync::OnceLock::new();
    T.get_or_init(|| ForwardTimers::resolve("reference"))
}

impl ServeBackend for PjrtBackend {
    fn shape(&self) -> (usize, usize, usize) {
        (self.batch, self.seq, self.vocab)
    }

    fn forward(
        &mut self,
        name: &str,
        generation: u64,
        weights: &Arc<NamedTensors>,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let _t = telem_pjrt().forward.start();
        let idx = self.ensure_uploaded(name, generation, weights)?;
        let tok = self.exe.upload_i32(self.nb + self.nl + 2, tokens)?;
        let adapter_bufs = self.device_cache.get(idx);
        let mut all: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.nb + self.nl + 3);
        all.extend(self.base_bufs.iter());
        all.extend(adapter_bufs.iter());
        all.push(&self.mask_bufs[0]);
        all.push(&self.mask_bufs[1]);
        all.push(&tok);
        let outs = self.exe.execute(&all)?;
        outs.into_iter()
            .next()
            .context("forward graph returned no outputs")?
            .into_f32()
    }

    // forward_fused: the default per-group scatter — the graph takes
    // one adapter's weight set per call, so a mixed batch executes
    // group by group; the device cache (warmed across batches AND
    // across groups of one batch) is what removes the re-upload cost.

    fn upload_stats(&self) -> UploadStats {
        self.device_cache.stats
    }
}

/// Deterministic host-side [`ServeBackend`] for routing tests and the
/// offline bench smoke (see module docs). Logit `[b, t, v]` is a
/// fixed function of the base fingerprint, the adapter fingerprint,
/// and the weighted non-PAD token prefix of row `b` up to `t` — rows
/// are independent, so a request's logits cannot depend on its
/// batchmates, and any change to adapter weights or prompt moves the
/// output. (Row independence is also why its single-pass
/// `forward_fused` is bit-identical to the per-group serial path.)
pub struct ReferenceBackend {
    batch: usize,
    seq: usize,
    vocab: usize,
    /// Base fingerprint, reduced once at construction.
    base_fp: f64,
    /// `(name, generation)` → adapter fingerprint. The same
    /// [`KeyedLru`] the PJRT device-buffer cache uses (safe because
    /// one generation's merged weights are bit-identical), so serving
    /// stops re-reducing every adapter tensor on every forward.
    fp_cache: KeyedLru<f64>,
    /// Artificial per-forward latency, for tests that need requests to
    /// pile up behind a busy worker (shutdown/in-flight coverage).
    pub forward_delay: std::time::Duration,
}

impl ReferenceBackend {
    pub fn new(batch: usize, seq: usize, vocab: usize, base: &NamedTensors) -> ReferenceBackend {
        assert!(batch > 0 && seq > 0 && vocab > 0);
        ReferenceBackend {
            batch,
            seq,
            vocab,
            base_fp: fingerprint(base),
            fp_cache: KeyedLru::new(device_cache_capacity()),
            forward_delay: std::time::Duration::ZERO,
        }
    }

    /// Builder-style `forward_delay` (handy inside the `move` backend
    /// factories servers and pools take).
    pub fn with_forward_delay(mut self, delay: std::time::Duration) -> ReferenceBackend {
        self.forward_delay = delay;
        self
    }

    /// Cached adapter fingerprint (computed on miss, LRU-touched on
    /// hit) — the reference analogue of [`PjrtBackend::ensure_uploaded`].
    fn adapter_fp(&mut self, name: &str, generation: u64, weights: &Arc<NamedTensors>) -> f64 {
        if let Some(idx) = self.fp_cache.touch(name, generation) {
            return *self.fp_cache.get(idx);
        }
        let fp = fingerprint(weights);
        self.fp_cache.insert(name, generation, fp);
        fp
    }

    /// Fill one row's `[seq, vocab]` logits. Shared verbatim by
    /// `forward` and `forward_fused` so the two paths cannot drift
    /// even by a rounding step.
    fn row_into(&self, afp: f64, row_tokens: &[i32], out_row: &mut [f32]) {
        debug_assert_eq!(row_tokens.len(), self.seq);
        debug_assert_eq!(out_row.len(), self.seq * self.vocab);
        let mut prefix = 0f64;
        for t in 0..self.seq {
            let tok = row_tokens[t];
            if tok != PAD {
                prefix += (t as f64 + 1.0) * (tok as f64 + 1.0);
            }
            let row = &mut out_row[t * self.vocab..(t + 1) * self.vocab];
            for (v, slot) in row.iter_mut().enumerate() {
                *slot = (1e-3 * self.base_fp
                    + 1e-2 * afp * ((v % 31) as f64 + 1.0)
                    + 1e-4 * prefix * ((v % 7) as f64 + 1.0))
                    as f32;
            }
        }
    }

    /// Fill one row's `[vocab]` next-token logits at position
    /// `len - 1` — the single-position compute behind `forward_step`.
    /// The prefix fold and the per-slot formula are the SAME
    /// expressions [`Self::row_into`] evaluates at `t = len - 1`, in
    /// the same accumulation order, so the streamed step is
    /// bit-identical to slicing the full `[seq, vocab]` row.
    fn step_row_into(&self, afp: f64, row_tokens: &[i32], len: usize, out_row: &mut [f32]) {
        debug_assert!(len >= 1 && len <= row_tokens.len());
        debug_assert_eq!(out_row.len(), self.vocab);
        let mut prefix = 0f64;
        for t in 0..len {
            let tok = row_tokens[t];
            if tok != PAD {
                prefix += (t as f64 + 1.0) * (tok as f64 + 1.0);
            }
        }
        for (v, slot) in out_row.iter_mut().enumerate() {
            *slot = (1e-3 * self.base_fp
                + 1e-2 * afp * ((v % 31) as f64 + 1.0)
                + 1e-4 * prefix * ((v % 7) as f64 + 1.0))
                as f32;
        }
    }
}

/// Fingerprint tile width, in elements. 4096 = 64 quantization blocks
/// of 64 values, so for every k in 1..=8 a tile boundary falls on a
/// whole packed byte (`4096 * k` bits ≡ `512 * k` bytes) — the
/// property `hal::native` relies on to stream tiles straight out of
/// packed storage through `quant::fused::dequantize_packed_into`
/// without ever materializing a full dequantized tensor.
pub(crate) const FP_TILE: usize = 4096;

/// Order- and position-sensitive weighted sum over every tensor value:
/// any change anywhere in the collection moves it.
///
/// Defined as a two-level fold so every consumer can reproduce it
/// bit-exactly regardless of how it obtains the values: per-tile
/// partials ([`fp_tile_partial`], strictly serial within a tile) are
/// summed in tile order, tiles may be *computed* in parallel, and
/// tensors fold left in collection order. The tile partials themselves
/// are what `hal::native` computes from packed storage — same tiles,
/// same fold, same bits.
pub(crate) fn fingerprint(nt: &NamedTensors) -> f64 {
    let mut fp = 0f64;
    let mut start = 0u64;
    for t in nt.tensors() {
        fp += fingerprint_slice(start, t.data());
        start += t.data().len() as u64;
    }
    fp
}

/// Fingerprint one tensor's values, `start` elements into the
/// collection-wide element stream. Tiles are computed in parallel but
/// reduced serially in tile order, so the result is independent of
/// worker count.
pub(crate) fn fingerprint_slice(start: u64, data: &[f32]) -> f64 {
    let n_tiles = data.len().div_ceil(FP_TILE);
    if n_tiles <= 1 {
        return fp_tile_partial(start, data);
    }
    let partials = crate::util::threads::par_map_with(n_tiles, 4, |ti| {
        let lo = ti * FP_TILE;
        let hi = (lo + FP_TILE).min(data.len());
        fp_tile_partial(start + lo as u64, &data[lo..hi])
    });
    let mut fp = 0f64;
    for p in partials {
        fp += p;
    }
    fp
}

/// Serial weighted sum over one tile: element `j` of `vals` is global
/// element `start + j` (0-based) and carries weight
/// `((start + j + 1) % 127) + 1`.
pub(crate) fn fp_tile_partial(start: u64, vals: &[f32]) -> f64 {
    let mut p = 0f64;
    for (j, &v) in vals.iter().enumerate() {
        let i = start + j as u64 + 1;
        p += v as f64 * ((i % 127) + 1) as f64;
    }
    p
}

impl ServeBackend for ReferenceBackend {
    fn shape(&self) -> (usize, usize, usize) {
        (self.batch, self.seq, self.vocab)
    }

    fn forward(
        &mut self,
        name: &str,
        generation: u64,
        weights: &Arc<NamedTensors>,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let _t = telem_reference().forward.start();
        if tokens.len() != self.batch * self.seq {
            bail!(
                "token matrix has {} elems, expected batch*seq = {}",
                tokens.len(),
                self.batch * self.seq
            );
        }
        if !self.forward_delay.is_zero() {
            std::thread::sleep(self.forward_delay);
        }
        let afp = self.adapter_fp(name, generation, weights);
        let mut out = vec![0f32; self.batch * self.seq * self.vocab];
        for b in 0..self.batch {
            self.row_into(
                afp,
                &tokens[b * self.seq..(b + 1) * self.seq],
                &mut out[b * self.seq * self.vocab..(b + 1) * self.seq * self.vocab],
            );
        }
        Ok(out)
    }

    /// True single-pass fused forward: resolve each group's adapter
    /// fingerprint (cached), then fill every row under its owner's
    /// fingerprint. One `forward_delay` sleep per fused batch — one
    /// "launch", however many adapters ride in it.
    fn forward_fused(&mut self, groups: &[AdapterGroup], tokens: &[i32]) -> Result<Vec<f32>> {
        let _t = telem_reference().fused.start();
        if tokens.len() != self.batch * self.seq {
            bail!(
                "token matrix has {} elems, expected batch*seq = {}",
                tokens.len(),
                self.batch * self.seq
            );
        }
        for g in groups {
            if g.rows.end > self.batch {
                bail!(
                    "adapter group '{}' rows {}..{} exceed batch {}",
                    g.name,
                    g.rows.start,
                    g.rows.end,
                    self.batch
                );
            }
        }
        if !self.forward_delay.is_zero() {
            std::thread::sleep(self.forward_delay);
        }
        let fps: Vec<f64> = groups
            .iter()
            .map(|g| self.adapter_fp(&g.name, g.generation, &g.weights))
            .collect();
        let mut out = vec![0f32; self.batch * self.seq * self.vocab];
        for (g, &afp) in groups.iter().zip(&fps) {
            for row in g.rows.clone() {
                self.row_into(
                    afp,
                    &tokens[row * self.seq..(row + 1) * self.seq],
                    &mut out[row * self.seq * self.vocab..(row + 1) * self.seq * self.vocab],
                );
            }
        }
        Ok(out)
    }

    /// True single-position streaming step: only position `lens[b]-1`
    /// of each live row is computed (a `seq`-fold cost reduction over
    /// the inherited full-forward-then-slice default). One
    /// `forward_delay` sleep per step — one "launch" per decode step.
    fn forward_step(
        &mut self,
        groups: &[AdapterGroup],
        tokens: &[i32],
        lens: &[usize],
    ) -> Result<Vec<f32>> {
        let _t = telem_reference().step.start();
        if tokens.len() != self.batch * self.seq {
            bail!(
                "token matrix has {} elems, expected batch*seq = {}",
                tokens.len(),
                self.batch * self.seq
            );
        }
        if lens.len() != self.batch {
            bail!("lens has {} entries, expected batch = {}", lens.len(), self.batch);
        }
        for g in groups {
            if g.rows.end > self.batch {
                bail!(
                    "adapter group '{}' rows {}..{} exceed batch {}",
                    g.name,
                    g.rows.start,
                    g.rows.end,
                    self.batch
                );
            }
            for row in g.rows.clone() {
                if !(1..=self.seq).contains(&lens[row]) {
                    bail!("row {row} prefix length {} out of range 1..={}", lens[row], self.seq);
                }
            }
        }
        if !self.forward_delay.is_zero() {
            std::thread::sleep(self.forward_delay);
        }
        let fps: Vec<f64> = groups
            .iter()
            .map(|g| self.adapter_fp(&g.name, g.generation, &g.weights))
            .collect();
        let mut out = vec![0f32; self.batch * self.vocab];
        for (g, &afp) in groups.iter().zip(&fps) {
            for row in g.rows.clone() {
                self.step_row_into(
                    afp,
                    &tokens[row * self.seq..(row + 1) * self.seq],
                    lens[row],
                    &mut out[row * self.vocab..(row + 1) * self.vocab],
                );
            }
        }
        Ok(out)
    }

    fn upload_stats(&self) -> UploadStats {
        self.fp_cache.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Rng, Tensor};

    fn named(seed: u64, n: usize) -> NamedTensors {
        let mut rng = Rng::new(seed);
        let mut nt = NamedTensors::new();
        nt.push("w", Tensor::new(&[n], rng.normal_vec(n, 0.0, 1.0)));
        nt
    }

    #[test]
    fn fingerprint_is_order_and_value_sensitive() {
        let a = named(1, 64);
        let b = named(2, 64);
        assert_ne!(fingerprint(&a), fingerprint(&b));
        assert_eq!(fingerprint(&a), fingerprint(&a.clone()));
        // swapping two values moves the fingerprint (position weights)
        let mut swapped = a.clone();
        let d = swapped.get_mut("w").unwrap().data_mut();
        d.swap(0, 1);
        assert_ne!(fingerprint(&a), fingerprint(&swapped));
    }

    /// The tiled fingerprint must be a pure function of the value
    /// stream — the parallel tile computation and the multi-tensor
    /// fold have to land on the exact bits a serial tile-ordered fold
    /// produces, because `hal::native` reproduces that fold from
    /// packed storage and asserts bit-identity against it.
    #[test]
    fn fingerprint_matches_serial_tile_fold() {
        let mut rng = Rng::new(99);
        let n1 = FP_TILE * 2 + 137; // multi-tile with a ragged tail
        let n2 = 513;
        let mut nt = NamedTensors::new();
        nt.push("a", Tensor::new(&[n1], rng.normal_vec(n1, 0.0, 1.0)));
        nt.push("b", Tensor::new(&[n2], rng.normal_vec(n2, 0.0, 1.0)));

        let mut want = 0f64;
        let mut start = 0u64;
        for t in nt.tensors() {
            let data = t.data();
            let mut slice_fp = 0f64;
            let mut lo = 0usize;
            while lo < data.len() {
                let hi = (lo + FP_TILE).min(data.len());
                slice_fp += fp_tile_partial(start + lo as u64, &data[lo..hi]);
                lo = hi;
            }
            want += slice_fp;
            start += data.len() as u64;
        }
        assert_eq!(fingerprint(&nt).to_bits(), want.to_bits());
    }

    #[test]
    fn device_cache_env_parsing() {
        assert_eq!(parse_device_cache_override("2"), Some(2));
        assert_eq!(parse_device_cache_override(" 16 "), Some(16));
        assert_eq!(parse_device_cache_override("999999"), Some(4096)); // capped
        assert_eq!(parse_device_cache_override("0"), None);
        assert_eq!(parse_device_cache_override("nope"), None);
        assert!(device_cache_capacity() >= 1);
    }

    #[test]
    fn reference_backend_contract() {
        let base = named(3, 32);
        let mut be = ReferenceBackend::new(2, 4, 8, &base);
        assert_eq!(be.shape(), (2, 4, 8));
        let w1 = Arc::new(named(4, 16));
        let w2 = Arc::new(named(5, 16));
        let toks = vec![1, 2, 3, PAD, 4, 5, PAD, PAD];
        let l1 = be.forward("a", 0, &w1, &toks).unwrap();
        assert_eq!(l1.len(), 2 * 4 * 8);
        // deterministic
        assert_eq!(l1, be.forward("a", 0, &w1, &toks).unwrap());
        // adapter-sensitive
        assert_ne!(l1, be.forward("b", 1, &w2, &toks).unwrap());
        // prompt-sensitive at the changed row only
        let toks2 = vec![1, 2, 9, PAD, 4, 5, PAD, PAD];
        let l2 = be.forward("a", 0, &w1, &toks2).unwrap();
        assert_ne!(l1[..4 * 8], l2[..4 * 8]);
        assert_eq!(l1[4 * 8..], l2[4 * 8..], "row 1 must not see row 0's change");
        // wrong token-matrix size is rejected
        assert!(be.forward("a", 0, &w1, &[1, 2, 3]).is_err());
        // the fingerprint cache served the repeats without recomputing
        let s = be.upload_stats();
        assert_eq!(s.misses, 2, "{s:?}"); // one per (name, generation)
        assert!(s.hits >= 2, "{s:?}");
    }

    /// The heart of the fused contract: a mixed-adapter fused forward
    /// must be bit-identical, row for row, to each group served alone
    /// through the per-group serial path.
    #[test]
    fn reference_fused_bit_identical_to_per_group_serial() {
        let base = named(7, 48);
        let (batch, seq, vocab) = (5usize, 4usize, 6usize);
        let w: Vec<Arc<NamedTensors>> =
            (0..3).map(|i| Arc::new(named(10 + i, 24))).collect();

        // fused batch: adapter 0 owns rows 0..2, adapter 1 rows 2..3,
        // adapter 2 rows 3..5 (row 4 padded inside the group span)
        let mut tokens = vec![PAD; batch * seq];
        for (row, len) in [(0usize, 3usize), (1, 1), (2, 4), (3, 2), (4, 3)] {
            for t in 0..len {
                tokens[row * seq + t] = (row * 7 + t * 3 + 1) as i32;
            }
        }
        let groups: Vec<AdapterGroup> = [(0usize, 0usize..2), (1, 2..3), (2, 3..5)]
            .into_iter()
            .map(|(i, rows)| AdapterGroup {
                name: format!("t{i}"),
                generation: i as u64,
                weights: w[i].clone(),
                rows,
            })
            .collect();

        let mut fused_be = ReferenceBackend::new(batch, seq, vocab, &base);
        let fused = fused_be.forward_fused(&groups, &tokens).unwrap();
        assert_eq!(fused.len(), batch * seq * vocab);

        let mut serial_be = ReferenceBackend::new(batch, seq, vocab, &base);
        for g in &groups {
            // serial path: the group's rows packed from 0, rest PAD
            let mut gt = vec![PAD; batch * seq];
            for (i, row) in g.rows.clone().enumerate() {
                gt[i * seq..(i + 1) * seq].copy_from_slice(&tokens[row * seq..(row + 1) * seq]);
            }
            let logits = serial_be
                .forward(&g.name, g.generation, &g.weights, &gt)
                .unwrap();
            for (i, row) in g.rows.clone().enumerate() {
                let f = &fused[row * seq * vocab..(row + 1) * seq * vocab];
                let s = &logits[i * seq * vocab..(i + 1) * seq * vocab];
                for (a, b) in f.iter().zip(s) {
                    assert_eq!(a.to_bits(), b.to_bits(), "row {row} of '{}'", g.name);
                }
            }
        }

        // the default scatter implementation agrees too (it is what
        // PjrtBackend inherits) — compare through a wrapper that hides
        // the override
        struct NoOverride(ReferenceBackend);
        impl ServeBackend for NoOverride {
            fn shape(&self) -> (usize, usize, usize) {
                self.0.shape()
            }
            fn forward(
                &mut self,
                name: &str,
                generation: u64,
                weights: &Arc<NamedTensors>,
                tokens: &[i32],
            ) -> Result<Vec<f32>> {
                self.0.forward(name, generation, weights, tokens)
            }
        }
        let mut default_be = NoOverride(ReferenceBackend::new(batch, seq, vocab, &base));
        let scattered = default_be.forward_fused(&groups, &tokens).unwrap();
        for g in &groups {
            for row in g.rows.clone() {
                let a = &fused[row * seq * vocab..(row + 1) * seq * vocab];
                let b = &scattered[row * seq * vocab..(row + 1) * seq * vocab];
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "default scatter row {row}");
                }
            }
        }
        // out-of-range group rows are rejected, not misindexed
        let bad = AdapterGroup {
            name: "t0".into(),
            generation: 0,
            weights: w[0].clone(),
            rows: 4..batch + 1,
        };
        assert!(fused_be.forward_fused(&[bad], &tokens).is_err());
    }

    /// The streaming contract: `forward_step` at prefix length `len`
    /// must be bit-identical to slicing the fused `[batch, seq,
    /// vocab]` result at position `len - 1` — for the reference
    /// override AND for the inherited full-forward-then-slice default.
    #[test]
    fn forward_step_bit_identical_to_fused_slice() {
        let base = named(7, 48);
        let (batch, seq, vocab) = (5usize, 4usize, 6usize);
        let w: Vec<Arc<NamedTensors>> =
            (0..3).map(|i| Arc::new(named(10 + i, 24))).collect();
        let row_lens = [(0usize, 3usize), (1, 1), (2, 4), (3, 2), (4, 3)];
        let mut tokens = vec![PAD; batch * seq];
        for (row, len) in row_lens {
            for t in 0..len {
                tokens[row * seq + t] = (row * 7 + t * 3 + 1) as i32;
            }
        }
        let mut lens = [0usize; 5];
        for (row, len) in row_lens {
            lens[row] = len;
        }
        let groups: Vec<AdapterGroup> = [(0usize, 0usize..2), (1, 2..3), (2, 3..5)]
            .into_iter()
            .map(|(i, rows)| AdapterGroup {
                name: format!("t{i}"),
                generation: i as u64,
                weights: w[i].clone(),
                rows,
            })
            .collect();

        let mut be = ReferenceBackend::new(batch, seq, vocab, &base);
        let fused = be.forward_fused(&groups, &tokens).unwrap();
        let step = be.forward_step(&groups, &tokens, &lens).unwrap();
        assert_eq!(step.len(), batch * vocab);
        for (row, len) in row_lens {
            let want = &fused[(row * seq + len - 1) * vocab..(row * seq + len) * vocab];
            let got = &step[row * vocab..(row + 1) * vocab];
            for (a, b) in got.iter().zip(want) {
                assert_eq!(a.to_bits(), b.to_bits(), "row {row}");
            }
        }

        // the inherited default (forward_fused + slice) agrees
        struct NoOverride(ReferenceBackend);
        impl ServeBackend for NoOverride {
            fn shape(&self) -> (usize, usize, usize) {
                self.0.shape()
            }
            fn forward(
                &mut self,
                name: &str,
                generation: u64,
                weights: &Arc<NamedTensors>,
                tokens: &[i32],
            ) -> Result<Vec<f32>> {
                self.0.forward(name, generation, weights, tokens)
            }
        }
        let mut default_be = NoOverride(ReferenceBackend::new(batch, seq, vocab, &base));
        let default_step = default_be.forward_step(&groups, &tokens, &lens).unwrap();
        for (a, b) in default_step.iter().zip(&step) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        // malformed lens are rejected by both paths
        assert!(be.forward_step(&groups, &tokens, &lens[..4]).is_err());
        let mut zero = lens;
        zero[0] = 0;
        assert!(be.forward_step(&groups, &tokens, &zero).is_err());
        assert!(default_be.forward_step(&groups, &tokens, &zero).is_err());
        let mut over = lens;
        over[2] = seq + 1;
        assert!(be.forward_step(&groups, &tokens, &over).is_err());
    }

    #[test]
    fn fingerprint_cache_keys_by_name_and_generation() {
        let base = named(20, 16);
        let mut be = ReferenceBackend::new(1, 2, 4, &base);
        let w = Arc::new(named(21, 8));
        let toks = vec![1, 2];
        be.forward("a", 0, &w, &toks).unwrap();
        be.forward("a", 0, &w, &toks).unwrap(); // hit
        be.forward("a", 1, &w, &toks).unwrap(); // new generation: miss
        be.forward("b", 0, &w, &toks).unwrap(); // new name: miss
        let s = be.upload_stats();
        assert_eq!((s.hits, s.misses), (1, 3), "{s:?}");
    }
}
