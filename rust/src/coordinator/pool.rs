//! Sharded serving pool: N batch-serving workers over ONE shared
//! [`AdapterRegistry`] (the serving-path scale-out layer above
//! [`super::server::BatchServer`]).
//!
//! `BatchServer` gives one worker thread per server, so the
//! shared-base + LRU-merge architecture saturates at one core. The
//! pool spawns N workers (default [`serve_workers`], the
//! `IRQLORA_SERVE_WORKERS` knob mirroring `IRQLORA_THREADS`) that all
//! route through one registry — merged adapter weights are computed
//! once and shared, while each worker owns its execution backend (for
//! PJRT: its own runtime + device buffers + generation-keyed adapter
//! upload LRU, built on the worker thread by the factory passed to
//! [`ServerPool::spawn_with`]). Each worker serves its drained batch
//! with ONE fused forward even when it spans adapters
//! (`PoolConfig::fused`, default on; `.serial()` pins the pre-fusion
//! per-group oracle path).
//!
//! Routing is adapter-affine: [`home_worker`] consistent-hashes the
//! adapter id onto a worker so consecutive requests for one tenant hit
//! the same backend (keeping its device-side adapter upload and the
//! registry's LRU entry warm). Three situations move a request off its
//! home worker, all counted in [`PoolStats`]:
//!
//! - **steal** (default scheduler, `PoolConfig::steal` /
//!   `IRQLORA_SERVE_STEAL=0` kill switch) — a saturated home worker
//!   (queue depth at the spill threshold, default `2 × backend
//!   batch`) *parks* the request in its overflow queue instead of
//!   pushing it off-affinity; the home worker tops spare batch slots
//!   from its own overflow when it catches up, and any worker with
//!   spare batch capacity (idle, or launching a non-full batch) whose
//!   own overflow is empty pulls from the most-loaded sibling's —
//!   affinity is traded away only when capacity would otherwise go
//!   unused (pull-based balancing; this also rescues requests parked
//!   for a worker that later died);
//! - **spill** (legacy scheduler, stealing disabled) — the saturated
//!   home's request is pushed to the least-loaded alive worker at
//!   submit time;
//! - **reroute** — the home worker is dead (its backend panicked or
//!   its thread exited); the request probes forward around the ring
//!   to the next alive worker. Dead workers stay dead (their reason
//!   string is kept in [`PoolStats`]) and the rest of the pool keeps
//!   serving: requests already queued on the dying worker fail with
//!   the worker-died error (their handles resolve, nothing hangs),
//!   while all *subsequent* traffic for its adapters reroutes — one
//!   poisoned tenant cannot take down its neighbours' ongoing
//!   service.
//!
//! Submission is asynchronous: [`ServerPool::submit_async`] returns a
//! [`Pending`] handle without waiting for the reply, and every failure
//! is a typed [`ServeError`] — validation fails fast with `Rejected`,
//! exactly like `BatchServer::submit`. **Admission control** bounds the
//! parked overflow ([`park_bound`], the `IRQLORA_PARK_BOUND` knob):
//! when a saturated home worker's overflow is full the submit returns
//! `Overloaded { depth, retry_after_hint }` *immediately* instead of
//! parking unboundedly, so an open-loop submitter sheds load instead of
//! growing queues without limit. Requests may carry a per-request
//! deadline ([`ServerPool::submit_with_deadline`]); one that expires
//! before its forward launches is shed with `DeadlineExceeded` at
//! whichever touch point sees it first (submit, parked-overflow pop,
//! drain) — dead work is never executed. Parked requests **age**: once
//! parked longer than [`park_age`] (`IRQLORA_PARK_AGE_MS`) they are
//! promoted ahead of fresh channel arrivals at their home's next
//! drain, so a home that never goes idle cannot starve its overflow.
//! Transient dead-worker submits reroute under a bounded retry budget
//! (counted in [`PoolStats::retries`]).
//! Streams ([`ServerPool::submit_stream`]) ride the same routing,
//! parking, and stealing as one-shot submits; the returned handle
//! yields one reply per decode step via [`Pending::next_step`] (or its
//! `Iterator` impl) and settles on the terminal step.
//! `Pending::wait` blocks for the reply; `Pending::try_wait` polls;
//! [`Pending::wait_timeout`] / [`Pending::wait_deadline`] bound the
//! block. The blocking [`ServerPool::query`] is
//! submit + wait. [`ServerPool::shutdown`] drains every worker:
//! already-submitted `Pending` handles all resolve before the workers
//! exit (same drain semantics as `BatchServer::shutdown`, per worker;
//! each exiting worker also drains the parked overflow, stealing
//! whatever a dead sibling stranded).
//!
//! Replies are bit-identical to a single serial `BatchServer` serving
//! the same (adapter, prompt) stream: workers share the dequantized
//! base through the registry, merges are deterministic, and the fused
//! forward contract guarantees a row's logits depend only on its own
//! adapter and prompt — which worker ran the forward, which tenants
//! co-rode the batch, and whether the request was stolen can never
//! leak into the logits (the pool concurrency battery in
//! `rust/tests/pool_concurrency.rs` asserts this under contention,
//! against a `ServerConfig::serial` single-server oracle).

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{
    sync_channel, Receiver, RecvError, RecvTimeoutError, TryRecvError,
};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::runtime::Manifest;
use crate::telemetry;
use crate::util::hash::{fnv1a, FNV1A_SEED};

use super::backend::{PjrtBackend, ServeBackend};
use super::error::ServeError;
use super::registry::AdapterRegistry;
use super::server::{
    AdapterServeStats, BatchServer, ExitHook, FeedPass, Feeder, Reply, Request,
    ServerConfig, ServerStats, SubmitError,
};

/// Worker count when `IRQLORA_SERVE_WORKERS` is unset (declared in
/// `util::env` with the other knobs).
pub const DEFAULT_SERVE_WORKERS: usize = crate::util::env::DEFAULT_SERVE_WORKERS;

/// Resolve the pool worker count: the `IRQLORA_SERVE_WORKERS`
/// override, else [`DEFAULT_SERVE_WORKERS`]. Reads through
/// `util::env`.
pub fn serve_workers() -> usize {
    crate::util::env::serve_workers()
}

/// Interpret an `IRQLORA_SERVE_WORKERS` value: positive integers are
/// honored (capped at 64); zero and garbage are ignored. The parse
/// lives in `util::env`; this wrapper anchors the contract tests.
#[cfg(test)]
fn parse_workers_override(v: &str) -> Option<usize> {
    crate::util::env::parse_count(v, crate::util::env::SERVE_WORKERS_CAP)
}

/// Is work-stealing allowed by the environment? `IRQLORA_SERVE_STEAL`
/// set to `0` / `false` / `off` / `no` disables it (the kill switch
/// `scripts/verify.sh` uses to pin the legacy spill scheduler);
/// anything else — including unset — leaves it on. Reads through
/// `util::env`.
pub fn serve_steal() -> bool {
    crate::util::env::serve_steal()
}

/// Interpret an `IRQLORA_SERVE_STEAL` value (parse in `util::env`).
#[cfg(test)]
fn parse_steal_override(v: &str) -> bool {
    crate::util::env::parse_off_flag(v)
}

/// Parked-overflow capacity when `IRQLORA_PARK_BOUND` is unset: the
/// pool-wide number of requests that may sit parked before
/// `submit_async` starts refusing with `ServeError::Overloaded`.
pub const DEFAULT_PARK_BOUND: usize = crate::util::env::DEFAULT_PARK_BOUND;

/// Resolve the parked-overflow bound: the `IRQLORA_PARK_BOUND`
/// override, else [`DEFAULT_PARK_BOUND`]. Reads through `util::env`.
pub fn park_bound() -> usize {
    crate::util::env::park_bound()
}

/// Interpret an `IRQLORA_PARK_BOUND` value: positive integers are
/// honored (capped at 2^20 — beyond that the bound is no longer a
/// memory guarantee); zero and garbage are ignored (parse in
/// `util::env`).
#[cfg(test)]
fn parse_park_bound_override(v: &str) -> Option<usize> {
    crate::util::env::parse_count(v, crate::util::env::PARK_BOUND_CAP)
}

/// Aging threshold when `IRQLORA_PARK_AGE_MS` is unset: a request
/// parked longer than this is promoted ahead of fresh arrivals at its
/// home worker's next drain.
pub const DEFAULT_PARK_AGE: Duration =
    Duration::from_millis(crate::util::env::DEFAULT_PARK_AGE_MS);

/// Resolve the parked-request aging threshold: the
/// `IRQLORA_PARK_AGE_MS` override (milliseconds; `0` promotes parked
/// work ahead of fresh arrivals immediately), else
/// [`DEFAULT_PARK_AGE`]. Reads through `util::env`.
pub fn park_age() -> Duration {
    crate::util::env::park_age()
}

/// Interpret an `IRQLORA_PARK_AGE_MS` value: a non-negative integer
/// millisecond count (capped at 10 minutes; `0` is meaningful —
/// promote immediately); garbage is ignored (parse in `util::env`).
#[cfg(test)]
fn parse_park_age_override(v: &str) -> Option<Duration> {
    crate::util::env::parse_ms(v, crate::util::env::PARK_AGE_CAP_MS)
}

/// Consistent adapter→worker assignment: FNV-1a over the adapter id
/// (`util::hash`, the same hash checkpoint checksums use), reduced mod
/// `n_workers`. Deterministic across processes and runs (no
/// per-process hash seed), so a tenant's home worker is stable for a
/// fixed pool size — the property the merged-weight and device buffer
/// caches rely on.
pub fn home_worker(adapter: &str, n_workers: usize) -> usize {
    assert!(n_workers > 0, "home_worker needs at least one worker");
    (fnv1a(FNV1A_SEED, adapter.as_bytes()) % n_workers as u64) as usize
}

/// Pool configuration.
pub struct PoolConfig {
    /// Worker count; `0` means [`serve_workers`] (the
    /// `IRQLORA_SERVE_WORKERS` env default). Clamped to 1..=64 at
    /// spawn (the same cap the env override has), so a typo'd
    /// `--workers 1000000` can't spawn unbounded threads/runtimes.
    pub workers: usize,
    /// Per-worker batcher window (see [`ServerConfig::max_wait`]).
    pub max_wait: Duration,
    /// Queue depth at which a request leaves the direct path on its
    /// home worker — parked in its overflow (stealing on) or spilled
    /// to the least-loaded worker (stealing off); `None` means
    /// `2 × backend batch`.
    pub spill_depth: Option<usize>,
    /// One fused forward per drained batch (default). `false` pins
    /// every worker to the per-group serial oracle path.
    pub fused: bool,
    /// Work-stealing scheduler (default). Gated additionally by the
    /// `IRQLORA_SERVE_STEAL` env kill switch ([`serve_steal`]), and
    /// inert on single-worker pools.
    pub steal: bool,
    /// Pool-wide parked-overflow capacity; `None` means [`park_bound`]
    /// (the `IRQLORA_PARK_BOUND` env default). A full overflow makes
    /// `submit_async` refuse with `ServeError::Overloaded`.
    pub park_bound: Option<usize>,
    /// Parked-request aging threshold; `None` means [`park_age`] (the
    /// `IRQLORA_PARK_AGE_MS` env default). Parked longer than this, a
    /// request is promoted ahead of fresh arrivals.
    pub park_age: Option<Duration>,
    /// Telemetry registry this pool (and its workers) record into;
    /// `None` means the process-global registry
    /// ([`crate::telemetry::global`], enabled by `IRQLORA_TELEMETRY`).
    /// Tests inject a scoped enabled registry here so parallel test
    /// binaries never touch process env or each other's counters.
    pub telemetry: Option<Arc<telemetry::Registry>>,
}

impl PoolConfig {
    pub fn new(workers: usize, max_wait: Duration) -> PoolConfig {
        PoolConfig {
            workers,
            max_wait,
            spill_depth: None,
            fused: true,
            steal: true,
            park_bound: None,
            park_age: None,
            telemetry: None,
        }
    }

    /// Pin the per-group serial oracle forward path.
    pub fn serial(mut self) -> PoolConfig {
        self.fused = false;
        self
    }

    /// Disable the work-stealing scheduler (legacy push-spill).
    pub fn no_steal(mut self) -> PoolConfig {
        self.steal = false;
        self
    }
}

/// Pool-level store of parked requests, shared between the submit path
/// (which parks when a home worker saturates) and the worker feeders
/// (which pull): one FIFO overflow queue per worker, a pool-wide
/// parked count doubling as the admission-control bound, the aging
/// threshold, and the steal / shed counters.
struct StealBus {
    queues: Vec<Mutex<VecDeque<Request>>>,
    parked: AtomicUsize,
    steals: AtomicUsize,
    /// Pool-wide parked capacity ([`park_bound`] / the config
    /// override); [`Self::try_park`] refuses beyond it.
    bound: usize,
    /// Promotion threshold for [`Self::pop_own_aged`] ([`park_age`]).
    age: Duration,
    /// High-water mark of `parked` — by the CAS in
    /// [`Self::try_park`], can never exceed `bound`.
    parked_peak: AtomicUsize,
    /// Parked requests shed with `DeadlineExceeded` at a pop.
    shed_deadline: AtomicUsize,
    /// Telemetry mirrors of `steals` / `shed_deadline` /
    /// `parked_peak`, incremented at the same sites. No-op handles
    /// (the [`StealBus::new`] default) unless the pool attaches live
    /// ones at spawn.
    t_steals: telemetry::Counter,
    t_shed_deadline: telemetry::Counter,
    t_parked_peak: telemetry::Gauge,
}

impl StealBus {
    fn new(n: usize, bound: usize, age: Duration) -> StealBus {
        StealBus {
            queues: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            parked: AtomicUsize::new(0),
            steals: AtomicUsize::new(0),
            bound: bound.max(1),
            age,
            parked_peak: AtomicUsize::new(0),
            shed_deadline: AtomicUsize::new(0),
            t_steals: telemetry::Counter::noop(),
            t_shed_deadline: telemetry::Counter::noop(),
            t_parked_peak: telemetry::Gauge::noop(),
        }
    }

    /// Park `r` for `worker` unless the pool-wide overflow is at its
    /// bound — then hand the request back so the submit path can
    /// refuse it with `Overloaded`. The slot is RESERVED by CAS before
    /// the push (not a load-then-add), so concurrent parkers can never
    /// drive `parked` past `bound` between them — the admission bound
    /// is exact, not advisory. (Reserving before pushing also means a
    /// drain's decrement can never underflow; the transient
    /// reserved-but-unpushed state only costs a harmless empty poll.)
    fn try_park(&self, worker: usize, r: Request) -> Result<(), Request> {
        let mut cur = self.parked.load(Ordering::Acquire);
        loop {
            if cur >= self.bound {
                return Err(r);
            }
            match self.parked.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        self.queues[worker].lock().unwrap().push_back(r);
        let depth = cur + 1;
        self.t_parked_peak.set_max(depth as u64);
        let mut peak = self.parked_peak.load(Ordering::Acquire);
        while depth > peak {
            match self.parked_peak.compare_exchange_weak(
                peak,
                depth,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => break,
                Err(seen) => peak = seen,
            }
        }
        Ok(())
    }

    /// Answer every expired request in `popped` with
    /// `DeadlineExceeded` (counting it) and return the live remainder.
    /// Runs at every pop — the parked-overflow deadline touch point.
    fn shed_expired(&self, popped: Vec<Request>, now: Instant) -> Vec<Request> {
        let mut live = Vec::with_capacity(popped.len());
        for r in popped {
            if r.expired(now) {
                self.shed_deadline.fetch_add(1, Ordering::AcqRel);
                self.t_shed_deadline.inc();
                r.shed_expired();
            } else {
                live.push(r);
            }
        }
        live
    }

    /// Pop up to `max` requests parked for `worker` (FIFO); expired
    /// ones are shed, not returned.
    fn pop_own(&self, worker: usize, max: usize) -> Vec<Request> {
        if max == 0 || self.parked.load(Ordering::Acquire) == 0 {
            return Vec::new();
        }
        let mut q = self.queues[worker].lock().unwrap();
        let take = q.len().min(max);
        let out: Vec<Request> = q.drain(..take).collect();
        drop(q);
        if take > 0 {
            self.parked.fetch_sub(take, Ordering::AcqRel);
        }
        self.shed_expired(out, Instant::now())
    }

    /// Pop up to `max` requests parked for `worker` that have aged past
    /// the promotion threshold. FIFO order means the queue front is the
    /// oldest parked request, so the aged set is exactly the queue's
    /// prefix — the pop stops at the first not-yet-aged request.
    /// Expired ones are shed, not returned.
    fn pop_own_aged(&self, worker: usize, max: usize) -> Vec<Request> {
        if max == 0 || self.parked.load(Ordering::Acquire) == 0 {
            return Vec::new();
        }
        let now = Instant::now();
        let mut q = self.queues[worker].lock().unwrap();
        let mut out: Vec<Request> = Vec::new();
        while out.len() < max {
            match q.front() {
                Some(r) if now.duration_since(r.enqueued) >= self.age => {
                    out.push(q.pop_front().unwrap());
                }
                _ => break,
            }
        }
        drop(q);
        if !out.is_empty() {
            self.parked.fetch_sub(out.len(), Ordering::AcqRel);
        }
        self.shed_expired(out, now)
    }

    /// Steal up to `max` requests from the longest overflow queue of
    /// any *other* worker (dead ones included — that is how requests
    /// stranded by a worker death get rescued). FIFO within the
    /// victim's queue; expired ones are shed, not returned (and not
    /// counted as steals — shed work was never served).
    fn steal_from_busiest(&self, thief: usize, max: usize) -> Vec<Request> {
        if max == 0 || self.parked.load(Ordering::Acquire) == 0 {
            return Vec::new();
        }
        let mut victim = None;
        let mut longest = 0usize;
        for (i, q) in self.queues.iter().enumerate() {
            if i == thief {
                continue;
            }
            let len = q.lock().unwrap().len();
            if len > longest {
                longest = len;
                victim = Some(i);
            }
        }
        let Some(v) = victim else { return Vec::new() };
        let mut q = self.queues[v].lock().unwrap();
        let take = q.len().min(max);
        let out: Vec<Request> = q.drain(..take).collect();
        drop(q);
        if take > 0 {
            self.parked.fetch_sub(take, Ordering::AcqRel);
        }
        let live = self.shed_expired(out, Instant::now());
        if !live.is_empty() {
            self.steals.fetch_add(live.len(), Ordering::AcqRel);
            self.t_steals.add(live.len() as u64);
        }
        live
    }

    /// Drop every parked request (closing their reply senders, so
    /// outstanding [`Pending`] handles resolve with the dropped-reply
    /// error instead of hanging). Called when the LAST worker dies —
    /// with no worker left to pull the overflow, the bus would
    /// otherwise keep the senders alive until pool teardown.
    fn purge(&self) {
        for q in &self.queues {
            let drained: Vec<Request> = q.lock().unwrap().drain(..).collect();
            if !drained.is_empty() {
                self.parked.fetch_sub(drained.len(), Ordering::AcqRel);
            }
            drop(drained);
        }
    }
}

/// Pool-wide liveness tally: when the last worker is marked dead, no
/// thread will ever pull the parked overflow again, so the watch
/// purges the [`StealBus`] — already-parked [`Pending`] handles
/// resolve (with an error) instead of blocking forever. (A death can
/// only be *observed* through a handle or a submit, so any thread
/// that could block on a parked reply either triggers this purge
/// itself or was already answered.)
struct DeathWatch {
    alive: AtomicUsize,
    bus: Option<Arc<StealBus>>,
}

impl DeathWatch {
    fn worker_died(&self) {
        if self.alive.fetch_sub(1, Ordering::AcqRel) == 1 {
            if let Some(bus) = &self.bus {
                bus.purge();
            }
        }
    }
}

/// State shared between the pool, its routing decisions, and the
/// [`Pending`] handles in flight against one worker.
struct WorkerShared {
    /// Requests routed here whose [`Pending`] handle has not settled
    /// yet (waited, polled to completion, or dropped). This is the
    /// queue-depth signal spill/park decisions use; note a reply that
    /// has been *delivered* but not yet harvested by its handle still
    /// counts, so a large un-harvested `submit_async` burst reads as
    /// depth — which is the intended hot-adapter trigger.
    in_flight: AtomicUsize,
    /// Total requests ever routed here.
    routed: AtomicUsize,
    /// `Some(reason)` once the worker is known dead. Sticky: a dead
    /// worker is never routed to again.
    dead: Mutex<Option<String>>,
    /// Pool-wide liveness watch, notified on this worker's first
    /// recorded death.
    watch: Arc<DeathWatch>,
}

impl WorkerShared {
    fn new(watch: Arc<DeathWatch>) -> WorkerShared {
        WorkerShared {
            in_flight: AtomicUsize::new(0),
            routed: AtomicUsize::new(0),
            dead: Mutex::new(None),
            watch,
        }
    }

    fn is_alive(&self) -> bool {
        self.dead.lock().unwrap().is_none()
    }

    /// First reason wins; later observers of the same death are no-ops.
    fn mark_dead(&self, reason: String) {
        let mut d = self.dead.lock().unwrap();
        if d.is_none() {
            *d = Some(reason);
            self.watch.worker_died();
        }
    }
}

struct PoolWorker {
    server: BatchServer,
    shared: Arc<WorkerShared>,
}

/// Telemetry mirrors of [`RoutingCounters`] (and the bus counters the
/// pool-level `pool.*` keys cover), incremented at the same mutation
/// sites so [`PoolStats`] and a telemetry snapshot reconcile exactly.
/// Resolved once at spawn from `PoolConfig.telemetry` (else the
/// process-global registry); all no-ops when that registry is
/// disabled.
struct PoolTelem {
    spills: telemetry::Counter,
    reroutes: telemetry::Counter,
    retries: telemetry::Counter,
    shed_overload: telemetry::Counter,
    shed_deadline: telemetry::Counter,
}

impl PoolTelem {
    fn resolve(reg: &telemetry::Registry) -> PoolTelem {
        PoolTelem {
            spills: reg.counter("pool.spills", &[]),
            reroutes: reg.counter("pool.reroutes", &[]),
            retries: reg.counter("pool.retries", &[]),
            shed_overload: reg.counter("pool.shed_overload", &[]),
            shed_deadline: reg.counter("pool.shed_deadline", &[]),
        }
    }
}

#[derive(Default)]
struct RoutingCounters {
    spills: usize,
    reroutes: usize,
    /// Transient dead-worker submit reroute retries spent (bounded per
    /// submit by the pool's retry budget).
    retries: usize,
    /// Submits refused with `Overloaded` (parked overflow full).
    shed_overload: usize,
    /// Submits shed with `DeadlineExceeded` before reaching a worker
    /// (the pool-level pre-routing touch point; worker-level and
    /// parked-overflow sheds are counted where they happen).
    shed_deadline: usize,
}

/// One worker's slice of [`PoolStats`].
#[derive(Clone, Debug)]
pub struct PoolWorkerStats {
    /// Requests routed to this worker over the pool's lifetime.
    pub routed: usize,
    /// Requests currently queued/executing here (snapshot).
    pub in_flight: usize,
    /// Why this worker died, if it did.
    pub dead: Option<String>,
    /// The worker's own serving counters.
    pub server: ServerStats,
}

/// Aggregate pool metrics: per-worker occupancy + liveness, routing
/// counters, and the per-adapter breakdown summed across workers.
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    pub workers: Vec<PoolWorkerStats>,
    /// Requests sent off their home worker because it was saturated
    /// (legacy scheduler; always 0 with stealing on).
    pub spills: usize,
    /// Requests sent off their home worker because it was dead.
    pub reroutes: usize,
    /// Parked requests served by a non-home worker (stealing
    /// scheduler; always 0 with stealing off).
    pub steals: usize,
    /// Requests currently parked in overflow queues (snapshot).
    pub parked: usize,
    /// Served requests, summed across workers.
    pub requests: usize,
    /// Forward calls, summed across workers.
    pub batches: usize,
    /// Fused forward calls, summed across workers.
    pub fused_batches: usize,
    /// Backend adapter-cache hits (device uploads avoided), summed.
    pub upload_hits: usize,
    /// Backend adapter-cache misses (uploads performed), summed.
    pub upload_misses: usize,
    /// Submit-time rejections, summed across workers.
    pub rejected: usize,
    /// Submits refused with `ServeError::Overloaded` because the
    /// bounded parked overflow was full (admission control).
    pub shed_overload: usize,
    /// Requests shed with `ServeError::DeadlineExceeded`, summed over
    /// every touch point: pool submit, worker submit/admission/
    /// mid-stream step boundaries, and parked-overflow pops.
    pub shed_deadline: usize,
    /// The subset of `shed_deadline` that hit a stream after it had
    /// already delivered at least one step, summed across workers.
    pub shed_midstream: usize,
    /// Decode-step results delivered, summed across workers (a
    /// one-shot request contributes 1; an S-step stream up to S).
    pub steps: usize,
    /// Requests admitted with more than one decode step, summed.
    pub stream_requests: usize,
    /// Transient dead-worker reroute retries spent at submit (each
    /// bounded per request by the pool's retry budget).
    pub retries: usize,
    /// High-water mark of the parked overflow; never exceeds the
    /// pool's park bound (`IRQLORA_PARK_BOUND` / config override).
    pub parked_peak: usize,
    /// Per-adapter occupancy, summed across workers.
    pub per_adapter: BTreeMap<String, AdapterServeStats>,
}

impl PoolStats {
    /// Workers still accepting traffic.
    pub fn alive(&self) -> usize {
        self.workers.iter().filter(|w| w.dead.is_none()).count()
    }

    /// Requests submitted but not yet resolved, across all workers.
    pub fn queue_depth(&self) -> usize {
        self.workers.iter().map(|w| w.in_flight).sum()
    }

    /// Mean rows per forward call across every worker's forwards.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.workers
                .iter()
                .map(|w| w.server.batch_occupancy_sum)
                .sum::<usize>() as f64
                / self.batches as f64
        }
    }
}

/// A reply that has been submitted but not yet received. Dropping the
/// handle abandons the reply (the worker still serves the request);
/// the pool's in-flight accounting settles either way.
pub struct Pending {
    rx: Receiver<Result<Reply, ServeError>>,
    shared: Arc<WorkerShared>,
    worker: usize,
    adapter: String,
    /// True when the request was parked in an overflow queue rather
    /// than submitted to `worker`'s own channel. A parked request may
    /// be served by ANY worker (the home when it catches up, a thief
    /// when idle), so a dropped reply cannot be blamed on `worker` —
    /// see [`Self::resolve`].
    parked: bool,
    settled: bool,
}

impl Pending {
    /// Worker index this request was routed to (with stealing enabled
    /// a *parked* request may ultimately be served by a different,
    /// idle worker — the logits are identical either way; this is the
    /// routing target whose load the request counted against).
    pub fn worker(&self) -> usize {
        self.worker
    }

    /// Adapter the request targets.
    pub fn adapter(&self) -> &str {
        &self.adapter
    }

    fn settle(&mut self) {
        if !self.settled {
            self.settled = true;
            self.shared.in_flight.fetch_sub(1, Ordering::AcqRel);
        }
    }

    fn consumed(&self) -> ServeError {
        ServeError::Rejected(format!(
            "reply for adapter '{}' already consumed",
            self.adapter
        ))
    }

    fn resolve(
        &mut self,
        got: Result<Result<Reply, ServeError>, RecvError>,
    ) -> Result<Reply, ServeError> {
        match got {
            // the worker delivered a step: the handle settles only on
            // the stream's TERMINAL message (`last` step or a typed
            // failure) — a one-shot request's single reply has
            // `last == true`, so its accounting is unchanged
            Ok(Ok(r)) => {
                if r.last {
                    self.settle();
                }
                Ok(r)
            }
            Ok(Err(e)) => {
                self.settle();
                Err(e)
            }
            Err(_) if self.parked => {
                self.settle();
                // a parked request's reply sender can be dropped by
                // whichever worker pulled it — a dying thief, not
                // necessarily the (possibly healthy) home this handle
                // counted against — or by pool teardown. Blame nobody:
                // an actually-dead server gets marked by its OWN
                // requests (reply drop below, WorkerGone at submit).
                Err(ServeError::WorkerDead {
                    worker: None,
                    reason: format!(
                        "request for adapter '{}' (parked for worker {}) was dropped \
                         before a reply — its serving worker died or the pool shut down",
                        self.adapter, self.worker
                    ),
                })
            }
            Err(_) => {
                self.settle();
                // the worker dropped our reply sender without
                // answering: its thread died (panicking backend) —
                // record the death so routing stops using it. The
                // adapter named here is the first to OBSERVE the
                // death, not necessarily the one whose forward killed
                // the worker (other queued requests die with it).
                let reason = format!(
                    "worker died (first observed by a request for adapter '{}')",
                    self.adapter
                );
                self.shared.mark_dead(reason);
                Err(ServeError::WorkerDead {
                    worker: Some(self.worker),
                    reason: format!(
                        "while serving adapter '{}' (reply dropped without an answer)",
                        self.adapter
                    ),
                })
            }
        }
    }

    /// Block until the reply arrives (or the worker dies). Like
    /// [`Self::try_wait`], a reply already consumed by an earlier poll
    /// reports an error — it must not be misread as a worker death.
    pub fn wait(mut self) -> Result<Reply, ServeError> {
        if self.settled {
            return Err(self.consumed());
        }
        let got = self.rx.recv();
        self.resolve(got)
    }

    /// Poll for the reply: `None` while still in flight. After it has
    /// returned `Some`, the reply is consumed — further polls report
    /// an error rather than misreading the closed channel as a death.
    pub fn try_wait(&mut self) -> Option<Result<Reply, ServeError>> {
        if self.settled {
            return Some(Err(self.consumed()));
        }
        match self.rx.try_recv() {
            Ok(r) => Some(self.resolve(Ok(r))),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => Some(self.resolve(Err(RecvError))),
        }
    }

    /// [`Self::wait`] bounded by the caller's own patience: blocks at
    /// most `timeout`, returning `None` when the reply has not arrived
    /// in time. The handle stays usable after a `None` — call again
    /// with a fresh timeout, or fall through to a blocking `wait`.
    /// Once it returns `Some`, the reply is consumed (further calls
    /// report the consumed error), matching [`Self::try_wait`].
    pub fn wait_timeout(&mut self, timeout: Duration) -> Option<Result<Reply, ServeError>> {
        if self.settled {
            return Some(Err(self.consumed()));
        }
        match self.rx.recv_timeout(timeout) {
            Ok(r) => Some(self.resolve(Ok(r))),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => Some(self.resolve(Err(RecvError))),
        }
    }

    /// [`Self::wait_timeout`] against an absolute deadline (a deadline
    /// already in the past degenerates to a single non-blocking poll).
    pub fn wait_deadline(&mut self, deadline: Instant) -> Option<Result<Reply, ServeError>> {
        self.wait_timeout(deadline.saturating_duration_since(Instant::now()))
    }

    /// Block for the stream's next decode step: `Some(Ok(reply))` per
    /// step ([`Reply::last`] marks the final one), `Some(Err(..))` on a
    /// terminal failure (deadline shed mid-stream, backend fault,
    /// worker death), and `None` once the stream has terminated (the
    /// last/error reply was already returned). For a one-shot submit
    /// this yields exactly one `Some`. [`Pending`] also implements
    /// `Iterator` over the same sequence, so
    /// `for step in pending { .. }` streams the tokens.
    pub fn next_step(&mut self) -> Option<Result<Reply, ServeError>> {
        if self.settled {
            return None;
        }
        let got = self.rx.recv();
        Some(self.resolve(got))
    }
}

/// Token streaming: each `next()` blocks for one decode step, ending
/// after the terminal reply (see [`Pending::next_step`]).
impl Iterator for Pending {
    type Item = Result<Reply, ServeError>;

    fn next(&mut self) -> Option<Self::Item> {
        self.next_step()
    }
}

impl Drop for Pending {
    fn drop(&mut self) {
        self.settle();
    }
}

/// N [`BatchServer`] workers over one shared [`AdapterRegistry`], with
/// adapter-affinity routing, fused mixed-adapter forwards, work
/// stealing, and async submission (module docs).
pub struct ServerPool {
    workers: Vec<PoolWorker>,
    registry: Arc<AdapterRegistry>,
    routing: Mutex<RoutingCounters>,
    telem: PoolTelem,
    /// Present iff the work-stealing scheduler is active.
    bus: Option<Arc<StealBus>>,
    /// Pool-wide liveness tally (drives the last-death overflow purge).
    watch: Arc<DeathWatch>,
    spill_depth: usize,
    /// Per-worker batcher window, kept for the `Overloaded`
    /// retry-after hint (≈ how long one drained batch occupies a
    /// worker).
    max_wait: Duration,
    seq: usize,
    vocab: usize,
}

/// Sleep between dead-worker submit reroute attempts, scaled by the
/// attempt number (linear backoff: 50µs, 100µs, …).
const SUBMIT_RETRY_BACKOFF: Duration = Duration::from_micros(50);

impl ServerPool {
    /// Spawn a pool of PJRT-backed workers over the manifest's
    /// `forward` graph for `tag`. Each worker owns its runtime and
    /// uploads the shared base once; the registry (and its merged
    /// cache) is shared across all of them.
    pub fn spawn(
        manifest: Manifest,
        tag: &str,
        cfg: PoolConfig,
        registry: Arc<AdapterRegistry>,
    ) -> Result<ServerPool> {
        let tag = tag.to_string();
        let reg = registry.clone();
        Self::spawn_with(cfg, registry, move |_worker| {
            Ok(Box::new(PjrtBackend::new(&manifest, &tag, reg.base())?)
                as Box<dyn ServeBackend>)
        })
    }

    /// Spawn over an explicit backend factory, called once per worker
    /// (with the worker index) on that worker's thread — backends may
    /// own thread-bound resources. Tests and the offline bench smoke
    /// pass [`super::backend::ReferenceBackend`] factories here.
    pub fn spawn_with<F>(
        cfg: PoolConfig,
        registry: Arc<AdapterRegistry>,
        make_backend: F,
    ) -> Result<ServerPool>
    where
        F: Fn(usize) -> Result<Box<dyn ServeBackend>> + Send + Sync + 'static,
    {
        let n = (if cfg.workers == 0 { serve_workers() } else { cfg.workers }).clamp(1, 64);
        // stealing needs a sibling to steal from, and the env kill
        // switch wins over the config so verify.sh can pin the legacy
        // scheduler without touching call sites
        let steal = cfg.steal && serve_steal() && n > 1;
        let bound = cfg.park_bound.unwrap_or_else(park_bound).max(1);
        let age = cfg.park_age.unwrap_or_else(park_age);
        let treg = cfg.telemetry.clone().unwrap_or_else(telemetry::global);
        let telem = PoolTelem::resolve(&treg);
        let serve_telem = super::server::ServeTelem::resolve(&treg);
        let bus = steal.then(|| {
            let mut b = StealBus::new(n, bound, age);
            b.t_steals = treg.counter("pool.steals", &[]);
            // bus sheds and routing sheds fold into ONE pool-level key,
            // matching how PoolStats::shed_deadline folds them
            b.t_shed_deadline = telem.shed_deadline.clone();
            b.t_parked_peak = treg.gauge("pool.parked_peak", &[]);
            Arc::new(b)
        });
        let watch = Arc::new(DeathWatch { alive: AtomicUsize::new(n), bus: bus.clone() });
        let factory = Arc::new(make_backend);
        let mut workers = Vec::with_capacity(n);
        for w in 0..n {
            let f = factory.clone();
            let feeder: Option<Feeder> = bus.as_ref().map(|bus| {
                let bus = bus.clone();
                Box::new(move |pass: FeedPass, max: usize| match pass {
                    // promotion pass: only this worker's own parked
                    // requests past the aging threshold — stealing
                    // stays an idle-capacity affair (the Any pass)
                    FeedPass::Aged => bus.pop_own_aged(w, max),
                    FeedPass::Any => {
                        let mut got = bus.pop_own(w, max);
                        if got.is_empty() {
                            got = bus.steal_from_busiest(w, max);
                        }
                        got
                    }
                }) as Feeder
            });
            let shared = Arc::new(WorkerShared::new(watch.clone()));
            // proactive death marking: a panicking worker marks ITSELF
            // during unwind, so even a death whose only witnesses are
            // parked/stolen requests (which deliberately blame nobody
            // — see Pending::resolve) still reaches the DeathWatch and
            // can trigger the last-death overflow purge
            let exit_hook: ExitHook = {
                let shared = shared.clone();
                Box::new(move |panicked: bool| {
                    if panicked {
                        shared.mark_dead(
                            "worker thread panicked (backend fault)".to_string(),
                        );
                    }
                })
            };
            let server = BatchServer::spawn_with_feeder(
                ServerConfig { max_wait: cfg.max_wait, fused: cfg.fused },
                registry.clone(),
                move || f(w),
                feeder,
                Some(exit_hook),
                serve_telem.clone(),
            )
            .with_context(|| format!("spawning pool worker {w} of {n}"))?;
            workers.push(PoolWorker { server, shared });
        }
        let spill_depth = cfg
            .spill_depth
            .unwrap_or_else(|| 2 * workers[0].server.max_batch())
            .max(1);
        let seq = workers[0].server.max_prompt_len();
        let vocab = workers[0].server.vocab();
        // routing assumes interchangeable workers: a factory returning
        // per-worker shapes would make accept/reject depend on where a
        // request happened to land
        for (i, w) in workers.iter().enumerate() {
            anyhow::ensure!(
                w.server.max_batch() == workers[0].server.max_batch()
                    && w.server.max_prompt_len() == seq
                    && w.server.vocab() == vocab,
                "pool worker {i} has a different backend shape than worker 0"
            );
        }
        Ok(ServerPool {
            workers,
            registry,
            routing: Mutex::new(RoutingCounters::default()),
            telem,
            bus,
            watch,
            spill_depth,
            max_wait: cfg.max_wait,
            seq,
            vocab,
        })
    }

    /// Pool size (including dead workers).
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Largest prompt (in tokens) the pool accepts.
    pub fn max_prompt_len(&self) -> usize {
        self.seq
    }

    /// Logit width of every reply.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// The registry every worker routes through.
    pub fn registry(&self) -> &Arc<AdapterRegistry> {
        &self.registry
    }

    /// Is the work-stealing scheduler active on this pool?
    pub fn stealing(&self) -> bool {
        self.bus.is_some()
    }

    /// First alive worker probing forward around the ring from `home`.
    /// `None` when every worker is dead. Returns (index, rerouted).
    fn first_alive(&self, home: usize) -> Option<(usize, bool)> {
        let n = self.workers.len();
        for off in 0..n {
            let i = (home + off) % n;
            if self.workers[i].shared.is_alive() {
                return Some((i, off != 0));
            }
        }
        None
    }

    /// Legacy-scheduler target for an adapter homed at `home`: the
    /// first alive worker from home, pushed to the least-loaded alive
    /// worker when saturated. Returns (index, spilled, rerouted).
    fn route(&self, home: usize) -> Option<(usize, bool, bool)> {
        let (pi, rerouted) = self.first_alive(home)?;
        let depth = self.workers[pi].shared.in_flight.load(Ordering::Acquire);
        if depth >= self.spill_depth {
            let spill = self
                .workers
                .iter()
                .enumerate()
                .filter(|(i, w)| *i != pi && w.shared.is_alive())
                .min_by_key(|(_, w)| w.shared.in_flight.load(Ordering::Acquire));
            if let Some((si, sw)) = spill {
                if sw.shared.in_flight.load(Ordering::Acquire) < depth {
                    return Some((si, true, rerouted));
                }
            }
        }
        Some((pi, false, rerouted))
    }

    /// Submit without waiting for the reply: returns a [`Pending`]
    /// handle, or a typed [`ServeError`]. Malformed prompts and
    /// unknown adapters fail here with `Rejected`, before routing; a
    /// dead target worker is marked and the request reroutes
    /// transparently (bounded retry budget, counted in
    /// [`PoolStats::retries`]); an all-dead pool fails with
    /// `Shutdown`. With stealing on, a saturated home worker's request
    /// parks in its *bounded* overflow (served by the home worker when
    /// it catches up, promoted once aged, or pulled by whichever
    /// worker goes idle first) — and when that overflow is FULL the
    /// submit refuses with `Overloaded { depth, retry_after_hint }`
    /// instead of growing queues without limit, so an open-loop
    /// submitter sheds load at the door. With stealing off it spills
    /// to the least-loaded worker (each worker's direct queue is
    /// bounded at 1024 slots, so a fully saturated legacy pool can
    /// block this call until a slot frees).
    pub fn submit_async(&self, adapter: &str, tokens: Vec<i32>) -> Result<Pending, ServeError> {
        self.submit_with_deadline(adapter, tokens, None)
    }

    /// [`Self::submit_async`] with an optional per-request deadline.
    /// A request still queued (anywhere — worker channel, parked
    /// overflow, drained batch) when `deadline` passes is shed with
    /// `DeadlineExceeded` instead of executing dead work; one that
    /// reaches its forward before the deadline is served normally.
    /// `None` waits forever (the plain `submit_async` behavior).
    pub fn submit_with_deadline(
        &self,
        adapter: &str,
        tokens: Vec<i32>,
        deadline: Option<Instant>,
    ) -> Result<Pending, ServeError> {
        self.submit_inner(adapter, tokens, 1, deadline)
    }

    /// Submit an S-step greedy decode stream: the request rides the
    /// same routing (affinity, parking, stealing, aging) as a one-shot
    /// submit, joins its worker's always-running batch, and the
    /// returned [`Pending`] yields one [`Reply`] per decode step via
    /// [`Pending::next_step`] / its `Iterator` impl (each step's
    /// logits are computed at the stream's current last position; the
    /// worker extends the prompt greedily between steps). `steps == 1`
    /// is exactly [`Self::submit_async`]. Step counts outside
    /// `1..=IRQLORA_STREAM_MAX_STEPS`, or prompts too long to extend
    /// (`tokens.len() + steps - 1 > seq`), are `Rejected` at submit.
    pub fn submit_stream(
        &self,
        adapter: &str,
        tokens: Vec<i32>,
        steps: usize,
    ) -> Result<Pending, ServeError> {
        self.submit_inner(adapter, tokens, steps, None)
    }

    /// [`Self::submit_stream`] with an optional deadline honored
    /// BETWEEN decode steps: a stream whose deadline passes mid-flight
    /// is shed with `DeadlineExceeded` at its next step boundary
    /// (counted in [`PoolStats::shed_deadline`] and, if it had already
    /// streamed a step, `shed_midstream`) without disturbing
    /// co-batched tenants.
    pub fn submit_stream_with_deadline(
        &self,
        adapter: &str,
        tokens: Vec<i32>,
        steps: usize,
        deadline: Option<Instant>,
    ) -> Result<Pending, ServeError> {
        self.submit_inner(adapter, tokens, steps, deadline)
    }

    fn submit_inner(
        &self,
        adapter: &str,
        tokens: Vec<i32>,
        steps: usize,
        deadline: Option<Instant>,
    ) -> Result<Pending, ServeError> {
        // shed already-dead work before spending any routing effort on
        // it (the submit-time deadline touch point)
        if deadline.map_or(false, |d| Instant::now() >= d) {
            self.routing.lock().unwrap().shed_deadline += 1;
            self.telem.shed_deadline.inc();
            return Err(ServeError::DeadlineExceeded { waited: Duration::ZERO });
        }
        let n = self.workers.len();
        let home = home_worker(adapter, n);
        let mut tokens = tokens;
        // each WorkerGone reroute marks its worker dead, so the loop
        // naturally terminates within n iterations; the explicit
        // budget is a backstop that also drives the backoff and the
        // observable retry counter
        let retry_budget = n + 2;
        let mut attempts = 0usize;
        loop {
            // stealing scheduler: saturated-but-alive home ⇒ park in
            // its overflow, preserving affinity when the home catches
            // up and letting idle siblings pull otherwise
            if let Some(bus) = &self.bus {
                let Some((pi, rerouted)) = self.first_alive(home) else {
                    return Err(ServeError::Shutdown);
                };
                let w = &self.workers[pi];
                let depth = w.shared.in_flight.load(Ordering::Acquire);
                if depth >= self.spill_depth {
                    // same submit-time validation (and rejected
                    // accounting) a direct submit would get
                    w.server.check_stream(adapter, &tokens, steps)?;
                    // one reply slot per step so whichever worker
                    // eventually pulls this stream never blocks on a
                    // lazy harvester (same capacity a direct stream
                    // submit gets)
                    let (reply_tx, reply_rx) = sync_channel(steps.max(1));
                    let parked = bus.try_park(
                        pi,
                        Request {
                            adapter: adapter.to_string(),
                            tokens,
                            steps,
                            enqueued: Instant::now(),
                            deadline,
                            reply: reply_tx,
                        },
                    );
                    if let Err(refused) = parked {
                        // admission control: the bounded overflow is
                        // full — refuse NOW with a typed, retryable
                        // error instead of queueing without limit
                        drop(refused);
                        self.routing.lock().unwrap().shed_overload += 1;
                        self.telem.shed_overload.inc();
                        let parked_depth = bus.parked.load(Ordering::Acquire);
                        return Err(ServeError::Overloaded {
                            depth: parked_depth,
                            retry_after_hint: self.retry_hint(parked_depth),
                        });
                    }
                    // close the park-vs-purge race: if the LAST worker
                    // died between the liveness check above and the
                    // push, DeathWatch's purge may have swept an
                    // empty queue — re-check (lock-free: the watch's
                    // tally, not n dead-mutexes, on this hot path) now
                    // that the item is visible and purge again, so the
                    // just-parked request resolves instead of
                    // stranding (either this purge or the one ordered
                    // after the final mark_dead sees it)
                    if self.watch.alive.load(Ordering::Acquire) == 0 {
                        bus.purge();
                    }
                    if rerouted {
                        self.routing.lock().unwrap().reroutes += 1;
                        self.telem.reroutes.inc();
                    }
                    w.shared.routed.fetch_add(1, Ordering::AcqRel);
                    w.shared.in_flight.fetch_add(1, Ordering::AcqRel);
                    return Ok(Pending {
                        rx: reply_rx,
                        shared: w.shared.clone(),
                        worker: pi,
                        adapter: adapter.to_string(),
                        parked: true,
                        settled: false,
                    });
                }
                match w.server.try_submit_stream_at(adapter, tokens, steps, deadline) {
                    Ok(rx) => {
                        if rerouted {
                            self.routing.lock().unwrap().reroutes += 1;
                            self.telem.reroutes.inc();
                        }
                        w.shared.routed.fetch_add(1, Ordering::AcqRel);
                        w.shared.in_flight.fetch_add(1, Ordering::AcqRel);
                        return Ok(Pending {
                            rx,
                            shared: w.shared.clone(),
                            worker: pi,
                            adapter: adapter.to_string(),
                            parked: false,
                            settled: false,
                        });
                    }
                    Err(SubmitError::Rejected(e)) => return Err(e),
                    Err(SubmitError::WorkerGone(t)) => {
                        w.shared
                            .mark_dead("worker exited before accepting a request".to_string());
                        tokens = t;
                        attempts += 1;
                        self.count_retry(pi, attempts, retry_budget)?;
                        continue;
                    }
                }
            }

            // legacy scheduler: push-spill off a saturated home
            let Some((idx, spilled, rerouted)) = self.route(home) else {
                return Err(ServeError::Shutdown);
            };
            let w = &self.workers[idx];
            match w.server.try_submit_stream_at(adapter, tokens, steps, deadline) {
                Ok(rx) => {
                    // one off-home cause per request: a dead home is
                    // the root cause even if the replacement was also
                    // saturated, so the counters stay disjoint and
                    // spills + reroutes never exceeds off-home requests
                    if spilled || rerouted {
                        let mut r = self.routing.lock().unwrap();
                        if rerouted {
                            r.reroutes += 1;
                            self.telem.reroutes.inc();
                        } else if spilled {
                            r.spills += 1;
                            self.telem.spills.inc();
                        }
                    }
                    w.shared.routed.fetch_add(1, Ordering::AcqRel);
                    w.shared.in_flight.fetch_add(1, Ordering::AcqRel);
                    return Ok(Pending {
                        rx,
                        shared: w.shared.clone(),
                        worker: idx,
                        adapter: adapter.to_string(),
                        parked: false,
                        settled: false,
                    });
                }
                Err(SubmitError::Rejected(e)) => return Err(e),
                Err(SubmitError::WorkerGone(t)) => {
                    // found dead at submit (raced its death): mark it
                    // so route() skips it, and try the next worker
                    w.shared
                        .mark_dead("worker exited before accepting a request".to_string());
                    tokens = t;
                    attempts += 1;
                    self.count_retry(idx, attempts, retry_budget)?;
                }
            }
        }
    }

    /// Coarse `Overloaded` retry-after estimate: how many batch drains
    /// (each occupying a worker ≈ one `max_wait` window plus the
    /// forward) the current parked depth represents.
    fn retry_hint(&self, parked_depth: usize) -> Duration {
        let batch = self.workers[0].server.max_batch().max(1);
        let drains = (parked_depth / batch + 1).min(1 << 16) as u32;
        self.max_wait.max(Duration::from_millis(1)) * drains
    }

    /// Count one dead-worker reroute retry (with linear backoff) and
    /// fail the submit with a typed `WorkerDead` once the budget is
    /// spent; `Ok(())` means "retry".
    fn count_retry(
        &self,
        worker: usize,
        attempts: usize,
        budget: usize,
    ) -> Result<(), ServeError> {
        self.routing.lock().unwrap().retries += 1;
        self.telem.retries.inc();
        if attempts > budget {
            return Err(ServeError::WorkerDead {
                worker: Some(worker),
                reason: format!(
                    "submit retry budget exhausted after {attempts} dead-worker reroutes"
                ),
            });
        }
        std::thread::sleep(SUBMIT_RETRY_BACKOFF * attempts.min(64) as u32);
        Ok(())
    }

    /// Submit and wait (the blocking path `BatchServer::query` users
    /// expect).
    pub fn query(&self, adapter: &str, tokens: Vec<i32>) -> Result<Reply, ServeError> {
        self.submit_async(adapter, tokens)?.wait()
    }

    /// Aggregate metrics snapshot (module docs).
    pub fn stats(&self) -> PoolStats {
        let (spills, reroutes, retries, shed_overload, mut shed_deadline) = {
            let r = self.routing.lock().unwrap();
            (r.spills, r.reroutes, r.retries, r.shed_overload, r.shed_deadline)
        };
        let (steals, parked, parked_peak, bus_shed) = self
            .bus
            .as_ref()
            .map(|b| {
                (
                    b.steals.load(Ordering::Acquire),
                    b.parked.load(Ordering::Acquire),
                    b.parked_peak.load(Ordering::Acquire),
                    b.shed_deadline.load(Ordering::Acquire),
                )
            })
            .unwrap_or((0, 0, 0, 0));
        shed_deadline += bus_shed;
        let mut out = PoolStats {
            spills,
            reroutes,
            steals,
            parked,
            retries,
            shed_overload,
            parked_peak,
            ..PoolStats::default()
        };
        for w in &self.workers {
            let server = w.server.stats();
            out.requests += server.requests;
            out.batches += server.batches;
            out.fused_batches += server.fused_batches;
            out.upload_hits += server.upload.hits;
            out.upload_misses += server.upload.misses;
            out.rejected += server.rejected;
            out.shed_midstream += server.shed_midstream;
            out.steps += server.steps;
            out.stream_requests += server.stream_requests;
            shed_deadline += server.shed_deadline;
            for (name, a) in &server.per_adapter {
                let e = out.per_adapter.entry(name.clone()).or_default();
                e.requests += a.requests;
                e.batches += a.batches;
                e.occupancy_sum += a.occupancy_sum;
            }
            out.workers.push(PoolWorkerStats {
                routed: w.shared.routed.load(Ordering::Acquire),
                in_flight: w.shared.in_flight.load(Ordering::Acquire),
                dead: w.shared.dead.lock().unwrap().clone(),
                server,
            });
        }
        out.shed_deadline = shed_deadline;
        out
    }

    /// Graceful shutdown: every worker drains its queue (and, via its
    /// feeder, the parked overflow — including queues stranded by dead
    /// siblings) first, so all outstanding [`Pending`] handles resolve
    /// (with a reply, or with the dead-worker error for requests that
    /// died with their worker).
    pub fn shutdown(self) {
        for w in self.workers {
            w.server.shutdown();
        }
        // anything still parked here could only belong to a pool whose
        // workers ALL died before draining; dropping the bus drops the
        // reply senders, resolving those handles with the death error
        drop(self.bus);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::ReferenceBackend;
    use crate::model::weights::NamedTensors;
    use crate::util::{Rng, Tensor};

    fn base(seed: u64) -> NamedTensors {
        let mut rng = Rng::new(seed);
        let mut nt = NamedTensors::new();
        nt.push("embed", Tensor::new(&[8, 16], rng.normal_vec(128, 0.0, 0.05)));
        nt
    }

    fn adapter(seed: u64) -> NamedTensors {
        let mut rng = Rng::new(seed);
        let (h, r, o) = (16usize, 4usize, 8usize);
        let mut nt = NamedTensors::new();
        nt.push("l0.wq.lora_a", Tensor::new(&[h, r], rng.normal_vec(h * r, 0.0, 0.3)));
        nt.push("l0.wq.lora_b", Tensor::new(&[r, o], rng.normal_vec(r * o, 0.0, 0.3)));
        nt.push("betas", Tensor::new(&[1, 7, 2], rng.normal_vec(14, 0.0, 0.5)));
        nt
    }

    fn reference_pool(workers: usize, registry: Arc<AdapterRegistry>) -> ServerPool {
        let reg = registry.clone();
        ServerPool::spawn_with(
            PoolConfig::new(workers, Duration::from_millis(1)),
            registry,
            move |_w| {
                Ok(Box::new(ReferenceBackend::new(4, 8, 12, reg.base()))
                    as Box<dyn ServeBackend>)
            },
        )
        .unwrap()
    }

    #[test]
    fn workers_env_override_parsing() {
        assert_eq!(parse_workers_override("4"), Some(4));
        assert_eq!(parse_workers_override(" 2 "), Some(2));
        assert_eq!(parse_workers_override("9999"), Some(64)); // capped
        assert_eq!(parse_workers_override("0"), None);
        assert_eq!(parse_workers_override("nope"), None);
        assert_eq!(parse_workers_override(""), None);
        assert!(serve_workers() >= 1);
    }

    #[test]
    fn steal_env_override_parsing() {
        assert!(!parse_steal_override("0"));
        assert!(!parse_steal_override(" false "));
        assert!(!parse_steal_override("OFF"));
        assert!(!parse_steal_override("no"));
        assert!(parse_steal_override("1"));
        assert!(parse_steal_override("true"));
        assert!(parse_steal_override("")); // anything-but-off means on
    }

    #[test]
    fn pool_config_builders() {
        let c = PoolConfig::new(2, Duration::from_millis(1));
        assert!(c.fused && c.steal);
        assert!(!c.serial().fused);
        let c = PoolConfig::new(2, Duration::from_millis(1)).no_steal();
        assert!(!c.steal && c.fused);
    }

    #[test]
    fn home_worker_deterministic_in_range() {
        for n in 1..=8 {
            for name in ["a", "tenant0", "tenant1", "a-long-adapter-id"] {
                let h = home_worker(name, n);
                assert!(h < n);
                assert_eq!(h, home_worker(name, n), "{name} n={n}");
            }
        }
        // single worker: everything homes to 0
        assert_eq!(home_worker("anything", 1), 0);
        // distinct ids do spread (not all on one worker)
        let homes: std::collections::BTreeSet<usize> =
            (0..32).map(|i| home_worker(&format!("t{i}"), 4)).collect();
        assert!(homes.len() > 1, "hash collapsed: {homes:?}");
    }

    #[test]
    fn pool_serves_and_aggregates_stats() {
        let registry = Arc::new(AdapterRegistry::with_capacity(base(1), (1.0, 1.0), 4));
        for i in 0..3 {
            registry.register(&format!("t{i}"), adapter(10 + i)).unwrap();
        }
        let pool = reference_pool(2, registry.clone());
        assert_eq!(pool.workers(), 2);
        assert_eq!(pool.max_prompt_len(), 8);
        assert_eq!(pool.vocab(), 12);

        // blocking queries across adapters
        let mut replies = Vec::new();
        for i in 0..9 {
            let a = format!("t{}", i % 3);
            replies.push(pool.query(&a, vec![1 + (i % 5) as i32, 2]).unwrap());
        }
        // async handles resolve too, bit-identical to the blocking path
        let h = pool.submit_async("t0", vec![1, 2]).unwrap();
        assert!(h.worker() < 2);
        assert_eq!(h.adapter(), "t0");
        let r = h.wait().unwrap();
        assert_eq!(r.logits, replies[0].logits);

        let s = pool.stats();
        assert_eq!(s.requests, 10);
        assert_eq!(s.alive(), 2);
        assert_eq!(s.queue_depth(), 0);
        assert_eq!(s.parked, 0);
        assert_eq!(s.per_adapter.len(), 3);
        assert_eq!(s.per_adapter["t0"].requests, 4);
        assert_eq!(s.workers.len(), 2);
        assert_eq!(s.workers.iter().map(|w| w.routed).sum::<usize>(), 10);
        // affinity: with no contention, each adapter's requests all
        // landed on its home worker — parking/stealing never fired
        assert_eq!(s.spills, 0);
        assert_eq!(s.reroutes, 0);
        assert_eq!(s.steals, 0);
        // nothing was shed or retried on this uncontended run
        assert_eq!(s.shed_overload, 0);
        assert_eq!(s.shed_deadline, 0);
        assert_eq!(s.retries, 0);
        assert_eq!(s.parked_peak, 0);
        for i in 0..3 {
            let name = format!("t{i}");
            let home = home_worker(&name, 2);
            assert_eq!(
                s.workers[home].server.per_adapter[&name].requests,
                s.per_adapter[&name].requests,
                "adapter {name} strayed off worker {home}: {s:?}"
            );
        }
        assert!(s.mean_batch_size() >= 1.0);
        // the fused drain path served these (one forward per drain)
        assert!(s.fused_batches >= 1, "{s:?}");
        assert_eq!(s.fused_batches, s.batches, "{s:?}");
        // each worker fingerprint-cached its adapters after one miss
        assert!(s.upload_misses >= 1, "{s:?}");
        pool.shutdown();
    }

    #[test]
    fn pool_rejects_bad_requests_at_submit() {
        let registry = Arc::new(AdapterRegistry::with_capacity(base(2), (0.0, 0.0), 4));
        registry.register("good", adapter(20)).unwrap();
        let pool = reference_pool(2, registry);
        assert!(pool.submit_async("good", vec![]).is_err());
        assert!(pool.submit_async("good", vec![1; 9]).is_err()); // seq = 8
        let err = pool.submit_async("ghost", vec![1, 2]).unwrap_err();
        assert!(format!("{err:#}").contains("unknown adapter"), "{err:#}");
        assert_eq!(pool.stats().rejected, 3);
        assert_eq!(pool.stats().requests, 0);
        pool.shutdown();
    }

    #[test]
    fn zero_workers_falls_back_to_env_default() {
        let registry = Arc::new(AdapterRegistry::with_capacity(base(3), (0.0, 0.0), 2));
        registry.register("a", adapter(30)).unwrap();
        let pool = reference_pool(0, registry);
        assert!(pool.workers() >= 1);
        assert!(pool.query("a", vec![3, 1]).is_ok());
        pool.shutdown();
    }

    #[test]
    fn worker_init_failure_fails_spawn() {
        let registry = Arc::new(AdapterRegistry::with_capacity(base(4), (0.0, 0.0), 2));
        let err = ServerPool::spawn_with(
            PoolConfig::new(3, Duration::from_millis(1)),
            registry,
            |w| {
                if w == 2 {
                    anyhow::bail!("no device {w}")
                }
                Ok(Box::new(ReferenceBackend::new(2, 4, 4, &NamedTensors::new()))
                    as Box<dyn ServeBackend>)
            },
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("pool worker 2") && msg.contains("no device"), "{msg}");
    }

    #[test]
    fn single_worker_pool_disables_stealing() {
        let registry = Arc::new(AdapterRegistry::with_capacity(base(5), (0.0, 0.0), 2));
        registry.register("a", adapter(50)).unwrap();
        let pool = reference_pool(1, registry);
        assert!(!pool.stealing(), "nothing to steal from on a 1-worker pool");
        assert!(pool.query("a", vec![1, 2]).is_ok());
        pool.shutdown();
    }

    #[test]
    fn park_knob_parsing() {
        assert_eq!(parse_park_bound_override("8"), Some(8));
        assert_eq!(parse_park_bound_override(" 16 "), Some(16));
        assert_eq!(parse_park_bound_override("0"), None);
        assert_eq!(parse_park_bound_override("junk"), None);
        assert_eq!(parse_park_bound_override("99999999"), Some(1 << 20)); // capped
        assert!(park_bound() >= 1);

        assert_eq!(parse_park_age_override("0"), Some(Duration::ZERO));
        assert_eq!(parse_park_age_override(" 25 "), Some(Duration::from_millis(25)));
        assert_eq!(parse_park_age_override("-3"), None);
        assert_eq!(parse_park_age_override("junk"), None);
        assert_eq!(
            parse_park_age_override("9999999999"),
            Some(Duration::from_millis(600_000)) // capped
        );
    }

    /// Build a `Request` as the park path would, optionally back-dating
    /// its enqueue time (aging) and attaching a deadline; the receiver
    /// is returned so sheds can be observed.
    fn parked_request(
        adapter: &str,
        aged_by: Duration,
        deadline: Option<Instant>,
    ) -> (Request, Receiver<Result<Reply, ServeError>>) {
        let (tx, rx) = sync_channel(1);
        (
            Request {
                adapter: adapter.to_string(),
                tokens: vec![1, 2],
                steps: 1,
                enqueued: Instant::now() - aged_by,
                deadline,
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn bus_bound_is_exact_and_peak_tracked() {
        let bus = StealBus::new(2, 2, Duration::from_millis(20));
        let (r1, _k1) = parked_request("a", Duration::ZERO, None);
        let (r2, _k2) = parked_request("a", Duration::ZERO, None);
        let (r3, _k3) = parked_request("a", Duration::ZERO, None);
        assert!(bus.try_park(0, r1).is_ok());
        assert!(bus.try_park(1, r2).is_ok());
        // the bound is POOL-WIDE: queue 0 holds one, queue 1 holds one,
        // and a third park anywhere refuses
        assert!(bus.try_park(0, r3).is_err(), "third park must refuse at bound 2");
        assert_eq!(bus.parked.load(Ordering::Acquire), 2);
        assert_eq!(bus.parked_peak.load(Ordering::Acquire), 2);
        // popping frees capacity again; the peak is a high-water mark
        assert_eq!(bus.pop_own(0, 8).len(), 1);
        let (r4, _k4) = parked_request("a", Duration::ZERO, None);
        assert!(bus.try_park(0, r4).is_ok());
        assert_eq!(bus.parked_peak.load(Ordering::Acquire), 2);
    }

    #[test]
    fn bus_aged_pop_promotes_only_the_aged_prefix() {
        let bus = StealBus::new(1, 16, Duration::from_secs(2));
        let (old, _k1) = parked_request("a", Duration::from_secs(5), None);
        let (fresh, _k2) = parked_request("a", Duration::ZERO, None);
        assert!(bus.try_park(0, old).is_ok());
        assert!(bus.try_park(0, fresh).is_ok());
        // only the aged front comes back; the fresh request stays
        let got = bus.pop_own_aged(0, 8);
        assert_eq!(got.len(), 1, "exactly the aged prefix is promoted");
        assert_eq!(bus.parked.load(Ordering::Acquire), 1);
        assert!(bus.pop_own_aged(0, 8).is_empty(), "fresh request must not be promoted");
        assert_eq!(bus.pop_own(0, 8).len(), 1, "the Any pass still drains it");
    }

    #[test]
    fn bus_pops_shed_expired_requests() {
        let bus = StealBus::new(2, 16, Duration::ZERO);
        let (dead, dead_rx) = parked_request(
            "a",
            Duration::from_millis(10),
            Some(Instant::now() - Duration::from_millis(5)),
        );
        let (live, _live_rx) =
            parked_request("a", Duration::ZERO, Some(Instant::now() + Duration::from_secs(30)));
        assert!(bus.try_park(0, dead).is_ok());
        assert!(bus.try_park(0, live).is_ok());
        let got = bus.pop_own(0, 8);
        assert_eq!(got.len(), 1, "the expired request must be shed, not returned");
        assert_eq!(bus.shed_deadline.load(Ordering::Acquire), 1);
        assert_eq!(bus.parked.load(Ordering::Acquire), 0);
        match dead_rx.recv().unwrap() {
            Err(ServeError::DeadlineExceeded { waited }) => {
                assert!(waited >= Duration::from_millis(5), "{waited:?}");
            }
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        // the steal path sheds too — and a shed is not a steal (it was
        // never served)
        let (dead2, dead2_rx) = parked_request(
            "b",
            Duration::from_millis(10),
            Some(Instant::now() - Duration::from_millis(1)),
        );
        assert!(bus.try_park(0, dead2).is_ok());
        assert!(bus.steal_from_busiest(1, 8).is_empty());
        assert_eq!(bus.steals.load(Ordering::Acquire), 0);
        assert!(matches!(
            dead2_rx.recv().unwrap(),
            Err(ServeError::DeadlineExceeded { .. })
        ));
    }

    #[test]
    fn full_overflow_refuses_with_overloaded() {
        let registry = Arc::new(AdapterRegistry::with_capacity(base(7), (1.0, 1.0), 4));
        // one adapter homed on each worker, so both drain loops can be
        // pinned inside their batch fill windows below
        let hot = (0..64)
            .map(|i| format!("h{i}"))
            .find(|n| home_worker(n, 2) == 0)
            .unwrap();
        let other = (0..64)
            .map(|i| format!("o{i}"))
            .find(|n| home_worker(n, 2) == 1)
            .unwrap();
        registry.register(&hot, adapter(70)).unwrap();
        registry.register(&other, adapter(71)).unwrap();
        let mut cfg = PoolConfig::new(2, Duration::from_millis(100));
        cfg.spill_depth = Some(1);
        cfg.park_bound = Some(1);
        let reg = registry.clone();
        let pool = ServerPool::spawn_with(cfg, registry, move |_w| {
            Ok(Box::new(ReferenceBackend::new(4, 8, 12, reg.base()))
                as Box<dyn ServeBackend>)
        })
        .unwrap();
        if !pool.stealing() {
            return; // IRQLORA_SERVE_STEAL=0 run: no overflow to bound
        }
        // worker 1 enters its 100ms fill window (so it cannot steal
        // the parked request while the burst below lands)...
        let busy_other = pool.submit_async(&other, vec![1, 2]).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        // ...then a burst at worker 0: one direct (depth 1 = spill
        // threshold), one parked (overflow 1/1), and the third REFUSED
        let h1 = pool.submit_async(&hot, vec![1, 2]).unwrap();
        let h2 = pool.submit_async(&hot, vec![1, 3]).unwrap();
        let err = pool.submit_async(&hot, vec![1, 4]).unwrap_err();
        match &err {
            ServeError::Overloaded { depth, retry_after_hint } => {
                assert!(*depth >= 1, "{err:?}");
                assert!(*retry_after_hint > Duration::ZERO, "{err:?}");
            }
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert!(err.retryable(), "Overloaded must invite a later retry");
        // shedding, not collapse: everything ADMITTED is still served
        busy_other.wait().unwrap();
        h1.wait().unwrap();
        h2.wait().unwrap();
        let s = pool.stats();
        assert_eq!(s.shed_overload, 1, "{s:?}");
        assert_eq!(s.parked_peak, 1, "{s:?}");
        assert_eq!(s.parked, 0, "{s:?}");
        pool.shutdown();
    }

    #[test]
    fn expired_deadline_shed_at_submit() {
        let registry = Arc::new(AdapterRegistry::with_capacity(base(8), (1.0, 1.0), 4));
        registry.register("a", adapter(80)).unwrap();
        let pool = reference_pool(2, registry);
        let err = pool
            .submit_with_deadline(
                "a",
                vec![1, 2],
                Some(Instant::now() - Duration::from_millis(1)),
            )
            .unwrap_err();
        assert!(matches!(err, ServeError::DeadlineExceeded { .. }), "{err:?}");
        assert!(!err.retryable(), "the request's time budget is gone");
        // a live deadline serves normally
        let r = pool
            .submit_with_deadline("a", vec![1, 2], Some(Instant::now() + Duration::from_secs(30)))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.adapter, "a");
        let s = pool.stats();
        assert_eq!(s.shed_deadline, 1, "{s:?}");
        assert_eq!(s.requests, 1, "{s:?}");
        pool.shutdown();
    }

    #[test]
    fn stream_steps_match_one_shot_oracle() {
        let registry = Arc::new(AdapterRegistry::with_capacity(base(11), (1.0, 1.0), 4));
        registry.register("a", adapter(110)).unwrap();
        let pool = reference_pool(2, registry);
        let steps = 3usize;
        let h = pool.submit_stream("a", vec![1, 2], steps).unwrap();
        let mut prefix = vec![1i32, 2];
        let mut got_steps = 0usize;
        for (i, got) in h.enumerate() {
            let r = got.unwrap();
            assert_eq!(r.step, i + 1);
            assert_eq!(r.last, i + 1 == steps);
            // each streamed step must equal the one-shot reply for the
            // stream's prefix at that step (the replay oracle)
            let oracle = pool.query("a", prefix.clone()).unwrap();
            assert_eq!(r.logits, oracle.logits, "step {} diverged", i + 1);
            prefix.push(super::super::server::greedy_next_token(&r.logits));
            got_steps += 1;
        }
        assert_eq!(got_steps, steps, "iterator must end after the last step");

        let s = pool.stats();
        assert_eq!(s.stream_requests, 1, "{s:?}");
        // the stream delivered `steps` results; each oracle query one
        assert_eq!(s.steps, 2 * steps, "{s:?}");
        assert_eq!(s.requests, steps + 1, "{s:?}");

        // stream validation: no room for the extensions (seq = 8),
        // zero steps, absurd step counts — all Rejected at submit
        assert!(pool.submit_stream("a", vec![1; 8], 2).is_err());
        assert!(pool.submit_stream("a", vec![1], 0).is_err());
        assert!(pool.submit_stream("a", vec![1], 1 << 20).is_err());
        pool.shutdown();
    }

    #[test]
    fn wait_timeout_and_deadline_bound_blocking() {
        let registry = Arc::new(AdapterRegistry::with_capacity(base(9), (1.0, 1.0), 4));
        registry.register("a", adapter(90)).unwrap();
        let reg = registry.clone();
        let pool = ServerPool::spawn_with(
            PoolConfig::new(1, Duration::from_millis(1)),
            registry,
            move |_w| {
                Ok(Box::new(
                    ReferenceBackend::new(4, 8, 12, reg.base())
                        .with_forward_delay(Duration::from_millis(40)),
                ) as Box<dyn ServeBackend>)
            },
        )
        .unwrap();
        let mut h = pool.submit_async("a", vec![1, 2]).unwrap();
        assert!(
            h.wait_timeout(Duration::from_millis(1)).is_none(),
            "a 40ms forward cannot answer within 1ms"
        );
        let r = h.wait_timeout(Duration::from_secs(30)).expect("must arrive").unwrap();
        assert_eq!(r.adapter, "a");
        // consumed: further bounded waits report the consumed error —
        // never a hang, never a phantom worker death
        match h.wait_timeout(Duration::from_millis(1)) {
            Some(Err(ServeError::Rejected(msg))) => {
                assert!(msg.contains("already consumed"), "{msg}");
            }
            other => panic!("expected consumed error, got {other:?}"),
        }
        drop(h);
        let mut h2 = pool.submit_async("a", vec![1, 3]).unwrap();
        assert!(
            h2.wait_deadline(Instant::now()).is_none(),
            "a past deadline degenerates to a non-blocking poll"
        );
        let r2 = h2
            .wait_deadline(Instant::now() + Duration::from_secs(30))
            .expect("must arrive")
            .unwrap();
        assert_eq!(r2.adapter, "a");
        pool.shutdown();
    }
}
