//! Sharded serving pool: N batch-serving workers over ONE shared
//! [`AdapterRegistry`] (the serving-path scale-out layer above
//! [`super::server::BatchServer`]).
//!
//! `BatchServer` gives one worker thread per server, so the
//! shared-base + LRU-merge architecture saturates at one core. The
//! pool spawns N workers (default [`serve_workers`], the
//! `IRQLORA_SERVE_WORKERS` knob mirroring `IRQLORA_THREADS`) that all
//! route through one registry — merged adapter weights are computed
//! once and shared, while each worker owns its execution backend (for
//! PJRT: its own runtime + device buffers, built on the worker thread
//! by the factory passed to [`ServerPool::spawn_with`]).
//!
//! Routing is adapter-affine: [`home_worker`] consistent-hashes the
//! adapter id onto a worker so consecutive requests for one tenant hit
//! the same backend (keeping its device-side adapter upload and the
//! registry's LRU entry warm). Two situations move a request off its
//! home worker, both counted in [`PoolStats`]:
//!
//! - **spill** — the home worker's queue depth reached the spill
//!   threshold (default `2 × backend batch`); the request goes to the
//!   least-loaded alive worker instead, trading cache affinity for
//!   latency on hot adapters;
//! - **reroute** — the home worker is dead (its backend panicked or
//!   its thread exited); the request probes forward around the ring
//!   to the next alive worker. Dead workers stay dead (their reason
//!   string is kept in [`PoolStats`]) and the rest of the pool keeps
//!   serving: requests already queued on the dying worker fail with
//!   the worker-died error (their handles resolve, nothing hangs),
//!   while all *subsequent* traffic for its adapters reroutes — one
//!   poisoned tenant cannot take down its neighbours' ongoing
//!   service.
//!
//! Submission is asynchronous: [`ServerPool::submit_async`] returns a
//! [`Pending`] handle without waiting for the reply (validation
//! failures — malformed prompt, unknown adapter — still fail fast at
//! submit time, exactly like `BatchServer::submit`; a completely
//! saturated pool applies backpressure — see the method docs).
//! `Pending::wait` blocks for the reply;
//! `Pending::try_wait` polls. The blocking [`ServerPool::query`] is
//! submit + wait. [`ServerPool::shutdown`] drains every worker:
//! already-submitted `Pending` handles all resolve before the workers
//! exit (same drain semantics as `BatchServer::shutdown`, per worker).
//!
//! Replies are bit-identical to a single `BatchServer` serving the
//! same (adapter, prompt) stream: workers share the dequantized base
//! through the registry, merges are deterministic, and each forward
//! batches only same-adapter rows — which worker ran the forward can
//! never leak into the logits (the pool concurrency battery in
//! `rust/tests/pool_concurrency.rs` asserts this under contention).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvError, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::runtime::Manifest;
use crate::util::hash::{fnv1a, FNV1A_SEED};

use super::backend::{PjrtBackend, ServeBackend};
use super::registry::AdapterRegistry;
use super::server::{
    AdapterServeStats, BatchServer, Reply, ServerConfig, ServerStats, SubmitError,
};

/// Worker count when `IRQLORA_SERVE_WORKERS` is unset.
pub const DEFAULT_SERVE_WORKERS: usize = 2;

/// Resolve the pool worker count: the `IRQLORA_SERVE_WORKERS`
/// override, else [`DEFAULT_SERVE_WORKERS`].
pub fn serve_workers() -> usize {
    std::env::var("IRQLORA_SERVE_WORKERS")
        .ok()
        .and_then(|v| parse_workers_override(&v))
        .unwrap_or(DEFAULT_SERVE_WORKERS)
}

/// Interpret an `IRQLORA_SERVE_WORKERS` value: positive integers are
/// honored (capped at 64); zero and garbage are ignored. Pure so it is
/// testable without process-global env mutation (mirrors
/// `util::threads::parse_thread_override`).
fn parse_workers_override(v: &str) -> Option<usize> {
    match v.trim().parse::<usize>() {
        Ok(n) if n >= 1 => Some(n.min(64)),
        _ => None,
    }
}

/// Consistent adapter→worker assignment: FNV-1a over the adapter id
/// (`util::hash`, the same hash checkpoint checksums use), reduced mod
/// `n_workers`. Deterministic across processes and runs (no
/// per-process hash seed), so a tenant's home worker is stable for a
/// fixed pool size — the property the merged-weight and device buffer
/// caches rely on.
pub fn home_worker(adapter: &str, n_workers: usize) -> usize {
    assert!(n_workers > 0, "home_worker needs at least one worker");
    (fnv1a(FNV1A_SEED, adapter.as_bytes()) % n_workers as u64) as usize
}

/// Pool configuration.
pub struct PoolConfig {
    /// Worker count; `0` means [`serve_workers`] (the
    /// `IRQLORA_SERVE_WORKERS` env default). Clamped to 1..=64 at
    /// spawn (the same cap the env override has), so a typo'd
    /// `--workers 1000000` can't spawn unbounded threads/runtimes.
    pub workers: usize,
    /// Per-worker batcher window (see [`ServerConfig::max_wait`]).
    pub max_wait: Duration,
    /// Queue depth at which a request spills off its home worker to
    /// the least-loaded one; `None` means `2 × backend batch`.
    pub spill_depth: Option<usize>,
}

impl PoolConfig {
    pub fn new(workers: usize, max_wait: Duration) -> PoolConfig {
        PoolConfig { workers, max_wait, spill_depth: None }
    }
}

/// State shared between the pool, its routing decisions, and the
/// [`Pending`] handles in flight against one worker.
#[derive(Default)]
struct WorkerShared {
    /// Requests routed here whose [`Pending`] handle has not settled
    /// yet (waited, polled to completion, or dropped). This is the
    /// queue-depth signal spill decisions use; note a reply that has
    /// been *delivered* but not yet harvested by its handle still
    /// counts, so a large un-harvested `submit_async` burst reads as
    /// depth — which is the intended hot-adapter spill trigger.
    in_flight: AtomicUsize,
    /// Total requests ever routed here.
    routed: AtomicUsize,
    /// `Some(reason)` once the worker is known dead. Sticky: a dead
    /// worker is never routed to again.
    dead: Mutex<Option<String>>,
}

impl WorkerShared {
    fn is_alive(&self) -> bool {
        self.dead.lock().unwrap().is_none()
    }

    /// First reason wins; later observers of the same death are no-ops.
    fn mark_dead(&self, reason: String) {
        let mut d = self.dead.lock().unwrap();
        if d.is_none() {
            *d = Some(reason);
        }
    }
}

struct PoolWorker {
    server: BatchServer,
    shared: Arc<WorkerShared>,
}

#[derive(Default)]
struct RoutingCounters {
    spills: usize,
    reroutes: usize,
}

/// One worker's slice of [`PoolStats`].
#[derive(Clone, Debug)]
pub struct PoolWorkerStats {
    /// Requests routed to this worker over the pool's lifetime.
    pub routed: usize,
    /// Requests currently queued/executing here (snapshot).
    pub in_flight: usize,
    /// Why this worker died, if it did.
    pub dead: Option<String>,
    /// The worker's own serving counters.
    pub server: ServerStats,
}

/// Aggregate pool metrics: per-worker occupancy + liveness, routing
/// counters, and the per-adapter breakdown summed across workers.
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    pub workers: Vec<PoolWorkerStats>,
    /// Requests sent off their home worker because it was saturated.
    pub spills: usize,
    /// Requests sent off their home worker because it was dead.
    pub reroutes: usize,
    /// Served requests, summed across workers.
    pub requests: usize,
    /// Forward calls, summed across workers.
    pub batches: usize,
    /// Submit-time rejections, summed across workers.
    pub rejected: usize,
    /// Per-adapter occupancy, summed across workers.
    pub per_adapter: BTreeMap<String, AdapterServeStats>,
}

impl PoolStats {
    /// Workers still accepting traffic.
    pub fn alive(&self) -> usize {
        self.workers.iter().filter(|w| w.dead.is_none()).count()
    }

    /// Requests submitted but not yet resolved, across all workers.
    pub fn queue_depth(&self) -> usize {
        self.workers.iter().map(|w| w.in_flight).sum()
    }

    /// Mean same-adapter group size across every worker's forwards.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.workers
                .iter()
                .map(|w| w.server.batch_occupancy_sum)
                .sum::<usize>() as f64
                / self.batches as f64
        }
    }
}

/// A reply that has been submitted but not yet received. Dropping the
/// handle abandons the reply (the worker still serves the request);
/// the pool's in-flight accounting settles either way.
pub struct Pending {
    rx: Receiver<Result<Reply, String>>,
    shared: Arc<WorkerShared>,
    worker: usize,
    adapter: String,
    settled: bool,
}

impl Pending {
    /// Worker index this request was routed to.
    pub fn worker(&self) -> usize {
        self.worker
    }

    /// Adapter the request targets.
    pub fn adapter(&self) -> &str {
        &self.adapter
    }

    fn settle(&mut self) {
        if !self.settled {
            self.settled = true;
            self.shared.in_flight.fetch_sub(1, Ordering::AcqRel);
        }
    }

    fn resolve(&mut self, got: Result<Result<Reply, String>, RecvError>) -> Result<Reply> {
        self.settle();
        match got {
            Ok(Ok(r)) => Ok(r),
            Ok(Err(e)) => Err(anyhow!("request failed: {e}")),
            Err(_) => {
                // the worker dropped our reply sender without
                // answering: its thread died (panicking backend) —
                // record the death so routing stops using it. The
                // adapter named here is the first to OBSERVE the
                // death, not necessarily the one whose forward killed
                // the worker (other queued requests die with it).
                let reason = format!(
                    "worker died (first observed by a request for adapter '{}')",
                    self.adapter
                );
                self.shared.mark_dead(reason);
                Err(anyhow!(
                    "pool worker {} died while serving adapter '{}'",
                    self.worker,
                    self.adapter
                ))
            }
        }
    }

    /// Block until the reply arrives (or the worker dies). Like
    /// [`Self::try_wait`], a reply already consumed by an earlier poll
    /// reports an error — it must not be misread as a worker death.
    pub fn wait(mut self) -> Result<Reply> {
        if self.settled {
            return Err(anyhow!(
                "reply for adapter '{}' already consumed",
                self.adapter
            ));
        }
        let got = self.rx.recv();
        self.resolve(got)
    }

    /// Poll for the reply: `None` while still in flight. After it has
    /// returned `Some`, the reply is consumed — further polls report
    /// an error rather than misreading the closed channel as a death.
    pub fn try_wait(&mut self) -> Option<Result<Reply>> {
        if self.settled {
            return Some(Err(anyhow!(
                "reply for adapter '{}' already consumed",
                self.adapter
            )));
        }
        match self.rx.try_recv() {
            Ok(r) => Some(self.resolve(Ok(r))),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => Some(self.resolve(Err(RecvError))),
        }
    }
}

impl Drop for Pending {
    fn drop(&mut self) {
        self.settle();
    }
}

/// N [`BatchServer`] workers over one shared [`AdapterRegistry`], with
/// adapter-affinity routing and async submission (module docs).
pub struct ServerPool {
    workers: Vec<PoolWorker>,
    registry: Arc<AdapterRegistry>,
    routing: Mutex<RoutingCounters>,
    spill_depth: usize,
    seq: usize,
    vocab: usize,
}

impl ServerPool {
    /// Spawn a pool of PJRT-backed workers over the manifest's
    /// `forward` graph for `tag`. Each worker owns its runtime and
    /// uploads the shared base once; the registry (and its merged
    /// cache) is shared across all of them.
    pub fn spawn(
        manifest: Manifest,
        tag: &str,
        cfg: PoolConfig,
        registry: Arc<AdapterRegistry>,
    ) -> Result<ServerPool> {
        let tag = tag.to_string();
        let reg = registry.clone();
        Self::spawn_with(cfg, registry, move |_worker| {
            Ok(Box::new(PjrtBackend::new(&manifest, &tag, reg.base())?)
                as Box<dyn ServeBackend>)
        })
    }

    /// Spawn over an explicit backend factory, called once per worker
    /// (with the worker index) on that worker's thread — backends may
    /// own thread-bound resources. Tests and the offline bench smoke
    /// pass [`super::backend::ReferenceBackend`] factories here.
    pub fn spawn_with<F>(
        cfg: PoolConfig,
        registry: Arc<AdapterRegistry>,
        make_backend: F,
    ) -> Result<ServerPool>
    where
        F: Fn(usize) -> Result<Box<dyn ServeBackend>> + Send + Sync + 'static,
    {
        let n = (if cfg.workers == 0 { serve_workers() } else { cfg.workers }).clamp(1, 64);
        let factory = Arc::new(make_backend);
        let mut workers = Vec::with_capacity(n);
        for w in 0..n {
            let f = factory.clone();
            let server = BatchServer::spawn_with(
                ServerConfig { max_wait: cfg.max_wait },
                registry.clone(),
                move || f(w),
            )
            .with_context(|| format!("spawning pool worker {w} of {n}"))?;
            workers.push(PoolWorker { server, shared: Arc::new(WorkerShared::default()) });
        }
        let spill_depth = cfg
            .spill_depth
            .unwrap_or_else(|| 2 * workers[0].server.max_batch())
            .max(1);
        let seq = workers[0].server.max_prompt_len();
        let vocab = workers[0].server.vocab();
        // routing assumes interchangeable workers: a factory returning
        // per-worker shapes would make accept/reject depend on where a
        // request happened to land
        for (i, w) in workers.iter().enumerate() {
            anyhow::ensure!(
                w.server.max_batch() == workers[0].server.max_batch()
                    && w.server.max_prompt_len() == seq
                    && w.server.vocab() == vocab,
                "pool worker {i} has a different backend shape than worker 0"
            );
        }
        Ok(ServerPool {
            workers,
            registry,
            routing: Mutex::new(RoutingCounters::default()),
            spill_depth,
            seq,
            vocab,
        })
    }

    /// Pool size (including dead workers).
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Largest prompt (in tokens) the pool accepts.
    pub fn max_prompt_len(&self) -> usize {
        self.seq
    }

    /// Logit width of every reply.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// The registry every worker routes through.
    pub fn registry(&self) -> &Arc<AdapterRegistry> {
        &self.registry
    }

    /// Pick a target worker for an adapter whose home index is `home`:
    /// the first alive worker probing forward from home, spilled to
    /// the least-loaded alive worker when saturated. `None` when every
    /// worker is dead. Returns (index, spilled, rerouted).
    fn route(&self, home: usize) -> Option<(usize, bool, bool)> {
        let n = self.workers.len();
        let mut primary = None;
        for off in 0..n {
            let i = (home + off) % n;
            if self.workers[i].shared.is_alive() {
                primary = Some((i, off != 0));
                break;
            }
        }
        let (pi, rerouted) = primary?;
        let depth = self.workers[pi].shared.in_flight.load(Ordering::Acquire);
        if depth >= self.spill_depth {
            let spill = self
                .workers
                .iter()
                .enumerate()
                .filter(|(i, w)| *i != pi && w.shared.is_alive())
                .min_by_key(|(_, w)| w.shared.in_flight.load(Ordering::Acquire));
            if let Some((si, sw)) = spill {
                if sw.shared.in_flight.load(Ordering::Acquire) < depth {
                    return Some((si, true, rerouted));
                }
            }
        }
        Some((pi, false, rerouted))
    }

    /// Submit without waiting for the reply: returns a [`Pending`]
    /// handle. Malformed prompts and unknown adapters fail here,
    /// before routing; a dead target worker is marked and the request
    /// reroutes transparently. Backpressure caveat: each worker's
    /// request queue is bounded (1024 slots), so once every alive
    /// worker is saturated past its spill depth AND the target queue
    /// is full, this call blocks until a slot frees — an open-loop
    /// submitter that never harvests its handles will eventually stall
    /// here instead of exhausting memory (turning a full queue into an
    /// error return is a ROADMAP next step).
    pub fn submit_async(&self, adapter: &str, tokens: Vec<i32>) -> Result<Pending> {
        let n = self.workers.len();
        let home = home_worker(adapter, n);
        let mut tokens = tokens;
        loop {
            let (idx, spilled, rerouted) = self.route(home).ok_or_else(|| {
                anyhow!("all {n} pool workers are dead (adapter '{adapter}')")
            })?;
            let w = &self.workers[idx];
            match w.server.try_submit(adapter, tokens) {
                Ok(rx) => {
                    // one off-home cause per request: a dead home is
                    // the root cause even if the replacement was also
                    // saturated, so the counters stay disjoint and
                    // spills + reroutes never exceeds off-home requests
                    if spilled || rerouted {
                        let mut r = self.routing.lock().unwrap();
                        if rerouted {
                            r.reroutes += 1;
                        } else if spilled {
                            r.spills += 1;
                        }
                    }
                    w.shared.routed.fetch_add(1, Ordering::AcqRel);
                    w.shared.in_flight.fetch_add(1, Ordering::AcqRel);
                    return Ok(Pending {
                        rx,
                        shared: w.shared.clone(),
                        worker: idx,
                        adapter: adapter.to_string(),
                        settled: false,
                    });
                }
                Err(SubmitError::Rejected(e)) => return Err(e),
                Err(SubmitError::WorkerGone(t)) => {
                    // found dead at submit (raced its death): mark it
                    // so route() skips it, and try the next worker
                    w.shared
                        .mark_dead("worker exited before accepting a request".to_string());
                    tokens = t;
                }
            }
        }
    }

    /// Submit and wait (the blocking path `BatchServer::query` users
    /// expect).
    pub fn query(&self, adapter: &str, tokens: Vec<i32>) -> Result<Reply> {
        self.submit_async(adapter, tokens)?.wait()
    }

    /// Aggregate metrics snapshot (module docs).
    pub fn stats(&self) -> PoolStats {
        let (spills, reroutes) = {
            let r = self.routing.lock().unwrap();
            (r.spills, r.reroutes)
        };
        let mut out = PoolStats { spills, reroutes, ..PoolStats::default() };
        for w in &self.workers {
            let server = w.server.stats();
            out.requests += server.requests;
            out.batches += server.batches;
            out.rejected += server.rejected;
            for (name, a) in &server.per_adapter {
                let e = out.per_adapter.entry(name.clone()).or_default();
                e.requests += a.requests;
                e.batches += a.batches;
                e.occupancy_sum += a.occupancy_sum;
            }
            out.workers.push(PoolWorkerStats {
                routed: w.shared.routed.load(Ordering::Acquire),
                in_flight: w.shared.in_flight.load(Ordering::Acquire),
                dead: w.shared.dead.lock().unwrap().clone(),
                server,
            });
        }
        out
    }

    /// Graceful shutdown: every worker drains its queue first, so all
    /// outstanding [`Pending`] handles resolve (with a reply, or with
    /// the dead-worker error for workers that already died).
    pub fn shutdown(self) {
        for w in self.workers {
            w.server.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::ReferenceBackend;
    use crate::model::weights::NamedTensors;
    use crate::util::{Rng, Tensor};

    fn base(seed: u64) -> NamedTensors {
        let mut rng = Rng::new(seed);
        let mut nt = NamedTensors::new();
        nt.push("embed", Tensor::new(&[8, 16], rng.normal_vec(128, 0.0, 0.05)));
        nt
    }

    fn adapter(seed: u64) -> NamedTensors {
        let mut rng = Rng::new(seed);
        let (h, r, o) = (16usize, 4usize, 8usize);
        let mut nt = NamedTensors::new();
        nt.push("l0.wq.lora_a", Tensor::new(&[h, r], rng.normal_vec(h * r, 0.0, 0.3)));
        nt.push("l0.wq.lora_b", Tensor::new(&[r, o], rng.normal_vec(r * o, 0.0, 0.3)));
        nt.push("betas", Tensor::new(&[1, 7, 2], rng.normal_vec(14, 0.0, 0.5)));
        nt
    }

    fn reference_pool(workers: usize, registry: Arc<AdapterRegistry>) -> ServerPool {
        let reg = registry.clone();
        ServerPool::spawn_with(
            PoolConfig::new(workers, Duration::from_millis(1)),
            registry,
            move |_w| {
                Ok(Box::new(ReferenceBackend::new(4, 8, 12, reg.base()))
                    as Box<dyn ServeBackend>)
            },
        )
        .unwrap()
    }

    #[test]
    fn workers_env_override_parsing() {
        assert_eq!(parse_workers_override("4"), Some(4));
        assert_eq!(parse_workers_override(" 2 "), Some(2));
        assert_eq!(parse_workers_override("9999"), Some(64)); // capped
        assert_eq!(parse_workers_override("0"), None);
        assert_eq!(parse_workers_override("nope"), None);
        assert_eq!(parse_workers_override(""), None);
        assert!(serve_workers() >= 1);
    }

    #[test]
    fn home_worker_deterministic_in_range() {
        for n in 1..=8 {
            for name in ["a", "tenant0", "tenant1", "a-long-adapter-id"] {
                let h = home_worker(name, n);
                assert!(h < n);
                assert_eq!(h, home_worker(name, n), "{name} n={n}");
            }
        }
        // single worker: everything homes to 0
        assert_eq!(home_worker("anything", 1), 0);
        // distinct ids do spread (not all on one worker)
        let homes: std::collections::BTreeSet<usize> =
            (0..32).map(|i| home_worker(&format!("t{i}"), 4)).collect();
        assert!(homes.len() > 1, "hash collapsed: {homes:?}");
    }

    #[test]
    fn pool_serves_and_aggregates_stats() {
        let registry = Arc::new(AdapterRegistry::with_capacity(base(1), (1.0, 1.0), 4));
        for i in 0..3 {
            registry.register(&format!("t{i}"), adapter(10 + i)).unwrap();
        }
        let pool = reference_pool(2, registry.clone());
        assert_eq!(pool.workers(), 2);
        assert_eq!(pool.max_prompt_len(), 8);
        assert_eq!(pool.vocab(), 12);

        // blocking queries across adapters
        let mut replies = Vec::new();
        for i in 0..9 {
            let a = format!("t{}", i % 3);
            replies.push(pool.query(&a, vec![1 + (i % 5) as i32, 2]).unwrap());
        }
        // async handles resolve too, bit-identical to the blocking path
        let h = pool.submit_async("t0", vec![1, 2]).unwrap();
        assert!(h.worker() < 2);
        assert_eq!(h.adapter(), "t0");
        let r = h.wait().unwrap();
        assert_eq!(r.logits, replies[0].logits);

        let s = pool.stats();
        assert_eq!(s.requests, 10);
        assert_eq!(s.alive(), 2);
        assert_eq!(s.queue_depth(), 0);
        assert_eq!(s.per_adapter.len(), 3);
        assert_eq!(s.per_adapter["t0"].requests, 4);
        assert_eq!(s.workers.len(), 2);
        assert_eq!(s.workers.iter().map(|w| w.routed).sum::<usize>(), 10);
        // affinity: with no spills, each adapter's requests all landed
        // on its home worker
        assert_eq!(s.spills, 0);
        assert_eq!(s.reroutes, 0);
        for i in 0..3 {
            let name = format!("t{i}");
            let home = home_worker(&name, 2);
            assert_eq!(
                s.workers[home].server.per_adapter[&name].requests,
                s.per_adapter[&name].requests,
                "adapter {name} strayed off worker {home}: {s:?}"
            );
        }
        assert!(s.mean_batch_size() >= 1.0);
        pool.shutdown();
    }

    #[test]
    fn pool_rejects_bad_requests_at_submit() {
        let registry = Arc::new(AdapterRegistry::with_capacity(base(2), (0.0, 0.0), 4));
        registry.register("good", adapter(20)).unwrap();
        let pool = reference_pool(2, registry);
        assert!(pool.submit_async("good", vec![]).is_err());
        assert!(pool.submit_async("good", vec![1; 9]).is_err()); // seq = 8
        let err = pool.submit_async("ghost", vec![1, 2]).unwrap_err();
        assert!(format!("{err:#}").contains("unknown adapter"), "{err:#}");
        assert_eq!(pool.stats().rejected, 3);
        assert_eq!(pool.stats().requests, 0);
        pool.shutdown();
    }

    #[test]
    fn zero_workers_falls_back_to_env_default() {
        let registry = Arc::new(AdapterRegistry::with_capacity(base(3), (0.0, 0.0), 2));
        registry.register("a", adapter(30)).unwrap();
        let pool = reference_pool(0, registry);
        assert!(pool.workers() >= 1);
        assert!(pool.query("a", vec![3, 1]).is_ok());
        pool.shutdown();
    }

    #[test]
    fn worker_init_failure_fails_spawn() {
        let registry = Arc::new(AdapterRegistry::with_capacity(base(4), (0.0, 0.0), 2));
        let err = ServerPool::spawn_with(
            PoolConfig::new(3, Duration::from_millis(1)),
            registry,
            |w| {
                if w == 2 {
                    anyhow::bail!("no device {w}")
                }
                Ok(Box::new(ReferenceBackend::new(2, 4, 4, &NamedTensors::new()))
                    as Box<dyn ServeBackend>)
            },
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("pool worker 2") && msg.contains("no device"), "{msg}");
    }
}
