//! L3 coordinator: the quantize → finetune → evaluate → serve pipeline
//! (the paper's experimental apparatus as a deployable system).
//!
//! - [`quantize`]: model-level quantization with every paper method;
//! - [`trainer`]: pretraining + QLoRA finetuning over the AOT graphs;
//! - [`evaluator`]: 5-shot / 0-shot multiple-choice scoring;
//! - [`server`]: dynamic-batching inference server;
//! - [`experiment`]: per-table-row orchestration with run caching.

pub mod evaluator;
pub mod experiment;
pub mod quantize;
pub mod server;
pub mod trainer;

pub use evaluator::{EvalResult, Evaluator};
pub use experiment::{pretrained_base, run_arm, Arm, ArmResult, RunCfg};
pub use quantize::{quantize_model, QuantizedModel};
pub use server::{BatchServer, ServerConfig};
pub use trainer::{Finetuner, Pretrainer};
