//! L3 coordinator: the quantize → finetune → evaluate → serve pipeline
//! (the paper's experimental apparatus as a deployable system).
//!
//! - [`quantize`]: model-level quantization with every paper method,
//!   uniform-k or mixed-k from a `precision::PrecisionPlan`;
//! - [`trainer`]: pretraining + QLoRA finetuning over the AOT graphs;
//! - [`evaluator`]: 5-shot / 0-shot multiple-choice scoring;
//! - [`registry`]: named IEC-LoRA adapters over one shared
//!   dequantized base (LRU-cached merged weights);
//! - [`backend`]: serving forward engines (PJRT-owning + offline
//!   reference), with fused mixed-adapter forwards and
//!   generation-keyed adapter device caches;
//! - [`server`]: multi-adapter continuous-batching inference server
//!   (one worker, an always-running active set advanced one fused
//!   decode step per iteration; streams join/leave between steps);
//! - [`pool`]: N server workers sharded over one registry, with
//!   adapter-affinity routing, work stealing between idle workers,
//!   async submission, and admission control (bounded parked
//!   overflow, per-request deadlines, parked-request aging, bounded
//!   dead-worker retry);
//! - [`error`]: the typed [`ServeError`] taxonomy every serving
//!   failure resolves to — `Rejected` / `Overloaded` /
//!   `DeadlineExceeded` / `WorkerDead` / `BackendFault` / `Shutdown`,
//!   split by whether a retry is useful;
//! - [`chaos`]: seeded deterministic fault injection
//!   ([`FaultBackend`] over any `ServeBackend`: error-on-nth-call,
//!   panic, injected latency, per-adapter targeting) powering the
//!   chaos soak battery and `irqlora serve --chaos <seed>`;
//! - [`experiment`]: per-table-row orchestration with run caching.
//!
//! Serving env knobs (see the README for the full table):
//! `IRQLORA_SERVE_WORKERS`, `IRQLORA_SERVE_STEAL`,
//! `IRQLORA_PARK_BOUND`, `IRQLORA_PARK_AGE_MS`,
//! `IRQLORA_ADAPTER_CACHE`, `IRQLORA_DEVICE_CACHE`.

pub mod backend;
pub mod chaos;
pub mod error;
pub mod evaluator;
pub mod experiment;
pub mod pool;
pub mod quantize;
pub mod registry;
pub mod server;
pub mod trainer;

pub use backend::{
    device_cache_capacity, AdapterGroup, PjrtBackend, ReferenceBackend, ServeBackend,
    UploadStats,
};
pub use chaos::{FaultBackend, FaultConfig, FaultStats};
pub use error::ServeError;
pub use evaluator::{EvalResult, Evaluator};
pub use experiment::{
    plan_quantized, pretrained_base, run_arm, serve_pool, serve_pool_backend,
    serve_registry, synthetic_serve_registry, Arm, ArmResult, RunCfg,
};
pub use pool::{
    park_age, park_bound, serve_steal, Pending, PoolConfig, PoolStats, PoolWorkerStats,
    ServerPool,
};
pub use quantize::{quantize_model, quantize_model_planned, QuantizedModel};
pub use registry::{AdapterRegistry, RegistryStats};
pub use server::{
    fused_slot_plan, greedy_next_token, BatchServer, Reply, ServerConfig, ServerStats,
    SubmitError,
};
pub use trainer::{Finetuner, Pretrainer};
