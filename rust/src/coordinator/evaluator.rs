//! Multiple-choice evaluator: drives the `forward` graph over SynMMLU /
//! SynCSQA items and scores single-token choices by next-token logit —
//! the 5-shot / 0-shot MC protocol of the paper's benchmarks.
//!
//! Hot-loop discipline: the frozen base weights are dequantized **once**
//! (by `quantize_model`) and uploaded **once** at construction via the
//! zero-copy `upload_f32` path — nothing re-dequantizes or re-uploads
//! them per batch. Inside the eval loop only the token tensor changes;
//! it is filled into one reused scratch buffer and uploaded per batch.

use std::cell::RefCell;
use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::data::evalset::McItem;
use crate::data::PAD;
use crate::model::weights::NamedTensors;
use crate::runtime::{Executor, Manifest, Runtime};

use super::quantize::QuantizedModel;
use super::registry::AdapterRegistry;

/// Accuracy per group plus the average — one table row.
#[derive(Clone, Debug)]
pub struct EvalResult {
    /// (group index, correct, total)
    pub per_group: BTreeMap<usize, (usize, usize)>,
}

impl EvalResult {
    pub fn group_accuracy(&self, g: usize) -> f64 {
        match self.per_group.get(&g) {
            Some(&(c, t)) if t > 0 => c as f64 / t as f64,
            _ => 0.0,
        }
    }

    /// Macro-average over groups (the paper's "Avg." column).
    pub fn avg_accuracy(&self) -> f64 {
        if self.per_group.is_empty() {
            return 0.0;
        }
        let s: f64 = self
            .per_group
            .keys()
            .map(|&g| self.group_accuracy(g))
            .sum();
        s / self.per_group.len() as f64
    }
}

/// Evaluator bound to one (base weights, LoRA, masks) configuration.
pub struct Evaluator<'rt> {
    exe: Executor<'rt>,
    fixed_bufs: Vec<xla::PjRtBuffer>,
    /// Reused per-batch token scratch (batch × seq), so the eval loop
    /// allocates nothing on the host side.
    tok_scratch: RefCell<Vec<i32>>,
    batch: usize,
    seq: usize,
    vocab: usize,
}

impl<'rt> Evaluator<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        manifest: &Manifest,
        tag: &str,
        base: &NamedTensors,
        lora: &NamedTensors,
        masks: (f32, f32),
    ) -> Result<Self> {
        let spec = manifest.graph(tag, "forward")?;
        let cfg = &manifest.size(tag)?.config;
        let nb = base.len();
        let nl = lora.len();
        if spec.inputs.len() != nb + nl + 3 {
            bail!(
                "forward graph expects {} inputs, base+lora+3 = {}",
                spec.inputs.len(),
                nb + nl + 3
            );
        }
        let exe = rt.load(spec)?;
        let mut fixed_bufs = Vec::with_capacity(nb + nl + 2);
        let mut slot = 0usize;
        for nt in [base, lora] {
            for t in nt.tensors() {
                // zero-copy upload: no per-tensor host clone
                fixed_bufs.push(exe.upload_f32(slot, t.data())?);
                slot += 1;
            }
        }
        fixed_bufs.push(exe.upload_f32(slot, &[masks.0])?);
        fixed_bufs.push(exe.upload_f32(slot + 1, &[masks.1])?);
        Ok(Evaluator {
            exe,
            fixed_bufs,
            tok_scratch: RefCell::new(Vec::new()),
            batch: cfg.batch,
            seq: cfg.seq,
            vocab: cfg.vocab,
        })
    }

    /// Build an evaluator straight from a [`QuantizedModel`]: the base
    /// was dequantized exactly once by `quantize_model` (fused packed-
    /// domain path) and that buffer is reused here — callers should
    /// never re-dequantize storage tensors per evaluation. Works for
    /// uniform-k and mixed-k (plan-driven) models alike: by this point
    /// the base is plain f32, so per-tensor bit-widths are invisible.
    ///
    /// Consumers that need `W_q·x` against a stored projection (rather
    /// than the whole-graph forward) should not dequantize-then-matmul:
    /// [`QuantizedModel::packed_matvec`] computes the same bits
    /// straight from packed storage via `kernels::gemm_packed`, never
    /// materializing the dequantized matrix.
    pub fn from_quantized(
        rt: &'rt Runtime,
        manifest: &Manifest,
        tag: &str,
        qm: &QuantizedModel,
        lora: &NamedTensors,
        masks: (f32, f32),
    ) -> Result<Self> {
        Self::new(rt, manifest, tag, &qm.dequantized, lora, masks)
    }

    /// Evaluator for one registry adapter: scores over the registry's
    /// shared dequantized base and the adapter's cached merged weights
    /// (IEC folded in ⇒ masks off). N adapters evaluate against one
    /// base with no re-dequantization, and a warm registry charges no
    /// re-merge either.
    pub fn for_adapter(
        rt: &'rt Runtime,
        manifest: &Manifest,
        tag: &str,
        registry: &AdapterRegistry,
        adapter: &str,
    ) -> Result<Self> {
        let merged = registry.merged(adapter)?;
        Self::new(rt, manifest, tag, registry.base(), &merged, (0.0, 0.0))
    }

    /// Raw next-token logits at the last prompt position of each item.
    /// Returns one vocab-length row per item.
    pub fn score_batch(&self, items: &[&McItem]) -> Result<Vec<Vec<f32>>> {
        if items.len() > self.batch {
            bail!("batch too large: {} > {}", items.len(), self.batch);
        }
        let tok_buf = {
            let mut tokens = self.tok_scratch.borrow_mut();
            tokens.clear();
            tokens.resize(self.batch * self.seq, PAD);
            for (i, item) in items.iter().enumerate() {
                if item.prompt.len() > self.seq {
                    bail!("prompt longer than seq ({})", item.prompt.len());
                }
                tokens[i * self.seq..i * self.seq + item.prompt.len()]
                    .copy_from_slice(&item.prompt);
            }
            self.exe.upload_i32(self.fixed_bufs.len(), tokens.as_slice())?
        };
        let mut all: Vec<&xla::PjRtBuffer> = self.fixed_bufs.iter().collect();
        all.push(&tok_buf);
        let outs = self.exe.execute(&all)?;
        let logits = outs[0].as_f32()?;

        let mut rows = Vec::with_capacity(items.len());
        for (i, item) in items.iter().enumerate() {
            let pos = item.prompt.len() - 1;
            let off = (i * self.seq + pos) * self.vocab;
            rows.push(logits[off..off + self.vocab].to_vec());
        }
        Ok(rows)
    }

    /// Evaluate a full MC item set.
    pub fn evaluate(&self, items: &[McItem]) -> Result<EvalResult> {
        let mut per_group: BTreeMap<usize, (usize, usize)> = BTreeMap::new();
        for chunk in items.chunks(self.batch) {
            let refs: Vec<&McItem> = chunk.iter().collect();
            let rows = self.score_batch(&refs)?;
            for (item, row) in chunk.iter().zip(&rows) {
                let pick = item
                    .choices
                    .iter()
                    .enumerate()
                    .max_by(|a, b| {
                        row[*a.1 as usize]
                            .partial_cmp(&row[*b.1 as usize])
                            .unwrap()
                    })
                    .map(|(i, _)| i)
                    .unwrap();
                let e = per_group.entry(item.group).or_insert((0, 0));
                e.1 += 1;
                if pick == item.correct {
                    e.0 += 1;
                }
            }
        }
        Ok(EvalResult { per_group })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_result_math() {
        let mut per_group = BTreeMap::new();
        per_group.insert(0, (8usize, 10usize));
        per_group.insert(1, (2, 10));
        let r = EvalResult { per_group };
        assert!((r.group_accuracy(0) - 0.8).abs() < 1e-12);
        assert!((r.group_accuracy(1) - 0.2).abs() < 1e-12);
        assert!((r.avg_accuracy() - 0.5).abs() < 1e-12);
        assert_eq!(r.group_accuracy(9), 0.0);
    }
}
