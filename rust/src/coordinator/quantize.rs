//! Model-level quantization pipeline: applies a [`Method`] to every
//! projection tensor of a base model, producing (a) the dequantized
//! weights the AOT graphs consume, (b) the storage representation for
//! the serving path, and (c) the information/storage report behind
//! Tables 5/6 and Figures 4/5.

use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::model::weights::{is_quantized_proj, proj_kind, NamedTensors};
use crate::precision::PrecisionPlan;
use crate::quant::{blockwise, gptq, icq, integer, DequantScratch, Method, QuantizedTensor};
use crate::util::f16::round_f16;
use crate::util::timer::Timer;
use crate::util::{Rng, Tensor};

/// Per-tensor quantization record.
#[derive(Clone, Debug)]
pub struct TensorReport {
    pub name: String,
    /// Mean per-block code entropy (bits).
    pub entropy: f64,
    /// Entropy of the uncalibrated quantization of the same tensor.
    pub entropy_vanilla: f64,
    /// Effective stored bits per weight (codes + constants).
    pub bits_per_weight: f64,
    pub n_params: usize,
}

/// Model-level quantization result. Bit-widths are **per tensor**:
/// each storage entry carries its own k (uniform-k models simply have
/// them all equal), and dequantization dispatches per-k through the
/// fused LUTs, so every downstream consumer (evaluator, registry,
/// server, `lora::merge`) handles mixed-k bases unchanged.
pub struct QuantizedModel {
    /// Dequantized weights (graph inputs). Non-projection tensors pass
    /// through untouched.
    pub dequantized: NamedTensors,
    /// Storage representation per quantized tensor (NF methods only).
    pub storage: Vec<(String, QuantizedTensor)>,
    pub reports: Vec<TensorReport>,
    /// Wall time of the whole pipeline (Table 7's "additional time").
    pub elapsed: Duration,
    pub method: Method,
    /// The precision plan behind a mixed-k model
    /// ([`quantize_model_planned`]); `None` for uniform-k models.
    pub plan: Option<PrecisionPlan>,
}

impl QuantizedModel {
    /// Mean entropy across quantized tensors (Table 5 "Ent.").
    pub fn mean_entropy(&self) -> f64 {
        if self.reports.is_empty() {
            return 0.0;
        }
        self.reports.iter().map(|r| r.entropy).sum::<f64>() / self.reports.len() as f64
    }

    /// `y = W_q·x` for the named quantized projection, computed
    /// straight from packed NF-k storage via
    /// [`crate::kernels::gemm_packed_into`] — the evaluator-facing
    /// packed-domain replacement for "dequantize the tensor, then
    /// matmul". Bit-identical to running
    /// [`crate::kernels::gemm_f32_reference`] over
    /// `self.dequantized[name]`, for every stored k (mixed-k models
    /// dispatch per tensor). The dequantized matrix is never
    /// materialized; with warm `y`/`scratch` the call allocates
    /// nothing. Errors if `name` has no packed storage entry (f16 /
    /// integer methods, pass-through tensors) — callers fall back to
    /// the dense path for those.
    pub fn packed_matvec(
        &self,
        name: &str,
        x: &[f32],
        y: &mut Vec<f32>,
        scratch: &mut crate::kernels::PackedGemmScratch,
    ) -> Result<()> {
        let (_, qt) = self
            .storage
            .iter()
            .find(|(n, _)| n == name)
            .ok_or_else(|| anyhow!("tensor '{name}' has no packed storage entry"))?;
        crate::kernels::gemm_packed_into(qt, x, y, scratch);
        Ok(())
    }

    /// Model storage in megabytes: quantized projections at their
    /// effective bits, everything else at 16-bit (Table 6 #Params).
    pub fn storage_mb(&self) -> f64 {
        let mut bits = 0f64;
        for (name, t) in self.dequantized.iter() {
            if let Some(rep) = self.reports.iter().find(|r| r.name == name) {
                bits += rep.bits_per_weight * rep.n_params as f64;
            } else {
                bits += 16.0 * t.len() as f64;
            }
        }
        bits / 8.0 / 1e6
    }
}

/// Synthetic correlated calibration activations for GPTQ (AR(1) over
/// features — the substitution for real calibration text documented in
/// DESIGN.md §2; an identity Hessian would collapse GPTQ to RTN).
fn gptq_calibration(h: usize, n: usize, rng: &mut Rng) -> Tensor {
    let mut x = vec![0f32; n * h];
    for s in 0..n {
        let mut prev = rng.normal();
        for j in 0..h {
            let e = rng.normal();
            let v = 0.55 * prev + 0.85 * e;
            x[s * h + j] = v;
            prev = v;
        }
    }
    Tensor::new(&[n, h], x)
}

/// NF-path quantization of one projection tensor at bit-width `k`
/// (ICQ when `icq_cfg` is set): dequantized weights, mean code
/// entropy, effective stored bits/weight and the storage tensor.
/// Shared by the uniform-k and plan-driven pipelines.
fn quantize_nf_tensor(
    t: &Tensor,
    k: u8,
    block: usize,
    icq_cfg: Option<&icq::IcqConfig>,
    dq_scratch: &mut DequantScratch,
) -> (Vec<f32>, f64, f64, QuantizedTensor) {
    let qt = QuantizedTensor::quantize(t, k, block, icq_cfg);
    let h = qt.mean_entropy();
    let bits = qt.bits_per_weight();
    let mut dq = vec![0f32; qt.len];
    qt.dequantize_into(&mut dq, dq_scratch);
    (dq, h, bits, qt)
}

/// Quantize every projection tensor of `weights` with `method`.
pub fn quantize_model(
    weights: &NamedTensors,
    method: Method,
    seed: u64,
) -> Result<QuantizedModel> {
    if method == Method::Planned {
        bail!("Method::Planned carries no uniform k — use quantize_model_planned");
    }
    let timer = Timer::start();
    let mut dequantized = NamedTensors::new();
    let mut storage = Vec::new();
    let mut reports = Vec::new();
    let mut rng = Rng::new(seed ^ 0x51554e54);
    let icq_cfg = icq::IcqConfig::default();
    // one dequant scratch reused across every tensor: the per-block
    // constants buffers are recycled, and the fused packed-domain path
    // writes each tensor's weights straight into its output vec
    let mut dq_scratch = DequantScratch::default();

    for (name, t) in weights.iter() {
        if !is_quantized_proj(name) {
            dequantized.push(name, t.clone());
            continue;
        }
        let w = t.data();
        let n = w.len();
        let (dq, entropy, bits): (Vec<f32>, f64, f64) = match method {
            Method::Fp16 => {
                let dq = w.iter().map(|&x| round_f16(x)).collect();
                (dq, 0.0, 16.0)
            }
            Method::Nf { k } => {
                let (dq, h, bits, qt) =
                    quantize_nf_tensor(t, k, blockwise::DEFAULT_BLOCK, None, &mut dq_scratch);
                storage.push((name.to_string(), qt));
                (dq, h, bits)
            }
            Method::NfIcq { k } => {
                let (dq, h, bits, qt) = quantize_nf_tensor(
                    t,
                    k,
                    blockwise::DEFAULT_BLOCK,
                    Some(&icq_cfg),
                    &mut dq_scratch,
                );
                storage.push((name.to_string(), qt));
                (dq, h, bits)
            }
            Method::Planned => unreachable!("rejected before the loop"),
            Method::Int { k } => {
                let q = integer::quantize(w, k, blockwise::DEFAULT_BLOCK);
                let h = integer::mean_entropy(&q);
                // group-wise int stores k-bit codes + (s, z) per group
                let bits = k as f64 + 32.0 / blockwise::DEFAULT_BLOCK as f64;
                (integer::dequantize(&q), h, bits)
            }
            Method::IntIcq { k } => {
                let q = integer::quantize_icq(w, k, blockwise::DEFAULT_BLOCK, 3);
                let h = integer::mean_entropy(&q);
                let bits = k as f64 + 32.0 / blockwise::DEFAULT_BLOCK as f64;
                (integer::dequantize(&q), h, bits)
            }
            Method::Gptq { k } => {
                // w is [in, out]; GPTQ wants rows = outputs
                let wt = t.transpose();
                let calib = gptq_calibration(t.shape()[0], 96, &mut rng);
                let cfg = gptq::GptqConfig { k, group: 64, damp: 0.01 };
                let (wq, _) = gptq::gptq_quantize(&wt, &calib, &cfg);
                let q = integer::quantize(w, k, blockwise::DEFAULT_BLOCK);
                let h = integer::mean_entropy(&q);
                let bits = k as f64 + 32.0 / blockwise::DEFAULT_BLOCK as f64;
                (wq.transpose().into_data(), h, bits)
            }
        };
        // vanilla-NF entropy of the same tensor, for the ICQ-vs-vanilla
        // comparisons (Figures 4/5); skip for fp16
        let entropy_vanilla = if method.bits() < 16 {
            let q0 = blockwise::quantize(w, method.bits(), blockwise::DEFAULT_BLOCK, None);
            crate::quant::entropy::mean_block_entropy(&q0)
        } else {
            0.0
        };
        reports.push(TensorReport {
            name: name.to_string(),
            entropy,
            entropy_vanilla,
            bits_per_weight: bits,
            n_params: n,
        });
        dequantized.push(name, Tensor::new(t.shape(), dq));
    }

    Ok(QuantizedModel {
        dequantized,
        storage,
        reports,
        elapsed: timer.elapsed(),
        method,
        plan: None,
    })
}

/// Quantize every projection tensor with its plan-assigned bit-width
/// (ICQ NF-k, per-tensor k) — the mixed-k pipeline behind
/// `precision::apply`. The result serves and evaluates through
/// exactly the same downstream paths as a uniform-k model; errors if
/// plan and model disagree in either direction — a projection tensor
/// with no plan entry, or a plan entry matching no tensor (both are
/// stale-plan-applied-to-a-different-model symptoms).
pub fn quantize_model_planned(
    weights: &NamedTensors,
    plan: &PrecisionPlan,
    icq_cfg: &icq::IcqConfig,
) -> Result<QuantizedModel> {
    let timer = Timer::start();
    let mut dequantized = NamedTensors::new();
    let mut storage = Vec::new();
    let mut reports = Vec::new();
    let mut dq_scratch = DequantScratch::default();
    // quantize at the block size the plan was profiled at — its
    // entropy/storage numbers describe exactly that blocking
    let block = plan.block;
    if block == 0 {
        bail!("precision plan has block size 0");
    }

    for (name, t) in weights.iter() {
        if !is_quantized_proj(name) {
            dequantized.push(name, t.clone());
            continue;
        }
        let entry = plan
            .entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("tensor '{name}' is missing from precision plan"))?;
        // names like "l0.wq" are size-independent, so a stale plan for
        // a differently-sized model would otherwise match silently
        if entry.n_params != t.len() {
            bail!(
                "plan entry '{name}' describes {} params but the tensor has {} — \
                 the plan was built for a different model",
                entry.n_params,
                t.len()
            );
        }
        let k = entry.k;
        let (dq, entropy, bits, qt) =
            quantize_nf_tensor(t, k, block, Some(icq_cfg), &mut dq_scratch);
        let q0 = blockwise::quantize(t.data(), k, block, None);
        let entropy_vanilla = crate::quant::entropy::mean_block_entropy(&q0);
        storage.push((name.to_string(), qt));
        reports.push(TensorReport {
            name: name.to_string(),
            entropy,
            entropy_vanilla,
            bits_per_weight: bits,
            n_params: t.len(),
        });
        dequantized.push(name, Tensor::new(t.shape(), dq));
    }

    // the converse validation: every plan entry must have matched a
    // model tensor, or a stale plan's bookkeeping (total params/bits)
    // would travel with an artifact it does not describe
    if storage.len() != plan.entries.len() {
        let unmatched: Vec<&str> = plan
            .entries
            .iter()
            .filter(|e| !storage.iter().any(|(n, _)| *n == e.name))
            .map(|e| e.name.as_str())
            .collect();
        bail!("plan entries match no model tensor: {unmatched:?}");
    }

    Ok(QuantizedModel {
        dequantized,
        storage,
        reports,
        elapsed: timer.elapsed(),
        method: Method::Planned,
        plan: Some(plan.clone()),
    })
}

/// Per-(layer, projection) entropy pairs for Figures 4/5.
pub fn entropy_by_projection(
    weights: &NamedTensors,
    k: u8,
) -> Vec<(String, f64, f64)> {
    let icq_cfg = icq::IcqConfig::default();
    weights
        .iter()
        .filter(|(n, _)| is_quantized_proj(n))
        .map(|(name, t)| {
            let q0 = blockwise::quantize(t.data(), k, 64, None);
            let h0 = crate::quant::entropy::mean_block_entropy(&q0);
            let q1 = icq::quantize(t.data(), k, 64, &icq_cfg);
            let h1 = crate::quant::entropy::mean_block_entropy(&q1);
            let _ = proj_kind(name);
            (name.to_string(), h0, h1)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Dtype, InputSpec};

    fn tiny_model(seed: u64) -> NamedTensors {
        let specs = vec![
            InputSpec { name: "embed".into(), shape: vec![32, 64], dtype: Dtype::F32 },
            InputSpec { name: "l0.attn_norm".into(), shape: vec![64], dtype: Dtype::F32 },
            InputSpec { name: "l0.wq".into(), shape: vec![64, 64], dtype: Dtype::F32 },
            InputSpec { name: "l0.w2".into(), shape: vec![128, 64], dtype: Dtype::F32 },
            InputSpec { name: "lm_head".into(), shape: vec![64, 32], dtype: Dtype::F32 },
        ];
        let mut rng = Rng::new(seed);
        crate::model::weights::init_base(&specs, 1, &mut rng)
    }

    #[test]
    fn quantizes_only_projections() {
        let m = tiny_model(1);
        let q = quantize_model(&m, Method::Nf { k: 4 }, 0).unwrap();
        assert_eq!(q.reports.len(), 2); // wq, w2
        assert_eq!(q.dequantized.len(), m.len());
        // embed untouched
        assert_eq!(q.dequantized.get("embed").unwrap(), m.get("embed").unwrap());
        // wq changed (lossy)
        assert_ne!(q.dequantized.get("l0.wq").unwrap(), m.get("l0.wq").unwrap());
    }

    #[test]
    fn icq_entropy_gain_and_storage_cost() {
        let m = tiny_model(2);
        let v = quantize_model(&m, Method::Nf { k: 4 }, 0).unwrap();
        let i = quantize_model(&m, Method::NfIcq { k: 4 }, 0).unwrap();
        assert!(i.mean_entropy() >= v.mean_entropy());
        // ICQ stores tau next to scale: slightly more bits
        assert!(i.storage_mb() > v.storage_mb());
        assert!(i.storage_mb() < v.storage_mb() * 1.05);
    }

    #[test]
    fn methods_all_run() {
        let m = tiny_model(3);
        for method in [
            Method::Fp16,
            Method::Nf { k: 2 },
            Method::Nf { k: 3 },
            Method::Int { k: 4 },
            Method::IntIcq { k: 4 },
            Method::Gptq { k: 4 },
        ] {
            let q = quantize_model(&m, method, 7).unwrap();
            assert!(q
                .dequantized
                .get("l0.wq")
                .unwrap()
                .data()
                .iter()
                .all(|v| v.is_finite()));
        }
    }

    #[test]
    fn lower_bits_higher_error() {
        let m = tiny_model(4);
        let orig = m.get("l0.wq").unwrap().data().to_vec();
        let mut errs = Vec::new();
        for k in [2u8, 3, 4] {
            let q = quantize_model(&m, Method::Nf { k }, 0).unwrap();
            errs.push(crate::util::stats::mse(
                &orig,
                q.dequantized.get("l0.wq").unwrap().data(),
            ));
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }

    #[test]
    fn planned_method_requires_a_plan() {
        let m = tiny_model(6);
        let err = quantize_model(&m, Method::Planned, 0).unwrap_err().to_string();
        assert!(err.contains("quantize_model_planned"), "{err}");
        // the guard is unconditional — even with zero projection
        // tensors there is no silent Ok(Planned-without-plan)
        let mut bare = NamedTensors::new();
        bare.push("embed", Tensor::zeros(&[4, 4]));
        assert!(quantize_model(&bare, Method::Planned, 0).is_err());
    }

    #[test]
    fn planned_model_matches_per_tensor_uniform_oracles() {
        use crate::precision::{PlanEntry, PrecisionPlan};

        let m = tiny_model(7);
        let icq_cfg = icq::IcqConfig::default();
        // hand-built mixed plan: wq at 2 bits, w2 at 4
        let plan = PrecisionPlan {
            budget_bits: 3.0,
            block: blockwise::DEFAULT_BLOCK,
            entries: vec![
                PlanEntry {
                    name: "l0.wq".into(),
                    k: 2,
                    n_params: m.get("l0.wq").unwrap().len(),
                    entropy: 0.0,
                    bits_per_weight: 0.0,
                },
                PlanEntry {
                    name: "l0.w2".into(),
                    k: 4,
                    n_params: m.get("l0.w2").unwrap().len(),
                    entropy: 0.0,
                    bits_per_weight: 0.0,
                },
            ],
        };
        let qm = quantize_model_planned(&m, &plan, &icq_cfg).unwrap();
        assert_eq!(qm.method, Method::Planned);
        assert!(qm.plan.is_some());
        assert_eq!(qm.storage.len(), 2);
        // each tensor must be bit-identical to quantizing it alone at
        // its uniform k — mixed-k is per-tensor uniform-k, nothing else
        for (name, k) in [("l0.wq", 2u8), ("l0.w2", 4u8)] {
            let t = m.get(name).unwrap();
            let oracle =
                QuantizedTensor::quantize(t, k, blockwise::DEFAULT_BLOCK, Some(&icq_cfg));
            let (_, qt) = qm.storage.iter().find(|(n, _)| n == name).unwrap();
            assert_eq!(qt.k, k, "{name}");
            assert_eq!(qt.packed, oracle.packed, "{name}");
            let got = qm.dequantized.get(name).unwrap();
            let want = oracle.dequantize();
            for (a, b) in got.data().iter().zip(want.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{name}");
            }
        }
        // non-projection tensors pass through
        assert_eq!(qm.dequantized.get("embed").unwrap(), m.get("embed").unwrap());
    }

    /// `packed_matvec` must land on the exact bits of the dense
    /// dequantize-then-matmul oracle for every stored tensor — uniform
    /// and mixed-k — and refuse tensors with no packed storage.
    #[test]
    fn packed_matvec_matches_dense_oracle() {
        use crate::kernels::{gemm_f32_reference, PackedGemmScratch};
        use crate::precision::{PlanEntry, PrecisionPlan};

        let m = tiny_model(8);
        let icq_cfg = icq::IcqConfig::default();
        let plan = PrecisionPlan {
            budget_bits: 3.0,
            block: blockwise::DEFAULT_BLOCK,
            entries: vec![
                PlanEntry {
                    name: "l0.wq".into(),
                    k: 2,
                    n_params: m.get("l0.wq").unwrap().len(),
                    entropy: 0.0,
                    bits_per_weight: 0.0,
                },
                PlanEntry {
                    name: "l0.w2".into(),
                    k: 8,
                    n_params: m.get("l0.w2").unwrap().len(),
                    entropy: 0.0,
                    bits_per_weight: 0.0,
                },
            ],
        };
        for qm in [
            quantize_model(&m, Method::NfIcq { k: 4 }, 0).unwrap(),
            quantize_model_planned(&m, &plan, &icq_cfg).unwrap(),
        ] {
            let mut y = Vec::new();
            let mut scratch = PackedGemmScratch::new();
            for (name, qt) in &qm.storage {
                let shape = qm.dequantized.get(name).unwrap().shape().to_vec();
                let (rows, cols) = (shape[0], shape[1..].iter().product::<usize>());
                assert_eq!(rows * cols, qt.len);
                let x: Vec<f32> = (0..cols).map(|j| (j as f32 * 0.37).sin()).collect();
                qm.packed_matvec(name, &x, &mut y, &mut scratch).unwrap();
                let dense = qm.dequantized.get(name).unwrap().data();
                let want = gemm_f32_reference(dense, &x, rows, cols, 1);
                assert_eq!(y.len(), want.len(), "{name}");
                for (i, (a, b)) in y.iter().zip(&want).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "{name} row {i}: {a} vs {b}");
                }
            }
            // tensors with no packed storage are refused, not guessed at
            let err = qm
                .packed_matvec("embed", &[0.0; 64], &mut y, &mut scratch)
                .unwrap_err()
                .to_string();
            assert!(err.contains("no packed storage"), "{err}");
        }
    }

    #[test]
    fn entropy_by_projection_reports_both() {
        let m = tiny_model(5);
        let rows = entropy_by_projection(&m, 4);
        assert_eq!(rows.len(), 2);
        for (name, h0, h1) in rows {
            assert!(h1 >= h0 - 1e-9, "{name}: icq {h1} < vanilla {h0}");
            assert!(h0 > 2.0 && h1 <= 4.0);
        }
    }
}
