//! Deterministic fault injection for the serving stack.
//!
//! [`FaultBackend`] wraps any [`ServeBackend`] and perturbs its
//! forward calls according to a seeded, **deterministic** schedule:
//! given the same [`FaultConfig`] and the same sequence of forward
//! calls, the same calls fault. There is no clock or RNG draw at
//! fault-decision time — every decision is a pure function of the
//! per-backend call counter and the config — so a chaos run is
//! replayable and the soak battery (`rust/tests/chaos_soak.rs`) can
//! assert exact counter consistency.
//!
//! Fault kinds (each independently optional):
//! - **error-on-nth-call**: every `error_every`-th forward returns an
//!   `Err` instead of running (the worker survives — the server
//!   isolates or falls back, and replies carry
//!   `ServeError::BackendFault`);
//! - **panic**: the `panic_after`-th forward panics, killing the
//!   worker thread (the pool's death handling reroutes subsequent
//!   traffic);
//! - **injected latency**: every `delay_every`-th forward sleeps
//!   `delay` before running (builds queue depth, exercising parking,
//!   aging, and admission control);
//! - **per-adapter targeting**: when `target_adapter` is set, a fault
//!   only fires on calls whose batch contains that adapter — healthy
//!   tenants ride clean forwards.
//!
//! `irqlora serve --chaos <seed>` wires a seed-derived config under
//! the reference demo; tests construct explicit configs.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::model::weights::NamedTensors;

use super::backend::{AdapterGroup, ServeBackend, UploadStats};

/// Deterministic fault schedule for one [`FaultBackend`]. All knobs
/// count *forward calls* on that backend instance (fused and
/// per-group calls alike), starting at 1.
#[derive(Clone, Debug, Default)]
pub struct FaultConfig {
    /// Every n-th forward returns an injected error (None: never).
    pub error_every: Option<u64>,
    /// The n-th forward panics, killing the worker thread (None:
    /// never). One-shot by construction — the thread does not survive
    /// to make an (n+k)-th call.
    pub panic_after: Option<u64>,
    /// Every n-th forward sleeps `delay` first (None: never).
    pub delay_every: Option<u64>,
    /// Injected sleep for `delay_every` calls.
    pub delay: Duration,
    /// Restrict every fault kind to calls whose batch contains this
    /// adapter (None: any call can fault).
    pub target_adapter: Option<String>,
}

impl FaultConfig {
    /// Derive a full schedule from one seed — the `--chaos <seed>`
    /// mapping. Pure and stable: the same seed always produces the
    /// same schedule. Spreads the seed's bits across the knobs so
    /// nearby seeds still differ; every derived schedule injects
    /// errors and latency, and two seeds in three also panic one
    /// worker (exercising death + reroute under load).
    pub fn from_seed(seed: u64) -> FaultConfig {
        // FNV-style bit mix so low-entropy seeds (0, 1, 2...) still
        // spread across the knob ranges
        let mut x = seed.wrapping_mul(0x100000001b3).wrapping_add(0x9e3779b97f4a7c15);
        x ^= x >> 29;
        FaultConfig {
            error_every: Some(4 + x % 6),
            panic_after: if x % 3 != 0 { Some(24 + (x >> 8) % 32) } else { None },
            delay_every: Some(3 + (x >> 16) % 4),
            delay: Duration::from_micros(100 + (x >> 24) % 400),
            target_adapter: None,
        }
    }

    /// Builder: fault only calls carrying `adapter`.
    pub fn targeting(mut self, adapter: &str) -> FaultConfig {
        self.target_adapter = Some(adapter.to_string());
        self
    }

    /// Builder: disable the panic knob (e.g. for workers that must
    /// stay alive through a soak).
    pub fn no_panic(mut self) -> FaultConfig {
        self.panic_after = None;
        self
    }
}

/// Injected-fault counters, shared out of the worker thread via
/// [`FaultBackend::stats`] so tests and the CLI can reconcile observed
/// failures against what was actually injected.
#[derive(Debug, Default)]
pub struct FaultStats {
    /// Forward calls that reached this backend (faulted or not).
    pub forwards: AtomicU64,
    /// Calls answered with an injected error.
    pub errors_injected: AtomicU64,
    /// Calls that panicked (0 or 1 per backend — the thread dies).
    pub panics_injected: AtomicU64,
    /// Calls that slept the injected latency first.
    pub delays_injected: AtomicU64,
    /// The subset of `forwards` that were single-position decode steps
    /// (`forward_step`) — the continuous-batching hot path.
    pub step_forwards: AtomicU64,
}

impl FaultStats {
    pub fn forwards(&self) -> u64 {
        self.forwards.load(Ordering::Acquire)
    }
    pub fn errors(&self) -> u64 {
        self.errors_injected.load(Ordering::Acquire)
    }
    pub fn panics(&self) -> u64 {
        self.panics_injected.load(Ordering::Acquire)
    }
    pub fn delays(&self) -> u64 {
        self.delays_injected.load(Ordering::Acquire)
    }
    pub fn steps(&self) -> u64 {
        self.step_forwards.load(Ordering::Acquire)
    }
}

/// [`ServeBackend`] wrapper driven by a [`FaultConfig`] (module docs).
/// Wraps any backend — reference or PJRT — without touching its
/// results: a non-faulted call is passed through verbatim, so
/// delivered replies stay bit-identical to the unwrapped backend's.
pub struct FaultBackend {
    inner: Box<dyn ServeBackend>,
    cfg: FaultConfig,
    calls: u64,
    stats: Arc<FaultStats>,
    telem: ChaosTelem,
}

/// Telemetry mirrors of [`FaultStats`], incremented at the same
/// mutation sites (so a `chaos.*` snapshot reconciles exactly with the
/// struct counters). No-op handles unless the resolving registry is
/// enabled.
#[derive(Clone)]
struct ChaosTelem {
    forwards: crate::telemetry::Counter,
    errors: crate::telemetry::Counter,
    panics: crate::telemetry::Counter,
    delays: crate::telemetry::Counter,
    steps: crate::telemetry::Counter,
}

impl ChaosTelem {
    fn resolve(reg: &crate::telemetry::Registry) -> ChaosTelem {
        ChaosTelem {
            forwards: reg.counter("chaos.forwards", &[]),
            errors: reg.counter("chaos.errors_injected", &[]),
            panics: reg.counter("chaos.panics_injected", &[]),
            delays: reg.counter("chaos.delays_injected", &[]),
            steps: reg.counter("chaos.step_forwards", &[]),
        }
    }
}

impl FaultBackend {
    pub fn new(inner: Box<dyn ServeBackend>, cfg: FaultConfig) -> FaultBackend {
        Self::with_telemetry(inner, cfg, &crate::telemetry::global())
    }

    /// [`Self::new`] recording into an explicit telemetry registry
    /// instead of the process-global one — how parallel tests get
    /// isolated `chaos.*` counters without touching process env.
    pub fn with_telemetry(
        inner: Box<dyn ServeBackend>,
        cfg: FaultConfig,
        reg: &crate::telemetry::Registry,
    ) -> FaultBackend {
        FaultBackend {
            inner,
            cfg,
            calls: 0,
            stats: Arc::new(FaultStats::default()),
            telem: ChaosTelem::resolve(reg),
        }
    }

    /// Handle to the injected-fault counters; clone it out before
    /// moving the backend into a worker.
    pub fn stats(&self) -> Arc<FaultStats> {
        self.stats.clone()
    }

    /// Decide this call's fault. Counts the call, then applies the
    /// schedule in severity order (panic > error > delay); `targeted`
    /// is whether the batch contains the target adapter (vacuously
    /// true without targeting).
    fn fault_for_call(&mut self, targeted: bool) -> Result<()> {
        self.calls += 1;
        self.stats.forwards.fetch_add(1, Ordering::AcqRel);
        self.telem.forwards.inc();
        if !targeted {
            return Ok(());
        }
        if self.cfg.panic_after == Some(self.calls) {
            self.stats.panics_injected.fetch_add(1, Ordering::AcqRel);
            self.telem.panics.inc();
            panic!("chaos: injected panic at forward call {}", self.calls);
        }
        if let Some(n) = self.cfg.error_every {
            if n > 0 && self.calls % n == 0 {
                self.stats.errors_injected.fetch_add(1, Ordering::AcqRel);
                self.telem.errors.inc();
                bail!("chaos: injected backend error at forward call {}", self.calls);
            }
        }
        if let Some(n) = self.cfg.delay_every {
            if n > 0 && self.calls % n == 0 && !self.cfg.delay.is_zero() {
                self.stats.delays_injected.fetch_add(1, Ordering::AcqRel);
                self.telem.delays.inc();
                std::thread::sleep(self.cfg.delay);
            }
        }
        Ok(())
    }

    fn targets(&self, adapter: &str) -> bool {
        self.cfg.target_adapter.as_deref().map_or(true, |t| t == adapter)
    }
}

impl ServeBackend for FaultBackend {
    fn shape(&self) -> (usize, usize, usize) {
        self.inner.shape()
    }

    fn forward(
        &mut self,
        name: &str,
        generation: u64,
        weights: &Arc<NamedTensors>,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let targeted = self.targets(name);
        self.fault_for_call(targeted)?;
        self.inner.forward(name, generation, weights, tokens)
    }

    fn forward_fused(&mut self, groups: &[AdapterGroup], tokens: &[i32]) -> Result<Vec<f32>> {
        let targeted = self
            .cfg
            .target_adapter
            .as_deref()
            .map_or(true, |t| groups.iter().any(|g| g.name == t));
        self.fault_for_call(targeted)?;
        self.inner.forward_fused(groups, tokens)
    }

    // Explicit wrap — NOT the trait default. Inheriting the default
    // would route a step through this wrapper's own faulted
    // `forward_fused`, double-counting the call in the schedule and
    // desynchronizing chaos replay between streamed and one-shot runs.
    // A step is ONE schedule tick, exactly like a fused forward.
    fn forward_step(
        &mut self,
        groups: &[AdapterGroup],
        tokens: &[i32],
        lens: &[usize],
    ) -> Result<Vec<f32>> {
        let targeted = self
            .cfg
            .target_adapter
            .as_deref()
            .map_or(true, |t| groups.iter().any(|g| g.name == t));
        self.stats.step_forwards.fetch_add(1, Ordering::AcqRel);
        self.telem.steps.inc();
        self.fault_for_call(targeted)?;
        self.inner.forward_step(groups, tokens, lens)
    }

    fn upload_stats(&self) -> UploadStats {
        self.inner.upload_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::ReferenceBackend;

    fn inner() -> Box<dyn ServeBackend> {
        Box::new(ReferenceBackend::new(2, 4, 6, &NamedTensors::new()))
    }

    #[test]
    fn from_seed_is_deterministic_and_seed_sensitive() {
        for seed in [0u64, 1, 7, 0xdead_beef] {
            let a = FaultConfig::from_seed(seed);
            let b = FaultConfig::from_seed(seed);
            assert_eq!(a.error_every, b.error_every);
            assert_eq!(a.panic_after, b.panic_after);
            assert_eq!(a.delay_every, b.delay_every);
            assert_eq!(a.delay, b.delay);
            assert!(a.error_every.unwrap() >= 4);
            assert!(a.delay_every.unwrap() >= 3);
        }
        // adjacent seeds must not collapse onto one schedule
        let spread: std::collections::BTreeSet<u64> =
            (0..16).map(|s| FaultConfig::from_seed(s).error_every.unwrap()).collect();
        assert!(spread.len() > 1, "seed mixing collapsed: {spread:?}");
    }

    #[test]
    fn error_schedule_fires_on_exact_calls() {
        let cfg = FaultConfig {
            error_every: Some(3),
            ..FaultConfig::default()
        };
        let mut fb = FaultBackend::new(inner(), cfg);
        let stats = fb.stats();
        let w = Arc::new(NamedTensors::new());
        let toks = vec![1i32; 2 * 4];
        for call in 1..=9u64 {
            let r = fb.forward("a", 0, &w, &toks);
            if call % 3 == 0 {
                let e = r.unwrap_err();
                assert!(format!("{e:#}").contains("chaos"), "{e:#}");
            } else {
                assert!(r.is_ok(), "call {call} unexpectedly faulted");
            }
        }
        assert_eq!(stats.forwards(), 9);
        assert_eq!(stats.errors(), 3);
        assert_eq!(stats.panics(), 0);
    }

    #[test]
    fn targeting_spares_other_adapters() {
        let cfg = FaultConfig {
            error_every: Some(1), // every targeted call errors
            ..FaultConfig::default()
        }
        .targeting("victim");
        let mut fb = FaultBackend::new(inner(), cfg);
        let stats = fb.stats();
        let w = Arc::new(NamedTensors::new());
        let toks = vec![1i32; 2 * 4];
        assert!(fb.forward("healthy", 0, &w, &toks).is_ok());
        assert!(fb.forward("victim", 0, &w, &toks).is_err());
        assert!(fb.forward("healthy", 0, &w, &toks).is_ok());
        assert_eq!(stats.forwards(), 3);
        assert_eq!(stats.errors(), 1);
    }

    #[test]
    fn untouched_calls_pass_through_bit_identical() {
        let mut plain = ReferenceBackend::new(2, 4, 6, &NamedTensors::new());
        let mut fb = FaultBackend::new(inner(), FaultConfig::default());
        let w = Arc::new(NamedTensors::new());
        let toks = vec![2i32; 2 * 4];
        let a = plain.forward("t", 1, &w, &toks).unwrap();
        let b = fb.forward("t", 1, &w, &toks).unwrap();
        assert_eq!(a, b, "no-fault wrapper must not perturb logits");
    }

    #[test]
    fn step_forwards_tick_the_same_schedule() {
        // a decode step is one schedule tick, interleaved with full
        // forwards on the SAME counter — and tracked separately
        let cfg = FaultConfig { error_every: Some(2), ..FaultConfig::default() };
        let mut fb = FaultBackend::new(inner(), cfg);
        let stats = fb.stats();
        let w = Arc::new(NamedTensors::new());
        let toks = vec![1i32; 2 * 4];
        let lens = vec![2usize; 2];
        let groups = vec![AdapterGroup {
            name: "a".to_string(),
            generation: 0,
            weights: w.clone(),
            rows: 0..2,
        }];
        assert!(fb.forward("a", 0, &w, &toks).is_ok()); // call 1
        let e = fb.forward_step(&groups, &toks, &lens).unwrap_err(); // call 2 faults
        assert!(format!("{e:#}").contains("chaos"), "{e:#}");
        assert!(fb.forward_step(&groups, &toks, &lens).is_ok()); // call 3
        assert_eq!(stats.forwards(), 3, "steps and forwards share one schedule");
        assert_eq!(stats.steps(), 2);
        assert_eq!(stats.errors(), 1);
    }

    #[test]
    fn panic_schedule_panics_on_exact_call() {
        let cfg = FaultConfig { panic_after: Some(2), ..FaultConfig::default() };
        let mut fb = FaultBackend::new(inner(), cfg);
        let w = Arc::new(NamedTensors::new());
        let toks = vec![1i32; 2 * 4];
        assert!(fb.forward("a", 0, &w, &toks).is_ok());
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = fb.forward("a", 0, &w, &toks);
        }));
        assert!(caught.is_err(), "second call must panic");
    }
}
