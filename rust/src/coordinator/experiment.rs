//! Experiment orchestration: the pretrain → quantize → finetune →
//! evaluate pipeline each table row runs, with checkpoint caching so
//! repeated table invocations reuse the pretrained base.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::data::evalset::McItem;
use crate::data::instruct::{instruct_batch, Dataset};
use crate::data::{corpus, World};
use crate::model::{checkpoint, weights::NamedTensors};
use crate::precision::{self, PlannerConfig, PrecisionPlan, ProfileConfig};
use crate::quant::Method;
use crate::runtime::{Manifest, Runtime};
use crate::util::timer::Timer;
use crate::util::Rng;

use super::evaluator::{EvalResult, Evaluator};
use super::pool::{PoolConfig, ServerPool};
use super::quantize::{quantize_model, QuantizedModel};
use super::registry::AdapterRegistry;
use super::trainer::{Finetuner, Pretrainer};

/// A named experiment arm = quantizer + IEC gating + finetune or not.
/// These are exactly the method rows of the paper's tables.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Arm {
    pub name: &'static str,
    pub method: Method,
    /// IEC masks (m1, m2).
    pub masks: (f32, f32),
    pub finetune: bool,
}

impl Arm {
    pub fn fp16() -> Arm {
        Arm { name: "16-bit", method: Method::Fp16, masks: (0.0, 0.0), finetune: false }
    }

    pub fn normalfloat(k: u8) -> Arm {
        Arm { name: "NormalFloat", method: Method::Nf { k }, masks: (0.0, 0.0), finetune: false }
    }

    pub fn qlora(k: u8) -> Arm {
        Arm { name: "QLoRA", method: Method::Nf { k }, masks: (0.0, 0.0), finetune: true }
    }

    pub fn qlora_gptq(k: u8) -> Arm {
        Arm { name: "QLoRA w/ GPTQ", method: Method::Gptq { k }, masks: (0.0, 0.0), finetune: true }
    }

    pub fn qalora(k: u8) -> Arm {
        Arm { name: "QA-LoRA", method: Method::Int { k }, masks: (0.0, 0.0), finetune: true }
    }

    pub fn ir_qlora(k: u8) -> Arm {
        Arm { name: "IR-QLoRA", method: Method::NfIcq { k }, masks: (1.0, 1.0), finetune: true }
    }

    /// Table 4 ablations.
    pub fn icq_only(k: u8) -> Arm {
        Arm { name: "ICQ", method: Method::NfIcq { k }, masks: (0.0, 0.0), finetune: true }
    }

    pub fn iec_only(k: u8) -> Arm {
        Arm { name: "IEC", method: Method::Nf { k }, masks: (1.0, 1.0), finetune: true }
    }

    pub fn iec_u1(k: u8) -> Arm {
        Arm { name: "IEC(U1)", method: Method::Nf { k }, masks: (1.0, 0.0), finetune: true }
    }

    pub fn iec_u2(k: u8) -> Arm {
        Arm { name: "IEC(U2)", method: Method::Nf { k }, masks: (0.0, 1.0), finetune: true }
    }

    /// Table 10 integer-quantizer variants.
    pub fn ir_qlora_int(k: u8) -> Arm {
        Arm {
            name: "IR-QLoRA (QA-LoRA)",
            method: Method::IntIcq { k },
            masks: (1.0, 1.0),
            finetune: true,
        }
    }

    /// ICQ without LoRA / finetuning (Table 5).
    pub fn icq_no_ft(k: u8) -> Arm {
        Arm { name: "ICQ (no FT)", method: Method::NfIcq { k }, masks: (0.0, 0.0), finetune: false }
    }
}

/// Everything a table row needs.
pub struct ArmResult {
    pub arm: Arm,
    pub eval: EvalResult,
    pub mean_entropy: f64,
    pub storage_mb: f64,
    pub quantize_time: Duration,
    pub finetune_time: Duration,
    pub loss_curve: Vec<f32>,
}

/// Experiment-wide knobs (scaled-down defaults keep a full table run
/// in CPU-minutes; `--full` in the CLI raises them).
#[derive(Clone, Debug)]
pub struct RunCfg {
    pub world_seed: u64,
    pub pretrain_steps: usize,
    pub finetune_steps: usize,
    pub eval_per_group: usize,
    pub seed: u64,
    pub cache_dir: PathBuf,
}

impl Default for RunCfg {
    fn default() -> Self {
        RunCfg {
            world_seed: 2024,
            pretrain_steps: 300,
            finetune_steps: 60,
            eval_per_group: 50,
            seed: 7,
            cache_dir: PathBuf::from("runs"),
        }
    }
}

/// Pretrain a base model (or load it from the run cache).
pub fn pretrained_base(
    rt: &Runtime,
    manifest: &Manifest,
    tag: &str,
    cfg: &RunCfg,
) -> Result<NamedTensors> {
    let ckpt = cfg.cache_dir.join(format!(
        "base_{tag}_w{}_s{}_n{}.irqc",
        cfg.world_seed, cfg.seed, cfg.pretrain_steps
    ));
    if ckpt.exists() {
        if let Ok(w) = checkpoint::load(&ckpt) {
            log::info!("loaded cached base {}", ckpt.display());
            return Ok(w);
        }
        log::warn!("cache {} unreadable; re-pretraining", ckpt.display());
    }
    let size = manifest.size(tag)?;
    let world = World::new(cfg.world_seed);
    let mut rng = Rng::new(cfg.seed ^ 0xba5e);
    let mut pre = Pretrainer::new(rt, manifest, tag, cfg.seed)?;
    let t = Timer::start();
    for step in 0..cfg.pretrain_steps {
        let b = corpus::pretrain_batch(&world, &mut rng, size.config.batch, size.config.seq);
        let loss = pre.step(b.tokens, b.targets)?;
        if step % 50 == 0 || step + 1 == cfg.pretrain_steps {
            log::info!("pretrain[{tag}] step {step}: loss {loss:.4}");
        }
    }
    log::info!(
        "pretrained {tag} in {:.1}s (final loss {:.4})",
        t.elapsed_secs(),
        pre.losses.last().copied().unwrap_or(f32::NAN)
    );
    checkpoint::save(&pre.params, &ckpt)
        .with_context(|| format!("caching {}", ckpt.display()))?;
    Ok(pre.params)
}

/// Build a serving registry straight from a [`QuantizedModel`]: the
/// ICQ base was dequantized exactly once by `quantize_model` (fused
/// packed-domain path); that buffer becomes the shared base every
/// registered adapter serves over, with `masks` (the arm's IEC
/// gating) folded into each adapter at merge time. Register the
/// finetuned `lora` tensors of each tenant (e.g. `ArmResult` loras or
/// cached `.irqc` checkpoints) on the returned registry, then hand it
/// to `BatchServer::spawn` — or wrap it in an `Arc` and share it
/// across an N-worker [`ServerPool`] (see [`serve_pool`]). Mixed-k
/// bases (from [`plan_quantized`] / `quantize_model_planned`) serve
/// identically — the base is already dequantized, so nothing
/// downstream sees k.
pub fn serve_registry(qm: &QuantizedModel, masks: (f32, f32)) -> AdapterRegistry {
    AdapterRegistry::new(qm.dequantized.clone(), masks)
}

/// Synthetic serving fixture shared by the offline bench scenarios
/// (`serve_latency`'s reference/pool sweeps) and the
/// `irqlora serve --reference` demo: a tiny three-tensor base with
/// `n_adapters` registered tenants, seeded deterministically. Shapes
/// only matter for merge validity — the `ReferenceBackend` consumes
/// the tensors through fingerprints. Kept in one place so the bench
/// rows and the CLI demo can never silently drift apart.
pub fn synthetic_serve_registry(
    n_adapters: usize,
    seed: u64,
) -> std::sync::Arc<AdapterRegistry> {
    use crate::util::Tensor;
    const VOCAB: usize = 64;
    let mut rng = Rng::new(seed);
    let mut base = NamedTensors::new();
    base.push("embed", Tensor::new(&[VOCAB, 64], rng.normal_vec(VOCAB * 64, 0.0, 0.02)));
    base.push("l0.wq", Tensor::new(&[64, 64], rng.normal_vec(64 * 64, 0.0, 0.02)));
    base.push("lm_head", Tensor::new(&[64, VOCAB], rng.normal_vec(64 * VOCAB, 0.0, 0.02)));
    let registry = std::sync::Arc::new(AdapterRegistry::new(base, (1.0, 1.0)));
    for i in 0..n_adapters {
        let mut a = NamedTensors::new();
        a.push("l0.wq.lora_a", Tensor::new(&[64, 4], rng.normal_vec(64 * 4, 0.0, 0.3)));
        a.push("l0.wq.lora_b", Tensor::new(&[4, 64], rng.normal_vec(4 * 64, 0.0, 0.3)));
        a.push("betas", Tensor::new(&[1, 7, 2], rng.normal_vec(14, 0.0, 0.3)));
        registry
            .register(&format!("tenant{i}"), a)
            .expect("synthetic adapter shapes are valid");
    }
    registry
}

/// [`serve_registry`] scaled out: one shared registry under an
/// N-worker PJRT [`ServerPool`] (each worker owns its runtime,
/// uploads the base once, and keeps a generation-keyed device-buffer
/// LRU of merged adapters; merged weights are computed once in the
/// shared LRU cache). Workers serve each drained batch with one fused
/// mixed-adapter forward and steal parked work from saturated
/// siblings when idle — both defaults of `cfg`
/// (`PoolConfig::{fused, steal}`); `cfg.serial()` / `cfg.no_steal()`
/// pin the pre-fusion per-group path and the legacy push-spill
/// scheduler. Returns the registry alongside the pool so callers can
/// register/evict adapters while it serves. This is the engine behind
/// `irqlora serve --workers N [--no-fused] [--no-steal]`.
pub fn serve_pool(
    manifest: Manifest,
    tag: &str,
    qm: &QuantizedModel,
    masks: (f32, f32),
    cfg: PoolConfig,
) -> Result<(std::sync::Arc<AdapterRegistry>, ServerPool)> {
    let registry = std::sync::Arc::new(serve_registry(qm, masks));
    let pool = ServerPool::spawn(manifest, tag, cfg, registry.clone())?;
    Ok((registry, pool))
}

/// [`serve_pool`]'s HAL-routed sibling: spawn an N-worker pool over a
/// NAMED backend (`reference`, `native`, `pjrt`, …) resolved through
/// [`crate::hal::BackendRegistry::builtin`]. The (manifest, request,
/// pool config) combination is validated BEFORE any worker spawns —
/// an unknown name, failed gate, or unsupported shape comes back as
/// a typed [`crate::hal::HalError`] here, not as a dead worker
/// mid-drain. This is the engine behind `irqlora serve --backend
/// NAME` and the cross-backend test batteries.
pub fn serve_pool_backend(
    backend: &str,
    shape: (usize, usize, usize),
    cfg: PoolConfig,
    registry: std::sync::Arc<AdapterRegistry>,
) -> Result<ServerPool> {
    let (batch, seq, vocab) = shape;
    let mut req = crate::hal::BackendRequest::new(batch, seq, vocab);
    req.workers = cfg.workers;
    let hal = crate::hal::BackendRegistry::builtin();
    let factory = hal.pool_factory(backend, &req, registry.base().clone(), "serve")?;
    ServerPool::spawn_with(cfg, registry, factory)
}

/// Plan + quantize a base under a storage budget: profile every
/// projection's ICQ entropy across the candidate bit-widths, solve
/// the greedy information-per-bit allocation, and quantize mixed-k
/// (the `plan` CLI verb's engine). The returned model drops into
/// [`serve_registry`] / `Evaluator::from_quantized` exactly like a
/// uniform-k one and carries its plan for `.irqc` persistence
/// (`checkpoint::save_with_plan`).
pub fn plan_quantized(
    base: &NamedTensors,
    cfg: &PlannerConfig,
) -> Result<(PrecisionPlan, QuantizedModel)> {
    precision::plan_and_quantize(base, &ProfileConfig::default(), cfg)
}

/// Run one arm end to end against a given base; returns the table row.
pub fn run_arm(
    rt: &Runtime,
    manifest: &Manifest,
    tag: &str,
    base: &NamedTensors,
    arm: Arm,
    dataset: Dataset,
    eval_items: &[McItem],
    cfg: &RunCfg,
) -> Result<ArmResult> {
    let world = World::new(cfg.world_seed);
    let qm: QuantizedModel = quantize_model(base, arm.method, cfg.seed)?;
    let mean_entropy = qm.mean_entropy();
    let storage_mb = qm.storage_mb();
    let quantize_time = qm.elapsed;
    log::info!(
        "[{}] quantized in {:?} (entropy {:.3}, {:.2} MB)",
        arm.name, quantize_time, mean_entropy, storage_mb
    );

    let size = manifest.size(tag)?;
    let ft_timer = Timer::start();
    let (lora, losses) = if arm.finetune {
        let mut rng = Rng::new(cfg.seed ^ 0xf17e);
        let mut ft = Finetuner::new(rt, manifest, tag, &qm.dequantized, arm.masks, cfg.seed)?;
        for step in 0..cfg.finetune_steps {
            let b = instruct_batch(&world, dataset, &mut rng, size.config.batch, size.config.seq);
            let loss = ft.step(b.tokens, b.targets)?;
            if step % 20 == 0 || step + 1 == cfg.finetune_steps {
                log::info!("finetune[{}] step {step}: loss {loss:.4}", arm.name);
            }
        }
        (ft.lora, ft.losses)
    } else {
        // zero-initialized adapter == identity (l2 = 0, beta = 0)
        let spec = manifest.graph(tag, "train_step")?;
        let nb = qm.dequantized.len();
        let nl = super::trainer::train_layout(spec.inputs.len(), nb)?;
        let mut rng = Rng::new(cfg.seed ^ 0xf17e);
        let lora = crate::model::weights::init_lora(
            &spec.inputs[nb..nb + nl],
            size.config.rank,
            &mut rng,
        );
        (lora, Vec::new())
    };
    let finetune_time = ft_timer.elapsed();

    let ev = Evaluator::from_quantized(rt, manifest, tag, &qm, &lora, arm.masks)?;
    let eval = ev.evaluate(eval_items)?;
    log::info!("[{}] avg accuracy {:.1}%", arm.name, eval.avg_accuracy() * 100.0);

    Ok(ArmResult {
        arm,
        eval,
        mean_entropy,
        storage_mb,
        quantize_time,
        finetune_time,
        loss_curve: losses,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arm_constructors() {
        assert_eq!(Arm::ir_qlora(4).masks, (1.0, 1.0));
        assert!(Arm::ir_qlora(4).method.uses_icq());
        assert!(!Arm::qlora(4).method.uses_icq());
        assert!(!Arm::normalfloat(4).finetune);
        assert_eq!(Arm::iec_u1(4).masks, (1.0, 0.0));
        assert_eq!(Arm::iec_u2(4).masks, (0.0, 1.0));
        assert_eq!(Arm::qalora(2).method.bits(), 2);
    }

    #[test]
    fn run_cfg_defaults() {
        let c = RunCfg::default();
        assert!(c.pretrain_steps > 0 && c.finetune_steps > 0);
    }

    #[test]
    fn plan_quantized_serves_like_uniform() {
        let base = crate::precision::synthetic_model(1, 32, 13);
        let (plan, qm) = plan_quantized(&base, &PlannerConfig::new(3.2)).unwrap();
        assert!(plan.is_mixed());
        // the mixed-k model drops into the registry unchanged
        let reg = serve_registry(&qm, (1.0, 1.0));
        assert_eq!(
            reg.base().get("l0.wq").unwrap(),
            qm.dequantized.get("l0.wq").unwrap()
        );
    }

    #[test]
    fn serve_registry_shares_dequantized_base() {
        use crate::runtime::{Dtype, InputSpec};
        use crate::util::Tensor;

        let specs = vec![
            InputSpec { name: "embed".into(), shape: vec![16, 32], dtype: Dtype::F32 },
            InputSpec { name: "l0.wq".into(), shape: vec![32, 64], dtype: Dtype::F32 },
            InputSpec { name: "lm_head".into(), shape: vec![32, 16], dtype: Dtype::F32 },
        ];
        let mut rng = Rng::new(9);
        let base = crate::model::weights::init_base(&specs, 1, &mut rng);
        let qm = quantize_model(&base, Method::NfIcq { k: 4 }, 0).unwrap();

        let reg = serve_registry(&qm, (1.0, 1.0));
        assert_eq!(reg.masks(), (1.0, 1.0));
        // the registry's base IS the once-dequantized ICQ output
        assert_eq!(
            reg.base().get("l0.wq").unwrap(),
            qm.dequantized.get("l0.wq").unwrap()
        );

        let mut adapter = NamedTensors::new();
        adapter.push("l0.wq.lora_a", Tensor::new(&[32, 4], rng.normal_vec(128, 0.0, 0.3)));
        adapter.push("l0.wq.lora_b", Tensor::new(&[4, 64], rng.normal_vec(256, 0.0, 0.3)));
        adapter.push("betas", Tensor::new(&[1, 7, 2], rng.normal_vec(14, 0.0, 0.5)));
        reg.register("tenant", adapter).unwrap();
        let merged = reg.merged("tenant").unwrap();
        assert!(merged.get("betas").unwrap().data().iter().all(|&x| x == 0.0));
    }
}
