//! Batched inference server (the serving-path L3 component).
//!
//! Requests (token prompts) arrive on a channel; a worker thread
//! drains up to `batch` of them (waiting at most `max_wait` after the
//! first), pads them into one fixed-shape forward call, and replies
//! with the next-token logits per request. This is the dynamic-batching
//! structure of vLLM-style routers reduced to the single-model,
//! single-device case this paper needs.

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::data::PAD;
use crate::model::weights::NamedTensors;
use crate::runtime::{Manifest, Runtime};

/// One inference reply.
#[derive(Clone, Debug)]
pub struct Reply {
    /// Next-token logits at the last prompt position.
    pub logits: Vec<f32>,
    /// Time spent queued before its batch launched.
    pub queued: Duration,
    /// Total request latency.
    pub latency: Duration,
    /// How many requests shared the batch.
    pub batch_size: usize,
}

struct Request {
    tokens: Vec<i32>,
    enqueued: Instant,
    reply: SyncSender<Result<Reply, String>>,
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub requests: usize,
    pub batches: usize,
    pub batch_occupancy_sum: usize,
}

impl ServerStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_occupancy_sum as f64 / self.batches as f64
        }
    }
}

/// Handle to a running batch server.
pub struct BatchServer {
    tx: Option<SyncSender<Request>>,
    handle: Option<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<ServerStats>>,
    seq: usize,
}

/// Server configuration.
pub struct ServerConfig {
    pub tag: String,
    /// IEC masks for the forward graph.
    pub masks: (f32, f32),
    /// Max time the batcher waits to fill a batch after the first
    /// request arrives.
    pub max_wait: Duration,
}

impl BatchServer {
    /// Spawn the worker (it owns its own PJRT runtime + executor).
    pub fn spawn(
        manifest: Manifest,
        cfg: ServerConfig,
        base: NamedTensors,
        lora: NamedTensors,
    ) -> Result<BatchServer> {
        let size = manifest.size(&cfg.tag)?;
        let (seq, batch, vocab) = (size.config.seq, size.config.batch, size.config.vocab);
        let spec = manifest.graph(&cfg.tag, "forward")?.clone();
        let (tx, rx) = sync_channel::<Request>(1024);
        let stats = Arc::new(Mutex::new(ServerStats::default()));
        let stats_w = stats.clone();

        let (ready_tx, ready_rx) = sync_channel::<Result<(), String>>(1);
        let handle = std::thread::spawn(move || {
            let init = (|| -> Result<_> {
                let rt = Runtime::cpu()?;
                let exe_rt: &'static Runtime = Box::leak(Box::new(rt));
                let exe = exe_rt.load(&spec)?;
                let mut fixed = Vec::new();
                let mut slot = 0usize;
                for nt in [&base, &lora] {
                    for t in nt.tensors() {
                        // zero-copy upload: no per-tensor host clone
                        fixed.push(exe.upload_f32(slot, t.data())?);
                        slot += 1;
                    }
                }
                fixed.push(exe.upload_f32(slot, &[cfg.masks.0])?);
                fixed.push(exe.upload_f32(slot + 1, &[cfg.masks.1])?);
                Ok((exe, fixed))
            })();
            let (exe, fixed) = match init {
                Ok(v) => {
                    let _ = ready_tx.send(Ok(()));
                    v
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };

            loop {
                // block for the first request
                let first = match rx.recv() {
                    Ok(r) => r,
                    Err(_) => break, // all senders dropped: shut down
                };
                let mut pending = vec![first];
                let deadline = Instant::now() + cfg.max_wait;
                while pending.len() < batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => pending.push(r),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }

                let bsz = pending.len();
                let launch = Instant::now();
                let mut tokens = vec![PAD; batch * seq];
                let mut positions = Vec::with_capacity(bsz);
                let mut bad: Vec<Option<String>> = vec![None; bsz];
                for (i, r) in pending.iter().enumerate() {
                    if r.tokens.is_empty() || r.tokens.len() > seq {
                        bad[i] = Some(format!(
                            "prompt length {} out of range 1..={seq}",
                            r.tokens.len()
                        ));
                        positions.push(0);
                        continue;
                    }
                    tokens[i * seq..i * seq + r.tokens.len()].copy_from_slice(&r.tokens);
                    positions.push(r.tokens.len() - 1);
                }

                let result = (|| -> Result<Vec<f32>> {
                    // borrowed upload: no per-batch token clone
                    let tok = exe.upload_i32(fixed.len(), &tokens)?;
                    let mut all: Vec<&xla::PjRtBuffer> = fixed.iter().collect();
                    all.push(&tok);
                    let outs = exe.execute(&all)?;
                    Ok(outs[0].as_f32()?.to_vec())
                })();

                {
                    let mut s = stats_w.lock().unwrap();
                    s.requests += bsz;
                    s.batches += 1;
                    s.batch_occupancy_sum += bsz;
                }

                match result {
                    Ok(logits) => {
                        for (i, r) in pending.into_iter().enumerate() {
                            let resp = if let Some(msg) = bad[i].take() {
                                Err(msg)
                            } else {
                                let off = (i * seq + positions[i]) * vocab;
                                Ok(Reply {
                                    logits: logits[off..off + vocab].to_vec(),
                                    queued: launch - r.enqueued,
                                    latency: r.enqueued.elapsed(),
                                    batch_size: bsz,
                                })
                            };
                            let _ = r.reply.send(resp);
                        }
                    }
                    Err(e) => {
                        let msg = format!("{e:#}");
                        for r in pending {
                            let _ = r.reply.send(Err(msg.clone()));
                        }
                    }
                }
            }
        });

        ready_rx
            .recv()
            .context("server worker died during init")?
            .map_err(|e| anyhow!("server init failed: {e}"))?;

        Ok(BatchServer { tx: Some(tx), handle: Some(handle), stats, seq })
    }

    pub fn max_prompt_len(&self) -> usize {
        self.seq
    }

    /// Submit a prompt; returns a receiver for the reply.
    pub fn submit(&self, tokens: Vec<i32>) -> Result<Receiver<Result<Reply, String>>> {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.tx
            .as_ref()
            .context("server shut down")?
            .send(Request { tokens, enqueued: Instant::now(), reply: reply_tx })
            .map_err(|_| anyhow!("server worker exited"))?;
        Ok(reply_rx)
    }

    /// Submit and wait.
    pub fn query(&self, tokens: Vec<i32>) -> Result<Reply> {
        let rx = self.submit(tokens)?;
        match rx.recv().context("server dropped reply")? {
            Ok(r) => Ok(r),
            Err(e) => bail!("request failed: {e}"),
        }
    }

    pub fn stats(&self) -> ServerStats {
        self.stats.lock().unwrap().clone()
    }

    /// Graceful shutdown (drains in-flight work).
    pub fn shutdown(mut self) {
        self.tx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for BatchServer {
    fn drop(&mut self) {
        self.tx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let s = ServerStats { requests: 10, batches: 4, batch_occupancy_sum: 10 };
        assert!((s.mean_batch_size() - 2.5).abs() < 1e-12);
        assert_eq!(ServerStats::default().mean_batch_size(), 0.0);
    }
}
