//! Multi-adapter batched inference server (the serving-path L3
//! component).
//!
//! Requests (adapter id + token prompt) arrive on a channel; a worker
//! thread drains up to `batch` of them (waiting at most `max_wait`
//! after the first), groups them by adapter, pads each group into one
//! fixed-shape forward call, and replies with the next-token logits
//! per request. One worker serves many adapters over one *shared*
//! base: the expensive artifact (the dequantized ICQ-quantized base)
//! exists once per worker, uploaded once by the backend, while
//! adapters are cheap per-tenant state routed through an
//! [`AdapterRegistry`] (merged on demand, LRU-cached). This is the
//! dynamic-batching structure of vLLM-style multi-LoRA routers
//! reduced to the single-device case this paper needs.
//!
//! Malformed prompts (empty / over-length) and unknown adapters are
//! rejected at [`BatchServer::submit`] time — a bad request never
//! occupies a batch slot, so no all-PAD row ever runs through the
//! forward pass.
//!
//! The worker owns its execution backend (for PJRT: an
//! `OwnedExecutor` holding the runtime by `Arc`), so spawning N
//! servers no longer leaks N runtimes.

use std::collections::BTreeMap;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::data::PAD;
use crate::runtime::Manifest;

use super::backend::{PjrtBackend, ServeBackend};
use super::registry::AdapterRegistry;

/// One inference reply.
#[derive(Clone, Debug)]
pub struct Reply {
    /// Adapter that served the request.
    pub adapter: String,
    /// Next-token logits at the last prompt position.
    pub logits: Vec<f32>,
    /// Time spent queued before its batch launched.
    pub queued: Duration,
    /// Total request latency.
    pub latency: Duration,
    /// How many requests shared the forward call (all same-adapter).
    pub batch_size: usize,
}

struct Request {
    adapter: String,
    tokens: Vec<i32>,
    enqueued: Instant,
    reply: SyncSender<Result<Reply, String>>,
}

/// Per-adapter serving counters.
#[derive(Clone, Debug, Default)]
pub struct AdapterServeStats {
    pub requests: usize,
    /// Forward calls run for this adapter.
    pub batches: usize,
    pub occupancy_sum: usize,
}

impl AdapterServeStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.occupancy_sum as f64 / self.batches as f64
        }
    }
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub requests: usize,
    /// Total forward calls (one per same-adapter group).
    pub batches: usize,
    pub batch_occupancy_sum: usize,
    /// Requests rejected at submit time (malformed prompt / unknown
    /// adapter); they never occupied a batch slot.
    pub rejected: usize,
    /// Per-adapter occupancy breakdown.
    pub per_adapter: BTreeMap<String, AdapterServeStats>,
}

impl ServerStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_occupancy_sum as f64 / self.batches as f64
        }
    }
}

/// Server configuration.
pub struct ServerConfig {
    /// Max time the batcher waits to fill a batch after the first
    /// request arrives.
    pub max_wait: Duration,
}

/// Why a submission did not enqueue — split so routing layers
/// ([`super::pool::ServerPool`]) can tell a bad *request* (propagate
/// to the caller) from a bad *worker* (mark it dead and reroute).
#[derive(Debug)]
pub enum SubmitError {
    /// Malformed prompt or unknown adapter. Counted in
    /// [`ServerStats::rejected`]; resubmitting elsewhere is pointless.
    Rejected(anyhow::Error),
    /// The worker thread is gone (panicked backend or shut down); the
    /// request never reached a queue. The prompt tokens are handed
    /// back so the caller can reroute without a clone.
    WorkerGone(Vec<i32>),
}

/// Handle to a running batch server.
pub struct BatchServer {
    tx: Option<SyncSender<Request>>,
    handle: Option<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<ServerStats>>,
    registry: Arc<AdapterRegistry>,
    batch: usize,
    seq: usize,
    vocab: usize,
}

impl BatchServer {
    /// Spawn a PJRT-backed worker over the manifest's `forward` graph
    /// for `tag`. The worker owns its runtime (dropped with the
    /// worker — nothing leaks) and shares one uploaded base across
    /// every adapter in `registry`.
    pub fn spawn(
        manifest: Manifest,
        tag: &str,
        cfg: ServerConfig,
        registry: Arc<AdapterRegistry>,
    ) -> Result<BatchServer> {
        let tag = tag.to_string();
        let reg = registry.clone();
        Self::spawn_with(cfg, registry, move || {
            Ok(Box::new(PjrtBackend::new(&manifest, &tag, reg.base())?)
                as Box<dyn ServeBackend>)
        })
    }

    /// Spawn over an explicit backend factory. The factory runs on the
    /// worker thread, so the backend may own thread-bound resources
    /// (the PJRT runtime, device buffers). Tests and the offline bench
    /// smoke pass a [`super::backend::ReferenceBackend`] here.
    pub fn spawn_with<F>(
        cfg: ServerConfig,
        registry: Arc<AdapterRegistry>,
        make_backend: F,
    ) -> Result<BatchServer>
    where
        F: FnOnce() -> Result<Box<dyn ServeBackend>> + Send + 'static,
    {
        let (tx, rx) = sync_channel::<Request>(1024);
        let stats = Arc::new(Mutex::new(ServerStats::default()));
        let stats_w = stats.clone();
        let registry_w = registry.clone();

        let (ready_tx, ready_rx) = sync_channel::<Result<(usize, usize, usize), String>>(1);
        let handle = std::thread::spawn(move || {
            let mut backend = match make_backend() {
                Ok(b) => {
                    let _ = ready_tx.send(Ok(b.shape()));
                    b
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            let (batch, _, _) = backend.shape();
            let mut tok_scratch: Vec<i32> = Vec::new();

            loop {
                // block for the first request
                let first = match rx.recv() {
                    Ok(r) => r,
                    Err(_) => break, // all senders dropped: shut down
                };
                let mut pending = vec![first];
                let deadline = Instant::now() + cfg.max_wait;
                while pending.len() < batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => pending.push(r),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }

                // group by adapter, preserving first-arrival order; each
                // group runs as its own forward call so replies can never
                // read another adapter's logits
                let mut groups: Vec<(String, Vec<Request>)> = Vec::new();
                for r in pending {
                    match groups.iter().position(|(a, _)| *a == r.adapter) {
                        Some(i) => groups[i].1.push(r),
                        None => groups.push((r.adapter.clone(), vec![r])),
                    }
                }
                for (adapter, group) in groups {
                    run_group(
                        backend.as_mut(),
                        &registry_w,
                        &stats_w,
                        &adapter,
                        group,
                        &mut tok_scratch,
                    );
                }
            }
        });

        let (batch, seq, vocab) = ready_rx
            .recv()
            .context("server worker died during init")?
            .map_err(|e| anyhow!("server init failed: {e}"))?;

        Ok(BatchServer { tx: Some(tx), handle: Some(handle), stats, registry, batch, seq, vocab })
    }

    /// Largest prompt (in tokens) the server accepts.
    pub fn max_prompt_len(&self) -> usize {
        self.seq
    }

    /// Max requests one forward call can carry (the backend's batch
    /// dimension). Routing layers size their spill thresholds off it.
    pub fn max_batch(&self) -> usize {
        self.batch
    }

    /// Logit width of every reply.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// The registry this server routes through (register/evict
    /// adapters on it while the server runs).
    pub fn registry(&self) -> &Arc<AdapterRegistry> {
        &self.registry
    }

    /// Submit a prompt for `adapter`; returns a receiver for the
    /// reply. Empty / over-length prompts and unknown adapters are
    /// rejected here, before they can occupy a batch slot.
    pub fn submit(
        &self,
        adapter: &str,
        tokens: Vec<i32>,
    ) -> Result<Receiver<Result<Reply, String>>> {
        match self.try_submit(adapter, tokens) {
            Ok(rx) => Ok(rx),
            Err(SubmitError::Rejected(e)) => Err(e),
            Err(SubmitError::WorkerGone(_)) => Err(anyhow!("server worker exited")),
        }
    }

    /// [`Self::submit`] with the failure mode split for routing layers:
    /// request problems come back as [`SubmitError::Rejected`] (and are
    /// counted in [`ServerStats::rejected`]), a dead worker comes back
    /// as [`SubmitError::WorkerGone`] with the tokens returned so the
    /// caller can reroute them to another worker.
    pub fn try_submit(
        &self,
        adapter: &str,
        tokens: Vec<i32>,
    ) -> Result<Receiver<Result<Reply, String>>, SubmitError> {
        if tokens.is_empty() || tokens.len() > self.seq {
            self.stats.lock().unwrap().rejected += 1;
            return Err(SubmitError::Rejected(anyhow!(
                "prompt length {} out of range 1..={}",
                tokens.len(),
                self.seq
            )));
        }
        if !self.registry.contains(adapter) {
            self.stats.lock().unwrap().rejected += 1;
            return Err(SubmitError::Rejected(anyhow!(
                "unknown adapter '{adapter}' (registered: {:?})",
                self.registry.names()
            )));
        }
        let Some(tx) = self.tx.as_ref() else {
            return Err(SubmitError::WorkerGone(tokens));
        };
        let (reply_tx, reply_rx) = sync_channel(1);
        match tx.send(Request {
            adapter: adapter.to_string(),
            tokens,
            enqueued: Instant::now(),
            reply: reply_tx,
        }) {
            Ok(()) => Ok(reply_rx),
            Err(std::sync::mpsc::SendError(req)) => Err(SubmitError::WorkerGone(req.tokens)),
        }
    }

    /// Submit and wait.
    pub fn query(&self, adapter: &str, tokens: Vec<i32>) -> Result<Reply> {
        let rx = self.submit(adapter, tokens)?;
        match rx.recv().context("server dropped reply")? {
            Ok(r) => Ok(r),
            Err(e) => bail!("request failed: {e}"),
        }
    }

    pub fn stats(&self) -> ServerStats {
        self.stats.lock().unwrap().clone()
    }

    /// Graceful shutdown: already-submitted requests drain first
    /// (every in-flight receiver still gets its reply), then the
    /// worker exits and its backend (runtime included) drops.
    pub fn shutdown(mut self) {
        self.tx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for BatchServer {
    fn drop(&mut self) {
        self.tx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Pad one same-adapter group into a single forward call and deliver
/// per-request replies (or the shared error).
fn run_group(
    backend: &mut dyn ServeBackend,
    registry: &AdapterRegistry,
    stats: &Mutex<ServerStats>,
    adapter: &str,
    group: Vec<Request>,
    tok_scratch: &mut Vec<i32>,
) {
    let (batch, seq, vocab) = backend.shape();
    debug_assert!(group.len() <= batch);
    let bsz = group.len();
    let launch = Instant::now();

    // prompts were validated at submit time: 1..=seq tokens each
    tok_scratch.clear();
    tok_scratch.resize(batch * seq, PAD);
    let mut positions = Vec::with_capacity(bsz);
    for (i, r) in group.iter().enumerate() {
        tok_scratch[i * seq..i * seq + r.tokens.len()].copy_from_slice(&r.tokens);
        positions.push(r.tokens.len() - 1);
    }

    let result = registry.merged_tagged(adapter).and_then(|(generation, w)| {
        backend.forward(adapter, generation, &w, tok_scratch.as_slice())
    });

    {
        let mut s = stats.lock().unwrap();
        s.requests += bsz;
        s.batches += 1;
        s.batch_occupancy_sum += bsz;
        let a = s.per_adapter.entry(adapter.to_string()).or_default();
        a.requests += bsz;
        a.batches += 1;
        a.occupancy_sum += bsz;
    }

    match result {
        Ok(logits) => {
            for (i, r) in group.into_iter().enumerate() {
                let off = (i * seq + positions[i]) * vocab;
                let resp = if off + vocab <= logits.len() {
                    Ok(Reply {
                        adapter: adapter.to_string(),
                        logits: logits[off..off + vocab].to_vec(),
                        queued: launch - r.enqueued,
                        latency: r.enqueued.elapsed(),
                        batch_size: bsz,
                    })
                } else {
                    Err(format!(
                        "backend returned {} logits, need at least {}",
                        logits.len(),
                        off + vocab
                    ))
                };
                let _ = r.reply.send(resp);
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for r in group {
                let _ = r.reply.send(Err(msg.clone()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let s = ServerStats {
            requests: 10,
            batches: 4,
            batch_occupancy_sum: 10,
            ..ServerStats::default()
        };
        assert!((s.mean_batch_size() - 2.5).abs() < 1e-12);
        assert_eq!(ServerStats::default().mean_batch_size(), 0.0);

        let a = AdapterServeStats { requests: 6, batches: 3, occupancy_sum: 6 };
        assert!((a.mean_batch_size() - 2.0).abs() < 1e-12);
        assert_eq!(AdapterServeStats::default().mean_batch_size(), 0.0);
    }
}
