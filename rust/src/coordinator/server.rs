//! Multi-adapter batched inference server (the serving-path L3
//! component).
//!
//! Requests (adapter id + token prompt) arrive on a channel; a worker
//! thread drains up to `batch` of them (waiting at most `max_wait`
//! after the first), slot-packs the drained set into ONE padded
//! fixed-shape **fused** forward call — even when the batch spans
//! several adapters ([`fused_slot_plan`] gives each adapter a
//! contiguous row span, `ServeBackend::forward_fused` runs it) — and
//! replies with the next-token logits per request. The pre-fusion
//! one-forward-per-adapter-group path is kept in-tree
//! ([`ServerConfig::serial`]) as the bit-identity oracle the tests and
//! the paired `[per-group serial]` bench rows compare against.
//!
//! One worker serves many adapters over one *shared* base: the
//! expensive artifact (the dequantized ICQ-quantized base) exists once
//! per worker, uploaded once by the backend, while adapters are cheap
//! per-tenant state routed through an [`AdapterRegistry`] (merged on
//! demand, LRU-cached; the backend keeps its own device-side adapter
//! cache keyed by `(name, generation)`).
//!
//! Malformed prompts (empty / over-length) and unknown adapters are
//! rejected at [`BatchServer::submit`] time — a bad request never
//! occupies a batch slot, so no all-PAD row ever runs through the
//! forward pass.
//!
//! The worker owns its execution backend (for PJRT: an
//! `OwnedExecutor` holding the runtime by `Arc`), so spawning N
//! servers no longer leaks N runtimes. A routing layer
//! ([`super::pool::ServerPool`]) may additionally install a *feeder* —
//! a pull-source of parked requests the worker polls when its own
//! channel runs dry (own overflow first, work stolen from a saturated
//! sibling when idle) and tops spare batch slots from after a drain.
//! Each drain additionally starts by asking the feeder for *aged*
//! parked requests ([`FeedPass::Aged`]) — a request parked behind a
//! saturated home is promoted ahead of fresh channel arrivals once it
//! has waited `IRQLORA_PARK_AGE_MS`, so a home that never goes idle
//! can no longer starve its overflow.
//!
//! Failures travel the reply channel as typed
//! [`ServeError`](super::error::ServeError) values (not strings):
//! submit-time validation yields `Rejected`, an expired per-request
//! deadline sheds with `DeadlineExceeded` before any forward runs
//! (counted in [`ServerStats::shed_deadline`]), and forward/merge
//! failures arrive as `BackendFault`/`Rejected` — so callers can
//! tell retryable from fatal without parsing messages.

use std::collections::BTreeMap;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::data::PAD;
use crate::runtime::Manifest;

use super::backend::{AdapterGroup, PjrtBackend, ServeBackend, UploadStats};
use super::error::ServeError;
use super::registry::AdapterRegistry;
use crate::telemetry;

/// Telemetry counter handles for the serving hot path, resolved ONCE
/// at spawn (resolution takes the registry mutex; recording is a
/// branch + relaxed atomic) and cloned into each worker. Every handle
/// is a no-op when the resolving registry is disabled — the default
/// unless `IRQLORA_TELEMETRY=1` or a test injects a scoped registry
/// via `PoolConfig.telemetry`.
///
/// These counters are incremented at the SAME mutation sites as the
/// [`ServerStats`] fields of the same name, so the struct view and
/// the telemetry view reconcile exactly by construction (asserted per
/// seed by the chaos-soak battery).
#[derive(Clone)]
pub(crate) struct ServeTelem {
    reg: Arc<telemetry::Registry>,
    pub(crate) requests: telemetry::Counter,
    pub(crate) batches: telemetry::Counter,
    pub(crate) fused_batches: telemetry::Counter,
    pub(crate) fused_rows: telemetry::Counter,
    pub(crate) fused_adapters: telemetry::Counter,
    pub(crate) rejected: telemetry::Counter,
    pub(crate) shed_deadline: telemetry::Counter,
    /// Deltas of the backend's monotonic [`UploadStats`], mirrored
    /// each time a worker snapshots them into `ServerStats.upload`.
    pub(crate) upload_hits: telemetry::Counter,
    pub(crate) upload_misses: telemetry::Counter,
}

impl ServeTelem {
    pub(crate) fn resolve(reg: &Arc<telemetry::Registry>) -> ServeTelem {
        ServeTelem {
            reg: reg.clone(),
            requests: reg.counter("serve.requests", &[]),
            batches: reg.counter("serve.batches", &[]),
            fused_batches: reg.counter("serve.fused_batches", &[]),
            fused_rows: reg.counter("serve.fused_rows", &[]),
            fused_adapters: reg.counter("serve.fused_adapters", &[]),
            rejected: reg.counter("serve.rejected", &[]),
            shed_deadline: reg.counter("serve.shed_deadline", &[]),
            upload_hits: reg.counter("serve.upload", &[("event", "hit")]),
            upload_misses: reg.counter("serve.upload", &[("event", "miss")]),
        }
    }

    /// Per-adapter request counter — resolved per drain (cold-ish:
    /// once per batch, not per request; instant no-op when disabled).
    pub(crate) fn adapter_requests(&self, adapter: &str) -> telemetry::Counter {
        self.reg.counter("serve.adapter_requests", &[("adapter", adapter)])
    }

    /// Mirror a fresh monotonic upload snapshot against the previous
    /// one, crediting the deltas to the hit/miss counters.
    pub(crate) fn upload_delta(&self, prev: UploadStats, now: UploadStats) {
        self.upload_hits.add(now.hits.saturating_sub(prev.hits) as u64);
        self.upload_misses.add(now.misses.saturating_sub(prev.misses) as u64);
    }
}

/// One inference reply.
#[derive(Clone, Debug)]
pub struct Reply {
    /// Adapter that served the request.
    pub adapter: String,
    /// Next-token logits at the last prompt position.
    pub logits: Vec<f32>,
    /// Time spent queued before its batch launched.
    pub queued: Duration,
    /// Total request latency.
    pub latency: Duration,
    /// How many requests shared the forward call (fused batches may
    /// span several adapters; serial-oracle batches are same-adapter).
    pub batch_size: usize,
}

/// One queued request. `pub(crate)` so the pool's overflow/steal layer
/// can park fully-formed requests and hand them back to a worker
/// through its feeder.
pub(crate) struct Request {
    pub(crate) adapter: String,
    pub(crate) tokens: Vec<i32>,
    pub(crate) enqueued: Instant,
    /// Shed (with `ServeError::DeadlineExceeded`) instead of served if
    /// still queued past this instant. `None`: wait forever.
    pub(crate) deadline: Option<Instant>,
    pub(crate) reply: SyncSender<Result<Reply, ServeError>>,
}

impl Request {
    /// Has this request's deadline passed?
    pub(crate) fn expired(&self, now: Instant) -> bool {
        self.deadline.map_or(false, |d| now >= d)
    }

    /// Consume the request, answering it with the deadline-shed error.
    pub(crate) fn shed_expired(self) {
        let _ = self
            .reply
            .send(Err(ServeError::DeadlineExceeded { waited: self.enqueued.elapsed() }));
    }
}

/// Which parked requests a [`Feeder`] poll may return.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum FeedPass {
    /// Only requests parked longer than the aging threshold
    /// (`IRQLORA_PARK_AGE_MS`) — polled at the START of each drain, so
    /// aged parked work is promoted ahead of fresh channel arrivals.
    Aged,
    /// Any parked request (own overflow first, then stolen) — polled
    /// when the channel runs dry and to top spare batch slots.
    Any,
}

/// Pull-source of extra requests for a worker, installed by a routing
/// layer. `feeder(pass, max)` returns at most `max` requests — the
/// worker's own parked overflow first, then (when that is empty) work
/// stolen from a saturated or dead sibling, so any worker with spare
/// batch slots rescues parked requests instead of letting them starve
/// behind a busy or dead home. The [`FeedPass::Aged`] pass restricts
/// the pull to requests past the aging threshold (promotion).
pub(crate) type Feeder = Box<dyn FnMut(FeedPass, usize) -> Vec<Request> + Send>;

/// Invoked exactly once when the worker thread exits; the argument is
/// whether the thread was PANICKING (a backend fault) as opposed to a
/// normal shutdown drain or a failed init. Routing layers use it to
/// mark the worker dead proactively — without it, a worker that dies
/// while serving only parked/stolen requests would never be observed
/// dead by any submit or direct reply.
pub(crate) type ExitHook = Box<dyn FnOnce(bool) + Send>;

/// Drop guard that fires the [`ExitHook`] however the worker thread
/// ends (return or unwind).
struct ExitGuard(Option<ExitHook>);

impl Drop for ExitGuard {
    fn drop(&mut self) {
        if let Some(hook) = self.0.take() {
            hook(std::thread::panicking());
        }
    }
}

/// Idle-poll bounds for a worker with a feeder installed: it re-polls
/// the feeder between channel receives, starting at the floor and
/// backing off exponentially to the ceiling while nothing arrives (a
/// fully idle pool wakes each worker ~60×/s instead of 1000×/s; any
/// work resets the backoff, so steal latency under load stays at the
/// floor). Workers without a feeder block on their channel as before.
const IDLE_POLL_MIN: Duration = Duration::from_millis(1);
const IDLE_POLL_MAX: Duration = Duration::from_millis(16);

/// Per-adapter serving counters.
#[derive(Clone, Debug, Default)]
pub struct AdapterServeStats {
    pub requests: usize,
    /// Forward calls this adapter rode in (fused calls count once per
    /// participating adapter).
    pub batches: usize,
    pub occupancy_sum: usize,
}

impl AdapterServeStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.occupancy_sum as f64 / self.batches as f64
        }
    }
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub requests: usize,
    /// Total forward calls (fused mode: one per drained batch; serial
    /// oracle mode: one per same-adapter group).
    pub batches: usize,
    pub batch_occupancy_sum: usize,
    /// Fused forward calls (always 0 in serial oracle mode).
    pub fused_batches: usize,
    /// Rows served by fused forwards (occupancy of the fused calls).
    pub fused_rows: usize,
    /// Distinct adapters summed over fused calls (`/ fused_batches` =
    /// mean adapters per fused forward).
    pub fused_adapters: usize,
    /// Requests rejected at submit time (malformed prompt / unknown
    /// adapter); they never occupied a batch slot.
    pub rejected: usize,
    /// Requests shed with `DeadlineExceeded` by this worker — expired
    /// at submit time or in the drain before their forward launched.
    /// (Requests shed while parked are counted by the pool's overflow
    /// layer, not here.) Shed work never runs.
    pub shed_deadline: usize,
    /// Backend adapter-cache counters (device-buffer uploads for PJRT,
    /// fingerprint recomputes for the reference backend), snapshotted
    /// after each forward.
    pub upload: UploadStats,
    /// Per-adapter occupancy breakdown.
    pub per_adapter: BTreeMap<String, AdapterServeStats>,
}

impl ServerStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_occupancy_sum as f64 / self.batches as f64
        }
    }

    /// Mean rows per fused forward call.
    pub fn mean_fused_occupancy(&self) -> f64 {
        if self.fused_batches == 0 {
            0.0
        } else {
            self.fused_rows as f64 / self.fused_batches as f64
        }
    }
}

/// Server configuration.
pub struct ServerConfig {
    /// Max time the batcher waits to fill a batch after the first
    /// request arrives.
    pub max_wait: Duration,
    /// `true` (default): one fused forward per drained batch, however
    /// many adapters it spans. `false`: the pre-fusion per-adapter-
    /// group serial path — kept as the bit-identity oracle.
    pub fused: bool,
}

impl ServerConfig {
    pub fn new(max_wait: Duration) -> ServerConfig {
        ServerConfig { max_wait, fused: true }
    }

    /// Switch to the per-group serial oracle path.
    pub fn serial(mut self) -> ServerConfig {
        self.fused = false;
        self
    }
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig::new(Duration::from_millis(2))
    }
}

/// Why a submission did not enqueue — split so routing layers
/// ([`super::pool::ServerPool`]) can tell a bad *request* (propagate
/// to the caller) from a bad *worker* (mark it dead and reroute).
#[derive(Debug)]
pub enum SubmitError {
    /// The request cannot be served by ANY worker — a typed
    /// [`ServeError`]: `Rejected` (malformed prompt / unknown adapter,
    /// counted in [`ServerStats::rejected`]) or `DeadlineExceeded`
    /// (already expired at submit, counted in
    /// [`ServerStats::shed_deadline`]). Resubmitting elsewhere is
    /// pointless.
    Rejected(ServeError),
    /// The worker thread is gone (panicked backend or shut down); the
    /// request never reached a queue. The prompt tokens are handed
    /// back so the caller can reroute without a clone.
    WorkerGone(Vec<i32>),
}

/// Slot-packing plan for one fused drained batch: group the drained
/// requests' adapter ids in first-arrival order, preserving submit
/// order within every adapter. Each returned entry is `(adapter,
/// request indices in row order)`; rows are assigned contiguously
/// group after group, so the `i`-th index of group `g` sits in row
/// `(sum of earlier group sizes) + i` and the total row count equals
/// `adapters.len()` (the drain never hands over more than the
/// backend's `batch`). Pure — property-tested directly in
/// `tests/proptests.rs`, and the worker routes every fused drain
/// through it.
pub fn fused_slot_plan<'a>(adapters: &[&'a str]) -> Vec<(&'a str, Vec<usize>)> {
    let mut plan: Vec<(&str, Vec<usize>)> = Vec::new();
    for (i, a) in adapters.iter().enumerate() {
        match plan.iter_mut().find(|(name, _)| name == a) {
            Some((_, idx)) => idx.push(i),
            None => plan.push((a, vec![i])),
        }
    }
    plan
}

/// Handle to a running batch server.
pub struct BatchServer {
    tx: Option<SyncSender<Request>>,
    handle: Option<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<ServerStats>>,
    registry: Arc<AdapterRegistry>,
    telem: ServeTelem,
    batch: usize,
    seq: usize,
    vocab: usize,
}

impl BatchServer {
    /// Spawn a PJRT-backed worker over the manifest's `forward` graph
    /// for `tag`. The worker owns its runtime (dropped with the
    /// worker — nothing leaks) and shares one uploaded base across
    /// every adapter in `registry`.
    pub fn spawn(
        manifest: Manifest,
        tag: &str,
        cfg: ServerConfig,
        registry: Arc<AdapterRegistry>,
    ) -> Result<BatchServer> {
        let tag = tag.to_string();
        let reg = registry.clone();
        Self::spawn_with(cfg, registry, move || {
            Ok(Box::new(PjrtBackend::new(&manifest, &tag, reg.base())?)
                as Box<dyn ServeBackend>)
        })
    }

    /// Spawn over an explicit backend factory. The factory runs on the
    /// worker thread, so the backend may own thread-bound resources
    /// (the PJRT runtime, device buffers). Tests and the offline bench
    /// smoke pass a [`super::backend::ReferenceBackend`] here.
    pub fn spawn_with<F>(
        cfg: ServerConfig,
        registry: Arc<AdapterRegistry>,
        make_backend: F,
    ) -> Result<BatchServer>
    where
        F: FnOnce() -> Result<Box<dyn ServeBackend>> + Send + 'static,
    {
        let telem = ServeTelem::resolve(&telemetry::global());
        Self::spawn_with_feeder(cfg, registry, make_backend, None, None, telem)
    }

    /// [`Self::spawn_with`] plus an optional [`Feeder`] — the pull
    /// hook [`super::pool::ServerPool`]'s overflow/steal scheduler
    /// installs. Without a feeder the worker blocks on its channel
    /// exactly as before; with one it polls the feeder whenever the
    /// channel runs dry and before launching a non-full batch.
    pub(crate) fn spawn_with_feeder<F>(
        cfg: ServerConfig,
        registry: Arc<AdapterRegistry>,
        make_backend: F,
        feeder: Option<Feeder>,
        exit_hook: Option<ExitHook>,
        telem: ServeTelem,
    ) -> Result<BatchServer>
    where
        F: FnOnce() -> Result<Box<dyn ServeBackend>> + Send + 'static,
    {
        let (tx, rx) = sync_channel::<Request>(1024);
        let stats = Arc::new(Mutex::new(ServerStats::default()));
        let stats_w = stats.clone();
        let registry_w = registry.clone();
        let telem_w = telem.clone();

        let (ready_tx, ready_rx) = sync_channel::<Result<(usize, usize, usize), String>>(1);
        let handle = std::thread::spawn(move || {
            let _exit_guard = ExitGuard(exit_hook);
            let mut backend = match make_backend() {
                Ok(b) => {
                    let _ = ready_tx.send(Ok(b.shape()));
                    b
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            let (batch, _, _) = backend.shape();
            let mut tok_scratch: Vec<i32> = Vec::new();
            let mut feeder = feeder;
            let mut idle_poll = IDLE_POLL_MIN;

            'serve: loop {
                // acquire the first request(s): the channel, else
                // parked/stolen work from the feeder, else block. Once
                // the channel disconnects the worker keeps serving
                // whatever the feeder still holds (shutdown drains the
                // overflow, including queues stranded by dead
                // siblings), then exits.
                let mut pending: Vec<Request> = Vec::new();
                let mut disconnected = false;
                // aged parked requests FIRST: promoted ahead of
                // whatever fresh traffic sits in the channel, so a
                // home that never drains its channel backlog cannot
                // starve its overflow (`IRQLORA_PARK_AGE_MS`)
                if let Some(f) = feeder.as_mut() {
                    pending.extend(f(FeedPass::Aged, batch));
                }
                while pending.is_empty() {
                    match rx.try_recv() {
                        Ok(r) => {
                            pending.push(r);
                            break;
                        }
                        Err(TryRecvError::Empty) => {}
                        Err(TryRecvError::Disconnected) => disconnected = true,
                    }
                    if let Some(f) = feeder.as_mut() {
                        pending.extend(f(FeedPass::Any, batch));
                        if !pending.is_empty() {
                            break;
                        }
                    }
                    if disconnected {
                        break 'serve;
                    }
                    if feeder.is_some() {
                        match rx.recv_timeout(idle_poll) {
                            Ok(r) => pending.push(r),
                            Err(RecvTimeoutError::Timeout) => {
                                idle_poll = (idle_poll * 2).min(IDLE_POLL_MAX);
                            }
                            Err(RecvTimeoutError::Disconnected) => disconnected = true,
                        }
                    } else {
                        match rx.recv() {
                            Ok(r) => pending.push(r),
                            Err(_) => break 'serve,
                        }
                    }
                }
                // got work: poll eagerly again while traffic flows
                idle_poll = IDLE_POLL_MIN;

                // fill the batch from the channel within the window
                let deadline = Instant::now() + cfg.max_wait;
                while pending.len() < batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => pending.push(r),
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                // top spare slots from the parked overflow (own queue
                // first; a sibling's if ours is empty) — spare batch
                // capacity anywhere in the pool serves parked work
                if pending.len() < batch {
                    if let Some(f) = feeder.as_mut() {
                        pending.extend(f(FeedPass::Any, batch - pending.len()));
                    }
                }

                // deadline shedding at the drain touch point: a
                // request whose deadline passed while queued is
                // answered with `DeadlineExceeded` and never occupies
                // a batch slot — dead work is shed, not executed
                let now = Instant::now();
                if pending.iter().any(|r| r.expired(now)) {
                    let (live, dead): (Vec<Request>, Vec<Request>) =
                        pending.into_iter().partition(|r| !r.expired(now));
                    stats_w.lock().unwrap().shed_deadline += dead.len();
                    telem_w.shed_deadline.add(dead.len() as u64);
                    for r in dead {
                        r.shed_expired();
                    }
                    pending = live;
                    if pending.is_empty() {
                        continue 'serve;
                    }
                }

                // slot-pack by adapter, preserving first-arrival group
                // order and submit order within each adapter
                let ids: Vec<&str> = pending.iter().map(|r| r.adapter.as_str()).collect();
                let plan: Vec<(String, Vec<usize>)> = fused_slot_plan(&ids)
                    .into_iter()
                    .map(|(a, idx)| (a.to_string(), idx))
                    .collect();
                let mut slots: Vec<Option<Request>> =
                    pending.into_iter().map(Some).collect();
                let groups: Vec<(String, Vec<Request>)> = plan
                    .into_iter()
                    .map(|(a, idx)| {
                        (a, idx.into_iter().map(|i| slots[i].take().unwrap()).collect())
                    })
                    .collect();

                if cfg.fused {
                    run_fused(
                        backend.as_mut(),
                        &registry_w,
                        &stats_w,
                        &telem_w,
                        groups,
                        &mut tok_scratch,
                    );
                } else {
                    for (adapter, group) in groups {
                        run_group(
                            backend.as_mut(),
                            &registry_w,
                            &stats_w,
                            &telem_w,
                            &adapter,
                            group,
                            &mut tok_scratch,
                        );
                    }
                }
            }
        });

        let (batch, seq, vocab) = ready_rx
            .recv()
            .context("server worker died during init")?
            .map_err(|e| anyhow!("server init failed: {e}"))?;

        Ok(BatchServer { tx: Some(tx), handle: Some(handle), stats, registry, telem, batch, seq, vocab })
    }

    /// Largest prompt (in tokens) the server accepts.
    pub fn max_prompt_len(&self) -> usize {
        self.seq
    }

    /// Max requests one forward call can carry (the backend's batch
    /// dimension). Routing layers size their spill thresholds off it.
    pub fn max_batch(&self) -> usize {
        self.batch
    }

    /// Logit width of every reply.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// The registry this server routes through (register/evict
    /// adapters on it while the server runs).
    pub fn registry(&self) -> &Arc<AdapterRegistry> {
        &self.registry
    }

    /// The submit-time validation alone (prompt length, adapter
    /// existence), without enqueueing — for routing layers that park
    /// requests in their own queues. Failures are counted in
    /// [`ServerStats::rejected`], exactly like a rejected submit.
    pub(crate) fn check_request(&self, adapter: &str, tokens: &[i32]) -> Result<(), ServeError> {
        if tokens.is_empty() || tokens.len() > self.seq {
            self.stats.lock().unwrap().rejected += 1;
            self.telem.rejected.inc();
            return Err(ServeError::Rejected(format!(
                "prompt length {} out of range 1..={}",
                tokens.len(),
                self.seq
            )));
        }
        if !self.registry.contains(adapter) {
            self.stats.lock().unwrap().rejected += 1;
            self.telem.rejected.inc();
            return Err(ServeError::Rejected(format!(
                "unknown adapter '{adapter}' (registered: {:?})",
                self.registry.names()
            )));
        }
        Ok(())
    }

    /// Submit a prompt for `adapter`; returns a receiver for the
    /// reply. Empty / over-length prompts and unknown adapters are
    /// rejected here, before they can occupy a batch slot.
    pub fn submit(
        &self,
        adapter: &str,
        tokens: Vec<i32>,
    ) -> Result<Receiver<Result<Reply, ServeError>>> {
        match self.try_submit(adapter, tokens) {
            Ok(rx) => Ok(rx),
            Err(SubmitError::Rejected(e)) => Err(e.into()),
            Err(SubmitError::WorkerGone(_)) => Err(anyhow!("server worker exited")),
        }
    }

    /// [`Self::submit`] with the failure mode split for routing layers:
    /// request problems come back as [`SubmitError::Rejected`] (and are
    /// counted in [`ServerStats::rejected`]), a dead worker comes back
    /// as [`SubmitError::WorkerGone`] with the tokens returned so the
    /// caller can reroute them to another worker.
    pub fn try_submit(
        &self,
        adapter: &str,
        tokens: Vec<i32>,
    ) -> Result<Receiver<Result<Reply, ServeError>>, SubmitError> {
        self.try_submit_at(adapter, tokens, None)
    }

    /// [`Self::try_submit`] with an optional per-request deadline: a
    /// deadline already in the past is shed here (typed
    /// `DeadlineExceeded`, counted in [`ServerStats::shed_deadline`])
    /// without touching the queue; one still in the future rides with
    /// the request and is honored at every later touch point.
    pub fn try_submit_at(
        &self,
        adapter: &str,
        tokens: Vec<i32>,
        deadline: Option<Instant>,
    ) -> Result<Receiver<Result<Reply, ServeError>>, SubmitError> {
        if let Err(e) = self.check_request(adapter, &tokens) {
            return Err(SubmitError::Rejected(e));
        }
        if deadline.map_or(false, |d| Instant::now() >= d) {
            self.stats.lock().unwrap().shed_deadline += 1;
            self.telem.shed_deadline.inc();
            return Err(SubmitError::Rejected(ServeError::DeadlineExceeded {
                waited: Duration::ZERO,
            }));
        }
        let Some(tx) = self.tx.as_ref() else {
            return Err(SubmitError::WorkerGone(tokens));
        };
        let (reply_tx, reply_rx) = sync_channel(1);
        match tx.send(Request {
            adapter: adapter.to_string(),
            tokens,
            enqueued: Instant::now(),
            deadline,
            reply: reply_tx,
        }) {
            Ok(()) => Ok(reply_rx),
            Err(std::sync::mpsc::SendError(req)) => Err(SubmitError::WorkerGone(req.tokens)),
        }
    }

    /// Submit and wait.
    pub fn query(&self, adapter: &str, tokens: Vec<i32>) -> Result<Reply> {
        let rx = self.submit(adapter, tokens)?;
        match rx.recv().context("server dropped reply")? {
            Ok(r) => Ok(r),
            Err(e) => bail!("request failed: {e}"),
        }
    }

    pub fn stats(&self) -> ServerStats {
        self.stats.lock().unwrap().clone()
    }

    /// Graceful shutdown: already-submitted requests drain first
    /// (every in-flight receiver still gets its reply), then the
    /// worker exits and its backend (runtime included) drops.
    pub fn shutdown(mut self) {
        self.tx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for BatchServer {
    fn drop(&mut self) {
        self.tx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Slice one request's next-token logits out of a forward result and
/// deliver its reply (or the slicing error). `row` is the request's
/// absolute row within the call that produced `logits`; `bsz` is how
/// many requests shared that call. One implementation for the fused,
/// fallback, and serial-oracle paths, so the three can never drift.
fn deliver_reply(
    logits: &[f32],
    seq: usize,
    vocab: usize,
    row: usize,
    adapter: &str,
    bsz: usize,
    launch: Instant,
    r: Request,
) {
    let off = (row * seq + r.tokens.len() - 1) * vocab;
    let resp = if off + vocab <= logits.len() {
        Ok(Reply {
            adapter: adapter.to_string(),
            logits: logits[off..off + vocab].to_vec(),
            queued: launch - r.enqueued,
            latency: r.enqueued.elapsed(),
            batch_size: bsz,
        })
    } else {
        Err(ServeError::BackendFault(format!(
            "backend returned {} logits, need at least {}",
            logits.len(),
            off + vocab
        )))
    };
    let _ = r.reply.send(resp);
}

/// Serve one drained batch — possibly spanning several adapters —
/// with a SINGLE fused forward: each adapter group gets a contiguous
/// row span in one padded token matrix, and every request's reply is
/// sliced from the shared logits at its absolute row. A group whose
/// merge fails gets its error without poisoning co-batched groups;
/// the forward itself failing fails every request that rode in it.
fn run_fused(
    backend: &mut dyn ServeBackend,
    registry: &AdapterRegistry,
    stats: &Mutex<ServerStats>,
    telem: &ServeTelem,
    groups: Vec<(String, Vec<Request>)>,
    tok_scratch: &mut Vec<i32>,
) {
    let (batch, seq, vocab) = backend.shape();
    let launch = Instant::now();

    // resolve merged weights and assign row spans
    let mut metas: Vec<AdapterGroup> = Vec::with_capacity(groups.len());
    let mut reqs: Vec<Vec<Request>> = Vec::with_capacity(groups.len());
    let mut row = 0usize;
    for (adapter, group) in groups {
        match registry.merged_for_serving(&adapter) {
            Ok((generation, weights)) => {
                let rows = row..row + group.len();
                row = rows.end;
                metas.push(AdapterGroup { name: adapter, generation, weights, rows });
                reqs.push(group);
            }
            Err(e) => {
                // merge failure: this group errors (typed — `Rejected`
                // for an adapter evicted since submit, `BackendFault`
                // otherwise), the rest still fuse; counted as one
                // attempted batch, mirroring what the serial oracle
                // path records for the same stream
                let mut s = stats.lock().unwrap();
                s.requests += group.len();
                s.batches += 1;
                s.batch_occupancy_sum += group.len();
                let a = s.per_adapter.entry(adapter.clone()).or_default();
                a.requests += group.len();
                a.batches += 1;
                a.occupancy_sum += group.len();
                drop(s);
                telem.requests.add(group.len() as u64);
                telem.batches.inc();
                telem.adapter_requests(&adapter).add(group.len() as u64);
                for r in group {
                    let _ = r.reply.send(Err(e.clone()));
                }
            }
        }
    }
    if metas.is_empty() {
        return;
    }
    let bsz = row;
    debug_assert!(bsz <= batch);

    // prompts were validated at submit time: 1..=seq tokens each
    tok_scratch.clear();
    tok_scratch.resize(batch * seq, PAD);
    for (g, group) in metas.iter().zip(&reqs) {
        for (i, r) in group.iter().enumerate() {
            let row = g.rows.start + i;
            tok_scratch[row * seq..row * seq + r.tokens.len()].copy_from_slice(&r.tokens);
        }
    }

    let result = backend.forward_fused(&metas, tok_scratch.as_slice());

    {
        let mut s = stats.lock().unwrap();
        s.requests += bsz;
        s.batches += 1;
        s.batch_occupancy_sum += bsz;
        s.fused_batches += 1;
        s.fused_rows += bsz;
        s.fused_adapters += metas.len();
        let up = backend.upload_stats();
        telem.upload_delta(s.upload, up);
        s.upload = up;
        for (g, group) in metas.iter().zip(&reqs) {
            let a = s.per_adapter.entry(g.name.clone()).or_default();
            a.requests += group.len();
            a.batches += 1;
            a.occupancy_sum += group.len();
        }
    }
    telem.requests.add(bsz as u64);
    telem.batches.inc();
    telem.fused_batches.inc();
    telem.fused_rows.add(bsz as u64);
    telem.fused_adapters.add(metas.len() as u64);
    for (g, group) in metas.iter().zip(&reqs) {
        telem.adapter_requests(&g.name).add(group.len() as u64);
    }

    match result {
        Ok(logits) => {
            for (g, group) in metas.iter().zip(reqs) {
                for (i, r) in group.into_iter().enumerate() {
                    deliver_reply(&logits, seq, vocab, g.rows.start + i, &g.name, bsz, launch, r);
                }
            }
        }
        // a multi-group fused forward that ERRORS (not panics) falls
        // back to serving each group alone, so one group's failure
        // keeps the serial path's isolation: healthy co-batched
        // tenants still get answers, only the failing group errors
        Err(e) if metas.len() > 1 => {
            run_fused_fallback(backend, metas, reqs, tok_scratch, &e);
        }
        Err(e) => {
            let fault = ServeError::BackendFault(format!("{e:#}"));
            for group in reqs {
                for r in group {
                    let _ = r.reply.send(Err(fault.clone()));
                }
            }
        }
    }
}

/// Recovery path for a failed multi-group fused forward: re-serve each
/// group through its own [`ServeBackend::forward`] call (rows packed
/// from 0, bit-identical to the serial oracle by the fused contract)
/// and deliver per-group results — exactly the isolation the
/// pre-fusion path had. The drain's stats were already recorded by
/// [`run_fused`]; the recovery forwards are not double-counted.
fn run_fused_fallback(
    backend: &mut dyn ServeBackend,
    metas: Vec<AdapterGroup>,
    reqs: Vec<Vec<Request>>,
    tok_scratch: &mut Vec<i32>,
    fused_err: &anyhow::Error,
) {
    let (batch, seq, vocab) = backend.shape();
    for (g, group) in metas.into_iter().zip(reqs) {
        let bsz = group.len();
        let launch = Instant::now();
        tok_scratch.clear();
        tok_scratch.resize(batch * seq, PAD);
        for (i, r) in group.iter().enumerate() {
            tok_scratch[i * seq..i * seq + r.tokens.len()].copy_from_slice(&r.tokens);
        }
        match backend.forward(&g.name, g.generation, &g.weights, tok_scratch.as_slice()) {
            Ok(logits) => {
                for (i, r) in group.into_iter().enumerate() {
                    deliver_reply(&logits, seq, vocab, i, &g.name, bsz, launch, r);
                }
            }
            Err(e) => {
                let fault = ServeError::BackendFault(format!(
                    "{e:#} (fused forward had failed: {fused_err:#})"
                ));
                for r in group {
                    let _ = r.reply.send(Err(fault.clone()));
                }
            }
        }
    }
}

/// Pad one same-adapter group into a single forward call and deliver
/// per-request replies (or the shared error). The pre-fusion serial
/// path — kept as the oracle [`run_fused`] is verified against.
fn run_group(
    backend: &mut dyn ServeBackend,
    registry: &AdapterRegistry,
    stats: &Mutex<ServerStats>,
    telem: &ServeTelem,
    adapter: &str,
    group: Vec<Request>,
    tok_scratch: &mut Vec<i32>,
) {
    let (batch, seq, vocab) = backend.shape();
    debug_assert!(group.len() <= batch);
    let bsz = group.len();
    let launch = Instant::now();

    // prompts were validated at submit time: 1..=seq tokens each
    tok_scratch.clear();
    tok_scratch.resize(batch * seq, PAD);
    for (i, r) in group.iter().enumerate() {
        tok_scratch[i * seq..i * seq + r.tokens.len()].copy_from_slice(&r.tokens);
    }

    let result = registry.merged_for_serving(adapter).and_then(|(generation, w)| {
        backend
            .forward(adapter, generation, &w, tok_scratch.as_slice())
            .map_err(|e| ServeError::BackendFault(format!("{e:#}")))
    });

    {
        let mut s = stats.lock().unwrap();
        s.requests += bsz;
        s.batches += 1;
        s.batch_occupancy_sum += bsz;
        let up = backend.upload_stats();
        telem.upload_delta(s.upload, up);
        s.upload = up;
        let a = s.per_adapter.entry(adapter.to_string()).or_default();
        a.requests += bsz;
        a.batches += 1;
        a.occupancy_sum += bsz;
    }
    telem.requests.add(bsz as u64);
    telem.batches.inc();
    telem.adapter_requests(adapter).add(bsz as u64);

    match result {
        Ok(logits) => {
            for (i, r) in group.into_iter().enumerate() {
                deliver_reply(&logits, seq, vocab, i, adapter, bsz, launch, r);
            }
        }
        Err(e) => {
            for r in group {
                let _ = r.reply.send(Err(e.clone()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let s = ServerStats {
            requests: 10,
            batches: 4,
            batch_occupancy_sum: 10,
            ..ServerStats::default()
        };
        assert!((s.mean_batch_size() - 2.5).abs() < 1e-12);
        assert_eq!(ServerStats::default().mean_batch_size(), 0.0);

        let a = AdapterServeStats { requests: 6, batches: 3, occupancy_sum: 6 };
        assert!((a.mean_batch_size() - 2.0).abs() < 1e-12);
        assert_eq!(AdapterServeStats::default().mean_batch_size(), 0.0);

        let f = ServerStats {
            fused_batches: 2,
            fused_rows: 7,
            fused_adapters: 3,
            ..ServerStats::default()
        };
        assert!((f.mean_fused_occupancy() - 3.5).abs() < 1e-12);
        assert_eq!(ServerStats::default().mean_fused_occupancy(), 0.0);
    }

    #[test]
    fn slot_plan_groups_in_arrival_order() {
        let plan = fused_slot_plan(&["b", "a", "b", "c", "a", "b"]);
        assert_eq!(
            plan,
            vec![
                ("b", vec![0, 2, 5]),
                ("a", vec![1, 4]),
                ("c", vec![3]),
            ]
        );
        assert!(fused_slot_plan(&[]).is_empty());
        let single = fused_slot_plan(&["x"]);
        assert_eq!(single, vec![("x", vec![0])]);
    }

    #[test]
    fn server_config_builders() {
        let c = ServerConfig::new(Duration::from_millis(3));
        assert!(c.fused);
        assert_eq!(c.max_wait, Duration::from_millis(3));
        assert!(!c.serial().fused);
        assert!(ServerConfig::default().fused);
    }
}
