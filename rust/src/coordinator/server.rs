//! Multi-adapter continuous-batching inference server (the
//! serving-path L3 component).
//!
//! Requests (adapter id + token prompt + decode step count) arrive on
//! a channel; a worker thread keeps an **always-running active set**
//! of up to `batch` in-flight streams and advances ALL of them one
//! decode step per loop iteration: the active rows are slot-packed by
//! adapter ([`fused_slot_plan`]) into ONE padded fixed-shape
//! `ServeBackend::forward_step` call, each row's next-token logits are
//! streamed to its caller as an incremental [`Reply`] (`step`/`last`),
//! and non-final rows are extended by one greedy token
//! ([`greedy_next_token`]). Requests JOIN the running batch whenever a
//! slot is free (no drain barrier — time-to-first-token is one step
//! away, not a whole batch) and LEAVE it independently when their
//! steps are done, their deadline passes mid-stream, or their caller
//! drops the stream. A one-shot request is simply a 1-step stream, so
//! the pre-streaming API and every PR 4–6 invariant (affinity routing,
//! stealing, aging, shedding) ride the same loop. The pre-fusion
//! one-forward-per-adapter-group path is kept in-tree
//! ([`ServerConfig::serial`]) as the bit-identity oracle the tests and
//! the paired `[per-group serial]` bench rows compare against — it
//! advances step-wise too, but through plain `forward` calls.
//!
//! One worker serves many adapters over one *shared* base: the
//! expensive artifact (the dequantized ICQ-quantized base) exists once
//! per worker, uploaded once by the backend, while adapters are cheap
//! per-tenant state routed through an [`AdapterRegistry`] (merged on
//! demand, LRU-cached; the backend keeps its own device-side adapter
//! cache keyed by `(name, generation)`).
//!
//! Malformed prompts (empty / over-length) and unknown adapters are
//! rejected at [`BatchServer::submit`] time — a bad request never
//! occupies a batch slot, so no all-PAD row ever runs through the
//! forward pass.
//!
//! The worker owns its execution backend (for PJRT: an
//! `OwnedExecutor` holding the runtime by `Arc`), so spawning N
//! servers no longer leaks N runtimes. A routing layer
//! ([`super::pool::ServerPool`]) may additionally install a *feeder* —
//! a pull-source of parked requests the worker polls when its own
//! channel runs dry (own overflow first, work stolen from a saturated
//! sibling when idle) and tops spare batch slots from after a drain.
//! Each drain additionally starts by asking the feeder for *aged*
//! parked requests ([`FeedPass::Aged`]) — a request parked behind a
//! saturated home is promoted ahead of fresh channel arrivals once it
//! has waited `IRQLORA_PARK_AGE_MS`, so a home that never goes idle
//! can no longer starve its overflow.
//!
//! Failures travel the reply channel as typed
//! [`ServeError`](super::error::ServeError) values (not strings):
//! submit-time validation yields `Rejected`, an expired per-request
//! deadline sheds with `DeadlineExceeded` before any forward runs
//! (counted in [`ServerStats::shed_deadline`]), and forward/merge
//! failures arrive as `BackendFault`/`Rejected` — so callers can
//! tell retryable from fatal without parsing messages.

use std::collections::BTreeMap;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::data::PAD;
use crate::runtime::Manifest;

use super::backend::{AdapterGroup, PjrtBackend, ServeBackend, UploadStats};
use super::error::ServeError;
use super::registry::AdapterRegistry;
use crate::telemetry;

/// Telemetry counter handles for the serving hot path, resolved ONCE
/// at spawn (resolution takes the registry mutex; recording is a
/// branch + relaxed atomic) and cloned into each worker. Every handle
/// is a no-op when the resolving registry is disabled — the default
/// unless `IRQLORA_TELEMETRY=1` or a test injects a scoped registry
/// via `PoolConfig.telemetry`.
///
/// These counters are incremented at the SAME mutation sites as the
/// [`ServerStats`] fields of the same name, so the struct view and
/// the telemetry view reconcile exactly by construction (asserted per
/// seed by the chaos-soak battery).
#[derive(Clone)]
pub(crate) struct ServeTelem {
    reg: Arc<telemetry::Registry>,
    pub(crate) requests: telemetry::Counter,
    pub(crate) batches: telemetry::Counter,
    pub(crate) fused_batches: telemetry::Counter,
    pub(crate) fused_rows: telemetry::Counter,
    pub(crate) fused_adapters: telemetry::Counter,
    pub(crate) rejected: telemetry::Counter,
    pub(crate) shed_deadline: telemetry::Counter,
    /// Streamed decode-step results delivered (`serve.steps`).
    pub(crate) steps: telemetry::Counter,
    /// Requests admitted with more than one decode step
    /// (`serve.stream_requests`).
    pub(crate) stream_requests: telemetry::Counter,
    /// Deadline sheds that hit a stream AFTER it had delivered at
    /// least one step (`serve.shed_midstream`; also counted in
    /// `serve.shed_deadline`).
    pub(crate) shed_midstream: telemetry::Counter,
    /// Deltas of the backend's monotonic [`UploadStats`], mirrored
    /// each time a worker snapshots them into `ServerStats.upload`.
    pub(crate) upload_hits: telemetry::Counter,
    pub(crate) upload_misses: telemetry::Counter,
}

impl ServeTelem {
    pub(crate) fn resolve(reg: &Arc<telemetry::Registry>) -> ServeTelem {
        ServeTelem {
            reg: reg.clone(),
            requests: reg.counter("serve.requests", &[]),
            batches: reg.counter("serve.batches", &[]),
            fused_batches: reg.counter("serve.fused_batches", &[]),
            fused_rows: reg.counter("serve.fused_rows", &[]),
            fused_adapters: reg.counter("serve.fused_adapters", &[]),
            rejected: reg.counter("serve.rejected", &[]),
            shed_deadline: reg.counter("serve.shed_deadline", &[]),
            steps: reg.counter("serve.steps", &[]),
            stream_requests: reg.counter("serve.stream_requests", &[]),
            shed_midstream: reg.counter("serve.shed_midstream", &[]),
            upload_hits: reg.counter("serve.upload", &[("event", "hit")]),
            upload_misses: reg.counter("serve.upload", &[("event", "miss")]),
        }
    }

    /// Per-adapter request counter — resolved per drain (cold-ish:
    /// once per batch, not per request; instant no-op when disabled).
    pub(crate) fn adapter_requests(&self, adapter: &str) -> telemetry::Counter {
        self.reg.counter("serve.adapter_requests", &[("adapter", adapter)])
    }

    /// Mirror a fresh monotonic upload snapshot against the previous
    /// one, crediting the deltas to the hit/miss counters.
    pub(crate) fn upload_delta(&self, prev: UploadStats, now: UploadStats) {
        self.upload_hits.add(now.hits.saturating_sub(prev.hits) as u64);
        self.upload_misses.add(now.misses.saturating_sub(prev.misses) as u64);
    }
}

/// One inference reply — one decode step of a stream. A one-shot
/// request is a 1-step stream, so its single reply has `step == 1`,
/// `last == true`.
#[derive(Clone, Debug)]
pub struct Reply {
    /// Adapter that served the request.
    pub adapter: String,
    /// Next-token logits at the stream's current last position (the
    /// prompt's for step 1; after each greedy extension thereafter).
    pub logits: Vec<f32>,
    /// Time spent queued before the stream's FIRST step launched
    /// (identical across a stream's replies — the TTFT queue wait).
    pub queued: Duration,
    /// Latency from submit to this step's delivery. For step 1 this is
    /// the time-to-first-token.
    pub latency: Duration,
    /// How many requests shared the forward call that computed this
    /// step (fused steps may span several adapters; serial-oracle
    /// calls are same-adapter).
    pub batch_size: usize,
    /// 1-based step index within the stream.
    pub step: usize,
    /// `true` on the stream's final reply.
    pub last: bool,
}

/// One queued request. `pub(crate)` so the pool's overflow/steal layer
/// can park fully-formed requests and hand them back to a worker
/// through its feeder.
pub(crate) struct Request {
    pub(crate) adapter: String,
    /// The prompt at submit; the worker appends one greedy token per
    /// delivered non-final step while the stream is active.
    pub(crate) tokens: Vec<i32>,
    /// Decode steps to serve (1 = classic one-shot). Validated at
    /// submit: `tokens.len() + steps - 1 <= seq` and
    /// `steps <= IRQLORA_STREAM_MAX_STEPS`.
    pub(crate) steps: usize,
    pub(crate) enqueued: Instant,
    /// Shed (with `ServeError::DeadlineExceeded`) instead of served if
    /// still queued — or mid-stream — past this instant. `None`: wait
    /// forever.
    pub(crate) deadline: Option<Instant>,
    pub(crate) reply: SyncSender<Result<Reply, ServeError>>,
}

impl Request {
    /// Has this request's deadline passed?
    pub(crate) fn expired(&self, now: Instant) -> bool {
        self.deadline.map_or(false, |d| now >= d)
    }

    /// Consume the request, answering it with the deadline-shed error.
    pub(crate) fn shed_expired(self) {
        let _ = self
            .reply
            .send(Err(ServeError::DeadlineExceeded { waited: self.enqueued.elapsed() }));
    }
}

/// One in-flight stream in the worker's active set.
struct ActiveRow {
    req: Request,
    /// Steps already delivered.
    done: usize,
    /// When the stream's first step launched (fixes `Reply::queued`
    /// for every step of the stream).
    first_launch: Option<Instant>,
    /// Marked when the stream must leave the active set (steps
    /// complete, errored, or caller gone).
    finished: bool,
}

impl ActiveRow {
    fn admit(req: Request) -> ActiveRow {
        ActiveRow { req, done: 0, first_launch: None, finished: false }
    }

    /// Current live prefix length (prompt + greedy extensions so far).
    fn len(&self) -> usize {
        self.req.tokens.len()
    }
}

/// The decode rule every streaming path and every oracle shares:
/// greedy argmax over one step's logits, first maximum winning ties,
/// mapped to the 1-BASED token id `argmax + 1` (so a generated token
/// can never collide with `PAD == 0`). Deterministic given bit-exact
/// logits — which is exactly what the backend contract guarantees —
/// so a streamed prefix can be replayed against the one-shot oracle.
pub fn greedy_next_token(logits: &[f32]) -> i32 {
    debug_assert!(!logits.is_empty());
    let mut best = 0usize;
    for (v, &x) in logits.iter().enumerate() {
        if x > logits[best] {
            best = v;
        }
    }
    (best + 1) as i32
}

/// Which parked requests a [`Feeder`] poll may return.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum FeedPass {
    /// Only requests parked longer than the aging threshold
    /// (`IRQLORA_PARK_AGE_MS`) — polled at the START of each drain, so
    /// aged parked work is promoted ahead of fresh channel arrivals.
    Aged,
    /// Any parked request (own overflow first, then stolen) — polled
    /// when the channel runs dry and to top spare batch slots.
    Any,
}

/// Pull-source of extra requests for a worker, installed by a routing
/// layer. `feeder(pass, max)` returns at most `max` requests — the
/// worker's own parked overflow first, then (when that is empty) work
/// stolen from a saturated or dead sibling, so any worker with spare
/// batch slots rescues parked requests instead of letting them starve
/// behind a busy or dead home. The [`FeedPass::Aged`] pass restricts
/// the pull to requests past the aging threshold (promotion).
pub(crate) type Feeder = Box<dyn FnMut(FeedPass, usize) -> Vec<Request> + Send>;

/// Invoked exactly once when the worker thread exits; the argument is
/// whether the thread was PANICKING (a backend fault) as opposed to a
/// normal shutdown drain or a failed init. Routing layers use it to
/// mark the worker dead proactively — without it, a worker that dies
/// while serving only parked/stolen requests would never be observed
/// dead by any submit or direct reply.
pub(crate) type ExitHook = Box<dyn FnOnce(bool) + Send>;

/// Drop guard that fires the [`ExitHook`] however the worker thread
/// ends (return or unwind).
struct ExitGuard(Option<ExitHook>);

impl Drop for ExitGuard {
    fn drop(&mut self) {
        if let Some(hook) = self.0.take() {
            hook(std::thread::panicking());
        }
    }
}

/// Idle-poll bounds for a worker with a feeder installed: it re-polls
/// the feeder between channel receives, starting at the floor and
/// backing off exponentially to the ceiling while nothing arrives (a
/// fully idle pool wakes each worker ~60×/s instead of 1000×/s; any
/// work resets the backoff, so steal latency under load stays at the
/// floor). Workers without a feeder block on their channel as before.
const IDLE_POLL_MIN: Duration = Duration::from_millis(1);
const IDLE_POLL_MAX: Duration = Duration::from_millis(16);

/// Per-adapter serving counters.
#[derive(Clone, Debug, Default)]
pub struct AdapterServeStats {
    pub requests: usize,
    /// Forward calls this adapter rode in (fused calls count once per
    /// participating adapter).
    pub batches: usize,
    pub occupancy_sum: usize,
}

impl AdapterServeStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.occupancy_sum as f64 / self.batches as f64
        }
    }
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub requests: usize,
    /// Total forward calls (fused mode: one per drained batch; serial
    /// oracle mode: one per same-adapter group).
    pub batches: usize,
    pub batch_occupancy_sum: usize,
    /// Fused forward calls (always 0 in serial oracle mode).
    pub fused_batches: usize,
    /// Rows served by fused forwards (occupancy of the fused calls).
    pub fused_rows: usize,
    /// Distinct adapters summed over fused calls (`/ fused_batches` =
    /// mean adapters per fused forward).
    pub fused_adapters: usize,
    /// Requests rejected at submit time (malformed prompt / unknown
    /// adapter); they never occupied a batch slot.
    pub rejected: usize,
    /// Requests shed with `DeadlineExceeded` by this worker — expired
    /// at submit time, in the admission path before their first step
    /// launched, or mid-stream between steps. (Requests shed while
    /// parked are counted by the pool's overflow layer, not here.)
    /// Shed work never runs another step.
    pub shed_deadline: usize,
    /// The subset of `shed_deadline` that hit a stream AFTER it had
    /// delivered at least one step (the mid-stream sheds).
    pub shed_midstream: usize,
    /// Decode-step results delivered: a one-shot request contributes
    /// 1, an S-step stream up to S. `steps / seconds` is the worker's
    /// tokens/sec.
    pub steps: usize,
    /// Requests admitted with more than one decode step.
    pub stream_requests: usize,
    /// Backend adapter-cache counters (device-buffer uploads for PJRT,
    /// fingerprint recomputes for the reference backend), snapshotted
    /// after each forward.
    pub upload: UploadStats,
    /// Per-adapter occupancy breakdown.
    pub per_adapter: BTreeMap<String, AdapterServeStats>,
}

impl ServerStats {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_occupancy_sum as f64 / self.batches as f64
        }
    }

    /// Mean rows per fused forward call.
    pub fn mean_fused_occupancy(&self) -> f64 {
        if self.fused_batches == 0 {
            0.0
        } else {
            self.fused_rows as f64 / self.fused_batches as f64
        }
    }
}

/// Server configuration.
pub struct ServerConfig {
    /// Max time the batcher waits to fill a batch after the first
    /// request arrives.
    pub max_wait: Duration,
    /// `true` (default): one fused forward per drained batch, however
    /// many adapters it spans. `false`: the pre-fusion per-adapter-
    /// group serial path — kept as the bit-identity oracle.
    pub fused: bool,
}

impl ServerConfig {
    pub fn new(max_wait: Duration) -> ServerConfig {
        ServerConfig { max_wait, fused: true }
    }

    /// Switch to the per-group serial oracle path.
    pub fn serial(mut self) -> ServerConfig {
        self.fused = false;
        self
    }
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig::new(Duration::from_millis(2))
    }
}

/// Why a submission did not enqueue — split so routing layers
/// ([`super::pool::ServerPool`]) can tell a bad *request* (propagate
/// to the caller) from a bad *worker* (mark it dead and reroute).
#[derive(Debug)]
pub enum SubmitError {
    /// The request cannot be served by ANY worker — a typed
    /// [`ServeError`]: `Rejected` (malformed prompt / unknown adapter,
    /// counted in [`ServerStats::rejected`]) or `DeadlineExceeded`
    /// (already expired at submit, counted in
    /// [`ServerStats::shed_deadline`]). Resubmitting elsewhere is
    /// pointless.
    Rejected(ServeError),
    /// The worker thread is gone (panicked backend or shut down); the
    /// request never reached a queue. The prompt tokens are handed
    /// back so the caller can reroute without a clone.
    WorkerGone(Vec<i32>),
}

/// Slot-packing plan for one fused drained batch: group the drained
/// requests' adapter ids in first-arrival order, preserving submit
/// order within every adapter. Each returned entry is `(adapter,
/// request indices in row order)`; rows are assigned contiguously
/// group after group, so the `i`-th index of group `g` sits in row
/// `(sum of earlier group sizes) + i` and the total row count equals
/// `adapters.len()` (the drain never hands over more than the
/// backend's `batch`). Pure — property-tested directly in
/// `tests/proptests.rs`, and the worker routes every fused drain
/// through it.
pub fn fused_slot_plan<'a>(adapters: &[&'a str]) -> Vec<(&'a str, Vec<usize>)> {
    let mut plan: Vec<(&str, Vec<usize>)> = Vec::new();
    for (i, a) in adapters.iter().enumerate() {
        match plan.iter_mut().find(|(name, _)| name == a) {
            Some((_, idx)) => idx.push(i),
            None => plan.push((a, vec![i])),
        }
    }
    plan
}

/// Handle to a running batch server.
pub struct BatchServer {
    tx: Option<SyncSender<Request>>,
    handle: Option<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<ServerStats>>,
    registry: Arc<AdapterRegistry>,
    telem: ServeTelem,
    batch: usize,
    seq: usize,
    vocab: usize,
}

impl BatchServer {
    /// Spawn a PJRT-backed worker over the manifest's `forward` graph
    /// for `tag`. The worker owns its runtime (dropped with the
    /// worker — nothing leaks) and shares one uploaded base across
    /// every adapter in `registry`.
    pub fn spawn(
        manifest: Manifest,
        tag: &str,
        cfg: ServerConfig,
        registry: Arc<AdapterRegistry>,
    ) -> Result<BatchServer> {
        let tag = tag.to_string();
        let reg = registry.clone();
        Self::spawn_with(cfg, registry, move || {
            Ok(Box::new(PjrtBackend::new(&manifest, &tag, reg.base())?)
                as Box<dyn ServeBackend>)
        })
    }

    /// Spawn over an explicit backend factory. The factory runs on the
    /// worker thread, so the backend may own thread-bound resources
    /// (the PJRT runtime, device buffers). Tests and the offline bench
    /// smoke pass a [`super::backend::ReferenceBackend`] here.
    pub fn spawn_with<F>(
        cfg: ServerConfig,
        registry: Arc<AdapterRegistry>,
        make_backend: F,
    ) -> Result<BatchServer>
    where
        F: FnOnce() -> Result<Box<dyn ServeBackend>> + Send + 'static,
    {
        let telem = ServeTelem::resolve(&telemetry::global());
        Self::spawn_with_feeder(cfg, registry, make_backend, None, None, telem)
    }

    /// [`Self::spawn_with`] plus an optional [`Feeder`] — the pull
    /// hook [`super::pool::ServerPool`]'s overflow/steal scheduler
    /// installs. Without a feeder the worker blocks on its channel
    /// exactly as before; with one it polls the feeder whenever the
    /// channel runs dry and before launching a non-full batch.
    pub(crate) fn spawn_with_feeder<F>(
        cfg: ServerConfig,
        registry: Arc<AdapterRegistry>,
        make_backend: F,
        feeder: Option<Feeder>,
        exit_hook: Option<ExitHook>,
        telem: ServeTelem,
    ) -> Result<BatchServer>
    where
        F: FnOnce() -> Result<Box<dyn ServeBackend>> + Send + 'static,
    {
        let (tx, rx) = sync_channel::<Request>(1024);
        let stats = Arc::new(Mutex::new(ServerStats::default()));
        let stats_w = stats.clone();
        let registry_w = registry.clone();
        let telem_w = telem.clone();

        let (ready_tx, ready_rx) = sync_channel::<Result<(usize, usize, usize), String>>(1);
        let handle = std::thread::spawn(move || {
            let _exit_guard = ExitGuard(exit_hook);
            let mut backend = match make_backend() {
                Ok(b) => {
                    let _ = ready_tx.send(Ok(b.shape()));
                    b
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            let (batch, _, _) = backend.shape();
            let mut tok_scratch: Vec<i32> = Vec::new();
            let mut lens_scratch: Vec<usize> = Vec::new();
            let mut feeder = feeder;
            let mut idle_poll = IDLE_POLL_MIN;
            // the always-running batch: in-flight streams advance one
            // decode step per loop iteration; arrivals join free slots
            // between steps, finished/shed/abandoned streams leave
            let mut active: Vec<ActiveRow> = Vec::new();

            'serve: loop {
                let mut pending: Vec<Request> = Vec::new();
                if active.is_empty() {
                    // idle: acquire the first request(s) exactly as the
                    // pre-streaming drain did — the channel, else
                    // parked/stolen work from the feeder, else block.
                    // Once the channel disconnects the worker keeps
                    // serving whatever the feeder still holds (shutdown
                    // drains the overflow, including queues stranded by
                    // dead siblings), then exits.
                    let mut disconnected = false;
                    // aged parked requests FIRST: promoted ahead of
                    // whatever fresh traffic sits in the channel, so a
                    // home that never drains its channel backlog cannot
                    // starve its overflow (`IRQLORA_PARK_AGE_MS`)
                    if let Some(f) = feeder.as_mut() {
                        pending.extend(f(FeedPass::Aged, batch));
                    }
                    while pending.is_empty() {
                        match rx.try_recv() {
                            Ok(r) => {
                                pending.push(r);
                                break;
                            }
                            Err(TryRecvError::Empty) => {}
                            Err(TryRecvError::Disconnected) => disconnected = true,
                        }
                        if let Some(f) = feeder.as_mut() {
                            pending.extend(f(FeedPass::Any, batch));
                            if !pending.is_empty() {
                                break;
                            }
                        }
                        if disconnected {
                            break 'serve;
                        }
                        if feeder.is_some() {
                            match rx.recv_timeout(idle_poll) {
                                Ok(r) => pending.push(r),
                                Err(RecvTimeoutError::Timeout) => {
                                    idle_poll = (idle_poll * 2).min(IDLE_POLL_MAX);
                                }
                                Err(RecvTimeoutError::Disconnected) => disconnected = true,
                            }
                        } else {
                            match rx.recv() {
                                Ok(r) => pending.push(r),
                                Err(_) => break 'serve,
                            }
                        }
                    }
                    // got work: poll eagerly again while traffic flows
                    idle_poll = IDLE_POLL_MIN;

                    // fill the batch from the channel within the window
                    // — ONLY when starting fresh; a running batch never
                    // blocks on arrivals (that would stall every
                    // in-flight stream's next token)
                    let deadline = Instant::now() + cfg.max_wait;
                    while pending.len() < batch {
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match rx.recv_timeout(deadline - now) {
                            Ok(r) => pending.push(r),
                            Err(RecvTimeoutError::Timeout) => break,
                            Err(RecvTimeoutError::Disconnected) => break,
                        }
                    }
                    // top spare slots from the parked overflow (own
                    // queue first; a sibling's if ours is empty)
                    if pending.len() < batch {
                        if let Some(f) = feeder.as_mut() {
                            pending.extend(f(FeedPass::Any, batch - pending.len()));
                        }
                    }
                } else if active.len() < batch {
                    // the batch is running: top spare slots WITHOUT
                    // blocking — aged parked promotion first, then the
                    // channel, then any parked/stolen work. This is the
                    // continuous-batching join point: an arrival waits
                    // at most one decode step, not a whole batch drain.
                    let free = batch - active.len();
                    if let Some(f) = feeder.as_mut() {
                        pending.extend(f(FeedPass::Aged, free));
                    }
                    while pending.len() < free {
                        match rx.try_recv() {
                            Ok(r) => pending.push(r),
                            // Disconnected: keep stepping the active
                            // streams; the idle path handles exit once
                            // they drain
                            Err(_) => break,
                        }
                    }
                    if pending.len() < free {
                        if let Some(f) = feeder.as_mut() {
                            pending.extend(f(FeedPass::Any, free - pending.len()));
                        }
                    }
                }

                // deadline shedding at the admission touch point: a
                // request whose deadline passed while queued is
                // answered with `DeadlineExceeded` and never occupies
                // a batch slot — dead work is shed, not executed
                let now = Instant::now();
                if pending.iter().any(|r| r.expired(now)) {
                    let (live, dead): (Vec<Request>, Vec<Request>) =
                        pending.into_iter().partition(|r| !r.expired(now));
                    stats_w.lock().unwrap().shed_deadline += dead.len();
                    telem_w.shed_deadline.add(dead.len() as u64);
                    for r in dead {
                        r.shed_expired();
                    }
                    pending = live;
                }
                for r in pending {
                    active.push(ActiveRow::admit(r));
                }
                if active.is_empty() {
                    continue 'serve;
                }

                // mid-stream deadline shedding: a stream whose
                // deadline passes BETWEEN steps leaves the batch with
                // `DeadlineExceeded` before another step runs —
                // co-batched tenants keep streaming, mirroring the
                // fused-error isolation contract
                let now = Instant::now();
                if active.iter().any(|a| a.req.expired(now)) {
                    let (live, dead): (Vec<ActiveRow>, Vec<ActiveRow>) =
                        active.drain(..).partition(|a| !a.req.expired(now));
                    active = live;
                    let mid = dead.iter().filter(|a| a.done > 0).count();
                    {
                        let mut s = stats_w.lock().unwrap();
                        s.shed_deadline += dead.len();
                        s.shed_midstream += mid;
                    }
                    telem_w.shed_deadline.add(dead.len() as u64);
                    telem_w.shed_midstream.add(mid as u64);
                    for a in dead {
                        a.req.shed_expired();
                    }
                    if active.is_empty() {
                        continue 'serve;
                    }
                }

                // one decode step for the whole active set
                if cfg.fused {
                    run_step_fused(
                        backend.as_mut(),
                        &registry_w,
                        &stats_w,
                        &telem_w,
                        &mut active,
                        &mut tok_scratch,
                        &mut lens_scratch,
                    );
                } else {
                    run_step_serial(
                        backend.as_mut(),
                        &registry_w,
                        &stats_w,
                        &telem_w,
                        &mut active,
                        &mut tok_scratch,
                    );
                }
                active.retain(|a| !a.finished);
            }
        });

        let (batch, seq, vocab) = ready_rx
            .recv()
            .context("server worker died during init")?
            .map_err(|e| anyhow!("server init failed: {e}"))?;

        Ok(BatchServer { tx: Some(tx), handle: Some(handle), stats, registry, telem, batch, seq, vocab })
    }

    /// Largest prompt (in tokens) the server accepts.
    pub fn max_prompt_len(&self) -> usize {
        self.seq
    }

    /// Max requests one forward call can carry (the backend's batch
    /// dimension). Routing layers size their spill thresholds off it.
    pub fn max_batch(&self) -> usize {
        self.batch
    }

    /// Logit width of every reply.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// The registry this server routes through (register/evict
    /// adapters on it while the server runs).
    pub fn registry(&self) -> &Arc<AdapterRegistry> {
        &self.registry
    }

    /// The submit-time validation alone (prompt length, adapter
    /// existence), without enqueueing — for routing layers that park
    /// requests in their own queues. Failures are counted in
    /// [`ServerStats::rejected`], exactly like a rejected submit.
    pub(crate) fn check_request(&self, adapter: &str, tokens: &[i32]) -> Result<(), ServeError> {
        if tokens.is_empty() || tokens.len() > self.seq {
            self.stats.lock().unwrap().rejected += 1;
            self.telem.rejected.inc();
            return Err(ServeError::Rejected(format!(
                "prompt length {} out of range 1..={}",
                tokens.len(),
                self.seq
            )));
        }
        if !self.registry.contains(adapter) {
            self.stats.lock().unwrap().rejected += 1;
            self.telem.rejected.inc();
            return Err(ServeError::Rejected(format!(
                "unknown adapter '{adapter}' (registered: {:?})",
                self.registry.names()
            )));
        }
        Ok(())
    }

    /// Stream-specific validation on top of [`Self::check_request`]:
    /// the step count must be positive, within
    /// `IRQLORA_STREAM_MAX_STEPS`, and the prompt must leave room for
    /// every greedy extension (`tokens.len() + steps - 1 <= seq` —
    /// step i runs on a prefix of `tokens.len() + i - 1` tokens).
    /// Failures are counted in [`ServerStats::rejected`], exactly like
    /// a rejected one-shot submit.
    pub(crate) fn check_stream(
        &self,
        adapter: &str,
        tokens: &[i32],
        steps: usize,
    ) -> Result<(), ServeError> {
        self.check_request(adapter, tokens)?;
        let max_steps = crate::util::env::stream_max_steps();
        if steps == 0 || steps > max_steps {
            self.stats.lock().unwrap().rejected += 1;
            self.telem.rejected.inc();
            return Err(ServeError::Rejected(format!(
                "stream steps {steps} out of range 1..={max_steps} (IRQLORA_STREAM_MAX_STEPS)"
            )));
        }
        if tokens.len() + steps - 1 > self.seq {
            self.stats.lock().unwrap().rejected += 1;
            self.telem.rejected.inc();
            return Err(ServeError::Rejected(format!(
                "prompt length {} + {steps} decode steps overruns seq {} \
                 (need prompt + steps - 1 <= seq)",
                tokens.len(),
                self.seq
            )));
        }
        Ok(())
    }

    /// Submit a prompt for `adapter`; returns a receiver for the
    /// reply. Empty / over-length prompts and unknown adapters are
    /// rejected here, before they can occupy a batch slot.
    pub fn submit(
        &self,
        adapter: &str,
        tokens: Vec<i32>,
    ) -> Result<Receiver<Result<Reply, ServeError>>> {
        match self.try_submit(adapter, tokens) {
            Ok(rx) => Ok(rx),
            Err(SubmitError::Rejected(e)) => Err(e.into()),
            Err(SubmitError::WorkerGone(_)) => Err(anyhow!("server worker exited")),
        }
    }

    /// [`Self::submit`] with the failure mode split for routing layers:
    /// request problems come back as [`SubmitError::Rejected`] (and are
    /// counted in [`ServerStats::rejected`]), a dead worker comes back
    /// as [`SubmitError::WorkerGone`] with the tokens returned so the
    /// caller can reroute them to another worker.
    pub fn try_submit(
        &self,
        adapter: &str,
        tokens: Vec<i32>,
    ) -> Result<Receiver<Result<Reply, ServeError>>, SubmitError> {
        self.try_submit_at(adapter, tokens, None)
    }

    /// [`Self::try_submit`] with an optional per-request deadline: a
    /// deadline already in the past is shed here (typed
    /// `DeadlineExceeded`, counted in [`ServerStats::shed_deadline`])
    /// without touching the queue; one still in the future rides with
    /// the request and is honored at every later touch point.
    pub fn try_submit_at(
        &self,
        adapter: &str,
        tokens: Vec<i32>,
        deadline: Option<Instant>,
    ) -> Result<Receiver<Result<Reply, ServeError>>, SubmitError> {
        self.try_submit_stream_at(adapter, tokens, 1, deadline)
    }

    /// Submit an S-step greedy decode stream: the stream joins the
    /// worker's always-running batch and each decode step arrives on
    /// the returned receiver as an incremental [`Reply`] ([`Reply::step`]
    /// numbers it, [`Reply::last`] marks the final one). Between steps
    /// the worker extends the prompt with [`greedy_next_token`] of the
    /// step's logits. `steps == 1` is exactly [`Self::submit`].
    pub fn submit_stream(
        &self,
        adapter: &str,
        tokens: Vec<i32>,
        steps: usize,
    ) -> Result<Receiver<Result<Reply, ServeError>>> {
        match self.try_submit_stream_at(adapter, tokens, steps, None) {
            Ok(rx) => Ok(rx),
            Err(SubmitError::Rejected(e)) => Err(e.into()),
            Err(SubmitError::WorkerGone(_)) => Err(anyhow!("server worker exited")),
        }
    }

    /// [`Self::submit_stream`] with the routing-layer failure split of
    /// [`Self::try_submit_at`], plus an optional deadline that is
    /// honored BETWEEN decode steps: a stream whose deadline passes
    /// mid-flight is shed with `DeadlineExceeded` on its next step
    /// boundary without disturbing co-batched streams.
    pub fn try_submit_stream_at(
        &self,
        adapter: &str,
        tokens: Vec<i32>,
        steps: usize,
        deadline: Option<Instant>,
    ) -> Result<Receiver<Result<Reply, ServeError>>, SubmitError> {
        if let Err(e) = self.check_stream(adapter, &tokens, steps) {
            return Err(SubmitError::Rejected(e));
        }
        if deadline.map_or(false, |d| Instant::now() >= d) {
            self.stats.lock().unwrap().shed_deadline += 1;
            self.telem.shed_deadline.inc();
            return Err(SubmitError::Rejected(ServeError::DeadlineExceeded {
                waited: Duration::ZERO,
            }));
        }
        let Some(tx) = self.tx.as_ref() else {
            return Err(SubmitError::WorkerGone(tokens));
        };
        // one slot per step: the worker's step sends never block even
        // if the caller harvests lazily (at most `steps` messages —
        // j successful steps then at most one terminal error)
        let (reply_tx, reply_rx) = sync_channel(steps);
        match tx.send(Request {
            adapter: adapter.to_string(),
            tokens,
            steps,
            enqueued: Instant::now(),
            deadline,
            reply: reply_tx,
        }) {
            Ok(()) => Ok(reply_rx),
            Err(std::sync::mpsc::SendError(req)) => Err(SubmitError::WorkerGone(req.tokens)),
        }
    }

    /// Submit and wait.
    pub fn query(&self, adapter: &str, tokens: Vec<i32>) -> Result<Reply> {
        let rx = self.submit(adapter, tokens)?;
        match rx.recv().context("server dropped reply")? {
            Ok(r) => Ok(r),
            Err(e) => bail!("request failed: {e}"),
        }
    }

    pub fn stats(&self) -> ServerStats {
        self.stats.lock().unwrap().clone()
    }

    /// Graceful shutdown: already-submitted requests drain first
    /// (every in-flight receiver still gets its reply), then the
    /// worker exits and its backend (runtime included) drops.
    pub fn shutdown(mut self) {
        self.tx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for BatchServer {
    fn drop(&mut self) {
        self.tx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Count a group's first-step rows (a stream is a `request` once, at
/// its first step — never recounted on later steps) and which of those
/// are multi-step streams.
fn fresh_rows(active: &[ActiveRow], idx: &[usize]) -> (usize, usize) {
    let fresh = idx.iter().filter(|&&i| active[i].done == 0).count();
    let streams = idx
        .iter()
        .filter(|&&i| active[i].done == 0 && active[i].req.steps > 1)
        .count();
    (fresh, streams)
}

/// Deliver one step's logits (`logits[off..off + vocab]`) to a stream
/// and advance it: the step is counted, the greedy next token is
/// appended for the following step, and the row retires when the
/// stream completes, the slice is short (backend shape fault), or the
/// caller dropped its receiver (computing further steps would be
/// wasted work). `bsz` is how many rows shared the call that produced
/// `logits`. One implementation for the fused, fallback, and
/// serial-oracle paths, so the three can never drift.
#[allow(clippy::too_many_arguments)]
fn advance_row(
    a: &mut ActiveRow,
    logits: &[f32],
    off: usize,
    vocab: usize,
    adapter: &str,
    bsz: usize,
    launch: Instant,
    stats: &Mutex<ServerStats>,
    telem: &ServeTelem,
) {
    let first_launch = *a.first_launch.get_or_insert(launch);
    if off + vocab > logits.len() {
        let _ = a.req.reply.send(Err(ServeError::BackendFault(format!(
            "backend returned {} logits, need at least {}",
            logits.len(),
            off + vocab
        ))));
        a.finished = true;
        return;
    }
    let slice = &logits[off..off + vocab];
    let step = a.done + 1;
    let last = step == a.req.steps;
    let sent = a
        .req
        .reply
        .send(Ok(Reply {
            adapter: adapter.to_string(),
            logits: slice.to_vec(),
            queued: first_launch - a.req.enqueued,
            latency: a.req.enqueued.elapsed(),
            batch_size: bsz,
            step,
            last,
        }))
        .is_ok();
    a.done = step;
    stats.lock().unwrap().steps += 1;
    telem.steps.inc();
    if last || !sent {
        a.finished = true;
    } else {
        a.req.tokens.push(greedy_next_token(slice));
    }
}

/// Advance the whole active set by ONE decode step with a SINGLE
/// fused [`ServeBackend::forward_step`]: each adapter group gets a
/// contiguous row span in one padded token matrix (each row holding
/// that stream's CURRENT prefix — prompt plus the greedy tokens of
/// earlier steps), and every stream's step reply is sliced from the
/// `[batch, vocab]` result at its absolute row. A group whose merge
/// fails errors out (retiring its streams) without poisoning
/// co-batched groups; the step itself failing falls back per-group.
fn run_step_fused(
    backend: &mut dyn ServeBackend,
    registry: &AdapterRegistry,
    stats: &Mutex<ServerStats>,
    telem: &ServeTelem,
    active: &mut [ActiveRow],
    tok_scratch: &mut Vec<i32>,
    lens_scratch: &mut Vec<usize>,
) {
    let (batch, seq, vocab) = backend.shape();
    let launch = Instant::now();

    // slot-pack by adapter, preserving first-arrival group order and
    // admission order within each adapter
    let ids: Vec<&str> = active.iter().map(|a| a.req.adapter.as_str()).collect();
    let plan: Vec<(String, Vec<usize>)> = fused_slot_plan(&ids)
        .into_iter()
        .map(|(a, idx)| (a.to_string(), idx))
        .collect();

    // resolve merged weights and assign row spans
    let mut metas: Vec<AdapterGroup> = Vec::with_capacity(plan.len());
    let mut members: Vec<Vec<usize>> = Vec::with_capacity(plan.len());
    let mut row = 0usize;
    for (adapter, idx) in plan {
        match registry.merged_for_serving(&adapter) {
            Ok((generation, weights)) => {
                let rows = row..row + idx.len();
                row = rows.end;
                metas.push(AdapterGroup { name: adapter, generation, weights, rows });
                members.push(idx);
            }
            Err(e) => {
                // merge failure: this group errors (typed — `Rejected`
                // for an adapter evicted since submit, `BackendFault`
                // otherwise) and its streams retire, the rest still
                // fuse; counted as one attempted batch, with only
                // first-step rows counted as requests
                let (fresh, streams) = fresh_rows(active, &idx);
                let mut s = stats.lock().unwrap();
                s.requests += fresh;
                s.stream_requests += streams;
                s.batches += 1;
                s.batch_occupancy_sum += idx.len();
                let a = s.per_adapter.entry(adapter.clone()).or_default();
                a.requests += fresh;
                a.batches += 1;
                a.occupancy_sum += idx.len();
                drop(s);
                telem.requests.add(fresh as u64);
                telem.stream_requests.add(streams as u64);
                telem.batches.inc();
                telem.adapter_requests(&adapter).add(fresh as u64);
                for &i in &idx {
                    let _ = active[i].req.reply.send(Err(e.clone()));
                    active[i].finished = true;
                }
            }
        }
    }
    if metas.is_empty() {
        return;
    }
    let bsz = row;
    debug_assert!(bsz <= batch);

    // prompts were validated at submit time to leave room for every
    // greedy extension: len + steps - 1 <= seq
    tok_scratch.clear();
    tok_scratch.resize(batch * seq, PAD);
    lens_scratch.clear();
    lens_scratch.resize(batch, 1);
    for (g, idx) in metas.iter().zip(&members) {
        for (i, &ai) in idx.iter().enumerate() {
            let row = g.rows.start + i;
            let toks = &active[ai].req.tokens;
            tok_scratch[row * seq..row * seq + toks.len()].copy_from_slice(toks);
            lens_scratch[row] = toks.len();
        }
    }

    let result = backend.forward_step(&metas, tok_scratch.as_slice(), lens_scratch.as_slice());

    let (fresh, streams) = {
        let mut f = 0usize;
        let mut st = 0usize;
        for idx in &members {
            let (a, b) = fresh_rows(active, idx);
            f += a;
            st += b;
        }
        (f, st)
    };
    {
        let mut s = stats.lock().unwrap();
        s.requests += fresh;
        s.stream_requests += streams;
        s.batches += 1;
        s.batch_occupancy_sum += bsz;
        s.fused_batches += 1;
        s.fused_rows += bsz;
        s.fused_adapters += metas.len();
        let up = backend.upload_stats();
        telem.upload_delta(s.upload, up);
        s.upload = up;
        for (g, idx) in metas.iter().zip(&members) {
            let (gf, _) = fresh_rows(active, idx);
            let a = s.per_adapter.entry(g.name.clone()).or_default();
            a.requests += gf;
            a.batches += 1;
            a.occupancy_sum += idx.len();
        }
    }
    telem.requests.add(fresh as u64);
    telem.stream_requests.add(streams as u64);
    telem.batches.inc();
    telem.fused_batches.inc();
    telem.fused_rows.add(bsz as u64);
    telem.fused_adapters.add(metas.len() as u64);
    for (g, idx) in metas.iter().zip(&members) {
        let (gf, _) = fresh_rows(active, idx);
        telem.adapter_requests(&g.name).add(gf as u64);
    }

    match result {
        Ok(step_logits) => {
            for (g, idx) in metas.iter().zip(&members) {
                for (i, &ai) in idx.iter().enumerate() {
                    let off = (g.rows.start + i) * vocab;
                    advance_row(
                        &mut active[ai],
                        &step_logits,
                        off,
                        vocab,
                        &g.name,
                        bsz,
                        launch,
                        stats,
                        telem,
                    );
                }
            }
        }
        // a multi-group fused step that ERRORS (not panics) falls
        // back to stepping each group alone, so one group's failure
        // keeps the serial path's isolation: healthy co-batched
        // tenants still get their next token, only the failing group
        // errors
        Err(e) if metas.len() > 1 => {
            run_step_fallback(backend, active, &metas, &members, tok_scratch, &e, stats, telem);
        }
        Err(e) => {
            let fault = ServeError::BackendFault(format!("{e:#}"));
            for idx in &members {
                for &ai in idx {
                    let _ = active[ai].req.reply.send(Err(fault.clone()));
                    active[ai].finished = true;
                }
            }
        }
    }
}

/// Recovery path for a failed multi-group fused step: re-serve each
/// group through its own full [`ServeBackend::forward`] call (rows
/// packed from 0, bit-identical to the fused step by the forward_step
/// contract) and slice each stream's position from the full logits —
/// exactly the isolation the pre-fusion path had. The step's stats
/// were already recorded by [`run_step_fused`]; the recovery forwards
/// are not double-counted.
#[allow(clippy::too_many_arguments)]
fn run_step_fallback(
    backend: &mut dyn ServeBackend,
    active: &mut [ActiveRow],
    metas: &[AdapterGroup],
    members: &[Vec<usize>],
    tok_scratch: &mut Vec<i32>,
    fused_err: &anyhow::Error,
    stats: &Mutex<ServerStats>,
    telem: &ServeTelem,
) {
    let (batch, seq, vocab) = backend.shape();
    for (g, idx) in metas.iter().zip(members) {
        let bsz = idx.len();
        let launch = Instant::now();
        tok_scratch.clear();
        tok_scratch.resize(batch * seq, PAD);
        for (i, &ai) in idx.iter().enumerate() {
            let toks = &active[ai].req.tokens;
            tok_scratch[i * seq..i * seq + toks.len()].copy_from_slice(toks);
        }
        match backend.forward(&g.name, g.generation, &g.weights, tok_scratch.as_slice()) {
            Ok(logits) => {
                for (i, &ai) in idx.iter().enumerate() {
                    let off = (i * seq + active[ai].len() - 1) * vocab;
                    advance_row(
                        &mut active[ai],
                        &logits,
                        off,
                        vocab,
                        &g.name,
                        bsz,
                        launch,
                        stats,
                        telem,
                    );
                }
            }
            Err(e) => {
                let fault = ServeError::BackendFault(format!(
                    "{e:#} (fused forward had failed: {fused_err:#})"
                ));
                for &ai in idx {
                    let _ = active[ai].req.reply.send(Err(fault.clone()));
                    active[ai].finished = true;
                }
            }
        }
    }
}

/// Advance the active set by one decode step with one full
/// [`ServeBackend::forward`] call per same-adapter group (rows packed
/// from 0), slicing each stream's current position from the full
/// logits. The pre-fusion serial path — kept as the oracle
/// [`run_step_fused`] is verified against; per step it is exactly the
/// old one-shot `run_group` on the streams' current prefixes.
fn run_step_serial(
    backend: &mut dyn ServeBackend,
    registry: &AdapterRegistry,
    stats: &Mutex<ServerStats>,
    telem: &ServeTelem,
    active: &mut [ActiveRow],
    tok_scratch: &mut Vec<i32>,
) {
    let (batch, seq, vocab) = backend.shape();
    let ids: Vec<&str> = active.iter().map(|a| a.req.adapter.as_str()).collect();
    let plan: Vec<(String, Vec<usize>)> = fused_slot_plan(&ids)
        .into_iter()
        .map(|(a, idx)| (a.to_string(), idx))
        .collect();

    for (adapter, idx) in plan {
        debug_assert!(idx.len() <= batch);
        let bsz = idx.len();
        let launch = Instant::now();

        tok_scratch.clear();
        tok_scratch.resize(batch * seq, PAD);
        for (i, &ai) in idx.iter().enumerate() {
            let toks = &active[ai].req.tokens;
            tok_scratch[i * seq..i * seq + toks.len()].copy_from_slice(toks);
        }

        let result = registry.merged_for_serving(&adapter).and_then(|(generation, w)| {
            backend
                .forward(&adapter, generation, &w, tok_scratch.as_slice())
                .map_err(|e| ServeError::BackendFault(format!("{e:#}")))
        });

        let (fresh, streams) = fresh_rows(active, &idx);
        {
            let mut s = stats.lock().unwrap();
            s.requests += fresh;
            s.stream_requests += streams;
            s.batches += 1;
            s.batch_occupancy_sum += bsz;
            let up = backend.upload_stats();
            telem.upload_delta(s.upload, up);
            s.upload = up;
            let a = s.per_adapter.entry(adapter.clone()).or_default();
            a.requests += fresh;
            a.batches += 1;
            a.occupancy_sum += bsz;
        }
        telem.requests.add(fresh as u64);
        telem.stream_requests.add(streams as u64);
        telem.batches.inc();
        telem.adapter_requests(&adapter).add(fresh as u64);

        match result {
            Ok(logits) => {
                for (i, &ai) in idx.iter().enumerate() {
                    let off = (i * seq + active[ai].len() - 1) * vocab;
                    advance_row(
                        &mut active[ai],
                        &logits,
                        off,
                        vocab,
                        &adapter,
                        bsz,
                        launch,
                        stats,
                        telem,
                    );
                }
            }
            Err(e) => {
                for &ai in &idx {
                    let _ = active[ai].req.reply.send(Err(e.clone()));
                    active[ai].finished = true;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let s = ServerStats {
            requests: 10,
            batches: 4,
            batch_occupancy_sum: 10,
            ..ServerStats::default()
        };
        assert!((s.mean_batch_size() - 2.5).abs() < 1e-12);
        assert_eq!(ServerStats::default().mean_batch_size(), 0.0);

        let a = AdapterServeStats { requests: 6, batches: 3, occupancy_sum: 6 };
        assert!((a.mean_batch_size() - 2.0).abs() < 1e-12);
        assert_eq!(AdapterServeStats::default().mean_batch_size(), 0.0);

        let f = ServerStats {
            fused_batches: 2,
            fused_rows: 7,
            fused_adapters: 3,
            ..ServerStats::default()
        };
        assert!((f.mean_fused_occupancy() - 3.5).abs() < 1e-12);
        assert_eq!(ServerStats::default().mean_fused_occupancy(), 0.0);
    }

    #[test]
    fn slot_plan_groups_in_arrival_order() {
        let plan = fused_slot_plan(&["b", "a", "b", "c", "a", "b"]);
        assert_eq!(
            plan,
            vec![
                ("b", vec![0, 2, 5]),
                ("a", vec![1, 4]),
                ("c", vec![3]),
            ]
        );
        assert!(fused_slot_plan(&[]).is_empty());
        let single = fused_slot_plan(&["x"]);
        assert_eq!(single, vec![("x", vec![0])]);
    }

    #[test]
    fn server_config_builders() {
        let c = ServerConfig::new(Duration::from_millis(3));
        assert!(c.fused);
        assert_eq!(c.max_wait, Duration::from_millis(3));
        assert!(!c.serial().fused);
        assert!(ServerConfig::default().fused);
    }

    #[test]
    fn greedy_next_token_is_first_max_one_based() {
        // plain argmax, shifted past PAD == 0
        assert_eq!(greedy_next_token(&[0.0, 3.0, 1.0]), 2);
        assert_eq!(greedy_next_token(&[5.0, 3.0, 1.0]), 1);
        // ties break to the FIRST maximum (strict `>` never replaces)
        assert_eq!(greedy_next_token(&[1.0, 7.0, 7.0, 7.0]), 2);
        // single-logit vocab can only emit token 1
        assert_eq!(greedy_next_token(&[-2.0]), 1);
        // the result is never PAD
        assert_ne!(greedy_next_token(&[0.0; 8]), crate::data::PAD);
    }

    #[test]
    fn active_row_admit_tracks_prefix() {
        let (tx, _rx) = sync_channel(1);
        let a = ActiveRow::admit(Request {
            adapter: "t".into(),
            tokens: vec![1, 2, 3],
            steps: 4,
            enqueued: Instant::now(),
            deadline: None,
            reply: tx,
        });
        assert_eq!(a.len(), 3);
        assert_eq!(a.done, 0);
        assert!(!a.finished);
        assert!(a.first_launch.is_none());
    }
}
