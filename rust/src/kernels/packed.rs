//! Packed-domain GEMM — `y = W_q·x` straight from bit-packed NF-k
//! codes, no dequantized intermediate.
//!
//! The weight tensor stays in its Eq. 10 storage form ([`
//! QuantizedTensor`]: packed codes + double-quantized per-block
//! constants). Per quantization block the kernel reconstructs the
//! 2^k-entry scaled LUT `cb[c]·s + τ` **once per code** — the exact
//! f32 expression the dequantizer evaluates once per *weight* — and
//! then streams the block's codes word-at-a-time, accumulating
//! `lut[code]·x_j` in f64 in element order. Because the weights it
//! multiplies are bitwise the dequantizer's outputs and the reduction
//! order is untouched, [`gemm_packed`] is bit-identical to
//! dequantize-then-[`super::gemm_f32_reference`] for every geometry,
//! including partial last blocks, unaligned `block·k % 8 != 0` layouts
//! and mixed-k plans (per-block k just selects a different LUT).
//!
//! [`gemm_packed_hist`] is the reassociated variant: it buckets
//! x-contributions per code first (a 2^k histogram per block run) and
//! finishes with one 2^k-length dot against the scaled LUT — fewer
//! multiplies when `block >> 2^k`, but the sum is regrouped by code,
//! so it promises bit-identity only to its own serial twin plus a
//! relative-error bound against the exact kernel.

use crate::quant::fused::{lut, walk_codes, walk_codes_from};
use crate::quant::QuantizedTensor;
use crate::util::threads;

/// Reusable scratch for the packed kernels: dequantized per-block
/// constants, reused across calls so steady-state matvecs allocate
/// nothing (the per-block LUT and histogram live on the stack).
#[derive(Debug, Default)]
pub struct PackedGemmScratch {
    scales: Vec<f32>,
    taus: Vec<f32>,
}

impl PackedGemmScratch {
    pub fn new() -> PackedGemmScratch {
        PackedGemmScratch::default()
    }
}

/// Interpret a quantized tensor as a row-major matrix for `y = W·x`:
/// `shape[0]` rows, the remaining dims flattened into columns (a 1-D
/// tensor is a column vector: `len` rows × 1).
fn matvec_dims(qt: &QuantizedTensor) -> (usize, usize) {
    assert!(!qt.shape.is_empty(), "packed GEMM needs a shaped tensor");
    let rows = qt.shape[0];
    let cols: usize = qt.shape[1..].iter().product();
    assert_eq!(rows * cols, qt.len, "shape does not cover len");
    (rows, cols)
}

fn dequant_consts<'s>(
    qt: &QuantizedTensor,
    scratch: &'s mut PackedGemmScratch,
) -> (&'s [f32], Option<&'s [f32]>) {
    qt.scales.dequantize_into(&mut scratch.scales);
    let taus = match &qt.taus {
        Some(t) => {
            t.dequantize_into(&mut scratch.taus);
            Some(scratch.taus.as_slice())
        }
        None => None,
    };
    (scratch.scales.as_slice(), taus)
}

/// Exact packed-domain dot product over elements `start .. start+len`
/// of a packed code stream: returns
/// `Σ_j (cb[code_{start+j}]·s_b + τ_b) · x[j]` with one f64
/// accumulator in element order — the identical arithmetic DAG as
/// dequantizing those elements and folding them through
/// [`super::gemm_f32_reference`].
///
/// `scales`/`taus` are indexed by `(start + j) / block`, i.e. they are
/// block-aligned with the *given* `start` origin — callers that slice
/// the packed stream (the native fingerprint tiles) slice the constant
/// arrays to match and pass `start = 0`. `x[j]` pairs with element
/// `start + j`. The per-block scaled LUT lives on the stack; this
/// function allocates nothing.
#[allow(clippy::too_many_arguments)]
pub fn dot_packed(
    packed: &[u8],
    k: u8,
    start: usize,
    len: usize,
    block: usize,
    scales: &[f32],
    taus: Option<&[f32]>,
    x: &[f32],
) -> f64 {
    assert!(block > 0);
    assert!(x.len() >= len, "x shorter than the code run");
    if len == 0 {
        return 0.0;
    }
    let last_block = (start + len - 1) / block;
    assert!(scales.len() > last_block, "need one scale per block");
    if let Some(t) = taus {
        assert!(t.len() > last_block, "need one tau per block");
    }
    let cb = lut(k).codebook();
    let nvals = 1usize << k;
    let mut lut_scaled = [0f32; 256];
    let mut acc = 0f64;
    let mut next_reload = 0usize; // j at which the block (and LUT) changes
    let mut blocks = 0u64;
    walk_codes_from(packed, k, start, len, |j, code| {
        if j == next_reload {
            let bi = (start + j) / block;
            let s = scales[bi];
            let tau = taus.map_or(0.0, |t| t[bi]);
            for (c, slot) in lut_scaled[..nvals].iter_mut().enumerate() {
                *slot = cb[c] * s + tau;
            }
            next_reload = j + (block - (start + j) % block);
            blocks += 1;
        }
        acc += lut_scaled[code] as f64 * x[j] as f64;
    });
    super::telem_packed_blocks().add(k, blocks);
    acc
}

/// Histogram (code-bucketed) packed dot over the same element range as
/// [`dot_packed`]: per block run it accumulates `hist[code] += x[j]`
/// in f64, then finishes with one 2^k-length dot against the scaled
/// LUT in code order. Reassociates the sum by code — see the module
/// docs for the tolerance contract. Allocates nothing.
#[allow(clippy::too_many_arguments)]
pub fn dot_packed_hist(
    packed: &[u8],
    k: u8,
    start: usize,
    len: usize,
    block: usize,
    scales: &[f32],
    taus: Option<&[f32]>,
    x: &[f32],
) -> f64 {
    assert!(block > 0);
    assert!(x.len() >= len, "x shorter than the code run");
    if len == 0 {
        return 0.0;
    }
    let last_block = (start + len - 1) / block;
    assert!(scales.len() > last_block, "need one scale per block");
    if let Some(t) = taus {
        assert!(t.len() > last_block, "need one tau per block");
    }
    let cb = lut(k).codebook();
    let nvals = 1usize << k;
    let mut hist = [0f64; 256];
    let mut acc = 0f64;
    let mut blocks = 0u64;
    let mut j = 0usize;
    while j < len {
        let bi = (start + j) / block;
        let run = (block - (start + j) % block).min(len - j);
        hist[..nvals].fill(0.0);
        walk_codes_from(packed, k, start + j, run, |t, code| {
            hist[code] += x[j + t] as f64;
        });
        let s = scales[bi];
        let tau = taus.map_or(0.0, |t| t[bi]);
        for (c, &h) in hist[..nvals].iter().enumerate() {
            acc += h * ((cb[c] * s + tau) as f64);
        }
        blocks += 1;
        j += run;
    }
    super::telem_packed_blocks().add(k, blocks);
    acc
}

/// `y = W_q·x` directly from packed storage — exact path. Allocates a
/// fresh output; see [`gemm_packed_into`] for the steady-state API.
pub fn gemm_packed(qt: &QuantizedTensor, x: &[f32]) -> Vec<f32> {
    let mut y = Vec::new();
    let mut scratch = PackedGemmScratch::new();
    gemm_packed_into(qt, x, &mut y, &mut scratch);
    y
}

/// [`gemm_packed`] into caller buffers: rows fan out across
/// `util::threads` workers (each row is one independent
/// [`dot_packed`]); once `y` and `scratch` are warm, repeated calls
/// allocate nothing and never materialize the dequantized matrix.
/// Bit-identical to dequantize-then-[`super::gemm_f32_reference`].
pub fn gemm_packed_into(
    qt: &QuantizedTensor,
    x: &[f32],
    y: &mut Vec<f32>,
    scratch: &mut PackedGemmScratch,
) {
    let (rows, cols) = matvec_dims(qt);
    assert_eq!(x.len(), cols, "x must have one entry per column");
    let _t = super::timers().packed.start();
    let (scales, taus) = dequant_consts(qt, scratch);
    y.clear();
    y.resize(rows, 0.0);
    if rows == 0 || cols == 0 {
        return;
    }
    let min_rows = if rows * cols < super::gemm_serial_below() {
        usize::MAX // force the serial path of par_chunks_mut_with
    } else {
        2
    };
    threads::par_chunks_mut_with(y, 1, min_rows, |r, yr| {
        yr[0] = dot_packed(&qt.packed, qt.k, r * cols, cols, qt.block, scales, taus, x) as f32;
    });
}

/// Serial reference twin of [`gemm_packed`] — the in-tree oracle. One
/// element-order walk over the whole tensor; each weight is
/// reconstructed with the dequantizer's exact `cb[code]·s + τ`
/// expression and folded into a per-row f64 accumulator. No stack LUT,
/// no threads, no shared code with the fast path beyond the bit walk.
pub fn gemm_packed_reference(qt: &QuantizedTensor, x: &[f32]) -> Vec<f32> {
    let (rows, cols) = matvec_dims(qt);
    assert_eq!(x.len(), cols, "x must have one entry per column");
    let _t = super::timers().reference.start();
    let cb = lut(qt.k).codebook();
    let scales = qt.scales.dequantize();
    let taus = qt.taus.as_ref().map(|t| t.dequantize());
    let mut y = vec![0f32; rows];
    if rows == 0 || cols == 0 {
        return y;
    }
    let mut acc = 0f64;
    let mut row = 0usize;
    walk_codes(&qt.packed, qt.k, qt.len, |i, code| {
        let bi = i / qt.block;
        let tau = taus.as_ref().map_or(0.0, |t| t[bi]);
        let w = cb[code] * scales[bi] + tau;
        acc += w as f64 * x[i % cols] as f64;
        if (i + 1) % cols == 0 {
            y[row] = acc as f32;
            row += 1;
            acc = 0.0;
        }
    });
    y
}

/// `y ≈ W_q·x` via per-block code histograms (QA-LoRA-style grouping).
/// Allocating wrapper over [`gemm_packed_hist_into`].
pub fn gemm_packed_hist(qt: &QuantizedTensor, x: &[f32]) -> Vec<f32> {
    let mut y = Vec::new();
    let mut scratch = PackedGemmScratch::new();
    gemm_packed_hist_into(qt, x, &mut y, &mut scratch);
    y
}

/// [`gemm_packed_hist`] into caller buffers: rows fan out in parallel,
/// each row running [`dot_packed_hist`]. Bit-identical to
/// [`gemm_packed_hist_reference`] (the per-row arithmetic is shared
/// and rows are independent); matches [`gemm_packed`] only to
/// tolerance.
pub fn gemm_packed_hist_into(
    qt: &QuantizedTensor,
    x: &[f32],
    y: &mut Vec<f32>,
    scratch: &mut PackedGemmScratch,
) {
    let (rows, cols) = matvec_dims(qt);
    assert_eq!(x.len(), cols, "x must have one entry per column");
    let _t = super::timers().packed_hist.start();
    let (scales, taus) = dequant_consts(qt, scratch);
    y.clear();
    y.resize(rows, 0.0);
    if rows == 0 || cols == 0 {
        return;
    }
    let min_rows = if rows * cols < super::gemm_serial_below() {
        usize::MAX
    } else {
        2
    };
    threads::par_chunks_mut_with(y, 1, min_rows, |r, yr| {
        yr[0] = dot_packed_hist(&qt.packed, qt.k, r * cols, cols, qt.block, scales, taus, x) as f32;
    });
}

/// Serial twin of [`gemm_packed_hist`]: the same per-row histogram
/// arithmetic, one row at a time on the calling thread.
pub fn gemm_packed_hist_reference(qt: &QuantizedTensor, x: &[f32]) -> Vec<f32> {
    let (rows, cols) = matvec_dims(qt);
    assert_eq!(x.len(), cols, "x must have one entry per column");
    let _t = super::timers().reference.start();
    let mut scratch = PackedGemmScratch::new();
    let (scales, taus) = dequant_consts(qt, &mut scratch);
    let mut y = vec![0f32; rows];
    if cols == 0 {
        return y;
    }
    for (r, yr) in y.iter_mut().enumerate() {
        *yr = dot_packed_hist(&qt.packed, qt.k, r * cols, cols, qt.block, scales, taus, x) as f32;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::icq::IcqConfig;
    use crate::util::{Rng, Tensor};

    fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
        assert_eq!(got.len(), want.len(), "{ctx}: length");
        for (i, (a, b)) in got.iter().zip(want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{ctx} i={i}: {a} vs {b}");
        }
    }

    fn dequant_oracle(qt: &QuantizedTensor, x: &[f32]) -> Vec<f32> {
        let (rows, cols) = matvec_dims(qt);
        let w = qt.dequantize();
        super::super::gemm_f32_reference(w.data(), x, rows, cols, 1)
    }

    #[test]
    fn packed_matches_dequant_oracle_all_k() {
        let mut rng = Rng::new(80);
        for k in [2u8, 3, 4, 8] {
            for &(rows, cols) in &[(4usize, 64usize), (7, 65), (16, 100), (33, 96)] {
                let w = Tensor::new(&[rows, cols], rng.normal_vec(rows * cols, 0.0, 0.3));
                let x = rng.normal_vec(cols, 0.0, 1.0);
                for icq in [None, Some(IcqConfig::default())] {
                    let qt = QuantizedTensor::quantize(&w, k, 64, icq.as_ref());
                    let want = dequant_oracle(&qt, &x);
                    let ctx = format!("k={k} {rows}x{cols} icq={}", icq.is_some());
                    assert_bits_eq(&gemm_packed(&qt, &x), &want, &ctx);
                    assert_bits_eq(&gemm_packed_reference(&qt, &x), &want, &ctx);
                }
            }
        }
    }

    #[test]
    fn packed_handles_unaligned_blocks_and_partial_tails() {
        // block*k % 8 != 0 geometries and rows that straddle blocks
        let mut rng = Rng::new(81);
        for &(k, block, rows, cols) in
            &[(3u8, 10usize, 5usize, 13usize), (5, 9, 4, 21), (2, 3, 6, 7), (7, 11, 3, 40)]
        {
            let w = Tensor::new(&[rows, cols], rng.normal_vec(rows * cols, 0.0, 0.2));
            let x = rng.normal_vec(cols, 0.0, 1.0);
            let qt = QuantizedTensor::quantize(&w, k, block, None);
            let want = dequant_oracle(&qt, &x);
            let ctx = format!("k={k} block={block} {rows}x{cols}");
            assert_bits_eq(&gemm_packed(&qt, &x), &want, &ctx);
            assert_bits_eq(&gemm_packed_reference(&qt, &x), &want, &ctx);
        }
    }

    #[test]
    fn zero_blocks_and_degenerate_shapes() {
        let w = Tensor::new(&[3, 64], vec![0.0f32; 192]);
        let qt = QuantizedTensor::quantize(&w, 4, 64, None);
        let x = vec![1.0f32; 64];
        assert_eq!(gemm_packed(&qt, &x), vec![0.0; 3]);

        // 1-D tensor: len×1 column vector
        let mut rng = Rng::new(82);
        let w = Tensor::new(&[70], rng.normal_vec(70, 0.0, 0.1));
        let qt = QuantizedTensor::quantize(&w, 4, 64, None);
        let got = gemm_packed(&qt, &[2.0]);
        assert_bits_eq(&got, &dequant_oracle(&qt, &[2.0]), "1-D");
    }

    #[test]
    fn hist_twins_bit_identical_and_close_to_exact() {
        let mut rng = Rng::new(83);
        for k in [2u8, 4, 8] {
            let (rows, cols) = (9usize, 130usize);
            let w = Tensor::new(&[rows, cols], rng.normal_vec(rows * cols, 0.0, 0.3));
            let x = rng.normal_vec(cols, 0.0, 1.0);
            let qt = QuantizedTensor::quantize(&w, k, 64, None);
            let fast = gemm_packed_hist(&qt, &x);
            let twin = gemm_packed_hist_reference(&qt, &x);
            assert_bits_eq(&fast, &twin, &format!("hist twins k={k}"));
            let exact = gemm_packed(&qt, &x);
            for (i, (h, e)) in fast.iter().zip(&exact).enumerate() {
                let tol = 1e-4 * (1.0 + e.abs());
                assert!((h - e).abs() <= tol, "k={k} i={i}: hist {h} vs exact {e}");
            }
        }
    }

    #[test]
    fn dot_packed_respects_start_origin() {
        // slicing the stream and re-basing start must agree with the
        // full-tensor walk — the native fingerprint tiles rely on this
        let mut rng = Rng::new(84);
        let n = 256usize;
        let w = Tensor::new(&[n], rng.normal_vec(n, 0.0, 0.2));
        let qt = QuantizedTensor::quantize(&w, 4, 64, None);
        let scales = qt.scales.dequantize();
        let x = rng.normal_vec(n, 0.0, 1.0);
        let whole = dot_packed(&qt.packed, qt.k, 0, n, qt.block, &scales, None, &x);
        let a = dot_packed(&qt.packed, qt.k, 0, 128, qt.block, &scales, None, &x[..128]);
        let b = dot_packed(&qt.packed, qt.k, 128, 128, qt.block, &scales, None, &x[128..]);
        // two half-dots re-associate the sum, so compare to the same split
        let mut acc = 0f64;
        let wd = qt.dequantize();
        for (&wv, &xv) in wd.data().iter().zip(&x).take(128) {
            acc += wv as f64 * xv as f64;
        }
        assert_eq!(a.to_bits(), acc.to_bits(), "first half");
        let mut acc2 = 0f64;
        for (&wv, &xv) in wd.data().iter().zip(&x).skip(128) {
            acc2 += wv as f64 * xv as f64;
        }
        assert_eq!(b.to_bits(), acc2.to_bits(), "re-based second half");
        let mut acc_whole = 0f64;
        for (&wv, &xv) in wd.data().iter().zip(&x) {
            acc_whole += wv as f64 * xv as f64;
        }
        assert_eq!(whole.to_bits(), acc_whole.to_bits(), "whole");
    }
}
