//! Dense f32 GEMM — blocked fast path + serial reference oracle.
//!
//! Layout convention: all matrices are row-major, `C[m×n] = A[m×k] ·
//! B[k×n]`. Accumulation is f64 per output element, always in
//! k-index order from 0 — the blocked kernel tiles *i* (row panels,
//! parallel) and *j* (column stripes) but never splits the k
//! reduction, so it is bit-identical to [`gemm_f32_reference`] for
//! every shape and every stripe width.

use crate::util::threads;

/// Hard upper bound on the column-stripe width (`IRQLORA_GEMM_BLOCK`
/// is capped to it): the blocked kernel keeps one f64 accumulator per
/// stripe column on the stack, and this constant sizes that buffer.
/// Mirrors [`crate::util::env::GEMM_BLOCK_CAP`].
pub const GEMM_BLOCK_MAX: usize = 256;

fn check_dims(a: &[f32], b: &[f32], m: usize, kd: usize, n: usize) {
    assert_eq!(a.len(), m * kd, "lhs must be m×k row-major");
    assert_eq!(b.len(), kd * n, "rhs must be k×n row-major");
}

/// Serial reference GEMM: the in-tree oracle. One f64 accumulator per
/// output element, k-index order, no tiling, no threads.
pub fn gemm_f32_reference(a: &[f32], b: &[f32], m: usize, kd: usize, n: usize) -> Vec<f32> {
    let mut out = Vec::new();
    gemm_f32_reference_into(a, b, m, kd, n, &mut out);
    out
}

/// [`gemm_f32_reference`] into a caller buffer (allocation-free once
/// `out` has capacity).
pub fn gemm_f32_reference_into(
    a: &[f32],
    b: &[f32],
    m: usize,
    kd: usize,
    n: usize,
    out: &mut Vec<f32>,
) {
    check_dims(a, b, m, kd, n);
    let _t = super::timers().reference.start();
    out.clear();
    out.resize(m * n, 0.0);
    for i in 0..m {
        let arow = &a[i * kd..(i + 1) * kd];
        for j in 0..n {
            let mut acc = 0f64;
            for (p, &av) in arow.iter().enumerate() {
                acc += av as f64 * b[p * n + j] as f64;
            }
            out[i * n + j] = acc as f32;
        }
    }
}

/// Blocked dense GEMM: row panels in parallel, column stripes of
/// `IRQLORA_GEMM_BLOCK` width walked with a stack-resident f64
/// accumulator per stripe column (B is streamed row-wise through the
/// stripe, so both operands move through cache linearly). Bit-identical
/// to [`gemm_f32_reference`]. Shapes under `IRQLORA_GEMM_SERIAL_BELOW`
/// multiply-adds run serially — same arithmetic, no dispatch cost.
pub fn gemm_f32(a: &[f32], b: &[f32], m: usize, kd: usize, n: usize) -> Vec<f32> {
    let mut out = Vec::new();
    gemm_f32_into(a, b, m, kd, n, &mut out);
    out
}

/// [`gemm_f32`] into a caller buffer (allocation-free once `out` has
/// capacity — the per-stripe accumulator lives on the stack).
pub fn gemm_f32_into(a: &[f32], b: &[f32], m: usize, kd: usize, n: usize, out: &mut Vec<f32>) {
    check_dims(a, b, m, kd, n);
    let _t = super::timers().blocked.start();
    out.clear();
    out.resize(m * n, 0.0);
    if m == 0 || n == 0 {
        return;
    }
    let bw = super::gemm_block().clamp(1, GEMM_BLOCK_MAX);
    let min_rows = if m * kd * n < super::gemm_serial_below() {
        usize::MAX // force the serial path of par_chunks_mut_with
    } else {
        2
    };
    threads::par_chunks_mut_with(out, n, min_rows, |i, row| {
        let arow = &a[i * kd..(i + 1) * kd];
        let mut acc = [0f64; GEMM_BLOCK_MAX];
        let mut j0 = 0usize;
        while j0 < n {
            let w = (n - j0).min(bw);
            acc[..w].fill(0.0);
            for (p, &av) in arow.iter().enumerate() {
                let av = av as f64;
                let brow = &b[p * n + j0..p * n + j0 + w];
                for (slot, &bv) in acc[..w].iter_mut().zip(brow) {
                    *slot += av * bv as f64;
                }
            }
            for (jj, &v) in acc[..w].iter().enumerate() {
                row[j0 + jj] = v as f32;
            }
            j0 += w;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
        assert_eq!(got.len(), want.len(), "{ctx}: length");
        for (i, (a, b)) in got.iter().zip(want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{ctx} i={i}: {a} vs {b}");
        }
    }

    #[test]
    fn blocked_matches_reference_ragged_shapes() {
        let mut rng = Rng::new(70);
        // primes, ones, stripe-straddling and panel-straddling sizes
        for &(m, kd, n) in &[
            (1usize, 1usize, 1usize),
            (1, 7, 129),
            (3, 1, 2),
            (17, 13, 5),
            (64, 64, 64),
            (65, 33, 130),
            (128, 3, 257),
            (5, 300, 67),
        ] {
            let a = rng.normal_vec(m * kd, 0.0, 1.0);
            let b = rng.normal_vec(kd * n, 0.0, 1.0);
            let want = gemm_f32_reference(&a, &b, m, kd, n);
            let got = gemm_f32(&a, &b, m, kd, n);
            assert_bits_eq(&got, &want, &format!("{m}x{kd}x{n}"));
        }
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        assert!(gemm_f32(&[], &[], 0, 4, 0).is_empty());
        // kd = 0: well-defined all-zero result
        let out = gemm_f32(&[], &[], 3, 0, 2);
        assert_eq!(out, vec![0.0; 6]);
        assert_bits_eq(&out, &gemm_f32_reference(&[], &[], 3, 0, 2), "kd=0");
    }

    #[test]
    fn into_reuses_buffer_and_overwrites_stale_contents() {
        let mut rng = Rng::new(71);
        let (m, kd, n) = (9, 11, 13);
        let a = rng.normal_vec(m * kd, 0.0, 1.0);
        let b = rng.normal_vec(kd * n, 0.0, 1.0);
        let mut out = vec![f32::NAN; 999]; // wrong size, garbage contents
        gemm_f32_into(&a, &b, m, kd, n, &mut out);
        assert_bits_eq(&out, &gemm_f32_reference(&a, &b, m, kd, n), "reuse");
    }

    #[test]
    fn matvec_as_n_equals_one() {
        let mut rng = Rng::new(72);
        let (m, kd) = (33, 48);
        let w = rng.normal_vec(m * kd, 0.0, 0.5);
        let x = rng.normal_vec(kd, 0.0, 0.5);
        let got = gemm_f32(&w, &x, m, kd, 1);
        let want = gemm_f32_reference(&w, &x, m, kd, 1);
        assert_bits_eq(&got, &want, "matvec");
    }
}
