//! Compute-kernel layer — dense and packed-domain GEMM with the
//! repo's oracle discipline.
//!
//! Three entry points, one contract:
//!
//! - [`gemm_f32`] — cache-blocked dense `C = A·B` (row panels in
//!   parallel via `util::threads`, column stripes of
//!   `IRQLORA_GEMM_BLOCK` width, one stack-resident f64 accumulator
//!   per stripe column). The `lora::merge` dense-delta path and every
//!   future dense multiply route through it.
//! - [`gemm_packed`] — the headline: `y = W_q·x` computed **directly
//!   from packed NF-k storage**. Per quantization block the kernel
//!   builds the 2^k-entry absmax-scaled LUT `cb[c]·s + τ` once — the
//!   dequantizer's exact f32 expression, evaluated once per code
//!   instead of once per weight — then streams the block's codes
//!   through [`crate::quant::fused::walk_codes_from`] accumulating
//!   `lut[code_j]·x_j` in f64. The dequantized tensor is never
//!   materialized; per-block k only changes which LUT is loaded, which
//!   is what makes mixed-k plans from `precision::` pay off at serve
//!   time. A faster approximate variant, [`gemm_packed_hist`], buckets
//!   x-contributions per code first (QA-LoRA's group-wise insight) and
//!   does one 2^k-length dot per block — see its docs for the
//!   tolerance contract.
//! - a serial `*_reference` twin per kernel, kept as the in-tree
//!   oracle.
//!
//! ## Bit-identity contract
//!
//! The fast paths never split or reorder a k-reduction: every output
//! element is one f64 accumulator fed in index order, so the blocked /
//! parallel / packed variants are **bit-identical** to their serial
//! references (and [`gemm_packed`] is bit-identical to
//! dequantize-then-[`gemm_f32_reference`] — same weights bitwise, same
//! multiply-add DAG). Only *where* each subterm is computed moves.
//! The one deliberate exception is [`gemm_packed_hist`]: bucketing
//! reassociates the sum by code, which is exactly what buys its speed,
//! so it carries its own serial twin (bit-identical to it) and a
//! relative-error tolerance against the exact kernel instead of a
//! bit-identity claim. `rust/tests/kernel_identity.rs` enforces all of
//! this over ragged shapes, partial/zero blocks, k ∈ {2,3,4,8} and
//! mixed-k planned models.
//!
//! Telemetry: `kernel.gemm_time{kind=reference|blocked|packed|packed_hist}`
//! timers and the `kernel.packed_blocks{k=}` counter (per-block LUT
//! loads — the packed kernels' unit of work).

use std::sync::OnceLock;

mod gemm;
mod packed;

pub use gemm::{
    gemm_f32, gemm_f32_into, gemm_f32_reference, gemm_f32_reference_into, GEMM_BLOCK_MAX,
};
pub use packed::{
    dot_packed, dot_packed_hist, gemm_packed, gemm_packed_hist, gemm_packed_hist_into,
    gemm_packed_hist_reference, gemm_packed_into, gemm_packed_reference, PackedGemmScratch,
};

/// Cached `kernel.gemm_time{kind=...}` timers, resolved once per
/// process (no-ops unless `IRQLORA_TELEMETRY=1`).
struct KernelTimers {
    reference: crate::telemetry::Timer,
    blocked: crate::telemetry::Timer,
    packed: crate::telemetry::Timer,
    packed_hist: crate::telemetry::Timer,
}

fn timers() -> &'static KernelTimers {
    static T: OnceLock<KernelTimers> = OnceLock::new();
    T.get_or_init(|| {
        let reg = crate::telemetry::global();
        KernelTimers {
            reference: reg.timer("kernel.gemm_time", &[("kind", "reference")]),
            blocked: reg.timer("kernel.gemm_time", &[("kind", "blocked")]),
            packed: reg.timer("kernel.gemm_time", &[("kind", "packed")]),
            packed_hist: reg.timer("kernel.gemm_time", &[("kind", "packed_hist")]),
        }
    })
}

/// Cached `kernel.packed_blocks{k=}` counter: one increment per
/// per-block LUT load in the packed kernels.
fn telem_packed_blocks() -> &'static crate::telemetry::PerK {
    static C: OnceLock<crate::telemetry::PerK> = OnceLock::new();
    C.get_or_init(|| crate::telemetry::PerK::resolve("kernel.packed_blocks"))
}

/// `IRQLORA_GEMM_BLOCK`, latched on first kernel call. The kernels
/// guarantee allocation-free steady-state `*_into` calls, and an env
/// read allocates its key — so unlike the serving knobs these two are
/// resolved once per process (the repo's tests never mutate the
/// process environment; see `util::env` module docs).
fn gemm_block() -> usize {
    static V: OnceLock<usize> = OnceLock::new();
    *V.get_or_init(crate::util::env::gemm_block)
}

/// `IRQLORA_GEMM_SERIAL_BELOW`, latched on first kernel call (see
/// [`gemm_block`] for why).
fn gemm_serial_below() -> usize {
    static V: OnceLock<usize> = OnceLock::new();
    *V.get_or_init(crate::util::env::gemm_serial_below)
}
