//! Pre-training corpus: facts stated as sentences, packed into fixed
//! sequences. The base model learns p(value | category, entity) from
//! this — the "knowledge" that quantization later erodes.

use crate::util::Rng;

use super::*;

/// One fact sentence: `cat e1 e2 Q SEP val EOS` (7 tokens).
pub fn fact_sentence(world: &World, cat: usize, e1: u32, e2: u32) -> [i32; 7] {
    [
        cat_token(cat),
        entity_token(e1),
        entity_token(e2),
        Q,
        SEP,
        world.mmlu_value_token(cat, e1, e2),
        EOS,
    ]
}

/// A pre-training batch: sequences of packed fact sentences. Targets
/// supervise only the value and EOS positions — entity tokens are
/// uniform random (unlearnable), and masking them focuses capacity on
/// the facts themselves (the knowledge quantization later erodes).
pub struct PretrainBatch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
}

pub fn pretrain_batch(
    world: &World,
    rng: &mut Rng,
    batch: usize,
    seq: usize,
) -> PretrainBatch {
    let mut tokens = Vec::with_capacity(batch * seq);
    for _ in 0..batch {
        let mut row = Vec::with_capacity(seq + 8);
        row.push(BOS);
        while row.len() < seq {
            let cat = rng.below(MMLU_GROUPS.len());
            let e1 = rng.below(N_ENTITIES) as u32;
            let e2 = rng.below(N_E2) as u32;
            row.extend_from_slice(&fact_sentence(world, cat, e1, e2));
        }
        row.truncate(seq);
        tokens.extend_from_slice(&row);
    }
    // supervise positions whose next token is a value or EOS
    let mut targets = vec![-1i32; batch * seq];
    for b in 0..batch {
        for t in 0..seq - 1 {
            let next = tokens[b * seq + t + 1];
            let is_value = next >= VALUE_BASE && next < VALUE_BASE + N_VALUES as i32;
            if is_value || next == EOS {
                targets[b * seq + t] = next;
            }
        }
    }
    PretrainBatch { tokens, targets }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes() {
        let w = World::new(1);
        let mut rng = Rng::new(1);
        let b = pretrain_batch(&w, &mut rng, 4, 32);
        assert_eq!(b.tokens.len(), 128);
        assert_eq!(b.targets.len(), 128);
    }

    #[test]
    fn targets_supervise_only_values_and_eos() {
        let w = World::new(2);
        let mut rng = Rng::new(2);
        let b = pretrain_batch(&w, &mut rng, 2, 64);
        let mut supervised = 0;
        for row in 0..2 {
            for t in 0..63 {
                let tgt = b.targets[row * 64 + t];
                if tgt >= 0 {
                    supervised += 1;
                    assert_eq!(tgt, b.tokens[row * 64 + t + 1]);
                    assert!(
                        tgt == EOS || (tgt >= VALUE_BASE && tgt < VALUE_BASE + N_VALUES as i32)
                    );
                }
            }
            assert_eq!(b.targets[row * 64 + 63], -1);
        }
        assert!(supervised > 10, "some positions must be supervised");
    }

    #[test]
    fn rows_start_with_bos() {
        let w = World::new(3);
        let mut rng = Rng::new(3);
        let b = pretrain_batch(&w, &mut rng, 3, 24);
        for row in 0..3 {
            assert_eq!(b.tokens[row * 24], BOS);
        }
    }

    #[test]
    fn facts_are_consistent_with_world() {
        let w = World::new(4);
        let s = fact_sentence(&w, 2, 17, 5);
        assert_eq!(s[0], cat_token(2));
        assert_eq!(s[1], entity_token(17));
        assert_eq!(s[2], entity_token(5));
        assert_eq!(s[5], w.mmlu_value_token(2, 17, 5));
    }

    #[test]
    fn tokens_in_vocab() {
        let w = World::new(5);
        let mut rng = Rng::new(5);
        let b = pretrain_batch(&w, &mut rng, 4, 64);
        assert!(b.tokens.iter().all(|&t| t >= 0 && (t as usize) < VOCAB));
    }
}
