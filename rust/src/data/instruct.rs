//! Instruction-finetuning datasets: alpaca-syn (single template family)
//! and flan-syn (8-template multi-task mixture). Loss is masked to the
//! response tokens only, exactly like instruction tuning on Alpaca /
//! Flan v2 in the paper.

use crate::util::Rng;

use super::*;

/// Which synthetic instruction dataset to draw from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dataset {
    /// Single instruction template (Alpaca analog).
    AlpacaSyn,
    /// 8-template multi-task mixture incl. CSQA-suite facts
    /// (Flan v2 analog — broader supervision, better transfer).
    FlanSyn,
}

impl Dataset {
    pub fn paper_name(&self) -> &'static str {
        match self {
            Dataset::AlpacaSyn => "Alpaca",
            Dataset::FlanSyn => "Flan v2",
        }
    }
}

/// One finetuning example: prompt tokens + single-token answer.
#[derive(Clone, Debug)]
pub struct Example {
    pub prompt: Vec<i32>,
    pub answer: i32,
}

/// Build one example. Alpaca uses instruction template 0 over MMLU
/// facts; Flan mixes 8 templates over MMLU + CSQA facts.
pub fn example(world: &World, ds: Dataset, rng: &mut Rng) -> Example {
    let template = match ds {
        Dataset::AlpacaSyn => 0usize,
        Dataset::FlanSyn => rng.below(8),
    };
    let e1 = rng.below(N_ENTITIES) as u32;
    let e2 = rng.below(N_E2) as u32;
    let (task_tok, answer) = match ds {
        Dataset::AlpacaSyn => {
            let cat = rng.below(MMLU_GROUPS.len());
            (cat_token(cat), world.mmlu_value_token(cat, e1, e2))
        }
        Dataset::FlanSyn => {
            // half MMLU categories, half CSQA suites — the "1,836 task
            // mixture" effect at miniature scale
            if rng.chance(0.5) {
                let cat = rng.below(MMLU_GROUPS.len());
                (cat_token(cat), world.mmlu_value_token(cat, e1, e2))
            } else {
                let suite = rng.below(CSQA_SUITES.len());
                (suite_token(suite), world.csqa_value_token(suite, e1, e2))
            }
        }
    };
    let mut prompt = vec![BOS, INSTR_BASE + template as i32];
    if template % 2 == 1 {
        // template variant: entities before task token
        prompt.push(entity_token(e1));
        prompt.push(entity_token(e2));
        prompt.push(task_tok);
    } else {
        prompt.push(task_tok);
        prompt.push(entity_token(e1));
        prompt.push(entity_token(e2));
    }
    prompt.push(Q);
    if template >= 4 {
        prompt.push(INSTR_BASE + 8 + template as i32); // extra style token
    }
    prompt.push(SEP);
    Example { prompt, answer }
}

/// A finetuning batch: fixed-shape token/target arrays; targets are -1
/// everywhere except the answer and EOS positions.
pub struct InstructBatch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
}

pub fn instruct_batch(
    world: &World,
    ds: Dataset,
    rng: &mut Rng,
    batch: usize,
    seq: usize,
) -> InstructBatch {
    let mut tokens = vec![PAD; batch * seq];
    let mut targets = vec![-1i32; batch * seq];
    for b in 0..batch {
        // pack several examples per row to use the full context
        let mut pos = 0usize;
        loop {
            let ex = example(world, ds, rng);
            let need = ex.prompt.len() + 2; // + answer + EOS
            if pos + need > seq {
                break;
            }
            let row = &mut tokens[b * seq..(b + 1) * seq];
            let trow = &mut targets[b * seq..(b + 1) * seq];
            row[pos..pos + ex.prompt.len()].copy_from_slice(&ex.prompt);
            let ans_pos = pos + ex.prompt.len();
            row[ans_pos] = ex.answer;
            row[ans_pos + 1] = EOS;
            // next-token targets: predict answer at SEP, EOS at answer
            trow[ans_pos - 1] = ex.answer;
            trow[ans_pos] = EOS;
            pos = ans_pos + 2;
        }
    }
    InstructBatch { tokens, targets }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpaca_single_template() {
        let w = World::new(1);
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let ex = example(&w, Dataset::AlpacaSyn, &mut rng);
            assert_eq!(ex.prompt[1], INSTR_BASE);
            assert_eq!(*ex.prompt.last().unwrap(), SEP);
        }
    }

    #[test]
    fn flan_uses_many_templates() {
        let w = World::new(2);
        let mut rng = Rng::new(2);
        let templates: std::collections::HashSet<i32> = (0..200)
            .map(|_| example(&w, Dataset::FlanSyn, &mut rng).prompt[1])
            .collect();
        assert!(templates.len() >= 6, "flan should mix templates: {templates:?}");
    }

    #[test]
    fn batch_masks_prompts() {
        let w = World::new(3);
        let mut rng = Rng::new(3);
        let b = instruct_batch(&w, Dataset::AlpacaSyn, &mut rng, 4, 64);
        assert_eq!(b.tokens.len(), 256);
        // masked positions strictly outnumber supervised ones
        let masked = b.targets.iter().filter(|&&t| t == -1).count();
        let supervised = b.targets.iter().filter(|&&t| t >= 0).count();
        assert!(supervised > 0);
        assert!(masked > supervised);
        // every supervised target is a value token or EOS
        for &t in b.targets.iter().filter(|&&t| t >= 0) {
            assert!(
                t == EOS || (t >= VALUE_BASE && t < VALUE_BASE + N_VALUES as i32),
                "target {t}"
            );
        }
    }

    #[test]
    fn answers_match_world_facts() {
        let w = World::new(4);
        let mut rng = Rng::new(4);
        let ex = example(&w, Dataset::AlpacaSyn, &mut rng);
        // reconstruct (cat, e1, e2) from prompt (template 0 order)
        let cat = (ex.prompt[2] - CAT_BASE) as usize;
        let e1 = (ex.prompt[3] - ENTITY_BASE) as u32;
        let e2 = (ex.prompt[4] - ENTITY_BASE) as u32;
        assert_eq!(ex.answer, w.mmlu_value_token(cat, e1, e2));
    }

    #[test]
    fn deterministic_given_seed() {
        let w = World::new(5);
        let b1 = instruct_batch(&w, Dataset::FlanSyn, &mut Rng::new(9), 2, 48);
        let b2 = instruct_batch(&w, Dataset::FlanSyn, &mut Rng::new(9), 2, 48);
        assert_eq!(b1.tokens, b2.tokens);
        assert_eq!(b1.targets, b2.targets);
    }
}
