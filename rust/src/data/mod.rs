//! Synthetic data substrate — the substitution for Alpaca / Flan v2 /
//! MMLU / CommonsenseQA (see DESIGN.md §2).
//!
//! A deterministic "relational world" maps (category, entity-pair)
//! triples to value tokens via seeded hashing. Pair facts put the base
//! model in a capacity-limited regime (~8K facts, see [`N_E2`]), so
//! knowledge is partial and *graded* — quantization noise measurably
//! erases marginal facts instead of leaving a saturated benchmark. Pre-training sees facts stated as
//! sentences; instruction finetuning sees the same facts in QA format;
//! evaluation asks multiple-choice questions about held-out entities.
//! Because facts are stored in the base model's weights, quantization
//! that loses weight information measurably loses facts — which is
//! exactly the degradation ICQ/IEC are designed to mitigate.
//!
//! Vocabulary layout (512 tokens):
//! ```text
//! 0 PAD | 1 BOS | 2 EOS | 3 SEP | 4 Q
//! 8..16    category tokens (4 MMLU groups + 4 spare)
//! 16..32   CSQA suite tokens
//! 32..64   instruction-template tokens
//! 64..320  entity tokens (256)
//! 320..448 value tokens (128)
//! 448..512 filler tokens
//! ```

pub mod corpus;
pub mod evalset;
pub mod instruct;

use crate::util::rng::splitmix64;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3;
pub const Q: i32 = 4;

pub const CAT_BASE: i32 = 8;
pub const SUITE_BASE: i32 = 16;
pub const INSTR_BASE: i32 = 32;
pub const ENTITY_BASE: i32 = 64;
pub const N_ENTITIES: usize = 256;
/// Second-slot entity range (facts are (cat, e1, e2) with e2 < N_E2).
/// 2 gives 4·256·2 = 2,048 facts — calibrated so a NanoLLaMA base
/// reaches high-but-fragile knowledge within ~1K pretraining steps,
/// the regime where low-bit weight corruption measurably erases facts
/// (random associative triples are slow to memorize; see
/// EXPERIMENTS.md §Calibration for the sweep that picked this).
pub const N_E2: usize = 2;
pub const VALUE_BASE: i32 = 320;
pub const N_VALUES: usize = 128;
pub const FILLER_BASE: i32 = 448;
pub const VOCAB: usize = 512;

/// The four MMLU category groups and their value-space sizes (the
/// difficulty knob: more candidate values = harder category, mirroring
/// the Hums/STEM/Social/Other accuracy spread in the paper's tables).
pub const MMLU_GROUPS: [(&str, usize); 4] = [
    ("Hums.", 48),
    ("STEM", 64),
    ("Social", 32),
    ("Other", 24),
];

/// The seven CommonsenseQA-analog suites: (name, value-space, #choices).
pub const CSQA_SUITES: [(&str, usize, usize); 7] = [
    ("HellaSwag", 48, 4),
    ("PIQA", 24, 2),
    ("WinoGrande", 28, 2),
    ("ARC-e", 24, 4),
    ("ARC-c", 56, 4),
    ("BoolQ", 16, 2),
    ("OBQA", 40, 4),
];

/// A deterministic relational world.
#[derive(Clone, Copy, Debug)]
pub struct World {
    pub seed: u64,
}

impl World {
    pub fn new(seed: u64) -> World {
        World { seed }
    }

    /// The ground-truth value index for (relation, e1, e2), uniform in
    /// [0, space). `relation` namespaces MMLU categories (0..4) and
    /// CSQA suites (16..23). e1 ranges over all entities, e2 over the
    /// first [`N_E2`] (the capacity-limit knob).
    pub fn value_of(&self, relation: u32, e1: u32, e2: u32, space: usize) -> u32 {
        let mut s = self.seed
            ^ ((relation as u64) << 48)
            ^ (e1 as u64).wrapping_mul(0x9E37_79B9)
            ^ (e2 as u64).wrapping_mul(0xC2B2_AE3D);
        (splitmix64(&mut s) % space as u64) as u32
    }

    /// Value token for an MMLU category fact.
    pub fn mmlu_value_token(&self, cat: usize, e1: u32, e2: u32) -> i32 {
        let space = MMLU_GROUPS[cat].1;
        VALUE_BASE + self.value_of(cat as u32, e1, e2, space) as i32
    }

    /// Value token for a CSQA suite fact.
    pub fn csqa_value_token(&self, suite: usize, e1: u32, e2: u32) -> i32 {
        let space = CSQA_SUITES[suite].1;
        VALUE_BASE + self.value_of(16 + suite as u32, e1, e2, space) as i32
    }
}

pub fn cat_token(cat: usize) -> i32 {
    CAT_BASE + cat as i32
}

pub fn suite_token(suite: usize) -> i32 {
    SUITE_BASE + suite as i32
}

pub fn entity_token(e: u32) -> i32 {
    ENTITY_BASE + e as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_ranges_disjoint() {
        assert!(CAT_BASE >= 8 && (CAT_BASE + 8) <= SUITE_BASE);
        assert!(SUITE_BASE + 7 < INSTR_BASE);
        assert!(INSTR_BASE + 32 <= ENTITY_BASE);
        assert!(ENTITY_BASE + N_ENTITIES as i32 <= VALUE_BASE);
        assert!(VALUE_BASE + N_VALUES as i32 <= FILLER_BASE);
        assert!(FILLER_BASE < VOCAB as i32);
    }

    #[test]
    fn world_is_deterministic() {
        let w1 = World::new(42);
        let w2 = World::new(42);
        for e in 0..50 {
            for c in 0..4 {
                assert_eq!(w1.mmlu_value_token(c, e, e % 7), w2.mmlu_value_token(c, e, e % 7));
            }
        }
    }

    #[test]
    fn different_seeds_differ() {
        let w1 = World::new(1);
        let w2 = World::new(2);
        let diff = (0..100)
            .filter(|&e| w1.mmlu_value_token(0, e, 3) != w2.mmlu_value_token(0, e, 3))
            .count();
        assert!(diff > 50);
    }

    #[test]
    fn values_span_space() {
        let w = World::new(7);
        let space = MMLU_GROUPS[1].1;
        let mut seen = std::collections::HashSet::new();
        for e in 0..1000u32 {
            let v = w.value_of(1, e, e % N_E2 as u32, space);
            assert!((v as usize) < space);
            seen.insert(v);
        }
        assert!(seen.len() > space * 3 / 4, "values should cover the space");
    }

    #[test]
    fn both_pair_slots_matter() {
        let w = World::new(8);
        let d1 = (0..200u32)
            .filter(|&e| w.mmlu_value_token(0, e, 0) != w.mmlu_value_token(0, e, 1))
            .count();
        let d2 = (0..200u32)
            .filter(|&e| w.mmlu_value_token(0, 0, e % N_E2 as u32) != w.mmlu_value_token(0, 1, e % N_E2 as u32))
            .count();
        assert!(d1 > 100 && d2 > 100);
    }

    #[test]
    fn value_tokens_in_range() {
        let w = World::new(9);
        for s in 0..7 {
            for e in 0..100 {
                let t = w.csqa_value_token(s, e, e % N_E2 as u32);
                assert!(t >= VALUE_BASE && t < VALUE_BASE + N_VALUES as i32);
            }
        }
    }
}
