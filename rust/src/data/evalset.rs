//! Evaluation sets: SynMMLU (5-shot, 4 category groups) and SynCSQA
//! (0-shot, 7 suites). Items use held-out entities (the top quarter of
//! the entity range is never sampled by the finetuning generators'
//! packing loop — knowledge about them comes only from pre-training,
//! so eval measures what quantization preserved, plus the QA-format
//! competence finetuning adds).

use crate::util::Rng;

use super::*;

/// One multiple-choice item, fully tokenized.
#[derive(Clone, Debug)]
pub struct McItem {
    /// Prompt tokens (ends with SEP; answer position is prompt.len()-1's
    /// next-token distribution).
    pub prompt: Vec<i32>,
    /// Candidate answer tokens (single token each).
    pub choices: Vec<i32>,
    /// Index of the correct choice.
    pub correct: usize,
    /// Group index (MMLU category / CSQA suite).
    pub group: usize,
}

fn distractors(
    world: &World,
    relation: u32,
    space: usize,
    correct: i32,
    n: usize,
    rng: &mut Rng,
) -> Vec<i32> {
    let _ = (world, relation);
    let mut out = Vec::with_capacity(n);
    let mut guard = 0;
    while out.len() < n && guard < 1000 {
        guard += 1;
        let v = VALUE_BASE + rng.below(space) as i32;
        if v != correct && !out.contains(&v) {
            out.push(v);
        }
    }
    while out.len() < n {
        // degenerate tiny spaces: pad with wrapped values
        out.push(VALUE_BASE + ((correct - VALUE_BASE + 1 + out.len() as i32) % space as i32));
    }
    out
}

/// Build one 5-shot SynMMLU item for a category.
pub fn mmlu_item(world: &World, cat: usize, rng: &mut Rng, shots: usize) -> McItem {
    let space = MMLU_GROUPS[cat].1;
    let mut prompt = vec![BOS];
    for _ in 0..shots {
        let e1 = rng.below(N_ENTITIES) as u32;
        let e2 = rng.below(N_E2) as u32;
        prompt.extend_from_slice(&[
            cat_token(cat),
            entity_token(e1),
            entity_token(e2),
            Q,
            SEP,
            world.mmlu_value_token(cat, e1, e2),
            EOS,
        ]);
    }
    let e1 = rng.below(N_ENTITIES) as u32;
    let e2 = rng.below(N_E2) as u32;
    prompt.extend_from_slice(&[cat_token(cat), entity_token(e1), entity_token(e2), Q, SEP]);
    let correct_tok = world.mmlu_value_token(cat, e1, e2);
    let mut choices = vec![correct_tok];
    choices.extend(distractors(world, cat as u32, space, correct_tok, 3, rng));
    // shuffle choices, remember where the correct one lands
    let mut order: Vec<usize> = (0..choices.len()).collect();
    rng.shuffle(&mut order);
    let shuffled: Vec<i32> = order.iter().map(|&i| choices[i]).collect();
    let correct = order.iter().position(|&i| i == 0).unwrap();
    McItem { prompt, choices: shuffled, correct, group: cat }
}

/// Build one 0-shot SynCSQA item for a suite.
pub fn csqa_item(world: &World, suite: usize, rng: &mut Rng) -> McItem {
    let (_, space, n_choices) = CSQA_SUITES[suite];
    let e1 = rng.below(N_ENTITIES) as u32;
    let e2 = rng.below(N_E2) as u32;
    let prompt = vec![BOS, suite_token(suite), entity_token(e1), entity_token(e2), Q, SEP];
    let correct_tok = world.csqa_value_token(suite, e1, e2);
    let mut choices = vec![correct_tok];
    choices.extend(distractors(
        world,
        16 + suite as u32,
        space,
        correct_tok,
        n_choices - 1,
        rng,
    ));
    let mut order: Vec<usize> = (0..choices.len()).collect();
    rng.shuffle(&mut order);
    let shuffled: Vec<i32> = order.iter().map(|&i| choices[i]).collect();
    let correct = order.iter().position(|&i| i == 0).unwrap();
    McItem { prompt, choices: shuffled, correct, group: suite }
}

/// A full SynMMLU evaluation set: `per_cat` items per category.
pub fn mmlu_set(world: &World, per_cat: usize, seed: u64) -> Vec<McItem> {
    let mut rng = Rng::new(seed ^ 0x4d4d4c55);
    let mut out = Vec::new();
    for cat in 0..MMLU_GROUPS.len() {
        for _ in 0..per_cat {
            out.push(mmlu_item(world, cat, &mut rng, 5));
        }
    }
    out
}

/// A full SynCSQA evaluation set: `per_suite` items per suite.
pub fn csqa_set(world: &World, per_suite: usize, seed: u64) -> Vec<McItem> {
    let mut rng = Rng::new(seed ^ 0x43535141);
    let mut out = Vec::new();
    for suite in 0..CSQA_SUITES.len() {
        for _ in 0..per_suite {
            out.push(csqa_item(world, suite, &mut rng));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mmlu_item_structure() {
        let w = World::new(1);
        let mut rng = Rng::new(1);
        let item = mmlu_item(&w, 2, &mut rng, 5);
        // 1 BOS + 5 shots * 7 + 5 query tokens
        assert_eq!(item.prompt.len(), 1 + 5 * 7 + 5);
        assert_eq!(*item.prompt.last().unwrap(), SEP);
        assert_eq!(item.choices.len(), 4);
        assert!(item.correct < 4);
        assert_eq!(item.group, 2);
    }

    #[test]
    fn correct_choice_is_world_fact() {
        let w = World::new(2);
        let mut rng = Rng::new(2);
        let item = mmlu_item(&w, 0, &mut rng, 5);
        let n = item.prompt.len();
        let e1 = (item.prompt[n - 4] - ENTITY_BASE) as u32;
        let e2 = (item.prompt[n - 3] - ENTITY_BASE) as u32;
        assert_eq!(item.choices[item.correct], w.mmlu_value_token(0, e1, e2));
    }

    #[test]
    fn choices_distinct() {
        let w = World::new(3);
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let item = mmlu_item(&w, 1, &mut rng, 5);
            let set: std::collections::HashSet<i32> =
                item.choices.iter().cloned().collect();
            assert_eq!(set.len(), item.choices.len());
        }
    }

    #[test]
    fn correct_position_unbiased() {
        let w = World::new(4);
        let mut rng = Rng::new(4);
        let mut counts = [0usize; 4];
        for _ in 0..400 {
            counts[mmlu_item(&w, 0, &mut rng, 5).correct] += 1;
        }
        for &c in &counts {
            assert!(c > 50, "positions should be shuffled: {counts:?}");
        }
    }

    #[test]
    fn csqa_choice_counts_per_suite() {
        let w = World::new(5);
        let mut rng = Rng::new(5);
        for (suite, &(_, _, n)) in CSQA_SUITES.iter().enumerate() {
            let item = csqa_item(&w, suite, &mut rng);
            assert_eq!(item.choices.len(), n);
        }
    }

    #[test]
    fn sets_are_deterministic() {
        let w = World::new(6);
        let a = mmlu_set(&w, 10, 99);
        let b = mmlu_set(&w, 10, 99);
        assert_eq!(a.len(), 40);
        assert_eq!(a[7].prompt, b[7].prompt);
        assert_eq!(a[7].correct, b[7].correct);
    }

    #[test]
    fn prompts_fit_sequence() {
        let w = World::new(7);
        for item in mmlu_set(&w, 20, 1).iter().chain(csqa_set(&w, 20, 1).iter()) {
            assert!(item.prompt.len() + 1 <= 128, "prompt too long");
        }
    }
}
