//! Applying a [`PrecisionPlan`] — the execution half of the
//! mixed-precision planner.
//!
//! Thin orchestration over
//! [`crate::coordinator::quantize::quantize_model_planned`]: each
//! projection tensor is ICQ-quantized at its plan-assigned bit-width,
//! producing a mixed-k `QuantizedModel` that flows through the same
//! evaluator / registry / server paths as a uniform-k one
//! (dequantization already dispatches per-tensor k through the fused
//! per-k LUTs in [`crate::quant::fused`]).

use anyhow::Result;

use crate::coordinator::quantize::{quantize_model_planned, QuantizedModel};
use crate::model::weights::NamedTensors;
use crate::quant::icq::IcqConfig;

use super::planner::{plan, PlannerConfig, PrecisionPlan};
use super::profile::{profile_model, ProfileConfig};

/// Profile `weights` and solve for a plan under `cfg`'s budget.
pub fn plan_model(
    weights: &NamedTensors,
    pcfg: &ProfileConfig,
    cfg: &PlannerConfig,
) -> Result<PrecisionPlan> {
    plan(&profile_model(weights, pcfg), cfg)
}

/// Quantize `weights` per the plan (ICQ NF-k with per-tensor k).
pub fn apply_plan(
    weights: &NamedTensors,
    plan: &PrecisionPlan,
    icq: &IcqConfig,
) -> Result<QuantizedModel> {
    quantize_model_planned(weights, plan, icq)
}

/// The full profile → plan → apply pipeline in one call.
pub fn plan_and_quantize(
    weights: &NamedTensors,
    pcfg: &ProfileConfig,
    cfg: &PlannerConfig,
) -> Result<(PrecisionPlan, QuantizedModel)> {
    let p = plan_model(weights, pcfg, cfg)?;
    let qm = apply_plan(weights, &p, &pcfg.icq)?;
    Ok((p, qm))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::profile::synthetic_model;

    #[test]
    fn plan_and_quantize_end_to_end() {
        let base = synthetic_model(1, 32, 11);
        let cfg = PlannerConfig::new(3.2);
        let (p, qm) = plan_and_quantize(&base, &ProfileConfig::default(), &cfg).unwrap();
        assert!(p.is_mixed());
        assert!(qm.plan.is_some());
        assert_eq!(qm.storage.len(), p.entries.len());
        // every stored tensor carries its planned k
        for (name, qt) in &qm.storage {
            assert_eq!(Some(qt.k), p.k_for(name), "{name}");
            assert!(qt.taus.is_some(), "{name}: planned path is ICQ");
        }
        // actual packed code bits honor the budget exactly
        let code_bits: usize = qm.storage.iter().map(|(_, qt)| qt.len * qt.k as usize).sum();
        let params: usize = qm.storage.iter().map(|(_, qt)| qt.len).sum();
        assert!(code_bits as f64 <= 3.2 * params as f64 + 1e-6);
        // non-projection tensors pass through untouched
        assert_eq!(
            qm.dequantized.get("embed").unwrap(),
            base.get("embed").unwrap()
        );
    }

    #[test]
    fn plan_block_size_is_honored_when_applying() {
        // regression: the planned path must quantize at the block the
        // plan was profiled at, not silently at DEFAULT_BLOCK
        let base = synthetic_model(1, 32, 14);
        let pcfg = ProfileConfig { block: 32, ..ProfileConfig::default() };
        let (p, qm) = plan_and_quantize(&base, &pcfg, &PlannerConfig::new(3.2)).unwrap();
        assert_eq!(p.block, 32);
        for (name, qt) in &qm.storage {
            assert_eq!(qt.block, 32, "{name}");
        }
        // the plan's exact storage accounting matches the artifacts
        let storage_bits: usize = qm.storage.iter().map(|(_, qt)| qt.storage_bits()).sum();
        assert_eq!(storage_bits, p.total_storage_bits());
    }

    #[test]
    fn apply_rejects_plan_missing_a_tensor() {
        let base = synthetic_model(1, 32, 12);
        let cfg = PlannerConfig::new(3.2);
        let mut p = plan_model(&base, &ProfileConfig::default(), &cfg).unwrap();
        p.entries.retain(|e| !e.name.ends_with(".wo"));
        let err = apply_plan(&base, &p, &IcqConfig::default()).unwrap_err().to_string();
        assert!(err.contains("missing from precision plan"), "{err}");
    }

    #[test]
    fn apply_rejects_plan_for_differently_sized_model() {
        // same architecture, same tensor NAMES, different width — the
        // likeliest stale-plan mistake; must error, not silently apply
        let small = synthetic_model(1, 32, 16);
        let large = synthetic_model(1, 64, 16);
        let p = plan_model(&small, &ProfileConfig::default(), &PlannerConfig::new(3.2))
            .unwrap();
        let err = apply_plan(&large, &p, &IcqConfig::default()).unwrap_err().to_string();
        assert!(err.contains("built for a different model"), "{err}");
    }

    #[test]
    fn apply_rejects_plan_with_unmatched_entries() {
        use crate::precision::planner::PlanEntry;

        // a stale plan (entries for tensors this model does not have)
        // must be rejected, not silently partially applied
        let base = synthetic_model(1, 32, 15);
        let cfg = PlannerConfig::new(3.2);
        let mut p = plan_model(&base, &ProfileConfig::default(), &cfg).unwrap();
        p.entries.push(PlanEntry {
            name: "l9.wq".into(),
            k: 4,
            n_params: 1024,
            entropy: 3.0,
            bits_per_weight: 4.25,
        });
        let err = apply_plan(&base, &p, &IcqConfig::default()).unwrap_err().to_string();
        assert!(err.contains("match no model tensor"), "{err}");
    }
}
