//! Information-budgeted mixed-precision planning.
//!
//! IR-QLoRA's premise is that the Shannon entropy of quantized codes
//! measures retained information (paper Eq. 7). This subsystem spends
//! a storage budget where that information is densest, turning the
//! single uniform bit-width the pipeline used to apply into a
//! per-tensor assignment over the whole 2–8-bit accuracy/size
//! frontier (cf. LowRA's fine-grained precision assignment and
//! QA-LoRA's adaptation balance in PAPERS.md):
//!
//! 1. **profile** ([`profile`]) — measure every projection tensor's
//!    ICQ code entropy at each candidate bit-width (k ∈ {2, 3, 4, 8}),
//!    reusing `quant::icq::search_all` (parallel across blocks via
//!    `util::threads`) and `quant::entropy`;
//! 2. **plan** ([`planner`]) — deterministic greedy marginal-gain
//!    solve maximizing total retained information under an average
//!    code-bits-per-weight budget (`IRQLORA_BIT_BUDGET`, e.g. `3.2`),
//!    with global and per-projection floor/ceiling constraints; the
//!    resulting [`PrecisionPlan`] serializes into version-2 `.irqc`
//!    checkpoints (`model::checkpoint::save_with_plan`);
//! 3. **apply** ([`apply`]) — drive
//!    `coordinator::quantize::quantize_model_planned` with the
//!    per-tensor assignments, producing a mixed-k `QuantizedModel`
//!    that serves/evaluates through the unchanged downstream paths.
//!
//! The budget counts **packed code bits** per weight: the
//! double-quantized s/τ constants cost the same at every k (≈0.25 b/w
//! at block 64), so they are reported but not budgeted. The `plan`
//! CLI verb prints the chosen allocation table.

pub mod apply;
pub mod planner;
pub mod profile;

pub use apply::{apply_plan, plan_and_quantize, plan_model};
pub use planner::{parse_budget, plan, PlanEntry, PlannerConfig, PrecisionPlan};
pub use profile::{
    profile_model, profile_tensor, synthetic_model, KProfile, ModelProfile, ProfileConfig,
    TensorProfile, CANDIDATE_KS,
};
