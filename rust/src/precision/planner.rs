//! Information-budgeted bit allocation — the solver half of the
//! mixed-precision planner.
//!
//! Given a [`ModelProfile`] (per-tensor ICQ entropy at each candidate
//! bit-width) and a storage budget expressed as **average packed code
//! bits per weight** (`IRQLORA_BIT_BUDGET`, e.g. `3.2`), the planner
//! maximizes total retained information `Σ entropy(kᵢ) · nᵢ` subject
//! to `Σ kᵢ · nᵢ ≤ budget · Σ nᵢ` by deterministic greedy
//! marginal-gain ascent: every tensor starts at its floor bit-width
//! and the upgrade with the best Δinformation/Δbits ratio that still
//! fits is applied until nothing fits.
//!
//! The budget deliberately counts code bits only: the double-quantized
//! s/τ constants cost the same (≈0.25 b/w at block 64) at every k, so
//! they are not a quantity any allocation can trade — plans report the
//! full effective bits/weight per tensor alongside the budgeted code
//! bits.
//!
//! Floors/ceilings come from [`PlannerConfig`]: global bounds
//! (`IRQLORA_BIT_FLOOR` / `IRQLORA_BIT_CEIL`, defaults 2/8) plus
//! per-projection-kind overrides (e.g. pin `w2` — the residual-path
//! down-projection — to ≥ 3 bits).

use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

use super::profile::{storage_bits, ModelProfile};

/// One tensor's slot in a [`PrecisionPlan`].
#[derive(Clone, Debug, PartialEq)]
pub struct PlanEntry {
    pub name: String,
    /// Chosen bit-width.
    pub k: u8,
    pub n_params: usize,
    /// Expected mean code entropy (bits) at the chosen k, from the
    /// profile.
    pub entropy: f64,
    /// Full effective storage bits/weight at the chosen k (codes +
    /// double-quantized constants).
    pub bits_per_weight: f64,
}

/// A deterministic, serializable per-tensor bit-width assignment.
#[derive(Clone, Debug, PartialEq)]
pub struct PrecisionPlan {
    /// The code-bit budget the plan was solved under (avg bits/weight).
    pub budget_bits: f64,
    /// Quantization block size the plan was profiled at.
    pub block: usize,
    /// One entry per quantized projection, in model (push) order.
    pub entries: Vec<PlanEntry>,
}

const PLAN_MAGIC: &[u8; 4] = b"IRQP";
const PLAN_VERSION: u32 = 1;
const MAX_NAME_LEN: usize = 4096;
const MAX_ENTRIES: usize = 1 << 20;

impl PrecisionPlan {
    /// Assigned bit-width for a tensor, if planned.
    pub fn k_for(&self, name: &str) -> Option<u8> {
        self.entries.iter().find(|e| e.name == name).map(|e| e.k)
    }

    pub fn total_params(&self) -> usize {
        self.entries.iter().map(|e| e.n_params).sum()
    }

    /// Total packed code bits (the budgeted quantity). Exact integer
    /// accounting.
    pub fn total_code_bits(&self) -> usize {
        self.entries.iter().map(|e| e.n_params * e.k as usize).sum()
    }

    /// Average packed code bits per weight — must be ≤ `budget_bits`.
    pub fn avg_code_bits(&self) -> f64 {
        let n = self.total_params();
        if n == 0 {
            return 0.0;
        }
        self.total_code_bits() as f64 / n as f64
    }

    /// Total full storage bits (codes + double-quantized constants),
    /// mirroring `QuantizedTensor::storage_bits` exactly.
    pub fn total_storage_bits(&self) -> usize {
        self.entries
            .iter()
            .map(|e| storage_bits(e.n_params, e.k, self.block, true))
            .sum()
    }

    /// Average full storage bits per weight.
    pub fn avg_bits(&self) -> f64 {
        let n = self.total_params();
        if n == 0 {
            return 0.0;
        }
        self.total_storage_bits() as f64 / n as f64
    }

    /// Unweighted mean expected entropy across entries (the semantics
    /// of `QuantizedModel::mean_entropy`).
    pub fn mean_entropy(&self) -> f64 {
        if self.entries.is_empty() {
            return 0.0;
        }
        self.entries.iter().map(|e| e.entropy).sum::<f64>() / self.entries.len() as f64
    }

    /// Does the plan use more than one bit-width?
    pub fn is_mixed(&self) -> bool {
        self.entries
            .windows(2)
            .any(|w| w[0].k != w[1].k)
    }

    /// Serialize to the `IRQP` binary blob embedded in version-2
    /// `.irqc` checkpoints. Round-trips bit-identically through
    /// [`PrecisionPlan::from_bytes`] (f64 fields travel as raw LE bit
    /// patterns).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(PLAN_MAGIC);
        b.extend_from_slice(&PLAN_VERSION.to_le_bytes());
        b.extend_from_slice(&self.budget_bits.to_le_bytes());
        b.extend_from_slice(&(self.block as u64).to_le_bytes());
        b.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for e in &self.entries {
            b.extend_from_slice(&(e.name.len() as u32).to_le_bytes());
            b.extend_from_slice(e.name.as_bytes());
            b.push(e.k);
            b.extend_from_slice(&(e.n_params as u64).to_le_bytes());
            b.extend_from_slice(&e.entropy.to_le_bytes());
            b.extend_from_slice(&e.bits_per_weight.to_le_bytes());
        }
        b
    }

    /// Parse a blob written by [`PrecisionPlan::to_bytes`]. Every read
    /// is bounds-checked so corrupt checkpoints fail with an error,
    /// never a panic.
    pub fn from_bytes(bytes: &[u8]) -> Result<PrecisionPlan> {
        let mut c = Cursor { b: bytes, pos: 0 };
        if c.take(4)? != PLAN_MAGIC {
            bail!("not a precision plan (bad magic)");
        }
        let version = c.u32()?;
        if version != PLAN_VERSION {
            bail!("unsupported precision plan version {version}");
        }
        let budget_bits = c.f64()?;
        let block = c.u64()? as usize;
        if block == 0 {
            bail!("corrupt precision plan: block size 0");
        }
        let count = c.u32()? as usize;
        if count > MAX_ENTRIES {
            bail!("corrupt precision plan: {count} entries");
        }
        let mut entries = Vec::with_capacity(count.min(4096));
        for _ in 0..count {
            let name_len = c.u32()? as usize;
            if name_len > MAX_NAME_LEN {
                bail!("corrupt precision plan: name length {name_len}");
            }
            let name = String::from_utf8(c.take(name_len)?.to_vec())
                .map_err(|_| anyhow!("corrupt precision plan: non-utf8 name"))?;
            let k = c.u8()?;
            if !(1..=8).contains(&k) {
                bail!("corrupt precision plan: bit-width {k}");
            }
            let n_params = c.u64()? as usize;
            let entropy = c.f64()?;
            let bits_per_weight = c.f64()?;
            entries.push(PlanEntry { name, k, n_params, entropy, bits_per_weight });
        }
        if c.pos != bytes.len() {
            bail!("corrupt precision plan: {} trailing bytes", bytes.len() - c.pos);
        }
        Ok(PrecisionPlan { budget_bits, block, entries })
    }

    /// Human-readable allocation table (the `plan` CLI verb output).
    pub fn render_table(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "{:<16} {:>10} {:>3} {:>8} {:>9}",
            "tensor", "params", "k", "bits/w", "ent(bits)"
        );
        for e in &self.entries {
            let _ = writeln!(
                s,
                "{:<16} {:>10} {:>3} {:>8.3} {:>9.3}",
                e.name, e.n_params, e.k, e.bits_per_weight, e.entropy
            );
        }
        let _ = writeln!(
            s,
            "total: {} params | code {:.3} b/w (budget {:.3}) | storage {:.3} b/w | mean entropy {:.3} bits",
            self.total_params(),
            self.avg_code_bits(),
            self.budget_bits,
            self.avg_bits(),
            self.mean_entropy()
        );
        s
    }
}

/// Bounds-checked little-endian reader over a byte slice.
struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| anyhow!("corrupt precision plan: truncated"))?;
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes(s.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        let s = self.take(8)?;
        Ok(f64::from_le_bytes(s.try_into().unwrap()))
    }
}

/// Solver knobs. Environment counterparts: `IRQLORA_BIT_BUDGET`
/// (average code bits/weight), `IRQLORA_BIT_FLOOR`, `IRQLORA_BIT_CEIL`.
#[derive(Clone, Debug)]
pub struct PlannerConfig {
    /// Target average packed code bits per weight.
    pub budget_bits: f64,
    /// Global minimum bit-width (default 2).
    pub floor: u8,
    /// Global maximum bit-width (default 8).
    pub ceil: u8,
    /// Per-projection-kind overrides: (kind, floor, ceiling). First
    /// match wins; kinds not listed use the global bounds.
    pub proj_limits: Vec<(String, u8, u8)>,
}

impl PlannerConfig {
    pub fn new(budget_bits: f64) -> PlannerConfig {
        PlannerConfig { budget_bits, floor: 2, ceil: 8, proj_limits: Vec::new() }
    }

    /// Config from the environment with a fallback budget: the three
    /// knobs are independent — budget from `IRQLORA_BIT_BUDGET` when
    /// set (else `default_budget`), bounds from `IRQLORA_BIT_FLOOR` /
    /// `IRQLORA_BIT_CEIL` whenever THEY are set. Invalid values are
    /// ignored, mirroring `IRQLORA_THREADS`.
    pub fn from_env_or(default_budget: f64) -> PlannerConfig {
        let mut cfg = PlannerConfig::new(
            crate::util::env::bit_budget().unwrap_or(default_budget),
        );
        if let Some(f) = crate::util::env::bit_floor() {
            cfg.floor = f;
        }
        if let Some(c) = crate::util::env::bit_ceil() {
            cfg.ceil = c;
        }
        cfg
    }

    /// Effective (floor, ceiling) for a projection kind.
    pub fn limits_for(&self, proj: Option<&str>) -> (u8, u8) {
        if let Some(p) = proj {
            for (kind, f, c) in &self.proj_limits {
                if kind == p {
                    return (*f, *c);
                }
            }
        }
        (self.floor, self.ceil)
    }
}

/// Interpret an `IRQLORA_BIT_BUDGET` value: positive finite numbers are
/// honored; garbage is ignored (parse in `util::env`; this remains the
/// public entry point `main.rs` uses for `--budget`).
pub fn parse_budget(v: &str) -> Option<f64> {
    crate::util::env::parse_f64_pos(v)
}

/// Interpret a floor/ceiling value: integers in 1..=8 (parse in
/// `util::env`).
#[cfg(test)]
fn parse_k(v: &str) -> Option<u8> {
    crate::util::env::parse_k(v)
}

/// Solve the allocation: deterministic greedy marginal-gain ascent
/// from the per-tensor floors. Two invocations over the same profile
/// and config produce identical plans (stable iteration order, no
/// randomness, first-wins tie-breaking).
pub fn plan(profile: &ModelProfile, cfg: &PlannerConfig) -> Result<PrecisionPlan> {
    let _solve_t = crate::telemetry::global().timer("plan.solve_time", &[]).start();
    if profile.tensors.is_empty() {
        bail!("nothing to plan: the profile has no quantized projections");
    }
    if !(cfg.budget_bits.is_finite() && cfg.budget_bits > 0.0) {
        bail!("invalid bit budget {}", cfg.budget_bits);
    }

    // Per tensor: the candidate ladder within its floor/ceiling.
    let mut ladders: Vec<Vec<(u8, f64)>> = Vec::with_capacity(profile.tensors.len());
    for tp in &profile.tensors {
        let (floor, ceil) = cfg.limits_for(tp.proj.as_deref());
        if floor > ceil {
            bail!("floor {floor} > ceiling {ceil} for '{}'", tp.name);
        }
        let ladder: Vec<(u8, f64)> = tp
            .levels
            .iter()
            .filter(|l| l.k >= floor && l.k <= ceil)
            .map(|l| (l.k, l.entropy))
            .collect();
        if ladder.is_empty() {
            bail!(
                "no candidate bit-width within [{floor}, {ceil}] for '{}' (profiled: {:?})",
                tp.name,
                tp.levels.iter().map(|l| l.k).collect::<Vec<_>>()
            );
        }
        ladders.push(ladder);
    }

    let total_params: usize = profile.tensors.iter().map(|t| t.n_params).sum();
    let budget_total = cfg.budget_bits * total_params as f64;
    let mut idx = vec![0usize; ladders.len()];
    let code_bits =
        |ti: usize, li: usize| -> f64 { (profile.tensors[ti].n_params * ladders[ti][li].0 as usize) as f64 };
    let mut current: f64 = (0..ladders.len()).map(|ti| code_bits(ti, 0)).sum();
    if current > budget_total + 1e-6 {
        bail!(
            "budget {:.3} b/w is below the floor allocation ({:.3} b/w): raise \
             IRQLORA_BIT_BUDGET or lower the floors",
            cfg.budget_bits,
            current / total_params as f64
        );
    }

    loop {
        // best upgrade by Δinformation/Δbits, considering EVERY higher
        // rung of each tensor's ladder (not just the adjacent one) so
        // a flat intermediate step — entropy(k+1) == entropy(k) on
        // near-discrete data — cannot wall off a genuinely profitable
        // jump further up
        let mut best: Option<(f64, usize, usize, f64)> = None; // (ratio, tensor, rung, dbits)
        for ti in 0..ladders.len() {
            let li = idx[ti];
            for li2 in li + 1..ladders[ti].len() {
                let dbits = code_bits(ti, li2) - code_bits(ti, li);
                let dh = (ladders[ti][li2].1 - ladders[ti][li].1)
                    * profile.tensors[ti].n_params as f64;
                if dh <= 1e-9 {
                    continue; // no information gained — never spend bits on it
                }
                if current + dbits > budget_total + 1e-6 {
                    continue;
                }
                let ratio = dh / dbits;
                if best.map_or(true, |(br, _, _, _)| ratio > br) {
                    best = Some((ratio, ti, li2, dbits));
                }
            }
        }
        match best {
            Some((_, ti, li2, dbits)) => {
                idx[ti] = li2;
                current += dbits;
            }
            None => break,
        }
    }

    let entries = profile
        .tensors
        .iter()
        .zip(ladders.iter().zip(&idx))
        .map(|(tp, (ladder, &li))| {
            let (k, entropy) = ladder[li];
            PlanEntry {
                name: tp.name.clone(),
                k,
                n_params: tp.n_params,
                entropy,
                bits_per_weight: storage_bits(tp.n_params, k, profile.block, true) as f64
                    / tp.n_params.max(1) as f64,
            }
        })
        .collect();
    let plan = PrecisionPlan { budget_bits: cfg.budget_bits, block: profile.block, entries };
    // chosen-k histogram: one count per planned tensor, labeled by the
    // bit-width the solve landed on
    let reg = crate::telemetry::global();
    if reg.is_enabled() {
        for e in &plan.entries {
            let ks = e.k.to_string();
            reg.counter("plan.chosen_k", &[("k", ks.as_str())]).inc();
        }
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::profile::{profile_model, synthetic_model, ProfileConfig};

    fn tiny_profile() -> ModelProfile {
        profile_model(&synthetic_model(1, 32, 5), &ProfileConfig::default())
    }

    #[test]
    fn env_value_parsing() {
        assert_eq!(parse_budget("3.2"), Some(3.2));
        assert_eq!(parse_budget(" 4 "), Some(4.0));
        assert_eq!(parse_budget("0"), None);
        assert_eq!(parse_budget("-1"), None);
        assert_eq!(parse_budget("inf"), None);
        assert_eq!(parse_budget("nope"), None);
        assert_eq!(parse_k("3"), Some(3));
        assert_eq!(parse_k("9"), None);
        assert_eq!(parse_k("0"), None);
        assert_eq!(parse_k("x"), None);
    }

    #[test]
    fn plan_respects_budget_and_is_mixed() {
        let prof = tiny_profile();
        let p = plan(&prof, &PlannerConfig::new(3.2)).unwrap();
        assert!(p.avg_code_bits() <= 3.2 + 1e-9, "{}", p.avg_code_bits());
        assert!(p.is_mixed(), "expected a mixed-k plan:\n{}", p.render_table());
        // low-information wk/wv stay at the floor; normal tensors rise
        for e in &p.entries {
            if e.name.ends_with(".wk") || e.name.ends_with(".wv") {
                assert_eq!(e.k, 2, "{}", e.name);
            } else {
                assert!(e.k >= 3, "{} got k={}", e.name, e.k);
            }
        }
    }

    #[test]
    fn plan_beats_uniform_3bit_at_same_or_less_storage() {
        let prof = tiny_profile();
        let p = plan(&prof, &PlannerConfig::new(3.0)).unwrap();
        assert!(p.avg_code_bits() <= 3.0 + 1e-9);
        assert!(
            p.mean_entropy() >= prof.mean_entropy_at(3) - 1e-9,
            "planned {:.4} < uniform-3 {:.4}",
            p.mean_entropy(),
            prof.mean_entropy_at(3)
        );
    }

    #[test]
    fn planning_is_deterministic() {
        let prof = tiny_profile();
        let cfg = PlannerConfig::new(3.2);
        let a = plan(&prof, &cfg).unwrap();
        let b = plan(&prof, &cfg).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn floors_and_ceilings_respected() {
        let prof = tiny_profile();
        let mut cfg = PlannerConfig::new(3.2);
        cfg.proj_limits.push(("wk".to_string(), 3, 4));
        cfg.proj_limits.push(("wq".to_string(), 2, 2));
        let p = plan(&prof, &cfg).unwrap();
        for e in &p.entries {
            if e.name.ends_with(".wk") {
                assert!((3..=4).contains(&e.k), "{} k={}", e.name, e.k);
            }
            if e.name.ends_with(".wq") {
                assert_eq!(e.k, 2, "{}", e.name);
            }
        }
    }

    #[test]
    fn flat_intermediate_rung_does_not_block_higher_k() {
        use crate::precision::profile::{KProfile, TensorProfile};
        let mk = |k: u8, h: f64| KProfile {
            k,
            entropy: h,
            entropy_vanilla: h,
            bits_per_weight: k as f64,
        };
        let prof = ModelProfile {
            block: 64,
            tensors: vec![TensorProfile {
                name: "l0.wq".into(),
                proj: Some("wq".into()),
                n_params: 640,
                // flat 2 -> 3 (discrete-data bin collision), rising at 4
                levels: vec![mk(2, 2.0), mk(3, 2.0), mk(4, 3.5), mk(8, 3.6)],
            }],
        };
        let p = plan(&prof, &PlannerConfig::new(4.0)).unwrap();
        assert_eq!(p.entries[0].k, 4, "{}", p.render_table());
    }

    #[test]
    fn budget_below_floor_errors() {
        let prof = tiny_profile();
        let err = plan(&prof, &PlannerConfig::new(1.5)).unwrap_err().to_string();
        assert!(err.contains("below the floor"), "{err}");
    }

    #[test]
    fn conflicting_limits_error() {
        let prof = tiny_profile();
        let mut cfg = PlannerConfig::new(3.2);
        cfg.proj_limits.push(("wq".to_string(), 4, 3));
        assert!(plan(&prof, &cfg).is_err());
    }

    #[test]
    fn serialization_roundtrip_bit_identical() {
        let prof = tiny_profile();
        let p = plan(&prof, &PlannerConfig::new(3.2)).unwrap();
        let bytes = p.to_bytes();
        let back = PrecisionPlan::from_bytes(&bytes).unwrap();
        assert_eq!(back.budget_bits.to_bits(), p.budget_bits.to_bits());
        assert_eq!(back.block, p.block);
        assert_eq!(back.entries.len(), p.entries.len());
        for (a, b) in p.entries.iter().zip(&back.entries) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.k, b.k);
            assert_eq!(a.n_params, b.n_params);
            assert_eq!(a.entropy.to_bits(), b.entropy.to_bits());
            assert_eq!(a.bits_per_weight.to_bits(), b.bits_per_weight.to_bits());
        }
    }

    #[test]
    fn corrupt_plan_bytes_rejected() {
        assert!(PrecisionPlan::from_bytes(b"NOPE").is_err());
        assert!(PrecisionPlan::from_bytes(b"").is_err());
        let prof = tiny_profile();
        let p = plan(&prof, &PlannerConfig::new(3.2)).unwrap();
        let bytes = p.to_bytes();
        // truncation at every prefix must error, never panic
        for cut in [4usize, 8, 16, 24, bytes.len() - 1] {
            assert!(PrecisionPlan::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
        // trailing garbage rejected
        let mut long = bytes.clone();
        long.push(0);
        assert!(PrecisionPlan::from_bytes(&long).is_err());
    }

    #[test]
    fn render_table_mentions_budget_and_tensors() {
        let prof = tiny_profile();
        let p = plan(&prof, &PlannerConfig::new(3.2)).unwrap();
        let t = p.render_table();
        assert!(t.contains("budget 3.200"), "{t}");
        assert!(t.contains("l0.wq"), "{t}");
    }
}
