//! Per-tensor information profiles — the measurement half of the
//! mixed-precision planner.
//!
//! For every quantized projection tensor, a [`TensorProfile`] records
//! the ICQ code entropy (paper Eq. 7, the "retained information"
//! metric) the tensor would achieve at each candidate bit-width,
//! alongside its size and projection kind. The profile is what the
//! greedy solver in [`super::planner`] trades against the storage
//! budget: information-dense tensors (entropy keeps growing with k)
//! earn extra bits, information-sparse ones (entropy saturates early)
//! release them.
//!
//! The ICQ τ search inside [`icq::search_all`] already fans out across
//! blocks via [`crate::util::threads`]; the tensor × k outer loop here
//! stays serial on purpose so the two levels never oversubscribe the
//! worker pool.

use crate::model::weights::{is_quantized_proj, proj_kind, NamedTensors, PROJ_KINDS};
use crate::quant::double_quant;
use crate::quant::{blockwise, icq};
use crate::util::{Rng, Tensor};

/// Candidate bit-widths the planner chooses from (the paper's 2/3/4-bit
/// operating points plus an 8-bit headroom tier).
pub const CANDIDATE_KS: [u8; 4] = [2, 3, 4, 8];

/// Information/storage numbers for one tensor at one bit-width.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KProfile {
    pub k: u8,
    /// Mean per-block ICQ code entropy (bits) at this k.
    pub entropy: f64,
    /// Mean per-block entropy of the uncalibrated (τ = 0) quantization.
    pub entropy_vanilla: f64,
    /// Full effective storage bits/weight at this k: packed codes plus
    /// the double-quantized s/τ constants. The constants term is
    /// k-independent (≈0.25 b/w at block 64), which is why the planner
    /// budgets *code* bits only — see [`super::planner`].
    pub bits_per_weight: f64,
}

/// Information profile of one quantized projection tensor.
#[derive(Clone, Debug)]
pub struct TensorProfile {
    pub name: String,
    /// Projection kind ("wq".."w2"), used for per-projection
    /// floor/ceiling constraints.
    pub proj: Option<String>,
    pub n_params: usize,
    /// One entry per candidate k, ascending.
    pub levels: Vec<KProfile>,
}

impl TensorProfile {
    /// The profile entry for bit-width `k`, if it was a candidate.
    pub fn level(&self, k: u8) -> Option<&KProfile> {
        self.levels.iter().find(|l| l.k == k)
    }
}

/// Profiles of every quantized projection of a model.
#[derive(Clone, Debug)]
pub struct ModelProfile {
    pub tensors: Vec<TensorProfile>,
    /// Quantization block size the entropies were measured at.
    pub block: usize,
}

impl ModelProfile {
    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|t| t.n_params).sum()
    }

    /// Unweighted mean entropy if every tensor used bit-width `k` —
    /// the uniform-k baseline the planner must beat (matches the
    /// semantics of `QuantizedModel::mean_entropy`). Averages over the
    /// tensors that actually profiled `k`; NaN when none did (so a
    /// baseline comparison against an unprofiled k fails loudly
    /// instead of passing against a silent 0.0).
    pub fn mean_entropy_at(&self, k: u8) -> f64 {
        let hs: Vec<f64> = self
            .tensors
            .iter()
            .filter_map(|t| t.level(k).map(|l| l.entropy))
            .collect();
        if hs.is_empty() {
            return f64::NAN;
        }
        hs.iter().sum::<f64>() / hs.len() as f64
    }
}

/// Profiling knobs.
#[derive(Clone, Debug)]
pub struct ProfileConfig {
    /// ICQ search hyper-parameters (paper §3.2.2 defaults).
    pub icq: icq::IcqConfig,
    /// Quantization block size (paper default 64).
    pub block: usize,
    /// Candidate bit-widths, ascending (deduped/sorted defensively).
    pub candidates: Vec<u8>,
    /// Cap on profiled blocks per tensor (a deterministic prefix
    /// sample keeps profiling cheap on large tensors); `None` profiles
    /// every block.
    pub max_blocks: Option<usize>,
}

impl Default for ProfileConfig {
    fn default() -> Self {
        ProfileConfig {
            icq: icq::IcqConfig::default(),
            block: blockwise::DEFAULT_BLOCK,
            candidates: CANDIDATE_KS.to_vec(),
            max_blocks: Some(512),
        }
    }
}

/// Exact full storage bits of an ICQ-quantized tensor of `n` elements
/// at bit-width `k`: packed codes + double-quantized per-block s (and
/// τ, when `icq`). Mirrors `QuantizedTensor::storage_bits` term for
/// term so plans account storage identically to the artifacts they
/// describe.
pub fn storage_bits(n: usize, k: u8, block: usize, icq: bool) -> usize {
    let n_blocks = n.div_ceil(block);
    let n_groups = n_blocks.div_ceil(double_quant::DEFAULT_GROUP);
    let consts = n_blocks * 8 + n_groups * 16;
    n * k as usize + if icq { 2 * consts } else { consts }
}

/// Profile one tensor: ICQ entropy at every candidate k over a
/// deterministic prefix sample of its blocks.
pub fn profile_tensor(name: &str, w: &[f32], cfg: &ProfileConfig) -> TensorProfile {
    let mut candidates = cfg.candidates.clone();
    candidates.sort_unstable();
    candidates.dedup();
    let sample = match cfg.max_blocks {
        Some(mb) => &w[..w.len().min(mb.max(1) * cfg.block)],
        None => w,
    };
    let levels = candidates
        .iter()
        .map(|&k| {
            let searches = icq::search_all(sample, k, cfg.block, &cfg.icq);
            let nb = searches.len().max(1) as f64;
            let entropy = searches.iter().map(|s| s.entropy).sum::<f64>() / nb;
            let entropy_vanilla =
                searches.iter().map(|s| s.entropy_vanilla).sum::<f64>() / nb;
            let bits_per_weight = if w.is_empty() {
                k as f64
            } else {
                storage_bits(w.len(), k, cfg.block, true) as f64 / w.len() as f64
            };
            KProfile { k, entropy, entropy_vanilla, bits_per_weight }
        })
        .collect();
    TensorProfile {
        name: name.to_string(),
        proj: proj_kind(name).map(|p| p.to_string()),
        n_params: w.len(),
        levels,
    }
}

/// Profile every quantized projection tensor of `weights` (the same
/// selection rule as `coordinator::quantize::quantize_model`).
pub fn profile_model(weights: &NamedTensors, cfg: &ProfileConfig) -> ModelProfile {
    let _profile_t = crate::telemetry::global().timer("plan.profile_time", &[]).start();
    let tensors = weights
        .iter()
        .filter(|(n, _)| is_quantized_proj(n))
        .map(|(name, t)| profile_tensor(name, t.data(), cfg))
        .collect();
    ModelProfile { tensors, block: cfg.block }
}

/// Deterministic synthetic base model with heterogeneous information
/// density — the fixture behind the planner smoke (`irqlora plan
/// --synthetic --check`), the acceptance tests and the
/// `plan_throughput` bench. `wk`/`wv` carry ~2 bits of information per
/// weight (four discrete values, so code entropy saturates by k = 2
/// and extra bits buy nothing); every other projection is normal
/// noise whose entropy keeps growing with k. A budget planner
/// therefore has a real allocation decision to make.
pub fn synthetic_model(n_layers: usize, h: usize, seed: u64) -> NamedTensors {
    // spread so the four values land in distinct NF2 bins at τ = 0
    const LEVELS: [f32; 4] = [-1.0, -0.3, 0.35, 1.0];
    let mut rng = Rng::new(seed ^ 0x9c15);
    let mut nt = NamedTensors::new();
    nt.push("embed", Tensor::new(&[32, h], rng.normal_vec(32 * h, 0.0, 0.02)));
    for l in 0..n_layers {
        nt.push(format!("l{l}.attn_norm"), Tensor::full(&[h], 1.0));
        for kind in PROJ_KINDS {
            let (r, c) = match kind {
                "w1" | "w3" => (h, 2 * h),
                "w2" => (2 * h, h),
                _ => (h, h),
            };
            let n = r * c;
            let data: Vec<f32> = match kind {
                "wk" | "wv" => (0..n).map(|_| LEVELS[rng.below(4)] * 0.02).collect(),
                _ => rng.normal_vec(n, 0.01, 0.02),
            };
            nt.push(format!("l{l}.{kind}"), Tensor::new(&[r, c], data));
        }
    }
    nt.push("lm_head", Tensor::new(&[h, 32], rng.normal_vec(h * 32, 0.0, 0.02)));
    nt
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn storage_bits_matches_quantized_tensor() {
        let mut rng = Rng::new(21);
        for (n, k) in [(64 * 256, 4u8), (1000, 2), (64 * 300 + 17, 3), (64, 8)] {
            let t = Tensor::new(&[n], rng.normal_vec(n, 0.0, 0.05));
            let qt = crate::quant::QuantizedTensor::quantize(
                &t,
                k,
                64,
                Some(&icq::IcqConfig::default()),
            );
            assert_eq!(storage_bits(n, k, 64, true), qt.storage_bits(), "n={n} k={k}");
            let q0 = crate::quant::QuantizedTensor::quantize(&t, k, 64, None);
            assert_eq!(storage_bits(n, k, 64, false), q0.storage_bits(), "n={n} k={k}");
        }
    }

    #[test]
    fn profile_covers_candidates_ascending() {
        let mut rng = Rng::new(22);
        let w = rng.normal_vec(64 * 8, 0.01, 0.02);
        let cfg = ProfileConfig::default();
        let tp = profile_tensor("l0.wq", &w, &cfg);
        assert_eq!(tp.proj.as_deref(), Some("wq"));
        assert_eq!(tp.n_params, w.len());
        let ks: Vec<u8> = tp.levels.iter().map(|l| l.k).collect();
        assert_eq!(ks, CANDIDATE_KS.to_vec());
        // entropy is (weakly) monotone in k for normal data
        for pair in tp.levels.windows(2) {
            assert!(
                pair[1].entropy >= pair[0].entropy - 1e-9,
                "entropy not monotone: {:?}",
                tp.levels
            );
        }
        // the constants overhead is k-independent: bits/weight differ
        // by exactly the code-bit delta
        for pair in tp.levels.windows(2) {
            let want = (pair[1].k - pair[0].k) as f64;
            assert!(
                (pair[1].bits_per_weight - pair[0].bits_per_weight - want).abs() < 1e-12
            );
        }
    }

    #[test]
    fn profile_model_selects_projections_only() {
        let m = synthetic_model(1, 32, 7);
        let prof = profile_model(&m, &ProfileConfig::default());
        assert_eq!(prof.tensors.len(), PROJ_KINDS.len());
        assert!(prof.tensors.iter().all(|t| t.proj.is_some()));
        assert!(prof.total_params() > 0);
    }

    #[test]
    fn synthetic_model_is_heterogeneous() {
        let m = synthetic_model(1, 32, 3);
        let prof = profile_model(&m, &ProfileConfig::default());
        let wv = prof.tensors.iter().find(|t| t.proj.as_deref() == Some("wv")).unwrap();
        let wq = prof.tensors.iter().find(|t| t.proj.as_deref() == Some("wq")).unwrap();
        // discrete wv: four codes regardless of k — upgrading 2 -> 8
        // buys (almost) nothing
        let wv_gain = wv.level(8).unwrap().entropy - wv.level(2).unwrap().entropy;
        assert!(wv_gain < 0.05, "wv gain {wv_gain}");
        assert!(wv.level(2).unwrap().entropy > 1.8);
        // normal wq keeps gaining information with k
        let wq_gain = wq.level(4).unwrap().entropy - wq.level(2).unwrap().entropy;
        assert!(wq_gain > 1.0, "wq gain {wq_gain}");
    }

    #[test]
    fn prefix_sample_caps_cost_deterministically() {
        let mut rng = Rng::new(23);
        let w = rng.normal_vec(64 * 64, 0.0, 0.02);
        let full = ProfileConfig { max_blocks: None, ..ProfileConfig::default() };
        let capped = ProfileConfig { max_blocks: Some(8), ..ProfileConfig::default() };
        let a = profile_tensor("l0.wq", &w, &capped);
        let b = profile_tensor("l0.wq", &w, &capped);
        // deterministic, and a genuine estimate of the full profile
        for (x, y) in a.levels.iter().zip(&b.levels) {
            assert_eq!(x.entropy.to_bits(), y.entropy.to_bits());
        }
        let f = profile_tensor("l0.wq", &w, &full);
        for (x, y) in a.levels.iter().zip(&f.levels) {
            assert!((x.entropy - y.entropy).abs() < 0.3, "{} vs {}", x.entropy, y.entropy);
        }
        // sizes/bits always reflect the FULL tensor, not the sample
        assert_eq!(a.n_params, w.len());
        assert_eq!(
            a.levels[0].bits_per_weight.to_bits(),
            f.levels[0].bits_per_weight.to_bits()
        );
    }
}
