//! NanoLLaMA host-side model state: named tensors, initialization,
//! checkpoints. The actual math lives in the AOT graphs; this module
//! owns what the coordinator uploads to them.

pub mod checkpoint;
pub mod weights;

pub use checkpoint::CheckpointError;
pub use weights::NamedTensors;
