//! Binary checkpoint format for [`NamedTensors`].
//!
//! Layout (little-endian):
//! ```text
//! magic "IRQC" | version u32 | count u32
//! per tensor: name_len u32 | name bytes | rank u32 | dims u64* | f32 data
//! trailer: crc-ish checksum u64 (FNV-1a over all tensor bytes)
//! ```
//! Used to cache pretrained base weights and finetuned adapters under
//! `runs/` so the table harness doesn't re-train on every invocation.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::Tensor;

use super::weights::NamedTensors;

const MAGIC: &[u8; 4] = b"IRQC";
const VERSION: u32 = 1;

fn fnv1a(state: u64, bytes: &[u8]) -> u64 {
    let mut h = state;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

pub fn save(nt: &NamedTensors, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(nt.len() as u32).to_le_bytes())?;
    let mut check = 0xcbf29ce484222325u64;
    for (name, t) in nt.iter() {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&(t.shape().len() as u32).to_le_bytes())?;
        for &d in t.shape() {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        let bytes: Vec<u8> = t.data().iter().flat_map(|v| v.to_le_bytes()).collect();
        check = fnv1a(check, &bytes);
        f.write_all(&bytes)?;
    }
    f.write_all(&check.to_le_bytes())?;
    Ok(())
}

/// Element count of a header's dims with overflow treated as
/// corruption (a crafted header like [2^33, 2^31] must not wrap to a
/// small product and dodge the size cap).
fn checked_elems(dims: &[usize]) -> Result<usize> {
    dims.iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .filter(|&n| n <= 1 << 30)
        .ok_or_else(|| anyhow::anyhow!("corrupt checkpoint: tensor too large {dims:?}"))
}

pub fn load(path: impl AsRef<Path>) -> Result<NamedTensors> {
    let path = path.as_ref();
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {}", path.display()))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not an IRQC checkpoint", path.display());
    }
    let mut u32b = [0u8; 4];
    f.read_exact(&mut u32b)?;
    let version = u32::from_le_bytes(u32b);
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    f.read_exact(&mut u32b)?;
    let count = u32::from_le_bytes(u32b) as usize;

    let mut out = NamedTensors::new();
    let mut check = 0xcbf29ce484222325u64;
    for _ in 0..count {
        f.read_exact(&mut u32b)?;
        let name_len = u32::from_le_bytes(u32b) as usize;
        if name_len > 4096 {
            bail!("corrupt checkpoint: name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("non-utf8 tensor name")?;
        f.read_exact(&mut u32b)?;
        let rank = u32::from_le_bytes(u32b) as usize;
        if rank > 8 {
            bail!("corrupt checkpoint: rank {rank}");
        }
        let mut dims = Vec::with_capacity(rank);
        let mut u64b = [0u8; 8];
        for _ in 0..rank {
            f.read_exact(&mut u64b)?;
            dims.push(u64::from_le_bytes(u64b) as usize);
        }
        let n = checked_elems(&dims)?;
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes)?;
        check = fnv1a(check, &bytes);
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push(name, Tensor::new(&dims, data));
    }
    let mut u64b = [0u8; 8];
    f.read_exact(&mut u64b)
        .context("truncated checkpoint (missing checksum)")?;
    if u64::from_le_bytes(u64b) != check {
        bail!("checkpoint checksum mismatch — file corrupt");
    }
    Ok(out)
}

/// Read just the tensor names + shapes of a checkpoint, seeking past
/// the (potentially large) data payloads. Does NOT verify the
/// checksum — use [`load`] for a validated read; this exists for
/// cheap structural checks (e.g. "is this file an adapter?") before
/// committing to a full load, as the adapter registry does when
/// registering file-backed adapters.
pub fn peek_entries(path: impl AsRef<Path>) -> Result<Vec<(String, Vec<usize>)>> {
    use std::io::{Seek, SeekFrom};
    let path = path.as_ref();
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {}", path.display()))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not an IRQC checkpoint", path.display());
    }
    let mut u32b = [0u8; 4];
    f.read_exact(&mut u32b)?;
    let version = u32::from_le_bytes(u32b);
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    f.read_exact(&mut u32b)?;
    let count = u32::from_le_bytes(u32b) as usize;

    let mut out = Vec::with_capacity(count.min(4096));
    for _ in 0..count {
        f.read_exact(&mut u32b)?;
        let name_len = u32::from_le_bytes(u32b) as usize;
        if name_len > 4096 {
            bail!("corrupt checkpoint: name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("non-utf8 tensor name")?;
        f.read_exact(&mut u32b)?;
        let rank = u32::from_le_bytes(u32b) as usize;
        if rank > 8 {
            bail!("corrupt checkpoint: rank {rank}");
        }
        let mut dims = Vec::with_capacity(rank);
        let mut u64b = [0u8; 8];
        for _ in 0..rank {
            f.read_exact(&mut u64b)?;
            dims.push(u64::from_le_bytes(u64b) as usize);
        }
        let n = checked_elems(&dims)?;
        f.seek(SeekFrom::Current(n as i64 * 4))
            .context("seeking past tensor data")?;
        out.push((name, dims));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("irqc_test_{name}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(3);
        let mut nt = NamedTensors::new();
        nt.push("embed", Tensor::new(&[4, 8], rng.normal_vec(32, 0.0, 1.0)));
        nt.push("scalar", Tensor::scalar(3.25));
        nt.push("l0.wq", Tensor::new(&[8, 8], rng.normal_vec(64, 0.0, 0.02)));
        let p = tmp("roundtrip");
        save(&nt, &p).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back.names(), nt.names());
        for (name, t) in nt.iter() {
            assert_eq!(back.get(name).unwrap(), t, "{name}");
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn corrupt_file_rejected() {
        let p = tmp("corrupt");
        std::fs::write(&p, b"IRQC\x01\x00\x00\x00garbage").unwrap();
        assert!(load(&p).is_err());
        std::fs::write(&p, b"NOPE").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn checksum_detects_bitflip() {
        let mut nt = NamedTensors::new();
        nt.push("w", Tensor::full(&[16], 1.0));
        let p = tmp("bitflip");
        save(&nt, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("checksum") || err.contains("corrupt"), "{err}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn peek_matches_saved_structure() {
        let mut nt = NamedTensors::new();
        nt.push("l0.wq.lora_a", Tensor::zeros(&[8, 4]));
        nt.push("l0.wq.lora_b", Tensor::zeros(&[4, 16]));
        nt.push("betas", Tensor::zeros(&[1, 7, 2]));
        let p = tmp("peek");
        save(&nt, &p).unwrap();
        let entries = peek_entries(&p).unwrap();
        assert_eq!(entries.len(), 3);
        assert_eq!(entries[0], ("l0.wq.lora_a".to_string(), vec![8, 4]));
        assert_eq!(entries[2], ("betas".to_string(), vec![1, 7, 2]));
        // peek is header-only; the full load still validates
        assert!(load(&p).is_ok());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn peek_rejects_non_checkpoint() {
        let p = tmp("peek_bad");
        std::fs::write(&p, b"NOPEnope").unwrap();
        assert!(peek_entries(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn overflowing_dims_rejected_not_wrapped() {
        // dims [2^33, 2^31] multiply to 2^64 ≡ 0 in wrapping usize —
        // must be treated as corruption, not a zero-element tensor
        let p = tmp("peek_overflow");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"IRQC");
        bytes.extend_from_slice(&1u32.to_le_bytes()); // version
        bytes.extend_from_slice(&1u32.to_le_bytes()); // count
        bytes.extend_from_slice(&1u32.to_le_bytes()); // name_len
        bytes.push(b'w');
        bytes.extend_from_slice(&2u32.to_le_bytes()); // rank
        bytes.extend_from_slice(&(1u64 << 33).to_le_bytes());
        bytes.extend_from_slice(&(1u64 << 31).to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(peek_entries(&p).is_err());
        assert!(load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn missing_file_clear_error() {
        let err = load("/nonexistent/ckpt.irqc").unwrap_err().to_string();
        assert!(err.contains("opening checkpoint"));
    }
}
