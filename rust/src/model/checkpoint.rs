//! Binary checkpoint format for [`NamedTensors`].
//!
//! Layout (little-endian):
//! ```text
//! magic "IRQC" | version u32 | count u32
//! per tensor: name_len u32 | name bytes | rank u32 | dims u64* | f32 data
//! trailer: crc-ish checksum u64 (FNV-1a over all tensor bytes)
//! ```
//! Used to cache pretrained base weights and finetuned adapters under
//! `runs/` so the table harness doesn't re-train on every invocation.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::Tensor;

use super::weights::NamedTensors;

const MAGIC: &[u8; 4] = b"IRQC";
const VERSION: u32 = 1;

fn fnv1a(state: u64, bytes: &[u8]) -> u64 {
    let mut h = state;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

pub fn save(nt: &NamedTensors, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(nt.len() as u32).to_le_bytes())?;
    let mut check = 0xcbf29ce484222325u64;
    for (name, t) in nt.iter() {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&(t.shape().len() as u32).to_le_bytes())?;
        for &d in t.shape() {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        let bytes: Vec<u8> = t.data().iter().flat_map(|v| v.to_le_bytes()).collect();
        check = fnv1a(check, &bytes);
        f.write_all(&bytes)?;
    }
    f.write_all(&check.to_le_bytes())?;
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<NamedTensors> {
    let path = path.as_ref();
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {}", path.display()))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not an IRQC checkpoint", path.display());
    }
    let mut u32b = [0u8; 4];
    f.read_exact(&mut u32b)?;
    let version = u32::from_le_bytes(u32b);
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    f.read_exact(&mut u32b)?;
    let count = u32::from_le_bytes(u32b) as usize;

    let mut out = NamedTensors::new();
    let mut check = 0xcbf29ce484222325u64;
    for _ in 0..count {
        f.read_exact(&mut u32b)?;
        let name_len = u32::from_le_bytes(u32b) as usize;
        if name_len > 4096 {
            bail!("corrupt checkpoint: name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("non-utf8 tensor name")?;
        f.read_exact(&mut u32b)?;
        let rank = u32::from_le_bytes(u32b) as usize;
        if rank > 8 {
            bail!("corrupt checkpoint: rank {rank}");
        }
        let mut dims = Vec::with_capacity(rank);
        let mut u64b = [0u8; 8];
        for _ in 0..rank {
            f.read_exact(&mut u64b)?;
            dims.push(u64::from_le_bytes(u64b) as usize);
        }
        let n: usize = dims.iter().product();
        if n > 1 << 30 {
            bail!("corrupt checkpoint: tensor too large ({n} elems)");
        }
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes)?;
        check = fnv1a(check, &bytes);
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push(name, Tensor::new(&dims, data));
    }
    let mut u64b = [0u8; 8];
    f.read_exact(&mut u64b)
        .context("truncated checkpoint (missing checksum)")?;
    if u64::from_le_bytes(u64b) != check {
        bail!("checkpoint checksum mismatch — file corrupt");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("irqc_test_{name}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(3);
        let mut nt = NamedTensors::new();
        nt.push("embed", Tensor::new(&[4, 8], rng.normal_vec(32, 0.0, 1.0)));
        nt.push("scalar", Tensor::scalar(3.25));
        nt.push("l0.wq", Tensor::new(&[8, 8], rng.normal_vec(64, 0.0, 0.02)));
        let p = tmp("roundtrip");
        save(&nt, &p).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back.names(), nt.names());
        for (name, t) in nt.iter() {
            assert_eq!(back.get(name).unwrap(), t, "{name}");
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn corrupt_file_rejected() {
        let p = tmp("corrupt");
        std::fs::write(&p, b"IRQC\x01\x00\x00\x00garbage").unwrap();
        assert!(load(&p).is_err());
        std::fs::write(&p, b"NOPE").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn checksum_detects_bitflip() {
        let mut nt = NamedTensors::new();
        nt.push("w", Tensor::full(&[16], 1.0));
        let p = tmp("bitflip");
        save(&nt, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("checksum") || err.contains("corrupt"), "{err}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn missing_file_clear_error() {
        let err = load("/nonexistent/ckpt.irqc").unwrap_err().to_string();
        assert!(err.contains("opening checkpoint"));
    }
}
