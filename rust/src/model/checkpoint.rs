//! Binary checkpoint format for [`NamedTensors`].
//!
//! Layout (little-endian):
//! ```text
//! magic "IRQC" | version u32 | count u32
//! version 2 only: plan_len u32 | plan bytes (precision::PrecisionPlan)
//! per tensor: name_len u32 | name bytes | rank u32 | dims u64* | f32 data
//! trailer: crc-ish checksum u64 (FNV-1a over plan bytes, then all
//!          tensor bytes; version 1 has no plan bytes)
//! ```
//! Version 1 is the original uniform-k format; [`save`] still writes
//! it byte-for-byte, so checkpoints produced before the mixed-
//! precision planner existed — and new plan-less saves — stay
//! identical and keep loading everywhere. Version 2
//! ([`save_with_plan`]) prepends a serialized
//! [`PrecisionPlan`] so a mixed-k artifact travels with the
//! allocation that produced it; [`load`] accepts both and plan-aware
//! callers use [`load_with_plan`] / [`peek_plan`].
//!
//! Used to cache pretrained base weights and finetuned adapters under
//! `runs/` so the table harness doesn't re-train on every invocation.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::precision::PrecisionPlan;
use crate::util::hash::{fnv1a, FNV1A_SEED};
use crate::util::Tensor;

use super::weights::NamedTensors;

const MAGIC: &[u8; 4] = b"IRQC";
const VERSION: u32 = 1;
/// Version written when a precision plan is attached.
const VERSION_PLANNED: u32 = 2;
/// Cap on the serialized plan section (a plan is a few dozen bytes per
/// tensor; anything near this is corruption).
const MAX_PLAN_BYTES: usize = 1 << 24;
/// Smallest possible on-disk footprint of one tensor entry (empty
/// name, rank 0, no data): name_len u32 + rank u32. Used to bound the
/// header's tensor count against the real file size.
const MIN_ENTRY_BYTES: u64 = 8;

/// Typed rejection reasons for `.irqc` parsing. Every reader returns
/// one of these (wrapped in [`anyhow::Error`]) instead of panicking or
/// allocating unbounded memory when fed a truncated or crafted file —
/// the header is fully distrusted: counts and lengths are checked
/// against the actual on-disk size before any allocation or seek.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CheckpointError {
    /// The first four bytes are not `IRQC`.
    BadMagic,
    /// A version this build does not know how to read.
    UnsupportedVersion(u32),
    /// The header claims more tensors than the file could possibly
    /// hold (each entry needs ≥ [`MIN_ENTRY_BYTES`] bytes).
    AbsurdCount { count: u64, file_len: u64 },
    /// Plan section longer than [`MAX_PLAN_BYTES`] or than the file.
    PlanTooLarge { plan_len: u64, file_len: u64 },
    /// A tensor name longer than the 4096-byte cap.
    NameTooLong(u64),
    /// A tensor rank beyond the supported 8 dims.
    RankTooLarge(u64),
    /// Dims whose element product overflows or exceeds the 2^30 cap.
    TensorTooLarge(Vec<usize>),
    /// A tensor's data payload extends past the end of the file.
    DataOverrun { needed: u64, file_len: u64 },
    /// Payload bytes do not hash to the stored trailer checksum.
    ChecksumMismatch,
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::BadMagic => write!(f, "not an IRQC checkpoint"),
            CheckpointError::UnsupportedVersion(v) => {
                write!(f, "unsupported checkpoint version {v}")
            }
            CheckpointError::AbsurdCount { count, file_len } => write!(
                f,
                "corrupt checkpoint: header claims {count} tensors but the file \
                 is only {file_len} bytes"
            ),
            CheckpointError::PlanTooLarge { plan_len, file_len } => write!(
                f,
                "corrupt checkpoint: plan section of {plan_len} bytes \
                 (file is {file_len} bytes)"
            ),
            CheckpointError::NameTooLong(n) => {
                write!(f, "corrupt checkpoint: name length {n}")
            }
            CheckpointError::RankTooLarge(r) => write!(f, "corrupt checkpoint: rank {r}"),
            CheckpointError::TensorTooLarge(dims) => {
                write!(f, "corrupt checkpoint: tensor too large {dims:?}")
            }
            CheckpointError::DataOverrun { needed, file_len } => write!(
                f,
                "truncated checkpoint: tensor data needs {needed} bytes but the \
                 file is only {file_len} bytes"
            ),
            CheckpointError::ChecksumMismatch => {
                write!(f, "checkpoint checksum mismatch — file corrupt")
            }
        }
    }
}

impl std::error::Error for CheckpointError {}

/// Save without a plan — version-1 bytes, identical to every
/// checkpoint written before the mixed-precision planner existed.
pub fn save(nt: &NamedTensors, path: impl AsRef<Path>) -> Result<()> {
    save_impl(nt, None, path.as_ref())
}

/// Save with an attached [`PrecisionPlan`] (version-2 header).
pub fn save_with_plan(
    nt: &NamedTensors,
    plan: &PrecisionPlan,
    path: impl AsRef<Path>,
) -> Result<()> {
    save_impl(nt, Some(plan), path.as_ref())
}

fn save_impl(nt: &NamedTensors, plan: Option<&PrecisionPlan>, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    let version = if plan.is_some() { VERSION_PLANNED } else { VERSION };
    f.write_all(&version.to_le_bytes())?;
    f.write_all(&(nt.len() as u32).to_le_bytes())?;
    let mut check = FNV1A_SEED;
    if let Some(p) = plan {
        let blob = p.to_bytes();
        // refuse at write time what every reader would reject as
        // corrupt (and what the u32 length field cannot represent)
        if blob.len() > MAX_PLAN_BYTES {
            bail!(
                "precision plan serializes to {} bytes (cap {MAX_PLAN_BYTES})",
                blob.len()
            );
        }
        f.write_all(&(blob.len() as u32).to_le_bytes())?;
        check = fnv1a(check, &blob);
        f.write_all(&blob)?;
    }
    for (name, t) in nt.iter() {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&(t.shape().len() as u32).to_le_bytes())?;
        for &d in t.shape() {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        let bytes: Vec<u8> = t.data().iter().flat_map(|v| v.to_le_bytes()).collect();
        check = fnv1a(check, &bytes);
        f.write_all(&bytes)?;
    }
    f.write_all(&check.to_le_bytes())?;
    Ok(())
}

/// Open a checkpoint for reading plus its real on-disk length — the
/// bound every header-declared count and size is checked against.
fn open_checked(path: &Path) -> Result<(std::io::BufReader<std::fs::File>, u64)> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening checkpoint {}", path.display()))?;
    let file_len = f
        .metadata()
        .with_context(|| format!("opening checkpoint {}", path.display()))?
        .len();
    Ok((std::io::BufReader::new(f), file_len))
}

/// Shared header prelude of every reader: magic, version (validated
/// against the two known formats), tensor count (validated against
/// what `file_len` bytes could possibly hold, so a crafted count of
/// 2^32 cannot drive a 2^32-iteration parse loop or a pre-allocation).
fn read_prelude(f: &mut impl Read, file_len: u64) -> Result<(u32, usize)> {
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(CheckpointError::BadMagic.into());
    }
    let mut u32b = [0u8; 4];
    f.read_exact(&mut u32b)?;
    let version = u32::from_le_bytes(u32b);
    if version != VERSION && version != VERSION_PLANNED {
        return Err(CheckpointError::UnsupportedVersion(version).into());
    }
    f.read_exact(&mut u32b)?;
    let count = u32::from_le_bytes(u32b) as u64;
    if count > file_len / MIN_ENTRY_BYTES {
        return Err(CheckpointError::AbsurdCount { count, file_len }.into());
    }
    Ok((version, count as usize))
}

/// The version-2 plan section: length-prefixed blob, capped at
/// [`MAX_PLAN_BYTES`] and at the file's own size.
fn read_plan_blob(f: &mut impl Read, file_len: u64) -> Result<Vec<u8>> {
    let mut u32b = [0u8; 4];
    f.read_exact(&mut u32b)?;
    let plan_len = u32::from_le_bytes(u32b) as u64;
    if plan_len > MAX_PLAN_BYTES as u64 || plan_len > file_len {
        return Err(CheckpointError::PlanTooLarge { plan_len, file_len }.into());
    }
    let mut blob = vec![0u8; plan_len as usize];
    f.read_exact(&mut blob)?;
    Ok(blob)
}

/// Element count of a header's dims with overflow treated as
/// corruption (a crafted header like [2^33, 2^31] must not wrap to a
/// small product and dodge the size cap).
fn checked_elems(dims: &[usize]) -> Result<usize, CheckpointError> {
    dims.iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .filter(|&n| n <= 1 << 30)
        .ok_or_else(|| CheckpointError::TensorTooLarge(dims.to_vec()))
}

/// Bytes one tensor's f32 payload claims, rejected up front when it
/// cannot fit in the file — the guard that keeps `load` from
/// allocating gigabytes for a kilobyte of crafted header.
fn checked_data_len(n: usize, file_len: u64) -> Result<usize, CheckpointError> {
    let needed = n as u64 * 4;
    if needed > file_len {
        return Err(CheckpointError::DataOverrun { needed, file_len });
    }
    Ok(needed as usize)
}

/// Load the tensors of a (version 1 or 2) checkpoint, discarding any
/// attached plan — see [`load_with_plan`] to keep it.
pub fn load(path: impl AsRef<Path>) -> Result<NamedTensors> {
    Ok(load_with_plan(path)?.0)
}

/// Load a checkpoint plus its attached [`PrecisionPlan`], if the file
/// carries one (version-1 files never do).
pub fn load_with_plan(
    path: impl AsRef<Path>,
) -> Result<(NamedTensors, Option<PrecisionPlan>)> {
    let path = path.as_ref();
    let (mut f, file_len) = open_checked(path)?;
    let (version, count) =
        read_prelude(&mut f, file_len).with_context(|| format!("reading {}", path.display()))?;

    let mut out = NamedTensors::new();
    let mut check = FNV1A_SEED;
    let plan = if version == VERSION_PLANNED {
        let blob = read_plan_blob(&mut f, file_len)?;
        check = fnv1a(check, &blob);
        Some(PrecisionPlan::from_bytes(&blob).context("checkpoint precision plan")?)
    } else {
        None
    };
    let mut u32b = [0u8; 4];
    for _ in 0..count {
        f.read_exact(&mut u32b)?;
        let name_len = u32::from_le_bytes(u32b) as u64;
        if name_len > 4096 {
            return Err(CheckpointError::NameTooLong(name_len).into());
        }
        let mut name = vec![0u8; name_len as usize];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("non-utf8 tensor name")?;
        f.read_exact(&mut u32b)?;
        let rank = u32::from_le_bytes(u32b) as u64;
        if rank > 8 {
            return Err(CheckpointError::RankTooLarge(rank).into());
        }
        let mut dims = Vec::with_capacity(rank as usize);
        let mut u64b = [0u8; 8];
        for _ in 0..rank {
            f.read_exact(&mut u64b)?;
            dims.push(u64::from_le_bytes(u64b) as usize);
        }
        let n = checked_elems(&dims)?;
        let mut bytes = vec![0u8; checked_data_len(n, file_len)?];
        f.read_exact(&mut bytes)?;
        check = fnv1a(check, &bytes);
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push(name, Tensor::new(&dims, data));
    }
    let mut u64b = [0u8; 8];
    f.read_exact(&mut u64b)
        .context("truncated checkpoint (missing checksum)")?;
    if u64::from_le_bytes(u64b) != check {
        return Err(CheckpointError::ChecksumMismatch.into());
    }
    Ok((out, plan))
}

/// Read just the tensor names + shapes of a checkpoint, seeking past
/// the (potentially large) data payloads. Does NOT verify the
/// checksum — use [`load`] for a validated read; this exists for
/// cheap structural checks (e.g. "is this file an adapter?") before
/// committing to a full load, as the adapter registry does when
/// registering file-backed adapters.
pub fn peek_entries(path: impl AsRef<Path>) -> Result<Vec<(String, Vec<usize>)>> {
    use std::io::{Seek, SeekFrom};
    let path = path.as_ref();
    let (mut f, file_len) = open_checked(path)?;
    let (version, count) =
        read_prelude(&mut f, file_len).with_context(|| format!("reading {}", path.display()))?;
    if version == VERSION_PLANNED {
        read_plan_blob(&mut f, file_len)?; // peek skips the plan (it is small)
    }

    let mut u32b = [0u8; 4];
    let mut out = Vec::with_capacity(count.min(4096));
    for _ in 0..count {
        f.read_exact(&mut u32b)?;
        let name_len = u32::from_le_bytes(u32b) as u64;
        if name_len > 4096 {
            return Err(CheckpointError::NameTooLong(name_len).into());
        }
        let mut name = vec![0u8; name_len as usize];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("non-utf8 tensor name")?;
        f.read_exact(&mut u32b)?;
        let rank = u32::from_le_bytes(u32b) as u64;
        if rank > 8 {
            return Err(CheckpointError::RankTooLarge(rank).into());
        }
        let mut dims = Vec::with_capacity(rank as usize);
        let mut u64b = [0u8; 8];
        for _ in 0..rank {
            f.read_exact(&mut u64b)?;
            dims.push(u64::from_le_bytes(u64b) as usize);
        }
        let n = checked_elems(&dims)?;
        // a seek can't OOM and never fails past EOF, so peek must
        // check the span against the bytes actually left in the file —
        // the same truncation load would hit as a failed read_exact
        let span = checked_data_len(n, file_len)? as u64;
        let pos = f.stream_position()?;
        if pos.saturating_add(span) > file_len {
            return Err(CheckpointError::DataOverrun { needed: span, file_len }.into());
        }
        f.seek(SeekFrom::Current(span as i64))
            .context("seeking past tensor data")?;
        out.push((name, dims));
    }
    Ok(out)
}

/// Read just the attached [`PrecisionPlan`] of a checkpoint, without
/// touching tensor data. `Ok(None)` for version-1 (plan-less) files.
/// Like [`peek_entries`], this does NOT verify the file checksum.
pub fn peek_plan(path: impl AsRef<Path>) -> Result<Option<PrecisionPlan>> {
    let path = path.as_ref();
    let (mut f, file_len) = open_checked(path)?;
    let (version, _count) =
        read_prelude(&mut f, file_len).with_context(|| format!("reading {}", path.display()))?;
    if version != VERSION_PLANNED {
        return Ok(None);
    }
    let blob = read_plan_blob(&mut f, file_len)?;
    PrecisionPlan::from_bytes(&blob)
        .context("checkpoint precision plan")
        .map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("irqc_test_{name}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(3);
        let mut nt = NamedTensors::new();
        nt.push("embed", Tensor::new(&[4, 8], rng.normal_vec(32, 0.0, 1.0)));
        nt.push("scalar", Tensor::scalar(3.25));
        nt.push("l0.wq", Tensor::new(&[8, 8], rng.normal_vec(64, 0.0, 0.02)));
        let p = tmp("roundtrip");
        save(&nt, &p).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back.names(), nt.names());
        for (name, t) in nt.iter() {
            assert_eq!(back.get(name).unwrap(), t, "{name}");
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn corrupt_file_rejected() {
        let p = tmp("corrupt");
        std::fs::write(&p, b"IRQC\x01\x00\x00\x00garbage").unwrap();
        assert!(load(&p).is_err());
        std::fs::write(&p, b"NOPE").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn checksum_detects_bitflip() {
        let mut nt = NamedTensors::new();
        nt.push("w", Tensor::full(&[16], 1.0));
        let p = tmp("bitflip");
        save(&nt, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("checksum") || err.contains("corrupt"), "{err}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn peek_matches_saved_structure() {
        let mut nt = NamedTensors::new();
        nt.push("l0.wq.lora_a", Tensor::zeros(&[8, 4]));
        nt.push("l0.wq.lora_b", Tensor::zeros(&[4, 16]));
        nt.push("betas", Tensor::zeros(&[1, 7, 2]));
        let p = tmp("peek");
        save(&nt, &p).unwrap();
        let entries = peek_entries(&p).unwrap();
        assert_eq!(entries.len(), 3);
        assert_eq!(entries[0], ("l0.wq.lora_a".to_string(), vec![8, 4]));
        assert_eq!(entries[2], ("betas".to_string(), vec![1, 7, 2]));
        // peek is header-only; the full load still validates
        assert!(load(&p).is_ok());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn peek_rejects_non_checkpoint() {
        let p = tmp("peek_bad");
        std::fs::write(&p, b"NOPEnope").unwrap();
        assert!(peek_entries(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn overflowing_dims_rejected_not_wrapped() {
        // dims [2^33, 2^31] multiply to 2^64 ≡ 0 in wrapping usize —
        // must be treated as corruption, not a zero-element tensor
        let p = tmp("peek_overflow");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"IRQC");
        bytes.extend_from_slice(&1u32.to_le_bytes()); // version
        bytes.extend_from_slice(&1u32.to_le_bytes()); // count
        bytes.extend_from_slice(&1u32.to_le_bytes()); // name_len
        bytes.push(b'w');
        bytes.extend_from_slice(&2u32.to_le_bytes()); // rank
        bytes.extend_from_slice(&(1u64 << 33).to_le_bytes());
        bytes.extend_from_slice(&(1u64 << 31).to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(peek_entries(&p).is_err());
        assert!(load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn missing_file_clear_error() {
        let err = load("/nonexistent/ckpt.irqc").unwrap_err().to_string();
        assert!(err.contains("opening checkpoint"));
    }

    /// Header bytes up to and including `count`, with nothing after.
    fn header(version: u32, count: u32) -> Vec<u8> {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"IRQC");
        bytes.extend_from_slice(&version.to_le_bytes());
        bytes.extend_from_slice(&count.to_le_bytes());
        bytes
    }

    #[test]
    fn absurd_count_rejected_against_file_size() {
        // a 12-byte file claiming u32::MAX tensors must fail the
        // header check instantly — not spin u32::MAX loop iterations
        // of read_exact failures or pre-size any buffer from it
        let p = tmp("absurd_count");
        std::fs::write(&p, header(1, u32::MAX)).unwrap();
        for err in [
            load(&p).unwrap_err(),
            peek_entries(&p).map(|_| ()).unwrap_err(),
            peek_plan(&p).map(|_| ()).unwrap_err(),
        ] {
            let msg = format!("{err:#}");
            assert!(msg.contains("corrupt checkpoint"), "{msg}");
            assert!(msg.contains("4294967295"), "{msg}");
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn data_overrun_rejected_before_allocation() {
        // one tensor claiming 2^28 elements (1 GiB of f32) in a
        // ~40-byte file: the length check must fire before the data
        // buffer is allocated, for load and peek alike
        let p = tmp("data_overrun");
        let mut bytes = header(1, 1);
        bytes.extend_from_slice(&1u32.to_le_bytes()); // name_len
        bytes.push(b'w');
        bytes.extend_from_slice(&1u32.to_le_bytes()); // rank
        bytes.extend_from_slice(&(1u64 << 28).to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        for err in [load(&p).unwrap_err(), peek_entries(&p).map(|_| ()).unwrap_err()] {
            let msg = format!("{err:#}");
            assert!(msg.contains("truncated checkpoint"), "{msg}");
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn plan_length_capped_by_file_size() {
        // version-2 header whose plan_len field claims more bytes than
        // the file holds (but is still under MAX_PLAN_BYTES)
        let p = tmp("plan_overrun");
        let mut bytes = header(2, 0);
        bytes.extend_from_slice(&(1u32 << 20).to_le_bytes()); // plan_len: 1 MiB
        std::fs::write(&p, &bytes).unwrap();
        let msg = format!("{:#}", load_with_plan(&p).unwrap_err());
        assert!(msg.contains("plan section"), "{msg}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn truncated_files_error_at_every_cut() {
        // a valid checkpoint cut at any byte boundary must return Err
        // (never panic, hang, or Ok) from all three readers
        let mut nt = NamedTensors::new();
        nt.push("l0.wq", Tensor::full(&[4, 2], 0.5));
        nt.push("b", Tensor::full(&[3], -1.0));
        let p = tmp("truncate_sweep");
        save_with_plan(&nt, &sample_plan(), &p).unwrap();
        let full = std::fs::read(&p).unwrap();
        let plan_len =
            u32::from_le_bytes(full[12..16].try_into().unwrap()) as usize;
        for cut in 0..full.len() {
            std::fs::write(&p, &full[..cut]).unwrap();
            // load validates everything incl. the trailer checksum:
            // every proper prefix must fail
            assert!(load(&p).is_err(), "cut={cut} loaded");
            // peek stops after the last header entry (checksum is
            // explicitly unvalidated), so only cuts that remove entry
            // or data bytes must fail
            if cut + 8 < full.len() {
                assert!(peek_entries(&p).is_err(), "cut={cut} peeked");
            }
            // peek_plan needs header + plan section only
            if cut < 16 + plan_len {
                assert!(peek_plan(&p).is_err(), "cut={cut} peeked plan");
            }
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn typed_error_variants_surface() {
        let e = CheckpointError::AbsurdCount { count: 9, file_len: 12 };
        assert_eq!(e.clone(), e);
        assert!(e.to_string().contains("corrupt checkpoint"));
        // a typed error converts into the crate error via `?`
        fn f() -> Result<()> {
            Err(CheckpointError::ChecksumMismatch)?;
            Ok(())
        }
        assert!(format!("{:#}", f().unwrap_err()).contains("checksum"));
    }

    fn sample_plan() -> PrecisionPlan {
        use crate::precision::PlanEntry;
        PrecisionPlan {
            budget_bits: 3.2,
            block: 64,
            entries: vec![
                PlanEntry {
                    name: "l0.wq".into(),
                    k: 4,
                    n_params: 64,
                    entropy: 3.5,
                    bits_per_weight: 4.26,
                },
                PlanEntry {
                    name: "l0.wk".into(),
                    k: 2,
                    n_params: 64,
                    entropy: 1.9,
                    bits_per_weight: 2.26,
                },
            ],
        }
    }

    #[test]
    fn plan_section_roundtrips() {
        let mut nt = NamedTensors::new();
        nt.push("l0.wq", Tensor::full(&[8, 8], 0.25));
        let p = tmp("plan_roundtrip");
        let plan = sample_plan();
        save_with_plan(&nt, &plan, &p).unwrap();
        // header says version 2
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..4], b"IRQC");
        assert_eq!(u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]), 2);
        // all three readers agree
        let (back, got) = load_with_plan(&p).unwrap();
        assert_eq!(back.get("l0.wq").unwrap(), nt.get("l0.wq").unwrap());
        assert_eq!(got.as_ref(), Some(&plan));
        assert_eq!(peek_plan(&p).unwrap().as_ref(), Some(&plan));
        // plan-unaware load and peek_entries still work on v2 files
        let plain = load(&p).unwrap();
        assert_eq!(plain.len(), 1);
        assert_eq!(peek_entries(&p).unwrap(), vec![("l0.wq".to_string(), vec![8, 8])]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn plain_save_stays_version1_and_planless() {
        let mut nt = NamedTensors::new();
        nt.push("w", Tensor::full(&[4], 1.0));
        let p = tmp("still_v1");
        save(&nt, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]), 1);
        let (_, plan) = load_with_plan(&p).unwrap();
        assert!(plan.is_none());
        assert!(peek_plan(&p).unwrap().is_none());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn corrupt_plan_section_rejected() {
        let mut nt = NamedTensors::new();
        nt.push("w", Tensor::full(&[4], 1.0));
        let p = tmp("plan_bitflip");
        save_with_plan(&nt, &sample_plan(), &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // flip a byte inside the plan blob (starts after the 16-byte
        // header incl. plan_len)
        bytes[20] ^= 0x10;
        std::fs::write(&p, &bytes).unwrap();
        assert!(load_with_plan(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
