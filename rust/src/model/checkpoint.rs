//! Binary checkpoint format for [`NamedTensors`].
//!
//! Layout (little-endian):
//! ```text
//! magic "IRQC" | version u32 | count u32
//! version 2 only: plan_len u32 | plan bytes (precision::PrecisionPlan)
//! per tensor: name_len u32 | name bytes | rank u32 | dims u64* | f32 data
//! trailer: crc-ish checksum u64 (FNV-1a over plan bytes, then all
//!          tensor bytes; version 1 has no plan bytes)
//! ```
//! Version 1 is the original uniform-k format; [`save`] still writes
//! it byte-for-byte, so checkpoints produced before the mixed-
//! precision planner existed — and new plan-less saves — stay
//! identical and keep loading everywhere. Version 2
//! ([`save_with_plan`]) prepends a serialized
//! [`PrecisionPlan`] so a mixed-k artifact travels with the
//! allocation that produced it; [`load`] accepts both and plan-aware
//! callers use [`load_with_plan`] / [`peek_plan`].
//!
//! Used to cache pretrained base weights and finetuned adapters under
//! `runs/` so the table harness doesn't re-train on every invocation.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::precision::PrecisionPlan;
use crate::util::hash::{fnv1a, FNV1A_SEED};
use crate::util::Tensor;

use super::weights::NamedTensors;

const MAGIC: &[u8; 4] = b"IRQC";
const VERSION: u32 = 1;
/// Version written when a precision plan is attached.
const VERSION_PLANNED: u32 = 2;
/// Cap on the serialized plan section (a plan is a few dozen bytes per
/// tensor; anything near this is corruption).
const MAX_PLAN_BYTES: usize = 1 << 24;

/// Save without a plan — version-1 bytes, identical to every
/// checkpoint written before the mixed-precision planner existed.
pub fn save(nt: &NamedTensors, path: impl AsRef<Path>) -> Result<()> {
    save_impl(nt, None, path.as_ref())
}

/// Save with an attached [`PrecisionPlan`] (version-2 header).
pub fn save_with_plan(
    nt: &NamedTensors,
    plan: &PrecisionPlan,
    path: impl AsRef<Path>,
) -> Result<()> {
    save_impl(nt, Some(plan), path.as_ref())
}

fn save_impl(nt: &NamedTensors, plan: Option<&PrecisionPlan>, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(MAGIC)?;
    let version = if plan.is_some() { VERSION_PLANNED } else { VERSION };
    f.write_all(&version.to_le_bytes())?;
    f.write_all(&(nt.len() as u32).to_le_bytes())?;
    let mut check = FNV1A_SEED;
    if let Some(p) = plan {
        let blob = p.to_bytes();
        // refuse at write time what every reader would reject as
        // corrupt (and what the u32 length field cannot represent)
        if blob.len() > MAX_PLAN_BYTES {
            bail!(
                "precision plan serializes to {} bytes (cap {MAX_PLAN_BYTES})",
                blob.len()
            );
        }
        f.write_all(&(blob.len() as u32).to_le_bytes())?;
        check = fnv1a(check, &blob);
        f.write_all(&blob)?;
    }
    for (name, t) in nt.iter() {
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&(t.shape().len() as u32).to_le_bytes())?;
        for &d in t.shape() {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        let bytes: Vec<u8> = t.data().iter().flat_map(|v| v.to_le_bytes()).collect();
        check = fnv1a(check, &bytes);
        f.write_all(&bytes)?;
    }
    f.write_all(&check.to_le_bytes())?;
    Ok(())
}

/// Shared header prelude of every reader: magic, version (validated
/// against the two known formats), tensor count.
fn read_prelude(f: &mut impl Read) -> Result<(u32, usize)> {
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an IRQC checkpoint");
    }
    let mut u32b = [0u8; 4];
    f.read_exact(&mut u32b)?;
    let version = u32::from_le_bytes(u32b);
    if version != VERSION && version != VERSION_PLANNED {
        bail!("unsupported checkpoint version {version}");
    }
    f.read_exact(&mut u32b)?;
    Ok((version, u32::from_le_bytes(u32b) as usize))
}

/// The version-2 plan section: length-prefixed blob, capped at
/// [`MAX_PLAN_BYTES`].
fn read_plan_blob(f: &mut impl Read) -> Result<Vec<u8>> {
    let mut u32b = [0u8; 4];
    f.read_exact(&mut u32b)?;
    let plan_len = u32::from_le_bytes(u32b) as usize;
    if plan_len > MAX_PLAN_BYTES {
        bail!("corrupt checkpoint: plan section of {plan_len} bytes");
    }
    let mut blob = vec![0u8; plan_len];
    f.read_exact(&mut blob)?;
    Ok(blob)
}

/// Element count of a header's dims with overflow treated as
/// corruption (a crafted header like [2^33, 2^31] must not wrap to a
/// small product and dodge the size cap).
fn checked_elems(dims: &[usize]) -> Result<usize> {
    dims.iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .filter(|&n| n <= 1 << 30)
        .ok_or_else(|| anyhow::anyhow!("corrupt checkpoint: tensor too large {dims:?}"))
}

/// Load the tensors of a (version 1 or 2) checkpoint, discarding any
/// attached plan — see [`load_with_plan`] to keep it.
pub fn load(path: impl AsRef<Path>) -> Result<NamedTensors> {
    Ok(load_with_plan(path)?.0)
}

/// Load a checkpoint plus its attached [`PrecisionPlan`], if the file
/// carries one (version-1 files never do).
pub fn load_with_plan(
    path: impl AsRef<Path>,
) -> Result<(NamedTensors, Option<PrecisionPlan>)> {
    let path = path.as_ref();
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {}", path.display()))?,
    );
    let (version, count) =
        read_prelude(&mut f).with_context(|| format!("reading {}", path.display()))?;

    let mut out = NamedTensors::new();
    let mut check = FNV1A_SEED;
    let plan = if version == VERSION_PLANNED {
        let blob = read_plan_blob(&mut f)?;
        check = fnv1a(check, &blob);
        Some(PrecisionPlan::from_bytes(&blob).context("checkpoint precision plan")?)
    } else {
        None
    };
    let mut u32b = [0u8; 4];
    for _ in 0..count {
        f.read_exact(&mut u32b)?;
        let name_len = u32::from_le_bytes(u32b) as usize;
        if name_len > 4096 {
            bail!("corrupt checkpoint: name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("non-utf8 tensor name")?;
        f.read_exact(&mut u32b)?;
        let rank = u32::from_le_bytes(u32b) as usize;
        if rank > 8 {
            bail!("corrupt checkpoint: rank {rank}");
        }
        let mut dims = Vec::with_capacity(rank);
        let mut u64b = [0u8; 8];
        for _ in 0..rank {
            f.read_exact(&mut u64b)?;
            dims.push(u64::from_le_bytes(u64b) as usize);
        }
        let n = checked_elems(&dims)?;
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes)?;
        check = fnv1a(check, &bytes);
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push(name, Tensor::new(&dims, data));
    }
    let mut u64b = [0u8; 8];
    f.read_exact(&mut u64b)
        .context("truncated checkpoint (missing checksum)")?;
    if u64::from_le_bytes(u64b) != check {
        bail!("checkpoint checksum mismatch — file corrupt");
    }
    Ok((out, plan))
}

/// Read just the tensor names + shapes of a checkpoint, seeking past
/// the (potentially large) data payloads. Does NOT verify the
/// checksum — use [`load`] for a validated read; this exists for
/// cheap structural checks (e.g. "is this file an adapter?") before
/// committing to a full load, as the adapter registry does when
/// registering file-backed adapters.
pub fn peek_entries(path: impl AsRef<Path>) -> Result<Vec<(String, Vec<usize>)>> {
    use std::io::{Seek, SeekFrom};
    let path = path.as_ref();
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {}", path.display()))?,
    );
    let (version, count) =
        read_prelude(&mut f).with_context(|| format!("reading {}", path.display()))?;
    if version == VERSION_PLANNED {
        read_plan_blob(&mut f)?; // peek skips the plan (it is small)
    }

    let mut u32b = [0u8; 4];
    let mut out = Vec::with_capacity(count.min(4096));
    for _ in 0..count {
        f.read_exact(&mut u32b)?;
        let name_len = u32::from_le_bytes(u32b) as usize;
        if name_len > 4096 {
            bail!("corrupt checkpoint: name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("non-utf8 tensor name")?;
        f.read_exact(&mut u32b)?;
        let rank = u32::from_le_bytes(u32b) as usize;
        if rank > 8 {
            bail!("corrupt checkpoint: rank {rank}");
        }
        let mut dims = Vec::with_capacity(rank);
        let mut u64b = [0u8; 8];
        for _ in 0..rank {
            f.read_exact(&mut u64b)?;
            dims.push(u64::from_le_bytes(u64b) as usize);
        }
        let n = checked_elems(&dims)?;
        f.seek(SeekFrom::Current(n as i64 * 4))
            .context("seeking past tensor data")?;
        out.push((name, dims));
    }
    Ok(out)
}

/// Read just the attached [`PrecisionPlan`] of a checkpoint, without
/// touching tensor data. `Ok(None)` for version-1 (plan-less) files.
/// Like [`peek_entries`], this does NOT verify the file checksum.
pub fn peek_plan(path: impl AsRef<Path>) -> Result<Option<PrecisionPlan>> {
    let path = path.as_ref();
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint {}", path.display()))?,
    );
    let (version, _count) =
        read_prelude(&mut f).with_context(|| format!("reading {}", path.display()))?;
    if version != VERSION_PLANNED {
        return Ok(None);
    }
    let blob = read_plan_blob(&mut f)?;
    PrecisionPlan::from_bytes(&blob)
        .context("checkpoint precision plan")
        .map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("irqc_test_{name}_{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(3);
        let mut nt = NamedTensors::new();
        nt.push("embed", Tensor::new(&[4, 8], rng.normal_vec(32, 0.0, 1.0)));
        nt.push("scalar", Tensor::scalar(3.25));
        nt.push("l0.wq", Tensor::new(&[8, 8], rng.normal_vec(64, 0.0, 0.02)));
        let p = tmp("roundtrip");
        save(&nt, &p).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back.names(), nt.names());
        for (name, t) in nt.iter() {
            assert_eq!(back.get(name).unwrap(), t, "{name}");
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn corrupt_file_rejected() {
        let p = tmp("corrupt");
        std::fs::write(&p, b"IRQC\x01\x00\x00\x00garbage").unwrap();
        assert!(load(&p).is_err());
        std::fs::write(&p, b"NOPE").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn checksum_detects_bitflip() {
        let mut nt = NamedTensors::new();
        nt.push("w", Tensor::full(&[16], 1.0));
        let p = tmp("bitflip");
        save(&nt, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("checksum") || err.contains("corrupt"), "{err}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn peek_matches_saved_structure() {
        let mut nt = NamedTensors::new();
        nt.push("l0.wq.lora_a", Tensor::zeros(&[8, 4]));
        nt.push("l0.wq.lora_b", Tensor::zeros(&[4, 16]));
        nt.push("betas", Tensor::zeros(&[1, 7, 2]));
        let p = tmp("peek");
        save(&nt, &p).unwrap();
        let entries = peek_entries(&p).unwrap();
        assert_eq!(entries.len(), 3);
        assert_eq!(entries[0], ("l0.wq.lora_a".to_string(), vec![8, 4]));
        assert_eq!(entries[2], ("betas".to_string(), vec![1, 7, 2]));
        // peek is header-only; the full load still validates
        assert!(load(&p).is_ok());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn peek_rejects_non_checkpoint() {
        let p = tmp("peek_bad");
        std::fs::write(&p, b"NOPEnope").unwrap();
        assert!(peek_entries(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn overflowing_dims_rejected_not_wrapped() {
        // dims [2^33, 2^31] multiply to 2^64 ≡ 0 in wrapping usize —
        // must be treated as corruption, not a zero-element tensor
        let p = tmp("peek_overflow");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"IRQC");
        bytes.extend_from_slice(&1u32.to_le_bytes()); // version
        bytes.extend_from_slice(&1u32.to_le_bytes()); // count
        bytes.extend_from_slice(&1u32.to_le_bytes()); // name_len
        bytes.push(b'w');
        bytes.extend_from_slice(&2u32.to_le_bytes()); // rank
        bytes.extend_from_slice(&(1u64 << 33).to_le_bytes());
        bytes.extend_from_slice(&(1u64 << 31).to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(peek_entries(&p).is_err());
        assert!(load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn missing_file_clear_error() {
        let err = load("/nonexistent/ckpt.irqc").unwrap_err().to_string();
        assert!(err.contains("opening checkpoint"));
    }

    fn sample_plan() -> PrecisionPlan {
        use crate::precision::PlanEntry;
        PrecisionPlan {
            budget_bits: 3.2,
            block: 64,
            entries: vec![
                PlanEntry {
                    name: "l0.wq".into(),
                    k: 4,
                    n_params: 64,
                    entropy: 3.5,
                    bits_per_weight: 4.26,
                },
                PlanEntry {
                    name: "l0.wk".into(),
                    k: 2,
                    n_params: 64,
                    entropy: 1.9,
                    bits_per_weight: 2.26,
                },
            ],
        }
    }

    #[test]
    fn plan_section_roundtrips() {
        let mut nt = NamedTensors::new();
        nt.push("l0.wq", Tensor::full(&[8, 8], 0.25));
        let p = tmp("plan_roundtrip");
        let plan = sample_plan();
        save_with_plan(&nt, &plan, &p).unwrap();
        // header says version 2
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..4], b"IRQC");
        assert_eq!(u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]), 2);
        // all three readers agree
        let (back, got) = load_with_plan(&p).unwrap();
        assert_eq!(back.get("l0.wq").unwrap(), nt.get("l0.wq").unwrap());
        assert_eq!(got.as_ref(), Some(&plan));
        assert_eq!(peek_plan(&p).unwrap().as_ref(), Some(&plan));
        // plan-unaware load and peek_entries still work on v2 files
        let plain = load(&p).unwrap();
        assert_eq!(plain.len(), 1);
        assert_eq!(peek_entries(&p).unwrap(), vec![("l0.wq".to_string(), vec![8, 8])]);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn plain_save_stays_version1_and_planless() {
        let mut nt = NamedTensors::new();
        nt.push("w", Tensor::full(&[4], 1.0));
        let p = tmp("still_v1");
        save(&nt, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]), 1);
        let (_, plan) = load_with_plan(&p).unwrap();
        assert!(plan.is_none());
        assert!(peek_plan(&p).unwrap().is_none());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn corrupt_plan_section_rejected() {
        let mut nt = NamedTensors::new();
        nt.push("w", Tensor::full(&[4], 1.0));
        let p = tmp("plan_bitflip");
        save_with_plan(&nt, &sample_plan(), &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // flip a byte inside the plan blob (starts after the 16-byte
        // header incl. plan_len)
        bytes[20] ^= 0x10;
        std::fs::write(&p, &bytes).unwrap();
        assert!(load_with_plan(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
