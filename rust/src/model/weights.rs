//! Named tensor collections + weight initialization.
//!
//! The manifest's input specs define tensor names and shapes; this
//! module materializes values for them. Initialization mirrors
//! python/compile/model.py (GPT-2-style scaled normal, ones for norms,
//! ℓ1 ~ N(0, 1/√r) / ℓ2 = 0 / β = 0 for LoRA) so the Rust-driven
//! pretraining starts from the same distribution family the pytest
//! suite validates.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::runtime::InputSpec;
use crate::util::{Rng, Tensor};

/// Ordered, name-indexed tensor collection.
#[derive(Clone, Debug, Default)]
pub struct NamedTensors {
    names: Vec<String>,
    tensors: Vec<Tensor>,
    index: BTreeMap<String, usize>,
}

impl NamedTensors {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, name: impl Into<String>, t: Tensor) {
        let name = name.into();
        assert!(
            !self.index.contains_key(&name),
            "duplicate tensor name '{name}'"
        );
        self.index.insert(name.clone(), self.tensors.len());
        self.names.push(name);
        self.tensors.push(t);
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.index
            .get(name)
            .map(|&i| &self.tensors[i])
            .ok_or_else(|| anyhow!("tensor '{name}' not found"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        let i = *self
            .index
            .get(name)
            .ok_or_else(|| anyhow!("tensor '{name}' not found"))?;
        Ok(&mut self.tensors[i])
    }

    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        *self.get_mut(name)? = t;
        Ok(())
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Tensor)> {
        self.names.iter().map(|s| s.as_str()).zip(self.tensors.iter())
    }

    /// Tensors in push order (the manifest contract order).
    pub fn tensors(&self) -> &[Tensor] {
        &self.tensors
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }
}

/// Does this base tensor get quantized? (norms / embeddings / head stay
/// fp16 in QLoRA; only the 7 projection matrices per layer quantize.)
pub fn is_quantized_proj(name: &str) -> bool {
    name.starts_with('l')
        && (name.ends_with(".wq")
            || name.ends_with(".wk")
            || name.ends_with(".wv")
            || name.ends_with(".wo")
            || name.ends_with(".w1")
            || name.ends_with(".w3")
            || name.ends_with(".w2"))
}

/// The projection kind ("wq".."w2") of a quantized tensor name.
pub fn proj_kind(name: &str) -> Option<&str> {
    name.rsplit('.').next().filter(|k| {
        matches!(*k, "wq" | "wk" | "wv" | "wo" | "w1" | "w3" | "w2")
    })
}

/// The adapted projection kinds, in the order the `betas` tensor
/// `[n_layers, 7, 2]` is indexed (must match
/// `python/compile/config.py` `PROJ_KINDS`).
pub const PROJ_KINDS: [&str; 7] = ["wq", "wk", "wv", "wo", "w1", "w3", "w2"];

/// Index of a projection kind within [`PROJ_KINDS`] (= its middle
/// index into the `betas` tensor).
pub fn proj_index(kind: &str) -> Option<usize> {
    PROJ_KINDS.iter().position(|k| *k == kind)
}

/// Parse an adapted-projection stem `l{layer}.{kind}` (the prefix of
/// `*.lora_a` / `*.lora_b` tensor names) into (layer, betas
/// projection index).
pub fn parse_layer_proj(stem: &str) -> Option<(usize, usize)> {
    let rest = stem.strip_prefix('l')?;
    let (num, kind) = rest.split_once('.')?;
    if num.is_empty() || !num.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    Some((num.parse().ok()?, proj_index(kind)?))
}

/// Validate (name, shape) entries as an IEC-LoRA adapter: one `betas`
/// tensor `[n_layers, 7, 2]` plus at least one
/// `l{i}.{kind}.lora_a`/`.lora_b` pair agreeing on the LoRA rank,
/// with every pair's layer/kind indexable into `betas`. Shape-only on
/// purpose so it runs both on loaded adapters ([`validate_adapter`])
/// and on checkpoint headers (`checkpoint::peek_entries`) before the
/// data is read.
pub fn validate_adapter_shapes(entries: &[(String, Vec<usize>)]) -> Result<()> {
    let (_, bshape) = entries
        .iter()
        .find(|(n, _)| n == "betas")
        .ok_or_else(|| anyhow!("adapter has no 'betas' tensor"))?;
    if bshape.len() != 3 || bshape[1] != PROJ_KINDS.len() || bshape[2] != 2 {
        bail!(
            "betas shape {:?} != [n_layers, {}, 2]",
            bshape,
            PROJ_KINDS.len()
        );
    }
    let n_layers = bshape[0];
    let mut pairs = 0usize;
    for (name, shape) in entries {
        let Some(stem) = name.strip_suffix(".lora_a") else {
            continue;
        };
        let (layer, _) = parse_layer_proj(stem)
            .ok_or_else(|| anyhow!("'{name}' is not an adapted-projection tensor"))?;
        if layer >= n_layers {
            bail!("'{name}': layer {layer} outside betas ({n_layers} layers)");
        }
        if shape.len() != 2 {
            bail!("'{name}': lora_a must be rank 2, got {shape:?}");
        }
        let b_name = format!("{stem}.lora_b");
        let (_, b_shape) = entries
            .iter()
            .find(|(n, _)| n == &b_name)
            .ok_or_else(|| anyhow!("'{stem}': lora_a without lora_b"))?;
        if b_shape.len() != 2 || b_shape[0] != shape[1] {
            bail!(
                "'{stem}': lora_a {:?} and lora_b {:?} disagree on rank",
                shape,
                b_shape
            );
        }
        pairs += 1;
    }
    if pairs == 0 {
        bail!("adapter has no lora_a/lora_b pairs");
    }
    // orphan lora_b tensors would otherwise dodge the layer-bounds
    // check above and index out of `betas` at merge time
    for (name, _) in entries {
        let Some(stem) = name.strip_suffix(".lora_b") else {
            continue;
        };
        parse_layer_proj(stem)
            .ok_or_else(|| anyhow!("'{name}' is not an adapted-projection tensor"))?;
        let a_name = format!("{stem}.lora_a");
        if !entries.iter().any(|(n, _)| n == &a_name) {
            bail!("'{stem}': lora_b without lora_a");
        }
    }
    Ok(())
}

/// [`validate_adapter_shapes`] over a loaded adapter.
pub fn validate_adapter(nt: &NamedTensors) -> Result<()> {
    let entries: Vec<(String, Vec<usize>)> = nt
        .iter()
        .map(|(n, t)| (n.to_string(), t.shape().to_vec()))
        .collect();
    validate_adapter_shapes(&entries)
}

/// Initialize base weights for the given graph input specs (the first
/// `n` specs of the pretrain graph are the base tensors).
pub fn init_base(specs: &[InputSpec], n_layers: usize, rng: &mut Rng) -> NamedTensors {
    let mut out = NamedTensors::new();
    let residual_scale = 1.0 / (2.0 * n_layers as f32).sqrt();
    for s in specs {
        let n: usize = s.shape.iter().product();
        let t = if s.name.ends_with("norm") {
            Tensor::new(&s.shape, vec![1.0; n])
        } else {
            let mut std = 0.02f32;
            if s.name.ends_with(".wo") || s.name.ends_with(".w2") {
                std *= residual_scale;
            }
            Tensor::new(&s.shape, rng.normal_vec(n, 0.0, std))
        };
        out.push(s.name.clone(), t);
    }
    out
}

/// Initialize LoRA state for the given specs: a ~ N(0, 1/√r), b = 0,
/// betas = 0.
pub fn init_lora(specs: &[InputSpec], rank: usize, rng: &mut Rng) -> NamedTensors {
    let mut out = NamedTensors::new();
    let std = 1.0 / (rank as f32).sqrt();
    for s in specs {
        let n: usize = s.shape.iter().product();
        let t = if s.name.ends_with("lora_a") {
            Tensor::new(&s.shape, rng.normal_vec(n, 0.0, std))
        } else {
            Tensor::zeros(&s.shape)
        };
        out.push(s.name.clone(), t);
    }
    out
}

/// All-zeros state matching specs (Adam moments).
pub fn zeros_like(specs: &[InputSpec]) -> NamedTensors {
    let mut out = NamedTensors::new();
    for s in specs {
        out.push(s.name.clone(), Tensor::zeros(&s.shape));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Dtype;

    fn spec(name: &str, shape: &[usize]) -> InputSpec {
        InputSpec { name: name.into(), shape: shape.to_vec(), dtype: Dtype::F32 }
    }

    #[test]
    fn named_tensors_roundtrip() {
        let mut nt = NamedTensors::new();
        nt.push("a", Tensor::full(&[2, 2], 1.0));
        nt.push("b", Tensor::zeros(&[3]));
        assert_eq!(nt.len(), 2);
        assert_eq!(nt.get("a").unwrap().len(), 4);
        assert!(nt.get("c").is_err());
        nt.set("b", Tensor::full(&[3], 5.0)).unwrap();
        assert_eq!(nt.get("b").unwrap().data(), &[5.0; 3]);
        assert_eq!(nt.total_params(), 7);
    }

    #[test]
    #[should_panic]
    fn duplicate_names_panic() {
        let mut nt = NamedTensors::new();
        nt.push("a", Tensor::zeros(&[1]));
        nt.push("a", Tensor::zeros(&[1]));
    }

    #[test]
    fn quantized_proj_detection() {
        assert!(is_quantized_proj("l0.wq"));
        assert!(is_quantized_proj("l11.w2"));
        assert!(!is_quantized_proj("embed"));
        assert!(!is_quantized_proj("l0.attn_norm"));
        assert!(!is_quantized_proj("lm_head"));
        assert_eq!(proj_kind("l3.w1"), Some("w1"));
        assert_eq!(proj_kind("final_norm"), None);
    }

    #[test]
    fn layer_proj_parsing() {
        assert_eq!(proj_index("wq"), Some(0));
        assert_eq!(proj_index("w2"), Some(6));
        assert_eq!(proj_index("norm"), None);
        assert_eq!(parse_layer_proj("l0.wq"), Some((0, 0)));
        assert_eq!(parse_layer_proj("l11.w3"), Some((11, 5)));
        assert_eq!(parse_layer_proj("lm_head"), None);
        assert_eq!(parse_layer_proj("l2.attn_norm"), None);
        assert_eq!(parse_layer_proj("lx.wq"), None);
    }

    #[test]
    fn adapter_validation() {
        let ok = vec![
            ("l0.wq.lora_a".to_string(), vec![32usize, 8]),
            ("l0.wq.lora_b".to_string(), vec![8, 32]),
            ("betas".to_string(), vec![1, 7, 2]),
        ];
        assert!(validate_adapter_shapes(&ok).is_ok());

        let mut no_betas = ok.clone();
        no_betas.retain(|(n, _)| n != "betas");
        assert!(validate_adapter_shapes(&no_betas).is_err());

        let mut bad_betas = ok.clone();
        bad_betas[2].1 = vec![1, 3, 2];
        assert!(validate_adapter_shapes(&bad_betas).is_err());

        let mut widowed = ok.clone();
        widowed.retain(|(n, _)| n != "l0.wq.lora_b");
        assert!(validate_adapter_shapes(&widowed).is_err());

        let mut rank_mismatch = ok.clone();
        rank_mismatch[1].1 = vec![4, 32];
        assert!(validate_adapter_shapes(&rank_mismatch).is_err());

        let mut layer_oob = ok.clone();
        layer_oob[0].0 = "l9.wq.lora_a".to_string();
        layer_oob[1].0 = "l9.wq.lora_b".to_string();
        assert!(validate_adapter_shapes(&layer_oob).is_err());

        // orphan lora_b: would index betas out of bounds at merge time
        let mut orphan_b = ok.clone();
        orphan_b.push(("l5.wk.lora_b".to_string(), vec![8, 32]));
        assert!(validate_adapter_shapes(&orphan_b).is_err());

        // the NamedTensors flavor goes through the same checks
        let mut nt = NamedTensors::new();
        nt.push("l0.wq.lora_a", Tensor::zeros(&[32, 8]));
        nt.push("l0.wq.lora_b", Tensor::zeros(&[8, 32]));
        nt.push("betas", Tensor::zeros(&[1, 7, 2]));
        assert!(validate_adapter(&nt).is_ok());
    }

    #[test]
    fn init_base_distributions() {
        let specs = vec![
            spec("embed", &[64, 32]),
            spec("l0.attn_norm", &[32]),
            spec("l0.wq", &[32, 32]),
            spec("l0.wo", &[32, 32]),
        ];
        let mut rng = Rng::new(1);
        let w = init_base(&specs, 4, &mut rng);
        assert!(w.get("l0.attn_norm").unwrap().data().iter().all(|&x| x == 1.0));
        let std_q = crate::util::stats::std(w.get("l0.wq").unwrap().data());
        let std_o = crate::util::stats::std(w.get("l0.wo").unwrap().data());
        assert!((std_q - 0.02).abs() < 0.005, "{std_q}");
        assert!(std_o < std_q, "residual projections scaled down");
    }

    #[test]
    fn init_lora_structure() {
        let specs = vec![
            spec("l0.wq.lora_a", &[32, 8]),
            spec("l0.wq.lora_b", &[8, 32]),
            spec("betas", &[2, 7, 2]),
        ];
        let mut rng = Rng::new(2);
        let w = init_lora(&specs, 8, &mut rng);
        assert!(w.get("l0.wq.lora_b").unwrap().data().iter().all(|&x| x == 0.0));
        assert!(w.get("betas").unwrap().data().iter().all(|&x| x == 0.0));
        let std_a = crate::util::stats::std(w.get("l0.wq.lora_a").unwrap().data());
        assert!((std_a - 1.0 / (8.0f32).sqrt()).abs() < 0.05);
    }
}
