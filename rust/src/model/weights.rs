//! Named tensor collections + weight initialization.
//!
//! The manifest's input specs define tensor names and shapes; this
//! module materializes values for them. Initialization mirrors
//! python/compile/model.py (GPT-2-style scaled normal, ones for norms,
//! ℓ1 ~ N(0, 1/√r) / ℓ2 = 0 / β = 0 for LoRA) so the Rust-driven
//! pretraining starts from the same distribution family the pytest
//! suite validates.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::runtime::InputSpec;
use crate::util::{Rng, Tensor};

/// Ordered, name-indexed tensor collection.
#[derive(Clone, Debug, Default)]
pub struct NamedTensors {
    names: Vec<String>,
    tensors: Vec<Tensor>,
    index: BTreeMap<String, usize>,
}

impl NamedTensors {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, name: impl Into<String>, t: Tensor) {
        let name = name.into();
        assert!(
            !self.index.contains_key(&name),
            "duplicate tensor name '{name}'"
        );
        self.index.insert(name.clone(), self.tensors.len());
        self.names.push(name);
        self.tensors.push(t);
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.index
            .get(name)
            .map(|&i| &self.tensors[i])
            .ok_or_else(|| anyhow!("tensor '{name}' not found"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        let i = *self
            .index
            .get(name)
            .ok_or_else(|| anyhow!("tensor '{name}' not found"))?;
        Ok(&mut self.tensors[i])
    }

    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        *self.get_mut(name)? = t;
        Ok(())
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Tensor)> {
        self.names.iter().map(|s| s.as_str()).zip(self.tensors.iter())
    }

    /// Tensors in push order (the manifest contract order).
    pub fn tensors(&self) -> &[Tensor] {
        &self.tensors
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }
}

/// Does this base tensor get quantized? (norms / embeddings / head stay
/// fp16 in QLoRA; only the 7 projection matrices per layer quantize.)
pub fn is_quantized_proj(name: &str) -> bool {
    name.starts_with('l')
        && (name.ends_with(".wq")
            || name.ends_with(".wk")
            || name.ends_with(".wv")
            || name.ends_with(".wo")
            || name.ends_with(".w1")
            || name.ends_with(".w3")
            || name.ends_with(".w2"))
}

/// The projection kind ("wq".."w2") of a quantized tensor name.
pub fn proj_kind(name: &str) -> Option<&str> {
    name.rsplit('.').next().filter(|k| {
        matches!(*k, "wq" | "wk" | "wv" | "wo" | "w1" | "w3" | "w2")
    })
}

/// Initialize base weights for the given graph input specs (the first
/// `n` specs of the pretrain graph are the base tensors).
pub fn init_base(specs: &[InputSpec], n_layers: usize, rng: &mut Rng) -> NamedTensors {
    let mut out = NamedTensors::new();
    let residual_scale = 1.0 / (2.0 * n_layers as f32).sqrt();
    for s in specs {
        let n: usize = s.shape.iter().product();
        let t = if s.name.ends_with("norm") {
            Tensor::new(&s.shape, vec![1.0; n])
        } else {
            let mut std = 0.02f32;
            if s.name.ends_with(".wo") || s.name.ends_with(".w2") {
                std *= residual_scale;
            }
            Tensor::new(&s.shape, rng.normal_vec(n, 0.0, std))
        };
        out.push(s.name.clone(), t);
    }
    out
}

/// Initialize LoRA state for the given specs: a ~ N(0, 1/√r), b = 0,
/// betas = 0.
pub fn init_lora(specs: &[InputSpec], rank: usize, rng: &mut Rng) -> NamedTensors {
    let mut out = NamedTensors::new();
    let std = 1.0 / (rank as f32).sqrt();
    for s in specs {
        let n: usize = s.shape.iter().product();
        let t = if s.name.ends_with("lora_a") {
            Tensor::new(&s.shape, rng.normal_vec(n, 0.0, std))
        } else {
            Tensor::zeros(&s.shape)
        };
        out.push(s.name.clone(), t);
    }
    out
}

/// All-zeros state matching specs (Adam moments).
pub fn zeros_like(specs: &[InputSpec]) -> NamedTensors {
    let mut out = NamedTensors::new();
    for s in specs {
        out.push(s.name.clone(), Tensor::zeros(&s.shape));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Dtype;

    fn spec(name: &str, shape: &[usize]) -> InputSpec {
        InputSpec { name: name.into(), shape: shape.to_vec(), dtype: Dtype::F32 }
    }

    #[test]
    fn named_tensors_roundtrip() {
        let mut nt = NamedTensors::new();
        nt.push("a", Tensor::full(&[2, 2], 1.0));
        nt.push("b", Tensor::zeros(&[3]));
        assert_eq!(nt.len(), 2);
        assert_eq!(nt.get("a").unwrap().len(), 4);
        assert!(nt.get("c").is_err());
        nt.set("b", Tensor::full(&[3], 5.0)).unwrap();
        assert_eq!(nt.get("b").unwrap().data(), &[5.0; 3]);
        assert_eq!(nt.total_params(), 7);
    }

    #[test]
    #[should_panic]
    fn duplicate_names_panic() {
        let mut nt = NamedTensors::new();
        nt.push("a", Tensor::zeros(&[1]));
        nt.push("a", Tensor::zeros(&[1]));
    }

    #[test]
    fn quantized_proj_detection() {
        assert!(is_quantized_proj("l0.wq"));
        assert!(is_quantized_proj("l11.w2"));
        assert!(!is_quantized_proj("embed"));
        assert!(!is_quantized_proj("l0.attn_norm"));
        assert!(!is_quantized_proj("lm_head"));
        assert_eq!(proj_kind("l3.w1"), Some("w1"));
        assert_eq!(proj_kind("final_norm"), None);
    }

    #[test]
    fn init_base_distributions() {
        let specs = vec![
            spec("embed", &[64, 32]),
            spec("l0.attn_norm", &[32]),
            spec("l0.wq", &[32, 32]),
            spec("l0.wo", &[32, 32]),
        ];
        let mut rng = Rng::new(1);
        let w = init_base(&specs, 4, &mut rng);
        assert!(w.get("l0.attn_norm").unwrap().data().iter().all(|&x| x == 1.0));
        let std_q = crate::util::stats::std(w.get("l0.wq").unwrap().data());
        let std_o = crate::util::stats::std(w.get("l0.wo").unwrap().data());
        assert!((std_q - 0.02).abs() < 0.005, "{std_q}");
        assert!(std_o < std_q, "residual projections scaled down");
    }

    #[test]
    fn init_lora_structure() {
        let specs = vec![
            spec("l0.wq.lora_a", &[32, 8]),
            spec("l0.wq.lora_b", &[8, 32]),
            spec("betas", &[2, 7, 2]),
        ];
        let mut rng = Rng::new(2);
        let w = init_lora(&specs, 8, &mut rng);
        assert!(w.get("l0.wq.lora_b").unwrap().data().iter().all(|&x| x == 0.0));
        assert!(w.get("betas").unwrap().data().iter().all(|&x| x == 0.0));
        let std_a = crate::util::stats::std(w.get("l0.wq.lora_a").unwrap().data());
        assert!((std_a - 1.0 / (8.0f32).sqrt()).abs() < 0.05);
    }
}
