//! `native`: the cache-blocked CPU serve backend.
//!
//! Same logit contract as [`ReferenceBackend`] — bit-identical, row
//! for row — but engineered the way a real CPU kernel would be:
//!
//! - **Hoisted invariants.** The reference recomputes
//!   `1e-3 * base_fp`, `(v % 31) + 1`, and `(v % 7) + 1` inside the
//!   innermost vocab loop. Here the base term is folded once at
//!   construction and the two column-weight tables are precomputed
//!   per vocab slot, so the inner loop is two fused-shape f64 FMAs
//!   and a narrowing cast. Bit-identity holds because the arithmetic
//!   DAG per slot is unchanged (`(f0 + f1*w1[v]) + f2*w2[v]` is
//!   exactly how Rust parses the reference expression) — only *when*
//!   each subterm is computed moves, and f64 ops are deterministic.
//! - **Cache-blocked, column-strided inner loops.** Each row's
//!   `[seq, vocab]` tile is filled a [`COL_TILE`]-wide column stripe
//!   at a time: the stripe of `w1`/`w2` stays resident in L1 while
//!   every timestep streams over it.
//! - **Row-parallel execution** over [`crate::util::threads`]: rows
//!   are independent by contract, so a forward shards its `batch`
//!   rows across the worker pool (deterministic regardless of worker
//!   count — no cross-row reduction exists).
//! - **Packed-domain quantized construction.** [`NativeBackend::from_quantized`]
//!   reduces the base fingerprint straight out of packed k-bit
//!   storage, one [`FP_TILE`] tile at a time through
//!   [`crate::kernels::dot_packed`] — each tile's fingerprint is a
//!   dot of the packed codes against the integer position weights
//!   `((pos % 127) + 1)`, so neither the tile nor the full base is
//!   ever dequantized. This is the `packed_gemm` manifest capability
//!   the registry advertises for this backend. The tile width is 64
//!   quantization blocks, so every tile starts on a whole packed byte
//!   for every k in 1..=8 and the per-block scale slices index
//!   cleanly.
//! - **Native fused forward.** `forward_fused` is a true single
//!   launch: one delay, adapter fingerprints resolved once in group
//!   order (same cache traffic as the reference), then every owned
//!   row filled in one row-parallel sweep.

use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::backend::{
    device_cache_capacity, fingerprint, fingerprint_slice, KeyedLru, FP_TILE,
};
use crate::coordinator::{AdapterGroup, QuantizedModel, ServeBackend, UploadStats};
use crate::data::PAD;
use crate::model::weights::NamedTensors;

/// Column-stripe width for the blocked logit fill. 64 f64 weights per
/// table = two cache lines per stripe per table; both tables plus the
/// output stripe fit comfortably in L1.
const COL_TILE: usize = 64;

/// Cache-blocked CPU [`ServeBackend`], bit-identical to
/// [`crate::coordinator::ReferenceBackend`].
pub struct NativeBackend {
    batch: usize,
    seq: usize,
    vocab: usize,
    /// Base fingerprint (needed by tests/diagnostics comparing
    /// construction paths).
    base_fp: f64,
    /// Hoisted base term `1e-3 * base_fp`.
    f0: f64,
    /// Column weights `(v % 31) + 1`, one per vocab slot.
    w1: Vec<f64>,
    /// Column weights `(v % 7) + 1`, one per vocab slot.
    w2: Vec<f64>,
    /// `(name, generation)` → adapter fingerprint — the same
    /// [`KeyedLru`] the PJRT device cache and the reference
    /// fingerprint cache use.
    fp_cache: KeyedLru<f64>,
    /// Artificial per-forward latency (parity with the reference
    /// backend's test hook).
    pub forward_delay: Duration,
}

impl NativeBackend {
    /// Build over an already-dequantized shared base.
    pub fn new(batch: usize, seq: usize, vocab: usize, base: &NamedTensors) -> NativeBackend {
        Self::with_base_fp(batch, seq, vocab, fingerprint(base))
    }

    /// Build over a quantized model, folding the base fingerprint
    /// straight out of packed storage: tensors fold in collection
    /// order; a tensor whose packed form is tile-compatible
    /// (`FP_TILE % block == 0`) is reduced [`FP_TILE`] codes at a
    /// time by [`crate::kernels::dot_packed`] against the fingerprint
    /// position weights `((pos % 127) + 1)` (integers ≤ 127, exact in
    /// f32, so the dot is bit-identical to dequantize-then-
    /// [`crate::coordinator::backend::fp_tile_partial`] — see that
    /// function's weight definition);
    /// everything else (pass-through f32 tensors, exotic block sizes)
    /// falls back to the materialized values. No tile is ever
    /// dequantized. Lands on the exact bits of
    /// `new(.., &qm.dequantized)`.
    pub fn from_quantized(
        batch: usize,
        seq: usize,
        vocab: usize,
        qm: &QuantizedModel,
    ) -> NativeBackend {
        let mut fp = 0f64;
        let mut start = 0u64;
        let mut posw = vec![0f32; FP_TILE];
        let mut scales: Vec<f32> = Vec::new();
        let mut taus: Vec<f32> = Vec::new();
        for (name, t) in qm.dequantized.iter() {
            let data = t.data();
            let qt = qm
                .storage
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, qt)| qt)
                .filter(|qt| qt.block > 0 && FP_TILE % qt.block == 0 && qt.len == data.len());
            match qt {
                Some(qt) => {
                    qt.scales.dequantize_into(&mut scales);
                    let have_taus = match &qt.taus {
                        Some(tq) => {
                            tq.dequantize_into(&mut taus);
                            true
                        }
                        None => false,
                    };
                    let bytes_per_tile = FP_TILE * qt.k as usize / 8;
                    let mut lo = 0usize;
                    while lo < qt.len {
                        let tile_len = (qt.len - lo).min(FP_TILE);
                        let block_lo = lo / qt.block;
                        for (j, w) in posw[..tile_len].iter_mut().enumerate() {
                            *w = (((start + lo as u64 + j as u64 + 1) % 127) + 1) as f32;
                        }
                        fp += crate::kernels::dot_packed(
                            &qt.packed[lo / FP_TILE * bytes_per_tile..],
                            qt.k,
                            0,
                            tile_len,
                            qt.block,
                            &scales[block_lo..],
                            if have_taus { Some(&taus[block_lo..]) } else { None },
                            &posw[..tile_len],
                        );
                        lo += tile_len;
                    }
                }
                None => fp += fingerprint_slice(start, data),
            }
            start += data.len() as u64;
        }
        Self::with_base_fp(batch, seq, vocab, fp)
    }

    fn with_base_fp(batch: usize, seq: usize, vocab: usize, base_fp: f64) -> NativeBackend {
        assert!(batch > 0 && seq > 0 && vocab > 0);
        NativeBackend {
            batch,
            seq,
            vocab,
            base_fp,
            f0: 1e-3 * base_fp,
            w1: (0..vocab).map(|v| (v % 31) as f64 + 1.0).collect(),
            w2: (0..vocab).map(|v| (v % 7) as f64 + 1.0).collect(),
            fp_cache: KeyedLru::new(device_cache_capacity()),
            forward_delay: Duration::ZERO,
        }
    }

    /// Builder-style `forward_delay` (parity with the reference).
    pub fn with_forward_delay(mut self, delay: Duration) -> NativeBackend {
        self.forward_delay = delay;
        self
    }

    /// The base fingerprint this backend was constructed with —
    /// `from_quantized` and `new` must land on identical bits.
    pub fn base_fingerprint(&self) -> f64 {
        self.base_fp
    }

    /// Cached adapter fingerprint (same keying and counters as the
    /// reference/PJRT adapter caches).
    fn adapter_fp(&mut self, name: &str, generation: u64, weights: &Arc<NamedTensors>) -> f64 {
        if let Some(idx) = self.fp_cache.touch(name, generation) {
            return *self.fp_cache.get(idx);
        }
        let fp = fingerprint(weights);
        self.fp_cache.insert(name, generation, fp);
        fp
    }

    /// Fill one row's `[seq, vocab]` logits under hoisted adapter term
    /// `f1 = 1e-2 * afp`: prefix terms first (one pass over the
    /// tokens), then a column-striped sweep.
    fn fill_row(&self, f1: f64, row_tokens: &[i32], out_row: &mut [f32]) {
        debug_assert_eq!(row_tokens.len(), self.seq);
        debug_assert_eq!(out_row.len(), self.seq * self.vocab);
        // per-timestep prefix terms f2 = 1e-4 * prefix
        let mut f2s = vec![0f64; self.seq];
        let mut prefix = 0f64;
        for (t, &tok) in row_tokens.iter().enumerate() {
            if tok != PAD {
                prefix += (t as f64 + 1.0) * (tok as f64 + 1.0);
            }
            f2s[t] = 1e-4 * prefix;
        }
        // column-striped fill: one COL_TILE stripe of w1/w2 serves
        // every timestep before moving on
        let mut vt = 0usize;
        while vt < self.vocab {
            let ve = (vt + COL_TILE).min(self.vocab);
            let w1 = &self.w1[vt..ve];
            let w2 = &self.w2[vt..ve];
            for (t, &f2) in f2s.iter().enumerate() {
                let stripe = &mut out_row[t * self.vocab + vt..t * self.vocab + ve];
                for ((slot, &a), &b) in stripe.iter_mut().zip(w1).zip(w2) {
                    *slot = ((self.f0 + f1 * a) + f2 * b) as f32;
                }
            }
            vt = ve;
        }
    }

    /// Fill one row's `[vocab]` next-token logits at position
    /// `len - 1` — the single-position analogue of [`Self::fill_row`].
    /// Same prefix fold (t-order over the first `len` tokens), same
    /// per-slot DAG `(f0 + f1*a) + f2*b`, one column-striped sweep, so
    /// the result is bit-identical to slot `len - 1` of the full row.
    fn step_row_into(&self, f1: f64, row_tokens: &[i32], len: usize, out_row: &mut [f32]) {
        debug_assert!(len >= 1 && len <= row_tokens.len());
        debug_assert_eq!(out_row.len(), self.vocab);
        let mut prefix = 0f64;
        for (t, &tok) in row_tokens.iter().enumerate().take(len) {
            if tok != PAD {
                prefix += (t as f64 + 1.0) * (tok as f64 + 1.0);
            }
        }
        let f2 = 1e-4 * prefix;
        let mut vt = 0usize;
        while vt < self.vocab {
            let ve = (vt + COL_TILE).min(self.vocab);
            let w1 = &self.w1[vt..ve];
            let w2 = &self.w2[vt..ve];
            let stripe = &mut out_row[vt..ve];
            for ((slot, &a), &b) in stripe.iter_mut().zip(w1).zip(w2) {
                *slot = ((self.f0 + f1 * a) + f2 * b) as f32;
            }
            vt = ve;
        }
    }

    /// Shard `out`'s rows across the thread pool and fill row `b`
    /// under `owner(b)`'s hoisted adapter term (`None` = padding row,
    /// left zeroed — same as the reference).
    fn fill_rows(&self, owners: &[Option<f64>], tokens: &[i32], out: &mut [f32]) {
        let (seq, vocab) = (self.seq, self.vocab);
        crate::util::threads::par_chunks_mut_with(out, seq * vocab, 2, |b, row_out| {
            if let Some(f1) = owners[b] {
                self.fill_row(f1, &tokens[b * seq..(b + 1) * seq], row_out);
            }
        });
    }
}

/// `hal.forward_time{backend=native}` / `hal.fused_forward_time{...}`
/// timers, resolved once per process (no-op handles when telemetry is
/// disabled).
fn telem_native() -> &'static crate::coordinator::backend::ForwardTimers {
    static T: std::sync::OnceLock<crate::coordinator::backend::ForwardTimers> =
        std::sync::OnceLock::new();
    T.get_or_init(|| crate::coordinator::backend::ForwardTimers::resolve("native"))
}

impl ServeBackend for NativeBackend {
    fn shape(&self) -> (usize, usize, usize) {
        (self.batch, self.seq, self.vocab)
    }

    fn forward(
        &mut self,
        name: &str,
        generation: u64,
        weights: &Arc<NamedTensors>,
        tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let _t = telem_native().forward.start();
        if tokens.len() != self.batch * self.seq {
            bail!(
                "token matrix has {} elems, expected batch*seq = {}",
                tokens.len(),
                self.batch * self.seq
            );
        }
        if !self.forward_delay.is_zero() {
            std::thread::sleep(self.forward_delay);
        }
        let f1 = 1e-2 * self.adapter_fp(name, generation, weights);
        let mut out = vec![0f32; self.batch * self.seq * self.vocab];
        let owners = vec![Some(f1); self.batch];
        self.fill_rows(&owners, tokens, &mut out);
        Ok(out)
    }

    /// Native single-launch fused forward: one delay, fingerprints
    /// resolved once in group order (cache-traffic parity with the
    /// reference), one row-parallel sweep over the whole batch.
    fn forward_fused(&mut self, groups: &[AdapterGroup], tokens: &[i32]) -> Result<Vec<f32>> {
        let _t = telem_native().fused.start();
        if tokens.len() != self.batch * self.seq {
            bail!(
                "token matrix has {} elems, expected batch*seq = {}",
                tokens.len(),
                self.batch * self.seq
            );
        }
        for g in groups {
            if g.rows.end > self.batch {
                bail!(
                    "adapter group '{}' rows {}..{} exceed batch {}",
                    g.name,
                    g.rows.start,
                    g.rows.end,
                    self.batch
                );
            }
        }
        if !self.forward_delay.is_zero() {
            std::thread::sleep(self.forward_delay);
        }
        let mut owners: Vec<Option<f64>> = vec![None; self.batch];
        for g in groups {
            let f1 = 1e-2 * self.adapter_fp(&g.name, g.generation, &g.weights);
            for row in g.rows.clone() {
                owners[row] = Some(f1);
            }
        }
        let mut out = vec![0f32; self.batch * self.seq * self.vocab];
        self.fill_rows(&owners, tokens, &mut out);
        Ok(out)
    }

    /// Native single-position streaming step: one delay, fingerprints
    /// resolved once in group order, then only position `lens[b] - 1`
    /// of each owned row is computed (row-parallel over the step's
    /// `[batch, vocab]` output).
    fn forward_step(
        &mut self,
        groups: &[AdapterGroup],
        tokens: &[i32],
        lens: &[usize],
    ) -> Result<Vec<f32>> {
        let _t = telem_native().step.start();
        if tokens.len() != self.batch * self.seq {
            bail!(
                "token matrix has {} elems, expected batch*seq = {}",
                tokens.len(),
                self.batch * self.seq
            );
        }
        if lens.len() != self.batch {
            bail!("lens has {} entries, expected batch = {}", lens.len(), self.batch);
        }
        for g in groups {
            if g.rows.end > self.batch {
                bail!(
                    "adapter group '{}' rows {}..{} exceed batch {}",
                    g.name,
                    g.rows.start,
                    g.rows.end,
                    self.batch
                );
            }
            for row in g.rows.clone() {
                if !(1..=self.seq).contains(&lens[row]) {
                    bail!("row {row} prefix length {} out of range 1..={}", lens[row], self.seq);
                }
            }
        }
        if !self.forward_delay.is_zero() {
            std::thread::sleep(self.forward_delay);
        }
        let mut owners: Vec<Option<f64>> = vec![None; self.batch];
        for g in groups {
            let f1 = 1e-2 * self.adapter_fp(&g.name, g.generation, &g.weights);
            for row in g.rows.clone() {
                owners[row] = Some(f1);
            }
        }
        let (seq, vocab) = (self.seq, self.vocab);
        let mut out = vec![0f32; self.batch * vocab];
        crate::util::threads::par_chunks_mut_with(&mut out, vocab, 2, |b, row_out| {
            if let Some(f1) = owners[b] {
                self.step_row_into(f1, &tokens[b * seq..(b + 1) * seq], lens[b], row_out);
            }
        });
        Ok(out)
    }

    fn upload_stats(&self) -> UploadStats {
        self.fp_cache.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ReferenceBackend;
    use crate::util::{Rng, Tensor};

    fn named(seed: u64, n: usize) -> NamedTensors {
        let mut rng = Rng::new(seed);
        let mut nt = NamedTensors::new();
        nt.push("w", Tensor::new(&[n], rng.normal_vec(n, 0.0, 1.0)));
        nt
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: slot {i}: {x} vs {y}");
        }
    }

    #[test]
    fn forward_bit_identical_to_reference() {
        let base = named(3, FP_TILE + 777); // multi-tile base
        let (batch, seq, vocab) = (4usize, 6usize, 97usize); // vocab not a COL_TILE multiple
        let mut native = NativeBackend::new(batch, seq, vocab, &base);
        let mut refer = ReferenceBackend::new(batch, seq, vocab, &base);
        let w = Arc::new(named(4, 33));
        let mut toks = vec![PAD; batch * seq];
        for (i, t) in toks.iter_mut().enumerate().take(batch * seq - 5) {
            *t = (i * 13 % 50) as i32;
        }
        let a = native.forward("a", 0, &w, &toks).unwrap();
        let b = refer.forward("a", 0, &w, &toks).unwrap();
        assert_bits_eq(&a, &b, "single-adapter forward");
        // and the adapter cache behaves identically
        native.forward("a", 0, &w, &toks).unwrap();
        refer.forward("a", 0, &w, &toks).unwrap();
        assert_eq!(native.upload_stats(), refer.upload_stats());
    }

    #[test]
    fn fused_bit_identical_to_reference_fused() {
        let base = named(7, 200);
        let (batch, seq, vocab) = (5usize, 4usize, 70usize);
        let w: Vec<Arc<NamedTensors>> =
            (0..3).map(|i| Arc::new(named(10 + i, 24))).collect();
        let mut tokens = vec![PAD; batch * seq];
        for (row, len) in [(0usize, 3usize), (1, 1), (2, 4), (3, 2)] {
            for t in 0..len {
                tokens[row * seq + t] = (row * 7 + t * 3 + 1) as i32;
            }
        }
        // row 4 unowned: both backends must leave it zeroed
        let groups: Vec<AdapterGroup> = [(0usize, 0usize..2), (1, 2..3), (2, 3..4)]
            .into_iter()
            .map(|(i, rows)| AdapterGroup {
                name: format!("t{i}"),
                generation: i as u64,
                weights: w[i].clone(),
                rows,
            })
            .collect();
        let mut native = NativeBackend::new(batch, seq, vocab, &base);
        let mut refer = ReferenceBackend::new(batch, seq, vocab, &base);
        let a = native.forward_fused(&groups, &tokens).unwrap();
        let b = refer.forward_fused(&groups, &tokens).unwrap();
        assert_bits_eq(&a, &b, "fused forward");
        assert_eq!(native.upload_stats(), refer.upload_stats());
        // out-of-range rows rejected, same as the reference
        let bad = AdapterGroup {
            name: "t0".into(),
            generation: 0,
            weights: w[0].clone(),
            rows: 4..batch + 1,
        };
        assert!(native.forward_fused(&[bad], &tokens).is_err());
        // wrong token-matrix size rejected
        assert!(native.forward("a", 0, &w[0], &[1, 2]).is_err());
    }

    /// The native single-position step must agree bit-for-bit with the
    /// reference step AND with slicing the native fused forward at
    /// each row's live position.
    #[test]
    fn step_bit_identical_to_reference_step_and_fused_slice() {
        let base = named(7, 200);
        let (batch, seq, vocab) = (5usize, 4usize, 70usize);
        let w: Vec<Arc<NamedTensors>> =
            (0..3).map(|i| Arc::new(named(10 + i, 24))).collect();
        let row_lens = [(0usize, 3usize), (1, 1), (2, 4), (3, 2)];
        let mut tokens = vec![PAD; batch * seq];
        for (row, len) in row_lens {
            for t in 0..len {
                tokens[row * seq + t] = (row * 7 + t * 3 + 1) as i32;
            }
        }
        // row 4 unowned: lens entry ignored, output row left zeroed
        let mut lens = [1usize; 5];
        for (row, len) in row_lens {
            lens[row] = len;
        }
        let groups: Vec<AdapterGroup> = [(0usize, 0usize..2), (1, 2..3), (2, 3..4)]
            .into_iter()
            .map(|(i, rows)| AdapterGroup {
                name: format!("t{i}"),
                generation: i as u64,
                weights: w[i].clone(),
                rows,
            })
            .collect();
        let mut native = NativeBackend::new(batch, seq, vocab, &base);
        let mut refer = ReferenceBackend::new(batch, seq, vocab, &base);
        let a = native.forward_step(&groups, &tokens, &lens).unwrap();
        let b = refer.forward_step(&groups, &tokens, &lens).unwrap();
        assert_bits_eq(&a, &b, "streamed step");
        let fused = native.forward_fused(&groups, &tokens).unwrap();
        for (row, len) in row_lens {
            let want = &fused[(row * seq + len - 1) * vocab..(row * seq + len) * vocab];
            assert_bits_eq(&a[row * vocab..(row + 1) * vocab], want, "fused slice");
        }
        assert!(a[4 * vocab..].iter().all(|&x| x == 0.0), "unowned row stays zeroed");
        // malformed lens rejected
        assert!(native.forward_step(&groups, &tokens, &lens[..3]).is_err());
        let mut zero = lens;
        zero[0] = 0;
        assert!(native.forward_step(&groups, &tokens, &zero).is_err());
    }

    /// The streaming packed-storage construction must land on the
    /// exact base fingerprint of construction over the materialized
    /// dequantized base — this is the "no full dequantized base" path
    /// earning its bit-identity contract.
    #[test]
    fn from_quantized_matches_dequantized_construction() {
        use crate::coordinator::quantize::quantize_model;
        use crate::quant::Method;

        let mut rng = Rng::new(42);
        let mut model = NamedTensors::new();
        // projection tensors (quantized, multi-tile) + a pass-through
        let n = FP_TILE * 2 + 640; // block-aligned ragged tail
        model.push("l0.wq", Tensor::new(&[n / 64, 64], rng.normal_vec(n, 0.0, 0.7)));
        model.push("l0.wk", Tensor::new(&[8, 64], rng.normal_vec(512, 0.0, 0.7)));
        model.push("embed", Tensor::new(&[300], rng.normal_vec(300, 0.0, 0.7)));
        // NF-family methods populate packed storage → the streaming
        // tile path runs; the Int method stores no packed form → the
        // materialized fallback runs. Both must agree with `new`.
        for (method, streams) in [
            (Method::Nf { k: 4 }, true),
            (Method::NfIcq { k: 2 }, true),
            (Method::NfIcq { k: 8 }, true),
            (Method::IntIcq { k: 3 }, false),
        ] {
            let qm = quantize_model(&model, method, 64).unwrap();
            assert_eq!(!qm.storage.is_empty(), streams, "{method:?}");
            let streamed = NativeBackend::from_quantized(2, 4, 8, &qm);
            let materialized = NativeBackend::new(2, 4, 8, &qm.dequantized);
            assert_eq!(
                streamed.base_fingerprint().to_bits(),
                materialized.base_fingerprint().to_bits(),
                "{method:?}"
            );
        }
    }
}
